"""Streaming refits closing the fleet loop: the drift scenario.

A sustained 2x cluster-wide slowdown hits mid-run.  With
``FleetConfig.drift`` on, the scheduler's per-job DriftDetector fires
within a few ticks of onset, refits the job's pace factor from the
new-regime window (``RefitEvent.residual_after < residual_before``), and
forces a replanning pass that rescues the deadline.  The control arm
(``drift=False``) runs the identical scenario open-loop and misses.

Golden fixture: fleet_drift_seed0.json (regenerate with
tests/fixtures/make_fleet_drift_fixture.py).  Replay guarantees mirror
tests/test_fleet.py: in-process replay is BIT-identical on the full
signature — including replay reconstructed from the JSONL event log.
"""
from pathlib import Path

import pytest

from repro.fleet import (
    FleetRunLog,
    build_drift_scenario,
    replay,
    run_fleet_sim,
)

FIXTURES = Path(__file__).resolve().parent / "fixtures"


@pytest.fixture(scope="module")
def drift_run():
    return run_fleet_sim(0, scenario="drift", drift=True)


@pytest.fixture(scope="module")
def control_run():
    return run_fleet_sim(0, scenario="drift", drift=False)


# ------------------------------------------------------- the closed loop
def test_drift_run_detects_and_replans(drift_run):
    """Detector fires a few ticks after the injected slowdown (not before)
    and the very next tick carries a deadline-rescue resize."""
    onset = min(e.step for e in drift_run.trace.events
                if e.kind == "slowdown")
    drifts = drift_run.decisions("drift:job_drift")
    assert drifts, "no drift decision recorded"
    first = drifts[0][0]
    assert onset <= first <= onset + 8, (onset, first)
    rescues = [(s, d) for s, d in drift_run.decisions("resize:job_drift")
               if d.endswith(":deadline") and s > first]
    assert rescues and rescues[0][0] <= first + 2, rescues


def test_drift_arm_meets_deadline_control_misses(drift_run, control_run):
    drifted = drift_run.meta["summary"]["jobs"]["job_drift"]
    control = control_run.meta["summary"]["jobs"]["job_drift"]
    assert drifted["state"] == "done" and drifted["met_deadline"]
    assert control["state"] == "done" and not control["met_deadline"]
    # the open-loop arm never sees the drift; its model only notices via
    # lagging progress, tens of ticks later
    assert not control_run.decisions("drift:")
    late = control_run.decisions("resize:job_drift")
    first_drift = drift_run.decisions("drift:")[0][0]
    assert all(s > first_drift + 20 for s, _ in late), late


def test_refit_reduces_residuals(drift_run):
    """Every RefitEvent on the bus fits the new regime better than the
    stale model that triggered it, and pairs with a DriftDetected."""
    refits = drift_run.events("refit")
    detected = drift_run.events("drift")
    assert refits and len(refits) == len(detected)
    for det, ref in zip(detected, refits):
        assert det.step == ref.step and det.model == ref.model
        assert ref.residual_before == pytest.approx(det.residual)
        assert ref.residual_after < ref.residual_before
        assert det.residual > det.threshold


def test_drift_events_stay_out_of_rows(drift_run):
    """Drift/refit telemetry rides the same bus but never leaks into the
    row stream or signatures (so pre-drift goldens stay comparable)."""
    kinds = {e.kind for e in drift_run.events()}
    assert {"fleet_tick", "drift", "refit"} <= kinds
    assert len(drift_run.rows) == len(drift_run.events("fleet_tick"))
    assert all(r.keys() == drift_run.rows[0].keys()
               for r in drift_run.rows)


# ------------------------------------------------------- replay + golden
def test_drift_replay_is_bit_identical(drift_run):
    again = replay(drift_run)
    assert again.signature() == drift_run.signature()
    assert again.meta["summary"] == drift_run.meta["summary"]


def test_drift_replay_from_event_log(drift_run, tmp_path):
    """to_jsonl -> from_jsonl -> replay: the JSONL event log alone (header
    + typed events) reconstructs a log that replays bit-identically."""
    p = tmp_path / "drift.jsonl"
    drift_run.to_jsonl(p)
    back = FleetRunLog.from_jsonl(p)
    assert back.signature() == drift_run.signature()
    assert ([e.to_dict() for e in back.events()]
            == [e.to_dict() for e in drift_run.events()])
    again = replay(back)
    assert again.signature() == drift_run.signature()


def test_golden_drift_trace(drift_run):
    """The checked-in golden drift log replays exactly on the control
    sequence and to float tolerance on modeled quantities."""
    golden = FleetRunLog.load(FIXTURES / "fleet_drift_seed0.json")
    assert drift_run.control_signature() == golden.control_signature()
    for got, want in zip(drift_run.rows, golden.rows):
        for name, wj in want["jobs"].items():
            gj = got["jobs"][name]
            assert gj["prog"] == pytest.approx(wj["prog"], rel=1e-6,
                                               abs=1e-9)
        assert got["cost_hh"] == pytest.approx(want["cost_hh"], rel=1e-9)


def test_golden_drift_fixture_is_self_consistent():
    """The fixture's embedded trace regenerates from the scenario builder
    at its recorded seed — golden files cannot drift from the generator."""
    golden = FleetRunLog.load(FIXTURES / "fleet_drift_seed0.json")
    regen, _, _, _ = build_drift_scenario(int(golden.meta["seed"]))
    assert regen == golden.trace
    assert golden.meta["scenario"] == "drift" and golden.meta["drift"]
