"""End-to-end Hemingway: simulate -> fit f(m), g(i,m) -> plan -> adapt.

This is the paper's Figure-2 loop on a small (but real) convex workload.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    AdaptiveController,
    CombinedModel,
    ConvergenceData,
    ConvergenceModel,
    ErnestModel,
    Planner,
)
from repro.optim import BSPCluster, ERMProblem, synthetic_mnist
from repro.optim.simcluster import solve_reference


@pytest.fixture(scope="module")
def setup():
    X, y = synthetic_mnist(4096, 128, 32, 0.09, 0.35, 0)
    problem = ERMProblem(jnp.asarray(X), jnp.asarray(y), lam=1e-3,
                         loss="hinge")
    cluster = BSPCluster()
    p_star, _ = solve_reference(problem, iters=120)
    ms = (1, 2, 4, 8, 16)
    sims = {m: cluster.simulate(problem, "cocoa", m, 30, seed=2) for m in ms}
    return problem, cluster, p_star, sims


def test_fit_and_combine(setup):
    problem, cluster, p_star, sims = setup
    curves = {m: np.minimum.accumulate(s.record.primal)
              for m, s in sims.items()}
    data = ConvergenceData.from_curves(curves, p_star - 1e-5, stop_gap=None)
    conv = ConvergenceModel().fit(data)
    assert conv.r2(data) > 0.8
    ms = sorted(sims)
    times = [sims[m].t_iter for m in ms]
    sys_model = ErnestModel().fit(np.asarray(ms, float),
                                  np.full(len(ms), problem.n, float),
                                  np.asarray(times))
    cm = CombinedModel(sys_model, conv, data_size=problem.n, max_iters=2000)
    # monotonicity is asserted in ITERATION space (deterministic): the
    # fitted g(i, m) must improve over the fitted horizon.  h(t, m) itself
    # folds in measured step times (timing-noisy on a shared CPU), so for h
    # we only require finite, in-range values.
    g = conv.predict(np.asarray([5.0, 15.0, 30.0]), 8)
    assert g[0] >= g[1] - 0.05 * abs(g[1])
    assert g[1] >= g[2] - 0.05 * abs(g[2])
    h = cm.h(np.asarray([1.0, 5.0]), 8)
    assert np.all(np.isfinite(h)) and np.all(h > p_star - 0.2)
    planner = Planner({"cocoa": cm})
    target = p_star + 0.02
    decision = planner.fastest_to_epsilon(target - (p_star - 1e-5),
                                          m_grid=list(ms))
    assert decision.m in ms
    assert decision.predicted_time > 0


def test_adaptive_controller_reacts():
    """Feed the controller a slow-converging run where larger m is predicted
    (by its own models) to finish sooner."""
    sys_model = ErnestModel().fit(
        np.array([1, 2, 4, 8, 16]), np.full(5, 1000.0),
        # times nearly flat in m -> more machines are nearly free
        np.array([1.00, 0.52, 0.27, 0.15, 0.09]))
    ctrl = AdaptiveController(
        sys_model, target_gap=1e-4, p_star=0.0, m_options=[1, 4, 16],
        data_size=1000.0, refit_every=10, min_observations=20,
        reshard_cost_s=0.5)
    decision = None
    for i in range(1, 120):
        # current run on m=1: gap halves every 12 iters
        value = float(np.exp(-i / 12.0))
        d = ctrl.observe(i, 1, value)
        decision = d or decision
    assert decision is not None
    assert len(ctrl.decisions) >= 1


def test_algorithm_selection_reflects_observations(setup):
    """Planner choosing between a real fast/slow pair fit from simulation."""
    problem, cluster, p_star, sims = setup
    curves = {m: np.minimum.accumulate(s.record.primal)
              for m, s in sims.items()}
    data = ConvergenceData.from_curves(curves, p_star - 1e-5)
    conv = ConvergenceModel().fit(data)
    ms = sorted(sims)
    sys_fast = ErnestModel().fit(np.asarray(ms, float),
                                 np.full(len(ms), problem.n, float),
                                 np.asarray([sims[m].t_iter for m in ms]))
    # an artificial "expensive" algorithm: same convergence, 10x step time
    sys_slow = ErnestModel().fit(np.asarray(ms, float),
                                 np.full(len(ms), problem.n, float),
                                 np.asarray([10 * sims[m].t_iter for m in ms]))
    planner = Planner({
        "cheap": CombinedModel(sys_fast, conv, problem.n, 2000),
        "pricey": CombinedModel(sys_slow, conv, problem.n, 2000),
    })
    d = planner.fastest_to_epsilon(0.05, m_grid=ms)
    assert d.algorithm == "cheap"
