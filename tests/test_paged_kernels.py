"""Paged-native decode: bit-identity across implementations, physical
placement invariance, and the no-dense-KV jaxpr guarantee.

The stream and gather implementations share one blocking scheme and one
jnp op structure, so their outputs must match **bitwise** — under any
page table, any shared prefix pages, any ragged lengths, and any
pages_per_program.  That exactness is what lets the engine switch
implementations without perturbing prefix-cache guarantees (tested
end-to-end: a stream engine and a gather engine serve identical traces
token-for-token and logit-for-logit).  The Pallas kernel runs the same
blocked math and must match to float exactness (interpret mode may lower
its per-program 2D dots through a different gemm microkernel, so the
last ulp is not contractual)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.flash_decode.ops import (
    paged_decode_attention,
    paged_latent_decode_attention,
)
from repro.serve import ServeEngine

IMPLS = ("stream", "pallas", "gather")


def _assert_impls_agree(outs):
    """outs: dict impl -> np array.  stream == gather bitwise; pallas to
    float exactness (~1 ulp in f32; exact after a bf16 downcast)."""
    np.testing.assert_array_equal(outs["stream"], outs["gather"])
    atol = 1e-2 if outs["stream"].dtype == np.dtype("bfloat16") else 1e-6
    np.testing.assert_allclose(
        np.asarray(outs["pallas"], np.float32),
        np.asarray(outs["stream"], np.float32), atol=atol)


def _paged_inputs(seed, b=3, hk=2, g=2, d=16, page=8, npp=6, n_pages=32,
                  dtype=jnp.float32, share_prefix=True):
    rng = np.random.RandomState(seed)
    q = jnp.asarray(rng.randn(b, hk * g, d), dtype)
    kp = jnp.asarray(rng.randn(n_pages, hk, page, d), dtype)
    vp = jnp.asarray(rng.randn(n_pages, hk, page, d), dtype)
    pts = np.stack([rng.choice(n_pages, npp, replace=False)
                    for _ in range(b)])
    if share_prefix and b > 1:
        pts[1][:2] = pts[0][:2]  # two rows share their first two pages
    lens = np.asarray([1 + rng.randint(npp * page) for _ in range(b)],
                      np.int32)
    return q, kp, vp, jnp.asarray(lens), jnp.asarray(pts, jnp.int32)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("ppp", [1, 3, 6])
def test_paged_impls_bit_identical(dtype, ppp):
    q, kp, vp, lens, pt = _paged_inputs(0, dtype=dtype)
    outs = {impl: np.asarray(paged_decode_attention(
        q, kp, vp, lens, pt, impl=impl, pages_per_program=ppp))
        for impl in IMPLS}
    _assert_impls_agree(outs)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_latent_impls_bit_identical(dtype):
    rng = np.random.RandomState(1)
    b, h, r, rope, page, npp, n_pages = 3, 4, 16, 8, 8, 6, 32
    q_lat = jnp.asarray(rng.randn(b, h, r), dtype)
    q_pe = jnp.asarray(rng.randn(b, h, rope), dtype)
    ckv = jnp.asarray(rng.randn(n_pages, page, r), dtype)
    kpe = jnp.asarray(rng.randn(n_pages, page, rope), dtype)
    pt = jnp.asarray(np.stack([rng.choice(n_pages, npp, replace=False)
                               for _ in range(b)]), jnp.int32)
    lens = jnp.asarray([5, 17, 41], jnp.int32)
    outs = {impl: np.asarray(paged_latent_decode_attention(
        q_lat, q_pe, ckv, kpe, lens, pt, sm_scale=0.2, impl=impl,
        pages_per_program=2)) for impl in IMPLS}
    _assert_impls_agree(outs)


@settings(max_examples=12, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.sampled_from([1, 2, 4, 5]),
       st.sampled_from([1, 2, 3]))
def test_paged_property_bit_identical(seed, ppp, g):
    """Property: stream == gather bitwise (pallas to float exactness) for
    random page tables, shared prefix pages, and ragged lengths."""
    q, kp, vp, lens, pt = _paged_inputs(seed, g=g, npp=5)
    outs = {impl: np.asarray(paged_decode_attention(
        q, kp, vp, lens, pt, impl=impl, pages_per_program=ppp))
        for impl in IMPLS}
    _assert_impls_agree(outs)


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_paged_physical_placement_invariance(seed):
    """Permuting the physical page pool (with the table re-pointed) must not
    change a single bit of the output — decode depends only on logical
    content, never on where pages landed."""
    q, kp, vp, lens, pt = _paged_inputs(seed)
    n_pages = kp.shape[0]
    rng = np.random.RandomState(seed + 1)
    perm = rng.permutation(n_pages)
    inv = np.argsort(perm)
    out = paged_decode_attention(q, kp, vp, lens, pt, impl="stream",
                                 pages_per_program=2)
    out_perm = paged_decode_attention(
        q, kp[jnp.asarray(perm)], vp[jnp.asarray(perm)], lens,
        jnp.asarray(inv[np.asarray(pt)], jnp.int32), impl="stream",
        pages_per_program=2)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(out_perm))


def test_paged_latent_matches_dense_softmax():
    """The blocked latent path agrees with a plain dense softmax over the
    gathered latent cache (numerical check, not bitwise)."""
    rng = np.random.RandomState(3)
    b, h, r, rope, page, npp, n_pages = 2, 4, 8, 4, 8, 4, 16
    q_lat = jnp.asarray(rng.randn(b, h, r), jnp.float32)
    q_pe = jnp.asarray(rng.randn(b, h, rope), jnp.float32)
    ckv = jnp.asarray(rng.randn(n_pages, page, r), jnp.float32)
    kpe = jnp.asarray(rng.randn(n_pages, page, rope), jnp.float32)
    pt = jnp.asarray(np.stack([rng.choice(n_pages, npp, replace=False)
                               for _ in range(b)]), jnp.int32)
    lens = jnp.asarray([9, 26], jnp.int32)
    out = paged_latent_decode_attention(q_lat, q_pe, ckv, kpe, lens, pt,
                                        sm_scale=0.3, impl="stream",
                                        pages_per_program=2)
    ckv_c = ckv[pt].reshape(b, npp * page, r)
    kpe_c = kpe[pt].reshape(b, npp * page, rope)
    s = (jnp.einsum("bhr,bsr->bhs", q_lat, ckv_c)
         + jnp.einsum("bhe,bse->bhs", q_pe, kpe_c)) * 0.3
    mask = jnp.arange(npp * page)[None, :] < lens[:, None]
    s = jnp.where(mask[:, None, :], s, -1e30)
    ref = jnp.einsum("bhs,bsr->bhr", jax.nn.softmax(s, axis=-1), ckv_c)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


# ---------------------------------------------------------------------------
# the zero-copy guarantee, checked structurally
# ---------------------------------------------------------------------------
def _all_avals(jaxpr):
    """Every intermediate aval in a jaxpr, recursing into sub-jaxprs."""
    avals = []

    def subjaxprs(param):
        if isinstance(param, jax.core.ClosedJaxpr):
            yield param.jaxpr
        elif isinstance(param, jax.core.Jaxpr):
            yield param
        elif isinstance(param, (tuple, list)):
            for item in param:
                yield from subjaxprs(item)

    for eqn in jaxpr.eqns:
        avals.extend(v.aval for v in eqn.outvars)
        for p in eqn.params.values():
            for sub in subjaxprs(p):
                avals.extend(_all_avals(sub))
    return avals


def test_stream_jaxpr_has_no_dense_kv_intermediate():
    """The O(B*Hk*S*d) gather the legacy path materializes must be provably
    absent from the paged-native jaxpr: no intermediate anywhere carries
    the full cache-capacity sequence axis."""
    q, kp, vp, lens, pt = _paged_inputs(5, page=8, npp=20)  # capacity 160
    capacity = 20 * 8

    def dims(impl):
        jaxpr = jax.make_jaxpr(
            lambda *a: paged_decode_attention(*a, impl=impl,
                                              pages_per_program=2))(
            q, kp, vp, lens, pt).jaxpr
        return {d for aval in _all_avals(jaxpr)
                if hasattr(aval, "shape") for d in aval.shape}

    assert capacity in dims("gather"), "oracle must materialize the gather"
    assert capacity not in dims("stream"), (
        "paged-native stream path materialized a dense KV intermediate")


# ---------------------------------------------------------------------------
# engine-level equivalence (covers decode_step_paged + serve wiring)
# ---------------------------------------------------------------------------
GEOM = dict(smoke=True, max_batch=2, page_size=8, max_seq=64, seed=0)


def _run_trace(arch, paged_impl):
    eng = ServeEngine(arch, collect_logits=True, paged_impl=paged_impl,
                      **GEOM)
    rng = np.random.RandomState(11)
    head = rng.randint(0, 256, 16).astype(np.int32)
    reqs = [
        eng.submit(np.concatenate([head, rng.randint(0, 256, 5)
                                   .astype(np.int32)]), 5),
        eng.submit(rng.randint(0, 256, 9).astype(np.int32), 4,
                   arrival_step=2),
        eng.submit(np.concatenate([head, rng.randint(0, 256, 7)
                                   .astype(np.int32)]), 3, arrival_step=4),
    ]
    eng.run()
    return reqs


@pytest.mark.parametrize("arch", ["qwen3-14b", "deepseek-v2-236b"])
def test_engine_stream_vs_gather_bit_identical(arch):
    """A full continuous-batching trace (joins, prefix sharing, evictions)
    must be token- and logit-identical between the paged-native engine and
    the gather-oracle engine — for GQA and for the MLA latent path."""
    stream = _run_trace(arch, "stream")
    gather = _run_trace(arch, "gather")
    for rs, rg in zip(stream, gather):
        assert rs.generated == rg.generated
        for a, b in zip(rs.logits_trace, rg.logits_trace):
            np.testing.assert_array_equal(a, b)
