"""Sharded serve data plane (serve/sharding.py): plan construction, the
serving Rules policy, world-size-1 bitwise equivalence, and real 2-way
tensor parallelism in a forced-host-device subprocess.

Exactness contract (Rules.for_serving docstring): a (1,1) mesh is trivially
bitwise the unsharded engine; at world size > 1 the model-axis contractions
psum across devices, so the *token streams* are the identity surface and
raw logits agree to float tolerance."""
import subprocess
import sys
from pathlib import Path
from types import SimpleNamespace

import numpy as np
import pytest

from repro.dist.partitioning import Rules
from repro.models.runtime import Runtime
from repro.serve import ServeEngine
from repro.serve.sharding import ShardingPlan, mesh_world_size

ARCH = "qwen3-14b"
GEOM = dict(smoke=True, max_batch=2, page_size=8, max_seq=64, seed=0)
SRC = str(Path(__file__).resolve().parents[1] / "src")


def _fake_mesh(data: int, model: int):
    """Mesh stand-in with the attributes Rules/ShardingPlan read — lets the
    multi-device guard paths run without forcing host devices in-process."""
    return SimpleNamespace(
        axis_names=("data", "model"), devices=np.empty((data, model))
    )


# ------------------------------------------------------------- plan basics
def test_plan_absent_without_mesh():
    assert ShardingPlan.for_runtime(Runtime(remat="none")) is None


def test_serving_rules_replicate_pool_and_slots():
    rules = Rules.for_serving(_fake_mesh(1, 2))
    # batch-like axes and embed replicated; wide dims keep TP over "model"
    assert rules.acts["batch"] is None
    assert rules.acts["cache_batch"] is None
    assert rules.params["embed"] is None
    assert rules.params["mlp"] == "model"
    assert rules.acts["cache_head_dim"] == "model"
    # pspec resolution: the page-pool axis of a paged leaf stays unsharded
    spec = rules.act_pspec(
        ("cache_batch", "cache_seq", "cache_head_dim"), (32, 8, 16)
    )
    assert spec == __import__("jax").sharding.PartitionSpec(None, None, "model")


def test_pallas_impl_rejected_on_multi_device_mesh():
    rt_multi = Runtime(
        remat="none", page_size=8, paged_impl="pallas", mesh=_fake_mesh(1, 2)
    )
    with pytest.raises(ValueError, match="pallas"):
        ShardingPlan.for_runtime(rt_multi)
    # world size 1 keeps the kernel path available
    assert mesh_world_size(_fake_mesh(1, 1)) == 1
    rt_single = Runtime(
        remat="none", page_size=8, paged_impl="pallas", mesh=_fake_mesh(1, 1)
    )
    assert ShardingPlan.for_runtime(rt_single) is not None


# ----------------------------------------------------- world size 1: bitwise
def test_sharded_engine_1x1_mesh_bitwise_identical():
    """On a (1,1) mesh the sharded data plane must be bitwise the unsharded
    engine: same tokens AND same logits, including the chunked-prefill jit."""
    from repro.launch.mesh import make_debug_mesh

    mesh = make_debug_mesh(1, 1)
    rt = Runtime(
        remat="none", block_q=16, block_k=16, scan_chunk=32,
        page_size=GEOM["page_size"], paged_impl="stream", mesh=mesh,
    )
    rng = np.random.RandomState(0)
    base = ServeEngine(ARCH, **GEOM, collect_logits=True)
    shard = ServeEngine(ARCH, **GEOM, rt=rt, collect_logits=True)
    assert shard.plan is not None and base.plan is None
    prompts = [
        rng.randint(0, base.cfg.vocab_size, n).astype(np.int32)
        for n in (7, 19)
    ]
    for eng in (base, shard):
        for p in prompts:
            eng.submit(p, 5)
        eng.run()
    for rb, rs in zip(base.scheduler.finished, shard.scheduler.finished):
        assert rb.generated == rs.generated
        for a, b in zip(rb.logits_trace, rs.logits_trace):
            assert np.array_equal(a, b)

    # chunked prefill under the plan (the kwarg-wrapped static-s0 jit)
    b2 = ServeEngine(ARCH, **GEOM, prefill_chunk=8)
    s2 = ServeEngine(ARCH, **GEOM, rt=rt, prefill_chunk=8)
    for eng in (b2, s2):
        for p in prompts:
            eng.submit(p, 5)
        eng.run()
    for rb, rs in zip(b2.scheduler.finished, s2.scheduler.finished):
        assert rb.generated == rs.generated


# --------------------------------------------------- world size 2: subprocess
SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import numpy as np
import jax
assert len(jax.devices()) == 2
from repro.launch.mesh import make_debug_mesh
from repro.models.runtime import Runtime
from repro.serve import ServeEngine

GEOM = dict(smoke=True, max_batch=2, page_size=8, max_seq=64, seed=0)
mesh = make_debug_mesh(1, 2)
rt = Runtime(remat="none", block_q=16, block_k=16, scan_chunk=32,
             page_size=8, paged_impl="stream", mesh=mesh)
rng = np.random.RandomState(3)
base = ServeEngine("qwen3-14b", **GEOM, collect_logits=True)
shard = ServeEngine("qwen3-14b", **GEOM, rt=rt, collect_logits=True)
for leaf in jax.tree.leaves(shard.params):
    pass  # params placed lazily is fine; decode asserts placement below
prompts = [rng.randint(0, base.cfg.vocab_size, n).astype(np.int32)
           for n in (7, 19)]
for eng in (base, shard):
    for p in prompts:
        eng.submit(p, 6)
    eng.run()
# at least one wide param leaf must actually be split over both devices
split = any(
    len({s.device.id for s in leaf.addressable_shards}) == 2
    for leaf in jax.tree.leaves(shard.params)
)
assert split, "no parameter was sharded across the 2-device mesh"
for rb, rs in zip(base.scheduler.finished, shard.scheduler.finished):
    assert rb.generated == rs.generated, (rb.generated, rs.generated)
    for a, b in zip(rb.logits_trace, rs.logits_trace):
        assert np.max(np.abs(a - b)) < 0.1  # float tolerance, NOT bitwise
print("TP2_OK")
"""


def test_sharded_engine_tp2_token_identical():
    res = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        env={"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin", "HOME": "/root",
             "JAX_PLATFORMS": "cpu"},  # backend probing hangs without it
        capture_output=True, text=True, timeout=420,
    )
    assert "TP2_OK" in res.stdout, (res.stdout[-500:], res.stderr[-2000:])
