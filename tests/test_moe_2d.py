"""Numerical correctness of the replicated-token 2D expert-parallel MoE path
(the long-context-decode optimization from EXPERIMENTS.md §Perf) against the
single-device reference — run on an 8-device (4 data x 2 model) mesh in a
subprocess."""
import subprocess
import sys
from pathlib import Path

SRC = str(Path(__file__).resolve().parents[1] / "src")

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_smoke_config
from repro.dist.partitioning import Rules
from repro.launch.mesh import make_debug_mesh
from repro.models import moe as moe_mod

import dataclasses
cfg = get_smoke_config("deepseek-moe-16b")
# drop-free capacity: the reference and sharded paths compute per-expert
# capacity over different token populations (global vs per-shard)
cfg = dataclasses.replace(
    cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=50.0))
key = jax.random.PRNGKey(0)
params_ann = moe_mod.init_moe(key, cfg)
from repro.models.param import split_tree
params, _ = split_tree(params_ann)
x = jax.random.normal(jax.random.PRNGKey(1), (2, 1, cfg.d_model), jnp.float32) * 0.3

# reference: local path (no mesh)
y_ref, _ = moe_mod.apply_moe(params, x, cfg, train=False)

# 2D path: mesh (4 data x 2 model), batch axes overridden to None
mesh = make_debug_mesh(4, 2)
rules = Rules.default(mesh).override(acts={"batch": None})
with mesh:
    y_2d, _ = jax.jit(lambda p, xx: moe_mod.apply_moe(
        p, xx, cfg, train=False, mesh=mesh, rules=rules))(params, x)
err = float(jnp.abs(y_2d - y_ref).max())
rel = err / float(jnp.abs(y_ref).max())
assert rel < 2e-2, (err, rel)

# standard EP path (batch sharded) must also agree
rules_b = Rules.default(mesh)
with mesh:
    y_ep, _ = jax.jit(lambda p, xx: moe_mod.apply_moe(
        p, xx, cfg, train=False, mesh=mesh, rules=rules_b))(
        params, jnp.tile(x, (4, 1, 1)))
y_ref4, _ = moe_mod.apply_moe(params, jnp.tile(x, (4, 1, 1)), cfg, train=False)
err2 = float(jnp.abs(y_ep - y_ref4).max())
rel2 = err2 / float(jnp.abs(y_ref4).max())
assert rel2 < 2e-2, (err2, rel2)
print("MOE_2D_OK", rel, rel2)
"""


def test_moe_2d_matches_reference():
    res = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        env={"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin", "HOME": "/root",
             "JAX_PLATFORMS": "cpu"},  # backend probing hangs without it
        capture_output=True, text=True, timeout=420)
    assert "MOE_2D_OK" in res.stdout, (res.stdout[-500:], res.stderr[-2000:])
