"""Serve subsystem: allocator/scheduler invariants, paged-decode equivalence,
prefix-reuse exactness, and the CapacityPlanner fit/query round-trip.

The allocator is covered by property-based tests (random alloc/share/free
schedules against a shadow refcount model) rather than hand-picked edge
cases — the invariants hold under ANY schedule, so that is what we test."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.hemingway import NoFeasiblePlan
from repro.serve import CapacityPlanner, OutOfPages, PagePool, ServeEngine
from repro.serve.paging import SCRATCH_PAGE

ARCH = "qwen3-14b"  # dense: slot-independent decode (see engine docstring)
GEOM = dict(smoke=True, max_batch=2, page_size=8, max_seq=64, seed=0)


def _prompt(rng, n):
    return rng.randint(0, 256, n).astype(np.int32)


# ---------------------------------------------------------------- allocator
@settings(max_examples=60, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.integers(2, 24))
def test_page_pool_random_schedule_invariants(seed, num_pages):
    """Under a random alloc/share/free schedule the pool matches a shadow
    refcount model exactly: conservation (free + in-use = capacity), no
    scratch handout, OutOfPages exactly when the free list is short, and
    zero leaked pages once every reference is dropped."""
    rng = np.random.RandomState(seed)
    pool = PagePool(num_pages=num_pages, page_size=8)
    shadow = {}  # page -> refcount (live pages only)
    for _ in range(200):
        op = rng.choice(["alloc", "share", "free"])
        live = [p for p, c in shadow.items() if c > 0]
        if op == "alloc":
            n = int(rng.randint(1, max(num_pages // 2, 2)))
            if n > pool.free_pages:
                with pytest.raises(OutOfPages):
                    pool.alloc(n)
            else:
                got = pool.alloc(n)
                assert len(got) == n == len(set(got))
                assert SCRATCH_PAGE not in got
                assert not any(p in live for p in got), "handed out live page"
                for p in got:
                    shadow[p] = 1
        elif op == "share" and live:
            take = [p for p in live if rng.rand() < 0.3] or [live[0]]
            pool.share(take)
            for p in take:
                shadow[p] += 1
        elif op == "free" and live:
            take = [p for p in live if rng.rand() < 0.4] or [live[0]]
            pool.free(take)
            for p in take:
                shadow[p] -= 1
        # invariants after every operation
        in_use = sum(1 for c in shadow.values() if c > 0)
        assert pool.pages_in_use == in_use
        assert pool.free_pages + in_use == num_pages - 1  # scratch pinned
        for p, c in shadow.items():
            assert pool.refcount(p) == c
    # drain every remaining reference -> no leaks
    for p, c in list(shadow.items()):
        if c > 0:
            pool.free([p] * c)
    assert pool.pages_in_use == 0
    assert pool.free_pages == num_pages - 1


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_page_pool_rejects_invalid_ops(seed):
    """Double free, freeing/sharing the scratch page, and sharing dead
    pages are errors under any state the pool can reach."""
    rng = np.random.RandomState(seed)
    pool = PagePool(num_pages=int(rng.randint(3, 12)), page_size=8)
    pages = pool.alloc(int(rng.randint(1, pool.free_pages + 1)))
    pool.free(pages)
    with pytest.raises(ValueError):
        pool.free(pages[:1])          # double free
    with pytest.raises(ValueError):
        pool.share(pages[:1])         # share after death
    with pytest.raises(ValueError):
        pool.free([SCRATCH_PAGE])     # scratch is pinned
    with pytest.raises(ValueError):
        pool.share([SCRATCH_PAGE])


# ---------------------------------------------------------------- scheduler
def test_no_page_leak_after_evict():
    eng = ServeEngine(ARCH, **GEOM)
    rng = np.random.RandomState(0)
    for i in range(5):  # more requests than slots -> queueing + eviction
        eng.submit(_prompt(rng, 9 + 3 * i), max_new_tokens=3,
                   arrival_step=i % 2)
    eng.run()
    assert eng.scheduler.drained
    # prefix cache still pins published pages; clearing it must leave zero
    eng.prefix.clear(eng.pool)
    assert eng.pool.pages_in_use == 0
    assert eng.pool.free_pages == eng.pool.num_pages - 1
    # idle slots all point at the scratch page with zero length
    assert (eng.page_tables == SCRATCH_PAGE).all()
    assert (eng.lengths == 0).all()


def test_join_on_arrival_preserves_decoded_tokens():
    rng = np.random.RandomState(1)
    prompt = _prompt(rng, 16)
    guest = _prompt(rng, 9)

    solo = ServeEngine(ARCH, **GEOM)
    r_solo = solo.submit(prompt, max_new_tokens=8)
    solo.run()

    busy = ServeEngine(ARCH, **GEOM)
    r_host = busy.submit(prompt, max_new_tokens=8)
    r_guest = busy.submit(guest, max_new_tokens=4, arrival_step=3)
    busy.run()

    assert r_guest.admitted_step >= 3, "guest must join mid-decode"
    assert r_host.generated == r_solo.generated
    assert len(r_guest.generated) == 4


def test_evict_on_finish_frees_slot_for_queued_request():
    eng = ServeEngine(ARCH, **GEOM)
    rng = np.random.RandomState(2)
    first = [eng.submit(_prompt(rng, 10), max_new_tokens=2) for _ in range(2)]
    third = eng.submit(_prompt(rng, 10), max_new_tokens=2)  # no free slot
    eng.run()
    assert all(r.finished_step >= 0 for r in first + [third])
    assert third.admitted_step > first[0].admitted_step


# ------------------------------------------------------------- prefix reuse
def test_prefix_reuse_bit_identical_logits():
    rng = np.random.RandomState(3)
    head = _prompt(rng, 16)  # two full pages of 8
    pA = np.concatenate([head, _prompt(rng, 5)])
    pB = np.concatenate([head, _prompt(rng, 7)])

    cold = ServeEngine(ARCH, collect_logits=True, **GEOM)
    rB_cold = cold.submit(pB, max_new_tokens=5)
    cold.run()

    warm = ServeEngine(ARCH, collect_logits=True, **GEOM)
    warm.submit(pA, max_new_tokens=5)
    warm.run()
    rB = warm.submit(pB, max_new_tokens=5)
    warm.run()

    assert rB.n_shared_pages == 2, "prompt head pages must be shared"
    assert rB.generated == rB_cold.generated
    assert len(rB.logits_trace) == len(rB_cold.logits_trace) == 5
    for got, want in zip(rB.logits_trace, rB_cold.logits_trace):
        np.testing.assert_array_equal(got, want)


def test_prefix_share_join_does_not_perturb_running_donor():
    """A prefix-sharing request joining mid-decode must neither disturb the
    donor's remaining tokens nor lose its own cold-prefill exactness: shared
    pages are never rewritten, and their content is bitwise what the
    joiner's own prefill computed (engine pins the flash block size)."""
    rng = np.random.RandomState(8)
    head = _prompt(rng, 16)
    pA = np.concatenate([head, _prompt(rng, 6)])
    pB = np.concatenate([head, _prompt(rng, 11)])

    solo = ServeEngine(ARCH, collect_logits=True, **GEOM)
    rA_solo = solo.submit(pA, max_new_tokens=10)
    solo.run()
    cold = ServeEngine(ARCH, collect_logits=True, **GEOM)
    rB_cold = cold.submit(pB, max_new_tokens=4)
    cold.run()

    eng = ServeEngine(ARCH, collect_logits=True, **GEOM)
    rA = eng.submit(pA, max_new_tokens=10)
    rB = eng.submit(pB, max_new_tokens=4, arrival_step=3)  # A still decoding
    eng.run()

    assert rB.n_shared_pages == 2 and rB.admitted_step >= 3
    assert rA.generated == rA_solo.generated, "donor perturbed by joiner"
    assert rB.generated == rB_cold.generated
    for got, want in zip(rB.logits_trace, rB_cold.logits_trace):
        np.testing.assert_array_equal(got, want)


def test_full_prompt_reuse_skips_prefill():
    rng = np.random.RandomState(4)
    prompt = _prompt(rng, 16)  # page-aligned
    eng = ServeEngine(ARCH, collect_logits=True, **GEOM)
    r1 = eng.submit(prompt, max_new_tokens=4)
    eng.run()
    r2 = eng.submit(prompt, max_new_tokens=4)
    eng.run()
    assert not r1.prefill_skipped and r2.prefill_skipped
    assert r1.generated == r2.generated
    for got, want in zip(r2.logits_trace, r1.logits_trace):
        np.testing.assert_array_equal(got, want)


def test_full_prompt_reuse_with_mamba_state():
    rng = np.random.RandomState(5)
    prompt = _prompt(rng, 16)
    eng = ServeEngine("falcon-mamba-7b", collect_logits=True, **GEOM)
    r1 = eng.submit(prompt, max_new_tokens=4)
    eng.run()
    r2 = eng.submit(prompt, max_new_tokens=4)
    eng.run()
    assert r2.prefill_skipped
    assert r1.generated == r2.generated
    for got, want in zip(r2.logits_trace, r1.logits_trace):
        np.testing.assert_array_equal(got, want)


# ---------------------------------------------------------- capacity planner
def test_capacity_planner_fit_query_roundtrip():
    # synthetic telemetry from a known affine step model t(b) = a + c*b
    a, c = 0.02, 0.005
    planner = CapacityPlanner()
    for b in [1, 2, 4, 8] * 4:
        planner.observe(b, a + c * b)
    planner.fit()
    for b in (1, 4, 16):
        assert planner.step_time(b) == pytest.approx(a + c * b, rel=0.05)

    # min-fleet query: 10-token responses, p50 target admits b <= 8.
    # capacity per replica at b=8 is 8/0.06 = 133 tok/s = 13.3 qps, so
    # 45 qps needs m=4 (b=4 offers only 40 qps at m=4).
    plan = planner.plan(target_p50_s=0.61, qps=45.0,
                        gen_tokens=10, batch_grid=[1, 2, 4, 8],
                        m_grid=[1, 2, 4, 8, 16, 32])
    assert plan.m == 4 and plan.algorithm == "continuous@b8"
    assert plan.predicted_time == pytest.approx(10 * (a + c * 8), rel=0.05)

    # budget query: fixed fleet, lowest feasible latency (b=1 suffices)
    best = planner.best_latency_within_fleet(
        m=4, qps=10.0, gen_tokens=10, batch_grid=[1, 2, 4, 8])
    assert best.predicted_time == pytest.approx(10 * (a + c * 1), rel=0.05)

    no_plan = planner.plan(target_p50_s=1e-6, qps=40.0, gen_tokens=10,
                           batch_grid=[1, 2], m_grid=[1])
    assert isinstance(no_plan, NoFeasiblePlan) and not no_plan
    assert no_plan.query == "capacity_plan"
    assert no_plan.table, "infeasible result still carries its predictions"

    no_fleet = planner.best_latency_within_fleet(
        m=1, qps=1e6, gen_tokens=10, batch_grid=[1, 2])
    assert isinstance(no_fleet, NoFeasiblePlan)
    assert "cannot sustain" in no_fleet.reason


def test_capacity_planner_from_engine_telemetry():
    eng = ServeEngine(ARCH, **GEOM)
    rng = np.random.RandomState(7)
    eng.submit(_prompt(rng, 10), max_new_tokens=6)
    eng.submit(_prompt(rng, 13), max_new_tokens=4, arrival_step=1)
    eng.run()
    planner = CapacityPlanner()
    planner.observe_telemetry(eng.telemetry)
    planner.fit()  # distinct batch sizes 1 and 2 observed
    assert planner.step_time(1) > 0
    assert planner.tokens_per_s(2, m=2) > planner.tokens_per_s(2, m=1) * 1.5
