"""Per-architecture smoke tests: reduced config, one train step + serve path
on CPU; asserts output shapes and no NaNs (assignment requirement)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import (
    ARCH_IDS,
    applicable_shapes,
    get_config,
    get_smoke_config,
)
from repro.models.model import LM
from repro.models.runtime import Runtime

RT = Runtime(remat="none", block_q=16, block_k=16, scan_chunk=16)


def _batch(cfg, b=2, s=32):
    f = cfg.n_frontend_tokens
    out = {"tokens": jnp.ones((b, s - f), jnp.int32),
           "labels": jnp.ones((b, s - f), jnp.int32)}
    if f:
        out["frontend_embeds"] = jnp.full((b, f, cfg.d_model), 0.01,
                                          jnp.float32)
    return out


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_smoke(arch):
    cfg = get_smoke_config(arch)
    lm = LM(cfg, RT)
    params, axes = lm.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    (loss, metrics), grads = jax.value_and_grad(
        lm.loss_fn, has_aux=True)(params, batch)
    assert np.isfinite(float(loss)), arch
    gnorm = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0, arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_smoke(arch):
    cfg = get_smoke_config(arch)
    lm = LM(cfg, RT)
    params, _ = lm.init(jax.random.PRNGKey(0))
    b, s = 2, 32
    batch = _batch(cfg, b, s)
    logits, cache = jax.jit(lm.prefill)(params, batch["tokens"],
                                        batch.get("frontend_embeds"))
    assert logits.shape == (b, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all(), arch
    # decode against a fresh full-capacity cache
    full = lm.init_cache(b, s + 4)
    logits2, new_cache = jax.jit(lm.decode_step)(
        params, jnp.ones((b,), jnp.int32), jnp.zeros((b,), jnp.int32), full)
    assert logits2.shape == (b, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits2, np.float32)).all(), arch
    # cache pytree structure preserved
    assert jax.tree.structure(full) == jax.tree.structure(new_cache)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_prefill_logits(arch):
    """Teacher-forced decode must reproduce prefill's final logits."""
    import dataclasses
    cfg = get_smoke_config(arch)
    if cfg.moe is not None:
        # capacity drops differ between a 16-token prefill and 1-token decode
        # steps (Switch semantics); use drop-free capacity for equivalence
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=100.0))
    lm = LM(cfg, RT)
    params, _ = lm.init(jax.random.PRNGKey(1))
    b = 2
    f = cfg.n_frontend_tokens
    s = 8 + f  # 8 text tokens for every arch; frontend positions on top
    tokens = jax.random.randint(jax.random.PRNGKey(2), (b, s - f), 0,
                                cfg.vocab_size)
    fe = (jax.random.normal(jax.random.PRNGKey(3), (b, f, cfg.d_model),
                            jnp.float32) * 0.02 if f else None)
    logits_prefill, _ = jax.jit(lm.prefill)(params, tokens, fe)
    # feed the sequence one position at a time through decode: frontend
    # embeds first (teacher-forced via decode_step's frontend_embed path),
    # then the text tokens
    cache = lm.init_cache(b, s + 1)
    lengths = jnp.zeros((b,), jnp.int32)
    dec = jax.jit(lm.decode_step)
    dummy = jnp.zeros((b,), jnp.int32)
    for t in range(f):
        logits_dec, cache = dec(params, dummy, lengths, cache,
                                frontend_embed=fe[:, t])
        lengths = lengths + 1
    for t in range(s - f):
        logits_dec, cache = dec(params, tokens[:, t], lengths, cache)
        lengths = lengths + 1
    np.testing.assert_allclose(
        np.asarray(logits_dec, np.float32),
        np.asarray(logits_prefill, np.float32), atol=0.1, rtol=0.05)


def test_applicable_shapes_assignment():
    """long_500k only for SSM/hybrid; decode applies everywhere."""
    cells = 0
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        shapes = {s.name for s in applicable_shapes(cfg)}
        assert {"train_4k", "prefill_32k", "decode_32k"} <= shapes
        if arch in ("falcon-mamba-7b", "jamba-1.5-large-398b"):
            assert "long_500k" in shapes
        else:
            assert "long_500k" not in shapes
        cells += len(shapes)
    assert cells == 32


def test_param_counts_match_published():
    expected = {
        "falcon-mamba-7b": 7.3e9,
        "qwen3-14b": 14.8e9,
        "qwen1.5-110b": 111e9,
        "qwen3-32b": 32.8e9,
        "jamba-1.5-large-398b": 399e9,
        "deepseek-v2-236b": 236e9,
        "deepseek-moe-16b": 16.4e9,
    }
    for arch, n in expected.items():
        got = get_config(arch).param_count()
        assert abs(got - n) / n < 0.02, f"{arch}: {got:.3e} vs {n:.3e}"
    # MoE active params
    assert abs(get_config("deepseek-v2-236b").param_count(True) - 21.4e9) < 1e9
    assert abs(get_config("jamba-1.5-large-398b").param_count(True) - 94e9) < 2e9
