"""Live serving-state migration: snapshot/restore exactness, router-level
drain-free handoff, and the state the snapshot must carry.

The contract under test is the strongest one the engine can offer: a
replica restored from a between-steps snapshot continues **bit-identically**
— every remaining token and logit equals what the unmigrated engine would
have produced — because the snapshot is an exact copy of every mutable
input to ``step()`` (paged cache, page tables, lengths, pending tokens,
pool free-list order, prefix chains, scheduler queue/slots, proposer
memory).  See serve/migrate.py and DESIGN.md §15."""
import numpy as np
import pytest

from repro.serve import (
    MigrationError,
    Router,
    ServeEngine,
    migrate_replica,
    restore_engine,
    snapshot_engine,
)
from repro.serve.scheduler import RequestState
from repro.telemetry import from_dict

ARCH = "qwen3-14b"  # dense: slot-independent decode
GEOM = dict(smoke=True, max_batch=2, page_size=8, max_seq=64, seed=0)
PS = GEOM["page_size"]


def _prompt(rng, n):
    return rng.randint(0, 256, n).astype(np.int32)


def _specs(seed=0, n=6):
    """Mixed lengths, staggered arrivals, shared head on every third."""
    rng = np.random.RandomState(seed)
    head = _prompt(rng, 2 * PS)
    specs = []
    for i in range(n):
        if i % 3 == 0:
            prompt = np.concatenate([head, _prompt(rng, 3)])
        else:
            prompt = _prompt(rng, int(rng.choice([7, 12, 21])))
        specs.append((prompt, int(rng.choice([4, 6])), (i // 2) * 2))
    return specs


def _submit_all(target, specs):
    return [target.submit(p, g, arrival_step=a) for p, g, a in specs]


def _run_with_handoff(migrate_step, specs, **engine_kw):
    """Serve ``specs`` on one engine, handing off to a fresh engine at
    ``migrate_step``; returns the request handles whose streams finished
    on the destination."""
    src = ServeEngine(ARCH, **GEOM, **engine_kw)
    reqs = _submit_all(src, specs)
    for _ in range(migrate_step):
        src.step()
    dst = ServeEngine(ARCH, **GEOM, **engine_kw)
    rid_map = restore_engine(dst, snapshot_engine(src))
    dst.run()
    return [rid_map[r.rid] for r in reqs], src, dst


# ----------------------------------------------------------- bit identity
def test_restored_engine_continues_bit_identically():
    specs = _specs()
    base = ServeEngine(ARCH, collect_logits=True, **GEOM)
    base_reqs = _submit_all(base, specs)
    base.run()

    moved, src, dst = _run_with_handoff(3, specs, collect_logits=True)
    assert any(r.state is not RequestState.FINISHED
               for r in src.scheduler.slots + src.scheduler.queue
               if r is not None), "handoff must catch requests in flight"
    for got, want in zip(moved, base_reqs):
        assert got.generated == want.generated
        assert len(got.logits_trace) == len(want.logits_trace)
        for lg, lw in zip(got.logits_trace, want.logits_trace):
            np.testing.assert_array_equal(lg, lw)
    # the destination resumed the source's step clock, not its own
    assert dst.step_count == base.step_count


@pytest.mark.parametrize("migrate_step", [1, 2, 5])
def test_handoff_step_does_not_change_outputs(migrate_step):
    specs = _specs(seed=3)
    base = ServeEngine(ARCH, **GEOM)
    base_reqs = _submit_all(base, specs)
    base.run()
    moved, _, _ = _run_with_handoff(migrate_step, specs)
    for got, want in zip(moved, base_reqs):
        assert got.generated == want.generated


def test_migrate_mid_chunked_prefill():
    """A snapshot taken while a prompt is streaming in chunk by chunk must
    carry the half-written pages and the prefill cursor."""
    rng = np.random.RandomState(7)
    specs = [(_prompt(rng, 30), 5, 0), (_prompt(rng, 28), 4, 0),
             (_prompt(rng, 21), 4, 1)]
    base = ServeEngine(ARCH, prefill_chunk=4, **GEOM)
    base_reqs = _submit_all(base, specs)
    base.run()

    src = ServeEngine(ARCH, prefill_chunk=4, **GEOM)
    reqs = _submit_all(src, specs)
    src.step()
    assert any(r is not None and r.state is RequestState.PREFILLING
               for r in src.scheduler.slots), \
        "test premise: a request must be mid-prefill at the snapshot"
    dst = ServeEngine(ARCH, prefill_chunk=4, **GEOM)
    rid_map = restore_engine(dst, snapshot_engine(src))
    dst.run()
    for req, want in zip(reqs, base_reqs):
        assert rid_map[req.rid].generated == want.generated


def test_migrate_during_speculative_decode():
    """Speculation state (proposer counters, per-slot draft-source memory,
    the prefix cache's stored draft sources) migrates too: the restored
    engine keeps drafting and the committed streams stay exact.

    Workload is the self-continuation setup from test_serve_speculative:
    a follow-up prompt extends a stored document, so greedy decode retraces
    the stored continuation and drafts are dense and accepted."""

    def drive(migrate_at=None):
        eng = ServeEngine(ARCH, speculate=4, **GEOM)
        seed = _prompt(np.random.RandomState(3), 16)
        doc_req = eng.submit(seed, 40)
        eng.run()
        doc = np.concatenate([seed, np.asarray(doc_req.generated, np.int32)])
        eng.submit(doc, 1)  # page-aligned: stored as a draft source
        eng.run()
        follow = eng.submit(doc[:33].copy(), 20)
        if migrate_at is None:
            eng.run()
            return follow, eng
        for _ in range(migrate_at):
            eng.step()
        dst = ServeEngine(ARCH, speculate=4, **GEOM)
        rid_map = restore_engine(dst, snapshot_engine(eng))
        dst.run()
        return rid_map[follow.rid], dst

    base_follow, base = drive()
    assert base.proposer.accepted_tokens > 0, \
        "test premise: speculation must fire on this trace"
    moved_follow, dst = drive(migrate_at=3)
    assert moved_follow.generated == base_follow.generated
    # the verify path keeps running on the destination after the hop...
    assert any(e.op == "verify" for e in dst.events("serve_step"))
    # ...and the counters carried over: both lives sum to one life's worth
    assert dst.proposer.proposed_tokens == base.proposer.proposed_tokens
    assert dst.proposer.accepted_tokens == base.proposer.accepted_tokens


# ------------------------------------------------- carried state details
def test_pool_and_prefix_state_survive_the_hop():
    src = ServeEngine(ARCH, **GEOM)
    reqs = _submit_all(src, _specs(seed=5))
    for _ in range(4):
        src.step()
    dst = ServeEngine(ARCH, **GEOM)
    restore_engine(dst, snapshot_engine(src))
    # free-list ORDER (not just the set) must match: allocation order feeds
    # page ids, which feed page tables, which feed everything downstream
    assert list(dst.pool._free) == list(src.pool._free)
    assert dst.pool._refcount == src.pool._refcount
    assert list(dst.prefix._pages.items()) == list(src.prefix._pages.items())
    assert list(dst.prefix._full.keys()) == list(src.prefix._full.keys())
    assert dst.prefix.hits == src.prefix.hits
    assert np.array_equal(dst.page_tables, src.page_tables)
    assert np.array_equal(dst.lengths, src.lengths)
    assert np.array_equal(dst.next_tokens, src.next_tokens)
    assert dst._rid == src._rid

    # the migrated prefix cache still serves the skip-prefill fast path
    src.run()
    dst.run()
    done = [r for r in reqs if len(r.prompt) % PS == 0]
    if done:
        again = dst.submit(done[0].prompt.copy(), 2)
        dst.run()
        assert again.prefill_skipped


def test_page_leak_invariant_after_migration():
    """Drained + cleared after a mid-trace hop -> zero pages in use; a
    refcount mistake in the snapshot would surface here as a leak or a
    double free."""
    moved, _, dst = _run_with_handoff(3, _specs(seed=9))
    assert all(r.state is RequestState.FINISHED for r in moved)
    dst.prefix.clear(dst.pool)
    assert dst.pool.pages_in_use == 0


# ------------------------------------------------------- router handoff
def test_router_live_migration_bit_identical_to_single_engine():
    specs = _specs(seed=0)
    ref = ServeEngine(ARCH, **GEOM)
    ref_reqs = _submit_all(ref, specs)
    ref.run()

    router = Router([ServeEngine(ARCH, **GEOM) for _ in range(2)],
                    spill_slack=512)
    routed = _submit_all(router, specs)
    handed_off = None
    while not router.drained:
        if router.step_count == 3:
            handed_off = migrate_replica(
                router, 0, lambda: ServeEngine(ARCH, **GEOM))
        router.step()
    assert handed_off is not None and handed_off["in_flight"] > 0
    for rr, want in zip(routed, ref_reqs):
        assert rr.generated == want.generated
    assert router.stats()["requests_finished"] == len(specs)


def test_migration_emits_ckpt_cost_event():
    router = Router([ServeEngine(ARCH, **GEOM) for _ in range(2)])
    _submit_all(router, _specs(seed=2))
    router.step()
    router.step()
    info = migrate_replica(router, 1, lambda: ServeEngine(ARCH, **GEOM))
    evs = router.events("ckpt_cost")
    assert len(evs) == 1
    ev = evs[0]
    assert ev.op == "migrate" and ev.replica == 1
    assert ev.wall_s == pytest.approx(info["wall_s"])
    assert ev.nbytes == info["nbytes"] > 0
    assert ev.n_shards == info["n_shards"] > 0
    assert from_dict(ev.to_dict()) == ev
    router.run()


def test_migrated_replica_keeps_winning_affinity_probes():
    """The router's whole point is prefix affinity; a handoff that lost the
    prefix chains would silently cold-prefill every later relative."""
    rng = np.random.RandomState(13)
    head = _prompt(rng, 2 * PS)
    router = Router([ServeEngine(ARCH, **GEOM) for _ in range(2)],
                    spill_slack=512)
    router.submit(np.concatenate([head, _prompt(rng, 3)]), 3, arrival_step=0)
    router.submit(_prompt(rng, 7), 3, arrival_step=0)
    late = router.submit(np.concatenate([head, _prompt(rng, 5)]), 3,
                         arrival_step=6)
    while not router.drained:
        if router.step_count == 4:
            migrate_replica(router, 0, lambda: ServeEngine(ARCH, **GEOM))
        router.step()
    ev = next(e for e in router.events("router") if e.rid == late.rid)
    assert ev.reason == "affinity" and ev.replica == 0
    assert ev.matched_pages == 2


# ----------------------------------------------------------- guard rails
def test_geometry_mismatch_is_rejected():
    src = ServeEngine(ARCH, **GEOM)
    _submit_all(src, _specs())
    src.step()
    snap = snapshot_engine(src)
    for bad in (dict(page_size=16, max_seq=64),
                dict(max_batch=4),
                dict(seed=1),
                dict(prefill_chunk=4)):
        dst = ServeEngine(ARCH, **{**GEOM, **bad})
        with pytest.raises(MigrationError, match="geometry"):
            restore_engine(dst, snap)


def test_restore_onto_used_engine_is_rejected():
    src = ServeEngine(ARCH, **GEOM)
    _submit_all(src, _specs())
    src.step()
    snap = snapshot_engine(src)
    used = ServeEngine(ARCH, **GEOM)
    used.submit(np.arange(7, dtype=np.int32), 2)
    with pytest.raises(MigrationError, match="fresh"):
        restore_engine(used, snap)


def test_bad_replica_index_is_rejected():
    router = Router([ServeEngine(ARCH, **GEOM)])
    with pytest.raises(ValueError, match="out of range"):
        migrate_replica(router, 1, lambda: ServeEngine(ARCH, **GEOM))
