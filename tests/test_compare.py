"""benchmarks/compare.py exit-code contract (consumed by CI perf-smoke):
0 = within ratio, 1 = regression or new ERROR row, 2 = unusable input."""
import json

import pytest

from benchmarks import compare


def payload(rows):
    return {"rows": [{"name": n, "us_per_call": us} for n, us in rows]}


def write(tmp_path, name, rows):
    p = tmp_path / name
    p.write_text(json.dumps(payload(rows)))
    return str(p)


BASE = [("core/lasso_cv", 50_000.0), ("serve/schedule", 8_000.0),
        ("kernels/flash", 9_000.0),      # excluded prefix: never gated
        ("serve/tiny", 10.0)]            # below --min-us: never gated


def test_exit_0_when_within_ratio(tmp_path, capsys):
    base = write(tmp_path, "base.json", BASE)
    cur = write(tmp_path, "cur.json",
                [("core/lasso_cv", 90_000.0), ("serve/schedule", 8_100.0),
                 ("kernels/flash", 100_000.0),   # 11x but excluded
                 ("serve/tiny", 500.0)])         # 50x but sub-threshold
    assert compare.main([base, cur]) == 0
    assert "2 rows within" in capsys.readouterr().out


def test_exit_1_on_regression(tmp_path):
    base = write(tmp_path, "base.json", BASE)
    cur = write(tmp_path, "cur.json",
                [("core/lasso_cv", 200_000.0),   # 4x > 2.5x
                 ("serve/schedule", 8_000.0),
                 ("serve/tiny", 12.0)])
    assert compare.main([base, cur]) == 1
    # a looser gate lets the same payload pass
    assert compare.main([base, cur, "--max-ratio", "5.0"]) == 0


def test_exit_1_on_vanished_serve_row(tmp_path, capsys):
    # serve/* baseline rows are REQUIRED to persist: a vanished row fails
    # like a regression even when every surviving row is within ratio
    base = write(tmp_path, "base.json", BASE)
    cur = write(tmp_path, "cur.json",
                [("core/lasso_cv", 50_000.0), ("serve/schedule", 8_000.0)])
    assert compare.main([base, cur]) == 1
    assert "serve/tiny" in capsys.readouterr().out


def test_exit_1_on_new_error_row(tmp_path):
    base = write(tmp_path, "base.json", BASE)
    cur = write(tmp_path, "cur.json",
                [("core/lasso_cv", 50_000.0), ("serve/schedule", 8_000.0),
                 ("serve/engine/ERROR", 1.0)])
    assert compare.main([base, cur]) == 1


def test_exit_2_on_missing_file(tmp_path):
    base = write(tmp_path, "base.json", BASE)
    assert compare.main([base, str(tmp_path / "nope.json")]) == 2


def test_exit_2_on_unreadable_json(tmp_path):
    base = write(tmp_path, "base.json", BASE)
    bad = tmp_path / "bad.json"
    bad.write_text("{not json")
    assert compare.main([base, str(bad)]) == 2
    schema = tmp_path / "schema.json"
    schema.write_text(json.dumps({"rows": [{"nome": "x"}]}))
    assert compare.main([base, str(schema)]) == 2


def test_exit_2_when_no_comparable_rows(tmp_path):
    base = write(tmp_path, "base.json", [("kernels/flash", 9_000.0)])
    cur = write(tmp_path, "cur.json", [("kernels/flash", 9_000.0)])
    assert compare.main([base, cur]) == 2


@pytest.mark.parametrize("missing_side", ["baseline_only", "current_only"])
def test_one_sided_rows_reported_not_gated(tmp_path, missing_side, capsys):
    # one-sided rows outside REQUIRED_PREFIXES are reported, never gated
    # (baseline-only serve/* rows ARE gated — see the vanished-row test)
    rows = [("core/lasso_cv", 50_000.0), ("serve/schedule", 8_000.0)]
    extra = [("core/new_bench", 99_000.0)]
    base = write(tmp_path, "base.json",
                 rows + (extra if missing_side == "baseline_only" else []))
    cur = write(tmp_path, "cur.json",
                rows + (extra if missing_side == "current_only" else []))
    assert compare.main([base, cur]) == 0


# ------------------------------------------------- newest-baseline resolution
def test_newest_baseline_prefers_highest_pr_number(tmp_path):
    write(tmp_path, "BENCH_baseline_pr1.json", BASE)
    newest = write(tmp_path, "BENCH_pr4.json", BASE)
    write(tmp_path, "other.json", BASE)          # non-BENCH files ignored
    assert compare.newest_baseline(str(tmp_path)) == newest


def test_newest_baseline_mtime_breaks_number_tie(tmp_path):
    import os

    a = write(tmp_path, "BENCH_quick.json", BASE)     # no number: pr = -1
    b = write(tmp_path, "BENCH_full.json", BASE)
    os.utime(a, (1_000_000_000, 1_000_000_000))
    os.utime(b, (2_000_000_000, 2_000_000_000))
    assert compare.newest_baseline(str(tmp_path)) == b


def test_directory_baseline_resolves_and_gates(tmp_path, capsys):
    write(tmp_path, "BENCH_baseline_pr1.json",
          [("core/lasso_cv", 10_000.0)])               # old, loose baseline
    write(tmp_path, "BENCH_pr4.json", [("core/lasso_cv", 50_000.0)])
    cur = write(tmp_path, "cur.json", [("core/lasso_cv", 90_000.0)])
    # 1.8x vs the pr4 baseline (9x vs pr1 would have failed): newest wins
    assert compare.main([str(tmp_path), cur]) == 0
    assert "BENCH_pr4.json" in capsys.readouterr().out


def test_exit_2_when_directory_has_no_baselines(tmp_path):
    cur = write(tmp_path, "cur.json", BASE)
    empty = tmp_path / "empty"
    empty.mkdir()
    assert compare.main([str(empty), cur]) == 2
