"""The telemetry bus: typed events, sinks, atomic IO, streaming refits.

Covers the PR-7 contract end to end: the four legacy row shapes
round-trip bit-for-bit through their typed events (golden traces depend
on it), sinks compose under one ``Tracker.emit``, the atomic IO helpers
survive concurrent writers (real subprocesses, not threads — the race
they fix was cross-process), ``log_from_device`` emits from jit, the
one-release deprecation shims warn exactly once, and the drift detector
+ streaming refit wrappers behave: quiet on stationary noise, firing
within a window of a sustained 2x slowdown, and leaving the refit model
with lower residuals than the stale one.
"""
import json
import os
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

from repro.telemetry import (
    ChaosStepEvent,
    DriftConfig,
    DriftDetector,
    JSONLSink,
    MemorySink,
    RunMeta,
    SchemaError,
    ServeStepEvent,
    StatsSink,
    StreamingErnest,
    Tracker,
    TuneEvent,
    append_jsonl,
    atomic_write_json,
    from_dict,
    from_legacy,
    read_events,
    read_jsonl,
    registered_kinds,
    reset_deprecation_warnings,
    warn_deprecated,
)
from repro.telemetry.tracker import log_from_device

ROOT = Path(__file__).resolve().parents[1]


# ----------------------------------------------------------- event schema
def test_all_kinds_registered():
    assert set(registered_kinds()) >= {
        "tune", "serve_step", "chaos_step", "fleet_tick",
        "drift", "refit", "run_meta",
    }


TUNE_ROW = {
    "family": "flash_decode_paged",
    "shape": {"b": 4, "d": 64},
    "dtype": "float32",
    "backend": "cpu",
    "config": {"block_b": 4},
    "us_per_call": 12.5,
    "candidates_swept": 6,
    "candidates_pruned": 2,
}

SERVE_ROWS = [
    {"step": 0, "batch": 0, "step_s": 0.01, "kind": "prefill",
     "prefill_tokens": 128},
    {"step": 1, "batch": 4, "step_s": 0.002, "kind": "decode",
     "committed": 4},
    {"step": 2, "batch": 4, "step_s": 0.003, "kind": "verify",
     "committed": 9, "drafted": 12},
]

CHAOS_ROWS = [
    # a restore row has no step_s/objective — to_legacy must NOT invent
    # the keys, or golden signatures change
    {"step": 3, "m": 4, "events": ["preempt:1"], "restore": True,
     "wall_s": 12.0},
    {"step": 4, "m": 4, "events": [], "objective": 0.5, "step_s": 1.5,
     "wall_s": 13.5, "decision": "resize:8", "custom": 7},
]


@pytest.mark.parametrize("kind,row", [
    ("tune", TUNE_ROW),
    *[("serve_step", r) for r in SERVE_ROWS],
    *[("chaos_step", r) for r in CHAOS_ROWS],
])
def test_legacy_round_trip_is_exact(kind, row):
    """legacy -> event -> legacy reproduces the dict bit-for-bit, and the
    wire form (to_dict -> from_dict) preserves the event."""
    ev = from_legacy(kind, row)
    assert ev.to_legacy() == row
    assert from_dict(json.loads(json.dumps(ev.to_dict()))) == ev


def test_fleet_tick_round_trip():
    row = {"step": 7, "events": ["slowdown:-1"], "decisions": ["drift:j"],
           "serve": {"s": {"m": 2}}, "jobs": {"j": {"state": "running"}},
           "free": 3, "cost_hh": 1.25}
    ev = from_legacy("fleet_tick", row)
    assert ev.to_legacy() == row
    assert from_dict(ev.to_dict()) == ev


def test_schema_rejects_unknown_and_newer():
    with pytest.raises(SchemaError):
        from_dict({"kind": "nope", "v": 1})
    with pytest.raises(SchemaError):
        from_dict({"kind": "serve_step", "v": 99, "step": 0,
                   "step_s": 0.1, "op": "decode"})
    with pytest.raises(SchemaError):
        from_dict({"kind": "serve_step", "v": 1})  # missing required


def test_unknown_keys_fold_into_extra():
    ev = from_dict({"kind": "chaos_step", "v": 1, "step": 1, "m": 2,
                    "events": [], "mystery": 9})
    assert ev.extra == {"mystery": 9}
    assert ev.to_legacy()["mystery"] == 9


# ------------------------------------------------------------------ sinks
def _serve_events(n):
    return [ServeStepEvent(step=i, step_s=0.001 * (i + 1), op="decode",
                           batch=2, committed=2) for i in range(n)]


def test_memory_sink_ring():
    t = Tracker([MemorySink(maxlen=4)])
    t.emit_many(_serve_events(10))
    evs = t.events("serve_step")
    assert len(evs) == 4 and evs[0].step == 6


def test_tracker_fans_out_to_all_sinks(tmp_path):
    mem, stats = MemorySink(), StatsSink()
    jsonl = JSONLSink(tmp_path / "t.jsonl", flush_every=3)
    t = Tracker([mem, stats, jsonl])
    t.emit_many(_serve_events(5))
    assert len(mem) == 5 and stats.counts == {"serve_step": 5}
    # buffered: 3 flushed, 2 pending until close
    assert jsonl.written == 3
    t.close()
    assert jsonl.written == 5
    back = read_events(tmp_path / "t.jsonl")
    assert back == t.events()


def test_stats_sink_aggregates():
    s = StatsSink()
    for ev in _serve_events(3):
        s.write(ev)
    agg = s.summary()["serve_step"]
    assert agg["count"] == 3
    assert agg["fields"]["step_s"]["min"] == pytest.approx(0.001)
    assert agg["fields"]["step_s"]["max"] == pytest.approx(0.003)
    assert agg["fields"]["step_s"]["mean"] == pytest.approx(0.002)


def test_tracker_to_jsonl_with_header(tmp_path):
    t = Tracker()
    t.emit_many(_serve_events(2))
    p = tmp_path / "run.jsonl"
    t.to_jsonl(p, header=RunMeta(log_type="serve", meta={"seed": 0}))
    back = read_events(p)
    assert back[0].kind == "run_meta" and back[0].log_type == "serve"
    assert back[1:] == t.events()


# -------------------------------------------------------------- atomic io
def test_atomic_write_json_leaves_no_tmp(tmp_path):
    p = tmp_path / "sub" / "cache.json"
    atomic_write_json(p, {"a": 1})
    assert json.loads(p.read_text()) == {"a": 1}
    assert [f.name for f in p.parent.iterdir()] == ["cache.json"]


def test_append_jsonl_appends(tmp_path):
    p = tmp_path / "log.jsonl"
    assert append_jsonl(p, ['{"a": 1}']) == 1
    assert append_jsonl(p, ['{"a": 2}', '{"a": 3}']) == 2
    assert append_jsonl(p, []) == 0
    assert read_jsonl(p) == [{"a": 1}, {"a": 2}, {"a": 3}]


_APPEND_WORKER = """
import sys
sys.path.insert(0, {src!r})
from repro.telemetry import append_jsonl
wid = int(sys.argv[1])
for i in range(50):
    append_jsonl({path!r}, ['{{"w": %d, "i": %d}}' % (wid, i)])
"""


def test_concurrent_jsonl_appenders(tmp_path):
    """N processes hammering one JSONL file interleave whole lines only
    (single O_APPEND write per flush)."""
    p = tmp_path / "conc.jsonl"
    script = _APPEND_WORKER.format(src=str(ROOT / "src"), path=str(p))
    procs = [subprocess.Popen([sys.executable, "-c", script, str(w)])
             for w in range(4)]
    for pr in procs:
        assert pr.wait(timeout=120) == 0
    rows = read_jsonl(p)   # raises on any torn/partial line
    assert len(rows) == 4 * 50
    assert {(r["w"], r["i"]) for r in rows} \
        == {(w, i) for w in range(4) for i in range(50)}


_CACHE_WORKER = """
import sys
sys.path.insert(0, {src!r})
import os
os.environ.setdefault("JAX_PLATFORMS", "cpu")
from repro.kernels.tune.cache import ConfigCache
wid = sys.argv[1]
cache = ConfigCache({path!r})
for i in range(20):
    key = "fam|b%s_i%d|float32|cpu" % (wid, i)
    cache.put(key, family="fam", shape={{"b": int(wid), "i": i}},
              dtype="float32", config={{"block": 8}}, us_per_call=1.0,
              swept=1, pruned=0)
    cache.save()
"""


def test_concurrent_tune_cache_writers(tmp_path):
    """Two processes sweeping different keys against one cache file must
    union their entries (merge-on-save + atomic replace), not clobber."""
    p = tmp_path / "tune_cache.json"
    script = _CACHE_WORKER.format(src=str(ROOT / "src"), path=str(p))
    procs = [subprocess.Popen([sys.executable, "-c", script, str(w)])
             for w in (1, 2)]
    for pr in procs:
        assert pr.wait(timeout=300) == 0
    from repro.kernels.tune.cache import ConfigCache
    final = ConfigCache(str(p))
    assert len(final.entries) == 40
    # every entry is schema-valid and adapts to a TuneEvent
    for key in final.entries:
        assert TuneEvent.from_legacy_row(final.entries[key]).family == "fam"


# --------------------------------------------------------- jit-safe emits
def test_log_from_device_under_jit():
    import jax
    import jax.numpy as jnp

    t = Tracker()

    @jax.jit
    def step(x):
        y = x * 2.0
        log_from_device(
            t,
            lambda v: ServeStepEvent(step=0, step_s=float(v), op="decode",
                                     batch=1, committed=1),
            jnp.sum(y),
        )
        return y

    out = step(jnp.ones((4,)))
    jax.effects_barrier()
    assert float(out.sum()) == 8.0
    evs = t.events("serve_step")
    assert len(evs) == 1 and evs[0].step_s == pytest.approx(8.0)


# ------------------------------------------------------------ deprecation
def test_deprecation_shims_warn_once():
    reset_deprecation_warnings()
    with pytest.warns(DeprecationWarning, match="old_api"):
        warn_deprecated("old_api()", "new_api()")
    # second call is silent (one-release shim warns once per process)
    import warnings as w
    with w.catch_warnings():
        w.simplefilter("error")
        warn_deprecated("old_api()", "new_api()")
    reset_deprecation_warnings()
    with pytest.warns(DeprecationWarning):
        warn_deprecated("old_api()", "new_api()")
    reset_deprecation_warnings()


def test_legacy_accessors_are_deprecated_but_work():
    from repro.runtime.chaos import ChaosRunLog, ChaosTrace

    reset_deprecation_warnings()
    log = ChaosRunLog(trace=ChaosTrace.generate(0, 4, 2))
    log.append(step=0, m=2, events=[], objective=1.0, step_s=1.0,
               wall_s=1.0)
    with pytest.warns(DeprecationWarning, match="final_wall_clock"):
        assert log.final_wall_clock() == 1.0
    assert log.events("chaos_step")[-1].wall_s == 1.0
    reset_deprecation_warnings()


# ------------------------------------------------- drift detector + refit
def test_detector_quiet_on_stationary_noise():
    rng = np.random.default_rng(0)
    det = DriftDetector("m", DriftConfig(window=16, threshold=0.3,
                                         min_points=6, cooldown=8))
    for step in range(200):
        actual = 1.0 + 0.05 * rng.standard_normal()
        assert det.observe(step, 1.0, actual) is None
    assert det.residual() < 0.1


def test_detector_fires_within_window_of_2x_slowdown():
    det = DriftDetector("m", DriftConfig(window=16, threshold=0.3,
                                         min_points=6, cooldown=8))
    for step in range(50):
        assert det.observe(step, 1.0, 1.0) is None
    fired = None
    for step in range(50, 80):
        ev = det.observe(step, 1.0, 2.0)   # sustained 2x
        if ev is not None:
            fired = ev
            break
    assert fired is not None and fired.step <= 50 + det.cfg.window
    assert fired.residual > fired.threshold
    assert fired.model == "m" and fired.window == 16


def test_detector_cooldown_suppresses_refires():
    det = DriftDetector("m", DriftConfig(window=8, threshold=0.2,
                                         min_points=4, cooldown=10))
    fires = [s for s in range(40)
             if det.observe(s, 1.0, 3.0) is not None]
    assert fires and all(b - a >= 10 for a, b in zip(fires, fires[1:]))


def test_streaming_ernest_refit_reduces_residuals():
    """Feed an Ernest model fit at 1x a sustained 2x-slower stream: drift
    fires, the in-place refit tracks the new regime, and the post-refit
    residual beats the stale model's."""
    from repro.core.ernest import ErnestModel

    def true_time(m, size, scale=1.0):
        return scale * (1.0 + 8.0 * size / m + 0.05 * np.log2(m))

    ms = np.array([1, 2, 4, 8, 1, 2, 4, 8], dtype=float)
    sizes = np.full_like(ms, 4.0)
    model = ErnestModel().fit(ms, sizes, true_time(ms, sizes))

    s = StreamingErnest(model, DriftConfig(window=8, threshold=0.15,
                                           min_points=4, cooldown=4),
                        window=16)
    events = []
    step = 0
    for _ in range(4):          # healthy regime: no events
        for m in (1, 2, 4, 8):
            events += s.observe(step, m, 4.0, true_time(m, 4.0))
            step += 1
    assert events == []
    for _ in range(8):          # everything slows 2x
        for m in (1, 2, 4, 8):
            events += s.observe(step, m, 4.0, true_time(m, 4.0, scale=2.0))
            step += 1
    kinds = [e.kind for e in events]
    assert "drift" in kinds and "refit" in kinds
    refits = [e for e in events if e.kind == "refit"]
    assert all(r.residual_after < r.residual_before for r in refits)
    # successive refits converge onto the new regime as old points age out
    assert refits[-1].residual_after < 0.15
    # the wrapped model itself was refit in place onto the new regime
    pred = float(np.asarray(model.predict(np.array([4.0]),
                                          np.array([4.0])))[0])
    assert pred == pytest.approx(true_time(4, 4.0, scale=2.0), rel=0.1)


# -------------------------------------------------------- planner.ingest
def test_planner_ingest_dispatches_on_kind():
    from repro.serve.planner import CapacityPlanner

    planner = CapacityPlanner()
    events = [
        ServeStepEvent(step=0, step_s=0.01, op="prefill", prefill_tokens=64),
        ServeStepEvent(step=1, step_s=0.002, op="decode", batch=2,
                       committed=2),
        ServeStepEvent(step=2, step_s=0.003, op="verify", batch=4,
                       committed=9, drafted=12),
        TuneEvent(family="flash_decode_paged", shape={"b": 8}, dtype="f32",
                  backend="cpu", config={}, us_per_call=4000.0),
        TuneEvent(family="flash_attention", shape={"b": 8}, dtype="f32",
                  backend="cpu", config={}, us_per_call=1.0),  # ignored
        RunMeta(log_type="serve"),                              # ignored
    ]
    n = planner.ingest(events, n_layers=2)
    assert n == 4
    assert len(planner.observations) == 3
    assert planner.prefill_tokens_per_s == pytest.approx(6400.0)
    assert planner.accepted_per_slot_step == pytest.approx(11 / 6)
    planner.fit()
    assert planner.step_time(4) > 0


def test_planner_legacy_wrappers_match_ingest():
    from repro.serve.planner import CapacityPlanner

    rows = [r for r in SERVE_ROWS]
    a, b = CapacityPlanner(), CapacityPlanner()
    a.ingest(from_legacy("serve_step", r) for r in rows)
    b.observe_telemetry(rows)
    assert [(o.batch, o.step_s) for o in a.observations] \
        == [(o.batch, o.step_s) for o in b.observations]
    assert a.accepted_per_slot_step == b.accepted_per_slot_step


# -------------------------------------------------------------------- CLI
def test_cli_summarize(tmp_path, capsys):
    from repro.telemetry.__main__ import summarize

    p = tmp_path / "run.jsonl"
    t = Tracker()
    t.emit_many(_serve_events(3))
    t.to_jsonl(p, header=RunMeta(log_type="serve"))
    assert summarize(str(p), strict=True) == 0
    out = capsys.readouterr().out
    assert "serve_step   n=3" in out and "4 events, 0 invalid rows" in out

    with open(p, "a") as f:
        f.write('{"kind": "nope"}\n')
    assert summarize(str(p), strict=False) == 0
    assert summarize(str(p), strict=True) == 1
