"""Chunked prefill + speculative multi-token decode: exactness and policy.

The engine-level contract is *bit-identity*: with any ``prefill_chunk`` /
``speculate`` setting, generated tokens AND per-step logits must equal the
plain one-token-per-step engine's, logit for logit, on mixed traces with
staggered arrivals and shared prompt heads.  That is asserted here for a
GQA architecture (qwen3) and an MLA+MoE architecture (deepseek-v2), plus
scheduler edge cases (tiny chunk budgets under bursts, admission
backpressure, degenerate knobs), the n-gram/prefix-cache proposer, and the
CapacityPlanner's ingestion of verify/prefill telemetry."""
import numpy as np
import pytest

from repro.serve import CapacityPlanner, ServeEngine
from repro.serve.prefix import PrefixCache
from repro.serve.speculate import NgramProposer, find_last_ngram

GEOM = dict(smoke=True, max_batch=2, page_size=8, max_seq=64, seed=0)


def _prompt(rng, n):
    return rng.randint(0, 256, n).astype(np.int32)


def _mixed_trace(eng, seed=0, n_requests=8):
    """Mixed lengths, bursty arrivals, every third request shares a head."""
    rng = np.random.RandomState(seed)
    head = _prompt(rng, 2 * eng.page_size)
    reqs = []
    for i in range(n_requests):
        if i % 3 == 0:
            prompt = np.concatenate([head, _prompt(rng, 3 + rng.randint(0, 8))])
        else:
            prompt = _prompt(rng, int(rng.choice([7, 12, 21, 30])))
        reqs.append(eng.submit(prompt, int(rng.choice([4, 6, 8])),
                               arrival_step=(i // 2) * 2))
    return reqs


# ------------------------------------------------------------ bit-identity
@pytest.mark.parametrize("arch", ["qwen3-14b", "deepseek-v2-236b"])
def test_chunked_speculative_bit_identical_to_baseline(arch):
    """Chunked prefill + speculation change step count and cost, never the
    output: tokens and logits match the plain engine exactly on a mixed
    8-request trace (GQA and MLA+MoE paged attention paths)."""
    fast = ServeEngine(arch, prefill_chunk=8, speculate=3,
                       collect_logits=True, **GEOM)
    base = ServeEngine(arch, collect_logits=True, **GEOM)
    fast_reqs = _mixed_trace(fast)
    base_reqs = _mixed_trace(base)
    fast.run()
    base.run()
    for rf, rb in zip(fast_reqs, base_reqs):
        assert rf.generated == rb.generated
        assert len(rf.logits_trace) == len(rb.logits_trace)
        for lf, lb in zip(rf.logits_trace, rb.logits_trace):
            np.testing.assert_array_equal(lf, lb)


def test_speculation_commits_multiple_tokens_per_step():
    """On a self-continuation workload (follow-up prompt extends a stored
    document) drafts are accepted, so the trace drains in fewer decode
    steps than tokens committed."""
    eng = ServeEngine("qwen3-14b", speculate=4, **GEOM)
    seed = _prompt(np.random.RandomState(3), 16)
    doc_req = eng.submit(seed, 40)
    eng.run()
    doc = np.concatenate([seed, np.asarray(doc_req.generated, np.int32)])
    eng.submit(doc, 1)  # page-aligned prompt: stored as a draft source
    eng.run()
    follow = eng.submit(doc[:33].copy(), 20)
    eng.run()
    base = ServeEngine("qwen3-14b", **GEOM)
    base.submit(seed, 40)
    base.run()
    base.submit(doc, 1)
    base.run()
    follow_b = base.submit(doc[:33].copy(), 20)
    base.run()
    assert follow.generated == follow_b.generated
    s = eng.stats()
    assert s["draft_accepted"] > 0
    assert s["decode_steps"] < s["decode_tokens"]


# ------------------------------------------------------- scheduler policy
def test_tiny_chunk_budget_burst_drains_and_bounds_join():
    """A burst of long prompts under a tiny chunk budget must drain with
    every request served and first tokens paced by the budget (no request
    waits for the whole queue's prefill)."""
    eng = ServeEngine("qwen3-14b", prefill_chunk=4, **GEOM)
    rng = np.random.RandomState(0)
    reqs = [eng.submit(_prompt(rng, 40), 4, arrival_step=0) for _ in range(4)]
    stats = eng.run()
    assert stats["requests_finished"] == 4
    assert all(r.first_token_step >= 0 for r in reqs)
    assert "join_to_first_token_p99" in stats
    # 4 prompts x 40 tokens at 4 tokens/step is ~40 budget steps total;
    # p99 join must reflect pacing, not starvation
    assert stats["join_to_first_token_p99"] < 80


def test_admission_backpressure_no_deadlock():
    """Two requests that cannot coexist in the pool are served one after
    the other; a request that can never fit raises at submit."""
    eng = ServeEngine("qwen3-14b", prefill_chunk=8, num_pages=6, **GEOM)
    rng = np.random.RandomState(1)
    a = eng.submit(_prompt(rng, 24), 4)   # 4 of the 5 usable pages
    b = eng.submit(_prompt(rng, 24), 4)
    stats = eng.run(max_steps=500)
    assert stats["requests_finished"] == 2
    assert len(a.generated) == len(b.generated) == 4
    with pytest.raises(ValueError, match="never"):
        eng.submit(_prompt(rng, 44), 4)  # 6 pages: more than can ever free


def test_degenerate_knobs_rejected():
    with pytest.raises(ValueError, match="prefill_chunk"):
        ServeEngine("qwen3-14b", prefill_chunk=0, **GEOM)
    with pytest.raises(ValueError, match="speculate"):
        ServeEngine("qwen3-14b", speculate=-1, **GEOM)
    # recurrent-state mixers have no paged positional cache to chunk into
    with pytest.raises(ValueError, match="attention-only"):
        ServeEngine("falcon-mamba-7b", prefill_chunk=8, **GEOM)
    with pytest.raises(ValueError, match="attention-only"):
        ServeEngine("falcon-mamba-7b", speculate=2, **GEOM)


# ------------------------------------------------------------ the proposer
def test_find_last_ngram():
    hay = np.array([5, 1, 2, 9, 1, 2, 7], np.int32)
    assert find_last_ngram(hay, np.array([1, 2], np.int32)) == 4
    assert find_last_ngram(hay, np.array([9], np.int32)) == 3
    assert find_last_ngram(hay, np.array([3, 3], np.int32)) == -1
    assert find_last_ngram(hay[:1], np.array([5, 1], np.int32)) == -1


def test_proposer_self_lookup_continues_repetition():
    prop = NgramProposer(max_n=3)
    ctx = np.array([7, 3, 9, 4, 7, 3, 9, 4, 7, 3], np.int32)
    d = prop.propose(ctx, 4)
    np.testing.assert_array_equal(d, [9, 4, 7, 3])


def test_proposer_min_n_floor_ignores_unigram_noise():
    """A lone repeated token is not evidence of a continuation: with the
    default min_n=2 floor the proposer stays silent instead of turning
    every step into a wide verify step."""
    ctx = np.array([1, 2, 3, 4, 5, 6, 3], np.int32)  # only a 1-gram repeat
    assert len(NgramProposer(max_n=3).propose(ctx, 4)) == 0
    d = NgramProposer(max_n=3, min_n=1).propose(ctx, 4)
    np.testing.assert_array_equal(d, [4, 5, 6, 3])


def test_proposer_prefix_cache_fallback():
    """When the request's own context has no match, drafts come from the
    stored full prompt of an earlier request (cross-request lookup)."""
    cache = PrefixCache(page_size=4)
    doc = np.arange(100, 116, dtype=np.int32)  # aligned: gets stored

    class _Pool:
        def share(self, pages):
            pass

    cache.register_full(doc, [1, 2, 3, 4], np.zeros(8), None, _Pool())
    prop = NgramProposer(max_n=3, prefix_cache=cache)
    ctx = np.array([104, 105], np.int32)
    np.testing.assert_array_equal(prop.propose(ctx, 4), [106, 107, 108, 109])
    assert len(prop.propose(np.array([7, 8], np.int32), 4)) == 0


def test_proposer_accept_rate_accounting():
    prop = NgramProposer()
    prop.record(4, 3)
    prop.record(4, 1)
    prop.record(0, 0)  # no proposal: not counted
    assert prop.proposals == 2
    assert prop.proposed_tokens == 8
    assert prop.accepted_tokens == 4
    assert prop.accept_rate == 0.5


# ------------------------------------------------------- planner ingestion
def test_planner_ingests_verify_and_prefill_telemetry():
    """Verify rows raise the measured accepted-tokens multiplier above 1,
    scaling throughput up and per-request latency down; prefill rows feed
    the chunked-prefill throughput estimate; legacy rows (no ``kind``)
    still fit the f(b) step model unchanged."""
    rows = [
        {"step": 0, "batch": 2, "step_s": 0.010, "kind": "verify",
         "committed": 6, "drafted": 4},
        {"step": 1, "batch": 4, "step_s": 0.012, "kind": "verify",
         "committed": 12, "drafted": 8},
        {"step": 2, "batch": 0, "step_s": 0.004, "kind": "prefill",
         "prefill_tokens": 16},
    ]
    p = CapacityPlanner()
    p.observe_telemetry(rows)
    assert p.accepted_per_slot_step == pytest.approx(3.0)
    assert p.prefill_tokens_per_s == pytest.approx(16 / 0.004)
    p.fit()
    plain = CapacityPlanner()
    plain.observe_telemetry([
        {"step": 0, "batch": 2, "step_s": 0.010},
        {"step": 1, "batch": 4, "step_s": 0.012},
    ])
    plain.fit()
    assert plain.accepted_per_slot_step == 1.0
    assert plain.prefill_tokens_per_s == 0.0
    # same fitted f(b): the multiplier, not the step model, carries the win
    assert p.tokens_per_s(4) == pytest.approx(3.0 * plain.tokens_per_s(4))
    assert p.p50_latency_s(4, 30) == pytest.approx(
        plain.p50_latency_s(4, 30) / 3.0)
