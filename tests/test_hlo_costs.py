"""HLO cost parser: exact flop attribution through while loops (the fix for
cost_analysis counting loop bodies once)."""
import jax
import jax.numpy as jnp
import pytest
from jax import lax

from repro.dist.hlo_costs import analyze_hlo, top_contributors


def _costs(fn, *sds):
    txt = jax.jit(fn).lower(*sds).compile().as_text()
    return analyze_hlo(txt), txt


M, K, N = 64, 128, 96
A = jax.ShapeDtypeStruct((M, K), jnp.float32)
B = jax.ShapeDtypeStruct((K, N), jnp.float32)
W = jax.ShapeDtypeStruct((K, K), jnp.float32)


def test_plain_matmul_exact():
    c, _ = _costs(lambda a, b: a @ b, A, B)
    assert c.flops == pytest.approx(2 * M * N * K, rel=1e-3)


def test_scan_multiplies_by_trip_count():
    def scanned(a, ws):
        return lax.scan(lambda x, w: (x @ w, ()), a, ws)[0]

    ws = jax.ShapeDtypeStruct((10, K, K), jnp.float32)
    c, _ = _costs(scanned, A, ws)
    assert c.flops == pytest.approx(10 * 2 * M * K * K, rel=1e-3)
    assert c.n_whiles >= 1


def test_nested_scans_multiply():
    def nested(a, ws):
        def outer(x, w3):
            return lax.scan(lambda y, w: (y @ w, ()), x, w3)[0], ()

        return lax.scan(outer, a, ws)[0]

    ws = jax.ShapeDtypeStruct((3, 4, K, K), jnp.float32)
    c, _ = _costs(nested, A, ws)
    assert c.flops == pytest.approx(12 * 2 * M * K * K, rel=1e-3)


def test_fori_loop_static_bound():
    c, _ = _costs(lambda a, w: lax.fori_loop(0, 7, lambda i, x: x @ w, a),
                  A, W)
    assert c.flops == pytest.approx(7 * 2 * M * K * K, rel=1e-3)


def test_grad_counts_forward_and_backward():
    def loss(a, b):
        return jnp.sum((a @ b) ** 2)

    c, _ = _costs(jax.grad(loss, argnums=(0, 1)), A, B)
    # fwd (2MNK) + two bwd matmuls (dA = g b^T: 2MKN, dB = a^T g: 2KMN)
    assert c.flops >= 3 * 2 * M * N * K * 0.95


def test_bytes_and_collectives_nonnegative():
    c, txt = _costs(lambda a, b: a @ b, A, B)
    assert c.bytes_accessed > 0
    assert c.collective_wire_bytes == 0  # single device


def test_top_contributors_finds_the_dot():
    _, txt = _costs(lambda a, b: a @ b, A, B)
    rows = top_contributors(txt, "flops", 3)
    assert rows and rows[0][0] == pytest.approx(2 * M * N * K, rel=1e-3)
