"""Hemingway core: NNLS, Lasso, Ernest, convergence model, planner."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (
    CombinedModel,
    ConvergenceData,
    ConvergenceModel,
    ErnestModel,
    Planner,
    default_candidate_grid,
    greedy_d_optimal,
    lasso_cv,
    lasso_fit,
    nnls,
    r2_score,
)


# ---------------------------------------------------------------------------
# NNLS
# ---------------------------------------------------------------------------
def test_nnls_matches_scipy():
    scipy_opt = pytest.importorskip("scipy.optimize")
    rng = np.random.RandomState(0)
    for _ in range(10):
        A = rng.randn(25, 5)
        b = rng.randn(25)
        x1 = nnls(A, b)
        x2, _ = scipy_opt.nnls(A, b)
        np.testing.assert_allclose(x1, x2, atol=1e-6)


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_nnls_properties(seed):
    rng = np.random.RandomState(seed)
    A = rng.randn(20, 4)
    b = rng.randn(20)
    x = nnls(A, b)
    assert np.all(x >= 0)
    # no worse than the zero solution
    assert np.linalg.norm(b - A @ x) <= np.linalg.norm(b) + 1e-9


# ---------------------------------------------------------------------------
# Lasso
# ---------------------------------------------------------------------------
def test_lasso_recovers_sparse_coefficients():
    rng = np.random.RandomState(1)
    X = rng.randn(300, 8)
    w = np.array([2.0, 0, 0, -1.5, 0, 0.7, 0, 0])
    y = X @ w + 1.3 + 0.01 * rng.randn(300)
    fit = lasso_cv(X, y)
    np.testing.assert_allclose(fit.coef, w, atol=0.07)
    assert abs(fit.intercept - 1.3) < 0.05


def test_lasso_zero_lambda_is_ols():
    rng = np.random.RandomState(2)
    X = rng.randn(100, 3)
    w = np.array([1.0, -2.0, 0.5])
    y = X @ w
    fit = lasso_fit(X, y, lam=1e-9)
    np.testing.assert_allclose(fit.coef, w, atol=1e-4)


def test_lasso_large_lambda_kills_coefs():
    rng = np.random.RandomState(3)
    X = rng.randn(50, 4)
    y = X @ np.ones(4)
    fit = lasso_fit(X, y, lam=1e6)
    np.testing.assert_allclose(fit.coef, 0.0, atol=1e-8)


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.floats(0.5, 50.0))
def test_lasso_scale_invariance_of_predictions(seed, scale):
    """Standardization => predictions ~invariant to feature scaling."""
    rng = np.random.RandomState(seed)
    X = rng.randn(80, 3)
    y = X @ np.array([1.0, -1.0, 0.5]) + 0.01 * rng.randn(80)
    f1 = lasso_fit(X, y, lam=0.01)
    f2 = lasso_fit(X * scale, y, lam=0.01)
    np.testing.assert_allclose(f1.predict(X), f2.predict(X * scale), atol=1e-3)


# ---------------------------------------------------------------------------
# Ernest
# ---------------------------------------------------------------------------
def test_ernest_recovers_synthetic_and_extrapolates():
    m = np.array([1, 2, 4, 8, 16])
    size = np.full(5, 10_000.0)
    theta = dict(c=0.4, s=3e-4, l=0.25, m=0.02)
    t = theta["c"] + theta["s"] * size / m + theta["l"] * np.log(m + 1) \
        + theta["m"] * m
    em = ErnestModel().fit(m, size, t)
    pred = em.predict(np.array([64, 128]), np.array([10_000.0, 10_000.0]))
    true = theta["c"] + theta["s"] * 10_000 / np.array([64, 128]) \
        + theta["l"] * np.log(np.array([64, 128]) + 1.0) \
        + theta["m"] * np.array([64, 128])
    np.testing.assert_allclose(pred, true, rtol=1e-6)


def test_ernest_percent_error_under_noise():
    rng = np.random.RandomState(0)
    m = np.array([1, 2, 4, 8, 16, 32])
    size = np.full(6, 60_000.0)
    t = 0.1 + 2e-5 * size / m + 0.05 * np.log(m + 1) + 0.003 * m
    t_noisy = t * (1 + 0.03 * rng.randn(6))
    em = ErnestModel().fit(m, size, t_noisy)
    errs = em.percent_errors(m, size, t)
    # paper reports <=12% for mini-batch SGD; we demand it on synthetic
    assert np.max(errs) < 12.0


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_ernest_nonnegative_predictions(seed):
    rng = np.random.RandomState(seed)
    m = np.array([1, 2, 4, 8])
    size = np.full(4, 1000.0)
    t = np.abs(rng.randn(4)) + 0.1
    em = ErnestModel().fit(m, size, t)
    assert np.all(em.predict(np.array([1, 16, 256]), np.full(3, 1000.0)) >= 0)


# ---------------------------------------------------------------------------
# Convergence model (the paper's §4)
# ---------------------------------------------------------------------------
def _cocoa_like_curves(c0=0.5, c1=2.0, p_star=1.0, ms=(1, 2, 4, 8, 16, 32),
                       iters=500):
    return {m: p_star + c1 * np.power(1 - c0 / m, np.arange(1, iters + 1))
            for m in ms}


def test_convergence_fit_quality():
    data = ConvergenceData.from_curves(_cocoa_like_curves(), 1.0,
                                       stop_gap=1e-4)
    model = ConvergenceModel().fit(data)
    assert model.r2(data) > 0.99


def test_convergence_loo_m_extrapolation():
    """Fig 4: predict an unobserved degree of parallelism."""
    data = ConvergenceData.from_curves(_cocoa_like_curves(), 1.0,
                                       stop_gap=1e-4)
    loo = ConvergenceModel().loo_m(data)
    for m, (r2, _) in loo.items():
        assert r2 > 0.9, f"m={m} held-out R2={r2}"


def test_convergence_forward_prediction():
    """Fig 5: predict 1 and 10 iterations ahead from a 50-iter window."""
    curves = _cocoa_like_curves(ms=(8,), iters=220)
    data = ConvergenceData.from_curves(curves, 1.0)
    model = ConvergenceModel()
    for ahead in (1, 10):
        res = model.forward_prediction(data, window=50, ahead=ahead)
        rows = res[8]
        rel = np.abs(rows[:, 2] - rows[:, 1]) / np.abs(rows[:, 1])
        assert np.median(rel) < 0.05, f"ahead={ahead}: {np.median(rel)}"


# ---------------------------------------------------------------------------
# Planner h(t, m) = g(t/f(m), m)
# ---------------------------------------------------------------------------
def _fitted_combined(c0=0.5):
    data = ConvergenceData.from_curves(_cocoa_like_curves(c0=c0), 1.0,
                                       stop_gap=1e-4)
    conv = ConvergenceModel().fit(data)
    m = np.array([1, 2, 4, 8, 16, 32])
    size = np.full(6, 60_000.0)
    t = 0.05 + 1e-5 * size / m + 0.02 * np.log(m + 1) + 0.004 * m
    sys = ErnestModel().fit(m, size, t)
    return CombinedModel(sys, conv, data_size=60_000.0, max_iters=5_000)


def test_planner_fastest_to_epsilon_matches_bruteforce():
    cm = _fitted_combined()
    planner = Planner({"cocoa": cm})
    decision = planner.fastest_to_epsilon(1e-3, m_grid=[1, 2, 4, 8, 16, 32])
    # brute force over the same table
    best = min(decision.table, key=decision.table.get)
    assert (decision.algorithm, decision.m) == best
    assert decision.predicted_time == pytest.approx(
        decision.table[best])


def test_planner_budget_query():
    cm = _fitted_combined()
    planner = Planner({"cocoa": cm})
    d = planner.best_within_budget(5.0, m_grid=[1, 2, 4, 8, 16, 32])
    assert d.predicted_value == min(d.table.values())


def test_planner_prefers_fast_converger():
    slow = _fitted_combined(c0=0.1)
    fast = _fitted_combined(c0=0.9)
    planner = Planner({"slow": slow, "fast": fast})
    d = planner.fastest_to_epsilon(1e-3, m_grid=[4, 8])
    assert d.algorithm == "fast"


# ---------------------------------------------------------------------------
# Experiment design
# ---------------------------------------------------------------------------
def test_expdesign_selects_diverse_configs_within_budget():
    cands = default_candidate_grid(max_m=64)
    chosen = greedy_d_optimal(cands, budget=200.0)
    assert len(chosen) >= 4
    assert len({c.m for c in chosen}) >= 3  # spans multiple machine counts
    assert sum(c.cost() for c in chosen) <= 200.0


def test_r2_score_basics():
    y = np.array([1.0, 2.0, 3.0])
    assert r2_score(y, y) == 1.0
    assert r2_score(y, np.full(3, y.mean())) == 0.0
