"""Fault tolerance: failure -> restore -> continue; stragglers; elastic."""
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

from repro.launch.train import Trainer, TrainerOptions
from repro.runtime.failures import FailureInjector, RestartPolicy, SimulatedFailure
from repro.runtime.straggler import StragglerMonitor

SRC = str(Path(__file__).resolve().parents[1] / "src")


def test_training_survives_node_failure(tmp_path):
    inj = FailureInjector.at(12)
    opts = TrainerOptions(arch="stablelm-1.6b", smoke=True, steps=25,
                          seq_len=32, global_batch=2, ckpt_dir=str(tmp_path),
                          ckpt_every=5, failure_injector=inj, log_every=0)
    t = Trainer(opts)
    t.run()
    assert t.step == 25
    assert inj.fired == {12}
    losses = [l for _, l in t.history]
    assert np.isfinite(losses).all()


def test_restart_policy_exhausts():
    p = RestartPolicy(max_restarts=2)
    assert p.should_restart() and p.should_restart()
    assert not p.should_restart()


def test_repeated_failures_eventually_fatal(tmp_path):
    inj = FailureInjector(fail_at_steps={3, 4, 5, 6, 7, 8, 9})
    opts = TrainerOptions(arch="stablelm-1.6b", smoke=True, steps=12,
                          seq_len=32, global_batch=2, ckpt_dir=None,
                          failure_injector=inj, log_every=0)
    t = Trainer(opts)
    # without checkpoints the trainer restarts from scratch up to the policy
    # limit, then surfaces the failure
    with pytest.raises(SimulatedFailure):
        t.run()


def test_straggler_monitor_flags_persistent_slowness():
    mon = StragglerMonitor(consecutive=3, min_ratio=1.5)
    events = []
    for step in range(50):
        t = 0.10 + 0.001 * np.sin(step)
        events.append(mon.observe(step, t))
    assert not any(events), "steady steps must not flag"
    for step in range(50, 56):
        ev = mon.observe(step, 0.5)
        events.append(ev)
    fired = [e for e in events if e]
    assert fired and fired[0].action in ("rebalance", "hot_spare",
                                         "sync_relax")


def test_straggler_uses_ernest_expectation():
    mon = StragglerMonitor(expected_time=0.1, consecutive=1, min_ratio=1.5)
    for step in range(20):
        mon.observe(step, 0.1)
    ev = None
    for step in range(20, 24):
        ev = ev or mon.observe(step, 0.35)  # 3.5x expected -> rebalance band
    assert ev is not None and ev.action in ("rebalance", "sync_relax")


ELASTIC_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_smoke_config
from repro.dist.partitioning import Rules
from repro.launch.mesh import make_debug_mesh
from repro.models.model import LM
from repro.models.runtime import Runtime
from repro.runtime.elastic import rescale, shardings_for
from repro.checkpoint.manager import CheckpointManager
import tempfile

cfg = get_smoke_config("qwen3-14b")
lm = LM(cfg, Runtime(remat="none"))
params, axes = lm.init(jax.random.PRNGKey(0))
with tempfile.TemporaryDirectory() as td:
    mgr = CheckpointManager(td, async_write=False)
    mgr.save(1, {"params": params})
    # restore onto a 4x2 mesh, then onto a 2x4 mesh (elastic resize)
    for shape in [(4, 2), (2, 4)]:
        mesh = make_debug_mesh(*shape)
        rules = Rules.default(mesh)
        host, _ = mgr.restore()
        placed = rescale({"params": host["params"]}, mesh, rules,
                         {"params": axes})
        leaves = jax.tree.leaves(placed["params"])
        assert all(l.sharding.mesh.shape == dict(zip(("data", "model"), shape))
                   for l in leaves)
        # numerically identical after resharding
        for a, b in zip(jax.tree.leaves(params), leaves):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
print("ELASTIC_OK")
"""


def test_elastic_rescale_across_meshes():
    res = subprocess.run(
        [sys.executable, "-c", ELASTIC_SCRIPT],
        env={"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin", "HOME": "/root",
             "JAX_PLATFORMS": "cpu"},  # backend probing hangs without it
        capture_output=True, text=True, timeout=420)
    assert "ELASTIC_OK" in res.stdout, res.stderr[-2000:]
