"""Fault tolerance: failure -> restore -> continue; stragglers; elastic;
kill-the-writer crash safety and bit-identical resume from the last
complete manifest."""
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.checkpoint.manager import CheckpointManager, CorruptCheckpoint
from repro.launch.train import Trainer, TrainerOptions
from repro.runtime.failures import FailureInjector, RestartPolicy, SimulatedFailure
from repro.runtime.straggler import StragglerMonitor

SRC = str(Path(__file__).resolve().parents[1] / "src")


def test_training_survives_node_failure(tmp_path):
    inj = FailureInjector.at(12)
    opts = TrainerOptions(arch="stablelm-1.6b", smoke=True, steps=25,
                          seq_len=32, global_batch=2, ckpt_dir=str(tmp_path),
                          ckpt_every=5, failure_injector=inj, log_every=0)
    t = Trainer(opts)
    t.run()
    assert t.step == 25
    assert inj.fired == {12}
    losses = [l for _, l in t.history]
    assert np.isfinite(losses).all()


def test_restart_policy_exhausts():
    p = RestartPolicy(max_restarts=2)
    assert p.should_restart() and p.should_restart()
    assert not p.should_restart()


def test_repeated_failures_eventually_fatal(tmp_path):
    inj = FailureInjector(fail_at_steps={3, 4, 5, 6, 7, 8, 9})
    opts = TrainerOptions(arch="stablelm-1.6b", smoke=True, steps=12,
                          seq_len=32, global_batch=2, ckpt_dir=None,
                          failure_injector=inj, log_every=0)
    t = Trainer(opts)
    # without checkpoints the trainer restarts from scratch up to the policy
    # limit, then surfaces the failure
    with pytest.raises(SimulatedFailure):
        t.run()


def test_straggler_monitor_flags_persistent_slowness():
    mon = StragglerMonitor(consecutive=3, min_ratio=1.5)
    events = []
    for step in range(50):
        t = 0.10 + 0.001 * np.sin(step)
        events.append(mon.observe(step, t))
    assert not any(events), "steady steps must not flag"
    for step in range(50, 56):
        ev = mon.observe(step, 0.5)
        events.append(ev)
    fired = [e for e in events if e]
    assert fired and fired[0].action in ("rebalance", "hot_spare",
                                         "sync_relax")


def test_straggler_uses_ernest_expectation():
    mon = StragglerMonitor(expected_time=0.1, consecutive=1, min_ratio=1.5)
    for step in range(20):
        mon.observe(step, 0.1)
    ev = None
    for step in range(20, 24):
        ev = ev or mon.observe(step, 0.35)  # 3.5x expected -> rebalance band
    assert ev is not None and ev.action in ("rebalance", "sync_relax")


def test_failure_resume_is_bit_identical_to_clean_run(tmp_path):
    """Replay from the last complete manifest: a run that dies at step 12
    and restores from its step-10 checkpoint must retrace the clean run's
    losses EXACTLY — params, optimizer state and data cursor all resume
    from the manifest, so there is nothing left to diverge."""
    kw = dict(arch="stablelm-1.6b", smoke=True, steps=18, seq_len=32,
              global_batch=2, ckpt_every=5, log_every=0)
    clean = Trainer(TrainerOptions(ckpt_dir=str(tmp_path / "clean"), **kw))
    clean.run()

    inj = FailureInjector.at(12)
    crashed = Trainer(TrainerOptions(ckpt_dir=str(tmp_path / "crash"),
                                     failure_injector=inj, **kw))
    crashed.run()
    assert inj.fired == {12}
    want = dict(clean.history)
    # steps 10..11 were re-executed after the restore; the LAST recorded
    # loss per step is the one the surviving model actually trained on
    got = dict(crashed.history)
    assert set(got) == set(want)
    for step in sorted(want):
        assert got[step] == want[step], f"loss diverged at step {step}"


# ---------------------------------------------------------------------------
# kill the writer: crash-safety of the checkpoint commit protocol
# ---------------------------------------------------------------------------
WRITER_SCRIPT = r"""
import sys
import numpy as np
from repro.checkpoint.manager import CheckpointManager

mgr = CheckpointManager(sys.argv[1], keep=100, async_write=False,
                        shard_bytes=1 << 18)
for step in range(1, 10000):
    tree = {"w": np.full((256, 1024), step, np.float32),
            "nest": {"b": np.full((4096,), step, np.int32)}}
    mgr.save_async(step, tree).wait()
    print(f"COMMIT {step}", flush=True)
"""


def test_sigkill_mid_flush_leaves_restorable_state(tmp_path):
    """SIGKILL a real writer process mid-stream: whatever instant the kill
    lands at, the directory must restore to the newest COMPLETE step with
    that step's exact contents (the manifest-last commit protocol)."""
    proc = subprocess.Popen(
        [sys.executable, "-c", WRITER_SCRIPT, str(tmp_path)],
        env={"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin", "HOME": "/root",
             "JAX_PLATFORMS": "cpu"},
        stdout=subprocess.PIPE, text=True)
    try:
        commits = 0
        for line in proc.stdout:
            if line.startswith("COMMIT"):
                commits += 1
                if commits >= 3:
                    break
    finally:
        proc.kill()  # SIGKILL: no cleanup handlers run
        proc.wait()
    mgr = CheckpointManager(tmp_path, keep=100)
    steps = mgr.all_steps()
    assert steps and max(steps) >= 3
    tree, meta = mgr.restore()
    s = meta["step"]
    assert s == max(steps)
    assert (np.asarray(tree["w"]) == s).all()
    assert (np.asarray(tree["nest"]["b"]) == s).all()
    # the dead writer's flock died with it: a new writer takes over cleanly
    h = mgr.save_async(s + 1, {"w": np.zeros(4, np.float32)})
    h.wait()
    assert mgr.latest_step() == s + 1


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 7))
def test_writer_killed_at_any_file_op_never_serves_torn_state(
        tmp_path_factory, kill_at):
    """Kill-point schedule over the writer's file operations (shard writes,
    manifest, marker): whichever op the writer dies on, readers either see
    the new step complete (died after the manifest commit point) or fall
    back to the previous step — never a torn mixture.  A retried save then
    clears the debris and commits."""
    import repro.checkpoint.manager as M

    tmp = tmp_path_factory.mktemp(f"kp{kill_at}")
    tree = lambda s: {"a": np.full((8,), s, np.float32),  # noqa: E731
                      "b": {"c": np.full((3,), s, np.int32),
                            "d": np.full((5,), s, np.float32)}}
    mgr = CheckpointManager(tmp, keep=5, async_write=False, shard_bytes=1)
    mgr.save_async(1, tree(1)).wait()

    real = {n: getattr(M, n) for n in
            ("atomic_write_bytes", "atomic_write_json", "atomic_write_text")}
    calls = {"n": 0}

    def dying(fn):
        def inner(*a, **kw):
            if calls["n"] == kill_at:
                calls["n"] += 1
                raise RuntimeError("writer killed at file op")
            calls["n"] += 1
            return fn(*a, **kw)
        return inner

    for name, fn in real.items():
        setattr(M, name, dying(fn))
    try:
        killed = False
        try:
            mgr.save_async(2, tree(2)).wait()
        except RuntimeError:
            killed = True
    finally:
        for name, fn in real.items():
            setattr(M, name, fn)

    committed = 2 in mgr.all_steps()
    if committed:
        _, meta = mgr.restore(step=2, fallback=False)
        assert meta["step"] == 2
    else:
        assert killed and mgr.all_steps() == [1]
        with pytest.raises(CorruptCheckpoint):
            mgr.restore(step=2, fallback=False)
        with pytest.warns(RuntimeWarning, match="fell back"):
            restored, meta = mgr.restore(step=2)
        assert meta["step"] == 1
        assert (np.asarray(restored["a"]) == 1).all()
        # retry after the crash: torn remains are swept, the step commits
        mgr.save_async(2, tree(2)).wait()
        assert mgr.all_steps() == [1, 2]
        restored, meta = mgr.restore(step=2, fallback=False)
        assert meta["step"] == 2
        assert (np.asarray(restored["b"]["c"]) == 2).all()


ELASTIC_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_smoke_config
from repro.dist.partitioning import Rules
from repro.launch.mesh import make_debug_mesh
from repro.models.model import LM
from repro.models.runtime import Runtime
from repro.runtime.elastic import rescale, shardings_for
from repro.checkpoint.manager import CheckpointManager
import tempfile

cfg = get_smoke_config("qwen3-14b")
lm = LM(cfg, Runtime(remat="none"))
params, axes = lm.init(jax.random.PRNGKey(0))
with tempfile.TemporaryDirectory() as td:
    mgr = CheckpointManager(td, async_write=False)
    mgr.save(1, {"params": params})
    # restore onto a 4x2 mesh, then onto a 2x4 mesh (elastic resize)
    for shape in [(4, 2), (2, 4)]:
        mesh = make_debug_mesh(*shape)
        rules = Rules.default(mesh)
        host, _ = mgr.restore()
        placed = rescale({"params": host["params"]}, mesh, rules,
                         {"params": axes})
        leaves = jax.tree.leaves(placed["params"])
        assert all(l.sharding.mesh.shape == dict(zip(("data", "model"), shape))
                   for l in leaves)
        # numerically identical after resharding
        for a, b in zip(jax.tree.leaves(params), leaves):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
print("ELASTIC_OK")
"""


def test_elastic_rescale_across_meshes():
    res = subprocess.run(
        [sys.executable, "-c", ELASTIC_SCRIPT],
        env={"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin", "HOME": "/root",
             "JAX_PLATFORMS": "cpu"},  # backend probing hangs without it
        capture_output=True, text=True, timeout=420)
    assert "ELASTIC_OK" in res.stdout, res.stderr[-2000:]
