"""Gradient compression: fidelity bounds, error feedback, trainability."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compression.gradient import (
    CompressionConfig,
    GradientCompressor,
    int8_roundtrip,
    powersgd_roundtrip,
    topk_roundtrip,
)
from repro.launch.train import Trainer, TrainerOptions


def test_int8_roundtrip_error_bound():
    g = jax.random.normal(jax.random.PRNGKey(0), (64, 64))
    rt = int8_roundtrip(g)
    scale = float(jnp.max(jnp.abs(g))) / 127.0
    assert float(jnp.max(jnp.abs(rt - g))) <= scale * 0.5 + 1e-6


def test_topk_keeps_largest():
    g = jnp.asarray(np.arange(100, dtype=np.float32).reshape(10, 10))
    out = topk_roundtrip(g, 0.1)
    assert int((out != 0).sum()) == 10
    assert float(out.max()) == 99.0


def test_powersgd_rank_approximation():
    rng = np.random.RandomState(0)
    low = rng.randn(32, 4) @ rng.randn(4, 16)  # exactly rank 4
    g = jnp.asarray(low, jnp.float32)
    approx, q = powersgd_roundtrip(g, None, rank=4)
    # one power iteration on an exactly-low-rank matrix is exact-ish
    approx2, _ = powersgd_roundtrip(g, q, rank=4)
    rel = float(jnp.linalg.norm(approx2 - g) / jnp.linalg.norm(g))
    assert rel < 1e-3


def test_powersgd_skips_vectors():
    g = jnp.ones((7,))
    approx, _ = powersgd_roundtrip(g, None, rank=2)
    np.testing.assert_array_equal(np.asarray(approx), np.ones(7))


def test_error_feedback_accumulates_residual():
    comp = GradientCompressor(CompressionConfig(scheme="topk",
                                                topk_ratio=0.25))  # k=1
    grads = {"w": jnp.asarray([1.0, 0.1, 0.0, 0.0])}
    state = comp.init_state(grads)
    out, state = comp.compress(grads, state)
    # the dropped 0.1 must live in the error-feedback buffer
    assert float(state["ef"]["w"][1]) == pytest.approx(0.1, abs=1e-6)
    out2, _ = comp.compress({"w": jnp.zeros(4)}, state)
    # ...and be re-injected next round
    assert float(out2["w"][1]) == pytest.approx(0.1, abs=1e-6)


@pytest.mark.parametrize("scheme", ["int8", "topk", "powersgd"])
def test_training_converges_with_compression(scheme):
    opts = TrainerOptions(arch="stablelm-1.6b", smoke=True, steps=30,
                          seq_len=32, global_batch=2, log_every=0,
                          compression=scheme)
    t = Trainer(opts)
    t.run()
    losses = [l for _, l in t.history]
    assert losses[-1] < losses[0], f"{scheme}: {losses[0]} -> {losses[-1]}"


def test_compression_ratio_estimates():
    for scheme, bound in [("int8", 0.3), ("topk", 0.05), ("powersgd", 0.1)]:
        c = GradientCompressor(CompressionConfig(scheme=scheme,
                                                 topk_ratio=0.01))
        assert c.compressed_bytes_ratio() <= bound
