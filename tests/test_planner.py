"""Planner (core/hemingway.py): the paper's two queries over a small
registry of analytically-generated algorithm models — fast, no simulator."""
import numpy as np
import pytest

from repro.core import (
    CombinedModel,
    ConvergenceData,
    ConvergenceModel,
    ErnestModel,
    Planner,
)
from repro.core.hemingway import NoFeasiblePlan, PlanDecision

P_STAR = 0.25
MS = (1, 2, 4, 8)


def _combined(gap0: float, rate_c: float, t_base: float,
              max_iters: int = 20_000) -> CombinedModel:
    """Analytic algorithm: gap(i, m) = gap0 * exp(-rate_c * i / m) and
    t_iter(m) = t_base * (1 + 4/m + 0.01*m) — a clean Ernest family."""
    curves = {}
    for m in MS:
        i = np.arange(1, 400)
        curves[m] = P_STAR + gap0 * np.exp(-rate_c * i / m)
    conv = ConvergenceModel().fit(
        ConvergenceData.from_curves(curves, P_STAR))
    ms = np.asarray(MS, np.float64)
    times = t_base * (1.0 + 4.0 / ms + 0.01 * ms)
    sys_model = ErnestModel().fit(ms, np.full(len(ms), 1.0), times)
    return CombinedModel(sys_model, conv, data_size=1.0, max_iters=max_iters)


@pytest.fixture(scope="module")
def planner():
    return Planner({
        "fast_percall_slow_converge": _combined(2.0, 0.02, 1e-3),
        "slow_percall_fast_converge": _combined(2.0, 0.50, 5e-3),
    })


def test_fastest_to_epsilon_picks_global_argmin(planner):
    d = planner.fastest_to_epsilon(1e-3, m_grid=MS)
    assert isinstance(d, PlanDecision)
    assert d.algorithm in planner.models
    assert d.m in MS
    # the decision must be the argmin of its own table
    best_key = min(d.table, key=d.table.get)
    assert (d.algorithm, d.m) == best_key
    assert d.predicted_time == pytest.approx(d.table[best_key])
    assert d.predicted_time > 0


def test_fastest_to_epsilon_table_is_consistent(planner):
    d = planner.fastest_to_epsilon(1e-3, m_grid=MS)
    # every feasible (algorithm, m) appears with the model's own prediction
    for (name, m), t in d.table.items():
        assert name in planner.models and m in MS
        assert t == pytest.approx(
            planner.models[name].time_to_epsilon(1e-3, m), rel=1e-9)
    # table values for one algorithm agree with iters * f(m)
    for name, model in planner.models.items():
        for m in MS:
            iters = model.iters_to_epsilon(1e-3, m)
            if iters is not None:
                assert (name, m) in d.table


def test_fastest_to_epsilon_no_feasible_returns_typed_result():
    # gap can never get below gap0*exp(-rate*max_iters/m); ask for far less
    tight = Planner({"only": _combined(2.0, 1e-6, 1e-3, max_iters=100)})
    plan = tight.fastest_to_epsilon(1e-12, m_grid=MS)
    assert isinstance(plan, NoFeasiblePlan)
    assert not plan                       # falsy: `if plan:` means feasible
    assert plan.query == "fastest_to_epsilon"
    assert "eps=1e-12" in plan.reason
    assert plan.table == {}               # nothing converged -> empty table


def test_no_feasible_plan_carries_partial_table():
    """One algorithm converges, the target is still unreachable for the
    other: a feasible decision is returned and only converging entries
    appear in the table (partial predictions are data, not errors)."""
    mixed = Planner({
        "reaches": _combined(2.0, 0.50, 5e-3),
        "never": _combined(2.0, 1e-6, 1e-3, max_iters=100),
    })
    d = mixed.fastest_to_epsilon(1e-3, m_grid=MS)
    assert isinstance(d, PlanDecision)
    assert d.algorithm == "reaches"
    assert all(name == "reaches" for name, _ in d.table)


def test_best_within_budget_full_table_and_argmin(planner):
    d = planner.best_within_budget(2.0, m_grid=MS)
    # budget query is always feasible: the table covers the full grid
    assert set(d.table) == {(n, m) for n in planner.models for m in MS}
    best_key = min(d.table, key=d.table.get)
    assert (d.algorithm, d.m) == best_key
    assert d.predicted_value == pytest.approx(d.table[best_key])
    for (name, m), v in d.table.items():
        assert v == pytest.approx(
            float(planner.models[name].h(2.0, m)[0]), rel=1e-9)


def test_budget_monotonicity(planner):
    """More budget can only improve the best achievable objective."""
    v_small = planner.best_within_budget(0.5, m_grid=MS).predicted_value
    v_large = planner.best_within_budget(50.0, m_grid=MS).predicted_value
    assert v_large <= v_small + 1e-9


def test_fastest_to_epsilon_easier_target_is_faster(planner):
    t_loose = planner.fastest_to_epsilon(1e-1, m_grid=MS).predicted_time
    t_tight = planner.fastest_to_epsilon(1e-3, m_grid=MS).predicted_time
    assert t_loose <= t_tight + 1e-9
