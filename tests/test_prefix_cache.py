"""PrefixCache eviction-order and draft-MRU invariants (DESIGN.md §13).

Two regressions pinned here:

* ``release_lru`` used to evict chain pages one-at-a-time in raw LRU order,
  which could drop a chain's *head* while descendants stayed registered —
  ``match`` breaks at the first missing key, so the descendants became
  unreachable forever while still pinning pool references (a strand).
  Eviction must be suffix-first: only chain leaves are dropped.
* ``draft`` used to skip the MRU bump on its ``_draft_hit`` fast path, so a
  prompt actively serving speculative drafts could sit at the LRU end and be
  evicted mid-stream under pool pressure.

The property test runs random register/match/evict/clear schedules against
a shadow reachability + refcount model (same style as the PagePool schedule
test in tests/test_serve.py): after ANY schedule, every cached chain key
must be reachable via ``match``/``peek`` and the pool's in-use count must
equal exactly the references the cache plus outstanding matches hold."""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.serve import PagePool, PrefixCache

PS = 4  # page size for all tests here


def _prompt(base: int, n_tokens: int) -> np.ndarray:
    """Deterministic prompt in a per-``base`` disjoint token range, so
    different prompts never share chain keys or pages."""
    return np.arange(n_tokens, dtype=np.int32) + base * 10_000


def _register(pc: PrefixCache, pool: PagePool, prompt: np.ndarray):
    """Allocate pages, register the prompt's chains, then drop our refs —
    afterwards only the cache's own references pin the pages."""
    n_pages = -(-len(prompt) // PS)
    pages = pool.alloc(n_pages)
    pc.register(prompt, pages, pool)
    pool.free(pages)


def _cache_refs(pc: PrefixCache) -> int:
    return len(pc._pages) + sum(len(e.page_ids) for e in pc._full.values())


# ------------------------------------------------------------- strand bugfix
def test_release_lru_never_strands_descendants():
    """Force eviction with a long chain at the LRU end: raw-LRU eviction
    would drop the chain's page-0 key first, stranding pages 1..3; suffix-
    first eviction must unwind from the leaf instead."""
    pool = PagePool(num_pages=8, page_size=PS)  # 7 allocatable
    pc = PrefixCache(PS)
    long_prompt = _prompt(1, 4 * PS)  # 4-page chain, registered first (LRU)
    short_prompt = _prompt(2, PS)  # 1-page chain, registered second (MRU)
    _register(pc, pool, long_prompt)
    _register(pc, pool, short_prompt)
    assert pool.free_pages == 2 and len(pc._pages) == 5

    released = pc.release_lru(pool, min_free=3)
    assert released == 1 and pool.free_pages == 3
    # the long chain lost exactly its LEAF: 3 pages still reachable in order
    assert pc.peek(long_prompt) == 3
    assert pc.peek(short_prompt) == 1
    # nothing is stranded: every remaining key is reachable via match
    assert pc.peek(long_prompt) + pc.peek(short_prompt) == len(pc._pages)

    # deeper pressure keeps unwinding the old chain suffix-first
    pc.release_lru(pool, min_free=5)
    assert pc.peek(long_prompt) == 1
    assert pc.peek(long_prompt) + pc.peek(short_prompt) == len(pc._pages)

    pc.clear(pool)
    assert pool.pages_in_use == 0


def test_release_lru_frees_only_unreferenced_refcounts():
    """A stranded page is unreachable BUT still referenced — the original
    bug's leak signature.  After eviction under any min_free, the pool's
    in-use count must equal the cache's reachable-key count exactly."""
    pool = PagePool(num_pages=12, page_size=PS)
    pc = PrefixCache(PS)
    prompts = [_prompt(i + 1, (i % 3 + 1) * PS) for i in range(4)]
    for p in prompts:
        _register(pc, pool, p)
    for min_free in (3, 5, 8):
        pc.release_lru(pool, min_free=min_free)
        reachable = sum(pc.peek(p) for p in prompts)
        assert reachable == len(pc._pages)
        assert pool.pages_in_use == _cache_refs(pc)
    pc.clear(pool)
    assert pool.pages_in_use == 0


# ------------------------------------------------------------ draft-MRU bugfix
def test_draft_fast_path_bumps_source_entry():
    """An entry serving drafts through the ``_draft_hit`` fast path must be
    MRU-bumped on every served draft, so eviction pressure takes idle
    entries first and never kills an active draft source mid-stream."""
    pool = PagePool(num_pages=16, page_size=PS)
    pc = PrefixCache(PS)

    def register_full(base: int, tokens: np.ndarray):
        pages = pool.alloc(len(tokens) // PS)
        pc.register_full(tokens, pages, np.zeros(8, np.float32), None, pool)
        pool.free(pages)

    source = np.asarray([5, 6, 7, 8, 9, 10, 11, 12], np.int32)  # 2 pages
    register_full(1, source)
    ngram = source[:3]
    # first draft scans and latches the source as _draft_hit
    d = pc.draft(ngram, max_draft=4)
    assert d is not None and list(d) == [8, 9, 10, 11]
    # two younger idle entries arrive after it
    register_full(2, _prompt(2, 2 * PS))
    register_full(3, _prompt(3, 2 * PS))
    # fast-path draft: must bump the source past both idle entries
    assert pc.draft(ngram, max_draft=4) is not None
    assert next(iter(pc._full)) != pc._draft_hit

    # pressure evicts two full entries; the drafting source must survive
    pc.release_lru(pool, min_free=pool.free_pages + 4)
    assert len(pc._full) == 1
    d = pc.draft(ngram, max_draft=4)
    assert d is not None and list(d) == [8, 9, 10, 11]
    pc.clear(pool)
    assert pool.pages_in_use == 0


# ----------------------------------------------------------- property schedule
@settings(max_examples=40, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(8, 24))
def test_prefix_cache_random_schedule_invariants(seed, num_pages):
    """Random register/match/register_full/evict/clear schedules vs a shadow
    reachability + refcount model.  Invariants after every operation:

    * reachability — every cached chain key is reachable by walking some
      prompt from page 0 (``sum(peek) == len(_pages)``: no strands);
    * refcount conservation — pool in-use equals cache-held references plus
      references handed out by ``match``/``match_full`` and not yet freed;
    * ``match`` agrees with ``peek`` (the router's probe sees exactly what
      admission would share)."""
    rng = np.random.RandomState(seed)
    pool = PagePool(num_pages=num_pages, page_size=PS)
    pc = PrefixCache(PS)
    prompts = [_prompt(i + 1, int(rng.randint(1, 5)) * PS) for i in range(5)]

    def assert_invariants():
        reachable = sum(pc.peek(p) for p in prompts)
        assert reachable == len(pc._pages), "stranded chain keys"
        assert pool.pages_in_use == _cache_refs(pc)

    for _ in range(120):
        op = rng.choice(["register", "register_full", "match", "evict", "clear"])
        p = prompts[rng.randint(len(prompts))]
        n_pages = len(p) // PS
        if op == "register":
            if pool.free_pages < n_pages:
                pc.release_lru(pool, min_free=n_pages)
            if pool.free_pages >= n_pages:
                _register(pc, pool, p)
        elif op == "register_full":
            if pool.free_pages < n_pages:
                pc.release_lru(pool, min_free=n_pages)
            if pool.free_pages >= n_pages:
                pages = pool.alloc(n_pages)
                pc.register_full(p, pages, np.zeros(4, np.float32), None, pool)
                pool.free(pages)
        elif op == "match":
            expect = pc.peek(p)
            got = pc.match(p, pool)
            assert len(got) == expect
            if got:
                pool.free(got)  # immediately return the shared refs
        elif op == "evict":
            pc.release_lru(pool, min_free=int(rng.randint(1, num_pages)))
        else:
            pc.clear(pool)
            assert pool.pages_in_use == 0
        assert_invariants()

    pc.clear(pool)
    assert pool.pages_in_use == 0
    assert pool.free_pages == num_pages - 1
