"""repro.kernels.tune: config-cache round-trip, sweep memoization,
roofline pruning, and the telemetry export the capacity planner ingests.

The sweeps here use the "smoke" preset shapes (interpret-mode / CPU-proxy
timings) so the whole module runs in tier-1; the full-preset sweep runs
in the non-blocking slow CI job via the module CLI."""
import json

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.tune import (
    FAMILIES,
    SWEEP_SHAPES,
    ConfigCache,
    bench_rows,
    cache_key,
    candidates_for,
    decode_step_rows,
    ensure,
    sweep,
)
from repro.kernels.tune.roofline import (
    VMEM_BUDGET,
    estimate,
    light_speed_s,
    prune,
)
from repro.serve import CapacityPlanner

SHAPE = dict(SWEEP_SHAPES["smoke"]["flash_decode_paged"])


# ------------------------------------------------------------------- cache
def test_config_cache_roundtrip(tmp_path):
    path = tmp_path / "tune.json"
    cache = ConfigCache(str(path))
    key = cache_key("flash_decode_paged", SHAPE, jnp.float32, backend="cpu")
    assert "flash_decode_paged|" in key and "|float32|cpu" in key
    cache.put(key, family="flash_decode_paged", shape=SHAPE,
              dtype=jnp.float32, config={"pages_per_program": 2},
              us_per_call=123.4, swept=3, pruned=4, backend="cpu")
    cache.save()
    # a fresh instance reads the same entry back
    reloaded = ConfigCache(str(path))
    entry = reloaded.get(key)
    assert entry["config"] == {"pages_per_program": 2}
    assert entry["us_per_call"] == pytest.approx(123.4)
    assert entry["candidates_swept"] == 3 and entry["candidates_pruned"] == 4
    assert reloaded.config(key) == {"pages_per_program": 2}
    # the file is plain JSON with a schema version
    payload = json.loads(path.read_text())
    assert payload["version"] == 1 and key in payload["entries"]
    # a stale schema version is discarded, not misread
    payload["version"] = 0
    path.write_text(json.dumps(payload))
    assert ConfigCache(str(path)).entries == {}


def test_cache_key_dtype_and_backend_separation():
    k1 = cache_key("ssm_scan", {"s": 64}, jnp.float32, backend="cpu")
    k2 = cache_key("ssm_scan", {"s": 64}, jnp.bfloat16, backend="cpu")
    k3 = cache_key("ssm_scan", {"s": 64}, jnp.float32, backend="tpu")
    assert len({k1, k2, k3}) == 3


# ------------------------------------------------------------------- sweep
def test_ensure_returns_cached_config_without_resweeping(tmp_path):
    """Acceptance: the second call for the same (shape, dtype, backend) key
    returns the cached config without re-sweeping."""
    cache = ConfigCache(str(tmp_path / "tune.json"))
    cfg1 = ensure("flash_decode_paged", SHAPE, jnp.float32, cache=cache,
                  iters=1)
    assert cache.sweeps == 1
    cfg2 = ensure("flash_decode_paged", SHAPE, jnp.float32, cache=cache,
                  iters=1)
    assert cfg2 == cfg1
    assert cache.sweeps == 1, "second ensure() must not re-sweep"
    # round-trip through disk: a fresh cache needs no sweep either
    fresh = ConfigCache(str(tmp_path / "tune.json"))
    assert ensure("flash_decode_paged", SHAPE, jnp.float32, cache=fresh,
                  sweep_on_miss=False) == cfg1
    assert fresh.sweeps == 0
    # a different dtype is a different key -> miss without sweep permission
    assert ensure("flash_decode_paged", SHAPE, jnp.bfloat16, cache=fresh,
                  sweep_on_miss=False) is None


@pytest.mark.parametrize("family", FAMILIES)
def test_smoke_sweep_every_family(family):
    """Interpret-mode autotuner smoke: each family sweeps at its smoke
    shape, returns a candidate from its own space, and records pruning."""
    cache = ConfigCache(path=None)  # in-memory
    shape = SWEEP_SHAPES["smoke"][family]
    config, entry = sweep(family, shape, jnp.float32, cache=cache, iters=1)
    assert config in candidates_for(family, shape)
    assert entry["us_per_call"] > 0
    assert entry["candidates_swept"] >= 1
    total = entry["candidates_swept"] + entry["candidates_pruned"]
    assert total == len(candidates_for(family, shape))


# ---------------------------------------------------------------- roofline
def test_roofline_prune_vmem_and_slack():
    shape = {"b": 1, "h": 2, "s": 4096, "d": 128}
    cands = candidates_for("flash_attention", shape)
    kept, n_pruned = prune("flash_attention", shape, cands)
    assert kept, "pruning must keep at least one candidate"
    assert n_pruned + len(kept) == len(cands)
    for est in kept:
        assert est.vmem_bytes <= VMEM_BUDGET
    # modeled times of the kept set stay within the slack of the best
    t_best = min(e.t_model_s for e in kept)
    assert all(e.t_model_s <= 3.0 * t_best + 1e-12 for e in kept)


def test_roofline_estimates_monotone_in_work():
    small = estimate("flash_decode_paged",
                     {"b": 1, "hk": 1, "g": 1, "d": 16, "page": 8,
                      "npp": 4}, {"pages_per_program": 2})
    big = estimate("flash_decode_paged",
                   {"b": 4, "hk": 4, "g": 2, "d": 64, "page": 16,
                    "npp": 128}, {"pages_per_program": 2})
    assert big.flops > small.flops and big.bytes_moved > small.bytes_moved
    assert light_speed_s(big.flops, big.bytes_moved) > light_speed_s(
        small.flops, small.bytes_moved)


# --------------------------------------------------------------- telemetry
def _cache_with_decode_entries():
    cache = ConfigCache(path=None)
    for b, us in [(1, 900.0), (2, 1100.0), (4, 1600.0), (8, 2500.0)]:
        shape = {"b": b, "hk": 2, "g": 2, "d": 32, "page": 16, "npp": 32}
        cache.put(cache_key("flash_decode_paged", shape, jnp.float32,
                            backend="cpu"),
                  family="flash_decode_paged", shape=shape,
                  dtype=jnp.float32, config={"pages_per_program": 4},
                  us_per_call=us, swept=2, pruned=5, backend="cpu")
    return cache


def test_bench_rows_shape():
    cache = _cache_with_decode_entries()
    rows = bench_rows(cache)
    assert len(rows) == 4
    name, us, derived = rows[0]
    assert name.startswith("tune/flash_decode_paged/")
    assert us > 0 and "pages_per_program=4" in derived
    assert "swept=2" in derived and "pruned=5" in derived


def test_capacity_planner_fits_on_tuned_kernel_rows():
    """The planner fits its f(b) step model from measured kernel timings
    (scaled to a whole decode step) — measured costs instead of defaults."""
    cache = _cache_with_decode_entries()
    rows = decode_step_rows(cache)
    assert sorted(r["batch"] for r in rows) == [1, 2, 4, 8]
    planner = CapacityPlanner()
    n = planner.observe_tuned_kernels(rows, n_layers=4, overhead_s=1e-4)
    assert n == 4
    planner.fit()
    # step time at batch 4: 4 layers x 1600us + 100us overhead
    assert planner.step_time(4) == pytest.approx(4 * 1.6e-3 + 1e-4, rel=0.2)
    assert planner.step_time(8) > planner.step_time(1)


def test_tuned_lookup_feeds_paged_decode(tmp_path, monkeypatch):
    """The ops wrapper resolves pages_per_program from the default cache
    when not given explicitly (tuned path), falling back to the default
    on a miss."""
    import repro.kernels.tune as tune
    from repro.kernels.flash_decode.ops import (
        DEFAULT_PAGES_PER_PROGRAM,
        _tuned_value,
    )

    path = tmp_path / "tune.json"
    monkeypatch.setenv("REPRO_TUNE_CACHE", str(path))
    tune.reset_default_cache()
    try:
        shape = {"b": 2, "hk": 2, "g": 1, "d": 8, "page": 4, "npp": 4}
        # miss -> default
        assert _tuned_value("flash_decode_paged", shape, jnp.float32,
                            "pages_per_program",
                            DEFAULT_PAGES_PER_PROGRAM) == \
            DEFAULT_PAGES_PER_PROGRAM
        cache = ConfigCache(str(path))
        cache.put(cache_key("flash_decode_paged", shape, jnp.float32),
                  family="flash_decode_paged", shape=shape,
                  dtype=jnp.float32, config={"pages_per_program": 2},
                  us_per_call=10.0, swept=1, pruned=0)
        cache.save()
        tune.reset_default_cache()
        assert _tuned_value("flash_decode_paged", shape, jnp.float32,
                            "pages_per_program",
                            DEFAULT_PAGES_PER_PROGRAM) == 2
        # end-to-end: tuned blocking yields the same bits as explicit
        rng = np.random.RandomState(0)
        q = jnp.asarray(rng.randn(2, 2, 8), jnp.float32)
        kp = jnp.asarray(rng.randn(9, 2, 4, 8), jnp.float32)
        vp = jnp.asarray(rng.randn(9, 2, 4, 8), jnp.float32)
        pt = jnp.asarray(rng.randint(0, 9, (2, 4)), jnp.int32)
        lens = jnp.asarray([3, 14], jnp.int32)
        from repro.kernels.flash_decode.ops import paged_decode_attention

        out_tuned = paged_decode_attention(q, kp, vp, lens, pt,
                                           impl="stream")
        out_explicit = paged_decode_attention(q, kp, vp, lens, pt,
                                              impl="stream",
                                              pages_per_program=2)
        np.testing.assert_array_equal(np.asarray(out_tuned),
                                      np.asarray(out_explicit))
    finally:
        tune.reset_default_cache()
