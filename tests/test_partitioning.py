"""Sharding rules: resolution, dedupe, divisibility fallback."""
import numpy as np
from hypothesis import given, settings, strategies as st
from jax.sharding import PartitionSpec as P

from repro.dist.partitioning import Rules


class FakeMesh:
    def __init__(self, shape, names):
        self.axis_names = names
        self.devices = np.empty(shape)


def rules_2d():
    return Rules.default(FakeMesh((16, 16), ("data", "model")))


def rules_3d():
    return Rules.default(FakeMesh((2, 16, 16), ("pod", "data", "model")))


def test_basic_param_resolution():
    r = rules_2d()
    assert r.param_pspec(("embed", "mlp")) == P("data", "model")
    assert r.param_pspec(("vocab", "embed")) == P("model", "data")
    assert r.param_pspec(("norm",)) == P(None)


def test_pod_axis_joins_fsdp():
    r = rules_3d()
    spec = r.param_pspec(("embed", "mlp"), (8192, 24576))
    assert spec == P(("pod", "data"), "model")


def test_dedupe_first_dim_wins():
    r = rules_2d()
    # both dims want 'model' -> second gets None
    spec = r.param_pspec(("mlp", "expert"))
    assert spec == P("model", None)


def test_divisibility_fallback_drops_axis():
    r = rules_2d()
    # kv_heads=8 can't shard over model=16 -> replicated, head_dim claims it
    spec = r.act_pspec(("cache_batch", "act_kv_heads", "cache_seq",
                        "cache_head_dim"), (128, 8, 32768, 128))
    assert spec == P("data", None, None, "model")
    # kv_heads=32 divides -> heads sharded, head_dim replicated
    spec = r.act_pspec(("cache_batch", "act_kv_heads", "cache_seq",
                        "cache_head_dim"), (128, 32, 32768, 128))
    assert spec == P("data", "model", None, None)


def test_partial_axis_tuple_kept():
    r = rules_3d()
    # batch 2 divides pod(2) but not pod*data(32): keep only 'pod'
    spec = r.act_pspec(("batch", "seq"), (2, 4096))
    assert spec == P("pod", None)


def test_override():
    r = rules_2d().override(acts={"cache_seq": "data", "batch": None})
    spec = r.act_pspec(("batch", "cache_seq"), (1, 524288))
    assert spec == P(None, "data")


PARAM_AXES = ["embed", "mlp", "vocab", "heads_flat", "kv_flat", "expert",
              "norm", "layers", None]
ACT_AXES = ["batch", "cache_batch", "act_heads", "act_mlp", "seq",
            "cache_seq", "cache_head_dim", "act_embed", None]


def _random_mesh(rng):
    """Random 2d/3d mesh with power-of-two axis sizes — divisibility
    fallback must hold for ANY mesh geometry, not just 16x16."""
    if rng.rand() < 0.5:
        shape = (int(rng.choice([2, 4, 8, 16])), int(rng.choice([2, 4, 8, 16])))
        names = ("data", "model")
    else:
        shape = (2, int(rng.choice([2, 4, 8])), int(rng.choice([2, 4, 8, 16])))
        names = ("pod", "data", "model")
    return Rules.default(FakeMesh(shape, names)), dict(zip(names, shape))


def _check_spec(spec, shape, sizes):
    """The two resolution invariants: no mesh axis claimed twice, and a
    sharded dim always divides the product of its axes' sizes."""
    seen = []
    for dim, entry in enumerate(tuple(spec)):
        if entry is None:
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        prod = 1
        for a in axes:
            assert a not in seen, f"axis {a} repeated in {spec}"
            seen.append(a)
            prod *= sizes[a]
        assert shape[dim] % prod == 0, (spec, shape, sizes)


@settings(max_examples=60, deadline=None)
@given(st.lists(st.sampled_from(PARAM_AXES), min_size=1, max_size=4),
       st.integers(0, 2**31 - 1))
def test_param_resolution_properties(logical, seed):
    """No mesh axis appears twice; sharded dims always divide — for random
    parameter shapes on random mesh geometries."""
    rng = np.random.RandomState(seed)
    r, sizes = _random_mesh(rng)
    shape = tuple(int(rng.choice([1, 2, 6, 8, 16, 64, 256, 1024]))
                  for _ in logical)
    _check_spec(r.param_pspec(tuple(logical), shape), shape, sizes)


@settings(max_examples=60, deadline=None)
@given(st.lists(st.sampled_from(ACT_AXES), min_size=1, max_size=4),
       st.integers(0, 2**31 - 1))
def test_act_resolution_properties(logical, seed):
    """Same invariants for activation/cache logical axes, including the
    tuple batch entries ("pod", "data") whose prefixes must also divide."""
    rng = np.random.RandomState(seed)
    r, sizes = _random_mesh(rng)
    shape = tuple(int(rng.choice([1, 2, 6, 8, 16, 64, 256, 1024]))
                  for _ in logical)
    _check_spec(r.act_pspec(tuple(logical), shape), shape, sizes)


@settings(max_examples=40, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_resolution_without_shape_never_repeats_axes(seed):
    """Shape-less resolution (shardings for ShapeDtypeStruct-free paths)
    still obeys dedupe on any mesh."""
    rng = np.random.RandomState(seed)
    r, sizes = _random_mesh(rng)
    names = [PARAM_AXES[i] for i in
             rng.choice(len(PARAM_AXES), size=rng.randint(1, 5))]
    spec = r.param_pspec(tuple(names))
    flat = []
    for entry in tuple(spec):
        if entry is None:
            continue
        flat.extend(entry if isinstance(entry, tuple) else (entry,))
    assert len(flat) == len(set(flat)), spec


def test_batch_axes_and_model_axis():
    r = rules_3d()
    assert r.batch_axes() == ("pod", "data")
    assert r.model_axis() == "model"
    r2 = rules_2d()
    assert r2.batch_axes() == ("data",)
