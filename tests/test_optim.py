"""Distributed optimization algorithms: the paper's §2 empirical claims."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import (
    BSPCluster,
    CocoaConfig,
    ERMProblem,
    GDConfig,
    LBFGSConfig,
    LocalSGDConfig,
    SGDConfig,
    run_cocoa,
    run_gd,
    run_lbfgs,
    run_local_sgd,
    run_minibatch_sgd,
    synthetic_mnist,
)


@pytest.fixture(scope="module")
def problem():
    X, y = synthetic_mnist(4096, 128, 32, 0.09, 0.35, 0)
    return ERMProblem(jnp.asarray(X), jnp.asarray(y), lam=1e-3, loss="hinge")


@pytest.fixture(scope="module")
def smooth_problem():
    X, y = synthetic_mnist(2048, 64, 16, 0.09, 0.35, 1)
    return ERMProblem(jnp.asarray(X), jnp.asarray(y), lam=1e-3,
                      loss="logistic")


def test_cocoa_dual_ascends_and_gap_shrinks(problem):
    rec = run_cocoa(problem, CocoaConfig(4, 25, plus=False))
    assert rec.gap[-1] < rec.gap[0]
    assert rec.gap[-1] > -1e-6  # weak duality
    assert rec.dual[-1] > rec.dual[0]


def test_cocoa_plus_dual_monotone(problem):
    """CoCoA+ (adding, sigma'=K) has a per-round dual ascent guarantee."""
    rec = run_cocoa(problem, CocoaConfig(8, 20, plus=True))
    assert np.all(np.diff(rec.dual) >= -1e-7)


def test_cocoa_convergence_degrades_with_m(problem):
    """Fig 1b: more machines => slower convergence per iteration."""
    gaps = {}
    for m in (4, 16, 64):
        rec = run_cocoa(problem, CocoaConfig(m, 20, plus=False, seed=3))
        gaps[m] = np.minimum.accumulate(rec.primal)[-1]
    assert gaps[64] > gaps[4], gaps


def test_cocoa_beats_sgd(problem):
    """Fig 1c: CoCoA-family >> SGD-family at the same iteration count."""
    m = 8
    cocoa = run_cocoa(problem, CocoaConfig(m, 20, plus=False))
    sgd = run_minibatch_sgd(problem, SGDConfig(m, 20, batch_per_worker=64))
    assert cocoa.primal[-1] < sgd.primal[-1]


def test_local_sgd_runs_and_descends(problem):
    rec = run_local_sgd(problem, LocalSGDConfig(4, 15))
    assert rec.primal[-1] < rec.primal[0]


def test_gd_converges_m_independent(smooth_problem):
    rec = run_gd(smooth_problem, GDConfig(60, lr=1.0))
    assert rec.primal[-1] < rec.primal[0]


def test_lbfgs_beats_gd_per_iteration(smooth_problem):
    gd = run_gd(smooth_problem, GDConfig(30, lr=1.0))
    lbfgs = run_lbfgs(smooth_problem, LBFGSConfig(30))
    assert lbfgs.primal[-1] <= gd.primal[-1] + 1e-9


def test_lbfgs_rejects_nonsmooth(problem):
    with pytest.raises(ValueError):
        run_lbfgs(problem, LBFGSConfig(2))


def test_bsp_cluster_u_shape():
    """Fig 1a: per-iteration time improves then degrades with m (comm)."""
    cluster = BSPCluster()
    times = {m: cluster.iteration_time(m, compute_total_s=2.0, d=784)
             for m in (1, 8, 64, 2048)}
    assert times[8] < times[1]          # parallelism helps
    assert times[2048] > times[64]      # comm/driver overhead dominates


def test_ernest_sample_collection(problem):
    cluster = BSPCluster()
    samples = cluster.collect_ernest_samples(
        problem, "cocoa", [(1, 0.1), (2, 0.1), (4, 0.2), (8, 0.2)],
        iters_per_sample=2)
    assert len(samples) == 4
    model = cluster.fit_ernest(samples)
    assert model.predict(16, problem.n) > 0
