"""Minimal, dependency-free stand-in for the ``hypothesis`` API surface the
test suite uses (``given``, ``settings``, ``strategies.integers/floats/
lists/sampled_from``).

The container bakes its dependency set and does not ship hypothesis;
tests/conftest.py registers this module under ``sys.modules["hypothesis"]``
**only when the real package is absent**, so environments with hypothesis
installed (e.g. CI images that include it) get true property-based
shrinking and this stub never shadows it.

Semantics: ``@given`` runs the wrapped test ``max_examples`` times with
pseudo-random draws from a deterministic seed (stable across runs, varied
per test name), re-raising the first failure with the offending example
attached — no shrinking, same contract otherwise.
"""
from __future__ import annotations

import functools
import inspect
import random
import zlib

DEFAULT_MAX_EXAMPLES = 100


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example_from(self, rng: random.Random):
        return self._draw(rng)


class strategies:
    @staticmethod
    def integers(min_value=0, max_value=2 ** 31 - 1) -> _Strategy:
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    @staticmethod
    def floats(min_value=0.0, max_value=1.0) -> _Strategy:
        return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    @staticmethod
    def booleans() -> _Strategy:
        return _Strategy(lambda rng: bool(rng.getrandbits(1)))

    @staticmethod
    def sampled_from(elements) -> _Strategy:
        pool = list(elements)
        return _Strategy(lambda rng: pool[rng.randrange(len(pool))])

    @staticmethod
    def lists(elements: _Strategy, min_size=0, max_size=10) -> _Strategy:
        def draw(rng):
            n = rng.randint(min_size, max_size)
            return [elements.example_from(rng) for _ in range(n)]

        return _Strategy(draw)


def settings(max_examples: int = DEFAULT_MAX_EXAMPLES, deadline=None,
             **_ignored):
    def deco(fn):
        fn._stub_max_examples = max_examples
        return fn

    return deco


def given(*strats: _Strategy):
    def deco(fn):
        # like hypothesis: positional strategies bind to the *rightmost*
        # test parameters; anything to their left stays visible to pytest
        # as fixtures
        orig_params = list(inspect.signature(fn).parameters.values())
        fixture_params = orig_params[:len(orig_params) - len(strats)]
        example_names = [p.name for p in orig_params[len(fixture_params):]]

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            # @settings may sit above OR below @given — check both targets
            n = getattr(wrapper, "_stub_max_examples",
                        getattr(fn, "_stub_max_examples",
                                DEFAULT_MAX_EXAMPLES))
            seed = zlib.crc32(fn.__qualname__.encode())
            rng = random.Random(seed)
            for i in range(n):
                example = {name: s.example_from(rng)
                           for name, s in zip(example_names, strats)}
                try:
                    fn(*args, **kwargs, **example)
                except Exception as e:  # noqa: BLE001 — annotate and re-raise
                    raise AssertionError(
                        f"falsifying example (stub run {i + 1}/{n}): "
                        f"{example!r}") from e

        # pytest must not see the example parameters as fixtures
        del wrapper.__wrapped__
        wrapper.__signature__ = inspect.Signature(fixture_params)
        return wrapper

    return deco
