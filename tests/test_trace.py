"""PR-9 observability: spans, attribution, SLO burn rates, percentiles.

Covers the trace subsystem contract end to end: deterministic span
identity (same seed -> byte-identical Perfetto exports), nesting
invariants (children link to parents and never out-time them), the
Perfetto schema validator, predicted-vs-measured attribution with
kernel rows joined from the tune cache, the P² streaming percentile
estimator against exact numpy quantiles, sink/tracker context managers
and torn-tail recovery, ordered ``log_from_device`` emission under jit,
and the SLO burn-rate monitor — including the headline claim that it
fires *before* the PR-7 drift detector on a sustained 2x slowdown, at
stream level and through the fleet scheduler.
"""
import json
import math

import numpy as np
import pytest

from repro.telemetry import (
    JSONLSink,
    MemorySink,
    P2Quantile,
    ServeStepEvent,
    SloAlertEvent,
    SpanEvent,
    StatsSink,
    Tracker,
    TuneEvent,
    read_events,
)
from repro.telemetry.refit import DriftConfig, DriftDetector
from repro.telemetry.trace import (
    CountingClock,
    SloConfig,
    SLOMonitor,
    SpanTracer,
    attribute,
    det_id,
    flame_summary,
    format_attribution,
    format_tree,
    monitor_serve_events,
    span_roots,
    to_perfetto,
    validate_perfetto,
    write_perfetto,
)


# ------------------------------------------------------- deterministic ids
def test_det_id_is_stable_and_distinct():
    assert det_id("trace", "serve", 0) == det_id("trace", "serve", 0)
    assert det_id("trace", "serve", 0) != det_id("trace", "serve", 1)
    assert len(det_id("x")) == 16
    int(det_id("x"), 16)  # hex


def test_same_seed_traces_have_identical_ids():
    def run():
        tr = SpanTracer(trace=("serve", "m", 0, 0), clock=CountingClock())
        with tr.span("step", step=0, component="engine.step"):
            with tr.span("decode", step=0, component="engine.decode", batch=2):
                pass
            tr.emit_span("join", dur=0.0, step=0, component="scheduler.join")
        return tr.tracker.events("span")

    a, b = run(), run()
    assert [e.span_id for e in a] == [e.span_id for e in b]
    assert [e.parent_id for e in a] == [e.parent_id for e in b]
    assert a[0].trace_id == b[0].trace_id


def test_same_seed_perfetto_exports_are_byte_identical(tmp_path):
    paths = []
    for i in range(2):
        tr = SpanTracer(trace=("run", 7), clock=CountingClock())
        with tr.span("outer", step=0):
            with tr.span("inner", step=0):
                pass
        p = tmp_path / f"trace_{i}.json"
        write_perfetto(p, tr.tracker.events("span"))
        paths.append(p)
    assert paths[0].read_bytes() == paths[1].read_bytes()


def test_set_trace_rekeys_only_before_first_span():
    tr = SpanTracer(trace=("serve", "m", 0, -1))
    old = tr.trace_id
    tr.set_trace("serve", "m", 0, 3, replica=3)
    assert tr.trace_id != old and tr.replica == 3
    with tr.span("s"):
        pass
    with pytest.raises(RuntimeError):
        tr.set_trace("serve", "m", 0, 4)


# ------------------------------------------------------- nesting invariants
def test_span_nesting_parent_links_and_durations():
    tr = SpanTracer(trace=("nest",), clock=CountingClock())
    with tr.span("parent", step=1, component="engine.step") as ph:
        with tr.span("child_a", step=1, component="engine.decode"):
            pass
        with tr.span("child_b", step=1, component="engine.verify"):
            pass
    evs = tr.tracker.events("span")
    # close order: children emit before the parent
    assert [e.name for e in evs] == ["child_a", "child_b", "parent"]
    parent = evs[-1]
    kids = evs[:-1]
    assert parent.span_id == ph.span_id
    assert all(k.parent_id == parent.span_id for k in kids)
    assert all(k.trace_id == parent.trace_id for k in kids)
    # children start within the parent and their summed time fits inside it
    assert all(k.t0 >= parent.t0 for k in kids)
    assert sum(k.dur for k in kids) <= parent.dur + 1e-12
    assert [r.name for r in span_roots(evs)] == ["parent"]


def test_emit_span_parents_to_open_scope():
    tr = SpanTracer(trace=("emit",), clock=CountingClock())
    with tr.span("outer") as h:
        tr.emit_span("marker", dur=0.0, component="scheduler.join", wait_steps=4)
    evs = tr.tracker.events("span")
    marker = [e for e in evs if e.name == "marker"][0]
    assert marker.parent_id == h.span_id
    assert marker.dur == 0.0 and marker.attrs["wait_steps"] == 4


def test_span_handle_annotations():
    tr = SpanTracer(trace=("attrs",), clock=CountingClock())
    with tr.span("decode", component="engine.decode", batch=4) as h:
        h.set(rows=2).predict(0.125)
    (ev,) = tr.tracker.events("span")
    assert ev.attrs == {"batch": 4, "rows": 2}
    assert ev.predicted_s == 0.125


# ------------------------------------------------------------ export layer
def _demo_spans():
    tr = SpanTracer(trace=("demo",), replica=0, clock=CountingClock())
    for step in range(3):
        with tr.span("step", step=step, component="engine.step"):
            with tr.span("decode", step=step, component="engine.decode",
                         predicted_s=0.002, batch=2):
                pass
    return tr.tracker.events("span")


def test_perfetto_schema_valid_and_loadable(tmp_path):
    evs = _demo_spans()
    payload = to_perfetto(evs)
    assert validate_perfetto(payload) == []
    xs = [e for e in payload["traceEvents"] if e["ph"] == "X"]
    assert len(xs) == len(evs)
    assert all(e["ts"] >= 0 and e["dur"] >= 0 for e in xs)
    out = tmp_path / "t.json"
    write_perfetto(out, evs)
    again = json.loads(out.read_text())
    assert validate_perfetto(again) == []


def test_perfetto_validator_catches_corruption():
    payload = to_perfetto(_demo_spans())
    xs = [e for e in payload["traceEvents"] if e["ph"] == "X"]
    xs[0]["args"]["parent_id"] = "feedfacefeedface"  # dangling link
    del xs[1]["name"]
    xs[2]["dur"] = -1.0
    errs = validate_perfetto(payload)
    assert len(errs) >= 3


def test_format_tree_and_flame_render():
    evs = _demo_spans()
    tree = format_tree(evs)
    assert "step" in tree and "decode" in tree
    assert sum(1 for ln in tree.splitlines()
               if ln.startswith("  decode")) == 3
    flame = flame_summary(evs)
    assert "engine.decode" in flame and "%" in flame


# ------------------------------------------------------------- attribution
def test_attribution_ratio_and_reconcile():
    evs = _demo_spans()  # decode spans carry predicted_s=0.002
    attr = attribute(evs)
    row = attr.row("engine.decode")
    assert row is not None and row.n == 3
    assert row.predicted_s == pytest.approx(0.006)
    assert row.ratio == pytest.approx(row.measured_s / 0.006)
    # root spans are the engine.step scopes: reconciliation against their
    # own summed wall time is exact by construction
    assert attr.reconcile(attr.total_measured_s, tol=0.0)
    assert not attr.reconcile(attr.total_measured_s * 2.0)


def test_attribution_kernel_rows_from_tune_cache():
    evs = list(_demo_spans())
    evs.append(TuneEvent(
        family="flash_decode_paged", shape={"b": 2, "d": 64},
        dtype="float32", backend="cpu", config={"block_b": 2},
        us_per_call=50.0,
    ))
    attr = attribute(evs, n_layers=4)
    row = attr.row("kernel/flash_decode_paged@b2")
    assert row is not None
    assert row.predicted_s == pytest.approx(4 * 50.0 * 1e-6)
    decode = [e for e in evs if getattr(e, "component", "") == "engine.decode"]
    assert row.measured_s == pytest.approx(
        sum(d.dur for d in decode) / len(decode))
    assert "kernel/flash_decode_paged@b2" in format_attribution(attr)


def test_attribution_prices_unpredicted_spans_via_planner():
    class FlatPlanner:
        def step_time(self, batch):
            return 0.004

    tr = SpanTracer(trace=("pl",), clock=CountingClock())
    with tr.span("decode", component="engine.decode", batch=4):
        pass
    attr = attribute(tr.tracker.events("span"), planner=FlatPlanner())
    row = attr.row("engine.decode")
    assert row.predicted_s == pytest.approx(0.004)


# -------------------------------------------------------------- P² sketch
def test_p2_quantile_matches_numpy():
    rng = np.random.default_rng(0)
    xs = rng.lognormal(mean=0.0, sigma=0.6, size=4000)
    for p in (0.5, 0.95, 0.99):
        est = P2Quantile(p)
        for x in xs:
            est.observe(float(x))
        exact = float(np.percentile(xs, 100 * p))
        assert est.value() == pytest.approx(exact, rel=0.05)


def test_p2_quantile_exact_below_five_points():
    est = P2Quantile(0.5)
    for x in (5.0, 1.0, 3.0):
        est.observe(x)
    assert est.value() == pytest.approx(3.0)
    assert est.n == 3


def test_stats_sink_streams_percentiles():
    sink = StatsSink()
    for i in range(200):
        sink.write(ServeStepEvent(step=i, step_s=float(i), op="decode",
                                  batch=1, committed=1))
    fields = sink.summary()["serve_step"]["fields"]["step_s"]
    assert fields["p50"] == pytest.approx(99.5, rel=0.1)
    assert fields["p95"] == pytest.approx(189.0, rel=0.1)
    assert fields["p99"] == pytest.approx(197.0, rel=0.1)


# ------------------------------------------------- sinks, tails, ordering
def test_tracker_and_sinks_are_context_managers(tmp_path):
    path = tmp_path / "run.jsonl"
    with Tracker([MemorySink(), JSONLSink(path)]) as t:
        t.emit(ServeStepEvent(step=0, step_s=0.01, op="decode", batch=1,
                              committed=1))
    # closing the tracker closed (and flushed) the JSONL sink
    evs = read_events(path)
    assert len(evs) == 1 and evs[0].step_s == 0.01


def test_read_events_skips_torn_trailing_line(tmp_path):
    path = tmp_path / "torn.jsonl"
    with Tracker([JSONLSink(path)]) as t:
        for i in range(3):
            t.emit(ServeStepEvent(step=i, step_s=0.01, op="decode",
                                  batch=1, committed=1))
    whole = path.read_text()
    path.write_text(whole[:-20])  # writer died mid-append
    with pytest.warns(RuntimeWarning):
        evs = read_events(path)
    assert [e.step for e in evs] == [0, 1]


def test_read_events_raises_on_mid_file_corruption(tmp_path):
    path = tmp_path / "corrupt.jsonl"
    with Tracker([JSONLSink(path)]) as t:
        for i in range(3):
            t.emit(ServeStepEvent(step=i, step_s=0.01, op="decode",
                                  batch=1, committed=1))
    lines = path.read_text().splitlines()
    lines[1] = lines[1][:-15]  # torn in the middle: corruption, not a tail
    path.write_text("\n".join(lines) + "\n")
    with pytest.raises(json.JSONDecodeError):
        read_events(path)


def test_log_from_device_ordered_preserves_program_order():
    import jax
    import jax.numpy as jnp

    from repro.telemetry.tracker import log_from_device

    t = Tracker()

    @jax.jit
    def step(x):
        for i in range(4):
            x = x + 1.0
            log_from_device(
                t,
                lambda v, i=i: ServeStepEvent(step=i, step_s=float(v),
                                              op="decode", batch=1,
                                              committed=1),
                jnp.sum(x),
                ordered=True,
            )
        return x

    step(jnp.zeros((2,)))
    jax.effects_barrier()
    evs = t.events("serve_step")
    assert [e.step for e in evs] == [0, 1, 2, 3]
    assert [e.step_s for e in evs] == [2.0, 4.0, 6.0, 8.0]


# ------------------------------------------------------------- SLO monitor
def test_slo_monitor_quiet_on_healthy_stream():
    mon = SLOMonitor(SloConfig(target=1.0, window=8, min_points=2),
                     name="svc", objective="latency")
    for step in range(50):
        assert mon.observe(step, 0.5) is None
    assert mon.burn_rate == 0.0
    assert mon.budget_remaining() == 1.0


def test_slo_monitor_fires_fast_burn_then_cools_down():
    cfg = SloConfig(target=1.0, budget=0.05, window=8, burn_threshold=2.0,
                    min_points=2, cooldown=10)
    mon = SLOMonitor(cfg, name="svc", objective="latency")
    alerts = []
    for step in range(30):
        lat = 0.5 if step < 10 else 2.5
        a = mon.observe(step, lat)
        if a is not None:
            alerts.append(a)
    # one bad point in an 8-window is 12.5% bad vs a 5% budget = 2.5x burn:
    # the alert lands on the FIRST breached observation
    assert alerts[0].step == 10
    assert alerts[0].burn_rate >= cfg.burn_threshold
    # cooldown: next alert no earlier than 10 steps later
    assert len(alerts) >= 2 and alerts[1].step - alerts[0].step >= 10
    assert mon.budget_remaining() < 1.0


def test_slo_alert_event_round_trips():
    from repro.telemetry import from_dict

    ev = SloAlertEvent(step=5, slo="svc", objective="latency", target=1.0,
                       burn_rate=2.5, budget=0.05, window_bad=1, window=8,
                       budget_remaining=0.9)
    again = from_dict(json.loads(json.dumps(ev.to_dict())))
    assert again == ev


def test_slo_fires_before_drift_detector_on_2x_slowdown():
    """The headline ordering claim, at stream level: one latency stream, a
    sustained 2x slowdown at step 100 — the burn-rate monitor pages on the
    first breached point, the drift detector needs several residuals."""
    slo = SLOMonitor(SloConfig(target=1.2, budget=0.05, window=8,
                               burn_threshold=2.0, min_points=2),
                     name="svc", objective="latency")
    det = DriftDetector("svc", DriftConfig(window=8, threshold=0.25,
                                           min_points=4))
    slo_step = drift_step = None
    for step in range(200):
        lat = 1.0 if step < 100 else 2.0  # predicted stays 1.0
        if slo_step is None and slo.observe(step, lat) is not None:
            slo_step = step
        if drift_step is None and det.observe(step, 1.0, lat) is not None:
            drift_step = step
    assert slo_step is not None and drift_step is not None
    assert slo_step < drift_step
    assert slo_step == 100  # first bad point
    assert drift_step >= 102  # window mean needs >= 3 bad points


def test_monitor_serve_events_replays_both_objectives():
    tr = SpanTracer(trace=("mon",), clock=CountingClock())
    events = []
    for step in range(20):
        tr.emit_span("join", dur=0.0, step=step, component="scheduler.join",
                     wait_steps=0 if step < 10 else 6)
        events.append(ServeStepEvent(
            step=step, op="decode", batch=1, committed=1,
            step_s=0.001 if step < 10 else 0.05))
    events.extend(tr.tracker.events("span"))
    events.sort(key=lambda e: e.step)
    alerts = monitor_serve_events(
        events,
        per_token=SloConfig(target=0.01, window=8, min_points=2),
        join_first_token=SloConfig(target=2.0, window=8, min_points=2),
    )
    objectives = {a.objective for a in alerts}
    assert objectives == {"per_token_latency", "join_to_first_token"}
    assert min(a.step for a in alerts) >= 10


# --------------------------------------------------- planner + fleet hooks
def test_capacity_planner_ingests_slo_alerts():
    from repro.serve.planner import CapacityPlanner

    p = CapacityPlanner()
    a = SloAlertEvent(step=7, slo="svc", objective="latency", target=1.0,
                      burn_rate=3.0, budget=0.05, window_bad=2, window=8)
    n = p.ingest([a, ServeStepEvent(step=8, step_s=0.01, op="decode",
                                    batch=2, committed=2)])
    assert n == 2
    assert p.slo_alerts == [a]
    assert p.last_slo_alert_step == 7


def _constrained_drift_fleet(ticks=90):
    """The 2x-slowdown scenario with a latency breach the autoscaler cannot
    absorb.  The effective-unit autoscaler neutralizes a pure capacity
    halving whenever spare hosts exist (that is its PR-8 contract), and
    exhausting hosts evicts the training job — killing the drift signal —
    so the breach is pinned to slowdown onset with a coincident demand
    spike the replica-capped deployment cannot serve inside its SLO."""
    from repro.fleet.scheduler import FleetConfig
    from repro.fleet.simulate import DEFAULT_FLEET_SLO, FleetSimulator
    from repro.fleet.workloads import (
        RequestTrace,
        ServeDeployment,
        TrainingJob,
        serve_capacity_planner,
        training_model,
    )
    from repro.runtime.chaos import ChaosEvent, ChaosTrace

    tick_s = 300.0
    trace = ChaosTrace.generate(0, ticks, 16, p_straggler=0.0,
                                p_slowdown=0.0, p_preempt=0.0,
                                p_membership=0.0, warmup=4)
    onset = ticks // 3
    trace.events.append(ChaosEvent(step=onset, kind="slowdown", host=-1,
                                   magnitude=2.0, duration=ticks // 3))
    trace.events.sort(key=lambda e: (e.step, e.host, e.kind))
    jobs = [TrainingJob(
        name="job_bg", eps=1e-2, arrival_s=0.0,
        deadline_s=0.70 * ticks * tick_s, m_options=(2, 4, 8),
        model=training_model(compute_s=36.0, rate=3.2e-3),
        ckpt_every_s=6 * tick_s)]
    qps = [2.0] * ticks
    for t in range(onset, min(onset + 6, ticks)):
        qps[t] = 8.0  # > 2-replica capacity: modeled p95 ~3.3s vs 2.2s SLO
    deployments = [ServeDeployment(
        name="serve_pinned",
        planner=serve_capacity_planner(dispatch_s=0.4, per_seq_s=0.35,
                                       log_b_s=0.02),
        trace=RequestTrace(seed=0, tick_s=tick_s, qps=qps),
        slo_p95_s=2.2, gen_tokens=1,
        batch_grid=(1, 2), replica_options=(1, 2))]
    cfg = FleetConfig(
        tick_s=tick_s, spans=True, slo=DEFAULT_FLEET_SLO,
        drift=DriftConfig(window=8, threshold=0.25, min_points=4,
                          cooldown=16))
    sim = FleetSimulator(trace, jobs, deployments, cfg)
    return sim.run(steps=ticks), onset


def test_fleet_slo_alert_precedes_drift_detector():
    log, onset = _constrained_drift_fleet()
    slo_decisions = log.decisions("slo_alert:serve_pinned")
    drift_decisions = log.decisions("drift:job_bg")
    assert slo_decisions, "burn-rate monitor never fired"
    assert drift_decisions, "drift detector never fired"
    assert slo_decisions[0][0] < drift_decisions[0][0]
    assert slo_decisions[0][0] >= onset
    # the alert rides the bus as a typed event too
    alerts = log.events("slo_alert")
    assert alerts and alerts[0].slo == "serve_pinned"
    assert alerts[0].burn_rate >= 2.0


def test_slo_boost_raises_autoscale_headroom():
    """A fired alert grants extra headroom: the same demand provisions one
    more replica while the boost window is open."""
    from repro.fleet.cluster import FleetCluster
    from repro.fleet.scheduler import SLO_BOOST_TICKS, FleetConfig, FleetScheduler
    from repro.fleet.workloads import (
        RequestTrace,
        ServeDeployment,
        serve_capacity_planner,
    )
    from repro.runtime.chaos import ChaosTrace

    def provision(boosted):
        trace = ChaosTrace.generate(0, 4, 12, p_straggler=0.0,
                                    p_slowdown=0.0, p_preempt=0.0,
                                    p_membership=0.0)
        cluster = FleetCluster(trace)
        cluster.advance(0)
        dep = ServeDeployment(
            name="svc",
            planner=serve_capacity_planner(dispatch_s=0.018,
                                           per_seq_s=0.0042, log_b_s=0.002),
            trace=RequestTrace(seed=0, tick_s=300.0, qps=[4.0] * 4),
            slo_p95_s=4.5, gen_tokens=64,
            batch_grid=(1, 2, 4, 8), replica_options=tuple(range(1, 13)))
        sched = FleetScheduler(cluster, [], [dep], FleetConfig(tick_s=300.0))
        if boosted:
            sched._slo_boost_until["svc"] = SLO_BOOST_TICKS
        sched._autoscale_serve(0, 0.0, [])
        return dep.replicas

    assert provision(boosted=True) == provision(boosted=False) + 1


def test_fleet_spans_are_modeled_time_and_deterministic():
    log1, _ = _constrained_drift_fleet(ticks=24)
    log2, _ = _constrained_drift_fleet(ticks=24)
    spans1 = log1.events("span")
    spans2 = log2.events("span")
    assert spans1 and spans1 == spans2
    ticks = [s for s in spans1 if s.component == "fleet.tick"]
    assert len(ticks) == 24
    assert all(t.dur == 300.0 and t.t0 == t.step * 300.0 for t in ticks)
    kids = [s for s in spans1 if s.parent_id]
    tick_ids = {t.span_id for t in ticks}
    assert kids and all(k.parent_id in tick_ids for k in kids)
    # children carry the model's promise next to the modeled measurement
    assert all(k.predicted_s is not None for k in kids)
    serve = [k for k in kids if k.component == "fleet.serve"]
    assert all(s.predicted_s == 2.2 for s in serve)  # the SLO target


def test_fleet_span_and_slo_opt_ins_stay_off_by_default():
    from repro.fleet import run_fleet_sim

    log = run_fleet_sim(0, ticks=12, scenario="drift")
    assert log.events("span") == []
    assert log.events("slo_alert") == []
    assert "spans" not in log.meta and "slo" not in log.meta


def test_fleet_run_with_spans_and_slo_replays_identically():
    from repro.fleet import replay, run_fleet_sim

    log = run_fleet_sim(0, ticks=30, scenario="drift", drift=True,
                        spans=True, slo=True)
    assert log.meta["spans"] and log.meta["slo"]
    again = replay(log)
    assert again.signature() == log.signature()
    assert again.events("span") == log.events("span")
