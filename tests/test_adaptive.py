"""Edge cases for the adaptive layer: AdaptiveController (empty/degenerate
telemetry, non-monotone fits, resize hysteresis) and the serve
CapacityPlanner (empty/single-point telemetry, non-monotone step models)."""
import numpy as np
import pytest

from repro.core import AdaptiveController, ErnestModel
from repro.serve import CapacityPlanner


def _system(times, ms=(1, 2, 4, 8)):
    ms = np.asarray(ms, np.float64)
    return ErnestModel().fit(ms, np.ones_like(ms), np.asarray(times))


def _controller(times, **kw):
    defaults = dict(target_gap=0.05, p_star=0.0, m_options=[1, 2, 4],
                    refit_every=5, min_observations=10, reshard_cost_s=0.5)
    defaults.update(kw)
    return AdaptiveController(_system(times), **defaults)


def _feed_decay(ctrl, n, m=2, gap0=2.0, rate=0.01, start=0):
    """Clean exponential-decay observations; returns the last decision."""
    d = None
    for i in range(start, start + n):
        d = ctrl.observe(i, m, ctrl.p_star + gap0 * np.exp(-rate * i)) or d
    return d


# ----------------------------------------------------------- controller
def test_controller_silent_below_min_observations():
    ctrl = _controller([1.0, 0.55, 0.3, 0.2], min_observations=30)
    for i in range(29):
        assert ctrl.observe(i, 2, 2.0 * np.exp(-0.01 * i)) is None
    assert ctrl.model is None   # no refit yet either


def test_controller_single_then_degenerate_telemetry():
    """Constant objective (zero-variance log-gap) must not crash the refit
    or force a resize — 'stay' (or no decision) is the only sane answer."""
    ctrl = _controller([1.0, 0.55, 0.3, 0.2], min_observations=5,
                       refit_every=5)
    d = None
    for i in range(40):
        d = ctrl.observe(i, 2, 1.0) or d   # flat: no signal to act on
    assert d is None or not d.resize


def test_controller_non_monotone_objective_no_crash():
    """An objective that oscillates and trends UP gives a non-monotone
    (even exploding) fit; predictions must stay finite and the controller
    must not recommend a resize on garbage."""
    ctrl = _controller([1.0, 0.55, 0.3, 0.2], min_observations=10,
                       refit_every=5)
    rng = np.random.RandomState(0)
    d = None
    for i in range(60):
        value = 1.0 + 0.01 * i + 0.5 * rng.rand()   # diverging + noisy
        d = ctrl.observe(i, 2, value) or d
    if d is not None:
        for t in (d.predicted_remaining_current, d.predicted_remaining_target):
            assert t is None or np.isfinite(t)


def test_controller_resizes_on_clear_advantage():
    """Sanity anchor for the hysteresis test: with f(4) ~4x faster the
    controller must leave m=2."""
    ctrl = _controller([1.0, 0.52, 0.26, 0.13])
    d = _feed_decay(ctrl, 60, m=2)
    assert d is not None and d.resize and d.target_m == 4


def test_controller_hysteresis_no_flapping_within_noise():
    """When every m predicts remaining time within the hysteresis band
    (~10%), the controller must keep the current m — a prediction inside
    the noise floor is not worth a reshard."""
    # nearly-flat f(m): 5% spread across options
    ctrl = _controller([1.02, 1.0, 0.97, 0.96])
    decisions = []
    d = None
    for i in range(120):
        d = ctrl.observe(i, 2, 2.0 * np.exp(-0.01 * i))
        if d is not None:
            decisions.append(d)
    assert decisions, "controller must keep deciding"
    assert all(not d.resize for d in decisions), \
        [f"{d.target_m}:{d.reason}" for d in decisions if d.resize]


def test_controller_no_flapping_after_a_resize():
    """After moving to the best m the controller must not bounce back:
    once at m=4 every subsequent decision stays at 4."""
    ctrl = _controller([1.0, 0.52, 0.26, 0.13])
    m = 2
    resizes = []
    for i in range(150):
        d = ctrl.observe(i, m, 2.0 * np.exp(-0.01 * i))
        if d is not None and d.resize:
            resizes.append((i, m, d.target_m))
            m = d.target_m
    assert [r[2] for r in resizes] == [4], resizes


def test_controller_set_m_options():
    ctrl = _controller([1.0, 0.52, 0.26, 0.13])
    ctrl.set_m_options([1, 2])   # capacity shrank: 4 is gone
    d = _feed_decay(ctrl, 60, m=2)
    assert 4 not in ctrl.m_options
    if d is not None and d.resize:
        assert d.target_m in (1, 2)


# ------------------------------------------------------ capacity planner
def test_planner_empty_and_single_point_telemetry():
    planner = CapacityPlanner()
    with pytest.raises(ValueError):
        planner.fit()                      # empty
    planner.observe(4, 0.05)
    planner.observe(4, 0.06)               # same batch twice: still 1 point
    with pytest.raises(ValueError):
        planner.fit()
    planner.observe(8, 0.08)               # second distinct batch
    planner.fit()
    assert planner.step_time(6) > 0


def test_planner_non_monotone_telemetry_stays_sane():
    """Step times DECREASING with batch contradict the model family; the
    NNLS fit must still produce positive, finite predictions and the plan
    query must either answer or return a typed NoFeasiblePlan (never
    nonsense)."""
    from repro.core.hemingway import NoFeasiblePlan

    planner = CapacityPlanner()
    for b, t in [(1, 0.09), (2, 0.07), (4, 0.05), (8, 0.04)] * 3:
        planner.observe(b, t)
    planner.fit()
    for b in (1, 2, 4, 8, 16):
        t = planner.step_time(b)
        assert np.isfinite(t) and t > 0
    plan = planner.plan(target_p50_s=10.0, qps=1.0, gen_tokens=10,
                        batch_grid=[1, 2, 4, 8], m_grid=[1, 2, 4])
    if plan:
        assert plan.m >= 1 and np.isfinite(plan.predicted_time)
    else:   # an honest typed refusal is acceptable; garbage is not
        assert isinstance(plan, NoFeasiblePlan) and plan.reason


def test_planner_noisy_but_monotone_telemetry():
    """Realistic noisy telemetry: fit recovers the trend and both queries
    answer consistently (more replicas never hurts capacity)."""
    rng = np.random.RandomState(3)
    planner = CapacityPlanner()
    for b in [1, 2, 4, 8] * 8:
        planner.observe(b, 0.02 + 0.005 * b + 0.002 * rng.rand())
    planner.fit()
    caps = [planner.tokens_per_s(8, m=m) for m in (1, 2, 4)]
    assert caps[0] < caps[1] < caps[2]
    plan = planner.plan(target_p50_s=1.0, qps=20.0, gen_tokens=10,
                        batch_grid=[1, 2, 4, 8], m_grid=[1, 2, 4, 8])
    best = planner.best_latency_within_fleet(
        m=plan.m, qps=20.0, gen_tokens=10, batch_grid=[1, 2, 4, 8])
    assert best.predicted_time <= plan.predicted_time * (1 + 1e-9)
