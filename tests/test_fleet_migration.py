"""Measured recovery costs closing the fleet resize loop.

The scheduler's planning constants price every restore/re-shard as a
stop-the-world 1800s event, but the job actually recovers in 40s (the
async sharded checkpoint + live migration path; ``actual_recovery_s``).
With ``FleetConfig.measured`` on, every recovery the job pays feeds its
per-job ``StreamingCost``; the drift detector sees the assumption is
~45x off, refits the estimate to the measured cost, and mid-run the
now-correctly-priced shrink to m=2 clears the hysteresis bar — the
``resize:job_mig:4->2:cost`` flip the control arm (identical physics,
no measurement) never takes.

Golden fixture: fleet_migration_seed0.json (regenerate with
tests/fixtures/make_fleet_migration_fixture.py).  Replay guarantees
mirror tests/test_fleet_drift.py.
"""
from pathlib import Path

import pytest

from repro.fleet import (
    FleetRunLog,
    build_migration_scenario,
    replay,
    run_fleet_sim,
)

FIXTURES = Path(__file__).resolve().parent / "fixtures"


@pytest.fixture(scope="module")
def measured_run():
    return run_fleet_sim(0, scenario="migrate", measured=True)


@pytest.fixture(scope="module")
def control_run():
    return run_fleet_sim(0, scenario="migrate", measured=False)


# ------------------------------------------------------- the closed loop
def test_measured_costs_flip_the_resize_decision(measured_run, control_run):
    """The acceptance artifact: a cost-motivated shrink that exists in the
    measured arm and not in the control arm, caused only by measurement
    (both arms pay the same 40s per recovery)."""
    flips = [d for _, d in measured_run.decisions("resize:job_mig")
             if d.startswith("resize:job_mig:4->2:cost")]
    assert flips, "measured arm lost the 4->2 cost flip"
    assert not control_run.decisions("resize:"), \
        "control arm resized despite planning with the stale constant"


def test_refit_fires_after_min_points_restores(measured_run):
    """The recovery-cost refit lands exactly on the min_points-th measured
    restore (three injected preemptions in) and reprices to ~40s."""
    recosts = measured_run.decisions("recost:job_mig")
    assert recosts, "no recovery-cost refit decision recorded"
    preempt_steps = sorted(e.step for e in measured_run.trace.events
                           if e.kind == "preempt")
    assert recosts[0][0] == preempt_steps[2]
    assert recosts[0][1] == "recost:job_mig:40s"
    # the flip happens strictly after the refit repriced the shrink
    flip_step = measured_run.decisions("resize:job_mig")[0][0]
    assert flip_step > recosts[0][0]


def test_ckpt_cost_events_record_measured_vs_assumed(measured_run):
    """Every recovery the job pays rides the bus as a typed ckpt_cost
    event: measured wall time vs the estimate planning used at that
    moment (the assumption before the refit, the learned cost after)."""
    costs = measured_run.events("ckpt_cost")
    assert len(costs) >= 4     # 4 injected restores + the flip's reshard
    assert all(e.wall_s == pytest.approx(40.0) for e in costs)
    assert all(e.workload == "job_mig" for e in costs)
    pre = [e for e in costs if e.assumed_s == pytest.approx(1800.0)]
    post = [e for e in costs if e.assumed_s == pytest.approx(40.0)]
    assert pre and post, "refit must split the stream into before/after"
    assert max(e.step for e in pre) < min(e.step for e in post)
    assert any(e.op == "reshard" for e in post), \
        "the flip's re-shard must be measured too"


def test_refit_reduces_residuals(measured_run):
    refits = measured_run.events("refit")
    detected = measured_run.events("drift")
    assert refits and len(refits) == len(detected)
    for det, ref in zip(detected, refits):
        assert det.step == ref.step and det.model == ref.model
        assert ref.model == "recovery:job_mig"
        assert ref.residual_before == pytest.approx(det.residual)
        assert ref.residual_after < ref.residual_before
        assert det.residual > det.threshold


def test_measured_arm_finishes_cheaper_and_in_time(measured_run,
                                                   control_run):
    m = measured_run.meta["summary"]
    c = control_run.meta["summary"]
    assert m["jobs"]["job_mig"]["state"] == "done"
    assert m["jobs"]["job_mig"]["met_deadline"]
    assert c["jobs"]["job_mig"]["state"] == "done"
    assert m["cost_host_hours"] < c["cost_host_hours"]


def test_measured_events_stay_out_of_rows(measured_run):
    """ckpt_cost/drift/refit telemetry rides the same bus but never leaks
    into the row stream or signatures (pre-measurement goldens stay
    comparable)."""
    kinds = {e.kind for e in measured_run.events()}
    assert {"fleet_tick", "ckpt_cost", "drift", "refit"} <= kinds
    assert len(measured_run.rows) == len(measured_run.events("fleet_tick"))
    assert all(r.keys() == measured_run.rows[0].keys()
               for r in measured_run.rows)


# ------------------------------------------------------- replay + golden
def test_migration_replay_is_bit_identical(measured_run):
    again = replay(measured_run)
    assert again.signature() == measured_run.signature()
    assert again.meta["summary"] == measured_run.meta["summary"]


def test_migration_replay_from_event_log(measured_run, tmp_path):
    p = tmp_path / "migrate.jsonl"
    measured_run.to_jsonl(p)
    back = FleetRunLog.from_jsonl(p)
    assert back.signature() == measured_run.signature()
    assert ([e.to_dict() for e in back.events()]
            == [e.to_dict() for e in measured_run.events()])
    again = replay(back)
    assert again.signature() == measured_run.signature()


def test_golden_migration_trace(measured_run):
    """The checked-in golden log replays exactly on the control sequence
    and to float tolerance on modeled quantities."""
    golden = FleetRunLog.load(FIXTURES / "fleet_migration_seed0.json")
    assert measured_run.control_signature() == golden.control_signature()
    for got, want in zip(measured_run.rows, golden.rows):
        for name, wj in want["jobs"].items():
            gj = got["jobs"][name]
            assert gj["prog"] == pytest.approx(wj["prog"], rel=1e-6,
                                               abs=1e-9)
        assert got["cost_hh"] == pytest.approx(want["cost_hh"], rel=1e-9)


def test_golden_migration_fixture_is_self_consistent():
    golden = FleetRunLog.load(FIXTURES / "fleet_migration_seed0.json")
    regen, _, _, _ = build_migration_scenario(int(golden.meta["seed"]))
    assert regen == golden.trace
    assert golden.meta["scenario"] == "migrate" and golden.meta["measured"]
