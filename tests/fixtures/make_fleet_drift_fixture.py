"""Regenerate fleet_drift_seed0.json — the golden run log for the
streaming-refit drift scenario at seed 0 (drift detection ON).

The fixture pins the closed measure->model->decide loop end to end: the
DriftDetector firing a few ticks after the injected 2x slowdown, the
pace-model refit from the new-regime window, and the forced replanning
pass rescuing the deadline.  A change to the detector thresholds, the
refit math, or the scheduler's rescue policy shows up as a diff in the
decision sequence — a deliberate behavior change regenerates the fixture
with this script, an accidental one fails the golden test.

  PYTHONPATH=src python tests/fixtures/make_fleet_drift_fixture.py
"""
from pathlib import Path

OUT = Path(__file__).resolve().parent / "fleet_drift_seed0.json"


def main():
    from repro.fleet import replay, run_fleet_sim

    log = run_fleet_sim(0, scenario="drift", drift=True)
    again = replay(log)
    assert again.signature() == log.signature(), \
        "refusing to write a fixture that does not replay bit-identically"
    assert log.decisions("drift:"), "scenario no longer triggers the detector"
    assert log.decisions("resize:"), "drift no longer forces a replan"
    job = log.meta["summary"]["jobs"]["job_drift"]
    assert job["state"] == "done" and job["met_deadline"], \
        "the drift-aware arm must rescue the deadline"
    log.save(OUT)
    print(f"{len(log.rows)} ticks, {log.n_decisions()} decisions -> {OUT}")


if __name__ == "__main__":
    main()
