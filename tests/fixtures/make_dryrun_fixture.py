"""Regenerate dryrun_cells.json — the checked-in stand-in for a full
``python -m repro.launch.dryrun --all --mesh both`` sweep.

The real sweep takes hours of compile time, so CI (and fresh checkouts)
don't have results/dryrun; tests/test_system.py falls back to this fixture
so the sweep-consuming assertions still run.  Cell *identities* (arch,
shape, kind, optimizer) come from the real config registry; the roofline
numbers are synthetic but deterministic (seeded per cell) and satisfy the
cross-cell invariants the tests pin (positive finite terms, multi-pod not
inflating per-chip compute, the known MLA decode pathology exempted).

  PYTHONPATH=src python tests/fixtures/make_dryrun_fixture.py
"""
import json
import zlib
from pathlib import Path

from repro.configs import ARCH_IDS, applicable_shapes, get_config
from repro.training.optimizers import default_optimizer_for

OUT = Path(__file__).resolve().parent / "dryrun_cells.json"


def cell_record(arch: str, shape, mesh_kind: str) -> dict:
    cfg = get_config(arch)
    chips = 512 if mesh_kind == "multi" else 256
    cell_id = zlib.crc32(f"{arch}|{shape.name}".encode())
    rng = zlib.crc32(f"{arch}|{shape.name}|{mesh_kind}".encode())

    def u(lo, hi, salt, seed=None):
        x = zlib.crc32(f"{rng if seed is None else seed}|{salt}".encode()) \
            / 2 ** 32
        return lo + (hi - lo) * x

    n_params = cfg.param_count()
    # the single-pod base draw must NOT depend on mesh_kind: the test pins
    # multi-pod per-chip flops against the single-pod cell
    flops_single = n_params * u(2.0, 6.0, "flops", seed=cell_id) * 1e3 / 256
    # multi-pod keeps per-chip compute flat (the invariant the test pins);
    # the known GSPMD pathology cell genuinely replicates work
    if mesh_kind == "multi":
        if (arch, shape.name) == ("deepseek-v2-236b", "decode_32k"):
            flops = flops_single * 1.8
        else:
            flops = flops_single * u(0.92, 1.02, "multi")
    else:
        flops = flops_single
    t_compute = flops / 197e12
    t_memory = t_compute * u(0.2, 3.0, "mem")
    t_coll = t_compute * u(0.05, 1.5, "coll")
    dominant = max((("compute", t_compute), ("memory", t_memory),
                    ("collective", t_coll)), key=lambda kv: kv[1])[0]
    rec = {
        "stem": f"{arch}__{shape.name}__{'multi' if chips == 512 else 'single'}",
        "status": "ok",
        "arch": arch,
        "shape": shape.name,
        "kind": shape.kind,
        "chips": chips,
        "flops_per_device": flops,
        "bytes_per_device": t_memory * 819e9,
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "optimizer": (default_optimizer_for(n_params)
                      if shape.kind == "train" else None),
        "useful_flops_ratio": (u(0.3, 0.95, "ufr")
                               if shape.kind == "train" else None),
        "n_params": n_params,
        "fixture": True,
    }
    return rec


def main():
    cells = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in applicable_shapes(cfg):
            for mesh_kind in ("single", "multi"):
                cells.append(cell_record(arch, shape, mesh_kind))
    OUT.write_text(json.dumps({"cells": cells}, indent=1))
    print(f"{len(cells)} cells -> {OUT}")


if __name__ == "__main__":
    main()
