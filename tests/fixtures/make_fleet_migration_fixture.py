"""Regenerate fleet_migration_seed0.json — the golden run log for the
measured-recovery-cost scenario at seed 0 (measurement ON).

The fixture pins the closed measure->model->decide loop for recovery
costs end to end: injected preemptions make the job pay (and report)
real 40s restores while the scheduler's planning constants still assume
a stop-the-world 1800s; the per-job StreamingCost refit replaces the
assumption with the measured cost; and mid-run the now-correctly-priced
shrink to m=2 clears the hysteresis bar — the ``resize:job_mig:4->2:cost``
decision that the control arm (same physics, no measurement) never
takes.  A change to the cost estimator, the drift thresholds, or the
resize pricing shows up as a diff in the decision sequence — a
deliberate behavior change regenerates the fixture with this script, an
accidental one fails the golden test.

  PYTHONPATH=src python tests/fixtures/make_fleet_migration_fixture.py
"""
from pathlib import Path

OUT = Path(__file__).resolve().parent / "fleet_migration_seed0.json"


def main():
    from repro.fleet import replay, run_fleet_sim

    log = run_fleet_sim(0, scenario="migrate", measured=True)
    again = replay(log)
    assert again.signature() == log.signature(), \
        "refusing to write a fixture that does not replay bit-identically"
    assert log.decisions("recost:"), "scenario no longer refits the cost"
    assert any(d.startswith("resize:job_mig:4->2:cost")
               for _, d in log.decisions("resize:")), \
        "measured costs no longer flip the shrink decision"
    control = run_fleet_sim(0, scenario="migrate", measured=False)
    assert not control.decisions("resize:"), \
        "the control arm must NOT resize (the flip is the artifact)"
    assert (log.meta["summary"]["cost_host_hours"]
            < control.meta["summary"]["cost_host_hours"]), \
        "the measured arm must finish cheaper than the control arm"
    job = log.meta["summary"]["jobs"]["job_mig"]
    assert job["state"] == "done" and job["met_deadline"], \
        "the measured arm must still meet the deadline"
    log.save(OUT)
    print(f"{len(log.rows)} ticks, {log.n_decisions()} decisions -> {OUT}")


if __name__ == "__main__":
    main()
