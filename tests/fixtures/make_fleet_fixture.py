"""Regenerate fleet_golden_seed0.json — the golden run log for the
canonical 24h fleet scenario at seed 0.

The fixture pins the whole adaptive fleet layer: a change to the
scheduler's admission/preemption/resize policy, the capacity planner, the
analytic workload models, or the chaos reconciliation shows up as a diff
in the decision sequence — a deliberate behavior change regenerates the
fixture with this script, an accidental one fails the golden test.

  PYTHONPATH=src python tests/fixtures/make_fleet_fixture.py
"""
from pathlib import Path

OUT = Path(__file__).resolve().parent / "fleet_golden_seed0.json"


def main():
    from repro.fleet import replay, run_fleet_sim

    log = run_fleet_sim(0)
    again = replay(log)
    assert again.signature() == log.signature(), \
        "refusing to write a fixture that does not replay bit-identically"
    summary = log.meta["summary"]
    assert all(d["slo_met"] for d in summary["serve"].values())
    assert all(j["state"] in ("done", "infeasible")
               for j in summary["jobs"].values())
    log.save(OUT)
    print(f"{len(log.rows)} ticks, {log.n_decisions()} decisions -> {OUT}")


if __name__ == "__main__":
    main()
