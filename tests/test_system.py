"""End-to-end behaviour tests for the whole system."""
import json
from pathlib import Path

import numpy as np
import pytest

from repro.launch.serve import Server
from repro.launch.train import Trainer, TrainerOptions

RESULTS = Path(__file__).resolve().parents[1] / "results" / "dryrun"
FIXTURE = Path(__file__).resolve().parent / "fixtures" / "dryrun_cells.json"


def load_dryrun_cells():
    """(stem, record) pairs from the real sweep when it has been run,
    otherwise from the checked-in fixture (tests/fixtures/
    make_dryrun_fixture.py) so the sweep-consuming assertions always run."""
    if RESULTS.exists():
        return [(f.stem, json.loads(f.read_text()))
                for f in sorted(RESULTS.glob("*.json"))]
    payload = json.loads(FIXTURE.read_text())
    return [(c["stem"], c) for c in payload["cells"]]


def test_train_loss_decreases_end_to_end():
    opts = TrainerOptions(arch="qwen3-14b", smoke=True, steps=40, seq_len=64,
                          global_batch=4, log_every=0)
    t = Trainer(opts)
    t.run()
    losses = [l for _, l in t.history]
    assert losses[-1] < losses[0] - 0.3, (losses[0], losses[-1])


def test_serve_generates_batched_tokens():
    server = Server("stablelm-1.6b", smoke=True, max_seq=48)
    rng = np.random.RandomState(0)
    prompts = rng.randint(0, server.cfg.vocab_size, (4, 12)).astype(np.int32)
    res = server.generate(prompts, gen_tokens=8)
    assert res["tokens"].shape == (4, 8)
    assert (res["tokens"] >= 0).all()
    assert (res["tokens"] < server.cfg.vocab_size).all()


def test_serve_vlm_with_frontend_stub():
    server = Server("internvl2-76b", smoke=True, max_seq=64)
    rng = np.random.RandomState(1)
    prompts = rng.randint(0, server.cfg.vocab_size, (2, 8)).astype(np.int32)
    fe = rng.randn(2, server.cfg.n_frontend_tokens,
                   server.cfg.d_model).astype(np.float32) * 0.02
    res = server.generate(prompts, gen_tokens=4, frontend_embeds=fe)
    assert res["tokens"].shape == (2, 4)


def test_serve_ssm_constant_state():
    server = Server("falcon-mamba-7b", smoke=True, max_seq=48)
    rng = np.random.RandomState(2)
    prompts = rng.randint(0, server.cfg.vocab_size, (2, 12)).astype(np.int32)
    res = server.generate(prompts, gen_tokens=6)
    assert res["tokens"].shape == (2, 6)


def test_dryrun_cells_all_ok():
    """Every (arch x shape x mesh) dry-run cell compiled successfully."""
    cells = load_dryrun_cells()
    # hillclimb re-runs carry a -tag suffix; baselines have exactly 2 "__"
    base = [(stem, r) for stem, r in cells if stem.count("__") == 2]
    assert len(base) >= 64, f"expected 64 baseline cells, got {len(base)}"
    failures = []
    for stem, r in base:
        if r.get("status") != "ok":
            failures.append((stem, r.get("error", "")[:200]))
    assert not failures, failures


def test_dryrun_roofline_sanity():
    """Roofline terms positive/finite; train cells report an optimizer;
    multi-pod does not increase per-chip compute."""
    singles, multis = {}, {}
    for stem, r in load_dryrun_cells():
        if stem.count("__") != 2:
            continue
        if r.get("status") != "ok":
            continue
        key = (r["arch"], r["shape"])
        (singles if r["chips"] == 256 else multis)[key] = r
    assert len(singles) == 32 and len(multis) == 32
    for key, r in singles.items():
        assert r["t_compute_s"] > 0 and np.isfinite(r["t_compute_s"])
        assert r["t_memory_s"] > 0
        assert r["dominant"] in ("compute", "memory", "collective")
        if r["kind"] == "train":
            assert r["optimizer"] in ("adamw", "adafactor")
            assert r["useful_flops_ratio"] is not None
        m = multis[key]
        # known GSPMD pathology: the NAIVE (non-absorbed) MLA decode baseline
        # replicates the latent re-expansion on the 3-axis mesh; the absorbed
        # production path (§Perf cell a) removes that op entirely
        if key == ("deepseek-v2-236b", "decode_32k"):
            continue
        assert m["flops_per_device"] < r["flops_per_device"] * 1.05, key
