import sys
from pathlib import Path

# NOTE: do NOT set xla_force_host_platform_device_count here — smoke tests
# and benches must see 1 device (dry-run sets 512 in its own process).
sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

try:  # hypothesis is optional in the container image; tests only need the
    import hypothesis  # noqa: F401 — small API surface stubbed below
except ImportError:
    import _hypothesis_stub

    sys.modules["hypothesis"] = _hypothesis_stub
    sys.modules["hypothesis.strategies"] = _hypothesis_stub.strategies

import jax  # noqa: E402
import pytest  # noqa: E402

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(autouse=True, scope="module")
def _clear_jax_caches():
    """Free compiled executables between modules — the full suite compiles
    hundreds of programs and would otherwise exhaust container RAM."""
    yield
    jax.clear_caches()
