"""Chaos simulator + closed-loop elastic training: unit, property, and
golden-trace regression tests.

The golden logs under tests/fixtures/ were produced by
``run_chaos_sim(seed)`` on the reference machine.  Replay guarantees:

  * in-process: two runs from the same seed are BIT-identical (exact
    float equality on the whole (m, objective, decision) sequence);
  * cross-machine: the control sequence (events, m, mitigations,
    decisions, restores) is exact, objectives match to float tolerance
    (BLAS reduction order may differ between machines).
"""
from pathlib import Path

import numpy as np
import pytest

from repro.runtime.chaos import (
    ChaosEvent,
    ChaosRunLog,
    ChaosTrace,
    ClusterSim,
    replay,
    run_chaos_sim,
)

FIXTURES = Path(__file__).resolve().parent / "fixtures"


# ------------------------------------------------------------------ trace
def test_trace_generation_is_deterministic():
    a = ChaosTrace.generate(7, 200, 4)
    b = ChaosTrace.generate(7, 200, 4)
    assert a.events == b.events
    c = ChaosTrace.generate(8, 200, 4)
    assert a.events != c.events


def test_trace_json_roundtrip(tmp_path):
    t = ChaosTrace.generate(3, 120, 4)
    p = tmp_path / "trace.json"
    t.save(p)
    t2 = ChaosTrace.load(p)
    assert t2 == t


def test_runlog_json_roundtrip(tmp_path):
    t = ChaosTrace.generate(3, 10, 2)
    log = ChaosRunLog(trace=t, meta={"seed": 3})
    log.append(step=0, m=2, objective=1.5, events=[], wall_s=1.0)
    p = tmp_path / "log.json"
    log.save(p)
    log2 = ChaosRunLog.load(p)
    assert log2.signature() == log.signature()
    assert log2.trace == t


# ------------------------------------------------------------------ sim
def test_cluster_sim_straggler_lifecycle():
    trace = ChaosTrace(seed=0, n_hosts=2, steps=20, events=[
        ChaosEvent(step=3, kind="straggler_on", host=1, magnitude=4.0,
                   duration=5)])
    sim = ClusterSim(trace)
    sim.advance(0)
    base = sim.step_time(2, 1.0, 32)
    sim.advance(3)
    slow = sim.step_time(2, 1.0, 32)
    assert slow > 3.0 * base * 0.8
    # host 0 unaffected -> SSP mask excluding host 1 restores the pace
    masked = sim.step_time(2, 1.0, 32, sync_mask={0: True, 1: False})
    assert masked == pytest.approx(base)
    sim.advance(8)  # duration elapsed -> auto recovery
    assert sim.step_time(2, 1.0, 32) == pytest.approx(base)


def test_cluster_sim_mitigations_normalize_step_time():
    trace = ChaosTrace(seed=0, n_hosts=2, steps=10, events=[
        ChaosEvent(step=1, kind="straggler_on", host=0, magnitude=3.0)])
    sim = ClusterSim(trace)
    sim.advance(0)
    base = sim.step_time(2, 1.0, 32)
    sim.advance(1)
    assert sim.step_time(2, 1.0, 32) > 2.0 * base
    sim.rebalance(0)   # shrink the slow host's shard
    assert sim.step_time(2, 1.0, 32) == pytest.approx(base, rel=1e-6)
    sim.hot_spare(0)   # swap for a standby: multiplier and weight reset
    assert sim.step_time(2, 1.0, 32) == pytest.approx(base, rel=1e-6)


def test_cluster_sim_overlapping_faults_extend_not_cancel():
    """An older event's expiry must not end a newer overlapping event of
    the same kind early (keyed expiries, latest wins)."""
    trace = ChaosTrace(seed=0, n_hosts=2, steps=20, events=[
        ChaosEvent(step=1, kind="slowdown", host=-1, magnitude=1.5,
                   duration=5),                       # expires at 6
        ChaosEvent(step=3, kind="slowdown", host=-1, magnitude=1.8,
                   duration=8)])                      # expires at 11
    sim = ClusterSim(trace)
    for step in range(7):
        sim.advance(step)
    assert sim.slowdown == pytest.approx(1.8), \
        "older expiry cancelled the newer slowdown"
    for step in range(7, 12):
        sim.advance(step)
    assert sim.slowdown == 1.0


def test_loop_unrelaxes_recovered_host():
    """sync_relax is a mitigation, not a mode: when the straggler's fault
    expires the host rejoins every barrier and the executor returns to
    full-sync H=1."""
    import jax.numpy as jnp

    from repro.core.adaptive import AdaptiveController
    from repro.optim.problems import ERMProblem, synthetic_mnist
    from repro.optim.simcluster import SSPLocalSGD
    from repro.runtime.chaos import ChaosLoop, default_system_model

    # magnitude 1.7: flagged (>1.5x) but mild (<2x) -> sync_relax
    trace = ChaosTrace(seed=0, n_hosts=2, steps=40, events=[
        ChaosEvent(step=10, kind="straggler_on", host=1, magnitude=1.7,
                   duration=12)])
    X, y = synthetic_mnist(n=256, d=16, effective_rank=8, seed=0)
    problem = ERMProblem(jnp.asarray(X), jnp.asarray(y), lam=1e-2,
                         loss="smooth_hinge")
    executor = SSPLocalSGD(problem, 2, lr0=0.01, seed=0)
    controller = AdaptiveController(
        default_system_model(), target_gap=0.02, p_star=0.0,
        m_options=[2], min_observations=10 ** 6)   # resizes disabled
    loop = ChaosLoop(ClusterSim(trace), executor, controller,
                     base_compute_s=1.0, d=16, relax_local_steps=3)
    log = loop.run()
    relaxations = [r for r in log.rows
                   if (r.get("mitigation") or "").startswith("sync_relax")]
    assert relaxations, "mild straggler must trigger sync_relax"
    assert executor.local_steps == 1, "H must return to 1 after recovery"
    assert not loop._relaxed, "recovered host must rejoin every barrier"


def test_cluster_sim_membership():
    trace = ChaosTrace(seed=0, n_hosts=4, steps=10, events=[
        ChaosEvent(step=2, kind="leave", host=3),
        ChaosEvent(step=5, kind="join", host=-1)])
    sim = ClusterSim(trace)
    sim.advance(0)
    assert sim.capacity == 4
    sim.advance(2)
    assert sim.capacity == 3 and 3 not in sim.hosts()
    sim.advance(5)
    assert sim.capacity == 4   # fresh host id, not the departed one
    assert 3 not in sim.hosts()


def test_cluster_sim_never_drops_below_one_host():
    trace = ChaosTrace(seed=0, n_hosts=2, steps=10, events=[
        ChaosEvent(step=1, kind="leave", host=0),
        ChaosEvent(step=2, kind="leave", host=1)])
    sim = ClusterSim(trace)
    sim.advance(1)
    sim.advance(2)   # refused: the last host cannot leave
    assert sim.capacity == 1


# ------------------------------------------------------------- closed loop
@pytest.fixture(scope="module")
def seed0_log():
    return run_chaos_sim(0)


def test_closed_loop_fires_mitigation_and_resize(seed0_log):
    """Acceptance: seed 0 produces >=1 straggler mitigation and >=1
    ResizeDecision, and the objective genuinely improves."""
    assert seed0_log.n_mitigations() >= 1
    assert seed0_log.n_resizes() >= 1
    objs = [r["objective"] for r in seed0_log.rows]
    assert objs[-1] < objs[0] * 0.8
    assert all(np.isfinite(o) for o in objs)


def test_closed_loop_replay_bit_identical(seed0_log):
    """Replaying the emitted run log (same seed, same trace) reproduces
    the identical (m, objective, decision) sequence — exact equality."""
    again = replay(seed0_log)
    assert again.signature() == seed0_log.signature()
    assert again.meta["final_m"] == seed0_log.meta["final_m"]


def test_preemption_flows_through_injector_and_restores(seed0_log):
    restores = [r for r in seed0_log.rows if r.get("restore")]
    preempts = [r for r in seed0_log.rows
                if any(e.startswith("preempt") for e in r["events"])]
    assert preempts, "seed 0's trace must contain an assigned preemption"
    assert restores, "preemption must trigger a checkpoint restore"
    # a restored step performs no optimization work
    assert all(r["step_s"] == 0.0 for r in restores)


# ------------------------------------------------------- golden regression
@pytest.mark.parametrize("seed", [0, 1])
def test_golden_trace_replay(seed, seed0_log):
    """The checked-in golden run logs replay exactly (control sequence)
    and to float tolerance (objectives) on any machine."""
    golden = ChaosRunLog.load(FIXTURES / f"chaos_golden_seed{seed}.json")
    log = seed0_log if seed == 0 else run_chaos_sim(seed)
    assert len(log.rows) == len(golden.rows)
    for got, want in zip(log.rows, golden.rows):
        assert got["step"] == want["step"]
        assert got["m"] == want["m"]
        assert got["events"] == want["events"]
        assert got.get("mitigation") == want.get("mitigation")
        assert got.get("decision") == want.get("decision")
        assert got.get("restore") == want.get("restore")
        assert got["objective"] == pytest.approx(want["objective"],
                                                 rel=1e-4, abs=1e-6)
    assert log.meta["final_m"] == golden.meta["final_m"]


def test_golden_fixture_is_self_consistent():
    """The fixture's embedded trace regenerates from its recorded seed —
    golden files cannot silently drift from the generator."""
    golden = ChaosRunLog.load(FIXTURES / "chaos_golden_seed1.json")
    regen = ChaosTrace.generate(golden.trace.seed, golden.trace.steps,
                                golden.trace.n_hosts)
    assert regen == golden.trace


# ----------------------------------------------------------- SSP executor
def test_ssp_relax_changes_trajectory():
    """sync_relax (H>1 + a worker skipping the barrier) must have a real
    algorithmic effect: the objective sequence diverges from full-sync."""
    import jax.numpy as jnp

    from repro.optim.problems import ERMProblem, synthetic_mnist
    from repro.optim.simcluster import SSPLocalSGD

    X, y = synthetic_mnist(n=256, d=16, effective_rank=8, seed=0)
    problem = ERMProblem(jnp.asarray(X), jnp.asarray(y), lam=1e-2,
                         loss="smooth_hinge")
    full = SSPLocalSGD(problem, 4, lr0=0.01, seed=0)
    ssp = SSPLocalSGD(problem, 4, lr0=0.01, seed=0)
    full_objs, ssp_objs = [], []
    for t in range(30):
        full_objs.append(full.outer_step())
        if t == 10:
            ssp.relax(2)
        mask = [True, True, True, t % 4 == 0] if t >= 10 else None
        ssp_objs.append(ssp.outer_step(mask))
    assert full_objs[:10] == ssp_objs[:10], "identical until relaxation"
    assert full_objs[10:] != ssp_objs[10:], "relaxation must change it"
    assert np.isfinite(ssp_objs).all()


def test_ssp_checkpoint_restore_rewinds():
    import jax.numpy as jnp

    from repro.optim.problems import ERMProblem, synthetic_mnist
    from repro.optim.simcluster import SSPLocalSGD

    X, y = synthetic_mnist(n=256, d=16, effective_rank=8, seed=1)
    problem = ERMProblem(jnp.asarray(X), jnp.asarray(y), lam=1e-2,
                         loss="smooth_hinge")
    ex = SSPLocalSGD(problem, 2, lr0=0.01, seed=0)
    for _ in range(5):
        ex.outer_step()
    ex.checkpoint()
    branch_a = [ex.outer_step() for _ in range(5)]
    ex.restore()
    branch_b = [ex.outer_step() for _ in range(5)]
    assert branch_a == branch_b, "restore must rewind deterministically"


def test_ssp_resize_preserves_iterate():
    import jax.numpy as jnp

    from repro.optim.problems import ERMProblem, synthetic_mnist
    from repro.optim.simcluster import SSPLocalSGD

    X, y = synthetic_mnist(n=256, d=16, effective_rank=8, seed=2)
    problem = ERMProblem(jnp.asarray(X), jnp.asarray(y), lam=1e-2,
                         loss="smooth_hinge")
    ex = SSPLocalSGD(problem, 2, lr0=0.01, seed=0)
    for _ in range(5):
        ex.outer_step()
    obj_before = float(problem.primal(ex.w))
    ex.resize(4)
    assert ex.m == 4 and ex.W.shape == (4, problem.d)
    obj_after = float(problem.primal(ex.w))
    assert obj_after == pytest.approx(obj_before)


# ------------------------------------------------------------ straggler+
def test_monitor_host_attribution_and_reset():
    from repro.runtime.straggler import StragglerMonitor

    mon = StragglerMonitor(consecutive=2, min_ratio=1.5)
    for step in range(10):
        mon.observe(step, 1.0, host_times={0: 0.5, 1: 0.5})
    ev = None
    for step in range(10, 14):
        ev = ev or mon.observe(step, 3.0, host_times={0: 0.5, 1: 2.9})
    assert ev is not None and ev.host == 1
    # cluster-wide slowdown: no single host stands out -> no target
    mon.reset()
    for step in range(10):
        mon.observe(step, 1.0, host_times={0: 0.5, 1: 0.5})
    ev = None
    for step in range(10, 14):
        ev = ev or mon.observe(step, 2.0, host_times={0: 1.0, 1: 1.0})
    assert ev is not None and ev.host == -1


def test_injector_schedule_mid_run():
    from repro.runtime.failures import FailureInjector, SimulatedFailure

    inj = FailureInjector()
    inj.check(5)   # nothing armed
    inj.schedule(7)
    with pytest.raises(SimulatedFailure):
        inj.check(7)
    inj.check(7)   # fires once


# ----------------------------------------------------------- LM loop (slow)
@pytest.mark.slow
def test_chaos_lm_loop_end_to_end(tmp_path):
    """The closed loop over the REAL trainer: a crafted trace forces a
    straggler (mitigated) and a preemption (restored from checkpoint),
    the controller resizes through the elastic re-shard path, and the
    loss still goes down."""
    from repro.launch.train import run_chaos_lm

    trace = ChaosTrace(seed=0, n_hosts=4, steps=70, events=[
        ChaosEvent(step=30, kind="straggler_on", host=0, magnitude=3.0,
                   duration=8),
        ChaosEvent(step=50, kind="preempt", host=0)])
    log = run_chaos_lm("stablelm-1.6b", trace, str(tmp_path))
    assert len(log.rows) == 70
    assert log.n_resizes() >= 1, "controller never resized"
    assert log.n_mitigations() >= 1, "straggler never mitigated"
    assert any(r.get("restore") for r in log.rows), "preemption not restored"
    losses = [r["objective"] for r in log.rows]
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0] - 0.5
