"""Checkpoint manager: roundtrip, atomicity, retention, data-state resume."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.checkpoint.manager import CheckpointManager, _flatten, _unflatten
from repro.data.pipeline import SyntheticTokens


def _tree(seed=0):
    rng = np.random.RandomState(seed)
    return {
        "params": {
            "embed": jnp.asarray(rng.randn(8, 4), jnp.float32),
            "periods": {"pos0": {"w": jnp.asarray(rng.randn(2, 4, 4),
                                                  jnp.bfloat16)}},
            "head_layers": (
                {"w": jnp.asarray(rng.randn(3), jnp.float32)},
            ),
        },
        "opt_state": {"count": jnp.zeros((), jnp.int32)},
    }


def _assert_equal(a, b):
    np.testing.assert_array_equal(np.asarray(a, dtype=np.float32),
                                  np.asarray(b, dtype=np.float32))


def test_flatten_unflatten_roundtrip():
    t = _tree()
    flat = _flatten(t)
    rebuilt = _unflatten(flat)
    assert jax.tree.structure(jax.tree.map(np.asarray, t)) == \
        jax.tree.structure(rebuilt)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(rebuilt)):
        _assert_equal(a, b)


def test_save_restore_roundtrip(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2, async_write=False)
    t = _tree(1)
    mgr.save(10, t, metadata={"data_state": {"seed": 0, "step": 10}})
    restored, meta = mgr.restore()
    assert meta["step"] == 10
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        _assert_equal(a, b)


def test_keep_k_retention(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2, async_write=False)
    for s in (1, 2, 3, 4):
        mgr.save(s, _tree(s))
    assert mgr.all_steps() == [3, 4]


def test_async_write_and_wait(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=3, async_write=True)
    mgr.save(5, _tree(5))
    mgr.wait()
    assert mgr.latest_step() == 5


def test_uncommitted_checkpoint_ignored(tmp_path):
    mgr = CheckpointManager(tmp_path, async_write=False)
    mgr.save(1, _tree())
    # simulate a torn write: a step dir without COMMITTED
    bad = tmp_path / "step_00000002"
    bad.mkdir()
    (bad / "arrays.npz").write_bytes(b"garbage")
    assert mgr.latest_step() == 1


def test_dtype_preserved(tmp_path):
    mgr = CheckpointManager(tmp_path, async_write=False)
    mgr.save(1, _tree())
    restored, _ = mgr.restore()
    assert restored["params"]["periods"]["pos0"]["w"].dtype == np.dtype("bfloat16") \
        or str(restored["params"]["periods"]["pos0"]["w"].dtype) == "bfloat16"


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_roundtrip_property(tmp_path_factory, seed):
    tmp = tmp_path_factory.mktemp(f"ck{seed % 1000}")
    mgr = CheckpointManager(tmp, async_write=False)
    rng = np.random.RandomState(seed)
    tree = {"a": jnp.asarray(rng.randn(*rng.randint(1, 5, size=2))),
            "b": ({"c": jnp.asarray(rng.randn(3))},)}
    mgr.save(seed % 97, tree)
    restored, _ = mgr.restore()
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# data pipeline determinism + resume
# ---------------------------------------------------------------------------
def test_data_pipeline_deterministic_and_resumable():
    d1 = SyntheticTokens(256, 32, 4, seed=7)
    batches = [d1.next_batch() for _ in range(5)]
    # resume from step 3
    d2 = SyntheticTokens(256, 32, 4, seed=7)
    d2.load_state_dict({"seed": 7, "step": 3})
    resumed = d2.next_batch()
    np.testing.assert_array_equal(batches[3]["tokens"], resumed["tokens"])
    # host slicing partitions the global batch
    full = batches[0]["tokens"]
    parts = [d1.host_slice(batches[0], h, 2)["tokens"] for h in range(2)]
    np.testing.assert_array_equal(np.concatenate(parts), full)


def test_labels_are_shifted_tokens():
    d = SyntheticTokens(128, 16, 2, seed=0)
    b = d.next_batch()
    np.testing.assert_array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])
