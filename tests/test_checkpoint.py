"""Checkpoint manager: roundtrip, atomicity, retention, data-state resume,
sharded format-2 layout, corruption handling, and async-write handles."""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.checkpoint.manager import (
    CheckpointManager,
    CorruptCheckpoint,
    _flatten,
    _unflatten,
)
from repro.data.pipeline import SyntheticTokens


def _tree(seed=0):
    rng = np.random.RandomState(seed)
    return {
        "params": {
            "embed": jnp.asarray(rng.randn(8, 4), jnp.float32),
            "periods": {"pos0": {"w": jnp.asarray(rng.randn(2, 4, 4),
                                                  jnp.bfloat16)}},
            "head_layers": (
                {"w": jnp.asarray(rng.randn(3), jnp.float32)},
            ),
        },
        "opt_state": {"count": jnp.zeros((), jnp.int32)},
    }


def _assert_equal(a, b):
    np.testing.assert_array_equal(np.asarray(a, dtype=np.float32),
                                  np.asarray(b, dtype=np.float32))


def test_flatten_unflatten_roundtrip():
    t = _tree()
    flat = _flatten(t)
    rebuilt = _unflatten(flat)
    assert jax.tree.structure(jax.tree.map(np.asarray, t)) == \
        jax.tree.structure(rebuilt)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(rebuilt)):
        _assert_equal(a, b)


def test_save_restore_roundtrip(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2, async_write=False)
    t = _tree(1)
    mgr.save(10, t, metadata={"data_state": {"seed": 0, "step": 10}})
    restored, meta = mgr.restore()
    assert meta["step"] == 10
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        _assert_equal(a, b)


def test_keep_k_retention(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2, async_write=False)
    for s in (1, 2, 3, 4):
        mgr.save(s, _tree(s))
    assert mgr.all_steps() == [3, 4]


def test_async_write_and_wait(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=3, async_write=True)
    mgr.save(5, _tree(5))
    mgr.wait()
    assert mgr.latest_step() == 5


def test_uncommitted_checkpoint_ignored(tmp_path):
    mgr = CheckpointManager(tmp_path, async_write=False)
    mgr.save(1, _tree())
    # simulate a torn write: a step dir without COMMITTED
    bad = tmp_path / "step_00000002"
    bad.mkdir()
    (bad / "arrays.npz").write_bytes(b"garbage")
    assert mgr.latest_step() == 1


def test_dtype_preserved(tmp_path):
    mgr = CheckpointManager(tmp_path, async_write=False)
    mgr.save(1, _tree())
    restored, _ = mgr.restore()
    assert restored["params"]["periods"]["pos0"]["w"].dtype == np.dtype("bfloat16") \
        or str(restored["params"]["periods"]["pos0"]["w"].dtype) == "bfloat16"


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_roundtrip_property(tmp_path_factory, seed):
    tmp = tmp_path_factory.mktemp(f"ck{seed % 1000}")
    mgr = CheckpointManager(tmp, async_write=False)
    rng = np.random.RandomState(seed)
    tree = {"a": jnp.asarray(rng.randn(*rng.randint(1, 5, size=2))),
            "b": ({"c": jnp.asarray(rng.randn(3))},)}
    mgr.save(seed % 97, tree)
    restored, _ = mgr.restore()
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# format 2: sharding, corruption, fallback, async handles
# ---------------------------------------------------------------------------
def test_sharded_layout_and_manifest_schema(tmp_path):
    """Tiny shard budget -> one shard per leaf; the manifest indexes every
    shard with per-array shape/dtype and is the newest file in the dir."""
    mgr = CheckpointManager(tmp_path, async_write=False, shard_bytes=1)
    mgr.save_async(3, _tree(3)).wait()
    step_dir = tmp_path / "step_00000003"
    manifest = json.loads((step_dir / "manifest.json").read_text())
    assert manifest["format"] == 2 and manifest["step"] == 3
    n_leaves = len(_flatten(_tree(3)))
    assert manifest["n_shards"] == len(manifest["shards"]) == n_leaves
    for entry in manifest["shards"]:
        assert (step_dir / entry["file"]).exists()
    restored, _ = mgr.restore()
    for a, b in zip(jax.tree.leaves(_tree(3)), jax.tree.leaves(restored)):
        _assert_equal(a, b)


def test_async_handle_reports_measured_cost(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=3, async_write=True)
    h = mgr.save_async(7, _tree(7)).wait()
    assert h.done and h.step == 7
    assert h.wall_s is not None and h.wall_s > 0
    assert h.nbytes > 0 and h.n_shards >= 1
    mgr.restore()
    assert mgr.last_timing("save")["step"] == 7
    assert mgr.last_timing("restore")["wall_s"] > 0
    assert [t["op"] for t in mgr.timings] == ["save", "restore"]


def test_async_write_error_surfaces_on_wait(tmp_path, monkeypatch):
    import repro.checkpoint.manager as M

    def boom(*a, **kw):
        raise OSError("disk gone")

    monkeypatch.setattr(M, "atomic_write_bytes", boom)
    mgr = CheckpointManager(tmp_path, async_write=True)
    handle = mgr.save_async(1, _tree())
    with pytest.raises(OSError, match="disk gone"):
        handle.wait()
    assert mgr.all_steps() == []


def test_corrupt_manifest_raises_typed_error(tmp_path):
    mgr = CheckpointManager(tmp_path, async_write=False)
    mgr.save_async(1, _tree(1)).wait()
    (tmp_path / "step_00000001" / "manifest.json").write_text("{not json")
    with pytest.raises(CorruptCheckpoint, match="unreadable manifest"):
        mgr.restore(step=1, fallback=False)
    assert mgr.all_steps() == []


def test_shard_count_mismatch_detected(tmp_path):
    mgr = CheckpointManager(tmp_path, async_write=False, shard_bytes=1)
    mgr.save_async(1, _tree(1)).wait()
    mpath = tmp_path / "step_00000001" / "manifest.json"
    manifest = json.loads(mpath.read_text())
    manifest["n_shards"] += 1
    mpath.write_text(json.dumps(manifest))
    with pytest.raises(CorruptCheckpoint, match="shard count"):
        mgr.restore(step=1, fallback=False)


def test_missing_shard_detected(tmp_path):
    mgr = CheckpointManager(tmp_path, async_write=False, shard_bytes=1)
    mgr.save_async(1, _tree(1)).wait()
    (tmp_path / "step_00000001" / "shard_0000.npz").unlink()
    with pytest.raises(CorruptCheckpoint, match="missing shard"):
        mgr.restore(step=1, fallback=False)


def test_corrupt_step_falls_back_with_warning(tmp_path):
    """The auto-fallback contract: a torn newest step costs a warning, not
    the run — restore serves the previous complete step."""
    mgr = CheckpointManager(tmp_path, async_write=False)
    mgr.save_async(1, _tree(1)).wait()
    mgr.save_async(2, _tree(2)).wait()
    (tmp_path / "step_00000002" / "shard_0000.npz").write_bytes(b"torn")
    with pytest.warns(RuntimeWarning, match="fell back to step 1"):
        restored, meta = mgr.restore(step=2)
    assert meta["step"] == 1
    for a, b in zip(jax.tree.leaves(_tree(1)), jax.tree.leaves(restored)):
        _assert_equal(a, b)


def test_gc_never_deletes_newest_complete_manifest(tmp_path):
    """keep=1 with the newest step torn: GC must preserve step 2 (the
    newest COMPLETE manifest), or a crash after GC would lose everything."""
    mgr = CheckpointManager(tmp_path, keep=1, async_write=False)
    mgr.save_async(1, _tree(1)).wait()
    mgr.save_async(2, _tree(2)).wait()
    (tmp_path / "step_00000003").mkdir()  # torn: no manifest at all
    mgr.save_async(4, _tree(4)).wait()    # triggers GC
    assert mgr.all_steps() == [4]
    _, meta = mgr.restore()
    assert meta["step"] == 4


def test_legacy_format1_checkpoint_still_restores(tmp_path):
    """Pre-format-2 layout (arrays.npz + COMMITTED + format-1 manifest)
    written by old trainers must keep restoring."""
    import io as _io

    step_dir = tmp_path / "step_00000005"
    step_dir.mkdir(parents=True)
    flat = {k: np.asarray(v) for k, v in _flatten(_tree(5)).items()
            if np.asarray(v).dtype.kind in "fiu"}
    buf = _io.BytesIO()
    np.savez(buf, **flat)
    (step_dir / "arrays.npz").write_bytes(buf.getvalue())
    (step_dir / "manifest.json").write_text(json.dumps({
        "format": 1, "step": 5,
        "metadata": {"step": 5},
        "arrays": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                   for k, v in flat.items()},
    }))
    (step_dir / "COMMITTED").write_text("ok")
    mgr = CheckpointManager(tmp_path)
    assert mgr.all_steps() == [5]
    restored, meta = mgr.restore()
    assert meta["step"] == 5
    for k, v in flat.items():
        np.testing.assert_array_equal(_flatten(restored)[k], v)


def test_newer_format_rejected(tmp_path):
    mgr = CheckpointManager(tmp_path, async_write=False)
    mgr.save_async(1, _tree()).wait()
    mpath = tmp_path / "step_00000001" / "manifest.json"
    manifest = json.loads(mpath.read_text())
    manifest["format"] = 99
    mpath.write_text(json.dumps(manifest))
    with pytest.raises(CorruptCheckpoint, match="newer than supported"):
        mgr.restore(step=1, fallback=False)


def test_legacy_save_shim_warns_once(tmp_path):
    mgr = CheckpointManager(tmp_path, async_write=False)
    CheckpointManager._warned_legacy_save = False
    with pytest.warns(DeprecationWarning, match="save_async"):
        mgr.save(1, _tree(1), block=True)
    import warnings as _w
    with _w.catch_warnings():
        _w.simplefilter("error", DeprecationWarning)
        mgr.save(2, _tree(2), block=True)  # second call: silent
    assert mgr.all_steps() == [1, 2]


def test_restore_sharded_places_leaves(tmp_path):
    mgr = CheckpointManager(tmp_path, async_write=False)
    t = _tree(4)
    mgr.save_async(1, t).wait()
    shardings = jax.tree.map(lambda _: None, jax.tree.map(np.asarray, t))
    placed, meta = mgr.restore_sharded(shardings)
    assert meta["step"] == 1
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(placed)):
        assert isinstance(b, jax.Array)
        _assert_equal(a, b)


# ---------------------------------------------------------------------------
# data pipeline determinism + resume
# ---------------------------------------------------------------------------
def test_data_pipeline_deterministic_and_resumable():
    d1 = SyntheticTokens(256, 32, 4, seed=7)
    batches = [d1.next_batch() for _ in range(5)]
    # resume from step 3
    d2 = SyntheticTokens(256, 32, 4, seed=7)
    d2.load_state_dict({"seed": 7, "step": 3})
    resumed = d2.next_batch()
    np.testing.assert_array_equal(batches[3]["tokens"], resumed["tokens"])
    # host slicing partitions the global batch
    full = batches[0]["tokens"]
    parts = [d1.host_slice(batches[0], h, 2)["tokens"] for h in range(2)]
    np.testing.assert_array_equal(np.concatenate(parts), full)


def test_labels_are_shifted_tokens():
    d = SyntheticTokens(128, 16, 2, seed=0)
    b = d.next_batch()
    np.testing.assert_array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])
