"""Fleet scheduler: allocator invariants (property-based), model-driven
scheduling behavior, NoFeasiblePlan consumption, executor plumbing, and
the golden seed-0 day (regenerate with tests/fixtures/make_fleet_fixture.py).

Replay guarantees mirror tests/test_chaos.py: in-process replay is
BIT-identical on the full signature; the checked-in golden fixture is
compared exactly on the control sequence (decisions, allocations, states)
and to float tolerance on modeled quantities (latency, progress, cost).
"""
import subprocess
import sys
from pathlib import Path

import pytest
from hypothesis import given, settings, strategies as st

from repro.core.hemingway import NoFeasiblePlan
from repro.fleet import (
    AllocationError,
    FleetCluster,
    FleetConfig,
    FleetRunLog,
    FleetSimulator,
    RequestTrace,
    ServeDeployment,
    TrainingJob,
    build_day_scenario,
    replay,
    run_fleet_sim,
    serve_capacity_planner,
    training_model,
)
from repro.runtime.chaos import ChaosEvent, ChaosTrace

FIXTURES = Path(__file__).resolve().parent / "fixtures"
HOUR = 3600.0


# ------------------------------------------------------------------ traces
def test_request_trace_deterministic_and_roundtrip():
    a = RequestTrace.diurnal(3, 96, 300.0, base_qps=1.0, peak_qps=8.0)
    b = RequestTrace.diurnal(3, 96, 300.0, base_qps=1.0, peak_qps=8.0)
    assert a == b
    c = RequestTrace.diurnal(4, 96, 300.0, base_qps=1.0, peak_qps=8.0)
    assert a != c
    assert RequestTrace.from_json(a.to_json()) == a
    # forecast looks at the near-term peak, never below the instant demand
    for t in range(0, 96, 7):
        assert a.forecast(t, 3) >= a.qps_at(t)


def test_runlog_json_roundtrip(tmp_path):
    log = run_fleet_sim(0, ticks=24)
    p = tmp_path / "fleet.json"
    log.save(p)
    log2 = FleetRunLog.load(p)
    assert log2.signature() == log.signature()
    assert log2.trace == log.trace
    assert log2.meta["summary"] == log.meta["summary"]


# ------------------------------------------------------- allocator invariants
def _trace_from_draws(draws, n_hosts, steps):
    """Deterministically decode integer draws into a chaos event schedule
    (including the kinds that churn membership)."""
    kinds = ("preempt", "leave", "join", "straggler_on", "slowdown")
    events = []
    for i, d in enumerate(draws):
        step = d % steps
        kind = kinds[(d // steps) % len(kinds)]
        host = (d // (steps * len(kinds))) % n_hosts
        events.append(ChaosEvent(step=step, kind=kind, host=host,
                                 magnitude=2.0, duration=3))
    events.sort(key=lambda e: (e.step, e.host, e.kind))
    return ChaosTrace(seed=0, n_hosts=n_hosts, steps=steps, events=events)


@settings(max_examples=40, deadline=None)
@given(st.lists(st.integers(0, 2 ** 20), min_size=0, max_size=25),
       st.lists(st.integers(0, 2 ** 20), min_size=5, max_size=60))
def test_allocator_invariants_under_random_schedules(chaos_draws, op_draws):
    """Under ANY interleaving of allocate/release and membership churn:
    no host has two owners, freed capacity is conserved (free + allocated
    partitions the live hosts), and over-allocation raises."""
    steps = 30
    trace = _trace_from_draws(chaos_draws, n_hosts=6, steps=steps)
    cluster = FleetCluster(trace)
    owners = ("w0", "w1", "w2")
    shadow = {o: set() for o in owners}   # owner -> hosts (shadow model)
    step = 0
    for d in op_draws:
        if d % 5 == 0 and step < steps:   # sometimes advance time
            _, lost, _ = cluster.advance(step)
            for owner, hosts in lost.items():
                shadow[owner] -= set(hosts)
            step += 1
            continue
        owner = owners[d % len(owners)]
        if d % 3 == 0 and shadow[owner]:
            dropped = sorted(shadow[owner])[: (d % 7) % len(shadow[owner]) + 1]
            cluster.release(owner, dropped)
            shadow[owner] -= set(dropped)
        else:
            n = d % 4
            free_before = len(cluster.free_hosts())
            if n > free_before:
                with pytest.raises(AllocationError):
                    cluster.allocate(owner, n)
            else:
                got = cluster.allocate(owner, n)
                assert len(got) == n
                shadow[owner] |= set(got)
        # invariants, re-checked after every operation
        live = set(cluster.hosts())
        allocated = [h for o in owners for h in shadow[o]]
        assert len(allocated) == len(set(allocated)), "double-allocated host"
        assert set(cluster.free_hosts()) == live - set(allocated)
        for o in owners:
            assert set(cluster.owned(o)) == shadow[o]
            assert shadow[o] <= live


def test_allocator_rejects_foreign_release():
    cluster = FleetCluster(ChaosTrace(seed=0, n_hosts=4, steps=4, events=[]))
    got = cluster.allocate("a", 2)
    with pytest.raises(AllocationError):
        cluster.release("b", got[:1])
    cluster.release("a", got)
    assert cluster.free_hosts() == cluster.hosts()


# -------------------------------------------------------- scheduler behavior
def _quiet_trace(n_hosts, steps, events=()):
    return ChaosTrace(seed=0, n_hosts=n_hosts, steps=steps,
                      events=list(events))


def _job(name="job", *, m_options=(2, 4, 8), arrival_h=0.0, deadline_h=20.0,
         compute_s=30.0, rate=4e-3, eps=1e-2, max_iters=200_000,
         alpha=0.35, **kw):
    return TrainingJob(
        name=name, eps=eps, arrival_s=arrival_h * HOUR,
        deadline_s=deadline_h * HOUR, m_options=m_options,
        model=training_model(compute_s=compute_s, rate=rate, alpha=alpha,
                             max_iters=max_iters), **kw)


def _deployment(name="serve", *, qps, slo_p95_s=4.0, ticks=48,
                replica_options=tuple(range(1, 9))):
    return ServeDeployment(
        name=name,
        planner=serve_capacity_planner(dispatch_s=0.02, per_seq_s=0.004),
        trace=RequestTrace(seed=0, tick_s=300.0, qps=list(qps)),
        slo_p95_s=slo_p95_s, gen_tokens=64, batch_grid=(1, 2, 4, 8),
        replica_options=replica_options)


def _run(trace, jobs, deployments, steps=None, cfg=None):
    sim = FleetSimulator(trace, jobs, deployments,
                         cfg or FleetConfig(tick_s=300.0))
    return sim.run(steps), sim.scheduler


def test_admission_picks_cheapest_deadline_feasible_m():
    # generous deadline: host-seconds are minimized at the smallest option
    log, sched = _run(_quiet_trace(10, 8), [_job(deadline_h=40.0)], [])
    admits = log.decisions("admit")
    assert admits and admits[0][1] == "admit:job:m=2"
    assert sched.jobs["job"].m == 2

    # tight deadline: m=2 cannot make it, the scheduler pays for speed
    job = _job(deadline_h=0.0, m_options=(2, 4, 8))
    t2 = job.time_to_eps(2)
    job.deadline_s = t2 * 0.7   # only larger m finishes in time
    log, sched = _run(_quiet_trace(10, 8), [job], [])
    admits = log.decisions("admit")
    assert admits and admits[0][1] in ("admit:job:m=4", "admit:job:m=8")
    assert sched.jobs["job"].state == "running"


def test_unreachable_epsilon_yields_typed_no_feasible_plan():
    job = _job(eps=1e-30, max_iters=500)
    log, sched = _run(_quiet_trace(8, 4), [job], [])
    assert job.state == "infeasible"
    assert isinstance(job.no_plan, NoFeasiblePlan)
    assert job.no_plan.query == "fastest_to_epsilon"
    assert log.decisions("infeasible:job")


def test_impossible_deadline_yields_fleet_admission_no_plan():
    job = _job(deadline_h=0.01)
    log, sched = _run(_quiet_trace(8, 4), [job], [])
    assert job.state == "infeasible"
    assert isinstance(job.no_plan, NoFeasiblePlan)
    assert job.no_plan.query == "fleet_admission"
    assert "slack" in job.no_plan.reason
    assert job.no_plan.table, "the typed result carries the priced options"


def test_serve_scale_up_preempts_training():
    """When demand spikes past the free pool, serving takes hosts from the
    training job (SLO priority) and the job is evicted/queued."""
    qps = [0.5] * 4 + [60.0] * 12
    dep = _deployment(qps=qps, replica_options=tuple(range(1, 8)))
    job = _job(m_options=(4,), deadline_h=40.0)
    log, sched = _run(_quiet_trace(6, 12), [job], [dep])
    assert log.decisions("admit:job")
    evicts = log.decisions("evict:job")
    assert evicts and "serve=serve" in evicts[0][1]
    assert sched.deployments["serve"].replicas >= 5
    # freed capacity really went to serving: no double allocation
    assert set(sched.cluster.owned("serve")).isdisjoint(
        sched.cluster.owned("job"))


def test_forced_shrink_never_lands_on_unreachable_m():
    """A serve spike must not shrink a job onto an m whose model cannot
    reach eps (remaining time would be infinite and progress frozen):
    the job is evicted/requeued instead, and once capacity returns it is
    readmitted at a workable size."""
    # variance-limited regime (alpha<0: more machines need FEWER
    # iterations) with max_iters capped between iters(2) and iters(8),
    # so eps is reachable at m=8 but not at m=2
    job = _job(m_options=(2, 8), deadline_h=40.0, compute_s=30.0,
               rate=4e-3, alpha=-0.6, max_iters=500)
    assert job.time_to_eps(8) is not None
    assert job.time_to_eps(2) is None
    qps = [0.5] * 6 + [25.0] * 6 + [0.5] * 20
    dep = _deployment(qps=qps, replica_options=tuple(range(1, 7)))
    log, sched = _run(_quiet_trace(10, 32), [job], [dep])
    assert log.decisions("admit:job:m=8")
    # the spike displaced the job, but never onto the dead m=2
    assert not log.decisions("preempt:job:m=2")
    assert all(r["jobs"]["job"]["m"] != 2 for r in log.rows)
    # after the spike passes the job is running again (or already done)
    assert sched.jobs["job"].state in ("running", "done")
    assert sched.jobs["job"].m in (0, 8)


def test_infeasible_serve_slo_records_noplan_and_max_fleet():
    """An SLO no (m, batch) can meet: the scheduler records the typed
    NoFeasiblePlan and falls back to the largest allowed fleet."""
    dep = _deployment(qps=[5.0] * 8, slo_p95_s=1e-4,
                      replica_options=(1, 2, 3))
    log, sched = _run(_quiet_trace(8, 8), [], [dep])
    noplans = log.decisions("noplan:serve")
    assert noplans and "capacity_plan" in noplans[0][1]
    assert sched.deployments["serve"].replicas == 3


def test_straggling_replica_topped_up_same_tick():
    """A 4x-slow serve host shows up as missing effective capacity and the
    scheduler tops the deployment up the same tick the fault lands."""
    events = [ChaosEvent(step=4, kind="straggler_on", host=0, magnitude=4.0,
                         duration=6)]
    dep = _deployment(qps=[6.0] * 16, replica_options=tuple(range(1, 9)))
    log, sched = _run(_quiet_trace(10, 16, events), [], [dep])
    baseline = log.rows[3]["serve"]["serve"]["m"]
    assert log.rows[4]["serve"]["serve"]["m"] > baseline
    # after recovery (+patience) the extra host is released again
    assert log.rows[-1]["serve"]["serve"]["m"] == baseline


# ----------------------------------------------------- executor plumbing
class _RecordingExecutor:
    """Chaos executor contract, recording every call (the fleet analogue of
    SSPLocalSGD / launch.train.TrainerExecutor)."""

    def __init__(self):
        self.m = 0
        self.calls = []
        self.steps = 0

    def resize(self, m):
        self.calls.append(("resize", m))
        self.m = m

    def outer_step(self, sync_mask=None):
        self.steps += 1
        return 1.0 / self.steps

    def checkpoint(self):
        self.calls.append(("checkpoint", self.m))

    def restore(self):
        self.calls.append(("restore", self.m))

    def relax(self, h):
        self.calls.append(("relax", h))


def test_executor_driven_through_admit_preempt_and_shrink():
    events = [ChaosEvent(step=3, kind="preempt", host=0),
              ChaosEvent(step=6, kind="leave", host=1)]
    ex = _RecordingExecutor()
    job = _job(m_options=(2, 4), deadline_h=40.0, executor=ex)
    log, sched = _run(_quiet_trace(4, 10, events), [job], [])
    # admitted at the cheapest feasible m; the executor was re-sharded to it
    assert ("resize", job.m or 2) in ex.calls or ex.m in (2, 4)
    assert log.decisions("admit:job")
    # the preempted host triggered a checkpoint restore
    assert log.decisions("restore:job")
    assert any(c[0] == "restore" for c in ex.calls)
    # the departed host forced a shrink (or evict+readmit) via resize
    assert ex.m == job.m if job.state == "running" else job.m == 0
    assert any(c[0] == "resize" for c in ex.calls)
    # modeled objective flows from the executor into the run log
    assert any("obj" in r["jobs"]["job"] for r in log.rows)


# -------------------------------------------------- determinism + golden
@settings(max_examples=5, deadline=None)
@given(st.integers(0, 10_000))
def test_fleet_replay_is_bit_identical(seed):
    log = run_fleet_sim(seed, ticks=48, n_hosts=12)
    again = replay(log)
    assert again.signature() == log.signature()
    assert again.meta["summary"] == log.meta["summary"]


@pytest.fixture(scope="module")
def seed0_day():
    return run_fleet_sim(0)


def test_day_scenario_acceptance(seed0_day):
    """Seed 0, full 24h: every SLO met at p95, every job done in time or
    explicitly infeasible, chaos paths actually exercised."""
    s = seed0_day.meta["summary"]
    assert all(d["slo_met"] for d in s["serve"].values())
    for j in s["jobs"].values():
        assert (j["state"] == "done" and j["met_deadline"]) \
            or j["no_plan"] is not None
    assert seed0_day.decisions("restore"), "injected preemption not restored"
    assert seed0_day.decisions("resize"), "no model-driven resize fired"
    assert s["cost_host_hours"] > 0


def test_golden_fleet_trace(seed0_day):
    """The checked-in golden log replays exactly on the control sequence
    and to float tolerance on modeled quantities (cross-machine BLAS)."""
    golden = FleetRunLog.load(FIXTURES / "fleet_golden_seed0.json")
    assert len(seed0_day.rows) == len(golden.rows)
    for got, want in zip(seed0_day.rows, golden.rows):
        assert got["step"] == want["step"]
        assert got["events"] == want["events"]
        assert got["decisions"] == want["decisions"]
        assert got["free"] == want["free"]
        for name, ws in want["serve"].items():
            gs = got["serve"][name]
            assert (gs["m"], gs["ok"]) == (ws["m"], ws["ok"])
            assert gs["qps"] == pytest.approx(ws["qps"], rel=1e-9)
            assert gs["lat_s"] == pytest.approx(ws["lat_s"], rel=1e-6)
        for name, wj in want["jobs"].items():
            gj = got["jobs"][name]
            assert (gj["state"], gj["m"]) == (wj["state"], wj["m"])
            assert gj["prog"] == pytest.approx(wj["prog"], rel=1e-6,
                                               abs=1e-9)
        assert got["cost_hh"] == pytest.approx(want["cost_hh"], rel=1e-9)


def test_golden_fixture_is_self_consistent():
    """The fixture's embedded trace regenerates from the scenario builder
    at its recorded seed — golden files cannot drift from the generator."""
    golden = FleetRunLog.load(FIXTURES / "fleet_golden_seed0.json")
    regen, _, _, _ = build_day_scenario(int(golden.meta["seed"]))
    assert regen == golden.trace


# -------------------------------------------------------------- slow tier
@pytest.mark.slow
def test_fleet_day_example_end_to_end(tmp_path):
    """The acceptance scenario as users run it, plus the real-executor
    variant (job_sweep resized through SSPLocalSGD re-partitioning)."""
    env = {"JAX_PLATFORMS": "cpu", "PATH": "/usr/bin:/bin:/usr/local/bin"}
    root = Path(__file__).resolve().parents[1]
    for extra in ([], ["--real-convex"]):
        out = subprocess.run(
            [sys.executable, str(root / "examples" / "fleet_day.py"),
             "--seed", "0", "--out", str(tmp_path / "day.json")] + extra,
            capture_output=True, text=True, timeout=900,
            env={**env, "PYTHONPATH": str(root / "src")})
        assert out.returncode == 0, out.stderr[-2000:]
        assert "acceptance: all serve SLOs met" in out.stdout


@pytest.mark.slow
def test_multi_seed_day_sweep():
    """Days 1..3: the scheduler stays invariant-clean under other chaos
    draws (SLOs hold; jobs finish — possibly late under unlucky chaos —
    or carry a typed NoFeasiblePlan; replay stays exact)."""
    for seed in (1, 2, 3):
        log = run_fleet_sim(seed)
        s = log.meta["summary"]
        assert all(d["slo_met"] for d in s["serve"].values()), seed
        for j in s["jobs"].values():
            assert j["state"] in ("done", "infeasible"), (seed, j)
        assert replay(log).signature() == log.signature(), seed
