"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs pure-jnp oracle,
plus the jnp flash path (used by models) vs the naive reference."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ops import decode_attention, flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.flash_decode.kernel import flash_decode_pallas
from repro.kernels.sdca.kernel import local_sdca_pallas
from repro.kernels.sdca.ref import local_sdca_ref
from repro.kernels.ssm_scan.kernel import selective_scan_pallas
from repro.kernels.ssm_scan.ops import selective_scan, selective_scan_step
from repro.kernels.ssm_scan.ref import selective_scan_ref


def _tol(dtype):
    return 3e-2 if dtype == jnp.bfloat16 else 2e-4


# ---------------------------------------------------------------------------
# flash attention: jnp blocked path (what models run)
# ---------------------------------------------------------------------------
FLASH_CASES = [
    # b, hq, hk, sq, skv, d, causal, bq, bk, dtype
    (2, 4, 2, 37, 37, 16, True, 16, 16, jnp.float32),
    (1, 8, 8, 64, 64, 32, True, 32, 16, jnp.float32),
    (2, 4, 1, 33, 65, 16, False, 16, 32, jnp.float32),
    (1, 6, 2, 48, 48, 8, True, 16, 16, jnp.bfloat16),
    (1, 2, 2, 130, 130, 64, True, 64, 64, jnp.float32),
]


@pytest.mark.parametrize("case", FLASH_CASES)
def test_flash_jnp_forward_and_grad(case):
    b, hq, hk, sq, skv, d, causal, bq, bk, dtype = case
    ks = jax.random.split(jax.random.PRNGKey(hash(case) % 2**31), 3)
    q = jax.random.normal(ks[0], (b, hq, sq, d), dtype)
    k = jax.random.normal(ks[1], (b, hk, skv, d), dtype)
    v = jax.random.normal(ks[2], (b, hk, skv, d), dtype)
    out = flash_attention(q, k, v, causal=causal, block_q=bq, block_k=bk)
    ref = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=_tol(dtype))
    if dtype == jnp.float32:
        g1 = jax.grad(lambda a, b_, c: flash_attention(
            a, b_, c, causal=causal, block_q=bq, block_k=bk).sum(),
            argnums=(0, 1, 2))(q, k, v)
        g2 = jax.grad(lambda a, b_, c: attention_ref(
            a, b_, c, causal=causal).sum(), argnums=(0, 1, 2))(q, k, v)
        for a, b_ in zip(g1, g2):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                       atol=3e-4)


def test_flash_kv_lens_masking():
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (2, 2, 16, 8))
    k = jax.random.normal(ks[1], (2, 2, 24, 8))
    v = jax.random.normal(ks[2], (2, 2, 24, 8))
    lens = jnp.array([7.0, 24.0])
    out = flash_attention(q, k, v, causal=False, kv_lens=lens,
                          block_q=8, block_k=8)
    ref = attention_ref(q, k, v, causal=False, kv_lens=lens)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1), st.sampled_from([1, 2, 4]),
       st.integers(8, 70), st.booleans())
def test_flash_jnp_property(seed, g, sq, causal):
    """Property: blocked flash == naive attention for random shapes."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    hk, d = 2, 8
    q = jax.random.normal(ks[0], (1, hk * g, sq, d))
    k = jax.random.normal(ks[1], (1, hk, sq, d))
    v = jax.random.normal(ks[2], (1, hk, sq, d))
    out = flash_attention(q, k, v, causal=causal, block_q=16, block_k=16)
    ref = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-4)


# ---------------------------------------------------------------------------
# flash attention: Pallas kernel (interpret mode)
# ---------------------------------------------------------------------------
PALLAS_FLASH_CASES = [
    (2, 4, 2, 64, 32, True, jnp.float32),
    (1, 2, 2, 100, 16, True, jnp.float32),
    (2, 4, 4, 48, 32, False, jnp.bfloat16),
    (1, 8, 2, 128, 64, True, jnp.float32),
]


@pytest.mark.parametrize("case", PALLAS_FLASH_CASES)
def test_flash_pallas_kernel(case):
    b, hq, hk, s, d, causal, dtype = case
    ks = jax.random.split(jax.random.PRNGKey(abs(hash(case)) % 2**31), 3)
    q = jax.random.normal(ks[0], (b, hq, s, d), dtype)
    k = jax.random.normal(ks[1], (b, hk, s, d), dtype)
    v = jax.random.normal(ks[2], (b, hk, s, d), dtype)
    out = flash_attention_pallas(q, k, v, causal=causal, block_q=32,
                                 block_k=32, interpret=True)
    ref = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=_tol(dtype))


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("s,lens", [(50, (31, 50)), (128, (1, 100))])
def test_decode_jnp_vs_ref(s, lens):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    b, hk, g, d = 2, 2, 3, 16
    q = jax.random.normal(ks[0], (b, hk * g, d))
    kc = jax.random.normal(ks[1], (b, hk, s, d))
    vc = jax.random.normal(ks[2], (b, hk, s, d))
    lengths = jnp.asarray(lens, jnp.int32)
    out = decode_attention(q, kc, vc, lengths)
    ref = attention_ref(q[:, :, None], kc, vc, causal=False,
                        kv_lens=lengths.astype(jnp.float32))[:, :, 0]
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


def test_flash_decode_pallas_kernel():
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    b, h, s, d = 2, 4, 200, 32
    q = jax.random.normal(ks[0], (b, h, d))
    kc = jax.random.normal(ks[1], (b, h, s, d))
    vc = jax.random.normal(ks[2], (b, h, s, d))
    lens = jnp.array([137, 200], jnp.int32)
    out = flash_decode_pallas(q, kc, vc, lens, block_k=64, interpret=True)
    ref = attention_ref(q[:, :, None], kc, vc, causal=False,
                        kv_lens=lens.astype(jnp.float32))[:, :, 0]
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4)


# ---------------------------------------------------------------------------
# selective scan
# ---------------------------------------------------------------------------
def _ssm_inputs(seed, bt, s, dn, n):
    ks = jax.random.split(jax.random.PRNGKey(seed), 6)
    x = jax.random.normal(ks[1], (bt, s, dn))
    dt = jax.nn.softplus(jax.random.normal(ks[2], (bt, s, dn)))
    A = -jnp.abs(jax.random.normal(ks[3], (dn, n))) - 0.1
    B = jax.random.normal(ks[4], (bt, s, n))
    C = jax.random.normal(ks[5], (bt, s, n))
    D = jnp.full((dn,), 0.4)
    return x, dt, A, B, C, D


@pytest.mark.parametrize("shape,chunk", [((2, 37, 8, 4), 8),
                                         ((1, 64, 16, 4), 16),
                                         ((2, 100, 4, 2), 32)])
def test_selective_scan_chunked_vs_ref(shape, chunk):
    bt, s, dn, n = shape
    x, dt, A, B, C, D = _ssm_inputs(s, bt, s, dn, n)
    y1, h1 = selective_scan(x, dt, A, B, C, D, chunk=chunk)
    y0, h0 = selective_scan_ref(x, dt, A, B, C, D)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y0), atol=1e-4)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h0), atol=1e-4)
    # gradients
    g1 = jax.grad(lambda *a: selective_scan(*a, D, chunk=chunk)[0].sum(),
                  argnums=(0, 1, 3))(x, dt, A, B, C)
    g0 = jax.grad(lambda *a: selective_scan_ref(*a, D)[0].sum(),
                  argnums=(0, 1, 3))(x, dt, A, B, C)
    for a, b in zip(g1, g0):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)


def test_selective_scan_pallas_kernel():
    bt, s, dn, n = 2, 70, 16, 4
    x, dt, A, B, C, D = _ssm_inputs(7, bt, s, dn, n)
    yk = selective_scan_pallas(x, dt, A, B, C, D, chunk=16, d_block=8,
                               interpret=True)
    yr, _ = selective_scan_ref(x, dt, A, B, C, D)
    np.testing.assert_allclose(np.asarray(yk), np.asarray(yr), atol=1e-4)


def test_selective_scan_decode_step_consistency():
    bt, s, dn, n = 2, 12, 4, 3
    x, dt, A, B, C, D = _ssm_inputs(9, bt, s, dn, n)
    yref, _ = selective_scan_ref(x, dt, A, B, C, D)
    h = jnp.zeros((bt, dn, n))
    ys = []
    for t in range(s):
        y, h = selective_scan_step(x[:, t], dt[:, t], A, B[:, t], C[:, t], D, h)
        ys.append(y)
    np.testing.assert_allclose(np.asarray(jnp.stack(ys, 1)),
                               np.asarray(yref), atol=1e-5)


# ---------------------------------------------------------------------------
# SDCA kernel
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("sigma", [1.0, 4.0])
def test_sdca_pallas_vs_ref(sigma):
    m, nl, d, h = 3, 32, 16, 32
    ks = jax.random.split(jax.random.PRNGKey(4), 4)
    X = jax.random.normal(ks[0], (m, nl, d))
    y = jnp.sign(jax.random.normal(ks[1], (m, nl)))
    a = jnp.zeros((m, nl))
    w = jax.random.normal(ks[2], (d,)) * 0.1
    idx = jnp.stack([jax.random.permutation(k, nl)
                     for k in jax.random.split(ks[3], m)])
    ak, dwk = local_sdca_pallas(X, y, a, w, idx, sigma, 1e-3, float(m * nl),
                                interpret=True)
    ar, dwr = jax.vmap(lambda Xk, yk, ak_, ik: local_sdca_ref(
        Xk, yk, ak_, w, ik, sigma, 1e-3, float(m * nl)))(X, y, a, idx)
    np.testing.assert_allclose(np.asarray(ak), np.asarray(ar), atol=1e-4)
    np.testing.assert_allclose(np.asarray(dwk), np.asarray(dwr), atol=1e-4)
