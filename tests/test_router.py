"""Prefix-affinity router: dispatch rules, determinism, bit-identity vs a
single engine, and CapacityPlanner ingestion of router telemetry.

The bit-identity test is the routed analogue of the engine's batch-
composition guarantee (see serve/engine.py): dense-arch decode is slot-
independent, so splitting a trace across N same-seed replicas must produce
exactly the token streams one engine serving the whole trace produces."""
import numpy as np
import pytest

from repro.serve import CapacityPlanner, Router, ServeEngine
from repro.telemetry import RouterEvent, ServeStepEvent, from_dict

ARCH = "qwen3-14b"  # dense: slot-independent decode
GEOM = dict(smoke=True, max_batch=2, page_size=8, max_seq=64, seed=0)
PS = GEOM["page_size"]


def _trace(seed: int, n: int, vocab: int):
    """Mixed trace with a shared head on every even request."""
    rng = np.random.RandomState(seed)
    head = rng.randint(0, vocab, 2 * PS).astype(np.int32)
    specs = []
    for i in range(n):
        if i % 2 == 0:
            prompt = np.concatenate(
                [head, rng.randint(0, vocab, 3).astype(np.int32)]
            )
        else:
            prompt = rng.randint(0, vocab, 7).astype(np.int32)
        specs.append((prompt, 4, (i // 2) * 2))
    return specs


def _engines(n: int, **overrides):
    geom = {**GEOM, **overrides}
    return [ServeEngine(ARCH, **geom) for _ in range(n)]


# ------------------------------------------------------------- bit identity
def test_routed_fleet_bit_identical_to_single_engine():
    vocab = ServeEngine.config_for(ARCH, True).vocab_size
    specs = _trace(0, 6, vocab)

    ref = ServeEngine(ARCH, **GEOM)
    ref_reqs = [ref.submit(p, g, arrival_step=a) for p, g, a in specs]
    ref.run()

    router = Router(_engines(2), spill_slack=512)
    routed = [router.submit(p, g, arrival_step=a) for p, g, a in specs]
    stats = router.run()

    assert stats["requests_finished"] == len(specs)
    for rr, ref_req in zip(routed, ref_reqs):
        assert rr.generated == ref_req.generated
    # both replicas actually served traffic and affinity fired
    assert all(c > 0 for c in stats["dispatch_per_replica"])
    assert stats["affinity_hit_rate"] > 0


# ---------------------------------------------------------- dispatch rules
def test_affinity_routes_to_replica_holding_pages():
    vocab = ServeEngine.config_for(ARCH, True).vocab_size
    rng = np.random.RandomState(1)
    head = rng.randint(0, vocab, 2 * PS).astype(np.int32)
    other = rng.randint(0, vocab, 7).astype(np.int32)

    router = Router(_engines(2), spill_slack=512)
    # step 0: cold fleet — first request load-routes to replica 0, second to
    # replica 1 (load tiebreak); replica 0 then owns the shared head's pages
    router.submit(head, 3, arrival_step=0)
    router.submit(other, 3, arrival_step=0)
    # arrives after replica 0 registered the head's pages at admission
    target = router.submit(
        np.concatenate([head, rng.randint(0, vocab, 3).astype(np.int32)]),
        3,
        arrival_step=2,
    )
    router.run()

    evs = router.events("router")
    assert [e.reason for e in evs[:2]] == ["load", "load"]
    assert (evs[0].replica, evs[1].replica) == (0, 1)
    ev = next(e for e in evs if e.rid == target.rid)
    assert ev.reason == "affinity"
    assert ev.replica == 0
    assert ev.matched_pages == 2 == ev.best_affinity


def test_overloaded_affinity_winner_spills():
    vocab = ServeEngine.config_for(ARCH, True).vocab_size
    rng = np.random.RandomState(2)
    head = rng.randint(0, vocab, 2 * PS).astype(np.int32)

    router = Router(_engines(2), spill_slack=0)
    router.submit(head, 6, arrival_step=0)  # replica 0 owns the head, busy
    spilled = router.submit(
        np.concatenate([head, rng.randint(0, vocab, 3).astype(np.int32)]),
        3,
        arrival_step=1,  # replica 0 still decoding -> any load gap spills
    )
    router.run()

    ev = next(e for e in router.events("router") if e.rid == spilled.rid)
    assert ev.reason == "spill"
    assert ev.replica == 1
    assert ev.best_affinity == 2  # the pages existed, the router chose load
    assert ev.loads[0] > ev.loads[1]


def test_dispatch_deterministic_across_seeds():
    """Same trace + same fleet shape -> identical dispatch decisions, for
    several trace seeds (peek and pending_tokens are pure functions of
    prior dispatches)."""
    vocab = ServeEngine.config_for(ARCH, True).vocab_size
    for seed in (0, 3, 7):
        specs = _trace(seed, 5, vocab)
        decisions = []
        for _ in range(2):
            router = Router(_engines(2), spill_slack=512)
            for p, g, a in specs:
                router.submit(p, g, arrival_step=a)
            router.run()
            decisions.append(
                [
                    (e.rid, e.replica, e.reason, e.matched_pages)
                    for e in router.events("router")
                ]
            )
        assert decisions[0] == decisions[1]


def test_router_rejects_bad_fleets():
    with pytest.raises(ValueError):
        Router([])
    with pytest.raises(ValueError):
        Router(
            [
                ServeEngine(ARCH, **GEOM),
                ServeEngine(ARCH, **{**GEOM, "page_size": 16}),
            ]
        )
    with pytest.raises(ValueError):
        Router(_engines(1), spill_slack=-1)


# ------------------------------------------------------------- telemetry
def test_router_event_roundtrip():
    ev = RouterEvent(
        step=3,
        rid=7,
        replica=1,
        matched_pages=2,
        best_affinity=2,
        reason="affinity",
        prompt_pages=3,
        loads=[10, 4],
    )
    back = from_dict(ev.to_dict())
    assert back == ev


def test_planner_ingests_router_and_replica_tagged_events():
    planner = CapacityPlanner()
    events = [
        RouterEvent(step=0, rid=0, replica=0, matched_pages=0,
                    best_affinity=0, reason="load", prompt_pages=2,
                    loads=[0, 0]),
        RouterEvent(step=1, rid=1, replica=0, matched_pages=2,
                    best_affinity=2, reason="affinity", prompt_pages=3,
                    loads=[8, 0]),
        RouterEvent(step=1, rid=2, replica=1, matched_pages=0,
                    best_affinity=2, reason="spill", prompt_pages=2,
                    loads=[30, 0]),
        RouterEvent(step=2, rid=3, replica=1, matched_pages=0,
                    best_affinity=0, reason="load", prompt_pages=0,
                    loads=[8, 8]),
        # replica-tagged decode steps: replica 0 decodes 2x faster
        ServeStepEvent(step=2, step_s=0.1, op="decode", batch=2,
                       committed=2, replica=0),
        ServeStepEvent(step=2, step_s=0.2, op="decode", batch=2,
                       committed=2, replica=1),
    ]
    n = planner.ingest(events)
    assert n == len(events)
    # rid=3 has no full prompt page -> excluded from the routable base
    assert planner.affinity_hit_rate == pytest.approx(1 / 3)
    stats = planner.replica_stats()
    assert stats[0]["dispatches"] == 2 and stats[0]["affinity_hits"] == 1
    assert stats[1]["spills"] == 1
    assert stats[0]["tok_per_s"] == pytest.approx(20.0)
    assert stats[1]["tok_per_s"] == pytest.approx(10.0)
    assert planner.measured_effective_replicas() == pytest.approx(1.5)


def test_fleet_deployment_snapshot_affinity_is_goldens_safe():
    """ServeDeployment snapshots gain an ``affinity`` key ONLY after router
    telemetry is observed — golden fleet traces recorded without a router
    replay must stay byte-identical."""
    from repro.fleet.workloads import (
        RequestTrace,
        ServeDeployment,
        serve_capacity_planner,
    )

    dep = ServeDeployment(
        name="serve",
        planner=serve_capacity_planner(dispatch_s=0.02, per_seq_s=0.004),
        trace=RequestTrace(seed=0, tick_s=300.0, qps=[1.0]),
        slo_p95_s=4.0, gen_tokens=64, batch_grid=(1, 2, 4),
        replica_options=(1, 2, 4),
    )
    dep.replicas = 2
    assert "affinity" not in dep.snapshot(1.0, 0.5)
    assert dep.measured_effective_m() == 2.0

    n = dep.observe_router([
        RouterEvent(step=0, rid=0, replica=0, matched_pages=2,
                    best_affinity=2, reason="affinity", prompt_pages=2,
                    loads=[0, 0]),
        ServeStepEvent(step=1, step_s=0.1, op="decode", batch=2,
                       committed=2, replica=0),
        ServeStepEvent(step=1, step_s=0.4, op="decode", batch=2,
                       committed=2, replica=1),
    ])
    assert n == 3
    snap = dep.snapshot(1.0, 0.5)
    assert snap["affinity"] == 1.0
    assert dep.measured_effective_m() == pytest.approx(1.25)


def test_router_events_feed_planner_end_to_end():
    vocab = ServeEngine.config_for(ARCH, True).vocab_size
    specs = _trace(4, 5, vocab)
    router = Router(_engines(2), spill_slack=512)
    for p, g, a in specs:
        router.submit(p, g, arrival_step=a)
    rstats = router.run()

    planner = CapacityPlanner()
    planner.ingest(router.all_events())
    assert planner.affinity_hit_rate == pytest.approx(
        rstats["affinity_hit_rate"]
    )
    per = planner.replica_stats()
    assert sum(int(s["dispatches"]) for s in per.values()) == len(specs)
    assert 0 < planner.measured_effective_replicas() <= 2.0
