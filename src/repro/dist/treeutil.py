"""Pytree mappers for value trees paired with logical-axes trees.

An *axes tree* mirrors a value tree's container structure (dicts, tuples,
lists) but its leaves are tuples of logical axis names — one ``str | None``
per tensor dimension, ``()`` for scalars.  ``jax.tree.map`` cannot zip the
two (it would recurse into the axes tuples), so these walkers treat a tuple
whose elements are all ``str | None`` as a leaf.

Used by launch/inputs.py (ShapeDtypeStruct + NamedSharding construction),
runtime/elastic.py (re-sharding onto a new mesh), and
training/optimizers.py (mapping param axes onto optimizer-state axes).
"""
from __future__ import annotations

from typing import Any, Callable


def is_axes_leaf(x: Any) -> bool:
    """True for a tuple of logical axis names (incl. () for scalars)."""
    return isinstance(x, tuple) and all(
        e is None or isinstance(e, str) for e in x)


def map_axes(fn: Callable[[tuple], Any], axes_tree: Any) -> Any:
    """Map ``fn`` over every axes leaf of an axes tree."""
    if isinstance(axes_tree, dict):
        return {k: map_axes(fn, v) for k, v in axes_tree.items()}
    if is_axes_leaf(axes_tree):
        return fn(axes_tree)
    if isinstance(axes_tree, (tuple, list)):
        if isinstance(axes_tree, tuple) and hasattr(axes_tree, "_fields"):
            return type(axes_tree)(*(map_axes(fn, v) for v in axes_tree))
        return type(axes_tree)(map_axes(fn, v) for v in axes_tree)
    raise TypeError(f"not an axes tree node: {axes_tree!r}")


def map_zip_with_axes(fn: Callable[..., Any], value_tree: Any,
                      other_tree: Any, axes_tree: Any) -> Any:
    """Like ``map_with_axes`` but zips a second value tree:
    ``fn(value_leaf, other_leaf, axes_leaf)``.  Used by the serve subsystem
    to pair a paged cache with a prefill cache plus their axes."""
    if isinstance(value_tree, dict):
        return {k: map_zip_with_axes(fn, v, other_tree[k], axes_tree[k])
                for k, v in value_tree.items()}
    if isinstance(value_tree, (tuple, list)):
        if isinstance(value_tree, tuple) and hasattr(value_tree, "_fields"):
            return type(value_tree)(*(map_zip_with_axes(fn, v, o, a)
                                      for v, o, a in zip(value_tree,
                                                         other_tree,
                                                         axes_tree)))
        return type(value_tree)(map_zip_with_axes(fn, v, o, a)
                                for v, o, a in zip(value_tree, other_tree,
                                                   axes_tree))
    return fn(value_tree, other_tree, axes_tree)


def map_with_axes(fn: Callable[[Any, Any], Any], value_tree: Any,
                  axes_tree: Any) -> Any:
    """Map ``fn(value_leaf, axes_leaf)`` over a value tree, walking the
    *value* tree's containers and indexing the axes tree in parallel (so an
    empty container and a scalar's ``()`` axes never collide)."""
    if isinstance(value_tree, dict):
        return {k: map_with_axes(fn, v, axes_tree[k])
                for k, v in value_tree.items()}
    if isinstance(value_tree, (tuple, list)):
        if isinstance(value_tree, tuple) and hasattr(value_tree, "_fields"):
            return type(value_tree)(*(map_with_axes(fn, v, a)
                                      for v, a in zip(value_tree, axes_tree)))
        return type(value_tree)(map_with_axes(fn, v, a)
                                for v, a in zip(value_tree, axes_tree))
    return fn(value_tree, axes_tree)
