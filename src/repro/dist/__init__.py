"""Distribution subsystem: sharding rules, pytree axis mappers, HLO costs.

Four small modules used across launch/, models/, runtime/, and training/:

* ``partitioning`` — logical-axis -> mesh-axis ``Rules`` (the single place
  sharding policy lives; everything else passes logical names around)
* ``treeutil``     — pytree-with-logical-axes mappers
* ``hlo_costs``    — trip-count-exact flop/byte/collective parser over
  optimized HLO text (XLA's ``cost_analysis`` counts while bodies once)
* ``hlo_analysis`` — collective-byte summaries for the dry-run roofline
"""
