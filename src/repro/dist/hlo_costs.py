"""Trip-count-exact cost attribution over optimized HLO text.

XLA's ``compiled.cost_analysis()`` counts a while-loop body **once**, which
under-reports any scanned layer stack by the trip count (an 80-layer model
shows up as one period).  This parser walks the module's call graph —
fusions, calls, conditionals, and while loops — multiplying each
computation's cost by the product of enclosing static trip counts, read
from XLA's ``backend_config={"known_trip_count":{"n":...}}`` annotation
(with a fallback to the loop condition's ``LT`` bound).

Cost model per instruction:

* flops: ``dot`` = 2 * out_elems * contraction_size (from
  ``lhs_contracting_dims`` and the lhs operand shape); ``convolution`` =
  2 * out_elems * kernel_elems / out_features.  Elementwise ops are not
  counted — matmul-class flops are what the roofline compares against peak.
* bytes: operand bytes + output bytes for every materializing instruction.
  Fusion *interiors* are excluded (fused intermediates never touch HBM);
  the fusion's own boundary operands/outputs are what counts.
* collectives: operand bytes plus a ring-model wire estimate per kind
  (all-reduce 2(n-1)/n, all-gather/reduce-scatter/all-to-all (n-1)/n,
  collective-permute 1x), with n = replica-group size.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "s4": 1, "s8": 1, "u2": 1, "u4": 1, "u8": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1, "f8e5m2fnuz": 1,
    "f8e4m3fnuz": 1, "f4e2m1fn": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_SHAPE_RE = re.compile(
    r"\b(" + "|".join(sorted(_DTYPE_BYTES, key=len, reverse=True))
    + r")\[([0-9,]*)\]")

_COLLECTIVE_KINDS = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "collective-broadcast", "ragged-all-to-all",
)

# bytes that cross a link per participating device, ring algorithm, as a
# multiple of the payload (n = replica-group size)
_WIRE_FACTOR = {
    "all-reduce": lambda n: 2.0 * (n - 1) / n,
    "all-gather": lambda n: (n - 1) / n,
    "reduce-scatter": lambda n: (n - 1) / n,
    "all-to-all": lambda n: (n - 1) / n,
    "ragged-all-to-all": lambda n: (n - 1) / n,
    "collective-broadcast": lambda n: (n - 1) / n,
    "collective-permute": lambda n: 1.0,
}

# never touch memory / pure bookkeeping
_FREE_OPS = frozenset({
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "add-dependency", "partition-id", "replica-id", "domain",
})


def _shape_elems(dims: str) -> int:
    elems = 1
    if dims:
        for d in dims.split(","):
            elems *= int(d)
    return elems


def _shapes(text: str) -> List[Tuple[int, int]]:
    """All (elems, bytes) array-shape tokens in ``text``."""
    out = []
    for dtype, dims in _SHAPE_RE.findall(text):
        elems = _shape_elems(dims)
        out.append((elems, elems * _DTYPE_BYTES[dtype]))
    return out


def _split_type_and_op(rhs: str) -> Tuple[str, str, int]:
    """``rhs`` is everything after "= ".  Returns (type_str, op, open_idx)
    where open_idx is the index of the op's '(' in rhs."""
    i = 0
    if rhs.startswith("("):           # tuple type: scan to balanced close
        depth = 0
        for i, ch in enumerate(rhs):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
        i += 1
    else:
        i = rhs.find(" ")
    type_str = rhs[:i]
    rest = rhs[i:].lstrip()
    off = len(rhs) - len(rest)
    paren = rest.find("(")
    if paren < 0:
        return type_str, rest.strip(), -1
    return type_str, rest[:paren].strip(), off + paren


def _balanced(text: str, open_idx: int) -> Tuple[str, str]:
    """(inside-parens, after-close) starting at text[open_idx] == '('."""
    depth = 0
    for j in range(open_idx, len(text)):
        if text[j] == "(":
            depth += 1
        elif text[j] == ")":
            depth -= 1
            if depth == 0:
                return text[open_idx + 1:j], text[j + 1:]
    return text[open_idx + 1:], ""


_OPERAND_RE = re.compile(
    r"\b(" + "|".join(sorted(_DTYPE_BYTES, key=len, reverse=True))
    + r")\[([0-9,]*)\](?:\{[^}]*\})?\s+%([^\s,()]+)")


@dataclasses.dataclass
class _Instr:
    op: str
    name: str = ""
    out_elems: int = 0
    out_bytes: int = 0
    operand_bytes: int = 0
    operand_info: Tuple[Tuple[str, int], ...] = ()   # (name, bytes) per operand
    param_index: Optional[int] = None                # for op == "parameter"
    flops: float = 0.0
    callee: Optional[str] = None
    while_body: Optional[str] = None
    while_cond: Optional[str] = None
    trip: Optional[int] = None
    branches: Tuple[str, ...] = ()
    group_size: Optional[int] = None
    label: str = ""


def _parse_instr(line: str) -> Optional[_Instr]:
    s = line.strip()
    if s.startswith("ROOT "):
        s = s[5:]
    if not s.startswith("%") or " = " not in s:
        return None
    lhs, rhs = s.split(" = ", 1)
    type_str, op, paren = _split_type_and_op(rhs)
    if paren < 0:
        return None
    operands, attrs = _balanced(rhs, paren)
    ins = _Instr(op=op, name=lhs.strip().lstrip("%"))
    out = _shapes(type_str)
    ins.out_elems = sum(e for e, _ in out)
    ins.out_bytes = sum(b for _, b in out)
    opshapes = _shapes(operands)
    ins.operand_bytes = sum(b for _, b in opshapes)
    ins.operand_info = tuple(
        (m.group(3), _shape_elems(m.group(2)) * _DTYPE_BYTES[m.group(1)])
        for m in _OPERAND_RE.finditer(operands))
    if op == "parameter":
        mp = re.match(r"\s*(\d+)", operands)
        if mp:
            ins.param_index = int(mp.group(1))

    m = re.search(r'op_name="([^"]+)"', attrs)
    ins.label = f"{op} {type_str}" + (f"  {m.group(1)}" if m else "")

    if op == "dot":
        contraction = 1
        mdims = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", attrs)
        if mdims and opshapes:
            mlhs = _SHAPE_RE.search(operands)
            lhs_dims = ([int(d) for d in mlhs.group(2).split(",")]
                        if mlhs and mlhs.group(2) else [])
            for d in (mdims.group(1).split(",") if mdims.group(1) else []):
                di = int(d)
                if di < len(lhs_dims):
                    contraction *= lhs_dims[di]
        ins.flops = 2.0 * ins.out_elems * contraction
    elif op == "convolution":
        kernel_elems = opshapes[1][0] if len(opshapes) > 1 else 1
        out_features = 1
        mlab = re.search(r"dim_labels=[^_]+_([0-9a-z]+)->", attrs)
        if mlab:
            klabels = mlab.group(1)
            o_pos = klabels.find("o")
            mker = list(_SHAPE_RE.finditer(operands))
            if o_pos >= 0 and len(mker) > 1 and mker[1].group(2):
                kdims = [int(d) for d in mker[1].group(2).split(",")]
                if o_pos < len(kdims):
                    out_features = max(kdims[o_pos], 1)
        ins.flops = 2.0 * ins.out_elems * kernel_elems / out_features
    elif op == "while":
        mb = re.search(r"body=%([^\s,]+)", attrs)
        mc = re.search(r"condition=%([^\s,]+)", attrs)
        ins.while_body = mb.group(1) if mb else None
        ins.while_cond = mc.group(1) if mc else None
        mt = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', attrs)
        if mt:
            ins.trip = int(mt.group(1))
    elif op in ("fusion", "call", "async-start"):
        mcal = re.search(r"calls=%([^\s,)]+)", attrs)
        ins.callee = mcal.group(1) if mcal else None
    elif op == "conditional":
        mbr = re.findall(r"(?:true_computation|false_computation)=%([^\s,]+)",
                         attrs)
        if not mbr:
            mset = re.search(r"branch_computations=\{([^}]*)\}", attrs)
            if mset:
                mbr = re.findall(r"%([^\s,]+)", mset.group(1))
        ins.branches = tuple(mbr)

    kind = op[:-6] if op.endswith("-start") else op
    if kind in _COLLECTIVE_KINDS and not op.endswith("-done"):
        ins.op = kind if op.endswith("-start") else op
        mg = re.search(r"replica_groups=\{\{([0-9,]+)\}", attrs)
        if mg:
            ins.group_size = len(mg.group(1).split(","))
        else:
            mg = re.search(r"replica_groups=\[\d+,(\d+)\]<=\[\d+\]", attrs)
            if mg:
                ins.group_size = int(mg.group(1))
    return ins


@dataclasses.dataclass
class _FusionIO:
    """HBM traffic model for one fused computation's boundary."""
    param_reads: Dict[int, int]       # parameter index -> bytes actually read
    out_bytes_override: Optional[int]  # None = use the fusion's output bytes


@dataclasses.dataclass
class HloModule:
    comps: Dict[str, List[_Instr]]
    raw: Dict[str, List[str]]
    entry: Optional[str]
    num_partitions: int
    _fusion_io: Dict[str, _FusionIO] = dataclasses.field(default_factory=dict)

    def fusion_io(self, comp: str) -> _FusionIO:
        """XLA lowers scan bodies to fusions that *slice* their big operands
        (dynamic-slice) and *update* big outputs in place
        (dynamic-update-slice).  Charging full operand/output bytes per trip
        would overstate HBM traffic by the trip count, so: a parameter
        consumed only by dynamic-slice/gather reads just the slices; a
        parameter consumed only as a dynamic-update-slice target is aliased
        (read ~0); when every output store is an in-place update, the write
        is the update bytes, not the whole buffer."""
        if comp in self._fusion_io:
            return self._fusion_io[comp]
        instrs = self.comps.get(comp, [])
        reads: Dict[int, int] = {}
        for p in instrs:
            if p.op != "parameter" or p.param_index is None:
                continue
            uses = [(ins, pos) for ins in instrs if ins.op != "parameter"
                    for pos, (oname, _) in enumerate(ins.operand_info)
                    if oname == p.name]

            def _reduced(ins, pos):
                if ins.op in ("dynamic-slice", "gather") and pos == 0:
                    return ins.out_bytes          # reads just the slice
                if ins.op == "dynamic-update-slice" and pos == 0:
                    return 0                      # aliased in-place target
                return None

            per_use = [_reduced(ins, pos) for ins, pos in uses]
            if uses and all(r is not None for r in per_use):
                reads[p.param_index] = sum(per_use)
        dus = [ins for ins in instrs if ins.op == "dynamic-update-slice"]
        out_override = None
        if dus and all(len(ins.operand_info) > 1 for ins in dus):
            # read + write of each updated region
            out_override = 2 * sum(ins.operand_info[1][1] for ins in dus)
        io = _FusionIO(reads, out_override)
        self._fusion_io[comp] = io
        return io


def parse_module(hlo_text: str) -> HloModule:
    comps: Dict[str, List[_Instr]] = {}
    raw_lines: Dict[str, List[str]] = {}
    entry = None
    num_partitions = 1
    current: Optional[List[_Instr]] = None
    current_raw: Optional[List[str]] = None
    for raw in hlo_text.splitlines():
        if raw.startswith("HloModule"):
            m = re.search(r"num_partitions=(\d+)", raw)
            if m:
                num_partitions = int(m.group(1))
            continue
        if raw.startswith((" ", "\t")):
            if current is not None:
                current_raw.append(raw)
                ins = _parse_instr(raw)
                if ins is not None:
                    current.append(ins)
            continue
        m = re.match(r"(ENTRY\s+)?%?([^\s(]+)\s*\(.*\{\s*$", raw)
        if m:
            name = m.group(2)
            current = comps.setdefault(name, [])
            current_raw = raw_lines.setdefault(name, [])
            if m.group(1):
                entry = name
        elif raw.startswith("}"):
            current = None
            current_raw = None
    if entry is None and comps:
        entry = next(iter(comps))
    return HloModule(comps=comps, raw=raw_lines, entry=entry,
                     num_partitions=num_partitions)


def _trip_fallback(module: HloModule, cond_name: Optional[str]) -> int:
    """Read the loop bound from ``compare(.., constant(N)), direction=LT``
    in the condition computation (assumes a 0-based unit-stride counter,
    which is how lax.scan/fori_loop lower).  Used only when XLA's
    known_trip_count annotation is absent."""
    lines = module.raw.get(cond_name or "", [])
    constants = {}
    for ln in lines:
        m = re.match(r"\s*(?:ROOT\s+)?%([^\s]+) = \S+ constant\((\d+)\)", ln)
        if m:
            constants[m.group(1)] = int(m.group(2))
    for ln in lines:
        if "compare(" not in ln or "direction=LT" not in ln:
            continue
        for name in re.findall(r"%([^\s,)]+)", ln.split("compare(", 1)[1]):
            if name in constants:
                return max(constants[name], 1)
    return 1


@dataclasses.dataclass
class HloCostSummary:
    flops: float = 0.0
    bytes_accessed: float = 0.0
    n_whiles: int = 0
    collective_operand_bytes: float = 0.0
    collective_wire_bytes: float = 0.0
    per_kind_operand: Dict[str, float] = dataclasses.field(default_factory=dict)
    per_kind_wire: Dict[str, float] = dataclasses.field(default_factory=dict)


def _walk(module: HloModule, comp: str, mult: float, count_bytes: bool,
          totals: HloCostSummary, rows: List[Tuple[float, float, str, str]],
          stack: Tuple[str, ...]) -> None:
    if comp not in module.comps or comp in stack:
        return
    stack = stack + (comp,)
    for ins in module.comps[comp]:
        if ins.op == "while":
            totals.n_whiles += 1
            trip = ins.trip if ins.trip is not None else _trip_fallback(
                module, ins.while_cond)
            for sub in (ins.while_body, ins.while_cond):
                if sub:
                    _walk(module, sub, mult * trip, count_bytes, totals,
                          rows, stack)
            continue
        if ins.op == "conditional":
            for b in ins.branches:
                _walk(module, b, mult, count_bytes, totals, rows, stack)
            continue
        if ins.op in ("fusion", "async-start") and ins.callee:
            # interior flops/collectives count; interior bytes do not (fused
            # intermediates stay in registers/cache, not HBM)
            _walk(module, ins.callee, mult, False, totals, rows, stack)
            if count_bytes:
                io = module.fusion_io(ins.callee)
                reads = sum(io.param_reads.get(i, nbytes_i)
                            for i, (_, nbytes_i)
                            in enumerate(ins.operand_info))
                writes = (ins.out_bytes if io.out_bytes_override is None
                          else io.out_bytes_override)
                b = reads + writes
                totals.bytes_accessed += mult * b
                rows.append((0.0, mult * b, ins.label, comp))
            continue
        if ins.op == "call" and ins.callee:
            _walk(module, ins.callee, mult, count_bytes, totals, rows, stack)
            continue
        if ins.op in _FREE_OPS or ins.op.endswith("-done"):
            continue

        flops = mult * ins.flops
        if not count_bytes:
            nbytes = 0.0
        elif ins.op in ("dynamic-slice", "gather"):
            nbytes = mult * 2.0 * ins.out_bytes      # read slice + write out
        elif ins.op == "dynamic-update-slice" and len(ins.operand_info) > 1:
            nbytes = mult * 2.0 * ins.operand_info[1][1]  # update region r+w
        else:
            nbytes = mult * (ins.operand_bytes + ins.out_bytes)
        totals.flops += flops
        totals.bytes_accessed += nbytes
        if ins.op in _COLLECTIVE_KINDS:
            n = ins.group_size or module.num_partitions
            payload = (ins.out_bytes if ins.op == "all-gather"
                       else ins.operand_bytes)
            wire = mult * payload * _WIRE_FACTOR[ins.op](max(n, 1)) \
                if n > 1 else 0.0
            operand = mult * ins.operand_bytes
            totals.collective_operand_bytes += operand
            totals.collective_wire_bytes += wire
            totals.per_kind_operand[ins.op] = \
                totals.per_kind_operand.get(ins.op, 0.0) + operand
            totals.per_kind_wire[ins.op] = \
                totals.per_kind_wire.get(ins.op, 0.0) + wire
        if flops or nbytes:
            rows.append((flops, nbytes, ins.label, comp))


def _analyze(hlo_text: str):
    module = parse_module(hlo_text)
    totals = HloCostSummary()
    rows: List[Tuple[float, float, str, str]] = []
    if module.entry:
        _walk(module, module.entry, 1.0, True, totals, rows, ())
    return totals, rows


def analyze_hlo(hlo_text: str) -> HloCostSummary:
    """Whole-module costs with exact while-loop trip-count attribution."""
    return _analyze(hlo_text)[0]


def top_contributors(hlo_text: str, metric: str = "flops",
                     k: int = 10) -> List[Tuple[float, str, str]]:
    """Top-k instructions by ``metric`` ("flops" | "bytes"), each scaled by
    its enclosing trip counts.  Returns (value, label, computation) rows."""
    if metric not in ("flops", "bytes"):
        raise ValueError(f"metric must be 'flops' or 'bytes', got {metric!r}")
    idx = 0 if metric == "flops" else 1
    _, rows = _analyze(hlo_text)
    picked = [(r[idx], r[2], r[3]) for r in rows if r[idx] > 0]
    picked.sort(key=lambda r: r[0], reverse=True)
    return picked[:k]
