"""Collective-traffic summaries over optimized HLO text.

Thin queries on top of :mod:`repro.dist.hlo_costs` used by the dry-run
roofline (launch/dryrun.py) and benchmarks/roofline.py: how many bytes
enter collectives per device, and how many actually cross links under a
ring algorithm.  Both are trip-count-exact (collectives inside scanned
layer stacks are multiplied by the loop bound).
"""
from __future__ import annotations

from typing import Dict

from repro.dist.hlo_costs import analyze_hlo


def collective_bytes(hlo_text: str) -> int:
    """Total per-device operand bytes entering collective ops."""
    return int(analyze_hlo(hlo_text).collective_operand_bytes)


def collective_wire_bytes(hlo_text: str) -> int:
    """Total per-device ring-model wire bytes across all collectives."""
    return int(analyze_hlo(hlo_text).collective_wire_bytes)


def collective_breakdown(hlo_text: str) -> Dict[str, int]:
    """Per-kind operand bytes (e.g. {"all-reduce": ..., "all-gather": ...})."""
    parsed = analyze_hlo(hlo_text)
    return {k: int(v) for k, v in parsed.per_kind_operand.items()}


def collective_wire_breakdown(hlo_text: str) -> Dict[str, int]:
    """Per-kind ring-model wire bytes."""
    parsed = analyze_hlo(hlo_text)
    return {k: int(v) for k, v in parsed.per_kind_wire.items()}
