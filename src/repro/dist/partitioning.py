"""Sharding rules: logical axis names -> jax.sharding.PartitionSpec.

Model/optimizer code annotates every tensor dimension with a *logical* name
("embed", "mlp", "batch", "cache_seq", ...); this module is the one place
those names meet the physical mesh.  ``Rules.default(mesh)`` encodes the
production policy (FSDP over the batch axes, tensor parallelism over
"model"); ``override()`` produces per-cell variants (the dry-run and the
§Perf hillclimb tweak placement without touching model code).

Resolution semantics (pinned by tests/test_partitioning.py):

* **dedupe, first dim wins** — a mesh axis claimed by an earlier tensor
  dimension is unavailable to later ones (a PartitionSpec may not repeat a
  mesh axis).
* **divisibility fallback** — a dimension that does not divide the mesh
  axis size is left replicated rather than producing an uneven shard.
* **partial axis-tuple retention** — for tuple entries like
  ``("pod", "data")`` the longest *prefix* that divides (and is unclaimed)
  is kept, so a batch of 2 on a 2x16x16 mesh still shards over "pod".
* **pod joins fsdp** — every non-"model" mesh axis counts as a batch/FSDP
  axis, in mesh order.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Mapping, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

# An entry maps one logical axis to: replicated (None), one mesh axis, or an
# ordered tuple of mesh axes (sharded over their product).
AxisEntry = Union[None, str, Tuple[str, ...]]

MODEL_AXIS = "model"

# Sentinel resolved to the mesh's batch/FSDP axes at Rules construction.
_BATCH = "__batch__"

# Parameter logical axes.  FSDP shards the d_model ("embed") dim over the
# batch axes; all "wide" dims take tensor parallelism over "model"; small or
# scan-carried dims stay replicated.
_PARAM_TABLE: Dict[str, Any] = {
    "embed": _BATCH,
    "vocab": MODEL_AXIS,
    "mlp": MODEL_AXIS,
    "heads_flat": MODEL_AXIS,
    "kv_flat": MODEL_AXIS,
    "expert": MODEL_AXIS,
    "expert_mlp": MODEL_AXIS,
    "mamba_inner": MODEL_AXIS,
    "norm": None,
    "layers": None,
    "lora": None,
    "conv": None,
    "dt_rank": None,
    "ssm_state": None,
}

# Activation / cache logical axes.  Batch dims shard over the batch axes;
# head/feature dims over "model"; sequence dims replicate by default (the
# long-context decode cells re-point "cache_seq" via override, see
# launch/inputs.rules_for_cell).
_ACT_TABLE: Dict[str, Any] = {
    "batch": _BATCH,
    "cache_batch": _BATCH,
    "act_heads": MODEL_AXIS,
    "act_kv_heads": MODEL_AXIS,
    "act_mlp": MODEL_AXIS,
    "act_mamba": MODEL_AXIS,
    "act_vocab": MODEL_AXIS,
    "cache_head_dim": MODEL_AXIS,
    "seq": None,
    "frontend_seq": None,
    "act_embed": None,
    "cache_seq": None,
    "cache_latent": None,
}


def _normalize(entry: Any) -> AxisEntry:
    if entry is None or isinstance(entry, str):
        return entry
    return tuple(entry)


@dataclasses.dataclass(frozen=True)
class Rules:
    """Immutable logical->physical placement policy for one mesh."""

    mesh: Any                         # jax.sharding.Mesh (or a stand-in)
    axis_sizes: Mapping[str, int]     # mesh axis name -> size, in mesh order
    params: Mapping[str, AxisEntry]
    acts: Mapping[str, AxisEntry]

    # ------------------------------------------------------------------
    # Construction
    # ------------------------------------------------------------------
    @classmethod
    def default(cls, mesh) -> "Rules":
        names = tuple(mesh.axis_names)
        sizes = dict(zip(names, mesh.devices.shape))
        batch = tuple(a for a in names if a != MODEL_AXIS)

        def concretize(table: Mapping[str, Any]) -> Dict[str, AxisEntry]:
            return {k: (batch if v is _BATCH else _normalize(v))
                    for k, v in table.items()}

        return cls(mesh=mesh, axis_sizes=sizes,
                   params=concretize(_PARAM_TABLE),
                   acts=concretize(_ACT_TABLE))

    @classmethod
    def for_serving(cls, mesh) -> "Rules":
        """Placement policy for the serve data plane (DESIGN.md §13).

        Pure tensor parallelism: wide parameter and activation feature dims
        shard over "model" exactly as in training, while every batch-like
        axis is replicated —

        * ``batch`` (the decode-slot axis): each device computes all slots;
          the fixed-shape decode batch is small at serving operating points
          and TP wants the full activation row per device anyway;
        * ``cache_batch``: in the *paged* cache this axis is the physical
          page pool (see serve/cache.py) — any slot may reference any page
          through its page table, so the pool must be resident everywhere
          (pages shard over "model" along their head/latent feature dims
          instead);
        * ``embed`` (FSDP in training): replicated — serving wants full
          parameter rows resident instead of paying an all-gather every
          decode step for a batch of a few slots.

        Note on exactness: at world size 1 this placement is trivially
        bitwise-identical to the unsharded engine.  At world size > 1 the
        model-axis contractions (attention output / MLP down projections)
        reduce across devices, so logits agree to float tolerance and the
        greedy token streams — not the raw logits — are the bit-identity
        surface (tests/test_serve_sharding.py).
        """
        return cls.default(mesh).override(
            params={"embed": None},
            acts={"batch": None, "cache_batch": None},
        )

    def override(self, params: Optional[Mapping[str, Any]] = None,
                 acts: Optional[Mapping[str, Any]] = None) -> "Rules":
        """New Rules with some logical-axis entries replaced."""
        new_params = dict(self.params)
        new_acts = dict(self.acts)
        for k, v in (params or {}).items():
            new_params[k] = _normalize(v)
        for k, v in (acts or {}).items():
            new_acts[k] = _normalize(v)
        return dataclasses.replace(self, params=new_params, acts=new_acts)

    # ------------------------------------------------------------------
    # Mesh structure
    # ------------------------------------------------------------------
    def batch_axes(self) -> Tuple[str, ...]:
        """Mesh axes the "batch" activation dim currently maps to — by
        default every non-"model" axis (data parallel + pod), but an
        ``override(acts={"batch": None})`` empties it, which is how the
        replicated-token paths (MoE 2D decode, DiLoCo replicas) signal
        that tokens are not batch-sharded."""
        entry = self.acts.get("batch")
        if entry is None:
            return ()
        axes = entry if isinstance(entry, tuple) else (entry,)
        return tuple(a for a in axes if a in self.axis_sizes)

    def model_axis(self) -> Optional[str]:
        return MODEL_AXIS if MODEL_AXIS in self.axis_sizes else None

    # ------------------------------------------------------------------
    # Resolution
    # ------------------------------------------------------------------
    def _pick(self, entry: AxisEntry, dim: Optional[int], used: set):
        """Resolve one tensor dim's entry against claimed axes + its size."""
        if entry is None:
            return None
        cand = entry if isinstance(entry, tuple) else (entry,)
        # axes absent from this mesh (e.g. "pod" on a single-pod mesh) are
        # skipped so overrides written for the big mesh still apply
        cand = tuple(a for a in cand if a in self.axis_sizes)
        picked, prod = [], 1
        for a in cand:
            if a in used:
                break
            size = self.axis_sizes[a]
            if dim is not None and dim % (prod * size) != 0:
                break
            picked.append(a)
            prod *= size
        if not picked:
            return None
        used.update(picked)
        return picked[0] if len(picked) == 1 else tuple(picked)

    def _pspec(self, lookup, axes: Sequence[Optional[str]],
               shape: Optional[Sequence[int]]) -> P:
        if shape is not None and len(shape) != len(axes):
            raise ValueError(f"shape {tuple(shape)} rank != axes {tuple(axes)}")
        used: set = set()
        entries = []
        for i, name in enumerate(axes):
            dim = None if shape is None else int(shape[i])
            entries.append(self._pick(lookup(name), dim, used))
        return P(*entries)

    def _param_entry(self, name: Optional[str]) -> AxisEntry:
        return self.params.get(name) if name else None

    def _act_entry(self, name: Optional[str]) -> AxisEntry:
        """Acts first, then params — cache trees reuse parameter logical
        names (e.g. "mamba_inner") for their feature dims."""
        if not name:
            return None
        if name in self.acts:
            return self.acts[name]
        return self.params.get(name)

    def param_pspec(self, axes: Sequence[Optional[str]],
                    shape: Optional[Sequence[int]] = None) -> P:
        return self._pspec(self._param_entry, tuple(axes), shape)

    def act_pspec(self, axes: Sequence[Optional[str]],
                  shape: Optional[Sequence[int]] = None) -> P:
        return self._pspec(self._act_entry, tuple(axes), shape)

    def param_sharding(self, mesh, axes: Sequence[Optional[str]],
                       shape: Optional[Sequence[int]] = None) -> NamedSharding:
        return NamedSharding(mesh, self.param_pspec(axes, shape))

    def act_sharding(self, mesh, axes: Sequence[Optional[str]],
                     shape: Optional[Sequence[int]] = None) -> NamedSharding:
        return NamedSharding(mesh, self.act_pspec(axes, shape))


def constrain(x: jax.Array, rules: Optional[Rules],
              axes: Sequence[Optional[str]]) -> jax.Array:
    """with_sharding_constraint via the activation rules (no-op without a
    mesh).  Shape-aware, so non-divisible dims silently stay replicated."""
    if rules is None or rules.mesh is None:
        return x
    spec = rules.act_pspec(tuple(axes), tuple(x.shape))
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(rules.mesh, spec))
