"""Convergence model g(i, m): objective value after i iterations on m machines.

Implements §3.2.2 + §4 of the paper:
  * fit log(P(i,m) - P*) with LassoCV over the feature library
  * leave-one-m-out cross validation (§4.1, Fig 4)
  * forward prediction over an iteration window (§4.2, Fig 5)
The model is metric-agnostic (footnote 4): any positive gap (primal
suboptimality, duality gap, LM train-loss - floor) works.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np

from repro.core.features import FeatureLibrary
from repro.core.lasso import LassoFit, lasso_cv, r2_score

GAP_FLOOR = 1e-12


@dataclasses.dataclass
class ConvergenceData:
    """Observations: objective P(i, m) for iterations i on m machines."""

    i: np.ndarray       # (n,) iteration index (>= 1)
    m: np.ndarray       # (n,) machine count
    value: np.ndarray   # (n,) objective value P(i, m)
    p_star: float       # optimal value P*

    @classmethod
    def from_curves(cls, curves: Dict[int, np.ndarray], p_star: float,
                    start_iter: int = 1,
                    stop_gap: Optional[float] = None) -> "ConvergenceData":
        """curves: {m: array of P over iterations}.

        ``stop_gap`` truncates each curve once the gap reaches the target —
        mirroring the paper's runs, which terminate at suboptimality 1e-4
        (points at machine precision would otherwise poison the log-gap fit).
        """
        i_all, m_all, v_all = [], [], []
        for m, vals in sorted(curves.items()):
            vals = np.asarray(vals, np.float64)
            if stop_gap is not None:
                gaps = vals - p_star
                below = np.nonzero(gaps <= stop_gap)[0]
                if len(below):
                    vals = vals[: below[0] + 1]
            its = np.arange(start_iter, start_iter + len(vals))
            i_all.append(its)
            m_all.append(np.full(len(vals), m))
            v_all.append(vals)
        return cls(np.concatenate(i_all), np.concatenate(m_all),
                   np.concatenate(v_all), float(p_star))

    def gap(self) -> np.ndarray:
        return np.maximum(self.value - self.p_star, GAP_FLOOR)

    def mask(self, keep: np.ndarray) -> "ConvergenceData":
        return ConvergenceData(self.i[keep], self.m[keep], self.value[keep],
                               self.p_star)


@dataclasses.dataclass
class ConvergenceModel:
    library: FeatureLibrary = dataclasses.field(default_factory=FeatureLibrary)
    fit_: Optional[LassoFit] = None
    p_star: float = 0.0

    # ------------------------------------------------------------------
    def fit(self, data: ConvergenceData, cv_folds: int = 5,
            seed: int = 0) -> "ConvergenceModel":
        X = self.library(data.i, data.m)
        y = np.log(data.gap())
        self.fit_ = lasso_cv(X, y, k=cv_folds, seed=seed)
        self.p_star = data.p_star
        return self

    def predict_log_gap(self, i, m) -> np.ndarray:
        assert self.fit_ is not None, "call fit() first"
        i = np.atleast_1d(np.asarray(i, np.float64))
        m = np.broadcast_to(np.atleast_1d(np.asarray(m, np.float64)), i.shape)
        return self.fit_.predict(self.library(i, m))

    def predict(self, i, m) -> np.ndarray:
        """g(i, m): predicted objective value."""
        return self.p_star + np.exp(self.predict_log_gap(i, m))

    def r2(self, data: ConvergenceData) -> float:
        pred = self.predict_log_gap(data.i, data.m)
        return r2_score(np.log(data.gap()), pred)

    def active_features(self, tol: float = 1e-10) -> Dict[str, float]:
        assert self.fit_ is not None
        return {n: float(c) for n, c in zip(self.library.names, self.fit_.coef)
                if abs(c) > tol}

    # ------------------------------------------------------------------
    # §4.1: predict a held-out degree of parallelism
    # ------------------------------------------------------------------
    def loo_m(self, data: ConvergenceData,
              seed: int = 0) -> Dict[int, Tuple[float, "ConvergenceModel"]]:
        """Leave-one-m-out: for each m, fit on the others, report held-out R²
        (in log-gap space) and the fitted model."""
        out: Dict[int, Tuple[float, ConvergenceModel]] = {}
        for m_hold in sorted(set(data.m.astype(int))):
            train = data.mask(data.m != m_hold)
            test = data.mask(data.m == m_hold)
            model = ConvergenceModel(self.library).fit(train, seed=seed)
            pred = model.predict_log_gap(test.i, test.m)
            out[int(m_hold)] = (r2_score(np.log(test.gap()), pred), model)
        return out

    # ------------------------------------------------------------------
    # §4.2: forward prediction (fit on a trailing window, predict ahead)
    # ------------------------------------------------------------------
    def forward_prediction(self, data: ConvergenceData, window: int = 50,
                           ahead: int = 1,
                           seed: int = 0) -> Dict[int, np.ndarray]:
        """For each m: walk the curve; at iteration t >= window fit on
        [t-window, t] and predict t+ahead.  Returns {m: (n_pred, 3) array of
        (iter_predicted, true_value, predicted_value)}."""
        results: Dict[int, np.ndarray] = {}
        for m_val in sorted(set(data.m.astype(int))):
            sel = data.m == m_val
            its = data.i[sel]
            vals = data.value[sel]
            order = np.argsort(its)
            its, vals = its[order], vals[order]
            rows = []
            for t_idx in range(window, len(its) - ahead):
                w_i = its[t_idx - window: t_idx + 1]
                w_v = vals[t_idx - window: t_idx + 1]
                sub = ConvergenceData(w_i, np.full(len(w_i), m_val), w_v,
                                      data.p_star)
                try:
                    model = ConvergenceModel(self.library).fit(sub, cv_folds=3,
                                                               seed=seed)
                except Exception:
                    continue
                i_pred = its[t_idx + ahead]
                pred = float(model.predict(i_pred, m_val)[0])
                rows.append((i_pred, vals[t_idx + ahead], pred))
            if rows:
                results[int(m_val)] = np.asarray(rows)
        return results
