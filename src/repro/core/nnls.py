"""Non-negative least squares (Lawson–Hanson active set), sklearn/scipy-free.

Ernest fits its system model with NNLS so that every cost term contributes
non-negatively (computation, communication terms can only add time).
"""
from __future__ import annotations

import numpy as np


def nnls(A: np.ndarray, b: np.ndarray, max_iter: int | None = None,
         tol: float = 1e-10) -> np.ndarray:
    """Solve min ||Ax - b||_2 s.t. x >= 0.  Returns x."""
    A = np.asarray(A, dtype=np.float64)
    b = np.asarray(b, dtype=np.float64)
    m, n = A.shape
    if max_iter is None:
        max_iter = 3 * n + 30
    passive: list[int] = []
    x = np.zeros(n)
    w = A.T @ (b - A @ x)
    it = 0
    while True:
        active = [j for j in range(n) if j not in passive]
        if not active:
            break
        w = A.T @ (b - A @ x)
        w_active = {j: w[j] for j in active}
        j_best = max(w_active, key=w_active.get)
        if w_active[j_best] <= tol:
            break
        passive.append(j_best)
        while True:
            it += 1
            if it > max_iter:
                return x
            Ap = A[:, passive]
            s_p, *_ = np.linalg.lstsq(Ap, b, rcond=None)
            if np.all(s_p > tol):
                x = np.zeros(n)
                x[passive] = s_p
                break
            # step back toward feasibility
            xp = x[passive]
            neg = s_p <= tol
            with np.errstate(divide="ignore", invalid="ignore"):
                ratios = np.where(neg, xp / np.maximum(xp - s_p, 1e-30), np.inf)
            alpha = float(np.min(ratios))
            x_new = np.zeros(n)
            x_new[passive] = xp + alpha * (s_p - xp)
            x = np.clip(x_new, 0.0, None)
            passive = [j for j in passive if x[j] > tol]
            if not passive:
                break
    return x


def nnls_fit(X: np.ndarray, y: np.ndarray) -> tuple[np.ndarray, float]:
    """Fit y ~ X theta with theta >= 0; returns (theta, rmse)."""
    theta = nnls(X, y)
    resid = y - X @ theta
    rmse = float(np.sqrt(np.mean(resid ** 2)))
    return theta, rmse
