"""Feature library phi_j(i, m) for the convergence model g(i, m).

The paper (§3.2.2) fits log(P(i,m) - P*) with a linear model over
"fractional, polynomial, and logarithmic" features of (i, m).  Theoretical
rates motivate the library, e.g. CoCoA's (1 - c0/m)^i c1 gives
log-suboptimality ≈ i*log(1 - c0/m) + log c1 ≈ -c0 * (i/m) + log c1,
so `i/m` (and friends) must be present; Lasso picks the active subset.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Sequence, Tuple

import numpy as np

FeatureFn = Callable[[np.ndarray, np.ndarray], np.ndarray]

# name -> phi(i, m); i >= 1, m >= 1 expected (shifted inside for safety)
DEFAULT_FEATURES: Dict[str, FeatureFn] = {
    "i": lambda i, m: i,
    "i/m": lambda i, m: i / m,
    "i/m^2": lambda i, m: i / m ** 2,
    "i/sqrt(m)": lambda i, m: i / np.sqrt(m),
    "i*log(m+1)": lambda i, m: i * np.log(m + 1.0),
    "i*log(m+1)/m": lambda i, m: i * np.log(m + 1.0) / m,
    "log(i+1)": lambda i, m: np.log(i + 1.0),
    "sqrt(i)": lambda i, m: np.sqrt(i),
    "sqrt(i/m)": lambda i, m: np.sqrt(i / m),
    "1/i": lambda i, m: 1.0 / np.maximum(i, 1.0),
    "m": lambda i, m: m,
    "log(m+1)": lambda i, m: np.log(m + 1.0),
    "1/m": lambda i, m: 1.0 / m,
    "log(i+1)*log(m+1)": lambda i, m: np.log(i + 1.0) * np.log(m + 1.0),
    "1/(i/m+1)": lambda i, m: 1.0 / (i / m + 1.0),
}


@dataclasses.dataclass(frozen=True)
class FeatureLibrary:
    names: Tuple[str, ...] = tuple(DEFAULT_FEATURES)

    def __call__(self, i: np.ndarray, m: np.ndarray) -> np.ndarray:
        """(n,) iteration counts and machine counts -> (n, d) design matrix."""
        i = np.asarray(i, np.float64)
        m = np.asarray(m, np.float64)
        cols = [DEFAULT_FEATURES[n](i, m) for n in self.names]
        return np.stack(cols, axis=1)

    def subset(self, names: Sequence[str]) -> "FeatureLibrary":
        unknown = set(names) - set(DEFAULT_FEATURES)
        if unknown:
            raise KeyError(f"unknown features {unknown}")
        return FeatureLibrary(tuple(names))
