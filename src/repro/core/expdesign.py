"""Experiment design for cheap model fitting (§6 "Training time/resources").

Greedy cost-aware D-optimal selection over a candidate grid of (m, size)
configurations: repeatedly pick the candidate maximizing the information
gain per unit cost,

    argmax_c  [logdet(M + x_c x_c^T) - logdet(M)] / cost(c),

where M is the current information matrix of the Ernest design.  This is
the greedy analogue of Ernest's convex experiment-design program and keeps
the number of profiling runs (and machine-hours) small.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.ernest import ErnestModel


@dataclasses.dataclass(frozen=True)
class Candidate:
    m: int
    size: float

    def cost(self) -> float:
        # machine-hours proxy: m machines for time ~ size/m + overhead
        return self.m * (self.size / self.m + 1.0)


def greedy_d_optimal(
    candidates: Sequence[Candidate],
    budget: float,
    model: Optional[ErnestModel] = None,
    ridge: float = 1e-6,
    cost_fn: Optional[Callable[[Candidate], float]] = None,
) -> List[Candidate]:
    """Pick candidates until the cost budget is exhausted."""
    model = model or ErnestModel()
    cost_fn = cost_fn or (lambda c: c.cost())
    d = len(model.term_names)
    M = np.eye(d) * ridge
    chosen: List[Candidate] = []
    remaining = list(candidates)
    spent = 0.0
    sign, logdet = np.linalg.slogdet(M)
    while remaining:
        best_gain, best_idx = -np.inf, -1
        for idx, c in enumerate(remaining):
            cost = cost_fn(c)
            if spent + cost > budget:
                continue
            x = model.design(np.asarray([c.m]), np.asarray([c.size]))[0]
            _, new_logdet = np.linalg.slogdet(M + np.outer(x, x))
            gain = (new_logdet - logdet) / max(cost, 1e-9)
            if gain > best_gain:
                best_gain, best_idx = gain, idx
        if best_idx < 0:
            break
        c = remaining.pop(best_idx)
        x = model.design(np.asarray([c.m]), np.asarray([c.size]))[0]
        M += np.outer(x, x)
        _, logdet = np.linalg.slogdet(M)
        spent += cost_fn(c)
        chosen.append(c)
    return chosen


def default_candidate_grid(max_m: int = 64,
                           sizes: Tuple[float, ...] = (0.0125, 0.025, 0.05, 0.1)
                           ) -> List[Candidate]:
    """Ernest-style: small data fractions on small machine counts."""
    ms: List[int] = []
    m = 1
    while m <= max_m:
        ms.append(m)
        m *= 2
    return [Candidate(m=m, size=s) for m in ms for s in sizes]
