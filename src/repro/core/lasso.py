"""Lasso via cyclic coordinate descent + K-fold LassoCV (sklearn-free).

Solves  min_w  1/(2n) ||y - Xw - b||^2 + lam * ||w||_1
with an unpenalized intercept, on standardized features (the paper fits
log-suboptimality with scikit-learn's LassoCV; this is a drop-in offline
replacement, unit-tested against closed forms).

The descent works on the Gram matrix (G = X'X/n, c = X'y/n) with O(d)
coordinate updates and warm-started lambda paths, so the CV grid costs a
handful of sweeps instead of thousands — this is the hot path of the
adaptive controller, which refits the convergence model on a trailing
window every few steps of a live run (repro.core.adaptive / §6).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import numpy as np


def _soft(x: np.ndarray, t: float) -> np.ndarray:
    return np.sign(x) * np.maximum(np.abs(x) - t, 0.0)


@dataclasses.dataclass
class LassoFit:
    coef: np.ndarray        # in original (unstandardized) feature space
    intercept: float
    lam: float
    n_iter: int
    # standardization stats (kept for diagnostics)
    x_mean: np.ndarray
    x_scale: np.ndarray

    def predict(self, X: np.ndarray) -> np.ndarray:
        return X @ self.coef + self.intercept


def _standardize(X: np.ndarray, y: np.ndarray):
    x_mean = X.mean(0)
    x_scale = X.std(0)
    x_scale[x_scale < 1e-12] = 1.0
    Xs = (X - x_mean) / x_scale
    y_mean = y.mean()
    return Xs, y - y_mean, x_mean, x_scale, float(y_mean)


def _cd_solve(G: np.ndarray, c: np.ndarray, lam: float, w: np.ndarray,
              max_iter: int, tol: float) -> Tuple[np.ndarray, int]:
    """Cyclic coordinate descent on the Gram system; ``w`` is updated in
    place and returned.  Each coordinate update is O(d) via the cached
    gradient ``Gw`` — independent of the number of observations."""
    d = len(c)
    col_sq = np.diagonal(G).copy()
    Gw = G @ w
    it = 0
    for it in range(1, max_iter + 1):
        w_max_delta = 0.0
        for j in range(d):
            cj = col_sq[j]
            if cj == 0.0:
                continue
            wj_old = w[j]
            rho = c[j] - Gw[j] + cj * wj_old
            mag = abs(rho) - lam
            wj_new = (mag / cj if rho > 0.0 else -mag / cj) if mag > 0.0 \
                else 0.0
            if wj_new != wj_old:
                delta = wj_new - wj_old
                Gw += G[:, j] * delta
                w[j] = wj_new
                if abs(delta) > w_max_delta:
                    w_max_delta = abs(delta)
        if w_max_delta < tol:
            break
    return w, it


def lasso_fit(X: np.ndarray, y: np.ndarray, lam: float,
              max_iter: int = 2000, tol: float = 1e-8,
              w0: Optional[np.ndarray] = None) -> LassoFit:
    X = np.asarray(X, np.float64)
    y = np.asarray(y, np.float64)
    n, d = X.shape
    Xs, yc, x_mean, x_scale, y_mean = _standardize(X, y)
    G = (Xs.T @ Xs) / n
    c = (Xs.T @ yc) / n
    w = np.zeros(d) if w0 is None else np.asarray(w0, np.float64).copy()
    w, it = _cd_solve(G, c, lam, w, max_iter, tol)
    coef = w / x_scale
    intercept = float(y_mean - x_mean @ coef)
    return LassoFit(coef=coef, intercept=intercept, lam=lam, n_iter=it,
                    x_mean=x_mean, x_scale=x_scale)


def lambda_grid(X: np.ndarray, y: np.ndarray, n: int = 30,
                eps: float = 1e-4) -> np.ndarray:
    Xs = (X - X.mean(0))
    scale = Xs.std(0)
    scale[scale < 1e-12] = 1.0
    Xs = Xs / scale
    yc = y - y.mean()
    lam_max = float(np.max(np.abs(Xs.T @ yc)) / len(y))
    lam_max = max(lam_max, 1e-12)
    return np.geomspace(lam_max, lam_max * eps, n)


def lasso_cv(X: np.ndarray, y: np.ndarray, k: int = 5,
             lams: Optional[Sequence[float]] = None,
             seed: int = 0, max_iter: int = 1000) -> LassoFit:
    """K-fold cross-validated Lasso (mirrors sklearn LassoCV).

    The lambda grid runs from large to small and each fold's fits are
    warm-started along the path, so the whole CV costs a few dozen sweeps."""
    X = np.asarray(X, np.float64)
    y = np.asarray(y, np.float64)
    n = len(y)
    if lams is None:
        lams = lambda_grid(X, y)
    k = min(k, n)
    rng = np.random.RandomState(seed)
    idx = rng.permutation(n)
    folds = np.array_split(idx, k)
    errs = np.zeros(len(lams))
    for fi in range(k):
        test = folds[fi]
        train = np.concatenate([folds[fj] for fj in range(k) if fj != fi])
        Xtr, ytr = X[train], y[train]
        ntr, d = Xtr.shape
        Xs, yc, x_mean, x_scale, y_mean = _standardize(Xtr, ytr)
        G = (Xs.T @ Xs) / ntr
        c = (Xs.T @ yc) / ntr
        w = np.zeros(d)
        for li, lam in enumerate(lams):      # descending: warm starts help
            w, _ = _cd_solve(G, c, float(lam), w, max_iter, 1e-8)
            coef = w / x_scale
            intercept = y_mean - x_mean @ coef
            pred = X[test] @ coef + intercept
            errs[li] += float(np.mean((pred - y[test]) ** 2))
    best = int(np.argmin(errs))
    return lasso_fit(X, y, float(lams[best]), max_iter=2 * max_iter)


def r2_score(y_true: np.ndarray, y_pred: np.ndarray) -> float:
    y_true = np.asarray(y_true, np.float64)
    y_pred = np.asarray(y_pred, np.float64)
    ss_res = float(np.sum((y_true - y_pred) ** 2))
    ss_tot = float(np.sum((y_true - y_true.mean()) ** 2))
    if ss_tot == 0.0:
        return 1.0 if ss_res == 0.0 else 0.0
    return 1.0 - ss_res / ss_tot
