"""Adaptive parallelism controller (§6 "Adaptive algorithms").

During a training run the controller ingests (iteration, m, objective)
observations, periodically refits the convergence model on a trailing
window, and — combined with the Ernest system model and a re-shard cost —
recommends growing/shrinking the data-parallel degree.  The elastic trainer
(repro.runtime.elastic) executes the recommendation by re-sharding onto a
new mesh from the latest checkpoint.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np

from repro.core.convergence import ConvergenceData, ConvergenceModel
from repro.core.ernest import ErnestModel
from repro.core.features import FeatureLibrary


@dataclasses.dataclass
class Observation:
    iteration: int
    m: int
    value: float


@dataclasses.dataclass
class ResizeDecision:
    resize: bool
    target_m: int
    reason: str
    predicted_remaining_current: Optional[float] = None
    predicted_remaining_target: Optional[float] = None


class AdaptiveController:
    def __init__(
        self,
        system: ErnestModel,
        *,
        target_gap: float,
        p_star: float,
        m_options: Sequence[int],
        data_size: float = 1.0,
        refit_every: int = 25,
        window: int = 200,
        reshard_cost_s: float = 30.0,
        min_observations: int = 30,
        library: Optional[FeatureLibrary] = None,
        hysteresis: float = 0.9,
    ):
        self.system = system
        self.target_gap = target_gap
        self.p_star = p_star
        self.m_options = sorted(set(int(m) for m in m_options))
        self.data_size = data_size
        self.refit_every = refit_every
        self.window = window
        self.reshard_cost_s = reshard_cost_s
        self.min_observations = min_observations
        self.library = library or FeatureLibrary()
        self.hysteresis = hysteresis
        self.observations: List[Observation] = []
        self.model: Optional[ConvergenceModel] = None
        self._since_refit = 0
        self.decisions: List[ResizeDecision] = []

    # ------------------------------------------------------------------
    def set_m_options(self, m_options: Sequence[int]) -> None:
        """Replace the candidate cluster sizes (elastic capacity changed)."""
        self.m_options = sorted(set(int(m) for m in m_options))

    # ------------------------------------------------------------------
    def observe(self, iteration: int, m: int, value: float) -> Optional[ResizeDecision]:
        self.observations.append(Observation(iteration, m, value))
        self._since_refit += 1
        if (len(self.observations) < self.min_observations
                or self._since_refit < self.refit_every):
            return None
        self._since_refit = 0
        self._refit()
        return self._decide(iteration, m, value)

    # ------------------------------------------------------------------
    def _refit(self) -> None:
        obs = self.observations[-self.window:]
        data = ConvergenceData(
            i=np.asarray([o.iteration for o in obs], np.float64),
            m=np.asarray([o.m for o in obs], np.float64),
            value=np.asarray([o.value for o in obs], np.float64),
            p_star=self.p_star,
        )
        try:
            self.model = ConvergenceModel(self.library).fit(data, cv_folds=3)
        except Exception:
            self.model = None

    def _remaining_time(self, now_iter: int, now_value: float, m: int) -> Optional[float]:
        """Predicted seconds until gap <= target on m machines, from now."""
        assert self.model is not None
        f_m = float(self.system.predict(m, self.data_size))
        # find iterations needed (on m machines) for predicted gap <= target
        lo, hi = now_iter + 1, now_iter + 200_000

        def pred_gap(i: int) -> float:
            # a non-monotone or degenerate fit can predict exploding gaps;
            # treat any non-finite prediction as "never reaches the target"
            with np.errstate(over="ignore", invalid="ignore"):
                g = float(self.model.predict(
                    np.asarray([i], np.float64), m)[0] - self.p_star)
            return g if np.isfinite(g) else np.inf

        if pred_gap(hi) > self.target_gap:
            return None
        while hi - lo > 1:
            mid = (lo + hi) // 2
            if pred_gap(mid) <= self.target_gap:
                hi = mid
            else:
                lo = mid
        return (hi - now_iter) * f_m

    def _decide(self, iteration: int, m: int, value: float) -> Optional[ResizeDecision]:
        if self.model is None:
            return None
        current = self._remaining_time(iteration, value, m)
        best_m, best_t = m, current
        for m_opt in self.m_options:
            if m_opt == m:
                continue
            t = self._remaining_time(iteration, value, m_opt)
            if t is None:
                continue
            t_total = t + self.reshard_cost_s
            if best_t is None or t_total < (best_t if best_m != m
                                            else best_t * self.hysteresis):
                best_m, best_t = m_opt, t_total
        if best_m != m:
            d = ResizeDecision(
                resize=True, target_m=best_m,
                reason=f"predicted remaining {best_t:.1f}s on m={best_m} vs "
                       f"{'inf' if current is None else f'{current:.1f}s'} on m={m}",
                predicted_remaining_current=current,
                predicted_remaining_target=best_t)
        else:
            d = ResizeDecision(resize=False, target_m=m, reason="stay",
                               predicted_remaining_current=current)
        self.decisions.append(d)
        return d
