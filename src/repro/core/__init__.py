"""Hemingway's contribution: system model + convergence model + planner."""
from repro.core.adaptive import AdaptiveController, ResizeDecision
from repro.core.convergence import ConvergenceData, ConvergenceModel
from repro.core.ernest import ErnestModel
from repro.core.expdesign import Candidate, default_candidate_grid, greedy_d_optimal
from repro.core.features import FeatureLibrary
from repro.core.hemingway import (
    CombinedModel,
    NoFeasiblePlan,
    PlanDecision,
    Planner,
)
from repro.core.lasso import LassoFit, lasso_cv, lasso_fit, r2_score
from repro.core.nnls import nnls, nnls_fit

__all__ = [
    "AdaptiveController",
    "Candidate",
    "CombinedModel",
    "ConvergenceData",
    "ConvergenceModel",
    "ErnestModel",
    "FeatureLibrary",
    "LassoFit",
    "NoFeasiblePlan",
    "PlanDecision",
    "Planner",
    "ResizeDecision",
    "default_candidate_grid",
    "greedy_d_optimal",
    "lasso_cv",
    "lasso_fit",
    "nnls",
    "nnls_fit",
    "r2_score",
]
