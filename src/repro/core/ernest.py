"""Ernest system model f(m): time per BSP iteration vs machine count.

    f(m) = th0 + th1 * (size/m) + th2 * log(m) + th3 * m   (+ optional terms)

fit with NNLS (all terms contribute non-negative time), exactly as in
Ernest [NSDI'16] / Hemingway §3.2.1.  Extra terms cover second-order methods
(superlinear compute) and all-to-all collectives.

On this CPU-only container the "measured" response can be wall-clock (for
the convex BSP simulator) or the dry-run roofline step-time (for the LM
meshes); the model is agnostic — see DESIGN.md §3.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Sequence, Tuple

import numpy as np

from repro.core.nnls import nnls

TermFn = Callable[[np.ndarray, np.ndarray], np.ndarray]  # (m, size) -> value

TERMS: Dict[str, TermFn] = {
    "const": lambda m, size: np.ones_like(m, dtype=np.float64),
    "size_over_m": lambda m, size: size / m,
    "log_m": lambda m, size: np.log(m + 1.0),
    "m": lambda m, size: m.astype(np.float64),
    # extensions (§3.2.1 last paragraph)
    "m^2": lambda m, size: m.astype(np.float64) ** 2,
    "size_over_sqrt_m": lambda m, size: size / np.sqrt(m),
    "size": lambda m, size: size.astype(np.float64),
    "sqrt_m": lambda m, size: np.sqrt(m),
}

DEFAULT_TERMS: Tuple[str, ...] = ("const", "size_over_m", "log_m", "m")


@dataclasses.dataclass
class ErnestModel:
    term_names: Tuple[str, ...] = DEFAULT_TERMS
    theta: np.ndarray | None = None

    def design(self, m: np.ndarray, size: np.ndarray) -> np.ndarray:
        m = np.asarray(m, np.float64)
        size = np.asarray(size, np.float64)
        return np.stack([TERMS[t](m, size) for t in self.term_names], axis=1)

    def fit(self, m: Sequence[float], size: Sequence[float],
            time: Sequence[float]) -> "ErnestModel":
        X = self.design(np.asarray(m), np.asarray(size))
        self.theta = nnls(X, np.asarray(time, np.float64))
        return self

    def predict(self, m, size) -> np.ndarray:
        assert self.theta is not None, "call fit() first"
        scalar = np.isscalar(m)
        m_arr = np.atleast_1d(np.asarray(m, np.float64))
        s_arr = np.broadcast_to(np.asarray(size, np.float64), m_arr.shape)
        out = self.design(m_arr, s_arr) @ self.theta
        return float(out[0]) if scalar else out

    def percent_errors(self, m, size, time) -> np.ndarray:
        pred = self.predict(np.asarray(m), np.asarray(size))
        time = np.asarray(time, np.float64)
        return np.abs(pred - time) / np.maximum(np.abs(time), 1e-12) * 100.0

    def coefficients(self) -> Dict[str, float]:
        assert self.theta is not None
        return dict(zip(self.term_names, map(float, self.theta)))
