"""Hemingway: h(t, m) = g(t / f(m), m) — combined model + planner (§3.1).

Answers the paper's two query types over a registry of candidate algorithms:
  * ``fastest_to_epsilon``: given error target eps, pick (algorithm, m)
    minimizing wall-clock time
  * ``best_within_budget``: given a latency budget, pick (algorithm, m)
    minimizing the achieved objective
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.convergence import ConvergenceModel
from repro.core.ernest import ErnestModel


@dataclasses.dataclass
class CombinedModel:
    """One algorithm's (system, convergence) model pair."""

    system: ErnestModel
    convergence: ConvergenceModel
    data_size: float = 1.0
    max_iters: int = 100_000

    def h(self, t, m) -> np.ndarray:
        """Objective value at wall-clock time t on m machines."""
        t = np.atleast_1d(np.asarray(t, np.float64))
        f_m = max(float(self.system.predict(m, self.data_size)), 1e-12)
        iters = np.maximum(t / f_m, 1.0)
        return self.convergence.predict(iters, float(m))

    def iters_to_epsilon(self, eps: float, m: int) -> Optional[int]:
        """Smallest i with predicted gap <= eps.  Fitted g's need not be
        monotone far outside the data, so scan a geometric iteration grid
        for the first crossing, then refine by bisection on that bracket."""
        grid = np.unique(np.geomspace(1, self.max_iters, 256).astype(int))
        gaps = self.convergence.predict(grid.astype(np.float64), m) \
            - self.convergence.p_star
        below = np.nonzero(gaps <= eps)[0]
        if len(below) == 0:
            return None
        j = below[0]
        if j == 0:
            return int(grid[0])
        lo, hi = int(grid[j - 1]), int(grid[j])
        gap = lambda i: float(
            self.convergence.predict(np.asarray([i], np.float64), m)[0]
            - self.convergence.p_star)
        while hi - lo > 1:
            mid = (lo + hi) // 2
            if gap(mid) <= eps:
                hi = mid
            else:
                lo = mid
        return hi

    def time_to_epsilon(self, eps: float, m: int) -> Optional[float]:
        iters = self.iters_to_epsilon(eps, m)
        if iters is None:
            return None
        return iters * float(self.system.predict(m, self.data_size))


@dataclasses.dataclass
class PlanDecision:
    algorithm: str
    m: int
    predicted_time: Optional[float] = None
    predicted_value: Optional[float] = None
    table: Optional[Dict[Tuple[str, int], float]] = None


@dataclasses.dataclass
class NoFeasiblePlan:
    """Typed infeasibility result for the planner queries.

    Returned (not raised) when no (algorithm, m) satisfies the query, so
    callers that schedule many workloads — the fleet scheduler above all —
    can treat "this job cannot be satisfied" as data: record the reason,
    queue or reject the workload, and keep planning the rest of the fleet.
    ``table`` carries whatever partial predictions were computed, the same
    shape as ``PlanDecision.table``.
    """

    query: str
    reason: str
    table: Optional[Dict[Tuple[str, int], float]] = None

    def __bool__(self) -> bool:   # `if plan:` reads as "is it feasible?"
        return False


PlanResult = Union[PlanDecision, NoFeasiblePlan]


class Planner:
    """The ML-optimizer front end (Fig 2)."""

    def __init__(self, models: Dict[str, CombinedModel]):
        self.models = dict(models)

    def fastest_to_epsilon(self, eps: float,
                           m_grid: Sequence[int]) -> PlanResult:
        table: Dict[Tuple[str, int], float] = {}
        best: Optional[PlanDecision] = None
        for name, model in self.models.items():
            for m in m_grid:
                t = model.time_to_epsilon(eps, int(m))
                if t is None:
                    continue
                table[(name, int(m))] = t
                if best is None or t < best.predicted_time:
                    best = PlanDecision(name, int(m), predicted_time=t)
        if best is None:
            return NoFeasiblePlan(
                query="fastest_to_epsilon",
                reason=f"no (algorithm, m) reaches eps={eps} within "
                       f"max_iters over {len(self.models)} model(s), "
                       f"m_grid={list(m_grid)}",
                table=table)
        best.table = table
        return best

    def best_within_budget(self, t_budget: float,
                           m_grid: Sequence[int]) -> PlanResult:
        table: Dict[Tuple[str, int], float] = {}
        best: Optional[PlanDecision] = None
        for name, model in self.models.items():
            for m in m_grid:
                v = float(model.h(t_budget, int(m))[0])
                table[(name, int(m))] = v
                if not np.isfinite(v):
                    continue
                if best is None or v < best.predicted_value:
                    best = PlanDecision(name, int(m), predicted_value=v)
        if best is None:
            return NoFeasiblePlan(
                query="best_within_budget",
                reason=f"no finite prediction within budget {t_budget}s "
                       f"({len(self.models)} model(s), m_grid={list(m_grid)})",
                table=table)
        best.table = table
        return best
