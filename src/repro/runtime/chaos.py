"""Deterministic fault-injecting cluster simulator + the closed control loop.

Hemingway §6 argues the system must *adapt during a run*: refit the
convergence and Ernest models online and resize the cluster.  This module
composes the previously-passive pieces — ``StragglerMonitor``,
``FailureInjector``, ``AdaptiveController``, the elastic re-shard path —
into one production-shaped loop, driven by a **replayable event trace**:

    ChaosTrace (seeded events) ──► ClusterSim (per-host speed state)
        │ simulated BSP step times / preemptions
        ▼
    StragglerMonitor ──mitigations──►┐
    FailureInjector  ──restores────► ChaosLoop ──► executor (SSP local-SGD
    AdaptiveController ─ResizeDecision─┘            or the LM Trainer)

Every step of the run (events, mitigations, decisions, objective, m, H,
wall-clock) is appended to a ``ChaosRunLog`` that serializes to JSON.  The
loop draws NO entropy of its own: given the same trace and executor seed it
replays **bit-identically**, which is what makes the adaptive layer
testable — golden run logs are regression tests (tests/test_chaos.py).

Event kinds (all drawn by ``ChaosTrace.generate`` from one ``random.Random``
seed, or hand-written / loaded from JSON):

  * ``straggler_on``  — host's speed multiplier jumps to ``magnitude`` for
                        ``duration`` steps (auto ``straggler_off``)
  * ``straggler_off`` — explicit recovery
  * ``slowdown``      — cluster-wide transient multiplier (network weather);
                        NOT a straggler: every host slows together
  * ``preempt``       — host killed; surfaces as ``SimulatedFailure`` through
                        the FailureInjector, the loop restores from the last
                        checkpoint and the host returns fresh
  * ``leave`` / ``join`` — capacity shrinks/grows; the controller's m options
                        are re-clamped, and a run above capacity is forced
                        down through the same resize path
"""
from __future__ import annotations

import dataclasses
import json
import math
import random
from pathlib import Path
from typing import Any, Dict, List, Optional, Sequence

from repro.core.adaptive import AdaptiveController
from repro.runtime.failures import FailureInjector, SimulatedFailure
from repro.runtime.straggler import StragglerMonitor
from repro.telemetry import (
    Event,
    MemorySink,
    RunMeta,
    Tracker,
    from_legacy,
    read_events,
    warn_deprecated,
)
from repro.telemetry.refit import StreamingCost, StreamingErnest

EVENT_KINDS = ("straggler_on", "straggler_off", "slowdown", "preempt",
               "join", "leave")


# ---------------------------------------------------------------------------
# Event trace
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ChaosEvent:
    step: int
    kind: str
    host: int = -1             # -1: cluster-wide (slowdown)
    magnitude: float = 1.0     # speed multiplier (>1 = slower)
    duration: int = 0          # steps until auto-recovery (0 = until event)

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "ChaosEvent":
        return cls(step=int(d["step"]), kind=str(d["kind"]),
                   host=int(d.get("host", -1)),
                   magnitude=float(d.get("magnitude", 1.0)),
                   duration=int(d.get("duration", 0)))


@dataclasses.dataclass
class ChaosTrace:
    """A replayable schedule of cluster events."""

    seed: int
    n_hosts: int
    steps: int
    events: List[ChaosEvent] = dataclasses.field(default_factory=list)

    # ------------------------------------------------------------------
    @classmethod
    def generate(cls, seed: int, steps: int, n_hosts: int, *,
                 p_straggler: float = 0.03, p_slowdown: float = 0.015,
                 p_preempt: float = 0.008, p_membership: float = 0.004,
                 warmup: int = 20) -> "ChaosTrace":
        """Draw a deterministic event schedule from one PRNG seed.

        ``warmup`` keeps the first steps quiet so the monitor can establish
        a baseline before anything goes wrong."""
        rng = random.Random(seed)
        events: List[ChaosEvent] = []
        busy_until = [0] * n_hosts   # one outstanding fault per host
        for step in range(warmup, steps):
            r = rng.random()
            host = rng.randrange(n_hosts)
            if r < p_straggler:
                if busy_until[host] <= step:
                    dur = rng.randint(6, 20)
                    events.append(ChaosEvent(step, "straggler_on", host,
                                             magnitude=rng.uniform(1.6, 6.0),
                                             duration=dur))
                    busy_until[host] = step + dur
            elif r < p_straggler + p_slowdown:
                events.append(ChaosEvent(step, "slowdown", -1,
                                         magnitude=rng.uniform(1.3, 2.0),
                                         duration=rng.randint(3, 8)))
            elif r < p_straggler + p_slowdown + p_preempt:
                if busy_until[host] <= step:
                    events.append(ChaosEvent(step, "preempt", host))
                    busy_until[host] = step + 1
            elif r < p_straggler + p_slowdown + p_preempt + p_membership:
                kind = "leave" if rng.random() < 0.5 else "join"
                events.append(ChaosEvent(step, kind, host))
        return cls(seed=seed, n_hosts=n_hosts, steps=steps, events=events)

    # ------------------------------------------------------------------
    def to_json(self) -> Dict[str, Any]:
        return {"seed": self.seed, "n_hosts": self.n_hosts,
                "steps": self.steps,
                "events": [e.to_dict() for e in self.events]}

    @classmethod
    def from_json(cls, d: Dict[str, Any]) -> "ChaosTrace":
        return cls(seed=int(d["seed"]), n_hosts=int(d["n_hosts"]),
                   steps=int(d["steps"]),
                   events=[ChaosEvent.from_dict(e) for e in d["events"]])

    def save(self, path) -> None:
        Path(path).write_text(json.dumps(self.to_json(), indent=1))

    @classmethod
    def load(cls, path) -> "ChaosTrace":
        return cls.from_json(json.loads(Path(path).read_text()))


# ---------------------------------------------------------------------------
# Cluster state machine
# ---------------------------------------------------------------------------
class ClusterSim:
    """Replays a ChaosTrace into per-host speed state + BSP step times.

    Wall-clock composition matches DESIGN.md §3 / simcluster.CommModel:
    compute scales 1/m but runs at the pace of the slowest *synchronizing*
    host; mitigation hooks (``rebalance``, ``hot_spare``) change per-host
    shard weights / multipliers exactly the way the real driver actions
    would."""

    def __init__(self, trace: ChaosTrace, comm=None):
        from repro.optim.simcluster import CommModel
        self.trace = trace
        self.comm = comm or CommModel()
        self.speed: Dict[int, float] = {h: 1.0 for h in range(trace.n_hosts)}
        self.shard_weight: Dict[int, float] = dict.fromkeys(self.speed, 1.0)
        self.slowdown: float = 1.0
        # (kind, host) -> expire step; keyed so an overlapping newer event
        # EXTENDS the fault instead of being cancelled by the older expiry
        self._expiry: Dict[tuple, int] = {}
        self._by_step: Dict[int, List[ChaosEvent]] = {}
        for ev in trace.events:
            self._by_step.setdefault(ev.step, []).append(ev)
        self._next_host = trace.n_hosts

    # ------------------------------------------------------------------
    @property
    def capacity(self) -> int:
        return len(self.speed)

    def hosts(self) -> List[int]:
        return sorted(self.speed)

    # ------------------------------------------------------------------
    def advance(self, step: int) -> List[ChaosEvent]:
        """Apply expirations + this step's events; returns applied events."""
        for key, exp_step in list(self._expiry.items()):
            if exp_step <= step:
                kind, host = key
                if kind == "straggler_on" and host in self.speed:
                    self.speed[host] = 1.0
                elif kind == "slowdown":
                    self.slowdown = 1.0
                del self._expiry[key]

        applied = []
        for ev in self._by_step.get(step, []):
            if ev.kind == "straggler_on":
                if ev.host not in self.speed:
                    continue
                self.speed[ev.host] = ev.magnitude
                if ev.duration:
                    self._expiry[(ev.kind, ev.host)] = step + ev.duration
                else:   # persists until straggler_off: drop any old expiry
                    self._expiry.pop((ev.kind, ev.host), None)
            elif ev.kind == "straggler_off":
                if ev.host in self.speed:
                    self.speed[ev.host] = 1.0
                    self._expiry.pop(("straggler_on", ev.host), None)
            elif ev.kind == "slowdown":
                self.slowdown = ev.magnitude
                if ev.duration:
                    self._expiry[(ev.kind, -1)] = step + ev.duration
                else:
                    self._expiry.pop((ev.kind, -1), None)
            elif ev.kind == "preempt":
                if ev.host not in self.speed:
                    continue
                # host comes back fresh after the restore the loop performs
                self.speed[ev.host] = 1.0
                self.shard_weight[ev.host] = 1.0
            elif ev.kind == "leave":
                if self.capacity > 1 and ev.host in self.speed:
                    del self.speed[ev.host]
                    del self.shard_weight[ev.host]
                else:
                    continue
            elif ev.kind == "join":
                h = self._next_host
                self._next_host += 1
                self.speed[h] = 1.0
                self.shard_weight[h] = 1.0
            applied.append(ev)
        return applied

    # ------------------------------------------------------------------
    def assigned_hosts(self, m: int) -> List[int]:
        """BSP workers run on the first m live hosts (stable order)."""
        return self.hosts()[:m]

    def host_times(self, m: int, base_compute_s: float) -> Dict[int, float]:
        """Per-host compute seconds this step (before the barrier)."""
        out = {}
        for h in self.assigned_hosts(m):
            out[h] = (base_compute_s / m * self.speed[h]
                      * self.shard_weight[h] * self.slowdown)
        return out

    def step_time(self, m: int, base_compute_s: float, d: int,
                  sync_mask: Optional[Dict[int, bool]] = None) -> float:
        """BSP barrier time: slowest synchronizing host + comm model.

        Hosts excluded from the barrier by SSP relaxation (sync_mask False)
        do not hold up the step."""
        times = self.host_times(m, base_compute_s)
        syncing = [t for h, t in times.items()
                   if sync_mask is None or sync_mask.get(h, True)]
        compute = max(syncing) if syncing else max(times.values())
        return compute + self.comm.iteration_comm(m, 4.0 * d) * self.slowdown

    # ------------------------------------------------------------------
    # Mitigation hooks (the real driver actions, simulated)
    # ------------------------------------------------------------------
    def rebalance(self, host: int) -> None:
        """Shrink the slow host's shard so its step time renormalizes."""
        if host in self.speed and self.speed[host] > 0:
            self.shard_weight[host] = 1.0 / self.speed[host]

    def hot_spare(self, host: int) -> None:
        """Swap the slow host for a fresh standby."""
        if host in self.speed:
            self.speed[host] = 1.0
            self.shard_weight[host] = 1.0


# ---------------------------------------------------------------------------
# Run log (the replayable output artifact)
# ---------------------------------------------------------------------------
class ChaosRunLog:
    """Replayable run artifact: a view over a telemetry ``Tracker``.

    ``append(**row)`` adapts the legacy row shape into a typed
    ``ChaosStepEvent`` and emits it on the tracker; the ``rows`` property
    reconstructs the legacy dicts bit-for-bit, so golden fixtures and the
    ``to_json``/``from_json`` wire format are unchanged.  Drift/refit
    events from the streaming-model layer land on the *same* tracker but
    are kind-filtered out of ``rows`` (and hence out of signatures)."""

    EVENT_KIND = "chaos_step"
    LOG_TYPE = "chaos"

    def __init__(self, trace: ChaosTrace,
                 rows: Optional[List[Dict[str, Any]]] = None,
                 meta: Optional[Dict[str, Any]] = None,
                 tracker: Optional[Tracker] = None):
        self.trace = trace
        self.meta: Dict[str, Any] = dict(meta) if meta else {}
        self.tracker = tracker if tracker is not None else Tracker([MemorySink()])
        for row in rows or []:
            self.append(**row)

    def append(self, **row) -> None:
        self.tracker.emit(from_legacy(self.EVENT_KIND, row))

    def emit(self, event: Event) -> Event:
        """Emit a non-row event (drift, refit, ...) onto the run's bus."""
        return self.tracker.emit(event)

    # ------------------------------------------------------------------
    @property
    def rows(self) -> List[Dict[str, Any]]:
        return [e.to_legacy() for e in self.tracker.events(self.EVENT_KIND)]

    def events(self, kind: Optional[str] = None) -> List[Event]:
        """Typed events on the run's bus (all kinds unless filtered)."""
        return self.tracker.events(kind)

    # ------------------------------------------------------------------
    def signature(self) -> List[tuple]:
        """The (m, objective, decision) sequence replay must reproduce."""
        return [(r["m"], r["objective"],
                 r.get("decision"), r.get("mitigation")) for r in self.rows]

    def n_mitigations(self) -> int:
        return sum(1 for r in self.rows if r.get("mitigation"))

    def n_resizes(self) -> int:
        return sum(1 for r in self.rows
                   if r.get("decision", "").startswith("resize"))

    def final_wall_clock(self) -> float:
        warn_deprecated(f"{type(self).__name__}.final_wall_clock()",
                        'events("chaos_step")[-1].wall_s')
        rows = self.rows
        return rows[-1]["wall_s"] if rows else 0.0

    # ------------------------------------------------------------------
    def to_json(self) -> Dict[str, Any]:
        return {"trace": self.trace.to_json(), "meta": self.meta,
                "rows": self.rows}

    @classmethod
    def from_json(cls, d: Dict[str, Any]) -> "ChaosRunLog":
        return cls(trace=ChaosTrace.from_json(d["trace"]),
                   rows=list(d["rows"]), meta=dict(d.get("meta", {})))

    def save(self, path) -> None:
        Path(path).write_text(json.dumps(self.to_json(), indent=1))

    @classmethod
    def load(cls, path) -> "ChaosRunLog":
        return cls.from_json(json.loads(Path(path).read_text()))

    # ------------------------------------------------------------------
    def to_jsonl(self, path) -> int:
        """Dump the full event stream (with a ``run_meta`` header row that
        makes the file self-contained for replay) as JSONL."""
        header = RunMeta(log_type=self.LOG_TYPE, trace=self.trace.to_json(),
                         meta=dict(self.meta))
        return self.tracker.to_jsonl(path, header=header)

    @classmethod
    def from_jsonl(cls, path) -> "ChaosRunLog":
        events = read_events(path)
        if not events or events[0].kind != "run_meta":
            raise ValueError(f"{path}: missing run_meta header row")
        header = events[0]
        log = cls(trace=ChaosTrace.from_json(header.trace),
                  meta=dict(header.meta))
        for e in events[1:]:
            log.tracker.emit(e)
        return log


# ---------------------------------------------------------------------------
# The closed loop
# ---------------------------------------------------------------------------
class ChaosLoop:
    """Drives an executor through a ChaosTrace under closed-loop control.

    The executor contract (duck-typed; see ``optim.simcluster.SSPLocalSGD``
    and ``launch.train.TrainerExecutor``):

      * ``m`` (int attribute) — current data-parallel degree
      * ``outer_step(sync_mask: Dict[host, bool]) -> float`` — one outer
        iteration, returns the objective (primal value / train loss)
      * ``resize(m) -> None``      — re-shard to m workers (from checkpoint)
      * ``relax(local_steps) -> None`` — sync_relax mitigation: switch to
        H local steps between syncs (staleness-aware local-SGD)
      * ``checkpoint() -> None`` / ``restore() -> None``

    All wall-clock is *modeled* (ClusterSim + costs below); all trajectory
    is *real* (the executor actually optimizes).  Determinism: the loop adds
    no entropy, so one (trace, executor seed) pair fixes the whole run.
    """

    def __init__(self, sim: ClusterSim, executor,
                 controller: AdaptiveController,
                 monitor: Optional[StragglerMonitor] = None,
                 injector: Optional[FailureInjector] = None, *,
                 base_compute_s: float = 1.0, d: int = 32,
                 ckpt_every: int = 10, restore_cost_s: float = 5.0,
                 relax_local_steps: int = 2, staleness_bound: int = 4,
                 system_refit: Optional[StreamingErnest] = None,
                 measured_costs: Optional[StreamingCost] = None):
        self.sim = sim
        self.executor = executor
        self.controller = controller
        self.monitor = monitor or StragglerMonitor(consecutive=3,
                                                   min_ratio=1.5)
        self.injector = injector or FailureInjector()
        self.base_compute_s = base_compute_s
        self.d = d
        self.ckpt_every = ckpt_every
        self.restore_cost_s = restore_cost_s
        self.relax_local_steps = relax_local_steps
        self.staleness_bound = staleness_bound
        # opt-in streaming f(m) refit: feed measured step times to a
        # StreamingErnest wrapping the controller's own system model (fit()
        # mutates in place, so refits flow straight into resize planning);
        # drift/refit events land on the run log's bus, not in its rows
        self.system_refit = system_refit
        # opt-in measured recovery costs: when set AND the executor reports
        # real restore/re-shard wall-times (duck-typed ``last_recovery_s``,
        # e.g. launch.train.TrainerExecutor reading its CheckpointManager's
        # timings), the loop charges the measured cost instead of the
        # assumed constant and feeds it to the estimator — once the refit
        # fires, the learned cost also replaces the controller's
        # ``reshard_cost_s`` in resize planning.  Default off: the golden
        # convex runs keep their assumed-constant wall model bit-identical.
        self.measured_costs = measured_costs
        self._base_m_options = list(controller.m_options)
        self._relaxed: Dict[int, int] = {}   # host -> step relaxation began
        self.wall_s = 0.0

    # ------------------------------------------------------------------
    def _sync_mask(self, step: int) -> Dict[int, bool]:
        """SSP: relaxed hosts sit out the barrier except every B-th step."""
        mask = {}
        for h in self.sim.assigned_hosts(self.executor.m):
            began = self._relaxed.get(h)
            if began is None:
                mask[h] = True
            else:
                mask[h] = (step - began) % self.staleness_bound == 0
        return mask

    def _clamp_m_options(self) -> List[int]:
        opts = [o for o in self._base_m_options if o <= self.sim.capacity]
        if not opts:
            opts = [1]
        self.controller.set_m_options(opts)
        return opts

    def _recovery_cost_s(self, step: int, op: str, log: ChaosRunLog) -> float:
        """The wall-clock a restore/re-shard costs this run: the executor's
        measured wall time when measured-cost feedback is on (and the
        executor reports one), the assumed constant otherwise."""
        assumed = (self.controller.reshard_cost_s if op == "reshard"
                   else self.restore_cost_s)
        if self.measured_costs is None:
            return assumed
        last = getattr(self.executor, "last_recovery_s", None)
        measured = last(op) if callable(last) else None
        if measured is None:
            return assumed
        for ev in self.measured_costs.observe(step, measured, op=op):
            log.emit(ev)
        if self.measured_costs.learned is not None:
            # propagate into planning: the controller prices resizes with
            # the learned cost from here on
            self.controller.reshard_cost_s = self.measured_costs.estimate_s
        return measured

    def _reset_monitor(self, m: int) -> None:
        """After a resize the step-time level legitimately shifts; re-anchor
        "slow" against the system model's prediction for the new m."""
        expected = None
        if self.controller.system.theta is not None:
            expected = float(self.controller.system.predict(
                m, self.controller.data_size))
        self.monitor.reset(expected_time=expected)

    # ------------------------------------------------------------------
    def run(self, steps: Optional[int] = None) -> ChaosRunLog:
        trace = self.sim.trace
        steps = trace.steps if steps is None else steps
        log = ChaosRunLog(trace=trace, meta={
            "m0": self.executor.m, "ckpt_every": self.ckpt_every,
            "base_compute_s": self.base_compute_s})
        objective = math.inf
        self.executor.checkpoint()
        for step in range(steps):
            assigned_before = set(self.sim.assigned_hosts(self.executor.m))
            events = self.sim.advance(step)
            row: Dict[str, Any] = {
                "step": step, "m": self.executor.m,
                "events": [f"{e.kind}:{e.host}" for e in events]}

            # a preemption of an *assigned* host flows through the injector,
            # exercising the same catch -> restore path a real heartbeat
            # timeout would take (an idle host dying costs nothing)
            for e in events:
                if e.kind == "preempt" and e.host in assigned_before:
                    self.injector.schedule(step)

            # sync_relax is a MITIGATION, not a mode: once a relaxed host
            # is healthy again (fault expired, hot-spared, preempted-fresh,
            # or gone), it rejoins every barrier; when no host is relaxed
            # the executor returns to full-sync H=1
            recovered = [h for h in self._relaxed
                         if self.sim.speed.get(h, 1.0) <= 1.0]
            if recovered:
                for h in recovered:
                    del self._relaxed[h]
                if not self._relaxed:
                    self.executor.relax(1)

            # membership changes re-clamp the controller's options; a run
            # above capacity is forced down through the same resize path
            if any(e.kind in ("join", "leave") for e in events):
                opts = self._clamp_m_options()
                if self.executor.m > self.sim.capacity:
                    target = max(opts)
                    self.executor.restore()
                    self.executor.resize(target)
                    self.wall_s += self._recovery_cost_s(step, "restore", log)
                    self._reset_monitor(target)
                    row["m"] = self.executor.m
                    row["decision"] = f"resize:{target}:capacity"

            # preemption -> SimulatedFailure -> restore from checkpoint
            try:
                self.injector.check(step)
            except SimulatedFailure as e:
                self.executor.restore()
                self.wall_s += self._recovery_cost_s(step, "restore", log)
                self._reset_monitor(self.executor.m)
                row.update(objective=objective, restore=f"{e.kind}@{e.step}",
                           step_s=0.0, wall_s=round(self.wall_s, 9))
                log.append(**row)
                continue

            mask = self._sync_mask(step)
            mask_list = [mask.get(h, True)
                         for h in self.sim.assigned_hosts(self.executor.m)]
            objective = self.executor.outer_step(mask_list)
            step_s = self.sim.step_time(self.executor.m, self.base_compute_s,
                                        self.d, sync_mask=mask)
            self.wall_s += step_s
            row.update(objective=objective, step_s=round(step_s, 9))

            if self.system_refit is not None:
                for ev in self.system_refit.observe(
                        step, self.executor.m, self.controller.data_size,
                        step_s):
                    log.emit(ev)

            # straggler detection + mitigation
            host_times = self.sim.host_times(self.executor.m,
                                             self.base_compute_s)
            ev = self.monitor.observe(step, step_s, host_times=host_times)
            if ev is not None:
                if ev.host < 0:
                    # cluster-wide slowdown: every host slowed together, so
                    # there is no host to mitigate — flag it and ride it out
                    row["flag"] = f"cluster:{ev.action}"
                else:
                    row["mitigation"] = f"{ev.action}:{ev.host}"
                    if ev.action == "sync_relax":
                        self._relaxed.setdefault(ev.host, step)
                        self.executor.relax(self.relax_local_steps)
                    elif ev.action == "rebalance":
                        self.sim.rebalance(ev.host)
                    elif ev.action == "hot_spare":
                        self.sim.hot_spare(ev.host)
                        self.executor.restore()
                        self.wall_s += self._recovery_cost_s(step, "restore",
                                                             log)

            # convergence-model refit + resize decision
            decision = self.controller.observe(step, self.executor.m,
                                               objective)
            if decision is not None and decision.resize:
                target = min(decision.target_m, self.sim.capacity)
                if target != self.executor.m:
                    self.executor.checkpoint()
                    self.executor.resize(target)
                    self.wall_s += self._recovery_cost_s(step, "reshard", log)
                    self._reset_monitor(target)
                    row["decision"] = f"resize:{target}"

            if step > 0 and step % self.ckpt_every == 0:
                self.executor.checkpoint()
            row["wall_s"] = round(self.wall_s, 9)
            log.append(**row)
        log.meta["final_m"] = self.executor.m
        log.meta["final_objective"] = objective
        return log


# ---------------------------------------------------------------------------
# Canonical convex-simulator run (examples/chaos_train.py + golden tests)
# ---------------------------------------------------------------------------
def default_system_model():
    """The analytic f(m) both chaos drivers plan against: strong compute
    scaling (the regime where growing m pays), fitted the same way
    launch/dryrun.py fits its f(m) sweep."""
    import numpy as np

    from repro.core.ernest import ErnestModel

    ms = np.asarray([1, 2, 4, 8], np.float64)
    t_iter = 1.0 / ms + 0.01 * np.log(ms + 1.0) + 0.002 * ms
    return ErnestModel().fit(ms, np.ones_like(ms), t_iter)


def run_chaos_sim(seed: int, *, steps: int = 160, n_hosts: int = 4,
                  m0: int = 2, m_options: Sequence[int] = (1, 2, 4),
                  trace: Optional[ChaosTrace] = None,
                  n: int = 512, d: int = 32) -> ChaosRunLog:
    """One closed-loop elastic run on the convex BSP simulator.

    Deterministic end to end: the trace comes from ``seed`` (or is passed
    in for replay), the SSP executor's data + minibatch draws come from the
    same seed, and the loop adds no entropy."""
    import jax.numpy as jnp

    from repro.optim.problems import ERMProblem, synthetic_mnist
    from repro.optim.simcluster import SSPLocalSGD

    if trace is None:
        trace = ChaosTrace.generate(seed, steps, n_hosts)
    X, y = synthetic_mnist(n=n, d=d, effective_rank=min(16, d), seed=seed)
    problem = ERMProblem(jnp.asarray(X), jnp.asarray(y), lam=1e-2,
                         loss="smooth_hinge")
    # lr0 tuned so convergence is *gradual* over the run — the regime where
    # adapting m mid-run pays (instant convergence leaves nothing to adapt)
    executor = SSPLocalSGD(problem, m0, lr0=0.01, seed=seed)

    # p_star: a cheap deterministic reference lower bound for the gap
    controller = AdaptiveController(
        default_system_model(), target_gap=0.02,
        p_star=executor.reference_floor(),
        m_options=m_options, refit_every=20, window=120,
        reshard_cost_s=2.0, min_observations=30)

    sim = ClusterSim(trace)
    loop = ChaosLoop(sim, executor, controller,
                     base_compute_s=1.0, d=d, ckpt_every=10,
                     restore_cost_s=3.0)
    log = loop.run()
    log.meta.update(seed=seed, n=n, d=d, m_options=list(m_options))
    return log


def replay(run_log: ChaosRunLog) -> ChaosRunLog:
    """Re-run a recorded chaos run from its embedded trace + seed; the
    result must match ``run_log.signature()`` exactly."""
    meta = run_log.meta
    return run_chaos_sim(
        int(meta["seed"]), trace=run_log.trace, m0=int(meta["m0"]),
        m_options=tuple(meta.get("m_options", (1, 2, 4))),
        n=int(meta.get("n", 512)), d=int(meta.get("d", 32)))
