"""Elastic rescale: move a training state between meshes of different size.

The adaptive controller (repro.core.adaptive) decides WHEN to change the
data-parallel degree; this module executes the move:

  1. checkpoint (or use in-memory host copies),
  2. build the new mesh + sharding rules,
  3. re-place every leaf with its sharding on the new mesh,
  4. resume — the step function is re-jitted for the new mesh by the driver.

Works across any pair of mesh shapes because checkpoints are global host
arrays (see CheckpointManager.restore_sharded).
"""
from __future__ import annotations

from typing import Any, Dict

import jax

from repro.dist.partitioning import Rules
from repro.dist.treeutil import map_with_axes


def shardings_for(mesh, rules: Rules, axes_tree, value_tree):
    """NamedSharding tree for params/opt-state (shape-aware)."""
    def mk(leaf, ax):
        return rules.param_sharding(mesh, ax, getattr(leaf, "shape", ()))

    return map_with_axes(mk, value_tree, axes_tree)


def reshard_tree(tree, shardings):
    """Place (host or device) arrays onto new shardings."""
    return jax.tree.map(jax.device_put, tree, shardings)


def rescale(host_state: Dict[str, Any], new_mesh, rules: Rules,
            axes: Dict[str, Any]) -> Dict[str, Any]:
    """host_state: {'params': tree, 'opt_state': tree}; axes: matching
    logical-axes trees {'params': ..., 'opt_state': ...}."""
    out = {}
    for key in host_state:
        sh = shardings_for(new_mesh, rules, axes[key], host_state[key])
        out[key] = reshard_tree(host_state[key], sh)
    return out


def rescale_training_state(host_state: Dict[str, Any], new_mesh,
                           rules: Rules, param_axes, opt) -> Dict[str, Any]:
    """The full elastic move for a checkpointed training state: derive the
    optimizer-state axes from the parameter axes (Optimizer.init_axes) and
    re-place both trees on the new mesh.  This is the single entry point
    the resize paths (chaos loop, elastic examples) go through, so the
    params/opt-state axis pairing is written down exactly once."""
    axes = {"params": param_axes, "opt_state": opt.init_axes(param_axes)}
    return rescale({"params": host_state["params"],
                    "opt_state": host_state["opt_state"]},
                   new_mesh, rules, axes)
