"""Straggler detection + mitigation decisions.

BSP steps run at the speed of the slowest participant.  The monitor keeps
an EWMA + variance of step times; a step slower than
``mean + threshold_sigmas * std`` (and slower than ``min_ratio`` x mean) is
flagged.  After ``consecutive`` flags it recommends mitigation:

  * "rebalance"  — shrink the slow host's data shard (the driver reshards
                   via the elastic path)
  * "hot_spare"  — swap the slow host for a standby and restore from the
                   latest checkpoint
  * "sync_relax" — switch the trainer to local-SGD (H>1) so one slow host
                   only hurts its own shard between syncs

The decision layer is driver-level by design: Hemingway's own Ernest model
supplies the expected step time, so "slow" is defined against the model's
prediction, not just history (a cluster-wide slowdown is not a straggler).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional


@dataclasses.dataclass
class StragglerEvent:
    step: int
    step_time: float
    expected: float
    action: str
    host: int = -1   # slowest host when per-host times were supplied and
    #                  one host stands out; -1 = cluster-wide (no target)


class StragglerMonitor:
    def __init__(self, threshold_sigmas: float = 3.0, min_ratio: float = 1.5,
                 consecutive: int = 3, ewma: float = 0.05,
                 expected_time: Optional[float] = None,
                 host_ratio: float = 1.3):
        self.threshold_sigmas = threshold_sigmas
        self.min_ratio = min_ratio
        self.consecutive = consecutive
        self.ewma = ewma
        self.expected_time = expected_time  # Ernest prediction, if available
        self.host_ratio = host_ratio  # outlier-host attribution threshold
        self.mean: Optional[float] = None
        self.var: float = 0.0
        self._flags = 0
        self.events: List[StragglerEvent] = []

    def reset(self, expected_time: Optional[float] = None) -> None:
        """Re-anchor after a legitimate step-time level shift (resize): new
        EWMA baseline, optionally a fresh Ernest expectation for the new m."""
        self.mean = None
        self.var = 0.0
        self._flags = 0
        self.expected_time = expected_time

    def _attribute(self, host_times: Optional[Dict[int, float]]) -> int:
        """Name the straggling host — only when one host is genuinely the
        outlier (a cluster-wide slowdown has no target to mitigate)."""
        if not host_times or len(host_times) < 2:
            return -1
        ordered = sorted(host_times.items(), key=lambda kv: kv[1])
        worst_host, worst = ordered[-1]
        runner_up = ordered[-2][1]
        if worst > self.host_ratio * max(runner_up, 1e-12):
            return worst_host
        return -1

    def observe(self, step: int, step_time: float,
                host_times: Optional[Dict[int, float]] = None
                ) -> Optional[StragglerEvent]:
        if self.mean is None:
            self.mean = step_time
            return None
        std = math.sqrt(max(self.var, 1e-12))
        baseline = self.expected_time or self.mean
        slow = (step_time > self.mean + self.threshold_sigmas * std
                and step_time > self.min_ratio * baseline)
        # update stats with non-outlier steps only
        if not slow:
            delta = step_time - self.mean
            self.mean += self.ewma * delta
            self.var = (1 - self.ewma) * (self.var + self.ewma * delta * delta)
            self._flags = 0
            return None
        self._flags += 1
        if self._flags < self.consecutive:
            return None
        self._flags = 0
        ratio = step_time / baseline
        action = ("hot_spare" if ratio > 4.0
                  else "rebalance" if ratio > 2.0 else "sync_relax")
        ev = StragglerEvent(step, step_time, baseline, action,
                            host=self._attribute(host_times))
        self.events.append(ev)
        return ev
