"""Failure injection + restart policy (node-failure tolerance).

In a real deployment the runtime watches for missing heartbeats /
NCCL-equivalent timeouts; in this single-process harness `FailureInjector`
deterministically raises ``SimulatedFailure`` at configured steps and the
driver's recovery path (catch -> restore latest checkpoint -> rebuild mesh
-> continue) is exactly the code a real restart would execute.  Tested in
tests/test_fault_tolerance.py.
"""
from __future__ import annotations

import dataclasses
from typing import Set


class SimulatedFailure(RuntimeError):
    def __init__(self, step: int, kind: str = "node_lost"):
        super().__init__(f"simulated {kind} at step {step}")
        self.step = step
        self.kind = kind


@dataclasses.dataclass
class FailureInjector:
    fail_at_steps: Set[int] = dataclasses.field(default_factory=set)
    kinds: str = "node_lost"
    fired: Set[int] = dataclasses.field(default_factory=set)

    @classmethod
    def at(cls, *steps: int) -> "FailureInjector":
        return cls(fail_at_steps=set(steps))

    def schedule(self, step: int) -> None:
        """Arm a failure at ``step`` mid-run — the chaos loop translates
        trace preemption events into injector schedules so recovery runs
        through the same catch/restore path a real heartbeat loss would."""
        self.fail_at_steps.add(step)

    def check(self, step: int) -> None:
        if step in self.fail_at_steps and step not in self.fired:
            self.fired.add(step)
            raise SimulatedFailure(step, self.kinds)


@dataclasses.dataclass
class RestartPolicy:
    max_restarts: int = 5
    backoff_s: float = 0.0
    restarts_used: int = 0

    def should_restart(self) -> bool:
        if self.restarts_used >= self.max_restarts:
            return False
        self.restarts_used += 1
        return True
