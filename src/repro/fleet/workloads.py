"""Fleet workloads: training jobs and serving deployments, model-costed.

The fleet scheduler never executes a workload to find out what it needs —
it asks the workload's Hemingway model, exactly the way the paper's
ML-optimizer answers "how many processors" for a single job:

  * ``TrainingJob`` carries a ``core.hemingway.CombinedModel``; admission,
    sizing, and deadline checks all go through
    ``CombinedModel.time_to_epsilon`` / ``Planner.fastest_to_epsilon``
    (which returns a typed ``NoFeasiblePlan`` when the target is
    unreachable — the scheduler records it instead of crashing).
  * ``ServeDeployment`` carries a fitted ``serve.planner.CapacityPlanner``
    plus a diurnal/bursty ``RequestTrace``; replica targets come from
    ``CapacityPlanner.plan`` and achieved latency from the same step
    model the planner fitted.

Progress is tracked in *work fractions* (the standard malleable-job
model): a job that has completed fraction p at parallelism m needs
``(1 - p) * time_to_epsilon(eps, m)`` more seconds, so the scheduler can
resize mid-run and the accounting stays consistent.
"""
from __future__ import annotations

import dataclasses
import math
import random
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.ernest import ErnestModel
from repro.core.hemingway import (
    CombinedModel,
    NoFeasiblePlan,
    Planner,
    PlanResult,
)
from repro.serve.planner import CapacityPlanner, decision_batch


# ---------------------------------------------------------------------------
# Request-rate traces (the serving load)
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class RequestTrace:
    """Deterministic per-tick request rate (QPS) for one deployment.

    Generated once from a seed (diurnal sine + seeded bursts) or loaded
    from JSON; the fleet simulator replays it, never re-draws it."""

    seed: int
    tick_s: float
    qps: List[float]

    @classmethod
    def diurnal(cls, seed: int, ticks: int, tick_s: float, *,
                base_qps: float, peak_qps: float, peak_frac: float = 0.58,
                burst_prob: float = 0.04, burst_mult: float = 1.8,
                burst_ticks: int = 3) -> "RequestTrace":
        """One day of load: a sine with its peak at ``peak_frac`` of the
        horizon, plus short seeded bursts (traffic spikes)."""
        rng = random.Random(seed)
        qps: List[float] = []
        burst_left, burst_scale = 0, 1.0
        for t in range(ticks):
            phase = 2.0 * math.pi * (t / ticks - peak_frac)
            diurnal = base_qps + (peak_qps - base_qps) * 0.5 * (
                1.0 + math.cos(phase))
            if burst_left > 0:
                burst_left -= 1
            elif rng.random() < burst_prob:
                burst_left = burst_ticks
                burst_scale = rng.uniform(1.2, burst_mult)
            scale = burst_scale if burst_left > 0 else 1.0
            qps.append(round(diurnal * scale, 6))
        return cls(seed=seed, tick_s=tick_s, qps=qps)

    # ------------------------------------------------------------------
    def qps_at(self, tick: int) -> float:
        return self.qps[min(tick, len(self.qps) - 1)]

    def forecast(self, tick: int, window: int) -> float:
        """Max demand over the next ``window`` ticks — the scheduler plans
        capacity against the near-term peak, not the instant."""
        lo = min(tick, len(self.qps) - 1)
        hi = min(tick + max(window, 1), len(self.qps))
        return max(self.qps[lo:hi])

    def to_json(self) -> Dict[str, Any]:
        return {"seed": self.seed, "tick_s": self.tick_s, "qps": self.qps}

    @classmethod
    def from_json(cls, d: Dict[str, Any]) -> "RequestTrace":
        return cls(seed=int(d["seed"]), tick_s=float(d["tick_s"]),
                   qps=[float(q) for q in d["qps"]])


# ---------------------------------------------------------------------------
# Analytic model builders (deterministic, no curve-fitting noise)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class AnalyticConvergence:
    """Closed-form g(i, m) with the paper's communication-avoiding shape:
    gap(i, m) = gap0 * exp(-rate * i / m**alpha).  ``alpha`` < 1 means
    more machines need proportionally more iterations (Fig 1b), which is
    what gives time-to-epsilon its interior optimum over m.

    Implements the slice of the ConvergenceModel interface CombinedModel
    uses (``predict`` + ``p_star``), so the canonical fleet scenarios are
    bit-stable across machines; fitted ConvergenceModels drop in
    unchanged (see examples/quickstart.py for the fitted path)."""

    p_star: float
    gap0: float
    rate: float
    alpha: float = 0.35

    def predict(self, i, m: float) -> np.ndarray:
        i = np.atleast_1d(np.asarray(i, np.float64))
        with np.errstate(over="ignore"):
            return self.p_star + self.gap0 * np.exp(
                -self.rate * i / float(m) ** self.alpha)


def training_model(*, compute_s: float, floor_s: float = 0.5,
                   log_s: float = 0.3, per_m_s: float = 0.05,
                   gap0: float = 1.0, rate: float = 2.5e-3,
                   alpha: float = 0.35, p_star: float = 0.0,
                   m_fit_grid: Sequence[int] = (1, 2, 4, 8, 16),
                   max_iters: int = 200_000) -> CombinedModel:
    """A CombinedModel from analytic curves: f(m) is a real ErnestModel
    NNLS-fitted on the BSP cost family (compute/m + log-tree comm + per-task
    + floor), g(i, m) is :class:`AnalyticConvergence`."""
    ms = np.asarray(m_fit_grid, np.float64)
    t_iter = (compute_s / ms + log_s * np.log(ms + 1.0)
              + per_m_s * ms + floor_s)
    system = ErnestModel().fit(ms, np.ones_like(ms), t_iter)
    conv = AnalyticConvergence(p_star=p_star, gap0=gap0, rate=rate,
                               alpha=alpha)
    return CombinedModel(system, conv, data_size=1.0, max_iters=max_iters)


def serve_capacity_planner(*, dispatch_s: float, per_seq_s: float,
                           log_b_s: float = 0.0,
                           fleet_overhead_s: float = 1e-3,
                           batch_grid: Sequence[int] = (1, 2, 4, 8, 16),
                           ) -> CapacityPlanner:
    """A fitted CapacityPlanner from an analytic step model
    t(b) = dispatch + per_seq*b + log_b*log b — the same three Ernest terms
    the planner fits from live telemetry, here supplied noise-free."""
    planner = CapacityPlanner(fleet_overhead_s_per_log_m=fleet_overhead_s)
    for b in batch_grid:
        for _ in range(2):   # NNLS wants a few rows; exact duplicates fine
            planner.observe(b, dispatch_s + per_seq_s * b
                            + log_b_s * math.log(b))
    return planner.fit()


# ---------------------------------------------------------------------------
# Training jobs
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class TrainingJob:
    """A deadline-constrained training run, costed by its CombinedModel.

    The scheduler owns all mutable state below the config block; an
    optional ``executor`` implementing the chaos-loop contract
    (``m``/``resize``/``outer_step``/``checkpoint``/``restore`` — e.g.
    ``optim.simcluster.SSPLocalSGD`` or ``launch.train.TrainerExecutor``,
    which re-shards through ``elastic.rescale_training_state``) is driven
    alongside the modeled progress so resizes exercise the real elastic
    path."""

    name: str
    model: CombinedModel
    eps: float
    arrival_s: float
    deadline_s: float            # absolute (seconds since fleet start)
    m_options: Tuple[int, ...]
    ckpt_every_s: float = 1800.0
    executor: Optional[Any] = None
    # what one restore/re-shard of this job ACTUALLY costs (the async
    # sharded checkpoint + live-migration path both reduce to placing
    # shards from the last manifest onto a mesh, so one number prices
    # both ops).  None = the scheduler's assumed config constants are
    # accurate, which keeps pre-existing golden scenarios bit-identical.
    actual_recovery_s: Optional[float] = None

    # -- scheduler-owned state -----------------------------------------
    state: str = "pending"       # pending -> queued -> running -> done
    #                              (or infeasible, with no_plan set)
    m: int = 0
    progress: float = 0.0        # completed work fraction in [0, 1]
    pace_factor: float = 1.0     # streaming-refit multiplier on remaining
    #                              time (>1: the cluster is delivering work
    #                              slower than the model assumed; set by the
    #                              scheduler's drift detector, never drawn)
    ckpt_progress: float = 0.0   # last checkpointed fraction
    since_ckpt_s: float = 0.0
    penalty_s: float = 0.0       # pending restore/reshard seconds to pay
    finish_s: Optional[float] = None
    no_plan: Optional[NoFeasiblePlan] = None
    objective: Optional[float] = None   # executor's trajectory, if attached
    _t_eps_cache: Dict[int, Optional[float]] = dataclasses.field(
        default_factory=dict, repr=False)

    # ------------------------------------------------------------------
    def planner(self) -> Planner:
        return Planner({self.name: self.model})

    def time_to_eps(self, m: int) -> Optional[float]:
        # pure in (eps, m) for a fixed model, and on the scheduler's
        # per-tick hot path — the bisection runs once per (job, m)
        m = int(m)
        if m not in self._t_eps_cache:
            self._t_eps_cache[m] = self.model.time_to_epsilon(self.eps, m)
        return self._t_eps_cache[m]

    def remaining_s(self, m: int) -> Optional[float]:
        t = self.time_to_eps(m)
        if t is None:
            return None
        return (1.0 - self.progress) * t * self.pace_factor + self.penalty_s

    def admission_plan(self) -> PlanResult:
        """The Hemingway query behind admission: fastest (m, t) per option.
        Returns the typed NoFeasiblePlan when the target is unreachable."""
        return self.planner().fastest_to_epsilon(self.eps, self.m_options)

    # ------------------------------------------------------------------
    def snapshot(self) -> Dict[str, Any]:
        """Compact per-tick state for the run log."""
        s: Dict[str, Any] = {"state": self.state, "m": self.m,
                             "prog": round(self.progress, 9)}
        if self.objective is not None:
            s["obj"] = round(self.objective, 9)
        return s


# ---------------------------------------------------------------------------
# Serving deployments
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class ServeDeployment:
    """A latency-SLO serving deployment under a time-varying load trace.

    Replica targets come from ``CapacityPlanner.plan`` (the serve-side
    fastest-to-epsilon analogue); per-tick achieved latency comes from the
    same fitted step model at the current effective replica count, with a
    utilization-dependent tail factor so under-provisioning surfaces as a
    p95 violation rather than silently queueing forever."""

    name: str
    planner: CapacityPlanner
    trace: RequestTrace
    slo_p95_s: float
    gen_tokens: int
    batch_grid: Tuple[int, ...]
    replica_options: Tuple[int, ...]
    p95_margin: float = 1.5      # plan p50 target = slo_p95 / margin
    tail_k: float = 0.45         # p95 ~= p50 * (1 + tail_k * utilization^2)

    # -- scheduler-owned state -----------------------------------------
    replicas: int = 0
    scale_down_votes: int = 0
    latencies: List[float] = dataclasses.field(default_factory=list)

    # ------------------------------------------------------------------
    @property
    def target_p50_s(self) -> float:
        return self.slo_p95_s / self.p95_margin

    def desired_replicas(self, qps: float) -> PlanResult:
        return self.planner.plan(
            target_p50_s=self.target_p50_s, qps=max(qps, 1e-9),
            gen_tokens=self.gen_tokens, batch_grid=self.batch_grid,
            m_grid=self.replica_options)

    def capacity_qps(self, effective_m: float, batch: int) -> float:
        return self.planner.tokens_per_s(batch, effective_m) / self.gen_tokens

    def tick_latency(self, effective_m: float, qps: float) -> float:
        """Modeled p95 latency this tick at ``effective_m`` replicas."""
        effective_m = max(effective_m, 1e-6)
        best = self.planner.best_latency_within_fleet(
            m=effective_m, qps=max(qps, 1e-9), gen_tokens=self.gen_tokens,
            batch_grid=self.batch_grid)
        if best:
            batch = decision_batch(best)
            p50 = best.predicted_time
        else:
            # overloaded: run flat out at max batch; latency inflates with
            # the overload ratio (queueing blow-up, still finite + smooth)
            batch = max(self.batch_grid)
            p50 = self.planner.p50_latency_s(batch, self.gen_tokens,
                                             effective_m)
        util = min(qps / max(self.capacity_qps(effective_m, batch), 1e-9),
                   4.0)
        return p50 * (1.0 + self.tail_k * min(util, 1.0) ** 2
                      + max(util - 1.0, 0.0) ** 2)

    # ------------------------------------------------------------------
    def p95_latency(self) -> float:
        if not self.latencies:
            return 0.0
        lat = sorted(self.latencies)
        idx = min(len(lat) - 1, math.ceil(0.95 * len(lat)) - 1)
        return lat[max(idx, 0)]

    def slo_met(self) -> bool:
        return self.p95_latency() <= self.slo_p95_s

    def observe_router(self, events) -> int:
        """Feed a routed deployment's telemetry (RouterEvent + replica-
        tagged serve_step rows) into this deployment's planner: affinity-hit
        rate and measured per-replica throughput then show up in snapshots
        and in ``measured_effective_m``."""
        return self.planner.ingest(events)

    def measured_effective_m(self) -> float:
        """Measured effective replica count from router telemetry (affinity-
        cold replicas count fractionally); falls back to the provisioned
        count when no routed run has been observed."""
        m = self.planner.measured_effective_replicas()
        return m if m > 0 else float(self.replicas)

    def snapshot(self, qps: float, lat_s: float) -> Dict[str, Any]:
        snap = {"m": self.replicas, "qps": round(qps, 6),
                "lat_s": round(lat_s, 9),
                "ok": bool(lat_s <= self.slo_p95_s)}
        # only present after router telemetry was observed, so golden-trace
        # fixtures recorded without a router replay byte-identically
        if self.planner.router_dispatches:
            snap["affinity"] = round(self.planner.affinity_hit_rate, 6)
        return snap
