"""Fleet cluster: host inventory + allocation table over chaos health state.

``runtime.chaos.ClusterSim`` already knows how to replay a seeded
``ChaosTrace`` into per-host speed multipliers, cluster-wide slowdowns,
preemptions, and join/leave churn.  This module adds the one thing a
multi-tenant fleet needs on top: an **allocation table** (host -> owner)
with hard invariants —

  * a host is owned by at most one workload (no double allocation),
  * allocate only hands out live, free hosts,
  * release returns exactly what was allocated (freed capacity conserved),

plus the per-owner health views the scheduler prices decisions with:
BSP training runs at the pace of its slowest host, serving capacity is the
sum of per-replica speeds (a 2x-slow replica is half a replica).
"""
from __future__ import annotations

from typing import Dict, Iterable, List, Tuple

from repro.runtime.chaos import ChaosEvent, ChaosTrace, ClusterSim


class AllocationError(ValueError):
    """Allocator misuse (double-alloc, bad release) or capacity shortfall."""


class FleetCluster:
    def __init__(self, trace: ChaosTrace):
        self.sim = ClusterSim(trace)
        self.alloc: Dict[int, str] = {}   # host -> owner name

    # -- inventory -----------------------------------------------------
    @property
    def capacity(self) -> int:
        return self.sim.capacity

    def hosts(self) -> List[int]:
        return self.sim.hosts()

    def free_hosts(self) -> List[int]:
        return [h for h in self.sim.hosts() if h not in self.alloc]

    def owned(self, owner: str) -> List[int]:
        return sorted(h for h, o in self.alloc.items() if o == owner)

    def n_allocated(self) -> int:
        return len(self.alloc)

    # -- allocation (the invariant-bearing operations) ------------------
    def allocate(self, owner: str, n: int) -> List[int]:
        """Hand ``owner`` the first n free live hosts (stable order)."""
        free = self.free_hosts()
        if n < 0:
            raise AllocationError(f"allocate({owner}, {n}): negative count")
        if n > len(free):
            raise AllocationError(
                f"allocate({owner}, {n}): only {len(free)} hosts free")
        taken = free[:n]
        for h in taken:
            self.alloc[h] = owner
        return taken

    def release(self, owner: str, hosts: Iterable[int]) -> None:
        for h in hosts:
            if self.alloc.get(h) != owner:
                raise AllocationError(
                    f"release({owner}, {h}): host owned by "
                    f"{self.alloc.get(h)!r}")
            del self.alloc[h]

    def release_all(self, owner: str) -> List[int]:
        hosts = self.owned(owner)
        self.release(owner, hosts)
        return hosts

    # -- time ------------------------------------------------------------
    def advance(self, step: int) -> Tuple[List[ChaosEvent],
                                          Dict[str, List[int]],
                                          Dict[str, List[int]]]:
        """Apply this step's chaos events.  Returns

        ``(events, lost, preempted)`` where ``lost[owner]`` are hosts that
        left the inventory out from under their owner (allocation dropped
        here — the owner must re-acquire), and ``preempted[owner]`` are
        owned hosts that were preempt-killed but return fresh (allocation
        kept; the owner lost in-flight work, not capacity)."""
        events = self.sim.advance(step)
        lost: Dict[str, List[int]] = {}
        preempted: Dict[str, List[int]] = {}
        for ev in events:
            if ev.kind == "preempt" and ev.host in self.alloc:
                preempted.setdefault(self.alloc[ev.host], []).append(ev.host)
        live = set(self.sim.hosts())
        for h in sorted(set(self.alloc) - live):
            lost.setdefault(self.alloc[h], []).append(h)
            del self.alloc[h]
        return events, lost, preempted

    # -- health views ----------------------------------------------------
    def host_multiplier(self, host: int) -> float:
        """Step-time multiplier for one host (>1 = slower)."""
        return self.sim.speed.get(host, 1.0) * self.sim.slowdown

    def bsp_pace(self, owner: str) -> float:
        """A BSP job runs at its slowest member's multiplier."""
        hosts = self.owned(owner)
        if not hosts:
            return 1.0
        return max(self.host_multiplier(h) for h in hosts)

    def effective_replicas(self, owner: str,
                           exclude: Iterable[int] = ()) -> float:
        """Serving capacity in replica units: a k-times-slower replica
        contributes 1/k of a replica."""
        skip = set(exclude)
        return sum(1.0 / self.host_multiplier(h)
                   for h in self.owned(owner) if h not in skip)


__all__ = ["AllocationError", "ChaosTrace", "FleetCluster"]
