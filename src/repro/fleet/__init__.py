"""Multi-tenant fleet scheduler: training + serving on one simulated
cluster, every decision priced by a Hemingway model.  See DESIGN.md §9."""

from repro.fleet.cluster import AllocationError, FleetCluster
from repro.fleet.scheduler import FleetConfig, FleetScheduler
from repro.fleet.simulate import (
    FleetRunLog,
    FleetSimulator,
    build_day_scenario,
    build_drift_scenario,
    build_migration_scenario,
    replay,
    run_fleet_sim,
)
from repro.fleet.workloads import (
    AnalyticConvergence,
    RequestTrace,
    ServeDeployment,
    TrainingJob,
    serve_capacity_planner,
    training_model,
)

__all__ = [
    "AllocationError",
    "AnalyticConvergence",
    "FleetCluster",
    "FleetConfig",
    "FleetRunLog",
    "FleetScheduler",
    "FleetSimulator",
    "RequestTrace",
    "ServeDeployment",
    "TrainingJob",
    "build_day_scenario",
    "build_drift_scenario",
    "build_migration_scenario",
    "replay",
    "run_fleet_sim",
    "serve_capacity_planner",
    "training_model",
]
