"""Model-driven multi-tenant placement: the fleet scheduler.

One scheduler tick is the Hemingway decision loop lifted to a fleet:

  1. **Reconcile** chaos: hosts that left drop out of allocations (training
     rolls back to its last checkpoint and shrinks; serving re-acquires),
     preempted hosts keep their allocation but lose in-flight work.
  2. **Serve first** (SLO priority): each deployment's replica target comes
     from ``CapacityPlanner.plan`` against the near-term forecast; scale-ups
     may preempt training hosts, scale-downs wait out a patience window.
  3. **Admit training**: ``Planner.fastest_to_epsilon`` over the job's
     m-options; a typed ``NoFeasiblePlan`` (target unreachable, or no m
     meets the deadline) marks the job infeasible *as data*.  Among
     deadline-feasible sizes the scheduler picks the cheapest in
     host-seconds — minimize fleet cost subject to the deadline.
  4. **Resize training**: the same remaining-time-vs-reshard-cost tradeoff
     ``core.adaptive.AdaptiveController`` applies during a single run,
     re-evaluated fleet-wide; decisions are recorded as
     ``core.adaptive.ResizeDecision`` and executed through the job's
     executor (``SSPLocalSGD`` re-partitions; ``launch.train``'s
     ``TrainerExecutor`` goes through ``elastic.rescale_training_state``).
  5. **Account**: modeled progress (work fractions, BSP pace = slowest
     host), per-tick serve latency, cumulative host-seconds.

Everything iterates in sorted order and draws no entropy, so a tick
sequence is a pure function of (chaos trace, request traces, config) —
the replay guarantee ``simulate.FleetRunLog`` is built on.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, Optional, Sequence

from collections import deque

from repro.core.adaptive import ResizeDecision
from repro.core.hemingway import NoFeasiblePlan
from repro.fleet.cluster import FleetCluster
from repro.fleet.workloads import ServeDeployment, TrainingJob
from repro.runtime.chaos import ChaosEvent
from repro.telemetry import (
    DriftConfig,
    DriftDetector,
    Event,
    RefitEvent,
    SpanEvent,
    StreamingCost,
)
from repro.telemetry.trace import SloConfig, SLOMonitor, det_id


@dataclasses.dataclass(frozen=True)
class FleetConfig:
    tick_s: float = 300.0
    serve_headroom: float = 1.15      # capacity target = forecast * headroom
    forecast_ticks: int = 3           # plan against the next-N-ticks peak
    scale_down_patience: int = 3      # consecutive lower targets before down
    reshard_cost_s: float = 120.0     # paid by a job on every resize
    restore_cost_s: float = 240.0     # paid on checkpoint restore
    resize_cooldown_ticks: int = 6    # no-flap guard between job resizes
    resize_hysteresis: float = 0.85   # resize only for >15% host-second win
    shrink_safety: float = 0.7        # shrink only into <70% of the slack:
    #                                   progress pays slack back 1:1, so a
    #                                   comfortable shrink never needs a
    #                                   deadline rescue later (no flapping)
    # opt-in streaming refit of each running job's pace model: watch the
    # modeled vs measured per-tick work rate, and when the normalized
    # residual drifts past the threshold, refit the job's pace factor from
    # the trailing window and force a replanning pass (None = off, which
    # keeps pre-drift golden traces bit-identical)
    drift: Optional[DriftConfig] = None
    # opt-in hierarchical trace spans over *modeled* time: one root span per
    # tick with per-job and per-deployment children (predicted vs delivered
    # work), riding the run log's bus outside rows/signatures — default off
    # so pre-span golden traces stay bit-identical
    spans: bool = False
    # opt-in per-deployment SLO burn-rate monitoring: each deployment's
    # modeled tick latency streams through an SLOMonitor (target = its own
    # slo_p95_s; the config below carries the budget/window tunables), and a
    # fast-burn alert grants the autoscaler extra headroom for a few ticks —
    # early warning that lands several ticks before the drift detector's
    # windowed refit (None = off, same golden-trace guarantee)
    slo: Optional[SloConfig] = None
    # opt-in measured-recovery-cost refit: every restore/re-shard a job
    # actually pays feeds a per-job StreamingCost, and once the detector
    # sees the assumed reshard/restore constants are persistently wrong
    # the learned cost replaces them in resize planning — the feedback
    # loop that lets a cheap async-checkpoint/migration path flip resize
    # decisions the stop-the-world assumption would veto (None = off,
    # which keeps pre-measurement golden traces bit-identical)
    measured: Optional[DriftConfig] = None


# A fired SLO alert boosts the deployment's autoscaling headroom by this
# factor for this many ticks: capacity tops up on the burn signal instead
# of waiting for the (slower) drift refit to reprice the pace model.
SLO_BOOST = 1.25
SLO_BOOST_TICKS = 6


class FleetScheduler:
    def __init__(self, cluster: FleetCluster, jobs: Sequence[TrainingJob],
                 deployments: Sequence[ServeDeployment],
                 cfg: Optional[FleetConfig] = None):
        self.cluster = cluster
        self.cfg = cfg or FleetConfig()
        self.jobs = {j.name: j for j in jobs}
        self.deployments = {d.name: d for d in deployments}
        if set(self.jobs) & set(self.deployments):
            raise ValueError("workload names must be unique across kinds")
        self.resize_decisions: List[ResizeDecision] = []
        self._last_resize: Dict[str, int] = {}
        self.cost_host_s = 0.0
        # streaming pace refit (cfg.drift opt-in): per-job detector + pace
        # window; typed drift/refit events buffer here until the simulator
        # drains them onto the run log's bus after each tick
        self._drift: Dict[str, DriftDetector] = {}
        self._pace_window: Dict[str, deque] = {}
        self._needs_replan: set = set()
        self.pending_events: List[Event] = []
        # measured-recovery-cost estimators (cfg.measured opt-in): one per
        # job; restore AND re-shard observations share it, because both
        # ops reduce to the same place-shards-from-manifest move
        self._recovery_cost: Dict[str, StreamingCost] = {}
        # SLO burn-rate monitors (cfg.slo opt-in): one per deployment,
        # created lazily with the deployment's own p95 target; a fired
        # alert boosts that deployment's autoscale headroom until the
        # recorded expiry tick
        self._slo: Dict[str, SLOMonitor] = {}
        self._slo_boost_until: Dict[str, int] = {}
        # trace identity for cfg.spans: derived from the scheduler config
        # only, so same-scenario runs produce identical span ids; each
        # workload gets its own lane (export maps it to a Perfetto track)
        self._trace_id = det_id("trace", "fleet", self.cfg.tick_s)
        self._lane = {n: i + 1 for i, n in enumerate(
            sorted(self.jobs) + sorted(self.deployments))}

    def drain_events(self) -> List[Event]:
        out, self.pending_events = self.pending_events, []
        return out

    # ------------------------------------------------------------------
    # One tick
    # ------------------------------------------------------------------
    def tick(self, step: int, events: List[ChaosEvent],
             lost: Dict[str, List[int]],
             preempted: Dict[str, List[int]]) -> Dict[str, Any]:
        now_s = step * self.cfg.tick_s
        decisions: List[str] = []

        self._reconcile(step, lost, preempted, decisions)
        self._autoscale_serve(step, now_s, decisions)
        self._admit_training(step, now_s, decisions)
        self._resize_training(step, now_s, decisions)
        self._account_training(step, now_s, decisions)
        serve_row = self._account_serve(step, preempted, decisions)
        if self.cfg.spans:
            self._emit_tick_spans(step, now_s, serve_row)

        self.cost_host_s += self.cluster.n_allocated() * self.cfg.tick_s
        return {
            "step": step,
            "events": [f"{e.kind}:{e.host}" for e in events],
            "decisions": decisions,
            "serve": serve_row,
            "jobs": {n: j.snapshot() for n, j in sorted(self.jobs.items())},
            "free": len(self.cluster.free_hosts()),
            "cost_hh": round(self.cost_host_s / 3600.0, 6),
        }

    # ------------------------------------------------------------------
    # 1. chaos reconciliation
    # ------------------------------------------------------------------
    def _reconcile(self, step: int, lost: Dict[str, List[int]],
                   preempted: Dict[str, List[int]],
                   decisions: List[str]) -> None:
        for owner in sorted(set(lost) | set(preempted)):
            if owner in self.deployments:
                dep = self.deployments[owner]
                if owner in lost:
                    dep.replicas = len(self.cluster.owned(owner))
                    decisions.append(
                        f"lost:{owner}:{sorted(lost[owner])}")
                # preempted replicas return fresh: capacity dip is priced
                # into this tick's latency (exclude list), nothing to do
            elif owner in self.jobs:
                self._reconcile_job(step, self.jobs[owner],
                                    lost.get(owner, []),
                                    preempted.get(owner, []), decisions)

    # ------------------------------------------------------------------
    # measured recovery costs (cfg.measured opt-in)
    # ------------------------------------------------------------------
    def _planned_recovery_s(self, job: TrainingJob, assumed: float) -> float:
        """The recovery cost resize planning prices in: the per-job learned
        estimate once the measured-cost refit has fired, the assumed config
        constant until then (and always when ``cfg.measured`` is off)."""
        est = self._recovery_cost.get(job.name)
        if est is not None and est.learned is not None:
            return est.estimate_s
        return assumed

    def _charge_recovery(self, step: int, job: TrainingJob, op: str,
                         assumed: float, decisions: List[str]) -> None:
        """Charge the job what a recovery ACTUALLY costs, and (opt-in) feed
        the measurement into its streaming cost estimator so planning stops
        trusting the assumed constant once it is persistently wrong."""
        actual = (job.actual_recovery_s if job.actual_recovery_s is not None
                  else assumed)
        job.penalty_s += actual
        if self.cfg.measured is None:
            return
        est = self._recovery_cost.get(job.name)
        if est is None:
            est = self._recovery_cost[job.name] = StreamingCost(
                f"recovery:{job.name}", self.cfg.reshard_cost_s,
                self.cfg.measured)
        events = est.observe(step, actual, op=op, workload=job.name)
        self.pending_events.extend(events)
        if any(isinstance(e, RefitEvent) for e in events):
            decisions.append(f"recost:{job.name}:{est.estimate_s:.0f}s")

    def _rollback(self, step: int, job: TrainingJob,
                  decisions: List[str]) -> None:
        job.progress = job.ckpt_progress
        self._charge_recovery(step, job, "restore", self.cfg.restore_cost_s,
                              decisions)
        job.since_ckpt_s = 0.0
        if job.executor is not None:
            job.executor.restore()

    def _reconcile_job(self, step: int, job: TrainingJob, lost: List[int],
                       preempted: List[int], decisions: List[str]) -> None:
        if job.state != "running":
            return
        if lost:
            survivors = sorted(self.cluster.owned(job.name),
                               key=lambda h: (self.cluster.host_multiplier(h),
                                              h))
            self._rollback(step, job, decisions)
            # only sizes the model says can still reach eps are acceptable
            # landing spots; otherwise requeue and let admission re-plan
            fits = [m for m in job.m_options if m <= len(survivors)
                    and job.remaining_s(m) is not None]
            if fits:
                target = max(fits)
                self.cluster.release(job.name, survivors[target:])
                job.m = target
                if job.executor is not None:
                    job.executor.resize(target)
                decisions.append(f"shrink:{job.name}:m={target}:lost_host")
            else:
                self.cluster.release_all(job.name)
                job.state, job.m = "queued", 0
                decisions.append(f"evict:{job.name}:lost_host")
        elif preempted:
            # capacity survives (host returns fresh) but in-flight BSP work
            # since the last checkpoint is gone
            self._rollback(step, job, decisions)
            decisions.append(
                f"restore:{job.name}:preempt{sorted(preempted)}")

    # ------------------------------------------------------------------
    # 2. serve autoscaling (SLO priority)
    # ------------------------------------------------------------------
    def _autoscale_serve(self, step: int, now_s: float,
                         decisions: List[str]) -> None:
        """Capacity-based autoscaling: the target is in *effective* replica
        units, so a straggling replica or a cluster-wide slowdown shows up
        as missing capacity and is topped up the same tick (new hosts are
        priced at their own degraded speed)."""
        for name in sorted(self.deployments):
            dep = self.deployments[name]
            headroom = self.cfg.serve_headroom
            if step < self._slo_boost_until.get(name, 0):
                # a recent fast-burn alert: over-provision until it expires
                headroom *= SLO_BOOST
            forecast = (dep.trace.forecast(step, self.cfg.forecast_ticks)
                        * headroom)
            plan = dep.desired_replicas(forecast)
            if plan:
                target = float(plan.m)
            else:
                target = float(max(dep.replica_options))
                decisions.append(f"noplan:{name}:{plan.query}")
            eff = self.cluster.effective_replicas(name)
            if eff + 1e-9 < target:
                need = self._hosts_for_capacity(target - eff)
                shortfall = need - len(self.cluster.free_hosts())
                if shortfall > 0:
                    self._preempt_training_for(shortfall, step, now_s, name,
                                               decisions)
                    need = self._hosts_for_capacity(target - eff)
                grant = min(need, len(self.cluster.free_hosts()))
                if grant > 0:
                    old = dep.replicas
                    self.cluster.allocate(name, grant)
                    dep.replicas = len(self.cluster.owned(name))
                    decisions.append(
                        f"scale_up:{name}:{old}->{dep.replicas}")
                if grant < need:
                    decisions.append(f"deficit:{name}:{need - grant}")
                dep.scale_down_votes = 0
                continue
            # scale down: drop the slowest owned hosts while the remaining
            # effective capacity still covers the target (with patience)
            drop = self._droppable_hosts(name, eff, target)
            if drop:
                dep.scale_down_votes += 1
                if dep.scale_down_votes >= self.cfg.scale_down_patience:
                    old = dep.replicas
                    self.cluster.release(name, drop)
                    dep.replicas = len(self.cluster.owned(name))
                    decisions.append(
                        f"scale_down:{name}:{old}->{dep.replicas}")
                    dep.scale_down_votes = 0
            else:
                dep.scale_down_votes = 0

    def _hosts_for_capacity(self, missing: float) -> int:
        """How many free hosts (in allocation order, at their current
        degraded speeds) cover ``missing`` effective replicas; if the whole
        free pool is short, the remainder is priced at the cluster-wide
        pace (what a preempted-then-allocated host would run at)."""
        covered, need = 0.0, 0
        for h in self.cluster.free_hosts():
            if covered + 1e-9 >= missing:
                return need
            covered += 1.0 / self.cluster.host_multiplier(h)
            need += 1
        if covered + 1e-9 < missing:
            need += math.ceil((missing - covered) * self.cluster.sim.slowdown
                              - 1e-9)
        return need

    def _droppable_hosts(self, name: str, eff: float,
                         target: float) -> List[int]:
        """Largest suffix of slowest hosts droppable without dipping below
        the capacity target (slowest-first: they cost a full host of fleet
        budget but contribute the least capacity)."""
        owned = sorted(self.cluster.owned(name),
                       key=lambda h: (-self.cluster.host_multiplier(h), -h))
        drop: List[int] = []
        remaining = eff
        for h in owned[:-1] if len(owned) > 1 else []:
            contribution = 1.0 / self.cluster.host_multiplier(h)
            if remaining - contribution + 1e-9 < target:
                break
            remaining -= contribution
            drop.append(h)
        return drop

    def _preempt_training_for(self, k: int, step: int, now_s: float,
                              dep_name: str, decisions: List[str]) -> None:
        """Free hosts for serving (until k more are free) by shrinking —
        then evicting — the training jobs with the most deadline slack."""
        goal = len(self.cluster.free_hosts()) + k
        while len(self.cluster.free_hosts()) < goal:
            victims = sorted(
                (j for j in self.jobs.values() if j.state == "running"),
                key=lambda j: (-self._slack(j, now_s), j.name))
            if not victims:
                return
            job = victims[0]
            # never shrink onto an m the model says cannot reach eps: the
            # job would hold hosts forever making no progress — evict it
            # (requeue) instead and let admission re-plan
            lower = [m for m in job.m_options if m < job.m
                     and job.remaining_s(m) is not None]
            if lower:
                target = max(lower)
                self._execute_resize(step, job, target, f"serve:{dep_name}",
                                     decisions)
                # a forced shrink is still a resize: start its cooldown so
                # the no-flap guard covers the follow-up grow as well
                self._last_resize[job.name] = step
                decisions.append(
                    f"preempt:{job.name}:m={target}:serve={dep_name}")
            else:
                self.cluster.release_all(job.name)
                self._rollback(step, job, decisions)
                job.state, job.m = "queued", 0
                decisions.append(f"evict:{job.name}:serve={dep_name}")

    def _slack(self, job: TrainingJob, now_s: float) -> float:
        rem = job.remaining_s(job.m) if job.m else job.remaining_s(
            min(job.m_options))
        if rem is None:
            return float("-inf")
        return (job.deadline_s - now_s) - rem

    # ------------------------------------------------------------------
    # 3. training admission (NoFeasiblePlan-aware)
    # ------------------------------------------------------------------
    def _admit_training(self, step: int, now_s: float,
                        decisions: List[str]) -> None:
        pending = sorted(
            (j for j in self.jobs.values()
             if j.state in ("pending", "queued") and j.arrival_s <= now_s),
            key=lambda j: (j.arrival_s, j.name))
        for job in pending:
            if job.state == "pending":
                job.state = "queued"
                decisions.append(f"queue:{job.name}")
            plan = job.admission_plan()
            if isinstance(plan, NoFeasiblePlan):
                job.state, job.no_plan = "infeasible", plan
                decisions.append(f"infeasible:{job.name}:{plan.query}")
                continue
            slack = job.deadline_s - now_s
            remaining = {m: (1.0 - job.progress) * t + job.penalty_s
                         for (_, m), t in sorted(plan.table.items())}
            feasible = {m: t for m, t in remaining.items() if t <= slack}
            if not feasible:
                fastest = min(remaining.values())
                job.no_plan = NoFeasiblePlan(
                    query="fleet_admission",
                    reason=f"fastest remaining {fastest:.0f}s on "
                           f"m={min(remaining, key=remaining.get)} exceeds "
                           f"deadline slack {slack:.0f}s",
                    table={(job.name, m): t for m, t in remaining.items()})
                job.state = "infeasible"
                decisions.append(f"infeasible:{job.name}:fleet_admission")
                continue
            free = len(self.cluster.free_hosts())
            affordable = {m: t for m, t in feasible.items() if m <= free}
            if not affordable:
                continue   # stays queued; retried next tick
            target = min(affordable, key=lambda m: (m * affordable[m], m))
            self.cluster.allocate(job.name, target)
            job.state, job.m = "running", target
            job.since_ckpt_s = 0.0
            if job.executor is not None:
                job.executor.resize(target)
                job.executor.checkpoint()
            self._last_resize[job.name] = step
            decisions.append(f"admit:{job.name}:m={target}")

    # ------------------------------------------------------------------
    # 4. training resize (the AdaptiveController tradeoff, fleet-wide)
    # ------------------------------------------------------------------
    def _resize_training(self, step: int, now_s: float,
                         decisions: List[str]) -> None:
        for name in sorted(self.jobs):
            job = self.jobs[name]
            if job.state != "running":
                continue
            slack = job.deadline_s - now_s
            free = len(self.cluster.free_hosts())
            rem_cur = job.remaining_s(job.m)
            # rem_cur None = the current m cannot reach eps at all: the
            # most at-risk state there is (progress is frozen)
            at_risk = rem_cur is None or rem_cur > slack
            in_cooldown = (step - self._last_resize.get(name, -10 ** 9)
                           < self.cfg.resize_cooldown_ticks)
            # rescues and drift-triggered replans don't wait out no-flap
            replan = name in self._needs_replan
            self._needs_replan.discard(name)
            if in_cooldown and not (at_risk or replan):
                continue
            candidates: Dict[int, float] = {}
            # price a resize with the measured recovery cost once it has
            # been learned (cfg.measured), the assumed constant otherwise
            reshard_s = self._planned_recovery_s(job, self.cfg.reshard_cost_s)
            for m in job.m_options:
                if m != job.m and m > job.m + free:
                    continue
                rem = job.remaining_s(m)
                if rem is None:
                    continue
                candidates[m] = rem + (reshard_s if m != job.m else 0.0)
            if not candidates:
                continue
            # shrinking trades slack for cost; demand a safety margin so a
            # later deadline rescue (and its reshard cost) never follows
            meeting = {m: t for m, t in candidates.items()
                       if t <= (slack * self.cfg.shrink_safety
                                if m < job.m else slack)}
            pool = meeting or candidates
            # minimize host-seconds among deadline-feasible sizes; if none
            # is feasible, minimize lateness instead (max useful speed)
            if meeting:
                target = min(pool, key=lambda m: (m * pool[m], m))
            else:
                target = min(pool, key=lambda m: (pool[m], m))
            if target == job.m:
                continue
            deadline_rescue = at_risk and candidates[target] <= slack
            cheaper = (rem_cur is not None and target * candidates[target]
                       < self.cfg.resize_hysteresis * job.m * rem_cur)
            if not (deadline_rescue or cheaper):
                continue
            why = "deadline" if deadline_rescue else "cost"
            self.resize_decisions.append(ResizeDecision(
                resize=True, target_m=target,
                reason=f"{job.name}: predicted remaining "
                       f"{candidates[target]:.0f}s on m={target} vs "
                       f"{'inf' if rem_cur is None else f'{rem_cur:.0f}s'} "
                       f"on m={job.m} ({why})",
                predicted_remaining_current=rem_cur,
                predicted_remaining_target=candidates[target]))
            old = job.m
            self._execute_resize(step, job, target, why, decisions)
            self._last_resize[name] = step
            decisions.append(f"resize:{name}:{old}->{target}:{why}")

    def _execute_resize(self, step: int, job: TrainingJob, target: int,
                        why: str, decisions: List[str]) -> None:
        if target > job.m:
            self.cluster.allocate(job.name, target - job.m)
        else:
            # BSP runs at the slowest member: a shrink keeps the fastest
            # hosts or the remaining-time model it was priced with is wrong
            keep = sorted(self.cluster.owned(job.name),
                          key=lambda h: (self.cluster.host_multiplier(h), h))
            self.cluster.release(job.name, keep[target:])
        job.m = target
        self._charge_recovery(step, job, "reshard", self.cfg.reshard_cost_s,
                              decisions)
        if job.executor is not None:
            # the chaos executor contract: checkpoint, then re-shard onto
            # the new parallelism (SSPLocalSGD re-partitions; the LM
            # TrainerExecutor routes through elastic.rescale_training_state)
            job.executor.checkpoint()
            job.executor.resize(target)

    # ------------------------------------------------------------------
    # 5. progress + 6. serve accounting
    # ------------------------------------------------------------------
    def _account_training(self, step: int, now_s: float,
                          decisions: List[str]) -> None:
        for name in sorted(self.jobs):
            job = self.jobs[name]
            if job.state != "running":
                continue
            pace = self.cluster.bsp_pace(name)   # >= 1: slowest-host drag
            work_s = self.cfg.tick_s / pace
            if self.cfg.drift is not None:
                self._observe_pace(step, job, pace, decisions)
            paid = min(job.penalty_s, work_s)
            job.penalty_s -= paid
            work_s -= paid
            t_full = job.time_to_eps(job.m)
            if t_full is None:
                continue
            job.progress = min(job.progress + work_s / t_full, 1.0)
            job.since_ckpt_s += self.cfg.tick_s
            if job.executor is not None:
                job.objective = float(job.executor.outer_step())
            if job.progress >= 1.0:
                job.state = "done"
                job.finish_s = now_s + self.cfg.tick_s
                self.cluster.release_all(name)
                job.m = 0
                decisions.append(f"complete:{name}")
            elif job.since_ckpt_s >= job.ckpt_every_s:
                job.ckpt_progress = job.progress
                job.since_ckpt_s = 0.0
                if job.executor is not None:
                    job.executor.checkpoint()

    def _observe_pace(self, step: int, job: TrainingJob, pace: float,
                      decisions: List[str]) -> None:
        """Streaming refit of the job's pace model (cfg.drift opt-in).

        The remaining-time model assumes the cluster delivers
        ``tick_s / pace_factor`` seconds of useful work per tick; the
        measured delivery is ``tick_s / pace``.  When the normalized
        residual between the two drifts past the threshold (a sustained
        slowdown, not a one-tick blip), refit ``pace_factor`` to the
        trailing-window mean pace — which rescales ``remaining_s`` for
        every m — emit the typed drift/refit events, and force a
        replanning pass through ``_resize_training`` next tick."""
        name = job.name
        cfgd = self.cfg.drift
        det = self._drift.get(name)
        if det is None:
            det = self._drift[name] = DriftDetector(f"pace:{name}", cfgd)
            self._pace_window[name] = deque(maxlen=cfgd.window)
        window = self._pace_window[name]
        window.append(pace)
        predicted = self.cfg.tick_s / job.pace_factor
        actual = self.cfg.tick_s / pace
        drift = det.observe(step, predicted, actual)
        if drift is None:
            return
        self.pending_events.append(drift)
        decisions.append(f"drift:{name}")
        # refit from the new regime only: the trailing run of window points
        # whose own residual (vs the stale model) exceeds the threshold —
        # averaging in pre-drift points would split the difference between
        # regimes and under-correct
        recent = list(window)
        for i in range(len(recent) - 1, -1, -1):
            err = abs(self.cfg.tick_s / recent[i] - predicted) / predicted
            if err <= cfgd.threshold:
                recent = recent[i + 1:]
                break
        recent = recent or list(window)
        new_factor = sum(recent) / len(recent)
        after = sum(
            abs(self.cfg.tick_s / p - self.cfg.tick_s / new_factor)
            / (self.cfg.tick_s / new_factor)
            for p in recent
        ) / len(recent)
        job.pace_factor = new_factor
        self.pending_events.append(RefitEvent(
            step=step, model=f"pace:{name}", n_obs=len(recent),
            residual_before=drift.residual, residual_after=after))
        det.reset()
        self._needs_replan.add(name)

    def _account_serve(self, step: int,
                       preempted: Dict[str, List[int]],
                       decisions: List[str]) -> Dict[str, Any]:
        out: Dict[str, Any] = {}
        for name in sorted(self.deployments):
            dep = self.deployments[name]
            demand = dep.trace.qps_at(step)
            eff = self.cluster.effective_replicas(
                name, exclude=preempted.get(name, []))
            if eff <= 0.0:
                lat = 4.0 * dep.slo_p95_s   # nothing serving: hard breach
            else:
                lat = dep.tick_latency(eff, demand)
            dep.latencies.append(lat)
            if self.cfg.slo is not None:
                self._observe_slo(step, name, dep, lat, decisions)
            out[name] = dep.snapshot(demand, lat)
        return out

    def _observe_slo(self, step: int, name: str, dep, lat: float,
                     decisions: List[str]) -> None:
        """Stream this tick's modeled latency through the deployment's SLO
        burn-rate monitor (cfg.slo opt-in).  A fast-burn alert — a couple
        of bad points in a short window — fires ticks before the drift
        detector's windowed residual mean can, so the alert both rides the
        bus (``CapacityPlanner.ingest`` consumes it) and grants the
        autoscaler ``SLO_BOOST`` extra headroom for ``SLO_BOOST_TICKS``."""
        mon = self._slo.get(name)
        if mon is None:
            moncfg = dataclasses.replace(self.cfg.slo, target=dep.slo_p95_s)
            mon = self._slo[name] = SLOMonitor(
                moncfg, name=name, objective="tick_p95_latency")
        alert = mon.observe(step, lat)
        if alert is not None:
            self.pending_events.append(alert)
            self._slo_boost_until[name] = step + 1 + SLO_BOOST_TICKS
            decisions.append(
                f"slo_alert:{name}:burn={alert.burn_rate:.2f}")

    # ------------------------------------------------------------------
    # 7. trace spans over modeled time (cfg.spans opt-in)
    # ------------------------------------------------------------------
    def _emit_tick_spans(self, step: int, now_s: float,
                        serve_row: Dict[str, Any]) -> None:
        """One modeled-time span tree per tick: a ``fleet.tick`` root of
        ``tick_s`` wall, a ``fleet.train`` child per running job (measured
        dur = the useful work the cluster delivered, ``tick_s / pace``;
        predicted = what the pace model promised, ``tick_s / pace_factor``
        — attribution's ratio column localizes pace drift per job), and a
        ``fleet.serve`` child per deployment (dur = modeled tick latency,
        predicted = its p95 target).  Ids derive from (config, step, name)
        only, so same-scenario runs emit byte-identical span streams."""
        tick_id = det_id(self._trace_id, "tick", step)
        spans = [SpanEvent(
            trace_id=self._trace_id, span_id=tick_id, name="tick",
            t0=now_s, dur=self.cfg.tick_s, component="fleet.tick",
            step=step, replica=0,
            attrs={"free": len(self.cluster.free_hosts())})]
        for name in sorted(self.jobs):
            job = self.jobs[name]
            if job.state != "running" or job.m == 0:
                continue
            pace = self.cluster.bsp_pace(name)
            spans.append(SpanEvent(
                trace_id=self._trace_id,
                span_id=det_id(tick_id, "train", name),
                parent_id=tick_id, name=f"train:{name}", t0=now_s,
                dur=self.cfg.tick_s / pace,
                predicted_s=self.cfg.tick_s / job.pace_factor,
                component="fleet.train", step=step,
                replica=self._lane[name],
                attrs={"m": job.m, "progress": round(job.progress, 9)}))
        for name, row in sorted(serve_row.items()):
            dep = self.deployments[name]
            spans.append(SpanEvent(
                trace_id=self._trace_id,
                span_id=det_id(tick_id, "serve", name),
                parent_id=tick_id, name=f"serve:{name}", t0=now_s,
                dur=float(row["lat_s"]), predicted_s=dep.slo_p95_s,
                component="fleet.serve", step=step,
                replica=self._lane[name],
                attrs={"m": row["m"], "qps": row["qps"],
                       "ok": row["ok"]}))
        self.pending_events.extend(spans)
