"""Fleet event loop + the replayable FleetRunLog artifact.

``run_fleet_sim(seed)`` is the canonical entry point (mirrors
``runtime.chaos.run_chaos_sim``): build the day scenario deterministically
from one seed, drive ``FleetScheduler`` tick by tick through the chaos
trace, and emit a ``FleetRunLog`` that serializes to JSON and **replays
bit-identically** from its embedded trace + meta — same guarantee, and
the same golden-fixture testing pattern, as the chaos layer.

The canonical 24h scenario (``build_day_scenario``): 288 five-minute
ticks on 24 hosts; two serving deployments under diurnal/bursty request
traces (a big midday-peaking "chat" and a smaller evening "search") and
three training jobs arriving through the day, with seeded chaos
(stragglers, slowdowns, preemptions, membership churn) layered on top.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

from repro.fleet.cluster import FleetCluster
from repro.fleet.scheduler import FleetConfig, FleetScheduler
from repro.fleet.workloads import (
    RequestTrace,
    ServeDeployment,
    TrainingJob,
    serve_capacity_planner,
    training_model,
)
from repro.runtime.chaos import ChaosEvent, ChaosRunLog, ChaosTrace
from repro.telemetry import DriftConfig, warn_deprecated
from repro.telemetry.trace import SloConfig

# Default burn-rate tunables for --slo runs: the per-deployment target is
# substituted by the scheduler (each deployment's own slo_p95_s); a short
# window with min_points=2 fires on the second breached tick, several
# ticks before the drift detector's windowed residual mean can react.
DEFAULT_FLEET_SLO = SloConfig(target=1.0, budget=0.05, window=8,
                              burn_threshold=2.0, min_points=2, cooldown=12)


# ---------------------------------------------------------------------------
# Run log
# ---------------------------------------------------------------------------
class FleetRunLog(ChaosRunLog):
    """ChaosRunLog's trace+rows+meta JSON artifact, with fleet semantics:
    the signature covers scheduler decisions, allocations, and the modeled
    serve/training outcomes.  Rows ride the telemetry bus as typed
    ``FleetTickEvent``s (kind ``fleet_tick``); scheduler drift/refit
    events share the same tracker but stay out of ``rows``/signatures."""

    EVENT_KIND = "fleet_tick"
    LOG_TYPE = "fleet"

    def signature(self) -> List[tuple]:
        """The full sequence in-process replay must reproduce exactly: per
        tick, every scheduler decision plus the allocation/latency/progress
        outcome (floats included — same machine, same bits)."""
        out = []
        for r in self.rows:
            serve = tuple((n, s["m"], s["lat_s"])
                          for n, s in sorted(r["serve"].items()))
            jobs = tuple((n, s["state"], s["m"], s["prog"])
                         for n, s in sorted(r["jobs"].items()))
            out.append((r["step"], tuple(r["decisions"]), serve, jobs,
                        r["free"], r["cost_hh"]))
        return out

    def control_signature(self) -> List[tuple]:
        """The machine-portable slice of the signature: decisions,
        allocations, and states only — no floats, so it compares exactly
        against a golden fixture recorded on another machine (modeled
        quantities are compared to tolerance in tests/test_fleet.py)."""
        out = []
        for r in self.rows:
            serve = tuple((n, s["m"], s["ok"])
                          for n, s in sorted(r["serve"].items()))
            jobs = tuple((n, s["state"], s["m"])
                         for n, s in sorted(r["jobs"].items()))
            out.append((r["step"], tuple(r["decisions"]), serve, jobs,
                        r["free"]))
        return out

    def n_decisions(self) -> int:
        return sum(len(r["decisions"]) for r in self.rows)

    def decisions(self, prefix: str = "") -> List[Tuple[int, str]]:
        return [(r["step"], d) for r in self.rows for d in r["decisions"]
                if d.startswith(prefix)]

    def fleet_cost_host_hours(self) -> float:
        warn_deprecated("FleetRunLog.fleet_cost_host_hours()",
                        'events("fleet_tick")[-1].cost_hh')
        rows = self.rows
        return rows[-1]["cost_hh"] if rows else 0.0


# ---------------------------------------------------------------------------
# Simulator
# ---------------------------------------------------------------------------
class FleetSimulator:
    """Drives scheduler ticks through a chaos trace; no entropy of its own."""

    def __init__(self, trace: ChaosTrace, jobs, deployments,
                 cfg: Optional[FleetConfig] = None):
        self.trace = trace
        self.cluster = FleetCluster(trace)
        self.scheduler = FleetScheduler(self.cluster, jobs, deployments, cfg)

    def run(self, steps: Optional[int] = None) -> FleetRunLog:
        steps = self.trace.steps if steps is None else steps
        sched = self.scheduler
        log = FleetRunLog(trace=self.trace, meta={
            "tick_s": sched.cfg.tick_s, "n_hosts": self.trace.n_hosts})
        for step in range(steps):
            events, lost, preempted = self.cluster.advance(step)
            log.append(**sched.tick(step, events, lost, preempted))
            # drift/refit events ride the same bus, outside rows/signature
            for ev in sched.drain_events():
                log.emit(ev)
        log.meta["summary"] = self.summary()
        return log

    def summary(self) -> Dict[str, Any]:
        sched = self.scheduler
        serve = {}
        for name, dep in sorted(sched.deployments.items()):
            serve[name] = {
                "p95_s": round(dep.p95_latency(), 9),
                "slo_p95_s": dep.slo_p95_s,
                "slo_met": bool(dep.slo_met()),
                "final_replicas": dep.replicas,
            }
        jobs = {}
        for name, job in sorted(sched.jobs.items()):
            jobs[name] = {
                "state": job.state,
                "progress": round(job.progress, 9),
                "finish_s": job.finish_s,
                "deadline_s": job.deadline_s,
                "met_deadline": bool(job.state == "done"
                                     and job.finish_s is not None
                                     and job.finish_s <= job.deadline_s),
                "no_plan": (None if job.no_plan is None
                            else {"query": job.no_plan.query,
                                  "reason": job.no_plan.reason}),
            }
        return {"serve": serve, "jobs": jobs,
                "cost_host_hours": round(sched.cost_host_s / 3600.0, 6),
                "n_resize_decisions": len(sched.resize_decisions)}


# ---------------------------------------------------------------------------
# The canonical 24h scenario
# ---------------------------------------------------------------------------
DAY_TICKS = 288
DAY_TICK_S = 300.0
DAY_HOSTS = 24


def build_day_scenario(seed: int, *, ticks: int = DAY_TICKS,
                       tick_s: float = DAY_TICK_S,
                       n_hosts: int = DAY_HOSTS,
                       trace: Optional[ChaosTrace] = None):
    """(trace, jobs, deployments, cfg) for the canonical diurnal day.

    Deterministic in ``seed``; a recorded trace can be passed back in for
    replay.  Preemptions are guaranteed: if the seeded draw produced none,
    one is injected mid-day (the scenario exists to exercise them)."""
    if trace is None:
        trace = ChaosTrace.generate(seed, ticks, n_hosts, warmup=12)
        # the seeded draw rarely preempts *busy* hosts (the allocator hands
        # out low ids first, the draw is uniform), so the scenario injects
        # two guaranteed preemptions where the work is: one on an early
        # serve replica, one on an early training host
        trace.events.extend([
            ChaosEvent(step=min(60, ticks - 1), kind="preempt", host=4),
            ChaosEvent(step=min(200, ticks - 1), kind="preempt", host=1),
        ])
        trace.events.sort(key=lambda e: (e.step, e.host, e.kind))

    hour = 3600.0
    jobs = [
        # overnight-scale run, arrives early, comfortable deadline
        TrainingJob(
            name="job_convex", eps=1e-2, arrival_s=0.5 * hour,
            deadline_s=20.0 * hour, m_options=(2, 4, 8),
            model=training_model(compute_s=36.0, rate=3.2e-3),
            ckpt_every_s=6 * tick_s),
        # mid-morning arrival, tighter deadline -> wants a bigger m
        TrainingJob(
            name="job_lm", eps=1e-2, arrival_s=4.0 * hour,
            deadline_s=18.0 * hour, m_options=(2, 4, 8),
            model=training_model(compute_s=52.0, rate=2.6e-3),
            ckpt_every_s=6 * tick_s),
        # small afternoon job; fits in the evening trough
        TrainingJob(
            name="job_sweep", eps=1e-2, arrival_s=9.0 * hour,
            deadline_s=23.5 * hour, m_options=(1, 2, 4),
            model=training_model(compute_s=14.0, rate=6.0e-3),
            ckpt_every_s=6 * tick_s),
    ]
    deployments = [
        ServeDeployment(
            name="serve_chat",
            planner=serve_capacity_planner(dispatch_s=0.018,
                                           per_seq_s=0.0042,
                                           log_b_s=0.002),
            trace=RequestTrace.diurnal(seed * 7919 + 1, ticks, tick_s,
                                       base_qps=2.0, peak_qps=11.0,
                                       peak_frac=0.55),
            slo_p95_s=4.5, gen_tokens=64,
            batch_grid=(1, 2, 4, 8), replica_options=tuple(range(1, 13))),
        ServeDeployment(
            name="serve_search",
            planner=serve_capacity_planner(dispatch_s=0.012,
                                           per_seq_s=0.0030,
                                           log_b_s=0.001),
            trace=RequestTrace.diurnal(seed * 7919 + 2, ticks, tick_s,
                                       base_qps=1.0, peak_qps=6.0,
                                       peak_frac=0.80),
            slo_p95_s=2.5, gen_tokens=32,
            batch_grid=(1, 2, 4, 8), replica_options=tuple(range(1, 9))),
    ]
    cfg = FleetConfig(tick_s=tick_s)
    return trace, jobs, deployments, cfg


# ---------------------------------------------------------------------------
# The drift scenario: a sustained cluster slowdown mid-run
# ---------------------------------------------------------------------------
DRIFT_TICKS = 192
DRIFT_TICK_S = 300.0
DRIFT_HOSTS = 16


def build_drift_scenario(seed: int, *, ticks: int = DRIFT_TICKS,
                         tick_s: float = DRIFT_TICK_S,
                         n_hosts: int = DRIFT_HOSTS,
                         trace: Optional[ChaosTrace] = None,
                         drift: bool = True):
    """(trace, jobs, deployments, cfg) for the streaming-refit scenario.

    An otherwise-quiet cluster takes a sustained 2x cluster-wide slowdown
    for the middle third of the run.  The one training job's deadline is
    sized so its admitted (cheapest) m=2 meets it comfortably at modeled
    pace but misses it at 2x.  With the streaming refit on the detector
    fires within a few ticks of onset, ``pace_factor`` is refit from the
    new-regime window (rescaling ``remaining_s`` for every m), and the
    forced replanning pass rescues the deadline immediately (m=2 -> 8 at
    seed 0).  With ``drift=False`` the same scenario runs open-loop: the
    stale model only notices via lagging *progress* ~40 ticks later, and
    its panicked late resizes no longer make the deadline — the control
    arm the tests compare against."""
    if trace is None:
        # background chaos off: the scenario isolates the drift signal
        trace = ChaosTrace.generate(seed, ticks, n_hosts, p_straggler=0.0,
                                    p_slowdown=0.0, p_preempt=0.0,
                                    p_membership=0.0, warmup=12)
        trace.events.append(ChaosEvent(
            step=ticks // 3, kind="slowdown", host=-1, magnitude=2.0,
            duration=ticks // 3))
        trace.events.sort(key=lambda e: (e.step, e.host, e.kind))

    horizon = ticks * tick_s
    jobs = [
        TrainingJob(
            name="job_drift", eps=1e-2, arrival_s=0.0,
            deadline_s=0.70 * horizon, m_options=(2, 4, 8),
            model=training_model(compute_s=36.0, rate=3.2e-3),
            ckpt_every_s=6 * tick_s),
    ]
    deployments = [
        ServeDeployment(
            name="serve_bg",
            planner=serve_capacity_planner(dispatch_s=0.012,
                                           per_seq_s=0.0030,
                                           log_b_s=0.001),
            trace=RequestTrace.diurnal(seed * 7919 + 3, ticks, tick_s,
                                       base_qps=1.0, peak_qps=3.0,
                                       burst_prob=0.0),
            slo_p95_s=2.5, gen_tokens=32,
            batch_grid=(1, 2, 4, 8), replica_options=tuple(range(1, 5))),
    ]
    drift_cfg = DriftConfig(window=8, threshold=0.25, min_points=4,
                            cooldown=16) if drift else None
    cfg = FleetConfig(tick_s=tick_s, drift=drift_cfg)
    return trace, jobs, deployments, cfg


# ---------------------------------------------------------------------------
# The migration scenario: measured recovery costs flip a resize decision
# ---------------------------------------------------------------------------
MIG_TICKS = 96
MIG_TICK_S = 300.0
MIG_HOSTS = 12


def build_migration_scenario(seed: int, *, ticks: int = MIG_TICKS,
                             tick_s: float = MIG_TICK_S,
                             n_hosts: int = MIG_HOSTS,
                             trace: Optional[ChaosTrace] = None,
                             measured: bool = True):
    """(trace, jobs, deployments, cfg) for the measured-recovery-cost loop.

    The scheduler's planning constants still price a restore/re-shard as a
    stop-the-world 1800s event, but the job actually recovers in 40s (the
    async sharded checkpoint + live migration path:
    ``actual_recovery_s=40``).  Four early injected preemptions make the
    job pay — and, with ``measured=True``, *measure* — real restores; the
    drift detector sees the 1800s assumption is ~45x off and refits the
    per-job recovery estimate to the measured 40s.

    The deadline forces admission at m=4 (m=2 alone cannot make it from a
    standing start).  Mid-run, once most of the work is done, shrinking to
    m=2 becomes the cheaper host-second plan — but only if a re-shard
    costs 40s; priced at the assumed 1800s the shrink never clears the
    hysteresis + shrink-safety bar.  So the measured arm emits a
    ``resize:job_mig:4->2:cost`` decision and finishes cheaper; the
    control arm (``measured=False``, *same physics*: it also pays only
    40s per recovery) plans with the stale constant and holds m=4 to the
    end.  The flip is the acceptance artifact: a resize decision that
    exists in one arm and not the other, caused only by measurement."""
    if trace is None:
        # background chaos off: every recovery in the log is an injected,
        # deterministic one (same schedule for both arms)
        trace = ChaosTrace.generate(seed, ticks, n_hosts, p_straggler=0.0,
                                    p_slowdown=0.0, p_preempt=0.0,
                                    p_membership=0.0, warmup=4)
        # four preemptions on hosts the training job owns (serve_bg holds
        # at most hosts 0-1; job_mig is admitted onto the next four):
        # enough restore observations for min_points=3 plus one post-refit
        trace.events.extend([
            ChaosEvent(step=6, kind="preempt", host=3),
            ChaosEvent(step=12, kind="preempt", host=4),
            ChaosEvent(step=18, kind="preempt", host=3),
            ChaosEvent(step=24, kind="preempt", host=4),
        ])
        trace.events.sort(key=lambda e: (e.step, e.host, e.kind))

    # t_eps(4) ~= 14500s (~48 ticks); t_eps(2) ~= 1.56x that, so a
    # deadline of 1.2 * t_eps(4) rules m=2 out at admission
    model = training_model(compute_s=36.0, floor_s=0.05, log_s=0.02,
                           per_m_s=0.005, rate=4.7e-3)
    jobs = [
        TrainingJob(
            name="job_mig", eps=1e-2, arrival_s=0.0,
            deadline_s=17400.0, m_options=(2, 4, 8),
            model=model, ckpt_every_s=6 * tick_s,
            actual_recovery_s=40.0),
    ]
    deployments = [
        ServeDeployment(
            name="serve_bg",
            planner=serve_capacity_planner(dispatch_s=0.012,
                                           per_seq_s=0.0030,
                                           log_b_s=0.001),
            trace=RequestTrace.diurnal(seed * 7919 + 5, ticks, tick_s,
                                       base_qps=1.0, peak_qps=2.0,
                                       burst_prob=0.0),
            slo_p95_s=2.5, gen_tokens=32,
            batch_grid=(1, 2, 4, 8), replica_options=(1, 2)),
    ]
    measured_cfg = DriftConfig(window=8, threshold=0.3, min_points=3,
                               cooldown=8) if measured else None
    cfg = FleetConfig(tick_s=tick_s, reshard_cost_s=1800.0,
                      restore_cost_s=1800.0, measured=measured_cfg)
    return trace, jobs, deployments, cfg


_SCENARIOS = {
    "day": (build_day_scenario, DAY_TICKS, DAY_TICK_S, DAY_HOSTS),
    "drift": (build_drift_scenario, DRIFT_TICKS, DRIFT_TICK_S, DRIFT_HOSTS),
    "migrate": (build_migration_scenario, MIG_TICKS, MIG_TICK_S, MIG_HOSTS),
}


def run_fleet_sim(seed: int, *, ticks: Optional[int] = None,
                  tick_s: Optional[float] = None,
                  n_hosts: Optional[int] = None,
                  trace: Optional[ChaosTrace] = None,
                  scenario: str = "day",
                  drift: bool = False,
                  spans: bool = False,
                  slo: bool = False,
                  measured: bool = False) -> FleetRunLog:
    """One deterministic fleet run; everything derives from ``seed``.

    ``scenario`` picks the builder ("day" or "drift") and its defaults;
    ``drift`` turns the scheduler's streaming pace refit on, ``spans``
    the modeled-time trace spans, and ``slo`` the per-deployment burn-
    rate monitors (all off by default everywhere, so pre-existing
    goldens stay bit-identical)."""
    build, d_ticks, d_tick_s, d_hosts = _SCENARIOS[scenario]
    ticks = d_ticks if ticks is None else ticks
    tick_s = d_tick_s if tick_s is None else tick_s
    n_hosts = d_hosts if n_hosts is None else n_hosts
    kwargs = dict(ticks=ticks, tick_s=tick_s, n_hosts=n_hosts, trace=trace)
    if scenario == "drift":
        kwargs["drift"] = drift
    if scenario == "migrate":
        kwargs["measured"] = measured
    trace, jobs, deployments, cfg = build(seed, **kwargs)
    if drift and cfg.drift is None:
        cfg = dataclasses.replace(cfg, drift=DriftConfig())
    if spans and not cfg.spans:
        cfg = dataclasses.replace(cfg, spans=True)
    if slo and cfg.slo is None:
        cfg = dataclasses.replace(cfg, slo=DEFAULT_FLEET_SLO)
    # the horizon is the *requested* one, not the trace's: a recorded trace
    # longer (or shorter) than --ticks must not silently change the run
    log = FleetSimulator(trace, jobs, deployments, cfg).run(steps=ticks)
    log.meta.update(seed=seed, ticks=ticks, scenario=scenario, drift=drift)
    # only recorded when on: logs from before these opt-ins existed (and
    # runs with them off) keep byte-identical meta blocks
    if spans:
        log.meta["spans"] = True
    if slo:
        log.meta["slo"] = True
    if measured:
        log.meta["measured"] = True
    return log


def replay(run_log: FleetRunLog) -> FleetRunLog:
    """Re-run a recorded fleet run from its embedded trace + meta; the
    result must match ``run_log.signature()`` exactly."""
    meta = run_log.meta
    return run_fleet_sim(int(meta["seed"]), ticks=int(meta["ticks"]),
                         tick_s=float(meta["tick_s"]),
                         n_hosts=int(meta["n_hosts"]),
                         trace=run_log.trace,
                         scenario=meta.get("scenario", "day"),
                         drift=bool(meta.get("drift", False)),
                         spans=bool(meta.get("spans", False)),
                         slo=bool(meta.get("slo", False)),
                         measured=bool(meta.get("measured", False)))
