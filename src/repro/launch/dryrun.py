import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST stay the first statements: jax locks the device
count at first initialization, and smoke tests / benches must NOT see 512
devices (this module is the only place the flag is set).

Per cell this script:
  1. builds the production mesh (16x16 single-pod or 2x16x16 multi-pod),
  2. builds ShapeDtypeStructs for params / optimizer state / inputs with
     NamedShardings from the partitioning rules,
  3. ``jax.jit(step).lower(...).compile()`` — proving the distribution
     config is coherent (no sharding mismatches, compilable collectives),
  4. records memory_analysis / cost_analysis / per-device collective bytes
     into a JSON consumed by benchmarks/roofline.py and EXPERIMENTS.md.

Usage:
  python -m repro.launch.dryrun --arch qwen3-14b --shape train_4k \
      --mesh single --out results/dryrun
  python -m repro.launch.dryrun --all --mesh both --out results/dryrun
"""
import argparse
import dataclasses
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import (ARCH_IDS, applicable_shapes, get_config,
                           get_smoke_config)
from repro.configs.base import SHAPES_BY_NAME, ShapeSpec
from repro.dist.hlo_costs import analyze_hlo
from repro.dist.partitioning import Rules
from repro.launch.inputs import (
    batch_sds,
    decode_sds,
    opt_state_sds,
    params_sds,
    rules_for_cell,
    text_seq_len,
)
from repro.launch.mesh import make_production_mesh, make_scaled_mesh
from repro.models.model import LM
from repro.models.runtime import Runtime
from repro.training.optimizers import default_optimizer_for, get_optimizer
from repro.training.trainer import TrainConfig, make_train_step

# TPU v5e constants (roofline)
PEAK_FLOPS = 197e12      # bf16 / chip
HBM_BW = 819e9           # bytes/s / chip
LINK_BW = 50e9           # bytes/s / ICI link


def _mesh_chips(mesh) -> int:
    return int(mesh.devices.size)


def _runtime_for(cfg, mesh, rules) -> Runtime:
    return Runtime(mesh=mesh, rules=rules, remat="full",
                   mla_absorb=False)  # paper-faithful baseline: no absorption


def model_flops(cfg, shape: ShapeSpec) -> float:
    """6*N*D (train) / 2*N*D (prefill) / 2*N*B (decode), N = active params."""
    n_active = cfg.param_count(active_only=True)
    if shape.kind == "train":
        tokens = shape.global_batch * text_seq_len(cfg, shape)
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * text_seq_len(cfg, shape)
        return 2.0 * n_active * tokens
    return 2.0 * n_active * shape.global_batch


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               rules_overrides: dict | None = None,
               runtime_overrides: dict | None = None,
               serve_params_bf16: bool = False,
               mesh=None, smoke: bool = False):
    """Returns (lowered, compiled, context dict).

    ``mesh`` overrides the production mesh (the f(m) sweep passes scaled
    meshes); ``smoke`` swaps in the shrunk config so the sweep compiles in
    CPU-container time."""
    cfg = get_smoke_config(arch) if smoke else get_config(arch)
    shape = SHAPES_BY_NAME[shape_name]
    if mesh is None:
        mesh = make_production_mesh(multi_pod=multi_pod)
    rules = Rules.default(mesh)
    if rules_overrides:
        rules = rules.override(**rules_overrides)
    rules = rules_for_cell(rules, shape, mesh)
    rt = _runtime_for(cfg, mesh, rules)
    if runtime_overrides:
        rt = dataclasses.replace(rt, **runtime_overrides)
    lm = LM(cfg, rt)
    p_sds, p_axes = params_sds(lm, mesh, rules)
    if serve_params_bf16 and shape.kind != "train":
        # serving checkpoints ship in bf16 (half the weight-streaming bytes)
        p_sds = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(
                s.shape, jnp.bfloat16 if s.dtype == jnp.float32 else s.dtype,
                sharding=s.sharding), p_sds)

    with mesh:
        if shape.kind == "train":
            opt_name = default_optimizer_for(cfg.param_count())
            opt = get_optimizer(opt_name)
            o_sds = opt_state_sds(opt, p_sds, p_axes, mesh, rules)
            b_sds = batch_sds(cfg, shape, mesh, rules)
            step = make_train_step(lm, opt, TrainConfig())
            p_sh = jax.tree.map(lambda s: s.sharding, p_sds,
                                is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
            o_sh = jax.tree.map(lambda s: s.sharding, o_sds,
                                is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
            fn = jax.jit(step,
                         in_shardings=(p_sh, o_sh, None, None),
                         out_shardings=(p_sh, o_sh, None),
                         donate_argnums=(0, 1))
            step_sds = jax.ShapeDtypeStruct((), jnp.int32)
            lowered = fn.lower(p_sds, o_sds, b_sds, step_sds)
            extra = {"optimizer": opt_name}
        elif shape.kind == "prefill":
            b_sds = batch_sds(cfg, shape, mesh, rules)

            def prefill_fn(params, batch):
                return lm.prefill(params, batch["tokens"],
                                  batch.get("frontend_embeds"))

            fn = jax.jit(prefill_fn)
            lowered = fn.lower(p_sds, b_sds)
            extra = {}
        else:  # decode
            tok_sds, len_sds, cache_sds = decode_sds(cfg, shape, mesh, rules, lm)
            cache_sh = jax.tree.map(
                lambda s: s.sharding, cache_sds,
                is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
            fn = jax.jit(lm.decode_step,
                         in_shardings=(
                             jax.tree.map(lambda s: s.sharding, p_sds,
                                          is_leaf=lambda x: isinstance(
                                              x, jax.ShapeDtypeStruct)),
                             None, None, cache_sh),
                         out_shardings=(None, cache_sh),
                         donate_argnums=(3,))
            lowered = fn.lower(p_sds, tok_sds, len_sds, cache_sds)
            extra = {}
        compiled = lowered.compile()
    ctx = {"cfg": cfg, "shape": shape, "mesh": mesh, "rules": rules,
           **extra}
    return lowered, compiled, ctx


def analyze(lowered, compiled, ctx) -> dict:
    cfg, shape, mesh = ctx["cfg"], ctx["shape"], ctx["mesh"]
    chips = _mesh_chips(mesh)
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):   # jax>=0.4.30 returns [dict]
        cost = cost[0] if cost else {}
    hlo = compiled.as_text()
    # trip-count-aware costs (cost_analysis counts while bodies once; our
    # parser multiplies by static loop bounds — see dist/hlo_costs.py)
    parsed = analyze_hlo(hlo)
    flops_per_device = parsed.flops
    bytes_per_device = parsed.bytes_accessed
    coll_per_device = parsed.collective_operand_bytes
    wire_per_device = parsed.collective_wire_bytes
    breakdown = {k: int(v) for k, v in parsed.per_kind_operand.items()}
    breakdown_wire = {k: int(v) for k, v in parsed.per_kind_wire.items()}
    # spec formulas use global sums over chips
    hlo_flops = flops_per_device * chips
    hlo_bytes = bytes_per_device * chips
    coll_bytes = float(wire_per_device) * chips  # ring-model wire bytes
    t_compute = hlo_flops / (chips * PEAK_FLOPS)
    t_memory = hlo_bytes / (chips * HBM_BW)
    t_coll = coll_bytes / (chips * LINK_BW)
    mf = model_flops(cfg, shape)
    dominant = max(
        (("compute", t_compute), ("memory", t_memory), ("collective", t_coll)),
        key=lambda kv: kv[1])[0]
    mem_fields = {}
    for attr in ("temp_size_in_bytes", "argument_size_in_bytes",
                 "output_size_in_bytes", "alias_size_in_bytes",
                 "generated_code_size_in_bytes"):
        mem_fields[attr] = int(getattr(mem, attr, -1))
    return {
        "arch": cfg.name,
        "shape": shape.name,
        "kind": shape.kind,
        "mesh": list(mesh.devices.shape),
        "mesh_axes": list(mesh.axis_names),
        "chips": chips,
        "optimizer": ctx.get("optimizer"),
        "flops_per_device": flops_per_device,
        "bytes_per_device": bytes_per_device,
        "xla_cost_analysis_flops": float(cost.get("flops", 0.0)),
        "xla_cost_analysis_bytes": float(cost.get("bytes accessed", 0.0)),
        "n_while_loops": parsed.n_whiles,
        "collective_bytes_per_device": int(coll_per_device),
        "collective_wire_bytes_per_device": int(wire_per_device),
        "collective_breakdown_per_device": breakdown,
        "collective_wire_breakdown_per_device": breakdown_wire,
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "model_flops": mf,
        "useful_flops_ratio": mf / hlo_flops if hlo_flops else None,
        "memory_analysis": mem_fields,
        "n_params": cfg.param_count(),
        "n_params_active": cfg.param_count(active_only=True),
    }


def attach_tuned_kernels(result: dict, tune_cache_path: str) -> dict:
    """Additive: record autotuner-measured kernel timings next to the
    analytic roofline numbers, so the system model can be fitted on
    measured kernel costs instead of defaults.  Decode cells whose batch
    matches a measured paged-decode entry also get ``t_kernel_measured_s``
    (layers x measured kernel); entries at other batches are ignored
    rather than passed off as measurements of this cell."""
    from repro.kernels.tune import ConfigCache, bench_rows

    cache = ConfigCache(tune_cache_path)
    result["tuned_kernel_rows"] = [
        {"name": n, "us_per_call": us, "derived": d}
        for n, us, d in bench_rows(cache)
    ]
    if result.get("kind") == "decode":
        batch = SHAPES_BY_NAME[result["shape"]].global_batch
        matched = [
            e["us_per_call"] * 1e-6
            for e in cache.entries.values()
            if e["family"] == "flash_decode_paged" and e["shape"]["b"] == batch
        ]
        if matched:
            cfg = get_config(result["arch"])
            result["t_kernel_measured_s"] = cfg.n_layers * min(matched)
    return result


def run_cell(arch: str, shape_name: str, mesh_kind: str, out_dir: Path,
             force: bool = False, rules_overrides=None,
             runtime_overrides=None, tag: str = "",
             serve_params_bf16: bool = False,
             tune_cache: str | None = None) -> dict:
    multi = mesh_kind == "multi"
    suffix = f"-{tag}" if tag else ""
    out_path = out_dir / f"{arch}__{shape_name}__{mesh_kind}{suffix}.json"
    if out_path.exists() and not force:
        return json.loads(out_path.read_text())
    t0 = time.time()
    try:
        lowered, compiled, ctx = lower_cell(
            arch, shape_name, multi, rules_overrides, runtime_overrides,
            serve_params_bf16=serve_params_bf16)
        result = analyze(lowered, compiled, ctx)
        result["status"] = "ok"
        result["compile_seconds"] = time.time() - t0
        if tune_cache:
            result = attach_tuned_kernels(result, tune_cache)
    except Exception as e:  # noqa: BLE001 — recorded, sweep continues
        result = {"arch": arch, "shape": shape_name, "mesh_kind": mesh_kind,
                  "status": "error", "error": f"{type(e).__name__}: {e}",
                  "traceback": traceback.format_exc()[-4000:],
                  "compile_seconds": time.time() - t0}
    out_dir.mkdir(parents=True, exist_ok=True)
    out_path.write_text(json.dumps(result, indent=2))
    return result


def fm_sweep(arch: str, shape_name: str, chips: list[int], out_dir: Path,
             smoke: bool = False, force: bool = False) -> dict:
    """Hemingway f(m) from the roofline: lower the same (arch, shape) on
    meshes of increasing size, record the analytic step time per mesh, and
    fit ErnestModel on the (m, size, t_step) samples — the paper's system
    model built from compiled programs instead of cluster runs (§3.2.1,
    DESIGN.md §4)."""
    from repro.core.ernest import ErnestModel

    tag = "smoke" if smoke else "full"
    out_path = out_dir / f"fm__{arch}__{shape_name}__{tag}.json"
    if out_path.exists() and not force:
        return json.loads(out_path.read_text())
    samples = []
    for n in chips:
        t0 = time.time()
        mesh = make_scaled_mesh(n, model=min(16, n))
        m = int(mesh.devices.size)   # may be < n (data axis truncates)
        lowered, compiled, ctx = lower_cell(arch, shape_name, False,
                                            mesh=mesh, smoke=smoke)
        r = analyze(lowered, compiled, ctx)
        t_step = max(r["t_compute_s"], r["t_memory_s"], r["t_collective_s"])
        tokens = ctx["shape"].global_batch * text_seq_len(ctx["cfg"],
                                                          ctx["shape"])
        samples.append({"m": m, "size": tokens, "t_step_s": t_step,
                        "dominant": r["dominant"],
                        "t_compute_s": r["t_compute_s"],
                        "t_memory_s": r["t_memory_s"],
                        "t_collective_s": r["t_collective_s"],
                        "compile_seconds": time.time() - t0})
        print(f"[f(m)] m={m:4d} t_step={t_step:.3e}s "
              f"dom={r['dominant']} ({samples[-1]['compile_seconds']:.0f}s "
              "compile)", flush=True)
    model = ErnestModel().fit([s["m"] for s in samples],
                              [s["size"] for s in samples],
                              [s["t_step_s"] for s in samples])
    result = {"arch": arch, "shape": shape_name, "smoke": smoke,
              "samples": samples, "ernest_terms": list(model.term_names),
              "ernest_theta": model.coefficients(),
              "ernest_pct_err": list(model.percent_errors(
                  np.asarray([s["m"] for s in samples], float),
                  np.asarray([s["size"] for s in samples], float),
                  np.asarray([s["t_step_s"] for s in samples], float)))}
    out_dir.mkdir(parents=True, exist_ok=True)
    out_path.write_text(json.dumps(result, indent=2))
    print(f"[f(m)] theta: {result['ernest_theta']}", flush=True)
    return result


def all_cells():
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in applicable_shapes(cfg):
            yield arch, shape.name


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--fm", action="store_true",
                    help="f(m) sweep: step-time estimates across mesh sizes, "
                         "fitted with ErnestModel")
    ap.add_argument("--fm-chips", type=int, nargs="+",
                    default=[16, 32, 64, 128, 256])
    ap.add_argument("--smoke", action="store_true",
                    help="use the shrunk config (CPU-container compile times)")
    ap.add_argument("--tune-cache", default=None, metavar="PATH",
                    help="attach measured kernel timings from this "
                         "autotuner config cache to each cell's JSON")
    args = ap.parse_args()
    out_dir = Path(args.out)
    if args.fm:
        if not args.arch or not args.shape:
            ap.error("--fm requires --arch and --shape")
        fm_sweep(args.arch, args.shape, args.fm_chips, out_dir,
                 smoke=args.smoke, force=args.force)
        return
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    cells = list(all_cells()) if args.all else [(args.arch, args.shape)]
    for arch, shape in cells:
        for mk in meshes:
            r = run_cell(arch, shape, mk, out_dir, force=args.force,
                         tune_cache=args.tune_cache)
            status = r.get("status")
            if status == "ok":
                print(f"[ok]   {arch:24s} {shape:12s} {mk:6s} "
                      f"compute={r['t_compute_s']:.3e}s "
                      f"mem={r['t_memory_s']:.3e}s "
                      f"coll={r['t_collective_s']:.3e}s "
                      f"dom={r['dominant']:10s} "
                      f"({r['compile_seconds']:.0f}s compile)", flush=True)
            else:
                print(f"[FAIL] {arch:24s} {shape:12s} {mk:6s} "
                      f"{r.get('error', '?')}", flush=True)


if __name__ == "__main__":
    main()
