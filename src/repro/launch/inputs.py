"""ShapeDtypeStruct stand-ins for every model input (no device allocation).

``input_specs(cfg, shape, mesh, rules, lm)`` returns the exact pytree the
lowered step function consumes, with NamedShardings attached — the pattern
the dry-run uses for every (arch x shape x mesh) cell.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeSpec
from repro.dist.partitioning import Rules
from repro.models.model import LM


def text_seq_len(cfg: ArchConfig, shape: ShapeSpec) -> int:
    """Frontend-stub archs spend n_frontend_tokens of the sequence budget."""
    if shape.kind == "train" or shape.kind == "prefill":
        return shape.seq_len - cfg.n_frontend_tokens
    return shape.seq_len


def _sds(shape, dtype, sharding=None):
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype), sharding=sharding)


def batch_sds(cfg: ArchConfig, shape: ShapeSpec, mesh, rules: Rules) -> Dict:
    """Train/prefill batch ShapeDtypeStructs."""
    b = shape.global_batch
    s_text = text_seq_len(cfg, shape)
    sh = lambda axes, shape: (None if mesh is None
                              else rules.act_sharding(mesh, axes, shape))
    out: Dict = {"tokens": _sds((b, s_text), jnp.int32,
                                sh(("batch", "seq"), (b, s_text)))}
    if shape.kind == "train":
        out["labels"] = _sds((b, s_text), jnp.int32,
                             sh(("batch", "seq"), (b, s_text)))
    if cfg.frontend != "none":
        fshape = (b, cfg.n_frontend_tokens, cfg.d_model)
        out["frontend_embeds"] = _sds(
            fshape, jnp.float32, sh(("batch", "frontend_seq", None), fshape))
    return out


def decode_sds(cfg: ArchConfig, shape: ShapeSpec, mesh, rules: Rules,
               lm: LM) -> Tuple:
    """(tokens, lengths, cache) ShapeDtypeStructs for serve_step."""
    from repro.dist.treeutil import map_with_axes

    b = shape.global_batch
    sh = lambda axes, shape: (None if mesh is None
                              else rules.act_sharding(mesh, axes, shape))
    tokens = _sds((b,), jnp.int32, sh(("batch",), (b,)))
    lengths = _sds((b,), jnp.int32, sh(("batch",), (b,)))
    cache_shapes = jax.eval_shape(lambda: lm.init_cache(b, shape.seq_len))
    cache_axes = lm.cache_axes()

    def attach(sds_leaf, axes_leaf):
        return _sds(sds_leaf.shape, sds_leaf.dtype,
                    None if mesh is None
                    else rules.act_sharding(mesh, axes_leaf, sds_leaf.shape))

    cache = map_with_axes(attach, cache_shapes, cache_axes)
    return tokens, lengths, cache


def params_sds(lm: LM, mesh, rules: Rules):
    """(params SDS with shardings, axes tree)."""
    from repro.dist.treeutil import map_with_axes

    values_sds = lm.param_shapes()
    axes = lm.param_axes()

    def attach(sds_leaf, ax):
        return _sds(sds_leaf.shape, sds_leaf.dtype,
                    None if mesh is None
                    else rules.param_sharding(mesh, ax, sds_leaf.shape))

    return map_with_axes(attach, values_sds, axes), axes


def opt_state_sds(opt, params_sds_tree, param_axes, mesh, rules: Rules):
    from repro.dist.treeutil import map_with_axes

    state_sds = jax.eval_shape(opt.init, params_sds_tree)
    state_axes = opt.init_axes(param_axes)

    def attach(sds_leaf, ax):
        return _sds(sds_leaf.shape, sds_leaf.dtype,
                    None if mesh is None
                    else rules.param_sharding(mesh, ax, sds_leaf.shape))

    return map_with_axes(attach, state_sds, state_axes)


def rules_for_cell(base: Rules, shape: ShapeSpec, mesh) -> Rules:
    """Per-cell sharding adjustments.

    Long-context decode (global_batch < data-axis size): batch can't fill the
    data axis, so shard the KV-cache sequence over it instead (flash-decode
    combine falls out of GSPMD's partial softmax reductions)."""
    if shape.kind == "decode" and mesh is not None:
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        data = sizes.get("data", 1) * sizes.get("pod", 1)
        if shape.global_batch < data:
            return base.override(acts={
                "batch": None,
                "cache_batch": None,
                "cache_seq": ("pod", "data"),
            })
    return base
