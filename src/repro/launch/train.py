"""End-to-end training driver.

Wires together: config-driven model, optimizer, synthetic data pipeline,
sharded step function, async checkpointing, failure-injection + restart,
straggler monitoring, gradient compression, and the Hemingway adaptive
parallelism controller (observe loss -> refit g(i,m) -> elastic resize).

Usage (CPU example — a ~100M model for a few hundred steps):
  PYTHONPATH=src python -m repro.launch.train --arch stablelm-1.6b --smoke \
      --steps 200 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.checkpoint.manager import CheckpointManager
from repro.compression.gradient import CompressionConfig, GradientCompressor
from repro.configs import get_config, get_smoke_config
from repro.data.pipeline import SyntheticTokens
from repro.dist.partitioning import Rules
from repro.models.model import LM
from repro.models.runtime import Runtime
from repro.runtime.failures import FailureInjector, RestartPolicy, SimulatedFailure
from repro.runtime.straggler import StragglerMonitor
from repro.training.optimizers import get_optimizer
from repro.training.trainer import TrainConfig, lr_schedule, make_train_step


@dataclasses.dataclass
class TrainerOptions:
    arch: str = "stablelm-1.6b"
    smoke: bool = True
    steps: int = 100
    seq_len: int = 128
    global_batch: int = 8
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    seed: int = 0
    optimizer: str = "adamw"
    learning_rate: float = 1e-3
    local_steps: int = 1                 # H>1 => local-SGD outer sync
    compression: Optional[str] = None    # int8 | topk | powersgd
    mesh: Optional[Any] = None
    rules: Optional[Rules] = None
    failure_injector: Optional[FailureInjector] = None
    log_every: int = 10


class Trainer:
    """Restartable trainer; `run()` survives SimulatedFailure via restore."""

    def __init__(self, opts: TrainerOptions):
        self.opts = opts
        cfg = (get_smoke_config(opts.arch) if opts.smoke
               else get_config(opts.arch))
        self.cfg = cfg
        rt = Runtime(mesh=opts.mesh, rules=opts.rules,
                     remat="none" if opts.smoke else "full",
                     block_q=64, block_k=64, scan_chunk=32)
        self.lm = LM(cfg, rt)
        self.opt = get_optimizer(opts.optimizer)
        self.tcfg = TrainConfig(learning_rate=opts.learning_rate,
                                warmup_steps=20, total_steps=opts.steps,
                                local_steps=opts.local_steps)
        self.compressor = None
        if opts.compression:
            self.compressor = GradientCompressor(
                CompressionConfig(scheme=opts.compression))
        self.data = SyntheticTokens(
            cfg.vocab_size, opts.seq_len, opts.global_batch, seed=opts.seed,
            n_frontend=cfg.n_frontend_tokens, d_model=cfg.d_model)
        self.ckpt = (CheckpointManager(opts.ckpt_dir)
                     if opts.ckpt_dir else None)
        self.monitor = StragglerMonitor()
        self.history: list = []
        self._build_state()
        self._step_fn = self._make_step()

    # ------------------------------------------------------------------
    def _build_state(self):
        params, axes = self.lm.init(jax.random.PRNGKey(self.opts.seed))
        self.params = params
        self.param_axes = axes
        self.opt_state = self.opt.init(params)
        self.comp_state = (self.compressor.init_state(params)
                           if self.compressor else None)
        self.step = 0

    def _make_step(self):
        base = make_train_step(self.lm, self.opt, self.tcfg)
        return jax.jit(base, donate_argnums=(0, 1))

    # ------------------------------------------------------------------
    def _maybe_restore(self) -> bool:
        if self.ckpt is None:
            return False
        latest = self.ckpt.latest_step()
        if latest is None:
            return False
        tree, meta = self.ckpt.restore(latest)
        self.params = jax.tree.map(jnp.asarray, tree["params"])
        self.opt_state = jax.tree.map(jnp.asarray, tree["opt_state"])
        self.data.load_state_dict(meta["data_state"])
        self.step = int(meta["step"])
        return True

    def _save(self, block: bool = False):
        if self.ckpt is None:
            return
        handle = self.ckpt.save_async(
            self.step,
            {"params": self.params, "opt_state": self.opt_state},
            metadata={"data_state": self.data.state_dict(),
                      "arch": self.cfg.name})
        if block:
            handle.wait()

    # ------------------------------------------------------------------
    def train_some(self, n_steps: int) -> Dict[str, float]:
        last = {}
        for _ in range(n_steps):
            if self.opts.failure_injector is not None:
                self.opts.failure_injector.check(self.step)
            batch_np = self.data.next_batch()
            batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
            t0 = time.perf_counter()
            if self.compressor is not None:
                # compression applied at the sync boundary, outside jit state
                (loss_val, _), grads = jax.value_and_grad(
                    self.lm.loss_fn, has_aux=True)(self.params, batch)
                grads, self.comp_state = self.compressor.compress(
                    grads, self.comp_state)
                from repro.training.optimizers import clip_by_global_norm
                grads, gnorm = clip_by_global_norm(grads, self.tcfg.grad_clip)
                lr = lr_schedule(self.tcfg, jnp.float32(self.step))
                self.params, self.opt_state = self.opt.update(
                    grads, self.opt_state, self.params, lr)
                metrics = {"loss": loss_val, "grad_norm": gnorm}
            else:
                self.params, self.opt_state, metrics = self._step_fn(
                    self.params, self.opt_state, batch, jnp.int32(self.step))
            jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0
            self.monitor.observe(self.step, dt)
            last = {k: float(v) for k, v in metrics.items()}
            last["step_time"] = dt
            self.history.append((self.step, last["loss"]))
            if self.opts.log_every and self.step % self.opts.log_every == 0:
                print(f"step {self.step:5d} loss={last['loss']:.4f} "
                      f"({dt*1e3:.0f} ms)", flush=True)
            self.step += 1
            if self.ckpt and self.step % self.opts.ckpt_every == 0:
                self._save()
        return last

    # ------------------------------------------------------------------
    def run(self) -> Dict[str, float]:
        """Train to opts.steps with automatic failure recovery."""
        policy = RestartPolicy()
        self._maybe_restore()
        last: Dict[str, float] = {}
        while self.step < self.opts.steps:
            try:
                last = self.train_some(self.opts.steps - self.step)
            except SimulatedFailure as e:
                if not policy.should_restart():
                    raise
                print(f"[failure] {e}; restoring from checkpoint", flush=True)
                if self.ckpt:
                    self.ckpt.wait()
                if not self._maybe_restore():
                    self._build_state()
                self._step_fn = self._make_step()
        if self.ckpt:
            self._save(block=True)
            self.ckpt.wait()
        return last


# ---------------------------------------------------------------------------
# Chaos mode: the closed elastic loop over the REAL trainer
# ---------------------------------------------------------------------------
class TrainerExecutor:
    """Chaos-loop executor backed by the real LM Trainer.

    Implements the ``repro.runtime.chaos.ChaosLoop`` executor contract with
    the production mechanisms: ``checkpoint``/``restore`` go through the
    CheckpointManager, and ``resize`` rebuilds the trainer at the new
    data-parallel degree and re-places params + optimizer state onto the
    mesh via the elastic re-shard path (repro.runtime.elastic.rescale) from
    the latest checkpoint — the same move a multi-host deployment makes,
    executed here on the debug mesh."""

    def __init__(self, arch: str, m0: int, *, ckpt_dir: str,
                 batch_per_worker: int = 2, seq_len: int = 32,
                 total_steps: int = 200, seed: int = 0):
        from repro.launch.mesh import make_debug_mesh
        self.arch = arch
        self.batch_per_worker = batch_per_worker
        self.seq_len = seq_len
        self.total_steps = total_steps
        self.seed = seed
        self.ckpt_dir = ckpt_dir
        self.mesh = make_debug_mesh(1, 1)
        self.rules = Rules.default(self.mesh)
        self.m0 = m0      # the base TrainConfig lr corresponds to m0's batch
        self.m = 0
        self._build(m0)

    # ------------------------------------------------------------------
    def _opts(self, m: int) -> TrainerOptions:
        return TrainerOptions(
            arch=self.arch, smoke=True, steps=self.total_steps,
            seq_len=self.seq_len, global_batch=m * self.batch_per_worker,
            ckpt_dir=self.ckpt_dir, ckpt_every=10 ** 9,  # loop checkpoints
            seed=self.seed, log_every=0, mesh=self.mesh, rules=self.rules)

    def _build(self, m: int) -> None:
        from repro.training.trainer import rescaled_config
        # every rebuild starts from the BASE config, so the linear-scaling
        # ratio is always m/m0 — per-resize ratios would compound wrongly
        ratio = m / self.m0
        self.trainer = Trainer(self._opts(m))
        if ratio != 1.0:
            self.trainer.tcfg = rescaled_config(self.trainer.tcfg, ratio)
            self.trainer._step_fn = self.trainer._make_step()
        self.m = m

    def _place_from_checkpoint(self) -> None:
        """Host arrays -> sharded arrays on the current mesh (elastic path)."""
        from repro.runtime.elastic import rescale_training_state
        t = self.trainer
        tree, meta = t.ckpt.restore(t.ckpt.latest_step())
        placed = rescale_training_state(tree, self.mesh, self.rules,
                                        t.param_axes, t.opt)
        t.params, t.opt_state = placed["params"], placed["opt_state"]
        t.data.load_state_dict(meta["data_state"])
        t.step = int(meta["step"])

    # -- executor contract ---------------------------------------------
    def outer_step(self, sync_mask=None) -> float:
        metrics = self.trainer.train_some(1)
        return float(metrics["loss"])

    def checkpoint(self) -> None:
        self.trainer._save(block=True)
        self.trainer.ckpt.wait()

    def restore(self) -> None:
        self._place_from_checkpoint()

    def resize(self, m: int) -> None:
        self._build(m)
        self._place_from_checkpoint()

    def relax(self, local_steps: int) -> None:
        from repro.training.trainer import rescaled_config
        self.trainer.tcfg = rescaled_config(self.trainer.tcfg, 1.0,
                                            local_steps=local_steps)
        self.trainer._step_fn = self.trainer._make_step()

    def last_recovery_s(self, op: str) -> Optional[float]:
        """Measured wall-time of the most recent restore/re-shard, read
        from the CheckpointManager's timing log (both ops reduce to the
        same place-shards-from-manifest move, recorded as a restore)."""
        timing = self.trainer.ckpt.last_timing("restore")
        return None if timing is None else float(timing["wall_s"])


def run_chaos_lm(arch: str, trace, ckpt_dir: str, *, m0: int = 1,
                 m_options=(1, 2, 4), seed: int = 0):
    """Closed-loop elastic training of a real (smoke) LM under a chaos
    trace: simulated step times + failures, real losses, real checkpoint
    restores, real mesh re-shards."""
    from repro.core.adaptive import AdaptiveController
    from repro.runtime.chaos import ChaosLoop, ClusterSim, default_system_model
    from repro.telemetry import DriftConfig, StreamingCost

    executor = TrainerExecutor(arch, m0, ckpt_dir=ckpt_dir,
                               total_steps=trace.steps, seed=seed)
    system = default_system_model()
    # objective = train loss; loss > 0 so p_star=0 is a valid gap floor
    controller = AdaptiveController(
        system, target_gap=1.0, p_star=0.0, m_options=m_options,
        refit_every=15, window=80, reshard_cost_s=2.0, min_observations=20)
    # the real trainer reports real restore wall-times (CheckpointManager
    # timings), so the loop charges — and learns — measured recovery costs
    # instead of the assumed constants; ckpt_cost/drift/refit events ride
    # the run log's bus outside rows/signatures
    measured = StreamingCost(
        "recovery:lm", controller.reshard_cost_s,
        DriftConfig(window=8, threshold=0.5, min_points=3, cooldown=8))
    loop = ChaosLoop(ClusterSim(trace), executor, controller,
                     base_compute_s=1.0, d=64, ckpt_every=10,
                     restore_cost_s=3.0, measured_costs=measured)
    log = loop.run()
    log.meta.update(seed=seed, arch=arch, mode="lm")
    return log


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--optimizer", default="adamw")
    ap.add_argument("--compression", default=None)
    ap.add_argument("--chaos", default=None, metavar="TRACE.json",
                    help="run the closed-loop elastic trainer under this "
                         "chaos trace (generated with --chaos-seed if the "
                         "file does not exist)")
    ap.add_argument("--chaos-seed", type=int, default=0)
    ap.add_argument("--chaos-out", default=None,
                    help="write the replayable run log JSON here")
    args = ap.parse_args()
    if args.chaos is not None:
        import tempfile
        from pathlib import Path

        from repro.runtime.chaos import ChaosTrace
        path = Path(args.chaos)
        if path.exists():
            trace = ChaosTrace.load(path)
        else:
            trace = ChaosTrace.generate(args.chaos_seed, args.steps,
                                        n_hosts=4)
            trace.save(path)
            print(f"[chaos] generated trace -> {path}")
        ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="chaos_ckpt_")
        log = run_chaos_lm(args.arch, trace, ckpt_dir,
                           seed=args.chaos_seed)
        if args.chaos_out:
            log.save(args.chaos_out)
            print(f"[chaos] run log -> {args.chaos_out}")
        print(f"[chaos] steps={len(log.rows)} mitigations="
              f"{log.n_mitigations()} resizes={log.n_resizes()} "
              f"final_m={log.meta['final_m']} "
              f"final_loss={log.meta['final_objective']:.4f}")
        return
    opts = TrainerOptions(arch=args.arch, smoke=args.smoke, steps=args.steps,
                          seq_len=args.seq_len, global_batch=args.global_batch,
                          ckpt_dir=args.ckpt_dir, optimizer=args.optimizer,
                          compression=args.compression)
    trainer = Trainer(opts)
    last = trainer.run()
    print("final:", last)


if __name__ == "__main__":
    main()
