"""End-to-end training driver.

Wires together: config-driven model, optimizer, synthetic data pipeline,
sharded step function, async checkpointing, failure-injection + restart,
straggler monitoring, gradient compression, and the Hemingway adaptive
parallelism controller (observe loss -> refit g(i,m) -> elastic resize).

Usage (CPU example — a ~100M model for a few hundred steps):
  PYTHONPATH=src python -m repro.launch.train --arch stablelm-1.6b --smoke \
      --steps 200 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.checkpoint.manager import CheckpointManager
from repro.compression.gradient import CompressionConfig, GradientCompressor
from repro.configs import get_config, get_smoke_config
from repro.data.pipeline import SyntheticTokens
from repro.dist.partitioning import Rules
from repro.models.model import LM
from repro.models.runtime import Runtime
from repro.runtime.failures import FailureInjector, RestartPolicy, SimulatedFailure
from repro.runtime.straggler import StragglerMonitor
from repro.training.optimizers import get_optimizer
from repro.training.trainer import TrainConfig, lr_schedule, make_train_step


@dataclasses.dataclass
class TrainerOptions:
    arch: str = "stablelm-1.6b"
    smoke: bool = True
    steps: int = 100
    seq_len: int = 128
    global_batch: int = 8
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    seed: int = 0
    optimizer: str = "adamw"
    learning_rate: float = 1e-3
    local_steps: int = 1                 # H>1 => local-SGD outer sync
    compression: Optional[str] = None    # int8 | topk | powersgd
    mesh: Optional[Any] = None
    rules: Optional[Rules] = None
    failure_injector: Optional[FailureInjector] = None
    log_every: int = 10


class Trainer:
    """Restartable trainer; `run()` survives SimulatedFailure via restore."""

    def __init__(self, opts: TrainerOptions):
        self.opts = opts
        cfg = (get_smoke_config(opts.arch) if opts.smoke
               else get_config(opts.arch))
        self.cfg = cfg
        rt = Runtime(mesh=opts.mesh, rules=opts.rules,
                     remat="none" if opts.smoke else "full",
                     block_q=64, block_k=64, scan_chunk=32)
        self.lm = LM(cfg, rt)
        self.opt = get_optimizer(opts.optimizer)
        self.tcfg = TrainConfig(learning_rate=opts.learning_rate,
                                warmup_steps=20, total_steps=opts.steps,
                                local_steps=opts.local_steps)
        self.compressor = None
        if opts.compression:
            self.compressor = GradientCompressor(
                CompressionConfig(scheme=opts.compression))
        self.data = SyntheticTokens(
            cfg.vocab_size, opts.seq_len, opts.global_batch, seed=opts.seed,
            n_frontend=cfg.n_frontend_tokens, d_model=cfg.d_model)
        self.ckpt = (CheckpointManager(opts.ckpt_dir)
                     if opts.ckpt_dir else None)
        self.monitor = StragglerMonitor()
        self.history: list = []
        self._build_state()
        self._step_fn = self._make_step()

    # ------------------------------------------------------------------
    def _build_state(self):
        params, axes = self.lm.init(jax.random.PRNGKey(self.opts.seed))
        self.params = params
        self.param_axes = axes
        self.opt_state = self.opt.init(params)
        self.comp_state = (self.compressor.init_state(params)
                           if self.compressor else None)
        self.step = 0

    def _make_step(self):
        base = make_train_step(self.lm, self.opt, self.tcfg)
        return jax.jit(base, donate_argnums=(0, 1))

    # ------------------------------------------------------------------
    def _maybe_restore(self) -> bool:
        if self.ckpt is None:
            return False
        latest = self.ckpt.latest_step()
        if latest is None:
            return False
        tree, meta = self.ckpt.restore(latest)
        self.params = jax.tree.map(jnp.asarray, tree["params"])
        self.opt_state = jax.tree.map(jnp.asarray, tree["opt_state"])
        self.data.load_state_dict(meta["data_state"])
        self.step = int(meta["step"])
        return True

    def _save(self, block: bool = False):
        if self.ckpt is None:
            return
        self.ckpt.save(
            self.step,
            {"params": self.params, "opt_state": self.opt_state},
            metadata={"data_state": self.data.state_dict(),
                      "arch": self.cfg.name},
            block=block)

    # ------------------------------------------------------------------
    def train_some(self, n_steps: int) -> Dict[str, float]:
        last = {}
        for _ in range(n_steps):
            if self.opts.failure_injector is not None:
                self.opts.failure_injector.check(self.step)
            batch_np = self.data.next_batch()
            batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
            t0 = time.perf_counter()
            if self.compressor is not None:
                # compression applied at the sync boundary, outside jit state
                (loss_val, _), grads = jax.value_and_grad(
                    self.lm.loss_fn, has_aux=True)(self.params, batch)
                grads, self.comp_state = self.compressor.compress(
                    grads, self.comp_state)
                from repro.training.optimizers import clip_by_global_norm
                grads, gnorm = clip_by_global_norm(grads, self.tcfg.grad_clip)
                lr = lr_schedule(self.tcfg, jnp.float32(self.step))
                self.params, self.opt_state = self.opt.update(
                    grads, self.opt_state, self.params, lr)
                metrics = {"loss": loss_val, "grad_norm": gnorm}
            else:
                self.params, self.opt_state, metrics = self._step_fn(
                    self.params, self.opt_state, batch, jnp.int32(self.step))
            jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0
            self.monitor.observe(self.step, dt)
            last = {k: float(v) for k, v in metrics.items()}
            last["step_time"] = dt
            self.history.append((self.step, last["loss"]))
            if self.opts.log_every and self.step % self.opts.log_every == 0:
                print(f"step {self.step:5d} loss={last['loss']:.4f} "
                      f"({dt*1e3:.0f} ms)", flush=True)
            self.step += 1
            if self.ckpt and self.step % self.opts.ckpt_every == 0:
                self._save()
        return last

    # ------------------------------------------------------------------
    def run(self) -> Dict[str, float]:
        """Train to opts.steps with automatic failure recovery."""
        policy = RestartPolicy()
        self._maybe_restore()
        last: Dict[str, float] = {}
        while self.step < self.opts.steps:
            try:
                last = self.train_some(self.opts.steps - self.step)
            except SimulatedFailure as e:
                if not policy.should_restart():
                    raise
                print(f"[failure] {e}; restoring from checkpoint", flush=True)
                if self.ckpt:
                    self.ckpt.wait()
                if not self._maybe_restore():
                    self._build_state()
                self._step_fn = self._make_step()
        if self.ckpt:
            self._save(block=True)
            self.ckpt.wait()
        return last


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--optimizer", default="adamw")
    ap.add_argument("--compression", default=None)
    args = ap.parse_args()
    opts = TrainerOptions(arch=args.arch, smoke=args.smoke, steps=args.steps,
                          seq_len=args.seq_len, global_batch=args.global_batch,
                          ckpt_dir=args.ckpt_dir, optimizer=args.optimizer,
                          compression=args.compression)
    trainer = Trainer(opts)
    last = trainer.run()
    print("final:", last)


if __name__ == "__main__":
    main()
