"""Batched serving driver: prefill + decode loop with a KV/state cache.

CPU demo (smoke config):
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-14b --smoke \
      --batch 4 --prompt-len 16 --gen 16
"""
from __future__ import annotations

import argparse
import time
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.models.model import LM
from repro.models.runtime import Runtime


class Server:
    def __init__(self, arch: str, smoke: bool = True, max_seq: int = 128,
                 mesh=None, rules=None, seed: int = 0):
        self.cfg = get_smoke_config(arch) if smoke else get_config(arch)
        rt = Runtime(mesh=mesh, rules=rules, remat="none",
                     block_q=64, block_k=64, scan_chunk=32)
        self.lm = LM(self.cfg, rt)
        self.params, _ = self.lm.init(jax.random.PRNGKey(seed))
        self.max_seq = max_seq
        self._prefill = jax.jit(self.lm.prefill)
        self._decode = jax.jit(self.lm.decode_step, donate_argnums=(3,))

    # ------------------------------------------------------------------
    def _grow_cache(self, prefill_cache, batch: int, prompt_len: int):
        """Copy the prefill cache (length P) into a max_seq-capacity cache."""
        full = self.lm.init_cache(batch, self.max_seq)

        def merge(full_leaf, pre_leaf):
            if full_leaf.shape == pre_leaf.shape:  # mamba state: no seq dim
                return pre_leaf.astype(full_leaf.dtype)
            # locate the sequence axis: the dim where sizes differ
            for ax in range(full_leaf.ndim):
                if full_leaf.shape[ax] != pre_leaf.shape[ax]:
                    break
            idx = [slice(None)] * full_leaf.ndim
            idx[ax] = slice(0, pre_leaf.shape[ax])
            return full_leaf.at[tuple(idx)].set(pre_leaf.astype(full_leaf.dtype))

        return jax.tree.map(merge, full, prefill_cache)

    def generate(self, prompts: np.ndarray, gen_tokens: int,
                 frontend_embeds: Optional[np.ndarray] = None,
                 greedy: bool = True) -> Dict:
        """prompts: (B, P) int32. Returns generated tokens + timing stats."""
        b, p = prompts.shape
        t0 = time.perf_counter()
        logits, cache = self._prefill(self.params, jnp.asarray(prompts),
                                      None if frontend_embeds is None
                                      else jnp.asarray(frontend_embeds))
        cache = self._grow_cache(cache, b, p + self.cfg.n_frontend_tokens)
        jax.block_until_ready(logits)
        t_prefill = time.perf_counter() - t0
        lengths = jnp.full((b,), p + self.cfg.n_frontend_tokens, jnp.int32)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        out = [np.asarray(tok)]
        t1 = time.perf_counter()
        for _ in range(gen_tokens - 1):
            logits, cache = self._decode(self.params, tok, lengths, cache)
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
            lengths = lengths + 1
            out.append(np.asarray(tok))
        jax.block_until_ready(tok)
        t_decode = time.perf_counter() - t1
        tokens = np.stack(out, axis=1)
        return {
            "tokens": tokens,
            "prefill_s": t_prefill,
            "decode_s": t_decode,
            "decode_tok_per_s": b * max(gen_tokens - 1, 1) / max(t_decode, 1e-9),
        }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-14b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()
    server = Server(args.arch, smoke=args.smoke,
                    max_seq=args.prompt_len + args.gen + 8)
    rng = np.random.RandomState(0)
    prompts = rng.randint(0, server.cfg.vocab_size,
                          (args.batch, args.prompt_len)).astype(np.int32)
    fe = None
    if server.cfg.n_frontend_tokens:
        fe = rng.randn(args.batch, server.cfg.n_frontend_tokens,
                       server.cfg.d_model).astype(np.float32) * 0.02
    res = server.generate(prompts, args.gen, fe)
    print(f"generated {res['tokens'].shape} tokens; "
          f"prefill {res['prefill_s']*1e3:.0f} ms, "
          f"decode {res['decode_tok_per_s']:.1f} tok/s")


if __name__ == "__main__":
    main()
