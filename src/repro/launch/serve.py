"""Serving CLI — thin front end over the ``repro.serve`` subsystem.

Continuous batching (paged KV cache, join-on-arrival, prefix reuse,
Hemingway capacity planning):

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-14b --smoke \
      --continuous

runs a mixed-length 8-request trace with staggered arrivals and shared
prompt heads, checks prefix-reuse logits against a cold prefill bit-for-bit,
and prints the fitted f(b) step model plus a capacity plan (what replica
count m and max-batch hit a p50 target at a given QPS).

Static batch (the original demo, now also served by the engine):

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-14b --smoke \
      --batch 4 --prompt-len 16 --gen 16
"""
from __future__ import annotations

import argparse
import sys
from typing import Dict, Optional

import numpy as np

from repro.serve import CapacityPlanner, ServeEngine


class Server:
    """Batch-synchronous facade kept for tests/back-compat; every request is
    admitted at step 0 and decoded by the continuous engine."""

    def __init__(self, arch: str, smoke: bool = True, max_seq: int = 128,
                 mesh=None, rules=None, seed: int = 0, page_size: int = 16):
        if mesh is not None or rules is not None:
            raise NotImplementedError(
                "sharded serving is not supported by the paged engine yet; "
                "pass mesh=None, rules=None")
        self.arch = arch
        self.smoke = smoke
        self.max_seq = max_seq
        self.seed = seed
        self.page_size = page_size
        self._engine: Optional[ServeEngine] = None
        self.cfg = ServeEngine.config_for(arch, smoke)

    def _make_engine(self, batch: int) -> ServeEngine:
        if self._engine is None or self._engine.max_batch != batch:
            self._engine = ServeEngine(
                self.arch, smoke=self.smoke, max_batch=batch,
                page_size=self.page_size, max_seq=self.max_seq,
                seed=self.seed)
        return self._engine

    def generate(self, prompts: np.ndarray, gen_tokens: int,
                 frontend_embeds: Optional[np.ndarray] = None,
                 greedy: bool = True) -> Dict:
        """prompts: (B, P) int32. Returns generated tokens + timing stats."""
        assert greedy, "only greedy decoding is supported"
        b, _ = prompts.shape
        eng = self._make_engine(b)
        # engine may be reused across calls
        n_before = len(eng.events("serve_step"))
        reqs = []
        for i in range(b):
            fe = None if frontend_embeds is None else frontend_embeds[i]
            reqs.append(eng.submit(np.asarray(prompts[i], np.int32),
                                   gen_tokens, frontend_embeds=fe))
        eng.run()
        tokens = np.stack([np.asarray(r.generated, np.int32) for r in reqs])
        this_call = [e for e in eng.events("serve_step")[n_before:]
                     if e.batch > 0]
        t_decode = sum(e.step_s for e in this_call)
        n_tok = sum(e.batch for e in this_call)
        return {
            "tokens": tokens,
            "prefill_s": sum(r.prefill_s for r in reqs),
            "decode_s": t_decode,
            "decode_tok_per_s": n_tok / t_decode if t_decode else 0.0,
        }


def _mixed_trace(eng: ServeEngine, n_requests: int, seed: int):
    """Mixed prompt lengths, bursty arrivals, one shared prompt head."""
    rng = np.random.RandomState(seed)
    ps = eng.page_size
    shared_head = rng.randint(0, eng.cfg.vocab_size, 2 * ps).astype(np.int32)
    reqs = []
    for i in range(n_requests):
        if i % 3 == 0:  # every third request shares the prompt head
            tail = rng.randint(0, eng.cfg.vocab_size,
                               3 + rng.randint(0, ps)).astype(np.int32)
            prompt = np.concatenate([shared_head, tail])
        else:
            plen = int(rng.choice([7, 12, 21, 30]))
            prompt = rng.randint(0, eng.cfg.vocab_size, plen).astype(np.int32)
        gen = int(rng.choice([4, 6, 8]))
        arrival = (i // 2) * 2  # bursty: pairs arrive together
        fe = None
        if eng.cfg.n_frontend_tokens:
            fe = (rng.randn(eng.cfg.n_frontend_tokens, eng.cfg.d_model)
                  * 0.02).astype(np.float32)
        reqs.append(eng.submit(prompt, gen, arrival_step=arrival,
                               frontend_embeds=fe))
    return reqs


def _verify_prefix_reuse(arch: str, smoke: bool, eng: ServeEngine,
                         seed: int) -> bool:
    """Serve one prefix-sharing prompt on the warm engine and the same
    prompt cold; logits must match bit-for-bit."""
    rng = np.random.RandomState(seed + 1)
    ps = eng.page_size
    head = rng.randint(0, eng.cfg.vocab_size, 2 * ps).astype(np.int32)
    pA = np.concatenate([head, rng.randint(0, eng.cfg.vocab_size, 5)
                         .astype(np.int32)])
    pB = np.concatenate([head, rng.randint(0, eng.cfg.vocab_size, 9)
                         .astype(np.int32)])
    eng.collect_logits = True
    eng.submit(pA, 4)
    eng.run()
    rB = eng.submit(pB, 4)
    eng.run()
    cold = ServeEngine(arch, smoke=smoke, max_batch=eng.max_batch,
                       page_size=ps, max_seq=eng.max_seq, seed=eng.seed,
                       collect_logits=True)
    rB_cold = cold.submit(pB, 4)
    cold.run()
    shared = rB.n_shared_pages
    exact = all(np.array_equal(a, b)
                for a, b in zip(rB.logits_trace, rB_cold.logits_trace))
    print(f"prefix reuse: shared_pages={shared} "
          f"bit_identical={'yes' if exact else 'NO'}")
    return shared > 0 and exact


def _resolve_prefill_chunk(value: Optional[int], smoke: bool) -> Optional[int]:
    """``--prefill-chunk -1`` -> the autotuned chunk size for the matching
    sweep preset; falls back to the built-in default on a cache miss."""
    if value is None or value >= 0:
        return value
    import jax.numpy as jnp

    from repro.kernels.flash_decode.ops import DEFAULT_PREFILL_CHUNK
    from repro.kernels.tune import SWEEP_SHAPES, lookup

    preset = "smoke" if smoke else "full"
    cfg = lookup("prefill_chunk", SWEEP_SHAPES[preset]["prefill_chunk"],
                 jnp.float32)
    chunk = int(cfg["chunk"]) if cfg else DEFAULT_PREFILL_CHUNK
    print(f"prefill chunk: auto -> {chunk} "
          f"({'tuned' if cfg else 'untuned default'})")
    return chunk


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-14b")
    ap.add_argument("--smoke", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="reduced config (default; --no-smoke serves the "
                         "full architecture)")
    ap.add_argument("--continuous", action="store_true",
                    help="mixed-length trace with join-on-arrival + "
                         "prefix-reuse verification + capacity plan")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    metavar="TOKENS",
                    help="chunked prefill: per-step prompt-token budget "
                         "shared with the decode batch (-1 picks the "
                         "autotuned chunk size; default off)")
    ap.add_argument("--speculate", type=int, default=0, metavar="K",
                    help="speculative decode: draft up to K tokens per "
                         "sequence per step from an n-gram/prefix-cache "
                         "proposer, verified in one batched target step "
                         "(default 0 = off)")
    ap.add_argument("--paged-impl", default="stream",
                    choices=["stream", "pallas", "gather"],
                    help="paged decode implementation (bit-identical; "
                         "stream is paged-native, gather is the legacy "
                         "oracle)")
    ap.add_argument("--tune-cache", default=None, metavar="PATH",
                    help="seed the capacity planner with measured "
                         "paged-decode kernel timings from this autotuner "
                         "config cache before fitting")
    args = ap.parse_args()

    if not args.continuous:
        server = Server(args.arch, smoke=args.smoke,
                        max_seq=args.prompt_len + args.gen + 8,
                        page_size=args.page_size)
        rng = np.random.RandomState(args.seed)
        prompts = rng.randint(0, server.cfg.vocab_size,
                              (args.batch, args.prompt_len)).astype(np.int32)
        fe = None
        if server.cfg.n_frontend_tokens:
            fe = rng.randn(args.batch, server.cfg.n_frontend_tokens,
                           server.cfg.d_model).astype(np.float32) * 0.02
        res = server.generate(prompts, args.gen, fe)
        print(f"generated {res['tokens'].shape} tokens; "
              f"prefill {res['prefill_s']*1e3:.0f} ms, "
              f"decode {res['decode_tok_per_s']:.1f} tok/s")
        return

    prefill_chunk = _resolve_prefill_chunk(args.prefill_chunk, args.smoke)
    eng = ServeEngine(args.arch, smoke=args.smoke, max_batch=args.max_batch,
                      page_size=args.page_size,
                      max_seq=64 + args.page_size * 2, seed=args.seed,
                      paged_impl=args.paged_impl,
                      prefill_chunk=prefill_chunk, speculate=args.speculate)
    reqs = _mixed_trace(eng, args.requests, args.seed)
    stats = eng.run()
    done = [r for r in reqs if r.finished_step >= 0]
    print(f"served {len(done)}/{len(reqs)} requests in {eng.step_count} steps "
          f"(mean batch {stats['mean_batch']:.2f}, "
          f"{stats['decode_tok_per_s']:.1f} tok/s, "
          f"prefix hits {stats.get('prefix_hits', 0)})")
    joins = sum(1 for r in reqs if r.admitted_step > 0)
    print(f"join-on-arrival: {joins} requests joined a running batch")
    if "join_to_first_token_p50" in stats:
        print(f"join-to-first-token: p50 {stats['join_to_first_token_p50']:.1f}"
              f" p99 {stats['join_to_first_token_p99']:.1f} steps")

    if prefill_chunk is not None or args.speculate:
        if prefill_chunk is not None:
            print(f"chunked prefill: {stats.get('prefill_chunks', 0)} chunk "
                  f"steps / {stats.get('prefill_chunk_tokens', 0)} prompt "
                  f"tokens at budget {prefill_chunk}")
        if args.speculate:
            print(f"speculation: accept rate "
                  f"{stats.get('spec_accept_rate', 0.0):.2f} "
                  f"({stats.get('draft_accepted', 0)}/"
                  f"{stats.get('draft_proposed', 0)} drafted tokens)")
        base = ServeEngine(args.arch, smoke=args.smoke,
                           max_batch=args.max_batch,
                           page_size=args.page_size,
                           max_seq=64 + args.page_size * 2, seed=args.seed,
                           paged_impl=args.paged_impl)
        base_reqs = _mixed_trace(base, args.requests, args.seed)
        base.run()
        identical = all(r.generated == b.generated
                        for r, b in zip(reqs, base_reqs))
        print(f"chunked+speculative vs one-token baseline: "
              f"bit_identical={'yes' if identical else 'NO'}")
        if not identical:
            print("FAIL: chunked/speculative outputs diverge from baseline")
            sys.exit(1)

    planner = CapacityPlanner()
    if args.tune_cache:
        from repro.kernels.tune import ConfigCache, tune_events

        n_layers = eng.cfg.n_layers
        n = planner.ingest(tune_events(ConfigCache(args.tune_cache)),
                           n_layers=n_layers)
        print(f"capacity plan: seeded with {n} measured kernel row(s) "
              f"from {args.tune_cache} (x{n_layers} layers)")
    planner.ingest(eng.events("serve_step"))
    try:
        planner.fit()
    except ValueError as e:
        print(f"capacity plan: insufficient telemetry ({e})")
    else:
        t1, t8 = planner.step_time(1), planner.step_time(8)
        print(f"f(b) step model: t(1)={t1*1e3:.1f} ms  t(8)={t8*1e3:.1f} ms  "
              f"coeffs={planner.step_model.coefficients()}")
        plan = planner.plan(target_p50_s=max(10 * t8 * 8, 1e-3), qps=2.0,
                            gen_tokens=8, batch_grid=[1, 2, 4, 8],
                            m_grid=[1, 2, 4, 8, 16])
        if plan:
            print(f"capacity plan: {plan.algorithm} on m={plan.m} replicas "
                  f"(predicted p50 {plan.predicted_time*1e3:.1f} ms)")
        else:
            print(f"capacity plan: no feasible operating point "
                  f"({plan.reason})")

    ok = _verify_prefix_reuse(args.arch, args.smoke, eng, args.seed)
    if not ok:
        print("FAIL: prefix-reuse verification")
        sys.exit(1)


if __name__ == "__main__":
    main()
