"""Serving CLI — thin front end over the ``repro.serve`` subsystem.

Continuous batching (paged KV cache, join-on-arrival, prefix reuse,
Hemingway capacity planning):

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-14b --smoke \
      --continuous

runs a mixed-length 8-request trace with staggered arrivals and shared
prompt heads, checks prefix-reuse logits against a cold prefill bit-for-bit,
and prints the fitted f(b) step model plus a capacity plan (what replica
count m and max-batch hit a p50 target at a given QPS).

Multi-replica routed serving (DESIGN.md §13):

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-14b --smoke \
      --continuous --router --replicas 2

replays the same trace through a prefix-affinity router over N replicas
(``--replicas 0`` asks the fitted capacity planner for its min-replicas
answer) and asserts every request's token stream is bit-identical to the
single-engine reference.  ``--tp K`` additionally runs each replica
tensor-parallel over K forced-host devices.

Static batch (the original demo, now also served by the engine):

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-14b --smoke \
      --batch 4 --prompt-len 16 --gen 16
"""
from __future__ import annotations

import os
import sys

# --tp K forces K host devices; jax locks the device count at first
# initialization, so this must run before ANY jax-importing import below
# (same contract as launch/dryrun.py).
if "--tp" in sys.argv[1:]:
    _k = int(sys.argv[sys.argv.index("--tp") + 1])
    if _k > 1 and "xla_force_host_platform_device_count" not in os.environ.get(
            "XLA_FLAGS", ""):
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={_k}").strip()

import argparse
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.serve import CapacityPlanner, Router, ServeEngine


class Server:
    """Batch-synchronous facade kept for tests/back-compat; every request is
    admitted at step 0 and decoded by the continuous engine.  Passing a mesh
    (and optionally Rules) runs the sharded data plane (serve/sharding.py)."""

    def __init__(self, arch: str, smoke: bool = True, max_seq: int = 128,
                 mesh=None, rules=None, seed: int = 0, page_size: int = 16):
        self.arch = arch
        self.smoke = smoke
        self.max_seq = max_seq
        self.seed = seed
        self.page_size = page_size
        self.rt = None
        if mesh is not None or rules is not None:
            self.rt = _serving_runtime(page_size, "stream", mesh=mesh,
                                       rules=rules)
        self._engine: Optional[ServeEngine] = None
        self.cfg = ServeEngine.config_for(arch, smoke)

    def _make_engine(self, batch: int) -> ServeEngine:
        if self._engine is None or self._engine.max_batch != batch:
            self._engine = ServeEngine(
                self.arch, smoke=self.smoke, max_batch=batch,
                page_size=self.page_size, max_seq=self.max_seq,
                seed=self.seed, rt=self.rt)
        return self._engine

    def generate(self, prompts: np.ndarray, gen_tokens: int,
                 frontend_embeds: Optional[np.ndarray] = None,
                 greedy: bool = True) -> Dict:
        """prompts: (B, P) int32. Returns generated tokens + timing stats."""
        assert greedy, "only greedy decoding is supported"
        b, _ = prompts.shape
        eng = self._make_engine(b)
        # engine may be reused across calls
        n_before = len(eng.events("serve_step"))
        reqs = []
        for i in range(b):
            fe = None if frontend_embeds is None else frontend_embeds[i]
            reqs.append(eng.submit(np.asarray(prompts[i], np.int32),
                                   gen_tokens, frontend_embeds=fe))
        eng.run()
        tokens = np.stack([np.asarray(r.generated, np.int32) for r in reqs])
        this_call = [e for e in eng.events("serve_step")[n_before:]
                     if e.batch > 0]
        t_decode = sum(e.step_s for e in this_call)
        n_tok = sum(e.batch for e in this_call)
        return {
            "tokens": tokens,
            "prefill_s": sum(r.prefill_s for r in reqs),
            "decode_s": t_decode,
            "decode_tok_per_s": n_tok / t_decode if t_decode else 0.0,
        }


def _serving_runtime(page_size: int, paged_impl: str, *, mesh=None,
                     rules=None):
    """Serving Runtime with the engine's pinned kernel geometry (see
    ServeEngine.__init__ on why block_q = block_k = 16)."""
    from repro.models.runtime import Runtime

    return Runtime(remat="none", block_q=16, block_k=16, scan_chunk=32,
                   page_size=page_size, paged_impl=paged_impl, mesh=mesh,
                   rules=rules)


# One trace request: (prompt, gen_tokens, arrival_step, frontend_embeds).
TraceSpec = Tuple[np.ndarray, int, int, Optional[np.ndarray]]


def _mixed_trace_specs(cfg, page_size: int, n_requests: int,
                       seed: int) -> List[TraceSpec]:
    """Mixed prompt lengths, bursty arrivals, one shared prompt head —
    generated independently of any engine so the same trace can be replayed
    through a single engine and a routed fleet.  The RNG draw order is
    load-bearing: it pins the traces existing goldens/smoke output use."""
    rng = np.random.RandomState(seed)
    ps = page_size
    shared_head = rng.randint(0, cfg.vocab_size, 2 * ps).astype(np.int32)
    specs: List[TraceSpec] = []
    for i in range(n_requests):
        if i % 3 == 0:  # every third request shares the prompt head
            tail = rng.randint(0, cfg.vocab_size,
                               3 + rng.randint(0, ps)).astype(np.int32)
            prompt = np.concatenate([shared_head, tail])
        else:
            plen = int(rng.choice([7, 12, 21, 30]))
            prompt = rng.randint(0, cfg.vocab_size, plen).astype(np.int32)
        gen = int(rng.choice([4, 6, 8]))
        arrival = (i // 2) * 2  # bursty: pairs arrive together
        fe = None
        if cfg.n_frontend_tokens:
            fe = (rng.randn(cfg.n_frontend_tokens, cfg.d_model)
                  * 0.02).astype(np.float32)
        specs.append((prompt, gen, arrival, fe))
    return specs


def _mixed_trace(eng: ServeEngine, n_requests: int, seed: int):
    specs = _mixed_trace_specs(eng.cfg, eng.page_size, n_requests, seed)
    return [eng.submit(prompt, gen, arrival_step=arrival, frontend_embeds=fe)
            for prompt, gen, arrival, fe in specs]


def _verify_prefix_reuse(arch: str, smoke: bool, eng: ServeEngine,
                         seed: int) -> bool:
    """Serve one prefix-sharing prompt on the warm engine and the same
    prompt cold; logits must match bit-for-bit."""
    rng = np.random.RandomState(seed + 1)
    ps = eng.page_size
    head = rng.randint(0, eng.cfg.vocab_size, 2 * ps).astype(np.int32)
    pA = np.concatenate([head, rng.randint(0, eng.cfg.vocab_size, 5)
                         .astype(np.int32)])
    pB = np.concatenate([head, rng.randint(0, eng.cfg.vocab_size, 9)
                         .astype(np.int32)])
    eng.collect_logits = True
    eng.submit(pA, 4)
    eng.run()
    rB = eng.submit(pB, 4)
    eng.run()
    cold = ServeEngine(arch, smoke=smoke, max_batch=eng.max_batch,
                       page_size=ps, max_seq=eng.max_seq, seed=eng.seed,
                       collect_logits=True)
    rB_cold = cold.submit(pB, 4)
    cold.run()
    shared = rB.n_shared_pages
    exact = all(np.array_equal(a, b)
                for a, b in zip(rB.logits_trace, rB_cold.logits_trace))
    print(f"prefix reuse: shared_pages={shared} "
          f"bit_identical={'yes' if exact else 'NO'}")
    return shared > 0 and exact


def _resolve_prefill_chunk(value: Optional[int], smoke: bool) -> Optional[int]:
    """``--prefill-chunk -1`` -> the autotuned chunk size for the matching
    sweep preset; falls back to the built-in default on a cache miss."""
    if value is None or value >= 0:
        return value
    import jax.numpy as jnp

    from repro.kernels.flash_decode.ops import DEFAULT_PREFILL_CHUNK
    from repro.kernels.tune import SWEEP_SHAPES, lookup

    preset = "smoke" if smoke else "full"
    cfg = lookup("prefill_chunk", SWEEP_SHAPES[preset]["prefill_chunk"],
                 jnp.float32)
    chunk = int(cfg["chunk"]) if cfg else DEFAULT_PREFILL_CHUNK
    print(f"prefill chunk: auto -> {chunk} "
          f"({'tuned' if cfg else 'untuned default'})")
    return chunk


def _trace_clock_factory(args):
    """Per-engine trace clock: fresh CountingClock for ``steps`` (fully
    deterministic span values -> byte-identical trace files across
    same-seed runs), ``None`` (wall clock) otherwise."""
    if args.trace and args.trace_clock == "steps":
        from repro.telemetry.trace import CountingClock

        return lambda: CountingClock()
    return lambda: None


def _export_trace(args, events, planner, busy_s: float, n_layers: int) -> None:
    """Write the Perfetto trace + attribution report; exit 1 on failure.

    Reconciliation compares the engine-op span components against the
    engine's own ``serve_step`` wall time — the same scopes timed by two
    perf_counter pairs, so the acceptance bound (5%) is generous.  Under
    ``--trace-clock steps`` span values are synthetic ticks and the wall
    reconciliation is skipped (byte-identity is the point of that mode)."""
    from repro.telemetry.trace import (
        attribute,
        format_attribution,
        load_perfetto,
        validate_perfetto,
        write_perfetto,
    )

    fitted = None
    try:
        planner.step_time(1)
        fitted = planner
    except Exception:
        pass
    n = write_perfetto(args.trace, events)
    errs = validate_perfetto(load_perfetto(args.trace))
    if errs:
        print(f"FAIL: trace schema: {errs[:5]}")
        sys.exit(1)
    print(f"trace: {n} spans -> {args.trace} (Perfetto/chrome://tracing)")
    attr = attribute(events, planner=fitted, n_layers=n_layers)
    print(format_attribution(attr))
    # serve_step rows time exactly decode, verify, and *chunked* prefill;
    # monolithic admission prefill (engine.prefill) is span-only (the
    # engine books it on the request, not the step stream), so it stays
    # out of the wall reconciliation set
    engine_ops = ("engine.decode", "engine.verify", "engine.prefill_chunk")
    span_busy = sum(r.measured_s for r in attr.rows
                    if r.component in engine_ops)
    if args.trace_clock == "steps":
        print("trace: deterministic step clock (wall reconciliation n/a)")
        return
    if busy_s > 0:
        rel = abs(span_busy - busy_s) / busy_s
        print(f"trace: span/engine wall reconciliation "
              f"{span_busy:.3f}s vs {busy_s:.3f}s ({rel:.2%})")
        if rel > 0.05:
            print("FAIL: trace spans do not reconcile with engine wall time")
            sys.exit(1)


def _run_router(args, specs: List[TraceSpec], reference, n_replicas: int,
                prefill_chunk: Optional[int]) -> "Router":
    """Replay the reference trace through a prefix-affinity router over
    ``n_replicas`` engines and assert bit-identical per-request outputs."""
    mesh = None
    if args.tp > 1:
        from repro.launch.mesh import make_debug_mesh

        mesh = make_debug_mesh(1, args.tp)
        print(f"tensor parallel: {args.tp}-way over mesh "
              f"{dict(zip(mesh.axis_names, mesh.devices.shape))}")
    rt = _serving_runtime(args.page_size, args.paged_impl, mesh=mesh)

    clock = _trace_clock_factory(args)

    def make_engine(i: int) -> ServeEngine:
        return ServeEngine(
            args.arch, smoke=args.smoke, max_batch=args.max_batch,
            page_size=args.page_size, max_seq=64 + args.page_size * 2,
            seed=args.seed, rt=rt, prefill_chunk=prefill_chunk,
            speculate=args.speculate, replica_id=i,
            trace=bool(args.trace), trace_clock=clock())

    if mesh is not None:
        # bit-identity is a same-placement guarantee: TP psums reduce in a
        # different order than the unsharded engine, so at K > 1 the routed
        # fleet is compared against a single engine on the SAME mesh (the
        # unsharded reference agrees to float tolerance, not bitwise)
        ref = make_engine(-1)
        for prompt, gen, arrival, fe in specs:
            ref.submit(prompt, gen, arrival_step=arrival, frontend_embeds=fe)
        ref.run()
        reference = ref.scheduler.finished
        reference.sort(key=lambda r: r.rid)

    engines = [make_engine(i) for i in range(n_replicas)]
    router = Router(engines, spill_slack=args.spill_slack,
                    trace=bool(args.trace), trace_clock=clock())
    routed = [router.submit(prompt, gen, arrival_step=arrival,
                            frontend_embeds=fe)
              for prompt, gen, arrival, fe in specs]
    if args.migrate_at is not None:
        from repro.serve.migrate import migrate_replica

        migrated = False
        while not router.drained:
            if router.step_count >= 100_000:
                raise RuntimeError("trace did not drain in 100000 steps")
            if router.step_count == args.migrate_at:
                info = migrate_replica(
                    router, args.migrate_replica,
                    lambda: make_engine(args.migrate_replica))
                migrated = True
                print(f"migration: replica {info['replica']} handed off at "
                      f"step {args.migrate_at} — {info['in_flight']} "
                      f"requests in flight, {info['pages_in_use']} pages, "
                      f"{info['nbytes'] / 1e6:.2f} MB cache in "
                      f"{info['wall_s'] * 1e3:.0f} ms")
            router.step()
        if not migrated:
            print(f"migration: trace drained before step {args.migrate_at} "
                  f"(no handoff performed)")
        rstats = router.stats()
    else:
        rstats = router.run()
    print(f"router: {rstats['dispatched']} requests over "
          f"{n_replicas} replicas {rstats['dispatch_per_replica']}, "
          f"affinity hit rate {rstats['affinity_hit_rate']:.2f} "
          f"({rstats['affinity_hits']} hits, {rstats['spills']} spills)")

    identical = all(rr.generated == ref.generated
                    for rr, ref in zip(routed, reference))
    print(f"routed fleet vs single engine: "
          f"bit_identical={'yes' if identical else 'NO'}")

    planner = CapacityPlanner()
    planner.ingest(router.all_events())
    per = planner.replica_stats()
    for idx, s in per.items():
        print(f"  replica {idx}: {int(s['dispatches'])} dispatched, "
              f"{int(s['affinity_hits'])} affinity hits, "
              f"{int(s['decode_tokens'])} tokens @ {s['tok_per_s']:.1f} tok/s")
    print(f"measured effective replicas: "
          f"{planner.measured_effective_replicas():.2f}/{n_replicas}")

    if args.router_log:
        n = router.to_jsonl(args.router_log)
        print(f"router log: {n} events -> {args.router_log}")
    if not identical:
        print("FAIL: routed outputs diverge from the single-engine reference")
        sys.exit(1)
    return router


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-14b")
    ap.add_argument("--smoke", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="reduced config (default; --no-smoke serves the "
                         "full architecture)")
    ap.add_argument("--continuous", action="store_true",
                    help="mixed-length trace with join-on-arrival + "
                         "prefix-reuse verification + capacity plan")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    metavar="TOKENS",
                    help="chunked prefill: per-step prompt-token budget "
                         "shared with the decode batch (-1 picks the "
                         "autotuned chunk size; default off)")
    ap.add_argument("--speculate", type=int, default=0, metavar="K",
                    help="speculative decode: draft up to K tokens per "
                         "sequence per step from an n-gram/prefix-cache "
                         "proposer, verified in one batched target step "
                         "(default 0 = off)")
    ap.add_argument("--paged-impl", default="stream",
                    choices=["stream", "pallas", "gather"],
                    help="paged decode implementation (bit-identical; "
                         "stream is paged-native, gather is the legacy "
                         "oracle)")
    ap.add_argument("--tune-cache", default=None, metavar="PATH",
                    help="seed the capacity planner with measured "
                         "paged-decode kernel timings from this autotuner "
                         "config cache before fitting")
    ap.add_argument("--router", action="store_true",
                    help="replay the trace through a prefix-affinity router "
                         "over N replicas and assert bit-identical outputs "
                         "(implies --continuous)")
    ap.add_argument("--replicas", type=int, default=0, metavar="N",
                    help="replica count for --router (0 = the fitted "
                         "capacity planner's min-replicas answer)")
    ap.add_argument("--spill-slack", type=int, default=512, metavar="TOKENS",
                    help="router overflow spill: an affinity winner more "
                         "than this many pending tokens above the fleet "
                         "minimum forfeits the request")
    ap.add_argument("--migrate-at", type=int, default=None, metavar="STEP",
                    help="live migration drill: at router step STEP, hand "
                         "one replica off to a freshly built engine "
                         "(serve/migrate.py) and keep serving — the "
                         "bit-identity check then also proves migrated "
                         "streams match the unmigrated control (implies "
                         "--router)")
    ap.add_argument("--migrate-replica", type=int, default=0, metavar="R",
                    help="which replica --migrate-at hands off (default 0)")
    ap.add_argument("--router-log", default=None, metavar="PATH",
                    help="dump the combined router + replica event stream "
                         "as JSONL")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="hierarchical span tracing: write a Perfetto/"
                         "chrome://tracing JSON span tree and print the "
                         "per-component predicted-vs-measured attribution "
                         "report (implies --continuous)")
    ap.add_argument("--trace-clock", default="wall",
                    choices=["wall", "steps"],
                    help="span timestamp source: wall (measured; reconciled "
                         "against engine step timings) or steps "
                         "(deterministic tick clock; same-seed runs emit "
                         "byte-identical trace files)")
    ap.add_argument("--tp", type=int, default=1, metavar="K",
                    help="tensor-parallel world size per replica (forces K "
                         "host devices; must be first jax initialization)")
    args = ap.parse_args()
    if args.migrate_at is not None:
        args.router = True
    if args.router or args.trace:
        args.continuous = True

    if not args.continuous:
        server = Server(args.arch, smoke=args.smoke,
                        max_seq=args.prompt_len + args.gen + 8,
                        page_size=args.page_size)
        rng = np.random.RandomState(args.seed)
        prompts = rng.randint(0, server.cfg.vocab_size,
                              (args.batch, args.prompt_len)).astype(np.int32)
        fe = None
        if server.cfg.n_frontend_tokens:
            fe = rng.randn(args.batch, server.cfg.n_frontend_tokens,
                           server.cfg.d_model).astype(np.float32) * 0.02
        res = server.generate(prompts, args.gen, fe)
        print(f"generated {res['tokens'].shape} tokens; "
              f"prefill {res['prefill_s']*1e3:.0f} ms, "
              f"decode {res['decode_tok_per_s']:.1f} tok/s")
        return

    prefill_chunk = _resolve_prefill_chunk(args.prefill_chunk, args.smoke)
    eng = ServeEngine(args.arch, smoke=args.smoke, max_batch=args.max_batch,
                      page_size=args.page_size,
                      max_seq=64 + args.page_size * 2, seed=args.seed,
                      paged_impl=args.paged_impl,
                      prefill_chunk=prefill_chunk, speculate=args.speculate,
                      trace=bool(args.trace),
                      trace_clock=_trace_clock_factory(args)())
    specs = _mixed_trace_specs(eng.cfg, eng.page_size, args.requests,
                               args.seed)
    reqs = [eng.submit(prompt, gen, arrival_step=arrival, frontend_embeds=fe)
            for prompt, gen, arrival, fe in specs]
    stats = eng.run()
    done = [r for r in reqs if r.finished_step >= 0]
    print(f"served {len(done)}/{len(reqs)} requests in {eng.step_count} steps "
          f"(mean batch {stats['mean_batch']:.2f}, "
          f"{stats['decode_tok_per_s']:.1f} tok/s, "
          f"prefix hits {stats.get('prefix_hits', 0)})")
    joins = sum(1 for r in reqs if r.admitted_step > 0)
    print(f"join-on-arrival: {joins} requests joined a running batch")
    if "join_to_first_token_p50" in stats:
        print(f"join-to-first-token: p50 {stats['join_to_first_token_p50']:.1f}"
              f" p99 {stats['join_to_first_token_p99']:.1f} steps")

    if prefill_chunk is not None or args.speculate:
        if prefill_chunk is not None:
            print(f"chunked prefill: {stats.get('prefill_chunks', 0)} chunk "
                  f"steps / {stats.get('prefill_chunk_tokens', 0)} prompt "
                  f"tokens at budget {prefill_chunk}")
        if args.speculate:
            print(f"speculation: accept rate "
                  f"{stats.get('spec_accept_rate', 0.0):.2f} "
                  f"({stats.get('draft_accepted', 0)}/"
                  f"{stats.get('draft_proposed', 0)} drafted tokens)")
        base = ServeEngine(args.arch, smoke=args.smoke,
                           max_batch=args.max_batch,
                           page_size=args.page_size,
                           max_seq=64 + args.page_size * 2, seed=args.seed,
                           paged_impl=args.paged_impl)
        base_reqs = _mixed_trace(base, args.requests, args.seed)
        base.run()
        identical = all(r.generated == b.generated
                        for r, b in zip(reqs, base_reqs))
        print(f"chunked+speculative vs one-token baseline: "
              f"bit_identical={'yes' if identical else 'NO'}")
        if not identical:
            print("FAIL: chunked/speculative outputs diverge from baseline")
            sys.exit(1)

    planner = CapacityPlanner()
    tune_evs: List = []
    if args.tune_cache:
        from repro.kernels.tune import ConfigCache, tune_events

        n_layers = eng.cfg.n_layers
        tune_evs = list(tune_events(ConfigCache(args.tune_cache)))
        n = planner.ingest(tune_evs, n_layers=n_layers)
        print(f"capacity plan: seeded with {n} measured kernel row(s) "
              f"from {args.tune_cache} (x{n_layers} layers)")
    planner.ingest(eng.events("serve_step"))
    plan = None
    try:
        planner.fit()
    except ValueError as e:
        print(f"capacity plan: insufficient telemetry ({e})")
    else:
        t1, t8 = planner.step_time(1), planner.step_time(8)
        print(f"f(b) step model: t(1)={t1*1e3:.1f} ms  t(8)={t8*1e3:.1f} ms  "
              f"coeffs={planner.step_model.coefficients()}")
        plan = planner.plan(target_p50_s=max(10 * t8 * 8, 1e-3), qps=2.0,
                            gen_tokens=8, batch_grid=[1, 2, 4, 8],
                            m_grid=[1, 2, 4, 8, 16])
        if plan:
            print(f"capacity plan: {plan.algorithm} on m={plan.m} replicas "
                  f"(predicted p50 {plan.predicted_time*1e3:.1f} ms)")
        else:
            print(f"capacity plan: no feasible operating point "
                  f"({plan.reason})")

    router = None
    if args.router:
        n_replicas = args.replicas
        if n_replicas <= 0:
            n_replicas = plan.m if plan else 2
            print(f"router: --replicas 0 -> planner min-replicas answer "
                  f"m={n_replicas}")
        router = _run_router(args, specs, reqs, n_replicas, prefill_chunk)

    if args.trace:
        trace_events = (router.all_events() if router is not None
                        else list(eng.events()))
        busy = sum(e.step_s for e in trace_events
                   if getattr(e, "kind", "") == "serve_step")
        _export_trace(args, list(trace_events) + tune_evs, planner, busy,
                      eng.cfg.n_layers)

    ok = _verify_prefix_reuse(args.arch, args.smoke, eng, args.seed)
    if not ok:
        print("FAIL: prefix-reuse verification")
        sys.exit(1)


if __name__ == "__main__":
    main()
