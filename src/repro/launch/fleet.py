"""Fleet simulator CLI: run a multi-tenant day on the simulated cluster.

  python -m repro.launch.fleet --seed 0
  python -m repro.launch.fleet --seed 0 --out run.json
  python -m repro.launch.fleet --trace trace.json --seed 0   # replay chaos
  python -m repro.launch.fleet --replay run.json             # verify a log

``--trace`` takes a ``ChaosTrace`` JSON (the same format launch/train.py's
--chaos consumes), so a recorded incident drives the fleet scheduler
instead of a seeded draw.  Every run re-verifies the replay guarantee
unless ``--no-replay`` is given.
"""
from __future__ import annotations

import argparse
import json
import sys


def summarize(log) -> None:
    s = log.meta["summary"]
    print(f"ticks={len(log.rows)} hosts={log.trace.n_hosts} "
          f"decisions={log.n_decisions()} "
          f"fleet_cost={s['cost_host_hours']:.1f} host-hours")
    for name, d in s["serve"].items():
        flag = "met" if d["slo_met"] else "VIOLATED"
        print(f"  serve {name}: p95={d['p95_s']:.3f}s "
              f"(slo {d['slo_p95_s']}s {flag}), "
              f"final replicas={d['final_replicas']}")
    for name, j in s["jobs"].items():
        if j["state"] == "done":
            hrs = j["finish_s"] / 3600.0
            flag = "in time" if j["met_deadline"] else "LATE"
            print(f"  train {name}: done at {hrs:.1f}h "
                  f"(deadline {j['deadline_s'] / 3600.0:.1f}h, {flag})")
        elif j["state"] == "infeasible":
            print(f"  train {name}: NoFeasiblePlan "
                  f"[{j['no_plan']['query']}] {j['no_plan']['reason']}")
        else:
            print(f"  train {name}: {j['state']} "
                  f"(progress {j['progress']:.2f})")
    for step, d in log.decisions():
        print(f"    tick {step:4d} {d}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ticks", type=int, default=None,
                    help="horizon in ticks (default: the 24h scenario, 288)")
    ap.add_argument("--hosts", type=int, default=None)
    ap.add_argument("--trace", default=None,
                    help="drive the fleet from this ChaosTrace JSON")
    ap.add_argument("--scenario", default="day",
                    choices=("day", "drift", "migrate"),
                    help="scenario builder: the 24h day, the streaming-"
                         "refit drift story, or the measured-recovery-cost "
                         "migration story")
    ap.add_argument("--drift", action="store_true",
                    help="turn the scheduler's streaming pace refit on")
    ap.add_argument("--measured", action="store_true",
                    help="feed measured restore/re-shard wall-times back "
                         "into resize planning (the migrate scenario's "
                         "closed loop)")
    ap.add_argument("--out", default=None, help="write FleetRunLog JSON here")
    ap.add_argument("--spans", default=None, metavar="TRACE_JSON",
                    help="emit modeled-time tick/job/deployment spans and "
                         "export them as a Perfetto trace here")
    ap.add_argument("--slo", action="store_true",
                    help="stream each deployment's tick latency through an "
                         "SLO burn-rate monitor (alerts become decisions "
                         "and boost autoscale headroom)")
    ap.add_argument("--replay", default=None, metavar="RUN_JSON",
                    help="load a recorded FleetRunLog and verify it replays")
    ap.add_argument("--no-replay", action="store_true",
                    help="skip the replay determinism check")
    args = ap.parse_args(argv)

    from repro.fleet import replay as replay_log
    from repro.fleet import run_fleet_sim
    from repro.runtime.chaos import ChaosTrace

    if args.replay:
        from repro.fleet import FleetRunLog
        recorded = FleetRunLog.load(args.replay)
        again = replay_log(recorded)
        if again.signature() != recorded.signature():
            print("replay DIVERGED from the recorded run", file=sys.stderr)
            return 1
        print(f"{args.replay}: replays bit-identically "
              f"({len(recorded.rows)} ticks)")
        summarize(recorded)
        return 0

    trace = None
    if args.trace:
        with open(args.trace) as f:
            trace = ChaosTrace.from_json(json.load(f))
        if args.hosts and args.hosts != trace.n_hosts:
            print(f"--hosts {args.hosts} ignored: the trace fixes the "
                  f"inventory at {trace.n_hosts} hosts", file=sys.stderr)
    ticks = args.ticks or (trace.steps if trace else None)
    hosts = trace.n_hosts if trace else args.hosts
    log = run_fleet_sim(args.seed, ticks=ticks, n_hosts=hosts, trace=trace,
                        scenario=args.scenario, drift=args.drift,
                        spans=bool(args.spans), slo=args.slo,
                        measured=args.measured)
    summarize(log)
    if args.measured:
        for e in log.events("ckpt_cost"):
            print(f"  ckpt_cost tick {e.step:4d} {e.op}:{e.workload} "
                  f"measured={e.wall_s:.0f}s planned={e.assumed_s:.0f}s")
    if args.slo:
        alerts = log.events("slo_alert")
        for a in alerts:
            print(f"  slo_alert tick {a.step:4d} {a.slo}: "
                  f"burn={a.burn_rate:.2f}x budget "
                  f"(remaining {a.budget_remaining:.0%})")
        print(f"slo: {len(alerts)} burn-rate alerts")
    if args.spans:
        from repro.telemetry.trace import write_perfetto
        n = write_perfetto(args.spans, log.events("span"))
        print(f"trace: {n} spans -> {args.spans}")
    if not args.no_replay:
        again = replay_log(log)
        assert again.signature() == log.signature(), \
            "replay diverged from the original run"
        print("replay: identical decision/allocation sequence ✓")
    if args.out:
        log.save(args.out)
        print(f"run log -> {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
