"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state.  The production target is a TPU v5e pod of
16 x 16 = 256 chips (axes: data, model), and 2 pods = 512 chips with a
leading "pod" axis.  On this CPU container the dry-run launcher sets
XLA_FLAGS=--xla_force_host_platform_device_count=... before any jax import
so these shapes can be built from placeholder host devices.
"""
from __future__ import annotations

import numpy as np

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devices)}; "
            "set XLA_FLAGS=--xla_force_host_platform_device_count=512 before "
            "importing jax (launch/dryrun.py does this)")
    return jax.make_mesh(shape, axes, devices=devices[:n])


def make_debug_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (possibly forced-host) devices exist."""
    n = data * model
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(f"need {n} devices, have {len(devices)}")
    return jax.make_mesh((data, model), ("data", "model"),
                         devices=devices[:n])


def make_scaled_mesh(n_chips: int, model: int = 16):
    """Meshes of varying size for Ernest f(m) fitting (m = n_chips).

    Keeps the model axis fixed (TP within a host ring) and scales the data
    axis, mirroring how capacity is added in production."""
    model = min(model, n_chips)
    data = n_chips // model
    devices = jax.devices()
    if len(devices) < data * model:
        raise RuntimeError(f"need {data * model} devices, have {len(devices)}")
    return jax.make_mesh((data, model), ("data", "model"),
                         devices=devices[: data * model])
