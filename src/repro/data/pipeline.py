"""Deterministic synthetic token pipeline: shardable + exactly resumable.

Production shape: each host slices its batch rows from the global batch
(``host_slice``); the iterator state is one integer (step) + the seed, so a
restored checkpoint resumes the exact token stream (tested in
tests/test_checkpoint.py).  Tokens follow a Zipfian-ish distribution over
the vocab with a repeating n-gram structure so tiny LMs have signal to fit
(loss decreases — used by the convergence-model experiments).
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import numpy as np


@dataclasses.dataclass
class DataState:
    seed: int
    step: int

    def to_dict(self) -> Dict:
        return {"seed": self.seed, "step": self.step}

    @classmethod
    def from_dict(cls, d: Dict) -> "DataState":
        return cls(int(d["seed"]), int(d["step"]))


class SyntheticTokens:
    """Next-token-prediction batches with learnable structure."""

    def __init__(self, vocab_size: int, seq_len: int, global_batch: int,
                 seed: int = 0, n_frontend: int = 0, d_model: int = 0,
                 ngram: int = 4):
        self.vocab = vocab_size
        self.seq_len = seq_len
        self.global_batch = global_batch
        self.state = DataState(seed=seed, step=0)
        self.ngram = ngram
        self.n_frontend = n_frontend
        self.d_model = d_model
        # fixed "language": a random n-gram transition table
        rng = np.random.RandomState(seed + 101)
        self.table = rng.randint(0, vocab_size, size=(256,)).astype(np.int32)

    # ------------------------------------------------------------------
    def _batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.RandomState((self.state.seed * 1_000_003 + step)
                                    % (2 ** 31 - 1))
        b, s = self.global_batch, self.seq_len
        # zipf-ish marginals + deterministic n-gram continuation
        base = rng.zipf(1.3, size=(b, s)).astype(np.int64)
        tokens = (base % self.vocab).astype(np.int32)
        for t in range(self.ngram, s, self.ngram):
            ctx = tokens[:, t - 1] % 256
            tokens[:, t] = self.table[ctx]
        labels = np.concatenate([tokens[:, 1:], tokens[:, :1]], axis=1)
        out = {"tokens": tokens, "labels": labels}
        if self.n_frontend:
            out["frontend_embeds"] = rng.randn(
                b, self.n_frontend, self.d_model).astype(np.float32) * 0.02
        return out

    def next_batch(self) -> Dict[str, np.ndarray]:
        batch = self._batch_at(self.state.step)
        self.state.step += 1
        return batch

    def host_slice(self, batch: Dict[str, np.ndarray], host_id: int,
                   n_hosts: int) -> Dict[str, np.ndarray]:
        per = self.global_batch // n_hosts
        sl = slice(host_id * per, (host_id + 1) * per)
        return {k: v[sl] for k, v in batch.items()}

    # ------------------------------------------------------------------
    def state_dict(self) -> Dict:
        return self.state.to_dict()

    def load_state_dict(self, d: Dict) -> None:
        self.state = DataState.from_dict(d)
