"""musicgen-medium — decoder-only transformer over EnCodec tokens.

[arXiv:2306.05284; hf] 48L d_model=1536 24H (kv=24, i.e. MHA) d_ff=6144
vocab=2048.  The audio/text conditioning frontend is a STUB per the
assignment: ``input_specs()`` provides precomputed conditioning frame
embeddings prepended to the EnCodec token stream.
"""
from repro.configs.base import ArchConfig, LayerSpec


def config() -> ArchConfig:
    return ArchConfig(
        name="musicgen-medium",
        family="audio",
        n_layers=48,
        d_model=1_536,
        n_heads=24,
        n_kv_heads=24,
        head_dim=64,
        d_ff=6_144,
        vocab_size=2_048,
        period=(LayerSpec(mixer="attn", ffn="dense"),),
        frontend="audio_stub",
        n_frontend_tokens=64,
        source="arXiv:2306.05284",
    )
