"""Base configuration dataclasses for the repro framework.

Every assigned architecture is expressed as an :class:`ArchConfig`.  The model
code (src/repro/models) is driven entirely by these configs; nothing about a
specific architecture is hard-coded in the model.

Layer layout is described by a *repeating period* so the transformer stack can
be lowered as ``scan(period)`` (cheap to trace/compile even for 80-layer
models):

* pure dense / moe / mamba archs   -> period of length 1
* jamba-style hybrids              -> period of length 8 (1 attn : 7 mamba)
* first-k-dense MoE (deepseek)     -> ``first_k_dense`` layers unrolled, then
                                      scan over the repeating MoE period.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import Literal, Optional, Sequence, Tuple

Family = Literal["dense", "moe", "ssm", "hybrid", "vlm", "audio"]
Mixer = Literal["attn", "mamba"]
Ffn = Literal["dense", "moe", "none"]


@dataclass(frozen=True)
class LayerSpec:
    """One layer inside the repeating period."""

    mixer: Mixer = "attn"
    ffn: Ffn = "dense"


@dataclass(frozen=True)
class MoEConfig:
    n_routed_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 1
    expert_d_ff: int = 0
    # capacity factor for the EP all_to_all dispatch path
    capacity_factor: float = 1.25
    router_aux_loss: float = 0.001
    # normalise top-k router weights to sum to one (deepseek-style)
    norm_topk: bool = True


@dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V2 multi-head latent attention."""

    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class MambaConfig:
    d_state: int = 16
    expand: int = 2
    d_conv: int = 4
    dt_rank: int = 0  # 0 => ceil(d_model / 16)

    def resolved_dt_rank(self, d_model: int) -> int:
        return self.dt_rank or int(math.ceil(d_model / 16))


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: Family
    n_layers: int
    d_model: int
    vocab_size: int
    # --- attention ---
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0
    qk_norm: bool = False
    qkv_bias: bool = False
    rotary_pct: float = 1.0
    rope_theta: float = 10_000.0
    # --- ffn ---
    d_ff: int = 0
    # --- moe / mla / mamba sub-configs ---
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    mamba: Optional[MambaConfig] = None
    # --- layer layout ---
    period: Tuple[LayerSpec, ...] = (LayerSpec(),)
    first_k_dense: int = 0  # leading layers forced to (attn|mamba as period[0].mixer, dense ffn)
    # --- frontend stubs (vlm / audio) ---
    frontend: Literal["none", "vision_stub", "audio_stub"] = "none"
    n_frontend_tokens: int = 0
    # --- misc ---
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    source: str = ""

    # ------------------------------------------------------------------
    def __post_init__(self):
        if self.n_layers % len(self.period) and self.n_layers > self.first_k_dense:
            n_scan = self.n_layers - self.first_k_dense
            if n_scan % len(self.period):
                raise ValueError(
                    f"{self.name}: n_layers-first_k_dense={n_scan} not divisible "
                    f"by period length {len(self.period)}"
                )

    # ------------------------------------------------------------------
    @property
    def n_periods(self) -> int:
        return (self.n_layers - self.first_k_dense) // len(self.period)

    @property
    def uses_attention(self) -> bool:
        return any(l.mixer == "attn" for l in self.period) or self.first_k_dense > 0

    @property
    def pure_attention(self) -> bool:
        return all(l.mixer == "attn" for l in self.period)

    @property
    def uses_mamba(self) -> bool:
        return any(l.mixer == "mamba" for l in self.period)

    @property
    def uses_moe(self) -> bool:
        return self.moe is not None and any(l.ffn == "moe" for l in self.period)

    def layer_specs(self) -> Sequence[LayerSpec]:
        """Fully unrolled layer list (for reference / parameter counting)."""
        head = [dataclasses.replace(self.period[0], ffn="dense")] * self.first_k_dense
        body = list(self.period) * self.n_periods
        return head + body

    # ------------------------------------------------------------------
    # Parameter counting (used for MODEL_FLOPS = 6*N*D roofline term).
    # ------------------------------------------------------------------
    def attn_params(self) -> int:
        d = self.d_model
        if self.mla is not None:
            m = self.mla
            q = d * m.q_lora_rank + m.q_lora_rank * self.n_heads * (
                m.qk_nope_head_dim + m.qk_rope_head_dim
            )
            kv = d * (m.kv_lora_rank + m.qk_rope_head_dim) + m.kv_lora_rank * self.n_heads * (
                m.qk_nope_head_dim + m.v_head_dim
            )
            o = self.n_heads * m.v_head_dim * d
            return q + kv + o
        hd = self.head_dim
        q = d * self.n_heads * hd
        k = d * self.n_kv_heads * hd
        v = d * self.n_kv_heads * hd
        o = self.n_heads * hd * d
        bias = (self.n_heads + 2 * self.n_kv_heads) * hd if self.qkv_bias else 0
        return q + k + v + o + bias

    def mamba_params(self) -> int:
        assert self.mamba is not None
        d = self.d_model
        cfg = self.mamba
        d_in = cfg.expand * d
        dt_rank = cfg.resolved_dt_rank(d)
        in_proj = d * 2 * d_in
        conv = d_in * cfg.d_conv + d_in
        x_proj = d_in * (dt_rank + 2 * cfg.d_state)
        dt_proj = dt_rank * d_in + d_in
        a_d = d_in * cfg.d_state + d_in
        out_proj = d_in * d
        return in_proj + conv + x_proj + dt_proj + a_d + out_proj

    def dense_ffn_params(self) -> int:
        # SwiGLU: gate, up, down
        return 3 * self.d_model * self.d_ff

    def moe_ffn_params(self, active_only: bool = False) -> int:
        assert self.moe is not None
        moe = self.moe
        per_expert = 3 * self.d_model * moe.expert_d_ff
        router = self.d_model * moe.n_routed_experts
        shared = moe.n_shared_experts * per_expert
        routed = (moe.top_k if active_only else moe.n_routed_experts) * per_expert
        return router + shared + routed

    def param_count(self, active_only: bool = False) -> int:
        """Total (or active, for MoE) parameter count, embeddings included."""
        total = self.vocab_size * self.d_model  # embed
        if not self.tie_embeddings:
            total += self.vocab_size * self.d_model  # lm head
        for spec in self.layer_specs():
            if spec.mixer == "attn":
                total += self.attn_params()
            else:
                total += self.mamba_params()
            if spec.ffn == "dense":
                total += self.dense_ffn_params()
            elif spec.ffn == "moe":
                total += self.moe_ffn_params(active_only=active_only)
            # 2 rmsnorm scales per layer
            total += 2 * self.d_model
        total += self.d_model  # final norm
        return total


@dataclass(frozen=True)
class ShapeSpec:
    """One assigned input shape."""

    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


TRAIN_4K = ShapeSpec("train_4k", 4_096, 256, "train")
PREFILL_32K = ShapeSpec("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeSpec("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeSpec("long_500k", 524_288, 1, "decode")

ALL_SHAPES: Tuple[ShapeSpec, ...] = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES_BY_NAME = {s.name: s for s in ALL_SHAPES}


def applicable_shapes(cfg: ArchConfig) -> Tuple[ShapeSpec, ...]:
    """long_500k requires sub-quadratic attention: SSM / hybrid only.

    All assigned archs are decoders, so decode shapes apply everywhere.
    """
    shapes = [TRAIN_4K, PREFILL_32K, DECODE_32K]
    if cfg.uses_mamba:  # ssm & hybrid families
        shapes.append(LONG_500K)
    return tuple(shapes)
