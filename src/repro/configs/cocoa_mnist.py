"""The paper's own experimental workload (§2.3, §4).

Binary classification (digit == 5) on MNIST, linear SVM loss, solved with
CoCoA / CoCoA+ while varying the degree of parallelism m in powers of two.
MNIST itself is not available offline, so we generate a synthetic stand-in
with the same shape (60000 x 784), a realistic low-rank covariance spectrum
and the same ~9% positive-class imbalance.  See repro.optim.problems.
"""
from dataclasses import dataclass
from typing import Tuple


@dataclass(frozen=True)
class CocoaMnistConfig:
    n_examples: int = 60_000
    n_features: int = 784
    positive_fraction: float = 0.09  # fraction of digit-5 labels in MNIST
    effective_rank: int = 40  # MNIST pixels are highly correlated
    noise: float = 0.35
    lam: float = 1e-4  # L2 regularization (lambda)
    seed: int = 0
    # sweep used by the paper: m = 1..128 in powers of 2
    parallelism_sweep: Tuple[int, ...] = (1, 2, 4, 8, 16, 32, 64, 128)
    target_suboptimality: float = 1e-4
    max_outer_iters: int = 500
    local_iters_fraction: float = 1.0  # H = fraction * n_local per outer iter


def config() -> CocoaMnistConfig:
    return CocoaMnistConfig()


def smoke_config() -> CocoaMnistConfig:
    return CocoaMnistConfig(
        n_examples=2_048,
        n_features=64,
        effective_rank=16,
        parallelism_sweep=(1, 2, 4, 8),
        max_outer_iters=60,
    )
