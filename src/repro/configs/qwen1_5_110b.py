"""qwen1.5-110b — dense transformer, GQA + QKV bias.

[hf:Qwen/Qwen1.5 family; hf] 80L d_model=8192 64H (GQA kv=8) d_ff=49152
vocab=152064, head_dim=128, QKV bias.
"""
from repro.configs.base import ArchConfig, LayerSpec


def config() -> ArchConfig:
    return ArchConfig(
        name="qwen1.5-110b",
        family="dense",
        n_layers=80,
        d_model=8_192,
        n_heads=64,
        n_kv_heads=8,
        head_dim=128,
        d_ff=49_152,
        vocab_size=152_064,
        qkv_bias=True,
        rope_theta=1_000_000.0,
        period=(LayerSpec(mixer="attn", ffn="dense"),),
        source="hf:Qwen/Qwen1.5-110B",
    )
