"""qwen3-14b — dense transformer, GQA + qk_norm.

[hf:Qwen/Qwen3-8B family; hf] 40L d_model=5120 40H (GQA kv=8) d_ff=17408
vocab=151936, head_dim=128, qk-norm.
"""
from repro.configs.base import ArchConfig, LayerSpec


def config() -> ArchConfig:
    return ArchConfig(
        name="qwen3-14b",
        family="dense",
        n_layers=40,
        d_model=5_120,
        n_heads=40,
        n_kv_heads=8,
        head_dim=128,
        d_ff=17_408,
        vocab_size=151_936,
        qk_norm=True,
        rope_theta=1_000_000.0,
        period=(LayerSpec(mixer="attn", ffn="dense"),),
        source="hf:Qwen/Qwen3-14B",
    )
