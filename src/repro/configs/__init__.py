"""Architecture registry: ``get_config(arch_id)`` / ``get_smoke_config``.

Arch ids use the assignment's dashed names, e.g. ``--arch qwen3-14b``.
"""
from __future__ import annotations

from typing import Callable, Dict

from repro.configs.base import (
    ALL_SHAPES,
    ArchConfig,
    LayerSpec,
    MambaConfig,
    MLAConfig,
    MoEConfig,
    ShapeSpec,
    SHAPES_BY_NAME,
    applicable_shapes,
)
from repro.configs.smoke import (
    SMOKE_DECODE,
    SMOKE_PREFILL,
    SMOKE_TRAIN,
    smoke_variant,
)

from repro.configs import (  # noqa: E402  (module registry)
    deepseek_moe_16b,
    deepseek_v2_236b,
    falcon_mamba_7b,
    internvl2_76b,
    jamba_1_5_large_398b,
    musicgen_medium,
    qwen1_5_110b,
    qwen3_14b,
    qwen3_32b,
    stablelm_1_6b,
)

_REGISTRY: Dict[str, Callable[[], ArchConfig]] = {
    "falcon-mamba-7b": falcon_mamba_7b.config,
    "stablelm-1.6b": stablelm_1_6b.config,
    "qwen3-14b": qwen3_14b.config,
    "qwen1.5-110b": qwen1_5_110b.config,
    "qwen3-32b": qwen3_32b.config,
    "internvl2-76b": internvl2_76b.config,
    "jamba-1.5-large-398b": jamba_1_5_large_398b.config,
    "musicgen-medium": musicgen_medium.config,
    "deepseek-v2-236b": deepseek_v2_236b.config,
    "deepseek-moe-16b": deepseek_moe_16b.config,
}

ARCH_IDS = tuple(_REGISTRY)


def get_config(arch_id: str) -> ArchConfig:
    try:
        return _REGISTRY[arch_id]()
    except KeyError:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_REGISTRY)}") from None


def get_smoke_config(arch_id: str) -> ArchConfig:
    return smoke_variant(get_config(arch_id))


__all__ = [
    "ALL_SHAPES",
    "ARCH_IDS",
    "ArchConfig",
    "LayerSpec",
    "MambaConfig",
    "MLAConfig",
    "MoEConfig",
    "ShapeSpec",
    "SHAPES_BY_NAME",
    "SMOKE_DECODE",
    "SMOKE_PREFILL",
    "SMOKE_TRAIN",
    "applicable_shapes",
    "get_config",
    "get_smoke_config",
    "smoke_variant",
]
