"""falcon-mamba-7b — pure Mamba-1 (attention-free) LM.

[arXiv:2410.05355; unverified] 64L d_model=4096 d_ff=0 vocab=65024 ssm_state=16.
"""
from repro.configs.base import ArchConfig, LayerSpec, MambaConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="falcon-mamba-7b",
        family="ssm",
        n_layers=64,
        d_model=4_096,
        vocab_size=65_024,
        d_ff=0,
        mamba=MambaConfig(d_state=16, expand=2, d_conv=4),
        period=(LayerSpec(mixer="mamba", ffn="none"),),
        tie_embeddings=False,
        source="arXiv:2410.05355",
    )
