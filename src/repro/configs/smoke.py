"""Reduced smoke-test variants of every architecture.

Same *family* (layer period, MoE/MLA/Mamba structure, frontend) but tiny
dimensions so one forward/train step runs in <1s on CPU.  Full configs are
only ever exercised via the dry-run (ShapeDtypeStruct, no allocation).
"""
from __future__ import annotations

import dataclasses
import math

from repro.configs.base import ArchConfig, MambaConfig, MLAConfig, ShapeSpec


def smoke_variant(cfg: ArchConfig) -> ArchConfig:
    """Shrink every dimension while preserving structure."""
    period_len = len(cfg.period)
    n_layers = cfg.first_k_dense + period_len  # one period + dense head
    kv = max(1, min(cfg.n_kv_heads, 2))
    heads = max(kv, min(cfg.n_heads, 4))
    heads = int(math.ceil(heads / kv) * kv)  # heads divisible by kv
    moe = cfg.moe
    if moe is not None:
        moe = dataclasses.replace(
            moe,
            n_routed_experts=min(moe.n_routed_experts, 8),
            n_shared_experts=min(moe.n_shared_experts, 1),
            top_k=min(moe.top_k, 2),
            expert_d_ff=64,
        )
    mla = cfg.mla
    if mla is not None:
        mla = MLAConfig(
            q_lora_rank=32,
            kv_lora_rank=16,
            qk_nope_head_dim=16,
            qk_rope_head_dim=8,
            v_head_dim=16,
        )
    mamba = cfg.mamba
    if mamba is not None:
        mamba = MambaConfig(d_state=4, expand=2, d_conv=4, dt_rank=8)
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        n_layers=n_layers,
        d_model=64,
        n_heads=heads if cfg.n_heads else 0,
        n_kv_heads=kv if cfg.n_kv_heads else 0,
        head_dim=16 if cfg.head_dim else 0,
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=256,
        moe=moe,
        mla=mla,
        mamba=mamba,
        n_frontend_tokens=8 if cfg.n_frontend_tokens else 0,
    )


SMOKE_TRAIN = ShapeSpec("smoke_train", seq_len=32, global_batch=2, kind="train")
SMOKE_PREFILL = ShapeSpec("smoke_prefill", seq_len=32, global_batch=2, kind="prefill")
SMOKE_DECODE = ShapeSpec("smoke_decode", seq_len=32, global_batch=2, kind="decode")
