"""deepseek-v2-236b — MoE with Multi-head Latent Attention (MLA).

[arXiv:2405.04434; hf] 60L d_model=5120 128H, MLA kv_lora=512 (q_lora=1536,
qk_nope=128, qk_rope=64, v_head=128), vocab=102400, MoE: 2 shared + 160
routed experts top-6, expert d_ff=1536, first layer dense (d_ff=12288).
"""
from repro.configs.base import ArchConfig, LayerSpec, MLAConfig, MoEConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="deepseek-v2-236b",
        family="moe",
        n_layers=60,
        d_model=5_120,
        n_heads=128,
        n_kv_heads=128,
        head_dim=128,
        d_ff=12_288,  # dense layers (first_k_dense)
        vocab_size=102_400,
        mla=MLAConfig(
            q_lora_rank=1_536,
            kv_lora_rank=512,
            qk_nope_head_dim=128,
            qk_rope_head_dim=64,
            v_head_dim=128,
        ),
        moe=MoEConfig(
            n_routed_experts=160,
            n_shared_experts=2,
            top_k=6,
            expert_d_ff=1_536,
        ),
        period=(LayerSpec(mixer="attn", ffn="moe"),),
        first_k_dense=1,
        source="arXiv:2405.04434",
    )
