"""jamba-1.5-large-398b — hybrid Mamba + attention + MoE.

[arXiv:2403.19887; hf] 72L d_model=8192 64H (GQA kv=8) d_ff=24576 vocab=65536,
MoE 16 experts top-2.  Layer layout: period of 8 with attention:mamba = 1:7
(attention at period position 4, as in the Jamba paper) and MoE applied every
other layer (odd positions).  72 = 9 periods of 8.
"""
from repro.configs.base import ArchConfig, LayerSpec, MambaConfig, MoEConfig


def _period():
    specs = []
    for j in range(8):
        mixer = "attn" if j == 4 else "mamba"
        ffn = "moe" if j % 2 == 1 else "dense"
        specs.append(LayerSpec(mixer=mixer, ffn=ffn))
    return tuple(specs)


def config() -> ArchConfig:
    return ArchConfig(
        name="jamba-1.5-large-398b",
        family="hybrid",
        n_layers=72,
        d_model=8_192,
        n_heads=64,
        n_kv_heads=8,
        head_dim=128,
        d_ff=24_576,
        vocab_size=65_536,
        moe=MoEConfig(
            n_routed_experts=16,
            n_shared_experts=0,
            top_k=2,
            expert_d_ff=24_576,
        ),
        mamba=MambaConfig(d_state=16, expand=2, d_conv=4),
        period=_period(),
        source="arXiv:2403.19887",
    )
