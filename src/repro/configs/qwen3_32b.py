"""qwen3-32b — dense transformer, GQA + qk_norm (head_dim 128 > d/H).

[hf:Qwen/Qwen3-32B; hf] 64L d_model=5120 64H (GQA kv=8) d_ff=25600
vocab=151936, head_dim=128 (q/k/v project to 8192).
"""
from repro.configs.base import ArchConfig, LayerSpec


def config() -> ArchConfig:
    return ArchConfig(
        name="qwen3-32b",
        family="dense",
        n_layers=64,
        d_model=5_120,
        n_heads=64,
        n_kv_heads=8,
        head_dim=128,
        d_ff=25_600,
        vocab_size=151_936,
        qk_norm=True,
        rope_theta=1_000_000.0,
        period=(LayerSpec(mixer="attn", ffn="dense"),),
        source="hf:Qwen/Qwen3-32B",
    )
