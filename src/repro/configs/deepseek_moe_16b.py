"""deepseek-moe-16b — fine-grained MoE, 2 shared + 64 routed top-6.

[arXiv:2401.06066; hf] 28L d_model=2048 16H (MHA kv=16) head_dim=128,
vocab=102400, expert d_ff=1408, first layer dense (d_ff=10944).
"""
from repro.configs.base import ArchConfig, LayerSpec, MoEConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="deepseek-moe-16b",
        family="moe",
        n_layers=28,
        d_model=2_048,
        n_heads=16,
        n_kv_heads=16,
        head_dim=128,
        d_ff=10_944,  # dense layers (first_k_dense)
        vocab_size=102_400,
        moe=MoEConfig(
            n_routed_experts=64,
            n_shared_experts=2,
            top_k=6,
            expert_d_ff=1_408,
        ),
        period=(LayerSpec(mixer="attn", ffn="moe"),),
        first_k_dense=1,
        source="arXiv:2401.06066",
    )
