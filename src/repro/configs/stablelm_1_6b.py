"""stablelm-1.6b — dense transformer, MHA, partial rotary.

[hf:stabilityai/stablelm-2-1_6b; unverified] 24L d_model=2048 32H (kv=32)
d_ff=5632 vocab=100352.  StableLM-2 uses 25% partial rotary embeddings.
"""
from repro.configs.base import ArchConfig, LayerSpec


def config() -> ArchConfig:
    return ArchConfig(
        name="stablelm-1.6b",
        family="dense",
        n_layers=24,
        d_model=2_048,
        n_heads=32,
        n_kv_heads=32,
        head_dim=64,
        d_ff=5_632,
        vocab_size=100_352,
        rotary_pct=0.25,
        period=(LayerSpec(mixer="attn", ffn="dense"),),
        source="hf:stabilityai/stablelm-2-1_6b",
    )
