"""internvl2-76b — VLM: InternViT frontend (stub) + InternLM2-style backbone.

[arXiv:2404.16821; unverified] backbone: 80L d_model=8192 64H (GQA kv=8)
d_ff=28672 vocab=128256.  Per the assignment, the vision frontend is a STUB:
``input_specs()`` provides precomputed patch embeddings (n_frontend_tokens per
image, already projected to d_model) which the model prepends to the token
embeddings.
"""
from repro.configs.base import ArchConfig, LayerSpec


def config() -> ArchConfig:
    return ArchConfig(
        name="internvl2-76b",
        family="vlm",
        n_layers=80,
        d_model=8_192,
        n_heads=64,
        n_kv_heads=8,
        head_dim=128,
        d_ff=28_672,
        vocab_size=128_256,
        period=(LayerSpec(mixer="attn", ffn="dense"),),
        frontend="vision_stub",
        n_frontend_tokens=256,
        source="arXiv:2404.16821",
    )
