# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.
"""Shared pallas compatibility helpers for the kernel implementations."""


def tpu_compiler_params(**kwargs):
    """``pltpu.CompilerParams`` was ``TPUCompilerParams`` before jax 0.5;
    construct whichever this jax ships."""
    from jax.experimental.pallas import tpu as pltpu

    cls = getattr(pltpu, "CompilerParams", None) or getattr(pltpu, "TPUCompilerParams")
    return cls(**kwargs)
