"""Chunked selective scan (Mamba-1) — the TPU-adapted formulation.

Instead of a length-S sequential scan (latency-bound) or one big
associative scan (memory-bound: O(S * Dn * N) live temporaries), we scan
sequentially over chunks of `chunk` timesteps and run an associative scan
*within* each chunk.  Peak temporary memory is O(chunk * Dn * N) per batch
element and the sequential depth is S / chunk.  The chunk body is
rematerialized (jax.checkpoint) so the backward pass does not store the
per-step (Bt, chunk, Dn, N) products.

The Pallas kernel (kernel.py) implements the same chunking with the
(chunk, Dn_block) tiles resident in VMEM.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax


def _combine(e1, e2):
    a1, b1 = e1
    a2, b2 = e2
    return a1 * a2, a2 * b1 + b2


def selective_scan(
    x: jnp.ndarray,  # (Bt, S, Dn)
    dt: jnp.ndarray,  # (Bt, S, Dn) positive
    A: jnp.ndarray,  # (Dn, N) negative
    B: jnp.ndarray,  # (Bt, S, N)
    C: jnp.ndarray,  # (Bt, S, N)
    D: jnp.ndarray,  # (Dn,)
    h0: Optional[jnp.ndarray] = None,
    *,
    chunk: int = 128,
    tuned: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    bt, s, dn = x.shape
    n = A.shape[1]
    if tuned:
        from repro.kernels.flash_decode.ops import _tuned_value

        shape = {"bt": bt, "s": s, "dn": dn, "n": n}
        chunk = _tuned_value("ssm_scan", shape, x.dtype, "chunk", chunk)
    chunk = min(chunk, s)
    pad = (-s) % chunk
    if pad:
        padder = lambda z: jnp.pad(z, [(0, 0), (0, pad)] + [(0, 0)] * (z.ndim - 2))
        x_, dt_, B_, C_ = map(padder, (x, dt, B, C))
    else:
        x_, dt_, B_, C_ = x, dt, B, C
    nc = x_.shape[1] // chunk
    resh = lambda z: z.reshape(bt, nc, chunk, *z.shape[2:]).swapaxes(0, 1)
    xc, dtc, Bc, Cc = map(resh, (x_, dt_, B_, C_))  # (nc, Bt, chunk, ...)
    Af = A.astype(jnp.float32)
    Df = D.astype(jnp.float32)
    h_init = jnp.zeros((bt, dn, n), jnp.float32) if h0 is None else h0.astype(jnp.float32)

    @jax.checkpoint
    def chunk_body(h, inputs):
        xi, dti, Bi, Ci = inputs
        xi = xi.astype(jnp.float32)
        dti = dti.astype(jnp.float32)
        a = jnp.exp(dti[..., None] * Af[None, None])  # (Bt,c,Dn,N)
        bx = (dti * xi)[..., None] * Bi.astype(jnp.float32)[:, :, None, :]
        a_cum, s_cum = lax.associative_scan(_combine, (a, bx), axis=1)
        hc = a_cum * h[:, None] + s_cum  # (Bt,c,Dn,N)
        ci_f = Ci.astype(jnp.float32)
        y = jnp.einsum("bcdn,bcn->bcd", hc, ci_f, preferred_element_type=jnp.float32)
        y = y + Df[None, None] * xi
        return hc[:, -1], y.astype(x.dtype)

    h_last, ys = lax.scan(chunk_body, h_init, (xc, dtc, Bc, Cc))
    y = ys.swapaxes(0, 1).reshape(bt, nc * chunk, dn)
    return y[:, :s], h_last


def selective_scan_step(
    x_t: jnp.ndarray,  # (Bt, Dn)
    dt_t: jnp.ndarray,  # (Bt, Dn)
    A: jnp.ndarray,  # (Dn, N)
    B_t: jnp.ndarray,  # (Bt, N)
    C_t: jnp.ndarray,  # (Bt, N)
    D: jnp.ndarray,  # (Dn,)
    h: jnp.ndarray,  # (Bt, Dn, N) fp32 state
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Single decode step: O(Dn * N) per token."""
    xf = x_t.astype(jnp.float32)
    dtf = dt_t.astype(jnp.float32)
    a = jnp.exp(dtf[..., None] * A.astype(jnp.float32)[None])
    bx = (dtf * xf)[..., None] * B_t.astype(jnp.float32)[:, None, :]
    h_new = a * h + bx
    y = jnp.einsum("bdn,bn->bd", h_new, C_t.astype(jnp.float32))
    y = y + D.astype(jnp.float32)[None] * xf
    return y.astype(x_t.dtype), h_new
