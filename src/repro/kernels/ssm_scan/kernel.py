"""Pallas TPU selective-scan kernel (Mamba-1), chunked over time.

Grid = (B, n_d_blocks, n_chunks); the chunk axis is innermost/sequential and
the (d_block, N) fp32 recurrent state persists in VMEM scratch across chunk
iterations.  Within a chunk the recurrence is stepped with a fori_loop over
time — each step is a (d_block, N) elementwise FMA on the VPU, with the
chunk's x/dt/B/C tiles already resident in VMEM, so HBM traffic is
O(S * (2*Dn + 2*N)) per batch element (the streaming minimum) instead of the
O(S * Dn * N) a naive materialized scan would move.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import tpu_compiler_params


def _ssm_kernel(
    x_ref,
    dt_ref,
    a_ref,
    b_ref,
    c_ref,
    d_ref,
    y_ref,
    h_ref,
    *,
    chunk: int,
    n_chunks: int,
    seq_len: int,
):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    a = a_ref[...].astype(jnp.float32)  # (bd, N)
    dvec = d_ref[...].astype(jnp.float32)  # (bd,)
    x = x_ref[0].astype(jnp.float32)  # (chunk, bd)
    dt = dt_ref[0].astype(jnp.float32)  # (chunk, bd)
    bmat = b_ref[0].astype(jnp.float32)  # (chunk, N)
    cmat = c_ref[0].astype(jnp.float32)  # (chunk, N)

    def step(t, carry):
        h, y = carry
        decay = jnp.exp(dt[t][:, None] * a)  # (bd, N)
        h = decay * h + (dt[t] * x[t])[:, None] * bmat[t][None, :]
        yt = jnp.sum(h * cmat[t][None, :], axis=1) + dvec * x[t]
        y = jax.lax.dynamic_update_slice(y, yt[None, :], (t, 0))
        return h, y

    y0 = jnp.zeros((chunk, x.shape[1]), jnp.float32)
    h, y = jax.lax.fori_loop(0, chunk, step, (h_ref[...], y0))
    h_ref[...] = h
    y_ref[0] = y.astype(y_ref.dtype)


def selective_scan_pallas(
    x: jnp.ndarray,  # (Bt, S, Dn)
    dt: jnp.ndarray,  # (Bt, S, Dn)
    A: jnp.ndarray,  # (Dn, N)
    B: jnp.ndarray,  # (Bt, S, N)
    C: jnp.ndarray,  # (Bt, S, N)
    D: jnp.ndarray,  # (Dn,)
    *,
    chunk: int = 128,
    d_block: int = 256,
    interpret: bool = True,
) -> jnp.ndarray:
    bt, s, dn = x.shape
    n = A.shape[1]
    chunk = min(chunk, s)
    d_block = min(d_block, dn)
    pad_s = (-s) % chunk
    pad_d = (-dn) % d_block
    padder = lambda z, ps, pd: jnp.pad(z, ((0, 0), (0, ps), (0, pd)))
    x_ = padder(x, pad_s, pad_d)
    dt_ = padder(dt, pad_s, pad_d)  # padded dt=0 -> decay=1, bx=0 (state held)
    B_ = padder(B, pad_s, 0)
    C_ = padder(C, pad_s, 0)
    A_ = jnp.pad(A, ((0, pad_d), (0, 0)))
    D_ = jnp.pad(D, (0, pad_d))
    nc = x_.shape[1] // chunk
    nd = x_.shape[2] // d_block
    kernel = functools.partial(_ssm_kernel, chunk=chunk, n_chunks=nc, seq_len=s)
    y = pl.pallas_call(
        kernel,
        grid=(bt, nd, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, d_block), lambda b, di, ci: (b, ci, di)),
            pl.BlockSpec((1, chunk, d_block), lambda b, di, ci: (b, ci, di)),
            pl.BlockSpec((d_block, n), lambda b, di, ci: (di, 0)),
            pl.BlockSpec((1, chunk, n), lambda b, di, ci: (b, ci, 0)),
            pl.BlockSpec((1, chunk, n), lambda b, di, ci: (b, ci, 0)),
            pl.BlockSpec((d_block,), lambda b, di, ci: (di,)),
        ],
        out_specs=pl.BlockSpec((1, chunk, d_block), lambda b, di, ci: (b, ci, di)),
        out_shape=jax.ShapeDtypeStruct((bt, nc * chunk, nd * d_block), x.dtype),
        scratch_shapes=[pltpu.VMEM((d_block, n), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(x_, dt_, A_, B_, C_, D_)
    return y[:, :s, :dn]
