"""Naive sequential selective-scan oracle (Mamba-1 recurrence).

h_t = exp(dt_t * A) * h_{t-1} + dt_t * B_t * x_t
y_t = sum_n C_t[n] * h_t[:, n] + D * x_t
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp
from jax import lax


def selective_scan_ref(
    x: jnp.ndarray,  # (Bt, S, Dn)
    dt: jnp.ndarray,  # (Bt, S, Dn)  (already softplus'd, positive)
    A: jnp.ndarray,  # (Dn, N)      (negative)
    B: jnp.ndarray,  # (Bt, S, N)
    C: jnp.ndarray,  # (Bt, S, N)
    D: jnp.ndarray,  # (Dn,)
    h0: Optional[jnp.ndarray] = None,  # (Bt, Dn, N)
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    bt, s, dn = x.shape
    n = A.shape[1]
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Af = A.astype(jnp.float32)
    Bf = B.astype(jnp.float32)
    Cf = C.astype(jnp.float32)
    Df = D.astype(jnp.float32)
    h = jnp.zeros((bt, dn, n), jnp.float32) if h0 is None else h0.astype(jnp.float32)

    def step(h, t):
        a = jnp.exp(dtf[:, t, :, None] * Af[None])  # (Bt, Dn, N)
        bx = (dtf[:, t] * xf[:, t])[..., None] * Bf[:, t, None, :]
        h = a * h + bx
        y = jnp.einsum("bdn,bn->bd", h, Cf[:, t]) + Df[None] * xf[:, t]
        return h, y

    h, ys = lax.scan(step, h, jnp.arange(s))
    y = jnp.moveaxis(ys, 0, 1)  # (Bt, S, Dn)
    return y.astype(x.dtype), h
