"""Roofline models for autotune candidate pruning.

Per (family, shape, candidate config) this module estimates FLOPs, HBM
bytes, VMEM footprint, and grid-step count, and turns them into a modeled
time ``max(flops/peak, bytes/bw) + overhead * grid_steps``.  The sweep
harness measures only candidates whose modeled time is within a slack
factor of the best modeled time and whose tiles fit VMEM — the same
light-speed reasoning ``benchmarks/roofline.py`` applies to whole
compiled programs, applied per kernel tile here (that module reuses
``light_speed_s``/``roofline_fraction_us`` for its ``--tune-cache``
report).

Chip constants mirror the TPU v5e numbers in ``repro.launch.dryrun``
(which cannot be imported here: it must set ``XLA_FLAGS`` before jax
initializes, so importing it anywhere else would poison the device
count).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

# TPU v5e roofline constants — keep in sync with repro/launch/dryrun.py
PEAK_FLOPS = 197e12  # bf16 FLOP/s per chip
HBM_BW = 819e9  # bytes/s per chip
VMEM_BUDGET = 12 * 1024 * 1024  # usable VMEM bytes (matches sdca/ops.py)
GRID_STEP_OVERHEAD_S = 1e-6  # per-program dispatch floor
PRUNE_SLACK = 3.0


def light_speed_s(
    flops: float, bytes_moved: float, peak_flops: float = PEAK_FLOPS, hbm_bw: float = HBM_BW
) -> float:
    """Roofline lower bound for one kernel invocation."""
    return max(flops / peak_flops, bytes_moved / hbm_bw)


def roofline_fraction_us(measured_us: float, flops: float, bytes_moved: float) -> float:
    """measured / light-speed (>= 1; how far from the roofline we run)."""
    floor = light_speed_s(flops, bytes_moved) * 1e6
    return measured_us / floor if floor > 0 else 0.0


@dataclasses.dataclass
class CandidateEstimate:
    config: Dict[str, int]
    flops: float
    bytes_moved: float
    vmem_bytes: int
    grid_steps: int

    @property
    def t_model_s(self) -> float:
        return light_speed_s(self.flops, self.bytes_moved) + GRID_STEP_OVERHEAD_S * self.grid_steps


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def estimate(family: str, shape: Dict[str, int], config: Dict[str, int]) -> CandidateEstimate:
    """FLOPs/bytes/VMEM/grid model for one candidate (itemsize 4: tiles are
    staged in fp32)."""
    it = 4
    if family == "flash_attention":
        b, h, s, d = shape["b"], shape["h"], shape["s"], shape["d"]
        bq, bk = config["block_q"], config["block_k"]
        flops = 4.0 * b * h * s * s * d
        bytes_moved = 4.0 * b * h * s * d * it
        vmem = (bq * d + 2 * bk * d + 2 * bq * bk + bq * d) * it
        steps = b * h * _ceil_div(s, bq) * _ceil_div(s, bk)
    elif family == "flash_decode":
        b, h, s, d = shape["b"], shape["h"], shape["s"], shape["d"]
        bk = config["block_k"]
        flops = 4.0 * b * h * s * d
        bytes_moved = 2.0 * b * h * s * d * it
        vmem = (2 * bk * d + 2 * d + bk) * it
        steps = b * h * _ceil_div(s, bk)
    elif family == "flash_decode_paged":
        b, hk, g = shape["b"], shape["hk"], shape["g"]
        d, page, npp = shape["d"], shape["page"], shape["npp"]
        ppp = config["pages_per_program"]
        s = npp * page
        flops = 4.0 * b * hk * g * s * d
        bytes_moved = 2.0 * b * hk * s * d * it
        vmem = (2 * ppp * page * d + g * d + g * ppp * page) * it
        steps = b * hk * _ceil_div(npp, ppp)
    elif family == "prefill_chunk":
        p, hk, g = shape["p"], shape["hk"], shape["g"]
        d, page, npp = shape["d"], shape["page"], shape["npp"]
        c = config["chunk"]
        s = npp * page
        n_chunks = _ceil_div(p, c)
        # every chunk re-gathers the full page row (the chunked-prefill
        # bytes tax) and attends c queries against s keys
        flops = 4.0 * hk * g * p * s * d
        bytes_moved = (2.0 * n_chunks * hk * s * d + 2.0 * hk * g * p * d) * it
        vmem = (c * g * d + 2 * 16 * d + 2 * c * 16) * it
        steps = n_chunks * hk * _ceil_div(c, 16) * _ceil_div(s, 16)
    elif family == "ssm_scan":
        bt, s, dn, n = shape["bt"], shape["s"], shape["dn"], shape["n"]
        chunk = config["chunk"]
        flops = 8.0 * bt * s * dn * n
        bytes_moved = 3.0 * bt * s * (dn + 2 * n) * it
        vmem = chunk * dn * (n + 2) * it
        steps = _ceil_div(s, chunk)  # sequential depth
    elif family == "sdca":
        m, nl, d = shape["m"], shape["nl"], shape["d"]
        h = shape.get("h", nl)
        flops = 4.0 * m * h * d
        bytes_moved = m * (nl * d + 2 * nl + 2 * d) * it
        # the pallas variant keeps the whole shard tile resident
        vmem = (nl * d + 2 * nl + 2 * d) * it if config.get("use_pallas") else 0
        steps = m
    else:
        raise ValueError(f"unknown kernel family {family!r}")
    return CandidateEstimate(
        config=config,
        flops=flops,
        bytes_moved=bytes_moved,
        vmem_bytes=int(vmem),
        grid_steps=int(steps),
    )


def prune(
    family: str,
    shape: Dict[str, int],
    candidates: Sequence[Dict[str, int]],
    slack: float = PRUNE_SLACK,
    vmem_budget: int = VMEM_BUDGET,
) -> Tuple[List[CandidateEstimate], int]:
    """Drop candidates that cannot fit VMEM or whose modeled time exceeds
    ``slack`` x the best modeled time.  Returns (survivors, n_pruned);
    always keeps at least one candidate (the best-modeled one)."""
    ests = [estimate(family, shape, c) for c in candidates]
    fits = [e for e in ests if e.vmem_bytes <= vmem_budget]
    if not fits:
        fits = [min(ests, key=lambda e: e.vmem_bytes)]
    t_best = min(e.t_model_s for e in fits)
    kept = [e for e in fits if e.t_model_s <= slack * t_best]
    return kept, len(ests) - len(kept)
