"""repro.kernels.tune — shape-keyed Pallas/jnp kernel autotuner.

A sweep harness plus a persisted config cache covering every kernel
family (flash_attention, flash_decode + flash_decode_paged, prefill_chunk,
ssm_scan, sdca).  Keys are (family, shape, dtype, backend); values are the
measured fastest block configs.  See DESIGN.md §10.

Public surface:

* ``ensure(family, shape, dtype)`` — cached config, sweeping at most once
  per key (the memoization the acceptance test asserts).
* ``lookup(family, shape, dtype)`` — cheap read-only cache hit for the
  ``tuned=True`` paths in the ops wrappers; never sweeps, returns None on
  a miss (callers fall back to their defaults).  Safe under jit tracing.
* ``default_cache()`` — process-wide cache bound to
  ``$REPRO_TUNE_CACHE`` / ``results/tune_cache.json``.
* ``tune_events`` / ``bench_rows`` — telemetry export: typed bus events
  for ``CapacityPlanner.ingest``/dryrun system-model fitting, bench rows
  for the perf-gate trajectory (``decode_step_rows`` is the deprecated
  dict form).

CLI: ``python -m repro.kernels.tune --preset smoke``.
"""

from __future__ import annotations

from typing import Dict, Optional

from repro.kernels.tune.cache import (
    ConfigCache,
    cache_key,
    shape_sig,
)
from repro.kernels.tune.sweep import (
    FAMILIES,
    SWEEP_SHAPES,
    candidates_for,
    ensure,
    ragged_lengths,
    sweep,
    sweep_all,
    time_fn,
)
from repro.kernels.tune.telemetry import bench_rows, decode_step_rows, tune_events

__all__ = [
    "ConfigCache",
    "FAMILIES",
    "SWEEP_SHAPES",
    "bench_rows",
    "cache_key",
    "candidates_for",
    "decode_step_rows",
    "default_cache",
    "ensure",
    "lookup",
    "ragged_lengths",
    "reset_default_cache",
    "shape_sig",
    "sweep",
    "sweep_all",
    "time_fn",
    "tune_events",
]

_default_cache: Optional[ConfigCache] = None


def default_cache() -> ConfigCache:
    """Process-wide cache, loaded lazily from ``ConfigCache.default_path``."""
    global _default_cache
    if _default_cache is None:
        _default_cache = ConfigCache(ConfigCache.default_path())
    return _default_cache


def reset_default_cache() -> None:
    """Drop the singleton (tests repoint ``$REPRO_TUNE_CACHE``)."""
    global _default_cache
    _default_cache = None


def lookup(family: str, shape: Dict[str, int], dtype) -> Optional[Dict]:
    """Read-only config lookup against the default cache; None on miss."""
    return default_cache().config(cache_key(family, shape, dtype))
