"""Export tuned kernel timings as telemetry consumers understand.

Two consumers:

* the benchmark harness (``benchmarks/run.py``) ingests ``bench_rows`` —
  one ``tune/<family>/<sig>`` row per cache entry, so tuned timings ride
  the same BENCH_*.json trajectory the perf gate tracks;
* the capacity planner (``repro.serve.planner``) and the dry-run system
  model (``repro.launch.dryrun``) ingest ``decode_step_rows`` — measured
  paged-decode kernel timings the planner scales to whole decode steps
  (``n_layers * kernel + overhead``), so f(b) can be fitted from measured
  kernel costs before any engine traffic exists.
"""

from __future__ import annotations

from typing import Dict, List, Tuple

from repro.kernels.tune.cache import ConfigCache
from repro.kernels.tune.roofline import estimate, roofline_fraction_us

Row = Tuple[str, float, str]


def bench_rows(cache: ConfigCache) -> List[Row]:
    """(name, us_per_call, derived) rows, one per cache entry."""
    rows: List[Row] = []
    for key in sorted(cache.entries):
        e = cache.entries[key]
        est = estimate(e["family"], e["shape"], e["config"])
        frac = roofline_fraction_us(e["us_per_call"], est.flops, est.bytes_moved)
        cfg = ";".join(f"{k}={v}" for k, v in sorted(e["config"].items()))
        sig = key.split("|", 2)[1]
        derived = (
            f"{cfg};swept={e['candidates_swept']};"
            f"pruned={e['candidates_pruned']};backend={e['backend']};"
            f"x_lightspeed={frac:.1f}"
        )
        rows.append((f"tune/{e['family']}/{sig}", e["us_per_call"], derived))
    return rows


def decode_step_rows(cache: ConfigCache) -> List[Dict]:
    """Measured paged-decode timings as ``{batch, step_s}`` telemetry rows
    (the shape the serve planner ingests; per-kernel seconds — layer-count
    scaling happens in ``CapacityPlanner.observe_tuned_kernels``).  One row
    per ``flash_decode_paged`` entry; batch comes from the entry's stored
    shape dict, never from parsing the signature."""
    rows = []
    for e in cache.entries.values():
        if e["family"] != "flash_decode_paged":
            continue
        rows.append(
            {
                "batch": int(e["shape"]["b"]),
                "step_s": e["us_per_call"] * 1e-6,
                "source": "kernel_tuner",
            }
        )
    return rows
