"""Export tuned kernel timings as telemetry consumers understand.

The canonical export is ``tune_events``: one typed
``repro.telemetry.TuneEvent`` per cache entry, the same events the sweep
harness emits on its tracker as results land.  Consumers:

* the capacity planner (``repro.serve.planner.CapacityPlanner.ingest``)
  and the dry-run system model ingest the events directly — measured
  paged-decode kernel timings the planner scales to whole decode steps
  (``n_layers * kernel + overhead``), so f(b) can be fitted from measured
  kernel costs before any engine traffic exists;
* the benchmark harness (``benchmarks/run.py``) ingests ``bench_rows`` —
  one ``tune/<family>/<sig>`` row per cache entry, so tuned timings ride
  the same BENCH_*.json trajectory the perf gate tracks.

``decode_step_rows`` is the deprecated pre-bus dict export (one release
of shim left).
"""

from __future__ import annotations

from typing import Dict, List, Tuple

from repro.kernels.tune.cache import ConfigCache
from repro.kernels.tune.roofline import estimate, roofline_fraction_us
from repro.telemetry import TuneEvent, warn_deprecated

Row = Tuple[str, float, str]


def tune_events(cache: ConfigCache) -> List[TuneEvent]:
    """One typed ``TuneEvent`` per cache entry (sorted by key)."""
    return [
        TuneEvent.from_legacy_row(cache.entries[key]) for key in sorted(cache.entries)
    ]


def bench_rows(cache: ConfigCache) -> List[Row]:
    """(name, us_per_call, derived) rows, one per cache entry."""
    rows: List[Row] = []
    for key in sorted(cache.entries):
        e = cache.entries[key]
        est = estimate(e["family"], e["shape"], e["config"])
        frac = roofline_fraction_us(e["us_per_call"], est.flops, est.bytes_moved)
        cfg = ";".join(f"{k}={v}" for k, v in sorted(e["config"].items()))
        sig = key.split("|", 2)[1]
        derived = (
            f"{cfg};swept={e['candidates_swept']};"
            f"pruned={e['candidates_pruned']};backend={e['backend']};"
            f"x_lightspeed={frac:.1f}"
        )
        rows.append((f"tune/{e['family']}/{sig}", e["us_per_call"], derived))
    return rows


def decode_step_rows(cache: ConfigCache) -> List[Dict]:
    """Deprecated: measured paged-decode timings as ``{batch, step_s}``
    dicts.  Use ``tune_events`` + ``CapacityPlanner.ingest`` instead."""
    warn_deprecated(
        "repro.kernels.tune.decode_step_rows",
        "tune_events(cache) + CapacityPlanner.ingest(events)",
    )
    rows = []
    for ev in tune_events(cache):
        if ev.family != "flash_decode_paged":
            continue
        rows.append(
            {
                "batch": int(ev.shape["b"]),
                "step_s": ev.us_per_call * 1e-6,
                "source": "kernel_tuner",
            }
        )
    return rows
