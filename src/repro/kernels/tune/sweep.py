"""Sweep harness: measure surviving candidates, persist the winner.

``sweep`` builds a real invocation of the kernel family at the requested
shape, times every candidate config that survives roofline pruning
(``tune.roofline``), and records the fastest in the config cache.
``ensure`` is the memoized entry point: a cache hit returns immediately
without re-sweeping (asserted by tests via ``ConfigCache.sweeps``).

On CPU the harness times the jnp implementations (and interpret-mode
Pallas where that is the only implementation) — a proxy with honest
relative ordering for blocking/looping overheads; on a TPU backend the
same harness times the real kernels, and entries are keyed by backend so
the two never mix.
"""

from __future__ import annotations

import functools
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.tune import roofline
from repro.kernels.tune.cache import ConfigCache, cache_key
from repro.telemetry import TuneEvent, default_tracker

FAMILIES = (
    "flash_attention",
    "flash_decode",
    "flash_decode_paged",
    "prefill_chunk",
    "ssm_scan",
    "sdca",
)

# default sweep shapes: "full" targets serving-scale caches, "smoke" keeps
# the CI sweep to tens of milliseconds
SWEEP_SHAPES: Dict[str, Dict[str, Dict[str, int]]] = {
    "full": {
        "flash_attention": {"b": 1, "h": 8, "s": 1024, "d": 64},
        "flash_decode": {"b": 4, "h": 8, "s": 512, "d": 64},
        "flash_decode_paged": {"b": 4, "hk": 4, "g": 2, "d": 64, "page": 16, "npp": 128},
        "prefill_chunk": {"p": 512, "hk": 4, "g": 2, "d": 64, "page": 16, "npp": 64},
        "ssm_scan": {"bt": 2, "s": 512, "dn": 64, "n": 16},
        "sdca": {"m": 4, "nl": 256, "d": 64, "h": 256},
    },
    "smoke": {
        "flash_attention": {"b": 1, "h": 2, "s": 64, "d": 16},
        "flash_decode": {"b": 2, "h": 2, "s": 64, "d": 16},
        "flash_decode_paged": {"b": 2, "hk": 2, "g": 2, "d": 16, "page": 8, "npp": 8},
        "prefill_chunk": {"p": 32, "hk": 2, "g": 2, "d": 16, "page": 8, "npp": 8},
        "ssm_scan": {"bt": 1, "s": 64, "dn": 8, "n": 4},
        "sdca": {"m": 2, "nl": 32, "d": 16, "h": 32},
    },
}


def time_fn(fn: Callable, *args, iters: int = 5) -> float:
    """Wall-clock microseconds per call (one warmup invocation, then the
    mean of ``iters`` timed calls)."""
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6


def _pow2_range(lo: int, hi: int) -> List[int]:
    out, v = [], lo
    while v <= hi:
        out.append(v)
        v *= 2
    return out


def ragged_lengths(b: int, capacity: int) -> np.ndarray:
    """Deterministic serving-like fill: longest sequence at half capacity,
    the rest tapering off — the operating point the engine actually runs
    at mid-trace."""
    return np.asarray([max(1, (capacity * (b - i)) // (2 * b)) for i in range(b)], np.int32)


def candidates_for(family: str, shape: Dict[str, int]) -> List[Dict[str, int]]:
    if family == "flash_attention":
        s = shape["s"]
        blocks = [v for v in _pow2_range(16, 512) if v <= max(s, 16)]
        return [{"block_q": bq, "block_k": bk} for bq in blocks for bk in blocks]
    if family == "flash_decode":
        s = shape["s"]
        return [{"block_k": bk} for bk in _pow2_range(16, 1024) if bk <= max(s, 16)]
    if family == "flash_decode_paged":
        npp = shape["npp"]
        return [{"pages_per_program": p} for p in _pow2_range(1, 128) if p <= npp]
    if family == "prefill_chunk":
        p = shape["p"]
        return [{"chunk": c} for c in _pow2_range(16, 512) if c <= max(p, 16)]
    if family == "ssm_scan":
        s = shape["s"]
        return [{"chunk": c} for c in _pow2_range(16, 256) if c <= max(s, 16)]
    if family == "sdca":
        return [{"use_pallas": 0}, {"use_pallas": 1}]
    raise ValueError(f"unknown kernel family {family!r}")


# ---------------------------------------------------------------------------
# Per-family measurable cases
# ---------------------------------------------------------------------------
def _case_flash_attention(shape, dtype):
    from repro.kernels.flash_attention.ops import flash_attention

    b, h, s, d = shape["b"], shape["h"], shape["s"], shape["d"]
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, h, s, d), dtype)
    k = jax.random.normal(ks[1], (b, h, s, d), dtype)
    v = jax.random.normal(ks[2], (b, h, s, d), dtype)

    def build(config):
        return jax.jit(functools.partial(flash_attention, causal=True, **config)), (q, k, v)

    return build


def _case_flash_decode(shape, dtype):
    from repro.kernels.flash_decode.kernel import flash_decode_pallas

    b, h, s, d = shape["b"], shape["h"], shape["s"], shape["d"]
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (b, h, d), dtype)
    kc = jax.random.normal(ks[1], (b, h, s, d), dtype)
    vc = jax.random.normal(ks[2], (b, h, s, d), dtype)
    lens = jnp.asarray(ragged_lengths(b, s))
    interpret = jax.default_backend() != "tpu"

    def build(config):
        fn = jax.jit(functools.partial(flash_decode_pallas, interpret=interpret, **config))
        return fn, (q, kc, vc, lens)

    return build


def _case_flash_decode_paged(shape, dtype):
    from repro.kernels.flash_decode.ops import paged_decode_attention

    b, hk, g, d = shape["b"], shape["hk"], shape["g"], shape["d"]
    page, npp = shape["page"], shape["npp"]
    n_pages = b * npp + 1
    rng = np.random.RandomState(2)
    q = jnp.asarray(rng.randn(b, hk * g, d), dtype)
    kp = jnp.asarray(rng.randn(n_pages, hk, page, d), dtype)
    vp = jnp.asarray(rng.randn(n_pages, hk, page, d), dtype)
    rows = [rng.choice(n_pages - 1, npp, replace=False) + 1 for _ in range(b)]
    pt = jnp.asarray(np.stack(rows), jnp.int32)
    lens = jnp.asarray(ragged_lengths(b, npp * page))
    impl = "pallas" if jax.default_backend() == "tpu" else "stream"

    def build(config):
        part = functools.partial(
            paged_decode_attention, impl=impl, pages_per_program=config["pages_per_program"]
        )
        return jax.jit(part), (q, kp, vp, lens, pt)

    return build


def _case_prefill_chunk(shape, dtype):
    """Whole-prompt chunked prefill at chunk width C: ceil(p/C) calls of the
    paged-prefill flash path (scatter chunk K/V, gather the page row, attend
    with static q_offset).  Small chunks pay repeated page-row gathers and
    dispatch; large chunks pay step latency — the tunable is that knee.  The
    timed fn drives every chunk so candidates are compared on full-prompt
    cost, not per-call cost."""
    from repro.kernels.flash_decode.ops import paged_prefill_attention

    p, hk, g, d = shape["p"], shape["hk"], shape["g"], shape["d"]
    page, npp = shape["page"], shape["npp"]
    n_pages = npp + 1
    rng = np.random.RandomState(5)
    kp = jnp.asarray(rng.randn(n_pages, hk, page, d), dtype)
    vp = jnp.asarray(rng.randn(n_pages, hk, page, d), dtype)
    pt = jnp.asarray(rng.permutation(npp)[None] + 1, jnp.int32)

    def build(config):
        c = config["chunk"]
        calls = []
        for i in range(-(-p // c)):
            s0 = i * c
            q = jnp.asarray(rng.randn(1, hk * g, c, d), dtype)
            lens = jnp.asarray([min(s0 + c, p)], jnp.int32)
            fn = jax.jit(functools.partial(paged_prefill_attention, q_offset=s0))
            calls.append((fn, q, lens))

        def run(kp_, vp_, pt_):
            out = None
            for fn, q, lens in calls:
                out = fn(q, kp_, vp_, lens, pt_)
            return out

        return run, (kp, vp, pt)

    return build


def _case_ssm_scan(shape, dtype):
    from repro.kernels.ssm_scan.ops import selective_scan

    bt, s, dn, n = shape["bt"], shape["s"], shape["dn"], shape["n"]
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    x = jax.random.normal(ks[0], (bt, s, dn), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (bt, s, dn), dtype))
    A = -jnp.abs(jax.random.normal(ks[2], (dn, n))) - 0.1
    B = jax.random.normal(ks[3], (bt, s, n), dtype)
    C = jax.random.normal(ks[4], (bt, s, n), dtype)
    D = jnp.full((dn,), 0.4)

    def build(config):
        return jax.jit(lambda *a: selective_scan(*a, chunk=config["chunk"])[0]), (x, dt, A, B, C, D)

    return build


def _case_sdca(shape, dtype):
    from repro.kernels.sdca.ops import local_sdca

    m, nl, d, h = shape["m"], shape["nl"], shape["d"], shape["h"]
    ks = jax.random.split(jax.random.PRNGKey(4), 4)
    X = jax.random.normal(ks[0], (m, nl, d), dtype)
    y = jnp.sign(jax.random.normal(ks[1], (m, nl), dtype))
    a = jnp.zeros((m, nl), dtype)
    w = jnp.zeros((d,), dtype)
    idx = jnp.stack([jax.random.permutation(k, nl)[:h] for k in jax.random.split(ks[2], m)])

    def build(config):
        use_pallas = bool(config["use_pallas"])

        def run(*args):
            return local_sdca(*args, 1.0, 1e-3, float(m * nl), use_pallas=use_pallas)

        return jax.jit(run), (X, y, a, w, idx)

    return build


_CASES = {
    "flash_attention": _case_flash_attention,
    "flash_decode": _case_flash_decode,
    "flash_decode_paged": _case_flash_decode_paged,
    "prefill_chunk": _case_prefill_chunk,
    "ssm_scan": _case_ssm_scan,
    "sdca": _case_sdca,
}


# ---------------------------------------------------------------------------
# Sweep + memoized entry point
# ---------------------------------------------------------------------------
def sweep(
    family: str,
    shape: Dict[str, int],
    dtype=jnp.float32,
    *,
    cache: Optional[ConfigCache] = None,
    iters: int = 5,
    slack: float = roofline.PRUNE_SLACK,
) -> Tuple[Dict[str, int], Dict]:
    """Measure the pruned candidate set; store and return the winner."""
    if cache is None:
        from repro.kernels.tune import default_cache

        cache = default_cache()
    cache.sweeps += 1
    build = _CASES[family](shape, dtype)
    kept, n_pruned = roofline.prune(family, shape, candidates_for(family, shape), slack=slack)
    results = []
    for est in kept:
        fn, args = build(est.config)
        results.append((time_fn(fn, *args, iters=iters), est.config))
    best_us, best_config = min(results, key=lambda r: r[0])
    key = cache_key(family, shape, dtype)
    entry = cache.put(
        key,
        family=family,
        shape=shape,
        dtype=dtype,
        config=best_config,
        us_per_call=best_us,
        swept=len(kept),
        pruned=n_pruned,
    )
    cache.save()
    # every sweep result rides the bus: a cache with its own tracker keeps
    # the events alongside the entries, otherwise the process-wide default
    tracker = getattr(cache, "tracker", None) or default_tracker()
    tracker.emit(TuneEvent.from_legacy_row(entry))
    return best_config, entry


def ensure(
    family: str,
    shape: Dict[str, int],
    dtype=jnp.float32,
    *,
    cache: Optional[ConfigCache] = None,
    sweep_on_miss: bool = True,
    **sweep_kwargs,
) -> Optional[Dict]:
    """Cached config for the key, sweeping at most once per (shape, dtype,
    backend).  Returns None on a miss when ``sweep_on_miss=False``."""
    if cache is None:
        from repro.kernels.tune import default_cache

        cache = default_cache()
    config = cache.config(cache_key(family, shape, dtype))
    if config is not None:
        return config
    if not sweep_on_miss:
        return None
    config, _ = sweep(family, shape, dtype, cache=cache, **sweep_kwargs)
    return config


def sweep_all(
    preset: str = "smoke",
    *,
    families: Sequence[str] = FAMILIES,
    dtype=jnp.float32,
    cache: Optional[ConfigCache] = None,
    iters: int = 5,
) -> List[Dict]:
    """Sweep every family at its preset shape; returns the cache entries."""
    entries = []
    for family in families:
        shape = SWEEP_SHAPES[preset][family]
        _, entry = sweep(family, shape, dtype, cache=cache, iters=iters)
        entries.append(entry)
    return entries
