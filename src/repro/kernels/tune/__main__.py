"""Autotune CLI: sweep kernel families, persist the config cache.

  PYTHONPATH=src python -m repro.kernels.tune --preset smoke
  PYTHONPATH=src python -m repro.kernels.tune --preset full \
      --families flash_decode_paged --cache results/tune_cache.json

Prints one line per swept family (winner config, measured us, pruning
stats) and, with ``--telemetry``, the exported benchmark rows.
"""

from __future__ import annotations

import argparse

import jax.numpy as jnp

from repro.kernels.tune import (
    FAMILIES,
    ConfigCache,
    bench_rows,
    sweep_all,
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=["smoke", "full"], default="smoke")
    ap.add_argument("--families", nargs="+", default=list(FAMILIES), choices=list(FAMILIES))
    ap.add_argument(
        "--cache",
        default=ConfigCache.default_path(),
        help="config-cache JSON path (default: $REPRO_TUNE_CACHE or results/tune_cache.json)",
    )
    ap.add_argument("--dtype", default="float32", choices=["float32", "bfloat16"])
    ap.add_argument("--iters", type=int, default=5)
    ap.add_argument(
        "--telemetry", action="store_true", help="also print the exported benchmark rows"
    )
    args = ap.parse_args()

    cache = ConfigCache(args.cache)
    dtype = jnp.dtype(args.dtype)
    entries = sweep_all(
        args.preset, families=args.families, dtype=dtype, cache=cache, iters=args.iters
    )
    for e in entries:
        cfg = ";".join(f"{k}={v}" for k, v in sorted(e["config"].items()))
        print(
            f"[tuned] {e['family']:20s} {cfg:24s} "
            f"{e['us_per_call']:10.1f} us  "
            f"(swept {e['candidates_swept']}, "
            f"pruned {e['candidates_pruned']}, {e['backend']})"
        )
    print(f"# cache: {args.cache} ({len(cache.entries)} entries)")
    if args.telemetry:
        for name, us, derived in bench_rows(cache):
            print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
