"""Shape-keyed persisted config cache for the kernel autotuner.

A cache entry maps one ``(family, shape, dtype, backend)`` key to the
block config the sweep harness measured fastest, plus the measurement
itself.  Keys are flat strings::

    flash_decode_paged|b4_d64_g2_hk4_npp128_page16|float32|cpu

— family, underscore-joined ``<name><value>`` shape items in sorted key
order, jnp dtype name, and ``jax.default_backend()``.  The value side
keeps the original shape dict so consumers (telemetry export, capacity
planning) never parse the signature back.

Persistence is a single JSON file (default ``results/tune_cache.json``,
overridable via ``$REPRO_TUNE_CACHE`` or the ``path`` argument), written
atomically (tmp + rename).  ``path=None`` keeps the cache in memory only.
"""

from __future__ import annotations

import json
import os
from pathlib import Path
from typing import Dict, Optional

import jax

from repro.telemetry.io import atomic_write_json, file_lock

DEFAULT_CACHE_PATH = "results/tune_cache.json"
_SCHEMA_VERSION = 1


def dtype_name(dtype) -> str:
    import jax.numpy as jnp

    return jnp.dtype(dtype).name


def backend_name() -> str:
    return jax.default_backend()


def shape_sig(shape: Dict[str, int]) -> str:
    return "_".join(f"{k}{int(v)}" for k, v in sorted(shape.items()))


def cache_key(family: str, shape: Dict[str, int], dtype, backend: Optional[str] = None) -> str:
    return "|".join([family, shape_sig(shape), dtype_name(dtype), backend or backend_name()])


class ConfigCache:
    def __init__(self, path: Optional[str] = None, tracker=None):
        self.path = path
        self.entries: Dict[str, Dict] = {}
        self.sweeps = 0  # incremented by the sweep harness, not persisted
        # optional repro.telemetry.Tracker; the sweep harness emits a
        # TuneEvent here (falls back to the process default tracker)
        self.tracker = tracker
        if path is not None and Path(path).exists():
            self.load()

    @classmethod
    def default_path(cls) -> str:
        return os.environ.get("REPRO_TUNE_CACHE", DEFAULT_CACHE_PATH)

    # ------------------------------------------------------------------
    def get(self, key: str) -> Optional[Dict]:
        return self.entries.get(key)

    def config(self, key: str) -> Optional[Dict]:
        entry = self.entries.get(key)
        return None if entry is None else entry["config"]

    def put(
        self,
        key: str,
        *,
        family: str,
        shape: Dict[str, int],
        dtype,
        config: Dict,
        us_per_call: float,
        swept: int,
        pruned: int,
        backend: Optional[str] = None,
    ) -> Dict:
        entry = {
            "family": family,
            "shape": {k: int(v) for k, v in shape.items()},
            "dtype": dtype_name(dtype),
            "backend": backend or backend_name(),
            "config": {k: int(v) for k, v in config.items()},
            "us_per_call": float(us_per_call),
            "candidates_swept": int(swept),
            "candidates_pruned": int(pruned),
        }
        self.entries[key] = entry
        return entry

    # ------------------------------------------------------------------
    def load(self) -> "ConfigCache":
        with open(self.path) as f:
            payload = json.load(f)
        if payload.get("version") != _SCHEMA_VERSION:
            # stale schema: start fresh rather than misread configs
            self.entries = {}
            return self
        self.entries = payload["entries"]
        return self

    def save(self) -> None:
        """Merge-then-write through the shared atomic helper.

        Two processes sweeping different keys against the same file (the
        CI slow job overlapping tier-1) used to race: last writer wins,
        silently dropping the other's entries.  Now each save takes an
        exclusive lock, re-reads the on-disk entries, and overlays its
        own before the atomic replace, so concurrent sweeps union
        instead of clobbering."""
        if self.path is None:
            return
        with file_lock(str(self.path) + ".lock"):
            if Path(self.path).exists():
                try:
                    with open(self.path) as f:
                        payload = json.load(f)
                    if payload.get("version") == _SCHEMA_VERSION:
                        self.entries = {**payload["entries"], **self.entries}
                except (OSError, json.JSONDecodeError):
                    pass  # torn/unreadable: our atomic write supersedes it
            atomic_write_json(
                self.path, {"version": _SCHEMA_VERSION, "entries": self.entries}
            )
