"""jit'd wrappers for decode attention: contiguous (kernel + jnp fallback)
and paged (three interchangeable implementations).

Paged decode reads the serve engine's physical page pool
((n_pages, Hk, page, d), see ``repro.serve.cache``) through a per-sequence
page table.  Three implementations share one blocking scheme
(``pages_per_program`` pages = one score block) and therefore one float
associativity.  ``stream`` and ``gather`` are **bit-identical** under any
page table / fill / blocking (tests assert it — this is what lets the
engine switch between them without perturbing prefix-cache guarantees);
the Pallas kernel computes the same blocked math and matches them to
float exactness (interpret mode may lower the per-program 2D dots through
a different gemm microkernel than the batched einsum, so the last ulp is
not contractual there):

* ``stream`` — paged-native jnp: a bounded loop gathers only the current
  group's pages ((B, ppp, Hk, page, d)) and runs an online softmax; the
  loop stops at ``max(lengths)``, so a step costs O(longest live sequence),
  not O(cache capacity).  No (B, Hk, P*page, d) dense KV intermediate ever
  exists in the jaxpr.  This is the engine's CPU path.
* ``pallas`` — ``paged_flash_decode_pallas``: same algorithm with the page
  table as a scalar-prefetch operand and pages streamed through VMEM
  (TPU path; interpret mode is the correctness proxy).
* ``gather`` — the legacy fallback and correctness oracle: materializes
  the full (B, Hk, P*page, d) gather, then runs the same blocked online
  softmax over it.  Pays the copy plus O(capacity) compute every step.

``pages_per_program`` defaults to the ``repro.kernels.tune`` config cache
entry for the call's (shape, dtype, backend) key when one exists.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp
from jax import lax

from repro.kernels.flash_attention.ops import decode_attention, flash_attention
from repro.kernels.flash_decode.kernel import (
    flash_decode_pallas,
    paged_flash_decode_pallas,
)

NEG_INF = -1e30
PAGED_IMPLS = ("stream", "pallas", "gather")
DEFAULT_PAGES_PER_PROGRAM = 4
DEFAULT_PREFILL_CHUNK = 32


def decode_attention_auto(
    q: jnp.ndarray,  # (B, Hq, D)
    k_cache: jnp.ndarray,  # (B, Hk, S, D)
    v_cache: jnp.ndarray,
    lengths: jnp.ndarray,
    *,
    use_pallas: bool = False,
    interpret: bool = True,
    block_k: int = 512,
    sm_scale: Optional[float] = None,
    tuned: bool = False,
) -> jnp.ndarray:
    """Dispatch decode attention to the Pallas kernel (TPU) or the jnp path
    (CPU / GSPMD-sharded caches).  ``tuned=True`` takes ``block_k`` from the
    autotuner's config cache when an entry exists."""
    if tuned:
        shape = {"b": q.shape[0], "h": q.shape[1], "s": k_cache.shape[2], "d": q.shape[2]}
        block_k = _tuned_value("flash_decode", shape, q.dtype, "block_k", block_k)
    if not use_pallas:
        return decode_attention(q, k_cache, v_cache, lengths, sm_scale=sm_scale)
    b, hq, d = q.shape
    hk = k_cache.shape[1]
    g = hq // hk
    if g > 1:
        k_cache = jnp.repeat(k_cache, g, axis=1)
        v_cache = jnp.repeat(v_cache, g, axis=1)
    return flash_decode_pallas(
        q, k_cache, v_cache, lengths, sm_scale=sm_scale, block_k=block_k, interpret=interpret
    )


# ---------------------------------------------------------------------------
# Paged decode: shared blocked core (stream / gather) + kernel dispatch
# ---------------------------------------------------------------------------
def _tuned_value(family: str, shape: dict, dtype, name: str, default):
    """Config-cache lookup (lazy import — tune imports this module's
    functions for sweeping)."""
    from repro.kernels.tune import lookup

    cfg = lookup(family, shape, dtype)
    if cfg and name in cfg:
        return int(cfg[name])
    return default


def _block_update(q, qpe, k_blk, kpe_blk, v_blk, start, length, scale, acc, m, l):
    """One online-softmax block update, shared op-for-op by ``stream`` and
    ``gather`` (and mirrored inside the Pallas kernel): q (..., G, dk),
    blocks (..., blk, d*), running stats acc (..., G, dv) / m, l (..., G)."""
    blk = k_blk.shape[-2]
    s = jnp.einsum("...gd,...pd->...gp", q, k_blk, preferred_element_type=jnp.float32)
    if qpe is not None:
        s = s + jnp.einsum("...gd,...pd->...gp", qpe, kpe_blk, preferred_element_type=jnp.float32)
    s = s * scale
    pos = start + lax.broadcasted_iota(jnp.int32, (blk,), 0)
    valid = pos[None, :] < length[:, None]  # (B, blk)
    valid = valid[:, None, None, :]  # (B, 1, 1, blk) -> bcast Hk, G
    s = jnp.where(valid, s, NEG_INF)
    m_new = jnp.maximum(m, s.max(axis=-1))
    alpha = jnp.exp(m - m_new)
    p = jnp.where(valid, jnp.exp(s - m_new[..., None]), 0.0)
    l_new = l * alpha + p.sum(axis=-1)
    pv = jnp.einsum("...gp,...pd->...gd", p, v_blk, preferred_element_type=jnp.float32)
    acc_new = acc * alpha[..., None] + pv
    return acc_new, m_new, l_new


def _paged_prep(q, page_tables, pages_per_program, n_pp):
    ppp = max(1, min(int(pages_per_program), n_pp))
    padc = (-n_pp) % ppp
    if padc:  # pad with the scratch page; padded positions are masked out
        page_tables = jnp.pad(page_tables, ((0, 0), (0, padc)))
    return page_tables.astype(jnp.int32), ppp, page_tables.shape[1] // ppp


def _stream_core(q, qpe, k_pages, kpe_pages, v_pages, lengths, page_tables, scale, ppp, n_groups):
    """Paged-native jnp: per group, gather only that group's pages and run
    the shared block update; trip count is bounded by the longest live
    sequence, so no dense KV view is ever built."""
    b, hk, g, dk = q.shape
    page = k_pages.shape[2]
    dv = v_pages.shape[3]
    blk = ppp * page
    qf = q.astype(jnp.float32)
    qpef = None if qpe is None else qpe.astype(jnp.float32)
    lens = lengths.astype(jnp.int32)
    hi = jnp.minimum(lax.div(jnp.max(lens) + blk - 1, blk), n_groups)

    def group_step(j, carry):
        acc, m, l = carry
        pids = lax.dynamic_slice(page_tables, (0, j * ppp), (b, ppp))

        def blocked(pool):
            # (B, ppp, Hk, page, d) -> (B, Hk, ppp*page, d)
            tile = pool[pids]
            return jnp.moveaxis(tile, 2, 1).reshape(b, hk, blk, pool.shape[-1]).astype(jnp.float32)

        kpe_blk = None if kpe_pages is None else blocked(kpe_pages)
        k_blk, v_blk = blocked(k_pages), blocked(v_pages)
        return _block_update(qf, qpef, k_blk, kpe_blk, v_blk, j * blk, lens, scale, acc, m, l)

    init = (
        jnp.zeros((b, hk, g, dv), jnp.float32),
        jnp.full((b, hk, g), NEG_INF, jnp.float32),
        jnp.zeros((b, hk, g), jnp.float32),
    )
    acc, _, l = lax.fori_loop(0, hi, group_step, init)
    return (acc / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)


def _gather_core(q, qpe, k_pages, kpe_pages, v_pages, lengths, page_tables, scale, ppp, n_groups):
    """Gather oracle: materialize the dense (B, Hk, P*page, d) views — the
    O(B*Hk*S*d) per-step copy — then run the same blocked online softmax
    over every group regardless of fill."""
    b, hk, g, dk = q.shape
    page = k_pages.shape[2]
    dv = v_pages.shape[3]
    blk = ppp * page
    s_cap = n_groups * blk

    def full(pool):
        return jnp.moveaxis(pool[page_tables], 2, 1).reshape(b, hk, s_cap, pool.shape[-1])

    k_full, v_full = full(k_pages), full(v_pages)
    kpe_full = None if kpe_pages is None else full(kpe_pages)
    qf = q.astype(jnp.float32)
    qpef = None if qpe is None else qpe.astype(jnp.float32)
    lens = lengths.astype(jnp.int32)

    def group_step(carry, j):
        acc, m, l = carry

        def blocked(dense):
            sizes = (b, hk, blk, dense.shape[-1])
            return lax.dynamic_slice(dense, (0, 0, j * blk, 0), sizes).astype(jnp.float32)

        kpe_blk = None if kpe_full is None else blocked(kpe_full)
        k_blk, v_blk = blocked(k_full), blocked(v_full)
        carry = _block_update(qf, qpef, k_blk, kpe_blk, v_blk, j * blk, lens, scale, acc, m, l)
        return carry, None

    init = (
        jnp.zeros((b, hk, g, dv), jnp.float32),
        jnp.full((b, hk, g), NEG_INF, jnp.float32),
        jnp.zeros((b, hk, g), jnp.float32),
    )
    (acc, _, l), _ = lax.scan(group_step, init, jnp.arange(n_groups))
    return (acc / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)


def _paged_dispatch(
    q, qpe, k_pages, kpe_pages, v_pages, lengths, page_tables, scale, impl, ppp, interpret
):
    n_pp = page_tables.shape[1]
    page_tables, ppp, n_groups = _paged_prep(q, page_tables, ppp, n_pp)
    args = (q, qpe, k_pages, kpe_pages, v_pages, lengths, page_tables, scale, ppp, n_groups)
    if impl == "stream":
        return _stream_core(*args)
    if impl == "gather":
        return _gather_core(*args)
    if impl == "pallas":
        return paged_flash_decode_pallas(
            q,
            k_pages,
            v_pages,
            lengths,
            page_tables,
            q_pe=qpe,
            kpe_pages=kpe_pages,
            sm_scale=scale,
            pages_per_program=ppp,
            interpret=interpret,
        )
    raise ValueError(f"impl={impl!r} not in {PAGED_IMPLS}")


def paged_decode_attention(
    q: jnp.ndarray,  # (B, Hq, d) one new query token per sequence
    k_pages: jnp.ndarray,  # (n_pages, Hk, page, d) physical page pool
    v_pages: jnp.ndarray,  # (n_pages, Hk, page, d)
    lengths: jnp.ndarray,  # (B,) valid positions incl. the new token
    page_tables: jnp.ndarray,  # (B, pages_per_seq) int32
    *,
    sm_scale: Optional[float] = None,
    impl: str = "stream",
    pages_per_program: Optional[int] = None,
    interpret: bool = True,
) -> jnp.ndarray:
    """GQA decode attention over the paged KV pool; returns (B, Hq, d).

    ``pages_per_program=None`` consults the autotuner's config cache for
    this (shape, dtype, backend) key, falling back to
    ``DEFAULT_PAGES_PER_PROGRAM``."""
    b, hq, d = q.shape
    hk, page = k_pages.shape[1], k_pages.shape[2]
    g = hq // hk
    if hq % hk:
        raise ValueError(f"Hq={hq} not a multiple of Hk={hk}")
    scale = sm_scale if sm_scale is not None else 1.0 / (d**0.5)
    if pages_per_program is None:
        shape = {"b": b, "hk": hk, "g": g, "d": d, "page": page, "npp": page_tables.shape[1]}
        pages_per_program = _tuned_value(
            "flash_decode_paged", shape, q.dtype, "pages_per_program", DEFAULT_PAGES_PER_PROGRAM
        )
    q4 = q.reshape(b, hk, g, d)
    out = _paged_dispatch(
        q4,
        None,
        k_pages,
        None,
        v_pages,
        lengths,
        page_tables,
        scale,
        impl,
        pages_per_program,
        interpret,
    )
    return out.reshape(b, hq, d)


def gather_pages(pool: jnp.ndarray, page_tables: jnp.ndarray) -> jnp.ndarray:
    """Dense per-sequence view of a page pool.

    ``pool`` is page-major with the page-position axis at index 2 of the
    gathered tile ((n_pages, ..., page, ...) with one leading page axis);
    ``page_tables`` is (B, pages_per_seq).  Returns
    (B, ..., pages_per_seq * page, ...): the contiguous cache view a
    chunked-prefill flash call attends over.  Positions past a sequence's
    fill hold stale/zero pages (including the scratch page) and must be
    masked by the caller via ``kv_lens``."""
    b, npp = page_tables.shape
    tile = pool[page_tables]  # (B, npp, ..., page, ...)
    if pool.ndim == 4:  # (n_pages, Hk, page, d) K/V pools
        return jnp.moveaxis(tile, 2, 1).reshape(
            b, pool.shape[1], npp * pool.shape[2], pool.shape[3])
    if pool.ndim == 3:  # (n_pages, page, r) MLA latent pools
        return tile.reshape(b, npp * pool.shape[1], pool.shape[2])
    raise ValueError(f"unsupported pool rank {pool.ndim}")


def paged_prefill_attention(
    q: jnp.ndarray,  # (B, Hq, C, d) one prompt chunk of queries
    k_pages: jnp.ndarray,  # (n_pages, Hk, page, d) pool incl. this chunk's K
    v_pages: jnp.ndarray,  # (n_pages, Hk, page, d)
    kv_lens: jnp.ndarray,  # (B,) valid positions incl. this chunk
    page_tables: jnp.ndarray,  # (B, pages_per_seq) int32
    *,
    q_offset: int,  # absolute position of the chunk's first query (static)
    sm_scale: Optional[float] = None,
    block_q: int = 16,
    block_k: int = 16,
) -> jnp.ndarray:
    """Causal chunked-prefill attention over the paged KV pool.

    The chunk's K/V must already be scattered into the pages (scatter then
    attend, exactly like the decode path); this gathers the whole page-table
    row to a contiguous view and runs the blocked flash forward with the
    chunk's absolute query offset.  Bit-identity with a monolithic prefill
    at the same ``block_k`` holds because (a) key blocks tile absolute
    positions from 0 regardless of the chunk boundary, (b) each query row's
    online-softmax accumulation is independent of how queries are blocked,
    and (c) positions at or past ``kv_lens`` are exact no-ops in the block
    update.  See DESIGN.md §11."""
    k_full = gather_pages(k_pages, page_tables)
    v_full = gather_pages(v_pages, page_tables)
    return flash_attention(
        q, k_full, v_full, causal=True, sm_scale=sm_scale,
        kv_lens=kv_lens.astype(jnp.float32), q_offset=q_offset,
        block_q=block_q, block_k=block_k)


def fold_verify_batch(
    tokens: jnp.ndarray,  # (B, T) row 0 = pending token, rows 1.. = drafts
    lengths: jnp.ndarray,  # (B,) committed fill per sequence
    page_tables: jnp.ndarray,  # (B, pages_per_seq)
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Fold a (B, T) speculative verify window into a (B*T,) decode batch.

    Row ``s*T + t`` carries draft position ``t`` of sequence ``s``: token
    ``tokens[s, t]`` at cache position ``lengths[s] + t``, reading sequence
    ``s``'s page-table row.  Because every decode layer scatters all folded
    rows' K/V before attending, row ``t`` sees rows ``< t`` of its own
    sequence through its length mask — one batched target step verifies the
    whole window, and each row's output is bit-identical to the sequential
    one-token step that would have produced it (same math per row; extra
    rows only add exact masked no-ops).  Returns
    (tokens (B*T,), lengths (B*T,), page_tables (B*T, pages_per_seq))."""
    b, t = tokens.shape
    toks = tokens.reshape(b * t)
    lens = (lengths[:, None] + jnp.arange(t, dtype=lengths.dtype)[None, :]
            ).reshape(b * t)
    pts = jnp.repeat(page_tables, t, axis=0)
    return toks, lens, pts


def paged_verify_attention(
    q: jnp.ndarray,  # (B, T, Hq, d) draft-window queries
    k_pages: jnp.ndarray,
    v_pages: jnp.ndarray,
    lengths: jnp.ndarray,  # (B,) fill BEFORE the window (row t attends l+t+1)
    page_tables: jnp.ndarray,  # (B, pages_per_seq)
    *,
    sm_scale: Optional[float] = None,
    impl: str = "stream",
    pages_per_program: Optional[int] = None,
    interpret: bool = True,
) -> jnp.ndarray:
    """Multi-query verify over pages: decode attention for T draft positions
    per sequence in one call, by folding the window into the batch axis with
    ragged lengths (row t of sequence s attends ``lengths[s] + t + 1``
    positions).  The fold is exactly ``fold_verify_batch`` minus the token
    column, so outputs are bit-identical to T sequential decode calls.
    Returns (B, T, Hq, d)."""
    b, t, hq, d = q.shape
    lens = (lengths[:, None] + 1 + jnp.arange(t, dtype=lengths.dtype)[None, :]
            ).reshape(b * t)
    pts = jnp.repeat(page_tables, t, axis=0)
    out = paged_decode_attention(
        q.reshape(b * t, hq, d), k_pages, v_pages, lens, pts,
        sm_scale=sm_scale, impl=impl, pages_per_program=pages_per_program,
        interpret=interpret)
    return out.reshape(b, t, hq, d)


def paged_latent_decode_attention(
    q_lat: jnp.ndarray,  # (B, H, r) absorbed queries (latent space)
    q_pe: jnp.ndarray,  # (B, H, rope)
    ckv_pages: jnp.ndarray,  # (n_pages, page, r) latent page pool
    kpe_pages: jnp.ndarray,  # (n_pages, page, rope)
    lengths: jnp.ndarray,  # (B,) valid positions incl. the new token
    page_tables: jnp.ndarray,  # (B, pages_per_seq) int32
    *,
    sm_scale: float,
    impl: str = "stream",
    pages_per_program: Optional[int] = None,
    interpret: bool = True,
) -> jnp.ndarray:
    """MLA latent decode over paged (c_kv, k_pe) pools; returns latent
    context (B, H, r).  scores = q_lat*ckv + q_pe*kpe; context accumulates
    against ckv directly (absorbed form), so the pools are both the keys
    and the values — zero re-expansion, zero gather in the non-oracle
    impls.  The size-1 head axis inserted below is a reshape (no copy)."""
    b, h, r = q_lat.shape
    page, npp = ckv_pages.shape[1], page_tables.shape[1]
    if pages_per_program is None:
        shape = {"b": b, "hk": 1, "g": h, "d": r, "page": page, "npp": npp}
        default = DEFAULT_PAGES_PER_PROGRAM
        pages_per_program = _tuned_value(
            "flash_decode_paged", shape, q_lat.dtype, "pages_per_program", default
        )
    out = _paged_dispatch(
        q_lat[:, None],
        q_pe[:, None],
        ckv_pages[:, None],
        kpe_pages[:, None],
        ckv_pages[:, None],
        lengths,
        page_tables,
        sm_scale,
        impl,
        pages_per_program,
        interpret,
    )
    return out[:, 0]
