"""jit'd wrappers for decode attention (kernel + jnp fallback + sharded)."""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.kernels.flash_attention.ops import decode_attention
from repro.kernels.flash_decode.kernel import flash_decode_pallas


def decode_attention_auto(
    q: jnp.ndarray,        # (B, Hq, D)
    k_cache: jnp.ndarray,  # (B, Hk, S, D)
    v_cache: jnp.ndarray,
    lengths: jnp.ndarray,
    *,
    use_pallas: bool = False,
    interpret: bool = True,
    block_k: int = 512,
    sm_scale: Optional[float] = None,
) -> jnp.ndarray:
    """Dispatch decode attention to the Pallas kernel (TPU) or the jnp path
    (CPU / GSPMD-sharded caches)."""
    if not use_pallas:
        return decode_attention(q, k_cache, v_cache, lengths,
                                sm_scale=sm_scale)
    b, hq, d = q.shape
    hk = k_cache.shape[1]
    g = hq // hk
    if g > 1:
        k_cache = jnp.repeat(k_cache, g, axis=1)
        v_cache = jnp.repeat(v_cache, g, axis=1)
    return flash_decode_pallas(q, k_cache, v_cache, lengths,
                               sm_scale=sm_scale, block_k=block_k,
                               interpret=interpret)
