"""Pallas TPU flash-decode kernels: one query token vs a long KV cache.

Two kernels:

* ``flash_decode_pallas`` — contiguous cache.  Grid = (B*H, n_kv_blocks);
  KV blocks stream through VMEM while the (head_dim,) fp32 accumulator +
  scalar running max/sum persist in scratch.  Per-sequence valid lengths
  mask the tail block.
* ``paged_flash_decode_pallas`` — paged cache.  The KV pool stays put in
  HBM ((n_pages, Hk, page, d)); the per-sequence page table and valid
  lengths ride in as scalar-prefetch operands, and the grid iterates
  (B, Hk, page groups).  Each program resolves its logical pages to
  physical pages through the prefetched table and streams them through
  VMEM — the (B, Hk, P*page, d) gather the jnp fallback materializes
  never exists.  Groups entirely past a sequence's valid length are
  predicated off with ``pl.when`` (skipped by the scalar unit on TPU).
  An optional rotary/PE operand pair (q_pe, kpe pool) serves the MLA
  latent path: scores = q_lat*ckv + q_pe*kpe, context in latent space.

Both compose with cross-chip KV sharding via psum of (acc, m, l) partials
(see ops.sharded_decode_attention and the GSPMD path in
kernels/flash_attention/ops.decode_attention).
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import tpu_compiler_params

NEG_INF = -1e30

# dot_general dimension_numbers: contract the last axis of both operands
# (scores: q @ k^T) / contract q's last with v's first (context: p @ v)
_DOT_QK = (((1,), (1,)), ((), ()))
_DOT_PV = (((1,), (0,)), ((), ()))


def _decode_kernel(
    len_ref,
    q_ref,
    k_ref,
    v_ref,
    o_ref,
    acc_ref,
    m_ref,
    l_ref,
    *,
    sm_scale: float,
    block_k: int,
    n_kv: int,
):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32)  # (1, d)
    k = k_ref[0].astype(jnp.float32)  # (bk, d)
    v = v_ref[0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, _DOT_QK, preferred_element_type=jnp.float32)[0] * sm_scale
    pos = j * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_k,), 0)
    valid = pos < len_ref[0]
    s = jnp.where(valid, s, NEG_INF)
    m_prev = m_ref[0]
    m_new = jnp.maximum(m_prev, s.max())
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.where(valid, jnp.exp(s - m_new), 0.0)
    l_ref[0] = l_ref[0] * alpha + p.sum()
    pv = jax.lax.dot_general(p[None], v, _DOT_PV, preferred_element_type=jnp.float32)
    acc_ref[...] = acc_ref[...] * alpha + pv
    m_ref[0] = m_new

    @pl.when(j == n_kv - 1)
    def _finalize():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[0], 1e-30))[0].astype(o_ref.dtype)


def flash_decode_pallas(
    q: jnp.ndarray,  # (B, H, D)
    k_cache: jnp.ndarray,  # (B, H, S, D) (GQA: broadcast KV heads first)
    v_cache: jnp.ndarray,  # (B, H, S, D)
    lengths: jnp.ndarray,  # (B,) int32
    *,
    sm_scale: Optional[float] = None,
    block_k: int = 512,
    interpret: bool = True,
) -> jnp.ndarray:
    b, h, s, d = k_cache.shape
    scale = sm_scale if sm_scale is not None else 1.0 / math.sqrt(d)
    block_k = min(block_k, s)
    pad = (-s) % block_k
    if pad:
        k_cache = jnp.pad(k_cache, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v_cache = jnp.pad(v_cache, ((0, 0), (0, 0), (0, pad), (0, 0)))
    nk = k_cache.shape[2] // block_k
    qf = q.reshape(b * h, 1, d)
    kf = k_cache.reshape(b * h, -1, d)
    vf = v_cache.reshape(b * h, -1, d)
    lens = jnp.repeat(lengths.astype(jnp.int32), h)  # (B*H,)
    kernel = functools.partial(_decode_kernel, sm_scale=scale, block_k=block_k, n_kv=nk)
    out = pl.pallas_call(
        kernel,
        grid=(b * h, nk),
        in_specs=[
            pl.BlockSpec((1,), lambda i, j: (i,), memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, d), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, block_k, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda i, j: (i, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, d), lambda i, j: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, 1, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1, d), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(lens, qf, kf, vf)
    return out.reshape(b, h, d)


def _paged_decode_kernel(
    *refs,
    sm_scale: float,
    page_size: int,
    pages_per_program: int,
    n_groups: int,
    has_pe: bool,
):
    """One (batch row, kv head, page group) program of paged flash decode.

    ``refs`` layout (scalar-prefetch first, then operands, then scratch):
      pt_ref   (B, n_pp_padded) int32 SMEM — logical -> physical page ids
      len_ref  (B,) int32 SMEM          — valid positions incl. new token
      q_ref    (1, 1, G, dk) VMEM block
      [qpe_ref (1, 1, G, dr) VMEM block]           (has_pe)
      k_ref    (n_pages, Hk, page, dk) ANY — whole pool, loaded per page
      [kpe_ref (n_pages, Hk, page, dr) ANY]        (has_pe)
      v_ref    (n_pages, Hk, page, dv) ANY
      o_ref    (1, 1, G, dv) VMEM block
      acc_ref (G, dv) f32, m_ref (G,) f32, l_ref (G,) f32 scratch.
    """
    if has_pe:
        (pt_ref, len_ref, q_ref, qpe_ref, k_ref, kpe_ref, v_ref, o_ref) = refs[:8]
        acc_ref, m_ref, l_ref = refs[8:]
    else:
        (pt_ref, len_ref, q_ref, k_ref, v_ref, o_ref) = refs[:6]
        acc_ref, m_ref, l_ref = refs[6:]
        qpe_ref = kpe_ref = None
    b = pl.program_id(0)
    h = pl.program_id(1)
    grp = pl.program_id(2)

    @pl.when(grp == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    length = len_ref[b]
    blk = pages_per_program * page_size
    start = grp * blk

    @pl.when(start < length)
    def _compute():
        def load_pages(ref):
            # resolve + stream this group's pages; python loop is static
            # (pages_per_program), each load is one page's (page, d) tile
            tiles = []
            for i in range(pages_per_program):
                pid = pt_ref[b, grp * pages_per_program + i]
                idx = (pl.dslice(pid, 1), pl.dslice(h, 1), slice(None), slice(None))
                tiles.append(pl.load(ref, idx)[0, 0])
            return jnp.concatenate(tiles, axis=0).astype(jnp.float32)

        q = q_ref[0, 0].astype(jnp.float32)  # (G, dk)
        k = load_pages(k_ref)  # (blk, dk)
        v = load_pages(v_ref)  # (blk, dv)
        s = jax.lax.dot_general(q, k, _DOT_QK, preferred_element_type=jnp.float32)
        if has_pe:
            qpe = qpe_ref[0, 0].astype(jnp.float32)  # (G, dr)
            kpe = load_pages(kpe_ref)  # (blk, dr)
            s = s + jax.lax.dot_general(qpe, kpe, _DOT_QK, preferred_element_type=jnp.float32)
        s = s * sm_scale  # (G, blk)
        pos = start + jax.lax.broadcasted_iota(jnp.int32, (blk,), 0)
        valid = (pos < length)[None, :]
        s = jnp.where(valid, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.where(valid, jnp.exp(s - m_new[:, None]), 0.0)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1)
        pv = jax.lax.dot_general(p, v, _DOT_PV, preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + pv
        m_ref[...] = m_new

    @pl.when(grp == n_groups - 1)
    def _finalize():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[:, None]).astype(o_ref.dtype)


def paged_flash_decode_pallas(
    q: jnp.ndarray,  # (B, Hk, G, dk)
    k_pages: jnp.ndarray,  # (n_pages, Hk, page, dk) physical pool
    v_pages: jnp.ndarray,  # (n_pages, Hk, page, dv)
    lengths: jnp.ndarray,  # (B,) int32 valid positions incl. new token
    page_tables: jnp.ndarray,  # (B, pages_per_seq) int32 physical page ids
    *,
    q_pe: Optional[jnp.ndarray] = None,  # (B, Hk, G, dr)
    kpe_pages: Optional[jnp.ndarray] = None,  # (n_pages, Hk, page, dr)
    sm_scale: Optional[float] = None,
    pages_per_program: int = 4,
    interpret: bool = True,
) -> jnp.ndarray:
    """Paged-native flash decode: the pool is read in place (zero copy).

    Returns (B, Hk, G, dv).  Shares its blocking (``pages_per_program``
    pages = one score block) and float associativity with the jnp
    ``stream``/``gather`` implementations in ops.py; interpret mode matches
    them to float exactness (the last ulp can differ — XLA may pick a
    different gemm microkernel for the per-program 2D dots than for the
    batched einsums).
    """
    b, hk, g, dk = q.shape
    n_pages, _, page_size, dv = v_pages.shape
    n_pp = page_tables.shape[1]
    has_pe = q_pe is not None
    scale = sm_scale if sm_scale is not None else 1.0 / math.sqrt(dk)
    pages_per_program = max(1, min(pages_per_program, n_pp))
    padc = (-n_pp) % pages_per_program
    if padc:  # pad with the scratch page; padded positions are masked out
        page_tables = jnp.pad(page_tables, ((0, 0), (0, padc)))
    n_groups = page_tables.shape[1] // pages_per_program
    kernel = functools.partial(
        _paged_decode_kernel,
        sm_scale=scale,
        page_size=page_size,
        pages_per_program=pages_per_program,
        n_groups=n_groups,
        has_pe=has_pe,
    )
    dr = 0 if q_pe is None else q_pe.shape[3]
    q_specs = [pl.BlockSpec((1, 1, g, dk), lambda b_, h_, g_, pt, ln: (b_, h_, 0, 0))]
    pool_specs = [pl.BlockSpec(memory_space=pltpu.ANY)]
    if has_pe:
        q_specs.append(pl.BlockSpec((1, 1, g, dr), lambda b_, h_, g_, pt, ln: (b_, h_, 0, 0)))
        pool_specs.append(pl.BlockSpec(memory_space=pltpu.ANY))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, hk, n_groups),
        in_specs=q_specs + pool_specs + [pl.BlockSpec(memory_space=pltpu.ANY)],
        out_specs=pl.BlockSpec((1, 1, g, dv), lambda b_, h_, g_, pt, ln: (b_, h_, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, dv), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
        ],
    )
    operands = [page_tables.astype(jnp.int32), lengths.astype(jnp.int32), q]
    if has_pe:
        operands += [q_pe, k_pages, kpe_pages, v_pages]
    else:
        operands += [k_pages, v_pages]
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hk, g, dv), q.dtype),
        interpret=interpret,
    )(*operands)
