"""Pallas TPU flash-decode kernel: one query token vs a long KV cache.

Grid = (B*H, n_kv_blocks); KV blocks stream through VMEM while the
(head_dim,) fp32 accumulator + scalar running max/sum persist in scratch.
Per-sequence valid lengths mask the tail block.  This is the single-chip
building block; cross-chip KV-sequence sharding composes the per-shard
(acc, m, l) partials with a psum (see ops.sharded_decode_attention and the
GSPMD path in kernels/flash_attention/ops.decode_attention).
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import tpu_compiler_params

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref,
                   acc_ref, m_ref, l_ref,
                   *, sm_scale: float, block_k: int, n_kv: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32)          # (1, d)
    k = k_ref[0].astype(jnp.float32)          # (bk, d)
    v = v_ref[0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)[0] * sm_scale
    pos = j * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_k,), 0)
    valid = pos < len_ref[0]
    s = jnp.where(valid, s, NEG_INF)
    m_prev = m_ref[0]
    m_new = jnp.maximum(m_prev, s.max())
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.where(valid, jnp.exp(s - m_new), 0.0)
    l_ref[0] = l_ref[0] * alpha + p.sum()
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p[None], v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[0] = m_new

    @pl.when(j == n_kv - 1)
    def _finalize():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[0], 1e-30)
                       )[0].astype(o_ref.dtype)


def flash_decode_pallas(
    q: jnp.ndarray,        # (B, H, D)
    k_cache: jnp.ndarray,  # (B, H, S, D) (GQA: broadcast KV heads first)
    v_cache: jnp.ndarray,  # (B, H, S, D)
    lengths: jnp.ndarray,  # (B,) int32
    *,
    sm_scale: Optional[float] = None,
    block_k: int = 512,
    interpret: bool = True,
) -> jnp.ndarray:
    b, h, s, d = k_cache.shape
    scale = sm_scale if sm_scale is not None else 1.0 / math.sqrt(d)
    block_k = min(block_k, s)
    pad = (-s) % block_k
    if pad:
        k_cache = jnp.pad(k_cache, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v_cache = jnp.pad(v_cache, ((0, 0), (0, 0), (0, pad), (0, 0)))
    nk = k_cache.shape[2] // block_k
    qf = q.reshape(b * h, 1, d)
    kf = k_cache.reshape(b * h, -1, d)
    vf = v_cache.reshape(b * h, -1, d)
    lens = jnp.repeat(lengths.astype(jnp.int32), h)  # (B*H,)
    kernel = functools.partial(_decode_kernel, sm_scale=scale,
                               block_k=block_k, n_kv=nk)
    out = pl.pallas_call(
        kernel,
        grid=(b * h, nk),
        in_specs=[
            pl.BlockSpec((1,), lambda i, j: (i,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, d), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, block_k, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda i, j: (i, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, d), lambda i, j: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, 1, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1, d), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(lens, qf, kf, vf)
    return out.reshape(b, h, d)
