"""Blocked flash attention in pure jnp with a flash-style custom VJP.

This is the implementation the *models* use everywhere (train / prefill).
It never materializes the (Sq, Skv) score matrix: the forward pass scans over
query blocks with an inner loop over only the causally-visible KV blocks, and
the backward pass recomputes scores blockwise (flash backward), so activation
memory is O(S * D) instead of O(S^2).  It lowers cleanly on CPU and TPU and
is exactly the algorithm the Pallas TPU kernel (kernel.py) implements with
VMEM BlockSpecs; tests assert both against ref.py.

GQA layout: q (B, Hq, Sq, D), kv (B, Hk, Skv, D) with Hq % Hk == 0; scores are
computed grouped as (B, Hk, G, ...) so KV is never repeated in memory.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

NEG_INF = -1e30


def _pad_to(x: jnp.ndarray, axis: int, mult: int) -> Tuple[jnp.ndarray, int]:
    size = x.shape[axis]
    rem = (-size) % mult
    if rem == 0:
        return x, size
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, rem)
    return jnp.pad(x, pad), size


def _blk(x: jnp.ndarray, axis: int, i, size: int) -> jnp.ndarray:
    """dynamic_slice one block along `axis`."""
    starts = [0] * x.ndim
    starts[axis] = i * size
    sizes = list(x.shape)
    sizes[axis] = size
    return lax.dynamic_slice(x, starts, sizes)


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7, 8))
def _flash(
    q, k, v, kv_lens, causal: bool, sm_scale: float, q_offset: int, block_q: int, block_k: int
):
    out, _ = _flash_fwd_impl(q, k, v, kv_lens, causal, sm_scale, q_offset, block_q, block_k)
    return out


def _flash_fwd_impl(q, k, v, kv_lens, causal, sm_scale, q_offset, block_q, block_k):
    b, hk, g, sq, d = q.shape
    skv = k.shape[2]
    dv = v.shape[3]
    qp, _ = _pad_to(q, 3, block_q)
    kp, _ = _pad_to(k, 2, block_k)
    vp, _ = _pad_to(v, 2, block_k)
    nq = qp.shape[3] // block_q
    nk = kp.shape[2] // block_k
    kv_pos = jnp.arange(block_k, dtype=jnp.int32)
    q_pos = jnp.arange(block_q, dtype=jnp.int32)
    lens = jnp.minimum(kv_lens.astype(jnp.int32), skv)  # (B,)

    def q_step(_, i):
        qi = _blk(qp, 3, i, block_q).astype(jnp.float32)  # (B,K,G,bq,D)
        acc0 = jnp.zeros((b, hk, g, block_q, dv), jnp.float32)
        m0 = jnp.full((b, hk, g, block_q), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, hk, g, block_q), jnp.float32)
        # NOTE: static trip count (all nk blocks, masked) — causally-skippable
        # blocks are computed and zeroed.  This keeps every loop bound
        # constant so the HLO cost parser (dist/hlo_costs) attributes exact
        # flops; the Pallas kernel skips masked tiles on real hardware, and
        # the triangular-pair variant is a §Perf hillclimb item.
        hi = nk

        def kv_step(j, carry):
            acc, m, l = carry
            kj = _blk(kp, 2, j, block_k).astype(jnp.float32)  # (B,K,bk,D)
            vj = _blk(vp, 2, j, block_k).astype(jnp.float32)
            s = jnp.einsum("bkgqd,bksd->bkgqs", qi, kj, preferred_element_type=jnp.float32)
            s = s * sm_scale
            kpos = j * block_k + kv_pos  # (bk,)
            valid = kpos[None, :] < lens[:, None]  # (B, bk)
            mask = valid[:, None, None, None, :]
            if causal:
                qpos = q_offset + i * block_q + q_pos  # (bq,)
                mask = mask & (qpos[:, None] >= kpos[None, :])[None, None, None]
            s = jnp.where(mask, s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None])
            p = jnp.where(mask, p, 0.0)
            l_new = l * alpha + p.sum(axis=-1)
            pv = jnp.einsum("bkgqs,bksd->bkgqd", p, vj, preferred_element_type=jnp.float32)
            acc_new = acc * alpha[..., None] + pv
            return acc_new, m_new, l_new

        acc, m, l = lax.fori_loop(0, hi, kv_step, (acc0, m0, l0))
        out_i = acc / jnp.maximum(l, 1e-30)[..., None]
        lse_i = m + jnp.log(jnp.maximum(l, 1e-30))
        return None, (out_i.astype(q.dtype), lse_i)

    _, (out_blocks, lse_blocks) = lax.scan(q_step, None, jnp.arange(nq, dtype=jnp.int32))
    # (nq, B, K, G, bq, Dv) -> (B, K, G, Sq, Dv)
    out = jnp.moveaxis(out_blocks, 0, 3).reshape(b, hk, g, nq * block_q, dv)
    lse = jnp.moveaxis(lse_blocks, 0, 3).reshape(b, hk, g, nq * block_q)
    return out[:, :, :, :sq], lse[:, :, :, :sq]


def _flash_fwd(q, k, v, kv_lens, causal, sm_scale, q_offset, block_q, block_k):
    out, lse = _flash_fwd_impl(q, k, v, kv_lens, causal, sm_scale, q_offset, block_q, block_k)
    return out, (q, k, v, kv_lens, out, lse)


def _flash_bwd(causal, sm_scale, q_offset, block_q, block_k, res, dout):
    q, k, v, kv_lens, out, lse = res
    b, hk, g, sq, d = q.shape
    skv = k.shape[2]
    dv_dim = v.shape[3]
    qp, _ = _pad_to(q, 3, block_q)
    kp, _ = _pad_to(k, 2, block_k)
    vp, _ = _pad_to(v, 2, block_k)
    dop, _ = _pad_to(dout, 3, block_q)
    lsep, _ = _pad_to(lse, 3, block_q)
    # delta = rowsum(dout * out)
    delta = jnp.sum(dout.astype(jnp.float32) * out.astype(jnp.float32), axis=-1)
    dlp, _ = _pad_to(delta, 3, block_q)
    nq = qp.shape[3] // block_q
    nk = kp.shape[2] // block_k
    kv_pos = jnp.arange(block_k, dtype=jnp.int32)
    q_pos = jnp.arange(block_q, dtype=jnp.int32)
    lens = jnp.minimum(kv_lens.astype(jnp.int32), skv)

    def s_block(qi, kj, i, j):
        s = jnp.einsum("bkgqd,bksd->bkgqs", qi, kj, preferred_element_type=jnp.float32)
        s = s * sm_scale
        kpos = j * block_k + kv_pos
        valid = kpos[None, :] < lens[:, None]
        mask = valid[:, None, None, None, :]
        if causal:
            qpos = q_offset + i * block_q + q_pos
            mask = mask & (qpos[:, None] >= kpos[None, :])[None, None, None]
        return jnp.where(mask, s, NEG_INF), mask

    # ---- dq: scan over q blocks, inner loop over visible kv blocks --------
    def dq_step(_, i):
        qi = _blk(qp, 3, i, block_q).astype(jnp.float32)
        doi = _blk(dop, 3, i, block_q).astype(jnp.float32)
        lsei = _blk(lsep, 3, i, block_q)
        dli = _blk(dlp, 3, i, block_q)
        hi = nk  # static trip count; masked blocks contribute zero

        def kv_step(j, dqi):
            kj = _blk(kp, 2, j, block_k).astype(jnp.float32)
            vj = _blk(vp, 2, j, block_k).astype(jnp.float32)
            s, mask = s_block(qi, kj, i, j)
            p = jnp.exp(s - lsei[..., None])
            p = jnp.where(mask, p, 0.0)
            dp = jnp.einsum("bkgqd,bksd->bkgqs", doi, vj, preferred_element_type=jnp.float32)
            ds = p * (dp - dli[..., None])
            dsk = jnp.einsum("bkgqs,bksd->bkgqd", ds, kj, preferred_element_type=jnp.float32)
            return dqi + dsk * sm_scale

        dqi = lax.fori_loop(0, hi, kv_step, jnp.zeros_like(qi))
        return None, dqi

    _, dq_blocks = lax.scan(dq_step, None, jnp.arange(nq, dtype=jnp.int32))
    dq = jnp.moveaxis(dq_blocks, 0, 3).reshape(b, hk, g, nq * block_q, d)
    dq = dq[:, :, :, :sq].astype(q.dtype)

    # ---- dk, dv: scan over kv blocks, inner loop over visible q blocks ----
    def dkv_step(_, j):
        kj = _blk(kp, 2, j, block_k).astype(jnp.float32)
        vj = _blk(vp, 2, j, block_k).astype(jnp.float32)
        lo = 0  # static trip count; masked blocks contribute zero

        def q_step(i, carry):
            dkj, dvj = carry
            qi = _blk(qp, 3, i, block_q).astype(jnp.float32)
            doi = _blk(dop, 3, i, block_q).astype(jnp.float32)
            lsei = _blk(lsep, 3, i, block_q)
            dli = _blk(dlp, 3, i, block_q)
            s, mask = s_block(qi, kj, i, j)
            p = jnp.exp(s - lsei[..., None])
            p = jnp.where(mask, p, 0.0)
            pdo = jnp.einsum("bkgqs,bkgqd->bksd", p, doi, preferred_element_type=jnp.float32)
            dvj = dvj + pdo
            dp = jnp.einsum("bkgqd,bksd->bkgqs", doi, vj, preferred_element_type=jnp.float32)
            ds = p * (dp - dli[..., None])
            dsq = jnp.einsum("bkgqs,bkgqd->bksd", ds, qi, preferred_element_type=jnp.float32)
            dkj = dkj + dsq * sm_scale
            return dkj, dvj

        init = (
            jnp.zeros((b, hk, block_k, d), jnp.float32),
            jnp.zeros((b, hk, block_k, dv_dim), jnp.float32),
        )
        dkj, dvj = lax.fori_loop(lo, nq, q_step, init)
        return None, (dkj, dvj)

    _, (dk_blocks, dv_blocks) = lax.scan(dkv_step, None, jnp.arange(nk, dtype=jnp.int32))
    dk = jnp.moveaxis(dk_blocks, 0, 2).reshape(b, hk, nk * block_k, d)
    dv = jnp.moveaxis(dv_blocks, 0, 2).reshape(b, hk, nk * block_k, dv_dim)
    dk = dk[:, :, :skv].astype(k.dtype)
    dv = dv[:, :, :skv].astype(v.dtype)
    dkv_lens = jnp.zeros_like(kv_lens)
    return dq, dk, dv, dkv_lens


_flash.defvjp(_flash_fwd, _flash_bwd)


def flash_attention(
    q: jnp.ndarray,  # (B, Hq, Sq, D)
    k: jnp.ndarray,  # (B, Hk, Skv, D)
    v: jnp.ndarray,  # (B, Hk, Skv, D)
    *,
    causal: bool = True,
    sm_scale: Optional[float] = None,
    kv_lens: Optional[jnp.ndarray] = None,  # (B,) float32
    q_offset: int = 0,
    block_q: int = 512,
    block_k: int = 512,
) -> jnp.ndarray:
    """Memory-efficient attention; see module docstring."""
    b, hq, sq, d = q.shape
    _, hk, skv, _ = k.shape
    if hq % hk:
        raise ValueError(f"Hq={hq} not a multiple of Hk={hk}")
    g = hq // hk
    scale = float(sm_scale) if sm_scale is not None else 1.0 / (d**0.5)
    block_q = min(block_q, max(sq, 16))
    block_k = min(block_k, max(skv, 16))
    if kv_lens is None:
        kv_lens = jnp.full((b,), float(skv), jnp.float32)
    q5 = q.reshape(b, hk, g, sq, d)
    lens32 = kv_lens.astype(jnp.float32)
    out = _flash(q5, k, v, lens32, causal, scale, int(q_offset), int(block_q), int(block_k))
    return out.reshape(b, hq, sq, v.shape[3])


def decode_attention(
    q: jnp.ndarray,  # (B, Hq, D) single new token per sequence
    k_cache: jnp.ndarray,  # (B, Hk, S, D)
    v_cache: jnp.ndarray,  # (B, Hk, S, D)
    lengths: jnp.ndarray,  # (B,) int32 — number of valid cache positions
    *,
    sm_scale: Optional[float] = None,
) -> jnp.ndarray:
    """One-token decode attention over a (possibly sequence-sharded) KV cache.

    Pure jnp: when the cache's S axis is sharded (long-context decode), the
    GSPMD partitioner lowers the max/sum reductions to the flash-decode
    combine (partial softmax stats + all-reduce) automatically.
    """
    b, hq, d = q.shape
    _, hk, s, _ = k_cache.shape
    g = hq // hk
    scale = sm_scale if sm_scale is not None else 1.0 / (d**0.5)
    # keep caches in their storage dtype (bf16): fp32-casting a 500k-token
    # cache would double its HBM traffic; the MXU accumulates in fp32 via
    # preferred_element_type
    qf = q.reshape(b, hk, g, d)
    scores = jnp.einsum("bkgd,bksd->bkgs", qf, k_cache, preferred_element_type=jnp.float32)
    scores = scores * scale
    pos = jnp.arange(s, dtype=jnp.int32)
    mask = pos[None, :] < lengths[:, None]  # (B, S)
    scores = jnp.where(mask[:, None, None, :], scores, NEG_INF)
    m = scores.max(axis=-1, keepdims=True)
    p = jnp.exp(scores - m)
    l = p.sum(axis=-1, keepdims=True)
    pv = p.astype(v_cache.dtype)
    out = jnp.einsum("bkgs,bksd->bkgd", pv, v_cache, preferred_element_type=jnp.float32)
    out = out / jnp.maximum(l, 1e-30)
    return out.reshape(b, hq, v_cache.shape[-1]).astype(q.dtype)
