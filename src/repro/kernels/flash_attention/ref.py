"""Pure-jnp oracle for flash attention (naive, materializes S x S).

Only used by tests/benchmarks on small shapes.
"""

from __future__ import annotations

from typing import Optional

import jax.numpy as jnp


def attention_ref(
    q: jnp.ndarray,  # (B, Hq, Sq, D)
    k: jnp.ndarray,  # (B, Hk, Skv, D)
    v: jnp.ndarray,  # (B, Hk, Skv, D)
    *,
    causal: bool = True,
    sm_scale: Optional[float] = None,
    kv_lens: Optional[jnp.ndarray] = None,  # (B,) float or int
    q_offset: int = 0,
) -> jnp.ndarray:
    b, hq, sq, d = q.shape
    _, hk, skv, _ = k.shape
    assert hq % hk == 0
    g = hq // hk
    scale = sm_scale if sm_scale is not None else 1.0 / (d**0.5)
    qf = q.astype(jnp.float32).reshape(b, hk, g, sq, d)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bkgqd,bksd->bkgqs", qf, kf) * scale
    kv_pos = jnp.arange(skv)
    mask = jnp.ones((b, 1, 1, sq, skv), dtype=bool)
    if causal:
        q_pos = q_offset + jnp.arange(sq)
        mask &= (q_pos[:, None] >= kv_pos[None, :])[None, None, None]
    if kv_lens is not None:
        valid = kv_pos[None, :] < kv_lens[:, None].astype(jnp.int32)  # (B, Skv)
        mask &= valid[:, None, None, None, :]
    s = jnp.where(mask, s, -jnp.inf)
    m = jnp.max(s, axis=-1, keepdims=True)
    m = jnp.where(jnp.isfinite(m), m, 0.0)
    p = jnp.exp(s - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("bkgqs,bksd->bkgqd", p, vf) / jnp.maximum(l, 1e-30)
    return out.reshape(b, hq, sq, v.shape[-1]).astype(q.dtype)
