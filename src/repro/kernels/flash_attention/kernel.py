"""Pallas TPU flash-attention forward kernel.

Grid = (batch*heads, n_q_blocks, n_kv_blocks); the KV axis is the innermost
(sequential / "arbitrary") dimension so the (block_q, head_dim) fp32
accumulator and the (block_q,) running max / sum live in VMEM scratch across
KV iterations — the canonical TPU flash schedule.  Tiles are MXU-aligned
(block sizes multiples of 128 on real hardware; tests use smaller tiles in
interpret mode).

Layout: q (BH, Sq, D), k/v (BH, Skv, D) — GQA callers broadcast KV heads in
the ops wrapper (`flash_attention_pallas`), keeping this kernel MHA-shaped.
Causally-masked blocks are predicated off with pl.when (on TPU these tiles
are skipped by the scalar unit before any VMEM traffic is issued).
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import tpu_compiler_params

NEG_INF = -1e30

# dot_general dimension_numbers: q @ k^T (contract last axes) / p @ v
_DOT_QK = (((1,), (1,)), ((), ()))
_DOT_PV = (((1,), (0,)), ((), ()))


def _flash_fwd_kernel(
    q_ref,
    k_ref,
    v_ref,
    o_ref,  # blocked refs
    acc_ref,
    m_ref,
    l_ref,  # VMEM scratch
    *,
    sm_scale: float,
    causal: bool,
    block_q: int,
    block_k: int,
    n_kv: int,
    sq: int,
    skv: int,
):
    iq = pl.program_id(1)
    jk = pl.program_id(2)

    @pl.when(jk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_pos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    k_pos = jk * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    needed = jnp.logical_or(not causal, jk * block_k <= iq * block_q + block_q - 1)

    @pl.when(needed)
    def _compute():
        q = q_ref[0].astype(jnp.float32)  # (bq, d)
        k = k_ref[0].astype(jnp.float32)  # (bk, d)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, _DOT_QK, preferred_element_type=jnp.float32) * sm_scale
        mask = k_pos < skv  # kv padding
        if causal:
            mask = jnp.logical_and(mask, q_pos >= k_pos)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(mask, p, 0.0)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1)
        pv = jax.lax.dot_general(p, v, _DOT_PV, preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + pv
        m_ref[...] = m_new

    @pl.when(jk == n_kv - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_fwd_pallas(
    q: jnp.ndarray,  # (BH, Sq, D)
    k: jnp.ndarray,  # (BH, Skv, D)
    v: jnp.ndarray,  # (BH, Skv, D)
    *,
    causal: bool = True,
    sm_scale: Optional[float] = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    bh, sq, d = q.shape
    skv = k.shape[1]
    scale = sm_scale if sm_scale is not None else 1.0 / math.sqrt(d)
    block_q = min(block_q, sq)
    block_k = min(block_k, skv)
    pad_q = (-sq) % block_q
    pad_k = (-skv) % block_k
    qp = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0))) if pad_q else q
    kp = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0))) if pad_k else k
    vp = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0))) if pad_k else v
    nq = qp.shape[1] // block_q
    nk = kp.shape[1] // block_k
    kernel = functools.partial(
        _flash_fwd_kernel,
        sm_scale=scale,
        causal=causal,
        block_q=block_q,
        block_k=block_k,
        n_kv=nk,
        sq=sq,
        skv=skv,
    )
    out = pl.pallas_call(
        kernel,
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, nq * block_q, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(qp, kp, vp)
    return out[:, :sq]


def flash_attention_pallas(
    q: jnp.ndarray,  # (B, Hq, Sq, D)
    k: jnp.ndarray,  # (B, Hk, Skv, D)
    v: jnp.ndarray,  # (B, Hk, Skv, D)
    *,
    causal: bool = True,
    sm_scale: Optional[float] = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    """GQA wrapper: broadcasts KV heads, flattens (B, H) for the kernel."""
    b, hq, sq, d = q.shape
    hk = k.shape[1]
    g = hq // hk
    if g > 1:
        k = jnp.repeat(k, g, axis=1)
        v = jnp.repeat(v, g, axis=1)
    out = flash_attention_fwd_pallas(
        q.reshape(b * hq, sq, d),
        k.reshape(b * hq, -1, d),
        v.reshape(b * hq, -1, d),
        causal=causal,
        sm_scale=sm_scale,
        block_q=block_q,
        block_k=block_k,
        interpret=interpret,
    )
    return out.reshape(b, hq, sq, d)
