"""jit'd wrapper for the local SDCA inner loop (kernel or jnp scan)."""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels.sdca.kernel import local_sdca_pallas
from repro.kernels.sdca.ref import local_sdca_ref

# VMEM budget (bytes) for the per-worker shard tile on v5e (~16 MiB usable)
VMEM_BUDGET = 12 * 1024 * 1024


def local_sdca(
    X: jnp.ndarray,  # (m, nl, d)
    y: jnp.ndarray,
    a: jnp.ndarray,
    w: jnp.ndarray,
    idx: jnp.ndarray,  # (m, H)
    sigma_prime: float,
    lam: float,
    n: float,
    *,
    use_pallas: bool = False,
    interpret: bool = True,
    tuned: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    m, nl, d = X.shape
    if tuned:
        from repro.kernels.flash_decode.ops import _tuned_value

        shape = {"m": m, "nl": nl, "d": d, "h": idx.shape[1]}
        use_pallas = bool(_tuned_value("sdca", shape, X.dtype, "use_pallas", int(use_pallas)))
    fits_vmem = (nl * d + 2 * nl + 2 * d) * 4 <= VMEM_BUDGET
    if use_pallas and fits_vmem:
        return local_sdca_pallas(X, y, a, w, idx, sigma_prime, lam, n, interpret=interpret)

    def one_worker(Xk, yk, ak, ik):
        return local_sdca_ref(Xk, yk, ak, w, ik, sigma_prime, lam, n)

    new_a, dw = jax.vmap(one_worker)(X, y, a, idx)
    return new_a, dw
