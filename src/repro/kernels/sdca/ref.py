"""Pure-jnp oracle for the local SDCA inner loop (hinge loss).

Identical math to repro.optim.cocoa._local_sdca for a single worker; the
Pallas kernel (kernel.py) is validated against this.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def local_sdca_ref(
    X: jnp.ndarray,  # (nl, d)
    y: jnp.ndarray,  # (nl,)
    a: jnp.ndarray,  # (nl,) dual vars (a = alpha * y in [0, 1])
    w: jnp.ndarray,  # (d,) current global model
    idx: jnp.ndarray,  # (H,) coordinate order
    sigma_prime: float,
    lam: float,
    n: float,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (new a, dw)."""

    def step(carry, j):
        a, v = carry
        x = X[j]
        yj = y[j]
        aj = a[j]
        xx = jnp.dot(x, x)
        q = sigma_prime * xx / (lam * n)
        margin = yj * jnp.dot(v, x)
        delta_raw = jnp.where(q > 0, (1.0 - margin) / jnp.maximum(q, 1e-30), 0.0)
        a_new = jnp.clip(aj + delta_raw, 0.0, 1.0)
        delta = jnp.where(xx > 0, a_new - aj, 0.0)
        a = a.at[j].add(delta)
        v = v + sigma_prime * delta * yj * x / (lam * n)
        return (a, v), None

    (a, v), _ = jax.lax.scan(step, (a, w), idx)
    return a, (v - w) / sigma_prime
