"""Pallas TPU kernel for the CoCoA local SDCA inner loop.

The paper's compute hot spot is "local learning": each worker runs H
sequential dual-coordinate updates over its (n_local, d) shard.  On GPU this
is a latency-bound pointer-chasing loop; the TPU adaptation keeps the whole
shard tile + the local model vector v resident in VMEM and runs the
sequential loop on-core — each update is one (d,)-dot + one (d,)-AXPY on the
VPU, with zero HBM traffic between updates.

Grid = (n_workers,): one program per worker (workers are embarrassingly
parallel within a BSP round).  The ops wrapper falls back to the jnp scan
(ref.py math) when the shard does not fit the VMEM budget.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import tpu_compiler_params


def _sdca_kernel(
    x_ref,
    y_ref,
    a_ref,
    w_ref,
    idx_ref,
    a_out_ref,
    dw_ref,
    v_ref,
    *,
    h: int,
    sigma_prime: float,
    lam: float,
    n: float,
):
    v_ref[...] = w_ref[0].astype(jnp.float32)
    a_out_ref[0] = a_ref[0]

    def step(t, _):
        j = idx_ref[0, t]
        # NOTE: pl.dslice(0, 1) instead of a bare 0 index — jax<0.5's
        # load/store discharge rule (interpret mode) rejects python ints
        row = (pl.dslice(0, 1), pl.dslice(j, 1))
        x = pl.load(x_ref, row + (slice(None),))[0, 0].astype(jnp.float32)  # (d,)
        yj = pl.load(y_ref, row)[0, 0].astype(jnp.float32)
        aj = pl.load(a_out_ref, row)[0, 0].astype(jnp.float32)
        xx = jnp.sum(x * x)
        q = sigma_prime * xx / (lam * n)
        margin = yj * jnp.sum(v_ref[...] * x)
        delta_raw = jnp.where(q > 0, (1.0 - margin) / jnp.maximum(q, 1e-30), 0.0)
        a_new = jnp.clip(aj + delta_raw, 0.0, 1.0)
        delta = jnp.where(xx > 0, a_new - aj, 0.0)
        pl.store(a_out_ref, row, (aj + delta)[None, None].astype(a_out_ref.dtype))
        v_ref[...] = v_ref[...] + sigma_prime * delta * yj * x / (lam * n)
        return 0

    jax.lax.fori_loop(0, h, step, 0)
    dw_ref[0] = ((v_ref[...] - w_ref[0].astype(jnp.float32)) / sigma_prime).astype(dw_ref.dtype)


def local_sdca_pallas(
    X: jnp.ndarray,  # (m, nl, d) worker shards
    y: jnp.ndarray,  # (m, nl)
    a: jnp.ndarray,  # (m, nl)
    w: jnp.ndarray,  # (d,)
    idx: jnp.ndarray,  # (m, H)
    sigma_prime: float,
    lam: float,
    n: float,
    *,
    interpret: bool = True,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (new a (m, nl), dw (m, d))."""
    m, nl, d = X.shape
    h = idx.shape[1]
    w_b = jnp.broadcast_to(w[None], (m, d))
    kernel = functools.partial(
        _sdca_kernel, h=h, sigma_prime=float(sigma_prime), lam=float(lam), n=float(n)
    )
    a_out, dw = pl.pallas_call(
        kernel,
        grid=(m,),
        in_specs=[
            pl.BlockSpec((1, nl, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, nl), lambda i: (i, 0)),
            pl.BlockSpec((1, nl), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (i, 0)),
            pl.BlockSpec((1, h), lambda i: (i, 0), memory_space=pltpu.SMEM),
        ],
        out_specs=[
            pl.BlockSpec((1, nl), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, nl), a.dtype),
            jax.ShapeDtypeStruct((m, d), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((d,), jnp.float32)],
        compiler_params=tpu_compiler_params(dimension_semantics=("parallel",)),
        interpret=interpret,
    )(X, y, a, w_b, idx.astype(jnp.int32))
    return a_out, dw
