"""Gradient compression with error feedback — the modern instance of the
paper's communication-efficiency axis (CoCoA trades iterations for less
communication; compression trades gradient fidelity for fewer bytes).

Hemingway models both sides of that trade: compression shrinks the Ernest
comm term (theta2/theta3) while degrading the convergence model g(i, m) —
the planner then decides when it pays off.

Three schemes (each a pure transform with carried error-feedback state):
  * int8   — per-tensor symmetric quantization (4x fewer bytes)
  * topk   — keep top r% magnitudes (sparse sync)
  * powersgd — rank-r subspace projection (Vogels et al. 2019)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    scheme: str = "int8"       # int8 | topk | powersgd
    # 5% keeps Adam training stable with plain error feedback; 1%-level
    # sparsity (DGC) additionally needs momentum correction + lr retuning
    topk_ratio: float = 0.05
    rank: int = 4
    error_feedback: bool = True


def _ef_add(g, e):
    return g + e if e is not None else g


# ---------------------------------------------------------------------------
def int8_roundtrip(g: jnp.ndarray) -> jnp.ndarray:
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q.astype(jnp.float32) * scale


def topk_roundtrip(g: jnp.ndarray, ratio: float) -> jnp.ndarray:
    flat = g.reshape(-1)
    k = max(1, int(flat.size * ratio))
    thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
    return jnp.where(jnp.abs(g) >= thresh, g, 0.0)


def powersgd_roundtrip(g: jnp.ndarray, q_prev: Optional[jnp.ndarray],
                       rank: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Rank-r approximation with a warm-started right factor (one power
    iteration per step, as in the paper)."""
    if g.ndim < 2:
        return g, q_prev  # don't compress vectors/scalars
    mat = g.reshape(g.shape[0], -1)
    n, m = mat.shape
    r = min(rank, n, m)
    if q_prev is None or q_prev.shape != (m, r):
        q_prev = jnp.eye(m, r, dtype=mat.dtype)
    p = mat @ q_prev                       # (n, r)
    p, _ = jnp.linalg.qr(p)
    q = mat.T @ p                          # (m, r)
    approx = p @ q.T
    return approx.reshape(g.shape), q


# ---------------------------------------------------------------------------
class GradientCompressor:
    """Stateful wrapper used by the trainer: grads -> compressed grads.

    State (error feedback residuals + PowerSGD factors) lives in a side tree
    carried by the caller; `init_state(params)` builds it."""

    def __init__(self, cfg: CompressionConfig):
        self.cfg = cfg

    def init_state(self, params) -> Dict[str, Any]:
        ef = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params) \
            if self.cfg.error_feedback else None
        return {"ef": ef, "q": None}

    def compress(self, grads, state) -> Tuple[Any, Dict[str, Any]]:
        cfg = self.cfg
        ef = state.get("ef")
        if ef is not None:
            grads = jax.tree.map(_ef_add, grads, ef)
        if cfg.scheme == "int8":
            comp = jax.tree.map(int8_roundtrip, grads)
            new_q = state.get("q")
        elif cfg.scheme == "topk":
            comp = jax.tree.map(lambda g: topk_roundtrip(g, cfg.topk_ratio),
                                grads)
            new_q = state.get("q")
        elif cfg.scheme == "powersgd":
            q_tree = state.get("q")
            leaves, treedef = jax.tree.flatten(grads)
            q_leaves = (treedef.flatten_up_to(q_tree) if q_tree is not None
                        else [None] * len(leaves))
            outs = [powersgd_roundtrip(g, q, cfg.rank)
                    for g, q in zip(leaves, q_leaves)]
            comp = jax.tree.unflatten(treedef, [o[0] for o in outs])
            new_q = jax.tree.unflatten(treedef, [o[1] for o in outs])
        else:
            raise ValueError(f"unknown scheme {cfg.scheme}")
        new_ef = (jax.tree.map(lambda g, c: g - c, grads, comp)
                  if ef is not None else None)
        return comp, {"ef": new_ef, "q": new_q}

    def compressed_bytes_ratio(self) -> float:
        """Bytes-on-wire ratio vs fp32 all-reduce (for the Ernest model)."""
        if self.cfg.scheme == "int8":
            return 0.25
        if self.cfg.scheme == "topk":
            return self.cfg.topk_ratio * 2  # value + index
        if self.cfg.scheme == "powersgd":
            return 0.05  # rank-r factors; depends on shapes, ~r(n+m)/(nm)
        return 1.0
