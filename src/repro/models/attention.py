"""GQA attention (covers dense / hybrid / vlm / audio archs).

Supports: grouped KV heads, qk-norm (Qwen3), QKV bias (Qwen1.5), partial
rotary (StableLM-2), explicit head_dim != d_model / n_heads (Qwen3-32B),
prefill -> KV cache, per-sequence decode positions.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.kernels.flash_attention.ops import decode_attention, flash_attention
from repro.kernels.flash_decode.ops import (
    paged_decode_attention,
    paged_prefill_attention,
)
from repro.models.layers import apply_rope, cast_to, rms_norm
from repro.models.param import ann


def init_attention(key: jax.Array, cfg: ArchConfig) -> Dict:
    """Projections are stored FLATTENED — (d, H*hd) etc. — so tensor
    parallelism shards the H*hd product even when H itself doesn't divide
    the model axis (qwen3-14b: 40 heads, musicgen: 24 heads, GQA kv=8 on a
    16-way axis)."""
    d, h, k_, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    keys = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(d)
    so = 1.0 / math.sqrt(h * hd)
    p = {
        "wq": ann(jax.random.normal(keys[0], (d, h * hd), jnp.float32) * s,
                  "embed", "heads_flat"),
        "wk": ann(jax.random.normal(keys[1], (d, k_ * hd), jnp.float32) * s,
                  "embed", "kv_flat"),
        "wv": ann(jax.random.normal(keys[2], (d, k_ * hd), jnp.float32) * s,
                  "embed", "kv_flat"),
        "wo": ann(jax.random.normal(keys[3], (h * hd, d), jnp.float32) * so,
                  "heads_flat", "embed"),
    }
    if cfg.qkv_bias:
        p["bq"] = ann(jnp.zeros((h * hd,), jnp.float32), "heads_flat")
        p["bk"] = ann(jnp.zeros((k_ * hd,), jnp.float32), "kv_flat")
        p["bv"] = ann(jnp.zeros((k_ * hd,), jnp.float32), "kv_flat")
    if cfg.qk_norm:
        p["q_norm"] = ann(jnp.ones((hd,), jnp.float32), "norm")
        p["k_norm"] = ann(jnp.ones((hd,), jnp.float32), "norm")
    return p


def init_attention_cache(cfg: ArchConfig, batch: int, max_seq: int) -> Dict:
    hd = cfg.head_dim
    shape = (batch, cfg.n_kv_heads, max_seq, hd)
    return {
        "k": jnp.zeros(shape, jnp.dtype(cfg.dtype)),
        "v": jnp.zeros(shape, jnp.dtype(cfg.dtype)),
    }


CACHE_AXES = {
    # cache_head_dim claims the model axis when kv_heads doesn't divide it
    "k": ("cache_batch", "act_kv_heads", "cache_seq", "cache_head_dim"),
    "v": ("cache_batch", "act_kv_heads", "cache_seq", "cache_head_dim"),
}


def _project_qkv(p: Dict, x: jnp.ndarray, cfg: ArchConfig, positions: jnp.ndarray,
                 constrain_fn=None):
    dt = cfg.dtype
    b, s, _ = x.shape
    h, k_, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    xc = cast_to(x, dt)
    q = xc @ cast_to(p["wq"], dt)
    k = xc @ cast_to(p["wk"], dt)
    v = xc @ cast_to(p["wv"], dt)
    if cfg.qkv_bias:
        q = q + cast_to(p["bq"], dt)[None, None]
        k = k + cast_to(p["bk"], dt)[None, None]
        v = v + cast_to(p["bv"], dt)[None, None]
    q = q.reshape(b, s, h, hd)
    k = k.reshape(b, s, k_, hd)
    v = v.reshape(b, s, k_, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    q = apply_rope(q, positions, rotary_pct=cfg.rotary_pct, theta=cfg.rope_theta)
    k = apply_rope(k, positions, rotary_pct=cfg.rotary_pct, theta=cfg.rope_theta)
    if constrain_fn is not None:
        q = constrain_fn(q, ("batch", "seq", "act_heads", None))
        k = constrain_fn(k, ("batch", "seq", "act_kv_heads", None))
        v = constrain_fn(v, ("batch", "seq", "act_kv_heads", None))
    return q, k, v


def apply_attention(
    p: Dict,
    x: jnp.ndarray,  # (B, S, d)
    cfg: ArchConfig,
    *,
    mode: str,  # "train" | "prefill"
    kv_lens: Optional[jnp.ndarray] = None,  # (B,) valid lengths
    constrain_fn=None,
    block_q: int = 512,
    block_k: int = 512,
) -> Tuple[jnp.ndarray, Optional[Dict]]:
    b, s, _ = x.shape
    positions = jnp.arange(s, dtype=jnp.int32)[None].repeat(b, 0)
    q, k, v = _project_qkv(p, x, cfg, positions, constrain_fn)
    qt = q.transpose(0, 2, 1, 3)  # (B, H, S, hd)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    out = flash_attention(
        qt, kt, vt, causal=True,
        kv_lens=None if kv_lens is None else kv_lens.astype(jnp.float32),
        block_q=block_q, block_k=block_k)
    out = out.transpose(0, 2, 1, 3)  # (B, S, H, hd)
    y = out.reshape(b, s, cfg.n_heads * cfg.head_dim) @ cast_to(
        p["wo"], cfg.dtype)
    cache = None
    if mode == "prefill":
        cache = {"k": kt, "v": vt}
    return y, cache


def apply_attention_decode_paged(
    p: Dict,
    x: jnp.ndarray,  # (B, 1, d) one new token
    cfg: ArchConfig,
    cache: Dict,  # k/v pages: (n_pages, Hk, page_size, hd)
    lengths: jnp.ndarray,  # (B,) current fill (also = new token position)
    page_tables: jnp.ndarray,  # (B, pages_per_seq) physical page ids
    *,
    page_size: int,
    paged_impl: str = "stream",
    pages_per_program: Optional[int] = None,
    interpret: bool = True,
) -> Tuple[jnp.ndarray, Dict]:
    """Paged-KV decode: scatter the new token's K/V into its page, then run
    decode attention against the page pool in place.  ``paged_impl`` picks
    the implementation (paged-native stream/pallas, or the legacy dense
    gather oracle — all bit-identical, see kernels/flash_decode/ops.py)."""
    b = x.shape[0]
    positions = lengths[:, None].astype(jnp.int32)
    q, k, v = _project_qkv(p, x, cfg, positions, None)
    # new-token K/V: (B, 1, Hk, hd) -> (B, Hk, hd)
    k_new = k[:, 0]
    v_new = v[:, 0]
    page_idx = lengths // page_size
    offset = lengths % page_size
    pid = jnp.take_along_axis(page_tables, page_idx[:, None], axis=1)[:, 0]
    k_pages = cache["k"].at[pid, :, offset, :].set(
        k_new.astype(cache["k"].dtype))
    v_pages = cache["v"].at[pid, :, offset, :].set(
        v_new.astype(cache["v"].dtype))
    out = paged_decode_attention(
        q[:, 0], k_pages, v_pages, lengths + 1, page_tables,
        impl=paged_impl, pages_per_program=pages_per_program,
        interpret=interpret)  # (B, H, hd)
    y = out.reshape(b, cfg.n_heads * cfg.head_dim) @ cast_to(
        p["wo"], cfg.dtype)
    return y[:, None, :], {"k": k_pages, "v": v_pages}


def apply_attention_prefill_paged(
    p: Dict,
    x: jnp.ndarray,  # (1, C, d) one prompt chunk, padded to C tokens
    cfg: ArchConfig,
    cache: Dict,  # k/v pages: (n_pages, Hk, page_size, hd)
    n_valid: jnp.ndarray,  # () valid tokens in this chunk (<= C)
    page_tables: jnp.ndarray,  # (1, pages_per_seq)
    *,
    s0: int,  # static absolute position of the chunk's first token
    page_size: int,
    scratch_page: int = 0,
    block_q: int = 16,
    block_k: int = 16,
) -> Tuple[jnp.ndarray, Dict]:
    """Chunked paged prefill: scatter the chunk's K/V into the request's
    pages at absolute positions ``s0 + i``, then run causally-masked flash
    over the gathered page row with a static ``q_offset`` so the key
    blocking starts from absolute position 0 — bitwise the block schedule
    of a monolithic prefill.  Padded chunk tail tokens are routed to the
    scratch page and masked by ``kv_lens``; real positions past the prompt
    are only ever read after being overwritten by a later chunk/decode."""
    c = x.shape[1]
    pos = s0 + jnp.arange(c, dtype=jnp.int32)
    positions = pos[None]  # (1, C)
    q, k, v = _project_qkv(p, x, cfg, positions, None)
    valid = jnp.arange(c) < n_valid
    page_idx = jnp.clip(pos // page_size, 0, page_tables.shape[1] - 1)
    pid = jnp.where(valid, page_tables[0, page_idx], scratch_page)
    offset = pos % page_size
    k_pages = cache["k"].at[pid, :, offset, :].set(k[0].astype(cache["k"].dtype))
    v_pages = cache["v"].at[pid, :, offset, :].set(v[0].astype(cache["v"].dtype))
    kv_lens = (s0 + n_valid)[None].astype(jnp.int32)  # (1,)
    out = paged_prefill_attention(
        q.transpose(0, 2, 1, 3), k_pages, v_pages, kv_lens, page_tables,
        q_offset=s0, block_q=block_q, block_k=block_k)  # (1, H, C, hd)
    y = out.transpose(0, 2, 1, 3).reshape(1, c, cfg.n_heads * cfg.head_dim)
    y = y @ cast_to(p["wo"], cfg.dtype)
    return y, {"k": k_pages, "v": v_pages}


def apply_attention_decode(
    p: Dict,
    x: jnp.ndarray,  # (B, 1, d) one new token
    cfg: ArchConfig,
    cache: Dict,
    lengths: jnp.ndarray,  # (B,) current cache fill (also = new token position)
    *,
    constrain_fn=None,
) -> Tuple[jnp.ndarray, Dict]:
    b = x.shape[0]
    positions = lengths[:, None].astype(jnp.int32)  # (B, 1)
    q, k, v = _project_qkv(p, x, cfg, positions, None)
    # insert new kv at per-sequence position
    k_new = k.transpose(0, 2, 1, 3)  # (B, K, 1, hd)
    v_new = v.transpose(0, 2, 1, 3)

    def upd(cache_b, new_b, len_b):
        return jax.lax.dynamic_update_slice(cache_b, new_b, (0, len_b, 0))

    k_cache = jax.vmap(upd)(cache["k"], k_new.astype(cache["k"].dtype), lengths)
    v_cache = jax.vmap(upd)(cache["v"], v_new.astype(cache["v"].dtype), lengths)
    if constrain_fn is not None:
        k_cache = constrain_fn(k_cache, CACHE_AXES["k"])
        v_cache = constrain_fn(v_cache, CACHE_AXES["v"])
    out = decode_attention(q[:, 0], k_cache, v_cache, lengths + 1)  # (B, H, hd)
    y = out.reshape(b, cfg.n_heads * cfg.head_dim) @ cast_to(p["wo"], cfg.dtype)
    return y[:, None, :], {"k": k_cache, "v": v_cache}
