"""The LM: config-driven decoder supporting all 10 assigned architectures.

Layer stack = ``first_k_dense`` unrolled head layers + ``scan`` over
``n_periods`` repetitions of the arch's layer period (so 80-layer models
trace/compile one period, not 80 layers).  Period bodies are rematerialized
according to ``Runtime.remat``.

Three entry points (all pure functions of (params, inputs)):
  * ``loss_fn``     — next-token CE for training shapes
  * ``prefill``     — full-sequence forward, returns last-token logits + cache
  * ``decode_step`` — one token per sequence against the cache
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig
from repro.models import blocks as blocks_mod
from repro.models.layers import (
    cast_to,
    embed_tokens,
    init_embedding,
    init_lm_head,
    init_rmsnorm,
    lm_logits,
    rms_norm,
    softmax_cross_entropy,
)
from repro.models.param import ann, split_tree, stack_periods
from repro.models.runtime import Runtime


class LM:
    def __init__(self, cfg: ArchConfig, rt: Optional[Runtime] = None):
        self.cfg = cfg
        self.rt = rt or Runtime(remat="none")

    # ------------------------------------------------------------------
    # Parameters
    # ------------------------------------------------------------------
    def init_annotated(self, key: jax.Array):
        cfg = self.cfg
        keys = jax.random.split(key, 8)
        tree: Dict = {
            "embed": init_embedding(keys[0], cfg.vocab_size, cfg.d_model),
            "final_norm": init_rmsnorm(cfg.d_model),
        }
        if not cfg.tie_embeddings:
            tree["lm_head"] = init_lm_head(keys[1], cfg.d_model, cfg.vocab_size)
        if cfg.frontend != "none":
            tree["frontend_proj"] = ann(
                jax.random.normal(keys[2], (cfg.d_model, cfg.d_model),
                                  jnp.float32) / math.sqrt(cfg.d_model),
                "embed", None)
        if cfg.first_k_dense:
            import dataclasses
            head_spec = dataclasses.replace(cfg.period[0], ffn="dense")
            hkeys = jax.random.split(keys[3], cfg.first_k_dense)
            tree["head_layers"] = tuple(
                blocks_mod.init_block(hkeys[i], cfg, head_spec)
                for i in range(cfg.first_k_dense))
        pkeys = jax.random.split(keys[4], max(cfg.n_periods, 1))
        per_period = []
        for pi in range(cfg.n_periods):
            lkeys = jax.random.split(pkeys[pi], len(cfg.period))
            per_period.append({
                f"pos{i}": blocks_mod.init_block(lkeys[i], cfg, spec)
                for i, spec in enumerate(cfg.period)
            })
        tree["periods"] = stack_periods(per_period)
        return tree

    def init(self, key: jax.Array):
        """Returns (param values pytree, logical axes pytree)."""
        return split_tree(self.init_annotated(key))

    def param_axes(self):
        """Axes tree without allocating parameters (eval_shape)."""
        annotated = jax.eval_shape(
            lambda: self.init_annotated(jax.random.PRNGKey(0)))
        return split_tree(annotated)[1]

    def param_shapes(self):
        """Param ShapeDtypeStruct tree without allocation."""
        annotated = jax.eval_shape(
            lambda: self.init_annotated(jax.random.PRNGKey(0)))
        return split_tree(annotated)[0]

    # ------------------------------------------------------------------
    # Shared stack application
    # ------------------------------------------------------------------
    def _head_spec(self):
        import dataclasses
        return dataclasses.replace(self.cfg.period[0], ffn="dense")

    def _embed_inputs(self, params, tokens: jnp.ndarray,
                      frontend_embeds: Optional[jnp.ndarray]):
        cfg, rt = self.cfg, self.rt
        x = embed_tokens(params["embed"], tokens, cfg.dtype)
        n_front = 0
        if cfg.frontend != "none":
            assert frontend_embeds is not None, f"{cfg.name} needs frontend_embeds"
            fe = cast_to(frontend_embeds, cfg.dtype) @ cast_to(
                params["frontend_proj"], cfg.dtype)
            x = jnp.concatenate([fe, x], axis=1)
            n_front = fe.shape[1]
        x = rt.constrain(x, ("batch", "seq", "act_embed")) if rt.rules else x
        return x, n_front

    def _apply_stack(self, params, x: jnp.ndarray, *, mode: str,
                     kv_lens: Optional[jnp.ndarray]):
        """mode in {train, prefill}; returns (hidden, cache, aux)."""
        cfg, rt = self.cfg, self.rt
        aux_total = jnp.zeros((), jnp.float32)
        head_caches = []
        for hp in params.get("head_layers", ()):
            x, c, aux = blocks_mod.apply_block(
                hp, x, cfg, self._head_spec(), rt, mode=mode, kv_lens=kv_lens)
            head_caches.append(c)
            aux_total = aux_total + aux

        def period_fn(carry, period_params):
            x, aux = carry
            caches = {}
            for i, spec in enumerate(cfg.period):
                x, c, aux_i = blocks_mod.apply_block(
                    period_params[f"pos{i}"], x, cfg, spec, rt,
                    mode=mode, kv_lens=kv_lens)
                caches[f"pos{i}"] = c if c is not None else 0
                aux = aux + aux_i
            return (x, aux), caches

        body = rt.remat_wrap(period_fn) if mode == "train" else period_fn
        (x, aux_total), period_caches = lax.scan(
            body, (x, aux_total), params["periods"])
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        cache = None
        if mode == "prefill":
            cache = {"head": tuple(head_caches), "periods": period_caches}
        return x, cache, aux_total

    # ------------------------------------------------------------------
    # Training
    # ------------------------------------------------------------------
    def loss_fn(self, params, batch: Dict) -> Tuple[jnp.ndarray, Dict]:
        """batch: tokens (B,S), labels (B,S) already shifted,
        optional frontend_embeds (B,F,d), optional loss_mask (B,S)."""
        cfg = self.cfg
        x, n_front = self._embed_inputs(params, batch["tokens"],
                                        batch.get("frontend_embeds"))
        hidden, _, aux = self._apply_stack(params, x, mode="train", kv_lens=None)
        hidden = hidden[:, n_front:]
        head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
        logits = lm_logits(head, hidden, cfg.dtype)
        if self.rt.rules is not None:
            logits = self.rt.constrain(logits, ("batch", "seq", "act_vocab"))
        ce = softmax_cross_entropy(logits, batch["labels"],
                                   batch.get("loss_mask"))
        loss = ce + aux
        return loss, {"ce": ce, "aux": aux,
                      "tokens": jnp.float32(batch["labels"].size)}

    # ------------------------------------------------------------------
    # Serving
    # ------------------------------------------------------------------
    def prefill(self, params, tokens: jnp.ndarray,
                frontend_embeds: Optional[jnp.ndarray] = None):
        cfg = self.cfg
        x, _ = self._embed_inputs(params, tokens, frontend_embeds)
        hidden, cache, _ = self._apply_stack(params, x, mode="prefill",
                                             kv_lens=None)
        head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
        logits_last = lm_logits(head, hidden[:, -1:], cfg.dtype)[:, 0]
        return logits_last, cache

    def init_cache(self, batch: int, max_seq: int):
        """Zero cache pytree (also used as the dry-run ShapeDtypeStruct
        template)."""
        cfg = self.cfg
        head = tuple(
            blocks_mod.init_block_cache(cfg, self._head_spec(), batch, max_seq)
            for _ in range(cfg.first_k_dense))

        def one_period():
            return {
                f"pos{i}": blocks_mod.init_block_cache(cfg, spec, batch, max_seq)
                for i, spec in enumerate(cfg.period)
            }

        periods = jax.tree.map(
            lambda *xs: jnp.stack(xs), *[one_period() for _ in range(cfg.n_periods)]
        ) if cfg.n_periods > 1 else jax.tree.map(
            lambda x: x[None], one_period())
        return {"head": head, "periods": periods}

    def cache_axes(self):
        """Logical axes pytree matching init_cache output."""
        cfg = self.cfg
        head = tuple(
            blocks_mod.block_cache_axes(cfg, self._head_spec())
            for _ in range(cfg.first_k_dense))
        period = {
            f"pos{i}": {k: ("layers",) + v for k, v in
                        blocks_mod.block_cache_axes(cfg, spec).items()}
            for i, spec in enumerate(cfg.period)
        }
        return {"head": head, "periods": period}

    def decode_step(self, params, tokens: jnp.ndarray, lengths: jnp.ndarray,
                    cache: Dict, frontend_embed: Optional[jnp.ndarray] = None):
        """tokens (B,) int32; lengths (B,) current cache fill.
        ``frontend_embed`` (B, d_model), when given, is projected through
        ``frontend_proj`` and decoded in place of the token embedding —
        teacher-forcing one frontend position (``tokens`` is ignored).
        Returns (logits (B,V), new_cache)."""
        cfg, rt = self.cfg, self.rt
        if frontend_embed is not None:
            x = cast_to(frontend_embed[:, None], cfg.dtype) @ cast_to(
                params["frontend_proj"], cfg.dtype)  # (B,1,d)
        else:
            x = embed_tokens(params["embed"], tokens[:, None], cfg.dtype)
        new_head = []
        for hp, hc in zip(params.get("head_layers", ()), cache["head"]):
            x, c = blocks_mod.apply_block_decode(
                hp, x, cfg, self._head_spec(), rt, hc, lengths)
            new_head.append(c)

        def period_fn(x, inputs):
            period_params, cache_in = inputs
            new_caches = {}
            for i, spec in enumerate(cfg.period):
                x, c = blocks_mod.apply_block_decode(
                    period_params[f"pos{i}"], x, cfg, spec, rt,
                    cache_in[f"pos{i}"], lengths)
                new_caches[f"pos{i}"] = c
            return x, new_caches

        x, new_periods = lax.scan(period_fn, x,
                                  (params["periods"], cache["periods"]))
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
        logits = lm_logits(head, x[:, 0], cfg.dtype)
        return logits, {"head": tuple(new_head), "periods": new_periods}

    def prefill_chunk(self, params, tokens: jnp.ndarray,
                      n_valid: jnp.ndarray, cache: Dict,
                      page_tables: jnp.ndarray, *, s0: int):
        """One chunk of a chunked paged prefill (serving; attn-only archs).

        ``tokens`` (1, C) int32 is the chunk padded to the fixed chunk width
        C (fixed jit shape); ``n_valid`` () is how many of those are real;
        ``s0`` (static) is the absolute position of the chunk's first token.
        Each layer scatters the chunk's K/V (or latent) into the request's
        pages then attends causally with ``q_offset=s0`` over the gathered
        page row, so after the final chunk the pages and the last-position
        logits are bitwise those of a monolithic prefill (see DESIGN.md §11).
        Returns (logits (1, C, V), new_cache)."""
        cfg, rt = self.cfg, self.rt
        x = embed_tokens(params["embed"], tokens, cfg.dtype)  # (1, C, d)
        new_head = []
        for hp, hc in zip(params.get("head_layers", ()), cache["head"]):
            x, c = blocks_mod.apply_block_prefill_paged(
                hp, x, cfg, self._head_spec(), rt, hc, n_valid, page_tables,
                s0=s0)
            new_head.append(c)

        def period_fn(x, inputs):
            period_params, cache_in = inputs
            new_caches = {}
            for i, spec in enumerate(cfg.period):
                x, c = blocks_mod.apply_block_prefill_paged(
                    period_params[f"pos{i}"], x, cfg, spec, rt,
                    cache_in[f"pos{i}"], n_valid, page_tables, s0=s0)
                new_caches[f"pos{i}"] = c
            return x, new_caches

        x, new_periods = lax.scan(period_fn, x,
                                  (params["periods"], cache["periods"]))
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
        logits = lm_logits(head, x, cfg.dtype)  # (1, C, V)
        return logits, {"head": tuple(new_head), "periods": new_periods}

    def decode_step_paged(self, params, tokens: jnp.ndarray,
                          lengths: jnp.ndarray, cache: Dict,
                          page_tables: jnp.ndarray):
        """Page-table-aware decode entry point (serving).

        ``cache`` mirrors ``init_cache`` but attention/MLA leaves are keyed by
        physical page ((n_pages, ..., page_size, ...), see
        ``repro.serve.cache.init_paged_cache``) and recurrent-state leaves by
        slot.  ``page_tables`` (B, pages_per_seq) int32 maps each sequence's
        logical pages to physical pages; page 0 is the scratch page that idle
        slots write into.  Attention over the pool is paged-native by
        default (``Runtime.paged_impl``: "stream" jnp / "pallas" TPU kernel,
        with the legacy "gather" oracle bit-identical to stream — see
        kernels/flash_decode/ops.py); ``Runtime.pages_per_program`` defaults
        to the ``repro.kernels.tune`` config cache.  Returns
        (logits (B,V), new_cache)."""
        cfg, rt = self.cfg, self.rt
        x = embed_tokens(params["embed"], tokens[:, None], cfg.dtype)
        new_head = []
        for hp, hc in zip(params.get("head_layers", ()), cache["head"]):
            x, c = blocks_mod.apply_block_decode_paged(
                hp, x, cfg, self._head_spec(), rt, hc, lengths, page_tables)
            new_head.append(c)

        def period_fn(x, inputs):
            period_params, cache_in = inputs
            new_caches = {}
            for i, spec in enumerate(cfg.period):
                x, c = blocks_mod.apply_block_decode_paged(
                    period_params[f"pos{i}"], x, cfg, spec, rt,
                    cache_in[f"pos{i}"], lengths, page_tables)
                new_caches[f"pos{i}"] = c
            return x, new_caches

        x, new_periods = lax.scan(period_fn, x,
                                  (params["periods"], cache["periods"]))
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
        logits = lm_logits(head, x[:, 0], cfg.dtype)
        return logits, {"head": tuple(new_head), "periods": new_periods}
