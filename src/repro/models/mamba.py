"""Mamba-1 block (selective SSM) — falcon-mamba / jamba mixer.

Uses the chunked selective scan from kernels/ssm_scan (TPU-adapted: bounded
VMEM working set, sequential only across chunks).  Decode keeps a constant
O(d_inner * d_state) recurrent state + (d_conv-1) conv taps per sequence.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig
from repro.kernels.ssm_scan.ops import selective_scan, selective_scan_step
from repro.models.layers import cast_to
from repro.models.param import ann


def init_mamba(key: jax.Array, cfg: ArchConfig) -> Dict:
    mc = cfg.mamba
    d = cfg.d_model
    di = mc.expand * d
    dtr = mc.resolved_dt_rank(d)
    n = mc.d_state
    keys = jax.random.split(key, 6)
    # S4D-real initialization for A
    a_init = jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32)[None, :], (di, 1))
    dt_init_std = dtr ** -0.5
    return {
        "in_proj": ann(jax.random.normal(keys[0], (d, 2 * di), jnp.float32)
                       / math.sqrt(d), "embed", "mamba_inner"),
        "conv_w": ann(jax.random.normal(keys[1], (di, mc.d_conv), jnp.float32)
                      / math.sqrt(mc.d_conv), "mamba_inner", "conv"),
        "conv_b": ann(jnp.zeros((di,), jnp.float32), "mamba_inner"),
        "x_proj": ann(jax.random.normal(keys[2], (di, dtr + 2 * n), jnp.float32)
                      / math.sqrt(di), "mamba_inner", "lora"),
        "dt_w": ann(jax.random.uniform(keys[3], (dtr, di), jnp.float32,
                                       -dt_init_std, dt_init_std),
                    "dt_rank", "mamba_inner"),
        "dt_b": ann(jnp.log(jnp.expm1(  # softplus^-1 of dt in [1e-3, 1e-1]
            jnp.exp(jax.random.uniform(keys[4], (di,), jnp.float32)
                    * (math.log(0.1) - math.log(1e-3)) + math.log(1e-3)))),
            "mamba_inner"),
        "A_log": ann(jnp.log(a_init), "mamba_inner", "ssm_state"),
        "D": ann(jnp.ones((di,), jnp.float32), "mamba_inner"),
        "out_proj": ann(jax.random.normal(keys[5], (di, d), jnp.float32)
                        / math.sqrt(di), "mamba_inner", "embed"),
    }


def init_mamba_cache(cfg: ArchConfig, batch: int) -> Dict:
    mc = cfg.mamba
    di = mc.expand * cfg.d_model
    return {
        "h": jnp.zeros((batch, di, mc.d_state), jnp.float32),
        "conv": jnp.zeros((batch, di, mc.d_conv - 1), jnp.dtype(cfg.dtype)),
    }


MAMBA_CACHE_AXES = {
    "h": ("cache_batch", "mamba_inner", None),
    "conv": ("cache_batch", "mamba_inner", None),
}


def _split_xdb(p: Dict, x_in: jnp.ndarray, cfg: ArchConfig):
    """x_in (B,S,di) -> dt (B,S,di), B (B,S,N), C (B,S,N)."""
    mc = cfg.mamba
    dtr = mc.resolved_dt_rank(cfg.d_model)
    n = mc.d_state
    dt_ = cfg.dtype
    xdb = x_in @ cast_to(p["x_proj"], dt_)
    dt_raw, b_ssm, c_ssm = jnp.split(xdb, [dtr, dtr + n], axis=-1)
    dt = jax.nn.softplus(
        (dt_raw @ cast_to(p["dt_w"], dt_)).astype(jnp.float32)
        + p["dt_b"].astype(jnp.float32))
    return dt, b_ssm, c_ssm


def apply_mamba(
    p: Dict,
    x: jnp.ndarray,  # (B, S, d)
    cfg: ArchConfig,
    *,
    mode: str,  # "train" | "prefill"
    constrain_fn=None,
    scan_chunk: int = 128,
) -> Tuple[jnp.ndarray, Dict]:
    mc = cfg.mamba
    dt_ = cfg.dtype
    b, s, _ = x.shape
    di = mc.expand * cfg.d_model
    xz = cast_to(x, dt_) @ cast_to(p["in_proj"], dt_)
    x_in, z = jnp.split(xz, 2, axis=-1)  # (B,S,di)
    if constrain_fn is not None:
        x_in = constrain_fn(x_in, ("batch", "seq", "act_mamba"))
        z = constrain_fn(z, ("batch", "seq", "act_mamba"))
    # causal depthwise conv over S — accumulated in fp32 and rounded to the
    # model dtype ONCE, so prefill and per-token decode (which computes the
    # same window as an explicit fp32 sum) round identically; in bf16 the
    # two paths drift ~1e-2 per layer, which deep hybrids (jamba: 7 mamba
    # layers per period) compound past decode-vs-prefill test tolerance
    rhs = p["conv_w"].astype(jnp.float32).T[:, None, :]  # (cw, 1, di)
    x_conv = lax.conv_general_dilated(
        x_in.astype(jnp.float32), rhs, window_strides=(1,),
        padding=[(mc.d_conv - 1, 0)],
        dimension_numbers=("NWC", "WIO", "NWC"), feature_group_count=di)
    x_conv = jax.nn.silu(x_conv + p["conv_b"].astype(jnp.float32)[None, None])
    x_conv = cast_to(x_conv, dt_)
    dt, b_ssm, c_ssm = _split_xdb(p, x_conv, cfg)
    a_neg = -jnp.exp(p["A_log"].astype(jnp.float32))
    y, h_last = selective_scan(
        x_conv, dt, a_neg, b_ssm, c_ssm, p["D"].astype(jnp.float32),
        chunk=scan_chunk)
    y = y * jax.nn.silu(z)
    out = y @ cast_to(p["out_proj"], dt_)
    cache = None
    if mode == "prefill":
        conv_tail = x_in[:, -(mc.d_conv - 1):, :].transpose(0, 2, 1)  # (B,di,cw-1)
        cache = {"h": h_last, "conv": conv_tail.astype(jnp.dtype(cfg.dtype))}
    return out, cache


def apply_mamba_decode(
    p: Dict,
    x: jnp.ndarray,  # (B, 1, d)
    cfg: ArchConfig,
    cache: Dict,
    *,
    constrain_fn=None,
) -> Tuple[jnp.ndarray, Dict]:
    dt_ = cfg.dtype
    xz = cast_to(x[:, 0], dt_) @ cast_to(p["in_proj"], dt_)  # (B, 2di)
    x_in, z = jnp.split(xz, 2, axis=-1)
    # conv over [state, x_in] — fp32 accumulate + single rounding, matching
    # apply_mamba's prefill conv bit-for-bit (see comment there)
    conv_w = p["conv_w"].astype(jnp.float32)  # (di, cw)
    window = jnp.concatenate([cache["conv"].astype(dt_), x_in[..., None]],
                             axis=-1)
    x_conv = jnp.sum(window.astype(jnp.float32) * conv_w[None], axis=-1) \
        + p["conv_b"].astype(jnp.float32)[None]
    x_conv = cast_to(jax.nn.silu(x_conv), dt_)
    dt, b_ssm, c_ssm = _split_xdb(p, x_conv[:, None, :], cfg)
    a_neg = -jnp.exp(p["A_log"].astype(jnp.float32))
    y, h_new = selective_scan_step(
        x_conv, dt[:, 0], a_neg, b_ssm[:, 0], c_ssm[:, 0],
        p["D"].astype(jnp.float32), cache["h"])
    y = y * jax.nn.silu(z)
    out = y @ cast_to(p["out_proj"], dt_)
    new_conv = window[..., 1:].astype(cache["conv"].dtype)
    if constrain_fn is not None:
        h_new = constrain_fn(h_new, MAMBA_CACHE_AXES["h"])
        new_conv = constrain_fn(new_conv, MAMBA_CACHE_AXES["conv"])
    return out[:, None, :], {"h": h_new, "conv": new_conv}
