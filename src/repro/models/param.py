"""Parameter trees annotated with logical sharding axes.

``Annotated`` is a registered pytree whose *children* are just the value
array — the axes tuple rides along as static aux data.  That makes
``jax.eval_shape`` over init functions work without allocating parameters
(the dry-run's way of getting full-model shapes + axes), since no string
ever appears as a pytree leaf.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax


@jax.tree_util.register_pytree_node_class
class Annotated:
    """A parameter leaf: array + logical axis names (one per dim)."""

    __slots__ = ("value", "axes")

    def __init__(self, value, axes: Tuple[Optional[str], ...]):
        self.value = value
        self.axes = tuple(axes)

    def tree_flatten(self):
        return (self.value,), self.axes

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], aux)

    def __repr__(self):
        shape = getattr(self.value, "shape", "?")
        return f"Annotated({shape}, axes={self.axes})"


def is_annotated(x) -> bool:
    return isinstance(x, Annotated)


def ann(value, *axes: Optional[str]) -> Annotated:
    if len(axes) != getattr(value, "ndim", len(axes)):
        raise ValueError(f"axes {axes} rank != value rank {value.shape}")
    return Annotated(value, tuple(axes))


def split_tree(tree):
    """(annotated tree) -> (value tree, axes tree); manual dict/tuple walk."""
    if is_annotated(tree):
        return tree.value, tree.axes
    if isinstance(tree, dict):
        vals, axes = {}, {}
        for k, v in tree.items():
            vals[k], axes[k] = split_tree(v)
        return vals, axes
    if isinstance(tree, (tuple, list)):
        if not tree:
            return type(tree)(), type(tree)()
        pairs = [split_tree(v) for v in tree]
        return (type(tree)(p[0] for p in pairs), type(tree)(p[1] for p in pairs))
    # plain leaf without annotation (shouldn't happen for params)
    return tree, tuple(None for _ in range(getattr(tree, "ndim", 0)))


def stack_periods(trees):
    """Stack a list of per-period annotated trees along a new leading 'layers'
    axis (for scan-over-periods)."""
    import jax.numpy as jnp

    def _stack(*leaves):
        vals = jnp.stack([l.value for l in leaves])
        return Annotated(vals, ("layers",) + tuple(leaves[0].axes))

    return jax.tree.map(_stack, *trees, is_leaf=is_annotated)
