"""Shared model layers: norms, RoPE, SwiGLU MLP, embeddings."""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.param import Annotated, ann


def cast_to(x: jnp.ndarray, dtype: str) -> jnp.ndarray:
    return x.astype(jnp.dtype(dtype))


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------
def init_rmsnorm(dim: int) -> Annotated:
    return ann(jnp.ones((dim,), jnp.float32), "norm")


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings (NeoX half-rotation, partial rotary supported)
# ---------------------------------------------------------------------------
def rope_angles(positions: jnp.ndarray, rot_dim: int, theta: float) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """positions (..., S) int -> cos/sin of shape (..., S, rot_dim//2)."""
    half = rot_dim // 2
    inv_freq = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * inv_freq
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, *, rotary_pct: float = 1.0,
               theta: float = 10_000.0) -> jnp.ndarray:
    """x: (B, S, H, D); positions: (B, S) or (S,)."""
    d = x.shape[-1]
    rot_dim = int(d * rotary_pct)
    rot_dim -= rot_dim % 2
    if rot_dim == 0:
        return x
    x_rot, x_pass = x[..., :rot_dim], x[..., rot_dim:]
    cos, sin = rope_angles(positions, rot_dim, theta)  # (B?, S, rot/2)
    if cos.ndim == 2:  # (S, rot/2) -> broadcast batch
        cos, sin = cos[None], sin[None]
    cos = cos[:, :, None, :].astype(jnp.float32)  # (B, S, 1, rot/2)
    sin = sin[:, :, None, :].astype(jnp.float32)
    half = rot_dim // 2
    x1 = x_rot[..., :half].astype(jnp.float32)
    x2 = x_rot[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return jnp.concatenate([out.astype(x.dtype), x_pass], axis=-1)


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------
def init_mlp(key: jax.Array, d_model: int, d_ff: int):
    k1, k2, k3 = jax.random.split(key, 3)
    s_in = 1.0 / math.sqrt(d_model)
    s_ff = 1.0 / math.sqrt(d_ff)
    return {
        "w_gate": ann(jax.random.normal(k1, (d_model, d_ff), jnp.float32) * s_in,
                      "embed", "mlp"),
        "w_up": ann(jax.random.normal(k2, (d_model, d_ff), jnp.float32) * s_in,
                    "embed", "mlp"),
        "w_down": ann(jax.random.normal(k3, (d_ff, d_model), jnp.float32) * s_ff,
                      "mlp", "embed"),
    }


def apply_mlp(params, x: jnp.ndarray, dtype: str, constrain_fn=None) -> jnp.ndarray:
    xc = cast_to(x, dtype)
    h = jax.nn.silu(xc @ cast_to(params["w_gate"], dtype)) * (
        xc @ cast_to(params["w_up"], dtype))
    if constrain_fn is not None:
        h = constrain_fn(h, ("batch", "seq", "act_mlp"))
    return h @ cast_to(params["w_down"], dtype)


# ---------------------------------------------------------------------------
# Embeddings / LM head
# ---------------------------------------------------------------------------
def init_embedding(key: jax.Array, vocab: int, d_model: int) -> Annotated:
    emb = jax.random.normal(key, (vocab, d_model), jnp.float32) / math.sqrt(d_model)
    return ann(emb, "vocab", "embed")


def init_lm_head(key: jax.Array, d_model: int, vocab: int) -> Annotated:
    w = jax.random.normal(key, (d_model, vocab), jnp.float32) / math.sqrt(d_model)
    return ann(w, "embed", "vocab")


def embed_tokens(embed: jnp.ndarray, tokens: jnp.ndarray, dtype: str) -> jnp.ndarray:
    return cast_to(embed, dtype)[tokens]


def lm_logits(head: jnp.ndarray, x: jnp.ndarray, dtype: str) -> jnp.ndarray:
    return cast_to(x, dtype) @ cast_to(head, dtype)


def softmax_cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                          mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Mean token CE. logits (..., V) (vocab may be sharded; reductions are
    GSPMD-safe), labels (...,) int32.  fp32 log-sum-exp."""
    lf = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is not None:
        nll = nll * mask
        return nll.sum() / jnp.maximum(mask.sum(), 1.0)
    return nll.mean()
