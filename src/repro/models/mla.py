"""Multi-head Latent Attention (DeepSeek-V2).

Train/prefill: queries via a low-rank bottleneck (q_lora), KV via a shared
compressed latent c_kv (kv_lora=512) plus a single shared rotary key slice;
attention runs as MHA with qk dim = nope+rope and separate v dim.

Decode caches ONLY (c_kv, k_pe) — the MLA memory win.  Two decode paths:

* ``absorb=False`` (naive): re-expands K/V from the latent cache blockwise
  (flash-decode style online softmax over chunks), paying
  O(S * kv_lora * H * (nope+v)) FLOPs per token.
* ``absorb=True``: absorbs W_uk into the query and W_uv into the output so
  attention runs directly in the latent space — scores against c_kv, context
  in latent space, one (H, kv_lora, v) expansion at the end.  This is the
  DeepSeek-paper inference optimization; EXPERIMENTS.md §Perf quantifies it.

The *paged* decode path (``apply_mla_decode_paged``, serving) is
paged-native and always absorbed: scores and context read the latent page
pool in place via ``kernels.flash_decode.ops.paged_latent_decode_attention``
(stream / pallas / gather impls, mutually bit-exact for stream/gather);
``paged_impl="legacy"`` keeps the old gather + ``_mla_decode_attn`` path.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_decode.ops import (
    gather_pages,
    paged_latent_decode_attention,
)
from repro.models.layers import apply_rope, cast_to, rms_norm
from repro.models.param import ann

NEG_INF = -1e30


def init_mla(key: jax.Array, cfg: ArchConfig) -> Dict:
    m = cfg.mla
    d, h = cfg.d_model, cfg.n_heads
    qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
    keys = jax.random.split(key, 5)
    # up-projections stored flattened (lora, H*dim) so TP shards H*dim even
    # when H doesn't divide the model axis
    return {
        "wq_a": ann(jax.random.normal(keys[0], (d, m.q_lora_rank), jnp.float32)
                    / math.sqrt(d), "embed", "lora"),
        "q_a_norm": ann(jnp.ones((m.q_lora_rank,), jnp.float32), "norm"),
        "wq_b": ann(jax.random.normal(keys[1], (m.q_lora_rank, h * qk_dim),
                                      jnp.float32)
                    / math.sqrt(m.q_lora_rank), "lora", "heads_flat"),
        "wkv_a": ann(jax.random.normal(
            keys[2], (d, m.kv_lora_rank + m.qk_rope_head_dim), jnp.float32)
            / math.sqrt(d), "embed", "lora"),
        "kv_a_norm": ann(jnp.ones((m.kv_lora_rank,), jnp.float32), "norm"),
        "wkv_b": ann(jax.random.normal(
            keys[3], (m.kv_lora_rank, h * (m.qk_nope_head_dim + m.v_head_dim)),
            jnp.float32) / math.sqrt(m.kv_lora_rank),
            "lora", "heads_flat"),
        "wo": ann(jax.random.normal(keys[4], (h * m.v_head_dim, d), jnp.float32)
                  / math.sqrt(h * m.v_head_dim), "heads_flat", "embed"),
    }


def init_mla_cache(cfg: ArchConfig, batch: int, max_seq: int) -> Dict:
    m = cfg.mla
    return {
        "ckv": jnp.zeros((batch, max_seq, m.kv_lora_rank), jnp.dtype(cfg.dtype)),
        "kpe": jnp.zeros((batch, max_seq, m.qk_rope_head_dim), jnp.dtype(cfg.dtype)),
    }


MLA_CACHE_AXES = {
    "ckv": ("cache_batch", "cache_seq", "cache_latent"),
    "kpe": ("cache_batch", "cache_seq", None),
}


def _mla_q(p: Dict, x: jnp.ndarray, cfg: ArchConfig, positions: jnp.ndarray):
    m, dt = cfg.mla, cfg.dtype
    b, s, _ = x.shape
    qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
    xc = cast_to(x, dt)
    cq = rms_norm(xc @ cast_to(p["wq_a"], dt), p["q_a_norm"], cfg.norm_eps)
    q = (cq @ cast_to(p["wq_b"], dt)).reshape(b, s, cfg.n_heads, qk_dim)
    q_nope = q[..., : m.qk_nope_head_dim]
    q_pe = apply_rope(q[..., m.qk_nope_head_dim:], positions, theta=cfg.rope_theta)
    return q_nope, q_pe


def _mla_kv_latent(p: Dict, x: jnp.ndarray, cfg: ArchConfig, positions: jnp.ndarray):
    m, dt = cfg.mla, cfg.dtype
    xc = cast_to(x, dt)
    kv_a = xc @ cast_to(p["wkv_a"], dt)
    ckv = rms_norm(kv_a[..., : m.kv_lora_rank], p["kv_a_norm"], cfg.norm_eps)
    kpe = apply_rope(kv_a[..., m.kv_lora_rank:][:, :, None, :], positions,
                     theta=cfg.rope_theta)[:, :, 0, :]  # (B,S,rope)
    return ckv, kpe


def apply_mla(
    p: Dict,
    x: jnp.ndarray,
    cfg: ArchConfig,
    *,
    mode: str,  # "train" | "prefill"
    kv_lens: Optional[jnp.ndarray] = None,
    constrain_fn=None,
    block_q: int = 512,
    block_k: int = 512,
) -> Tuple[jnp.ndarray, Optional[Dict]]:
    m, dt = cfg.mla, cfg.dtype
    b, s, _ = x.shape
    positions = jnp.arange(s, dtype=jnp.int32)[None].repeat(b, 0)
    q_nope, q_pe = _mla_q(p, x, cfg, positions)
    ckv, kpe = _mla_kv_latent(p, x, cfg, positions)
    kv = (ckv @ cast_to(p["wkv_b"], dt)).reshape(
        b, s, cfg.n_heads, m.qk_nope_head_dim + m.v_head_dim)
    k_nope = kv[..., : m.qk_nope_head_dim]
    v = kv[..., m.qk_nope_head_dim:]
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(kpe[:, :, None, :],
                                  (*k_nope.shape[:3], m.qk_rope_head_dim))],
        axis=-1)
    q = jnp.concatenate([q_nope, q_pe], axis=-1)
    if constrain_fn is not None:
        q = constrain_fn(q, ("batch", "seq", "act_heads", None))
        k = constrain_fn(k, ("batch", "seq", "act_heads", None))
        v = constrain_fn(v, ("batch", "seq", "act_heads", None))
    out = flash_attention(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3),
        causal=True, sm_scale=1.0 / math.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim),
        kv_lens=None if kv_lens is None else kv_lens.astype(jnp.float32),
        block_q=block_q, block_k=block_k)
    out = out.transpose(0, 2, 1, 3)  # (B,S,H,v)
    y = out.reshape(b, s, cfg.n_heads * m.v_head_dim) @ cast_to(p["wo"], dt)
    cache = {"ckv": ckv, "kpe": kpe} if mode == "prefill" else None
    return y, cache


def apply_mla_decode(
    p: Dict,
    x: jnp.ndarray,  # (B, 1, d)
    cfg: ArchConfig,
    cache: Dict,
    lengths: jnp.ndarray,  # (B,)
    *,
    absorb: bool = False,
    chunk: int = 2048,
    constrain_fn=None,
) -> Tuple[jnp.ndarray, Dict]:
    m, dt = cfg.mla, cfg.dtype
    b = x.shape[0]
    h = cfg.n_heads
    positions = lengths[:, None].astype(jnp.int32)
    q_nope, q_pe = _mla_q(p, x, cfg, positions)       # (B,1,H,·)
    ckv_new, kpe_new = _mla_kv_latent(p, x, cfg, positions)

    def upd(cache_b, new_b, len_b):
        return lax.dynamic_update_slice(cache_b, new_b, (len_b, 0))

    ckv_c = jax.vmap(upd)(cache["ckv"], ckv_new.astype(cache["ckv"].dtype), lengths)
    kpe_c = jax.vmap(upd)(cache["kpe"], kpe_new.astype(cache["kpe"].dtype), lengths)
    if constrain_fn is not None:
        ckv_c = constrain_fn(ckv_c, MLA_CACHE_AXES["ckv"])
        kpe_c = constrain_fn(kpe_c, MLA_CACHE_AXES["kpe"])
    new_cache = {"ckv": ckv_c, "kpe": kpe_c}
    out = _mla_decode_attn(p, q_nope[:, 0], q_pe[:, 0], ckv_c, kpe_c,
                           lengths + 1, cfg, absorb=absorb, chunk=chunk)
    y = out.reshape(b, h * m.v_head_dim) @ cast_to(p["wo"], dt)
    return y[:, None, :], new_cache


def apply_mla_decode_paged(
    p: Dict,
    x: jnp.ndarray,  # (B, 1, d)
    cfg: ArchConfig,
    cache: Dict,  # ckv pages (n_pages, page_size, r); kpe (n_pages, page_size, rope)
    lengths: jnp.ndarray,  # (B,)
    page_tables: jnp.ndarray,  # (B, pages_per_seq)
    *,
    page_size: int,
    absorb: bool = False,
    chunk: int = 2048,
    paged_impl: str = "stream",
    pages_per_program: Optional[int] = None,
    interpret: bool = True,
) -> Tuple[jnp.ndarray, Dict]:
    """Paged latent-cache decode: scatter the new (c_kv, k_pe) into its page,
    then attend over the latent pool in place (absorbed form: W_uk folded
    into the query, W_uv applied once to the latent context), via
    ``paged_latent_decode_attention``.  ``paged_impl="legacy"`` keeps the
    pre-paged-native behavior: gather contiguous views and run
    ``_mla_decode_attn`` with the caller's ``absorb``/``chunk``."""
    m, dt = cfg.mla, cfg.dtype
    b, h = x.shape[0], cfg.n_heads
    positions = lengths[:, None].astype(jnp.int32)
    q_nope, q_pe = _mla_q(p, x, cfg, positions)
    ckv_new, kpe_new = _mla_kv_latent(p, x, cfg, positions)
    page_idx = lengths // page_size
    offset = lengths % page_size
    pid = jnp.take_along_axis(page_tables, page_idx[:, None], axis=1)[:, 0]
    ckv_pages = cache["ckv"].at[pid, offset, :].set(
        ckv_new[:, 0].astype(cache["ckv"].dtype))
    kpe_pages = cache["kpe"].at[pid, offset, :].set(
        kpe_new[:, 0].astype(cache["kpe"].dtype))
    new_cache = {"ckv": ckv_pages, "kpe": kpe_pages}
    if paged_impl == "legacy":
        n_pp = page_tables.shape[1]
        ckv_c = ckv_pages[page_tables].reshape(b, n_pp * page_size,
                                               m.kv_lora_rank)
        kpe_c = kpe_pages[page_tables].reshape(b, n_pp * page_size,
                                               m.qk_rope_head_dim)
        out = _mla_decode_attn(p, q_nope[:, 0], q_pe[:, 0], ckv_c, kpe_c,
                               lengths + 1, cfg, absorb=absorb, chunk=chunk)
        y = out.reshape(b, h * m.v_head_dim) @ cast_to(p["wo"], dt)
        return y[:, None, :], new_cache
    scale = 1.0 / math.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    wkv_b = cast_to(p["wkv_b"], dt).reshape(
        m.kv_lora_rank, h, m.qk_nope_head_dim + m.v_head_dim)
    wk = wkv_b[..., : m.qk_nope_head_dim]
    wv = wkv_b[..., m.qk_nope_head_dim:]
    q_lat = jnp.einsum("bhe,rhe->bhr", q_nope[:, 0], wk)  # (B, H, r)
    ctx_lat = paged_latent_decode_attention(
        q_lat, q_pe[:, 0], ckv_pages, kpe_pages, lengths + 1, page_tables,
        sm_scale=scale, impl=paged_impl,
        pages_per_program=pages_per_program, interpret=interpret)
    out = jnp.einsum("bhr,rhe->bhe", ctx_lat.astype(dt), wv)  # (B, H, v)
    y = out.reshape(b, h * m.v_head_dim) @ cast_to(p["wo"], dt)
    return y[:, None, :], new_cache


def apply_mla_prefill_paged(
    p: Dict,
    x: jnp.ndarray,  # (1, C, d) one prompt chunk, padded to C tokens
    cfg: ArchConfig,
    cache: Dict,  # latent pages: ckv (n_pages, page, r), kpe (n_pages, page, rope)
    n_valid: jnp.ndarray,  # () valid tokens in this chunk (<= C)
    page_tables: jnp.ndarray,  # (1, pages_per_seq)
    *,
    s0: int,  # static absolute position of the chunk's first token
    page_size: int,
    scratch_page: int = 0,
    block_q: int = 16,
    block_k: int = 16,
) -> Tuple[jnp.ndarray, Dict]:
    """Chunked paged MLA prefill: scatter the chunk's (c_kv, k_pe) into the
    latent pages, gather the request's full latent row, re-expand K/V with
    ``wkv_b`` (row-stable matmul, so earlier positions are bitwise those of
    a monolithic prefill), and run causal flash with static ``q_offset``.
    Padded chunk tail tokens are routed to the scratch page."""
    m, dt = cfg.mla, cfg.dtype
    c, h = x.shape[1], cfg.n_heads
    pos = s0 + jnp.arange(c, dtype=jnp.int32)
    positions = pos[None]  # (1, C)
    q_nope, q_pe = _mla_q(p, x, cfg, positions)          # (1,C,H,·)
    ckv_new, kpe_new = _mla_kv_latent(p, x, cfg, positions)
    valid = jnp.arange(c) < n_valid
    page_idx = jnp.clip(pos // page_size, 0, page_tables.shape[1] - 1)
    pid = jnp.where(valid, page_tables[0, page_idx], scratch_page)
    offset = pos % page_size
    ckv_pages = cache["ckv"].at[pid, offset, :].set(
        ckv_new[0].astype(cache["ckv"].dtype))
    kpe_pages = cache["kpe"].at[pid, offset, :].set(
        kpe_new[0].astype(cache["kpe"].dtype))
    new_cache = {"ckv": ckv_pages, "kpe": kpe_pages}
    ckv_full = gather_pages(ckv_pages, page_tables)  # (1, S, r)
    kpe_full = gather_pages(kpe_pages, page_tables)  # (1, S, rope)
    kv = (ckv_full @ cast_to(p["wkv_b"], dt)).reshape(
        1, ckv_full.shape[1], h, m.qk_nope_head_dim + m.v_head_dim)
    k_nope = kv[..., : m.qk_nope_head_dim]
    v = kv[..., m.qk_nope_head_dim:]
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(kpe_full[:, :, None, :],
                                  (*k_nope.shape[:3], m.qk_rope_head_dim))],
        axis=-1)
    q = jnp.concatenate([q_nope, q_pe], axis=-1)
    kv_lens = (s0 + n_valid)[None].astype(jnp.float32)  # (1,)
    out = flash_attention(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), causal=True,
        sm_scale=1.0 / math.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim),
        kv_lens=kv_lens, q_offset=s0, block_q=block_q, block_k=block_k)
    out = out.transpose(0, 2, 1, 3)  # (1,C,H,v)
    y = out.reshape(1, c, h * m.v_head_dim) @ cast_to(p["wo"], dt)
    return y, new_cache


def _mla_decode_attn(
    p: Dict,
    q_nope1: jnp.ndarray,  # (B, H, nope)
    q_pe1: jnp.ndarray,    # (B, H, rope)
    ckv_c: jnp.ndarray,    # (B, S, r) latent cache incl. the new token
    kpe_c: jnp.ndarray,    # (B, S, rope)
    lens1: jnp.ndarray,    # (B,) valid lengths incl. the new token
    cfg: ArchConfig,
    *,
    absorb: bool,
    chunk: int,
) -> jnp.ndarray:
    """Shared decode attention over a contiguous latent cache view; returns
    (B, H, v_head_dim)."""
    m, dt = cfg.mla, cfg.dtype
    b, h = q_nope1.shape[0], cfg.n_heads
    s_max = ckv_c.shape[1]
    scale = 1.0 / math.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    wkv_b = cast_to(p["wkv_b"], dt).reshape(
        m.kv_lora_rank, h, m.qk_nope_head_dim + m.v_head_dim)
    wk = wkv_b[..., : m.qk_nope_head_dim]   # (r,H,nope)
    wv = wkv_b[..., m.qk_nope_head_dim:]    # (r,H,v)

    if absorb:
        # latent-space attention: scores vs compressed cache directly.
        # bf16 inputs with fp32 MXU accumulation — casting the whole cache
        # to fp32 would materialize 2x the cache per layer per step.
        q_lat = jnp.einsum("bhe,rhe->bhr", q_nope1, wk)  # (B,H,r)
        scores = (jnp.einsum("bhr,bsr->bhs", q_lat, ckv_c,
                             preferred_element_type=jnp.float32)
                  + jnp.einsum("bhe,bse->bhs", q_pe1, kpe_c,
                               preferred_element_type=jnp.float32)) * scale
        mask = jnp.arange(s_max)[None, :] < lens1[:, None]
        scores = jnp.where(mask[:, None, :], scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1)
        ctx_lat = jnp.einsum("bhs,bsr->bhr", probs.astype(dt), ckv_c,
                             preferred_element_type=jnp.float32)  # (B,H,r)
        out = jnp.einsum("bhr,rhe->bhe", ctx_lat.astype(dt), wv)  # (B,H,v)
    else:
        # naive: blockwise re-expansion of K/V from the latent cache with an
        # online softmax (bounded memory, heavy FLOPs)
        nchunks = max(1, -(-s_max // chunk))
        pad = nchunks * chunk - s_max
        ckv_p = jnp.pad(ckv_c, ((0, 0), (0, pad), (0, 0)))
        kpe_p = jnp.pad(kpe_c, ((0, 0), (0, pad), (0, 0)))

        def chunk_step(carry, j):
            acc, mx, l = carry
            ckv_j = lax.dynamic_slice(ckv_p, (0, j * chunk, 0), (b, chunk, m.kv_lora_rank))
            kpe_j = lax.dynamic_slice(kpe_p, (0, j * chunk, 0), (b, chunk, m.qk_rope_head_dim))
            kv_j = jnp.einsum("bsr,rhe->bshe", ckv_j, wkv_b)
            k_nope_j = kv_j[..., : m.qk_nope_head_dim]
            v_j = kv_j[..., m.qk_nope_head_dim:]
            s_j = (jnp.einsum("bhe,bshe->bhs", q_nope1.astype(jnp.float32),
                              k_nope_j.astype(jnp.float32))
                   + jnp.einsum("bhe,bse->bhs", q_pe1.astype(jnp.float32),
                                kpe_j.astype(jnp.float32))) * scale
            pos = j * chunk + jnp.arange(chunk)
            valid = pos[None, :] < lens1[:, None]
            s_j = jnp.where(valid[:, None, :], s_j, NEG_INF)
            mx_new = jnp.maximum(mx, s_j.max(-1))
            alpha = jnp.exp(mx - mx_new)
            pj = jnp.exp(s_j - mx_new[..., None])
            pj = jnp.where(valid[:, None, :], pj, 0.0)
            l_new = l * alpha + pj.sum(-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bhs,bshe->bhe", pj, v_j.astype(jnp.float32))
            return (acc_new, mx_new, l_new), None

        init = (jnp.zeros((b, h, m.v_head_dim), jnp.float32),
                jnp.full((b, h), NEG_INF, jnp.float32),
                jnp.zeros((b, h), jnp.float32))
        (acc, _, l), _ = lax.scan(chunk_step, init, jnp.arange(nchunks))
        out = (acc / jnp.maximum(l, 1e-30)[..., None]).astype(dt)

    return out
