"""Runtime options threaded through model apply functions.

Everything performance-tunable (block sizes, remat, sharding rules, MLA
absorption, MoE path) lives here so §Perf hillclimbing changes only a
Runtime, never model code.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax

from repro.dist.partitioning import Rules, constrain


@dataclasses.dataclass(frozen=True)
class Runtime:
    mesh: Optional[object] = None          # jax.sharding.Mesh
    rules: Optional[Rules] = None
    block_q: int = 512
    block_k: int = 512
    scan_chunk: int = 128
    mla_absorb: bool = False
    remat: str = "full"                     # none | full | dots
    use_pallas: bool = False                # TPU-only kernel path
    page_size: int = 16                     # paged-KV page length (serving)
    # paged decode implementation: "stream" (paged-native jnp, CPU default),
    # "pallas" (TPU kernel; interpret mode on CPU), "gather" (legacy dense
    # gather — the correctness oracle).  All three are bit-identical for the
    # same pages_per_program (see kernels/flash_decode/ops.py).
    paged_impl: str = "stream"
    pages_per_program: Optional[int] = None  # None -> autotuner cache/default
    interpret: bool = True                   # Pallas interpret mode (no TPU)

    def constrain(self, x: jax.Array, axes) -> jax.Array:
        return constrain(x, self.rules, axes)

    @property
    def constrain_fn(self):
        return None if self.rules is None else self.constrain

    def remat_wrap(self, fn):
        if self.remat == "none":
            return fn
        if self.remat == "dots":
            return jax.checkpoint(
                fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
        return jax.checkpoint(fn)


LOCAL_RUNTIME = Runtime(remat="none")
