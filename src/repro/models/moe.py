"""Mixture-of-Experts FFN with expert parallelism.

Routing (top-k, optional renorm, shared experts) follows DeepSeek-MoE /
Jamba.  The dispatch-compute-combine path is written once and run two ways:

* **EP shard_map path** (production): experts are sharded over the ``model``
  mesh axis.  Because activations are tensor-parallel-replicated across
  ``model`` (every model shard already holds its data shard's tokens), the
  dispatch is *local* — each shard gathers the tokens routed to its resident
  experts into an (E_local, C, d) capacity buffer, runs the grouped SwiGLU,
  scatter-adds weighted outputs, and a single psum over ``model`` combines
  expert contributions (the same collective a TP FFN needs anyway).  This is
  the TPU-idiomatic EP layout: no all-to-all is required on the ICI torus,
  unlike GPU EP implementations that shard activations over the expert axis.
* **local path** (single host / smoke tests): identical math, E_local = E,
  no psum.

Capacity-overflow tokens are dropped per expert (standard Switch/GShard
semantics); the router aux loss keeps load balanced.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models.layers import cast_to
from repro.models.param import ann


def _shard_map(fn, mesh, in_specs, out_specs):
    """jax.shard_map with replication checking off, across jax versions
    (top-level ``jax.shard_map``/``check_vma`` vs the older
    ``jax.experimental.shard_map``/``check_rep``)."""
    sm = getattr(jax, "shard_map", None)
    if sm is None:
        from jax.experimental.shard_map import shard_map as sm
    try:
        return sm(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_vma=False)
    except TypeError:  # transition releases kept the check_rep kwarg
        return sm(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_rep=False)


def init_moe(key: jax.Array, cfg: ArchConfig) -> Dict:
    moe = cfg.moe
    d, e, f = cfg.d_model, moe.n_routed_experts, moe.expert_d_ff
    keys = jax.random.split(key, 7)
    s_in, s_ff = 1.0 / math.sqrt(d), 1.0 / math.sqrt(f)
    p = {
        "router": ann(jax.random.normal(keys[0], (d, e), jnp.float32) * s_in,
                      "embed", "expert"),
        "w_gate": ann(jax.random.normal(keys[1], (e, d, f), jnp.float32) * s_in,
                      "expert", "embed", "expert_mlp"),
        "w_up": ann(jax.random.normal(keys[2], (e, d, f), jnp.float32) * s_in,
                    "expert", "embed", "expert_mlp"),
        "w_down": ann(jax.random.normal(keys[3], (e, f, d), jnp.float32) * s_ff,
                      "expert", "expert_mlp", "embed"),
    }
    if moe.n_shared_experts:
        fs = moe.n_shared_experts * f
        p["sh_gate"] = ann(jax.random.normal(keys[4], (d, fs), jnp.float32) * s_in,
                           "embed", "mlp")
        p["sh_up"] = ann(jax.random.normal(keys[5], (d, fs), jnp.float32) * s_in,
                         "embed", "mlp")
        p["sh_down"] = ann(jax.random.normal(keys[6], (fs, d), jnp.float32)
                           / math.sqrt(fs), "mlp", "embed")
    return p


def _capacity(t: int, moe, train: bool) -> int:
    """Per-expert token capacity for a dispatch over ``t`` tokens.

    Training uses the standard Switch/GShard formula (overflow drops are the
    price of balanced static shapes).  Inference is fully dropless
    (``cap = t``, the worst case of every token routing to one expert): a
    token's output then never depends on which other tokens share its
    dispatch, so the same token at the same position produces bit-identical
    results whether it is processed by a B-row decode step, a B*T-row
    speculative verify step, or a prefill chunk of any size — the invariant
    the serve engine's spec-decode and chunked-prefill paths rely on.  (The
    previous eval rule, ``min(t, max(cap, 16))``, was dropless only for
    t <= 16 and silently coupled larger eval dispatches.)"""
    if not train:
        return max(t, 1)
    cap = int(math.ceil(t * moe.top_k / moe.n_routed_experts
                        * moe.capacity_factor))
    return max(cap, 1)


def _route(p: Dict, x: jnp.ndarray, cfg: ArchConfig, train: bool):
    """Router in fp32. x (B,S,d) -> ids (B,S,k) int32, probs (B,S,k) f32, aux."""
    moe = cfg.moe
    logits = x.astype(jnp.float32) @ p["router"].astype(jnp.float32)
    probs_full = jax.nn.softmax(logits, axis=-1)
    probs, ids = lax.top_k(probs_full, moe.top_k)
    if moe.norm_topk:
        probs = probs / jnp.maximum(probs.sum(-1, keepdims=True), 1e-9)
    aux = jnp.zeros((), jnp.float32)
    if train and moe.router_aux_loss > 0:
        # Switch-style load-balance loss: E * sum_e f_e * P_e with f_e the
        # fraction of routed assignments landing on expert e.
        e = moe.n_routed_experts
        me = probs_full.reshape(-1, e).mean(0)
        fe = jax.nn.one_hot(ids.reshape(-1), e, dtype=jnp.float32).mean(0)
        aux = e * jnp.sum(me * fe) * moe.router_aux_loss
    return ids.astype(jnp.int32), probs, aux


def _dispatch_compute_combine(
    xt: jnp.ndarray,       # (T, d) local tokens
    ids: jnp.ndarray,      # (T, k) global expert ids
    probs: jnp.ndarray,    # (T, k) f32
    wg: jnp.ndarray,       # (El, d, f) local experts
    wu: jnp.ndarray,
    wd: jnp.ndarray,
    e0: jnp.ndarray,       # scalar int: first local expert id
    capacity: int,
    dtype: str,
) -> jnp.ndarray:
    t, d = xt.shape
    k = ids.shape[1]
    el = wg.shape[0]
    c = capacity
    flat_ids = ids.reshape(-1)                       # (T*k,)
    local_ids = flat_ids - e0
    is_local = (local_ids >= 0) & (local_ids < el)
    a_ids = jnp.where(is_local, local_ids, el)       # el = drop bucket
    order = jnp.argsort(a_ids, stable=True)
    sorted_ids = a_ids[order]
    ar = jnp.arange(t * k, dtype=jnp.int32)
    is_new = jnp.concatenate(
        [jnp.ones((1,), bool), sorted_ids[1:] != sorted_ids[:-1]])
    group_start = lax.cummax(jnp.where(is_new, ar, 0))
    rank = ar - group_start
    valid = (sorted_ids < el) & (rank < c)
    slot = jnp.where(valid, sorted_ids * c + rank, el * c)
    tok = order // k
    xbuf = jnp.zeros((el * c + 1, d), jnp.dtype(dtype)).at[slot].set(
        xt.astype(jnp.dtype(dtype))[tok])
    xe = xbuf[: el * c].reshape(el, c, d)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, cast_to(wg, dtype))) * \
        jnp.einsum("ecd,edf->ecf", xe, cast_to(wu, dtype))
    oe = jnp.einsum("ecf,efd->ecd", h, cast_to(wd, dtype)).reshape(el * c, d)
    w_sorted = probs.reshape(-1)[order].astype(jnp.float32)
    gathered = oe[jnp.where(valid, slot, 0)]
    contrib = gathered.astype(jnp.float32) * jnp.where(valid, w_sorted, 0.0)[:, None]
    y = jnp.zeros((t, d), jnp.float32).at[tok].add(contrib)
    return y.astype(jnp.dtype(dtype))


def _dispatch_2d(x_loc, xt_full, ids, probs, wg, wu, wd, e0, capacity,
                 dtype, spare_axes):
    """Replicated-token expert compute with d-sharded weights.

    x_loc (T, d_loc) is this shard's d-slice of the (replicated) tokens;
    wg/wu (El, d_loc, f) and wd (El, f, d_loc) keep their FSDP storage.
    Gate/up partials are psum'd over the spare axes BEFORE the
    nonlinearity; the down output stays d-sharded and is all-gathered
    (T x d bytes — tiny for decode) instead of gathering GBs of weights.
    """
    t, d_loc = x_loc.shape
    k = ids.shape[1]
    el, _, f = wg.shape
    c = capacity
    flat_ids = ids.reshape(-1)
    local_ids = flat_ids - e0
    is_local = (local_ids >= 0) & (local_ids < el)
    a_ids = jnp.where(is_local, local_ids, el)
    order = jnp.argsort(a_ids, stable=True)
    sorted_ids = a_ids[order]
    ar = jnp.arange(t * k, dtype=jnp.int32)
    is_new = jnp.concatenate(
        [jnp.ones((1,), bool), sorted_ids[1:] != sorted_ids[:-1]])
    group_start = lax.cummax(jnp.where(is_new, ar, 0))
    rank = ar - group_start
    valid = (sorted_ids < el) & (rank < c)
    slot = jnp.where(valid, sorted_ids * c + rank, el * c)
    tok = order // k
    xbuf = jnp.zeros((el * c + 1, d_loc), jnp.dtype(dtype)).at[slot].set(
        x_loc.astype(jnp.dtype(dtype))[tok])
    xe = xbuf[: el * c].reshape(el, c, d_loc)
    g_part = jnp.einsum("ecd,edf->ecf", xe, cast_to(wg, dtype))
    u_part = jnp.einsum("ecd,edf->ecf", xe, cast_to(wu, dtype))
    g_full = lax.psum(g_part, spare_axes)
    u_full = lax.psum(u_part, spare_axes)
    h = jax.nn.silu(g_full) * u_full
    o_loc = jnp.einsum("ecf,efd->ecd", h, cast_to(wd, dtype)).reshape(
        el * c, d_loc)
    w_sorted = probs.reshape(-1)[order].astype(jnp.float32)
    gathered = o_loc[jnp.where(valid, slot, 0)]
    contrib = gathered.astype(jnp.float32) * jnp.where(
        valid, w_sorted, 0.0)[:, None]
    y_loc = jnp.zeros((t, d_loc), jnp.float32).at[tok].add(contrib)
    # reassemble full d on every shard (T x d — tiny for decode shapes)
    y = lax.all_gather(y_loc, spare_axes, axis=1, tiled=True)
    return y.astype(jnp.dtype(dtype))


def _shared_ffn(xt, sh_g, sh_u, sh_d, dtype) -> jnp.ndarray:
    xc = cast_to(xt, dtype)
    h = jax.nn.silu(xc @ cast_to(sh_g, dtype)) * (xc @ cast_to(sh_u, dtype))
    return h @ cast_to(sh_d, dtype)


def apply_moe(
    p: Dict,
    x: jnp.ndarray,  # (B, S, d)
    cfg: ArchConfig,
    *,
    train: bool,
    mesh=None,
    rules=None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (y (B,S,d), aux_loss scalar)."""
    moe = cfg.moe
    b, s, d = x.shape
    ids, probs, aux = _route(p, x, cfg, train)
    has_shared = moe.n_shared_experts > 0
    use_shard_map = mesh is not None and rules is not None and \
        rules.model_axis() is not None

    if not use_shard_map:
        t = b * s
        cap = _capacity(t, moe, train)
        y = _dispatch_compute_combine(
            x.reshape(t, d), ids.reshape(t, -1), probs.reshape(t, -1),
            p["w_gate"], p["w_up"], p["w_down"], jnp.int32(0), cap, cfg.dtype)
        if has_shared:
            y = y + _shared_ffn(x.reshape(t, d), p["sh_gate"], p["sh_up"],
                                p["sh_down"], cfg.dtype)
        return y.reshape(b, s, d), aux

    model_axis = rules.model_axis()
    batch_axes = rules.batch_axes()
    bspec = tuple(batch_axes) if len(batch_axes) > 1 else (
        batch_axes[0] if batch_axes else None)
    # data axes NOT carrying the batch can carry the experts' d_model dim
    # (FSDP storage); with replicated tokens (latency-optimal decode) we
    # keep the weights fully sharded and psum partial activations instead
    # of gathering weights — see EXPERIMENTS.md §Perf.
    mesh_axes = tuple(mesh.axis_names)
    spare_axes = tuple(a for a in mesh_axes
                       if a != model_axis and a not in batch_axes)
    use_2d_experts = bool(spare_axes) and not batch_axes

    def fn(x_blk, ids_blk, probs_blk, wg, wu, wd, *shared):
        bl, sl, _ = x_blk.shape
        t = bl * sl
        el = wg.shape[0]
        j = lax.axis_index(model_axis)
        e0 = (j * el).astype(jnp.int32)
        cap = _capacity(t, moe, train)
        if use_2d_experts:
            # weights arrive d-sharded over the spare axes: slice the
            # replicated tokens to the matching d range, compute partials,
            # psum over the spare axes before the nonlinearity
            d_loc = wg.shape[1]
            i = lax.axis_index(spare_axes[0]) if len(spare_axes) == 1 else \
                lax.axis_index(spare_axes)
            xt = x_blk.reshape(t, d)
            x_loc = lax.dynamic_slice_in_dim(xt, i * d_loc, d_loc, axis=1)
            flat_ids = ids_blk.reshape(t, -1)
            probs_f = probs_blk.reshape(t, -1)
            y = _dispatch_2d(x_loc, xt, flat_ids, probs_f, wg, wu, wd, e0,
                             cap, cfg.dtype, spare_axes)
        else:
            y = _dispatch_compute_combine(
                x_blk.reshape(t, d), ids_blk.reshape(t, -1),
                probs_blk.reshape(t, -1), wg, wu, wd, e0, cap, cfg.dtype)
        if shared:
            sh_g, sh_u, sh_d = shared
            y = y + _shared_ffn(x_blk.reshape(t, d), sh_g, sh_u, sh_d, cfg.dtype)
        y = lax.psum(y, model_axis)
        return y.reshape(bl, sl, d)

    expert_w_spec = (P(model_axis, spare_axes if len(spare_axes) > 1 else
                       spare_axes[0], None) if use_2d_experts
                     else P(model_axis, None, None))
    expert_wd_spec = (P(model_axis, None, spare_axes if len(spare_axes) > 1
                        else spare_axes[0]) if use_2d_experts
                      else P(model_axis, None, None))
    in_specs = [
        P(bspec, None, None),          # x
        P(bspec, None, None),          # ids
        P(bspec, None, None),          # probs
        expert_w_spec,                 # w_gate
        expert_w_spec,                 # w_up
        expert_wd_spec,                # w_down
    ]
    args = [x, ids, probs, p["w_gate"], p["w_up"], p["w_down"]]
    if has_shared:
        in_specs += [P(None, model_axis), P(None, model_axis), P(model_axis, None)]
        args += [p["sh_gate"], p["sh_up"], p["sh_down"]]
    y = _shard_map(fn, mesh, tuple(in_specs),
                   P(bspec, None, None))(*args)
    return y, aux
