"""Decoder blocks: (attn|mamba) mixer + (dense|moe|none) FFN, pre-norm."""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, LayerSpec
from repro.models import attention as attn_mod
from repro.models import mamba as mamba_mod
from repro.models import mla as mla_mod
from repro.models import moe as moe_mod
from repro.models.layers import apply_mlp, init_mlp, init_rmsnorm, rms_norm
from repro.models.runtime import Runtime


def _uses_mla(cfg: ArchConfig) -> bool:
    return cfg.mla is not None


def init_block(key: jax.Array, cfg: ArchConfig, spec: LayerSpec) -> Dict:
    k1, k2 = jax.random.split(key)
    p: Dict = {"ln1": init_rmsnorm(cfg.d_model)}
    if spec.mixer == "attn":
        p["mixer"] = (mla_mod.init_mla(k1, cfg) if _uses_mla(cfg)
                      else attn_mod.init_attention(k1, cfg))
    else:
        p["mixer"] = mamba_mod.init_mamba(k1, cfg)
    if spec.ffn != "none":
        p["ln2"] = init_rmsnorm(cfg.d_model)
        if spec.ffn == "dense":
            p["ffn"] = init_mlp(k2, cfg.d_model, cfg.d_ff)
        else:
            p["ffn"] = moe_mod.init_moe(k2, cfg)
    return p


def init_block_cache(cfg: ArchConfig, spec: LayerSpec, batch: int,
                     max_seq: int) -> Dict:
    if spec.mixer == "attn":
        if _uses_mla(cfg):
            return mla_mod.init_mla_cache(cfg, batch, max_seq)
        return attn_mod.init_attention_cache(cfg, batch, max_seq)
    return mamba_mod.init_mamba_cache(cfg, batch)


def block_cache_axes(cfg: ArchConfig, spec: LayerSpec) -> Dict:
    if spec.mixer == "attn":
        if _uses_mla(cfg):
            return dict(mla_mod.MLA_CACHE_AXES)
        return dict(attn_mod.CACHE_AXES)
    return dict(mamba_mod.MAMBA_CACHE_AXES)


def apply_block(
    p: Dict,
    x: jnp.ndarray,
    cfg: ArchConfig,
    spec: LayerSpec,
    rt: Runtime,
    *,
    mode: str,  # "train" | "prefill"
    kv_lens: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, Optional[Dict], jnp.ndarray]:
    """Returns (x, cache-or-None, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if spec.mixer == "attn":
        if _uses_mla(cfg):
            y, cache = mla_mod.apply_mla(
                p["mixer"], h, cfg, mode=mode, kv_lens=kv_lens,
                constrain_fn=rt.constrain_fn, block_q=rt.block_q,
                block_k=rt.block_k)
        else:
            y, cache = attn_mod.apply_attention(
                p["mixer"], h, cfg, mode=mode, kv_lens=kv_lens,
                constrain_fn=rt.constrain_fn, block_q=rt.block_q,
                block_k=rt.block_k)
    else:
        y, cache = mamba_mod.apply_mamba(
            p["mixer"], h, cfg, mode=mode, constrain_fn=rt.constrain_fn,
            scan_chunk=rt.scan_chunk)
    x = x + y
    x = rt.constrain(x, ("batch", "seq", "act_embed")) if rt.rules else x
    if spec.ffn != "none":
        h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
        if spec.ffn == "dense":
            y2 = apply_mlp(p["ffn"], h2, cfg.dtype, rt.constrain_fn)
        else:
            y2, aux = moe_mod.apply_moe(
                p["ffn"], h2, cfg, train=(mode == "train"), mesh=rt.mesh,
                rules=rt.rules)
        x = x + y2
    return x, cache, aux


def apply_block_decode_paged(
    p: Dict,
    x: jnp.ndarray,  # (B, 1, d)
    cfg: ArchConfig,
    spec: LayerSpec,
    rt: Runtime,
    cache: Dict,
    lengths: jnp.ndarray,
    page_tables: jnp.ndarray,  # (B, pages_per_seq) physical page ids
) -> Tuple[jnp.ndarray, Dict]:
    """Decode step against a paged cache: attention/MLA leaves are page-major
    ((n_pages, ..., page_size, ...)); mamba state leaves are slot-major and
    use the regular decode path unchanged."""
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if spec.mixer == "attn":
        if _uses_mla(cfg):
            y, new_cache = mla_mod.apply_mla_decode_paged(
                p["mixer"], h, cfg, cache, lengths, page_tables,
                page_size=rt.page_size, absorb=rt.mla_absorb,
                paged_impl=rt.paged_impl,
                pages_per_program=rt.pages_per_program,
                interpret=rt.interpret)
        else:
            y, new_cache = attn_mod.apply_attention_decode_paged(
                p["mixer"], h, cfg, cache, lengths, page_tables,
                page_size=rt.page_size, paged_impl=rt.paged_impl,
                pages_per_program=rt.pages_per_program,
                interpret=rt.interpret)
    else:
        y, new_cache = mamba_mod.apply_mamba_decode(
            p["mixer"], h, cfg, cache, constrain_fn=rt.constrain_fn)
    x = x + y
    if spec.ffn != "none":
        h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
        if spec.ffn == "dense":
            y2 = apply_mlp(p["ffn"], h2, cfg.dtype, rt.constrain_fn)
        else:
            y2, _ = moe_mod.apply_moe(
                p["ffn"], h2, cfg, train=False, mesh=rt.mesh, rules=rt.rules)
        x = x + y2
    return x, new_cache


def apply_block_prefill_paged(
    p: Dict,
    x: jnp.ndarray,  # (1, C, d) one prompt chunk
    cfg: ArchConfig,
    spec: LayerSpec,
    rt: Runtime,
    cache: Dict,
    n_valid: jnp.ndarray,  # () valid tokens in this chunk
    page_tables: jnp.ndarray,  # (1, pages_per_seq)
    *,
    s0: int,  # static absolute position of the chunk's first token
) -> Tuple[jnp.ndarray, Dict]:
    """Chunked-prefill step against a paged cache.  Attention-only archs:
    mamba's slot-major recurrent state has no paged/positional form, so the
    engine gates chunked prefill to attn mixers (see ServeEngine)."""
    if spec.mixer != "attn":
        raise NotImplementedError(
            "chunked paged prefill supports attn mixers only")
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if _uses_mla(cfg):
        y, new_cache = mla_mod.apply_mla_prefill_paged(
            p["mixer"], h, cfg, cache, n_valid, page_tables,
            s0=s0, page_size=rt.page_size, block_q=rt.block_q,
            block_k=rt.block_k)
    else:
        y, new_cache = attn_mod.apply_attention_prefill_paged(
            p["mixer"], h, cfg, cache, n_valid, page_tables,
            s0=s0, page_size=rt.page_size, block_q=rt.block_q,
            block_k=rt.block_k)
    x = x + y
    if spec.ffn != "none":
        h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
        if spec.ffn == "dense":
            y2 = apply_mlp(p["ffn"], h2, cfg.dtype, rt.constrain_fn)
        else:
            y2, _ = moe_mod.apply_moe(
                p["ffn"], h2, cfg, train=False, mesh=rt.mesh, rules=rt.rules)
        x = x + y2
    return x, new_cache


def apply_block_decode(
    p: Dict,
    x: jnp.ndarray,  # (B, 1, d)
    cfg: ArchConfig,
    spec: LayerSpec,
    rt: Runtime,
    cache: Dict,
    lengths: jnp.ndarray,
) -> Tuple[jnp.ndarray, Dict]:
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if spec.mixer == "attn":
        if _uses_mla(cfg):
            y, new_cache = mla_mod.apply_mla_decode(
                p["mixer"], h, cfg, cache, lengths, absorb=rt.mla_absorb,
                constrain_fn=rt.constrain_fn)
        else:
            y, new_cache = attn_mod.apply_attention_decode(
                p["mixer"], h, cfg, cache, lengths,
                constrain_fn=rt.constrain_fn)
    else:
        y, new_cache = mamba_mod.apply_mamba_decode(
            p["mixer"], h, cfg, cache, constrain_fn=rt.constrain_fn)
    x = x + y
    if spec.ffn != "none":
        h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
        if spec.ffn == "dense":
            y2 = apply_mlp(p["ffn"], h2, cfg.dtype, rt.constrain_fn)
        else:
            y2, _ = moe_mod.apply_moe(
                p["ffn"], h2, cfg, train=False, mesh=rt.mesh, rules=rt.rules)
        x = x + y2
    return x, new_cache
