"""Continuous-batching request scheduler.

Keeps a FIFO admission queue and a fixed set of ``max_batch`` decode slots.
Requests join the running decode batch the moment a slot and enough pages
are available (*join-on-arrival*) and release their slot and pages the step
they finish (*evict-on-finish*) — the decode batch never drains and restarts.
Time is measured in decode steps, which keeps traces deterministic and
testable.

The scheduler owns all page accounting (allocation, prefix sharing, freeing);
the engine owns the tensors.  Idle slots keep page table rows pointing at the
scratch page and ``length = 0`` so the fixed-shape batched decode step stays
legal regardless of occupancy.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Any, List, Optional

import numpy as np

from repro.serve.paging import PagePool
from repro.serve.prefix import PrefixCache


class RequestState(enum.Enum):
    QUEUED = "queued"
    PREFILLING = "prefilling"  # admitted; prompt entering pages chunk by chunk
    RUNNING = "running"
    FINISHED = "finished"


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (P,) int32
    max_new_tokens: int
    arrival_step: int = 0
    frontend_embeds: Optional[np.ndarray] = None  # (F, d) float32
    # -- filled in by the scheduler / engine -------------------------------
    state: RequestState = RequestState.QUEUED
    slot: int = -1
    page_ids: List[int] = dataclasses.field(default_factory=list)
    n_shared_pages: int = 0
    prefill_skipped: bool = False
    full_entry: Any = None  # FullPromptEntry backing a skipped prefill
    generated: List[int] = dataclasses.field(default_factory=list)
    logits_trace: Optional[List[np.ndarray]] = None
    admitted_step: int = -1
    finished_step: int = -1
    prefill_s: float = 0.0
    prefill_pos: int = 0  # next absolute position to prefill (chunked path)
    first_token_step: int = -1  # step the first token was emitted

    @property
    def done(self) -> bool:
        return len(self.generated) >= self.max_new_tokens


class Scheduler:
    def __init__(
        self,
        max_batch: int,
        pool: PagePool,
        prefix_cache: Optional[PrefixCache] = None,
        n_frontend_tokens: int = 0,
        prefill_chunk: Optional[int] = None,
    ):
        if prefill_chunk is not None and prefill_chunk < 1:
            raise ValueError(
                f"prefill_chunk must be a positive token budget, "
                f"got {prefill_chunk}"
            )
        self.max_batch = max_batch
        self.pool = pool
        self.prefix = prefix_cache
        self.n_frontend_tokens = n_frontend_tokens
        self.prefill_chunk = prefill_chunk
        self.queue: List[Request] = []
        self.slots: List[Optional[Request]] = [None] * max_batch
        self.finished: List[Request] = []
        # optional SpanTracer (set by the owning engine when tracing is on):
        # admissions emit scheduler.join spans carrying the queue wait,
        # page accounting emits pages.alloc / pages.evict spans
        self.tracer: Optional[Any] = None

    # ------------------------------------------------------------------
    def submit(self, req: Request) -> None:
        # admission backpressure: a request whose lifetime can never fit in
        # the pool must be rejected up front — queueing it would deadlock the
        # FIFO head forever (pages free up, but never enough).
        need = self.pool.pages_for(self.total_tokens(req))
        if need > self.pool.num_pages - 1:  # scratch page is pinned
            raise ValueError(
                f"request rid={req.rid} needs {need} pages but the pool only "
                f"has {self.pool.num_pages - 1} allocatable pages; it can "
                f"never be admitted"
            )
        self.queue.append(req)
        self.queue.sort(key=lambda r: (r.arrival_step, r.rid))

    @property
    def active(self) -> List[Request]:
        return [r for r in self.slots if r is not None]

    @property
    def decoding(self) -> List[Request]:
        """Slots contributing a token to this step's decode batch."""
        return [r for r in self.slots
                if r is not None and r.state is RequestState.RUNNING]

    @property
    def prefilling(self) -> List[Request]:
        """Admitted requests still streaming their prompt in, FIFO."""
        reqs = [r for r in self.slots
                if r is not None and r.state is RequestState.PREFILLING]
        return sorted(reqs, key=lambda r: (r.admitted_step, r.rid))

    # ------------------------------------------------------------------
    def plan_prefill(self) -> List[tuple]:
        """Token-budget plan for this step's chunked prefill work: FIFO over
        PREFILLING requests, each assignment ``(req, n_tokens)`` consumes up
        to one chunk (``prefill_chunk`` positions) and the step's total
        assigned tokens never exceed the ``prefill_chunk`` budget — prefill
        progress shares the step with the running decode batch instead of
        stalling it for a whole prompt."""
        if self.prefill_chunk is None:
            return []
        budget = self.prefill_chunk
        plan: List[tuple] = []
        for req in self.prefilling:
            if budget <= 0:
                break
            remaining = len(req.prompt) - req.prefill_pos
            take = min(remaining, self.prefill_chunk, budget)
            if take > 0:
                plan.append((req, take))
                budget -= take
        return plan

    @property
    def drained(self) -> bool:
        return not self.queue and all(s is None for s in self.slots)

    @property
    def pending_tokens(self) -> int:
        """Outstanding work in cache positions: unprefilled prompt tokens
        plus remaining generation, summed over queued and active requests.
        The router's load signal — comparable across replicas because it is
        denominated in decode-step work, not request counts."""
        total = 0
        for req in self.queue:
            total += self.total_tokens(req)
        for req in self.slots:
            if req is None:
                continue
            if req.state is RequestState.PREFILLING:
                total += len(req.prompt) - req.prefill_pos
            total += req.max_new_tokens - len(req.generated)
        return total

    def total_tokens(self, req: Request) -> int:
        """Cache positions this request may occupy over its lifetime.
        Frontend tokens occupy positions only when embeddings are supplied."""
        n_front = self.n_frontend_tokens if req.frontend_embeds is not None else 0
        return len(req.prompt) + n_front + req.max_new_tokens

    # ------------------------------------------------------------------
    def admit_ready(self, now: int) -> List[Request]:
        """Admit arrived requests (FIFO) while slots and pages last.  Returns
        the newly admitted requests with slot and page_ids assigned; the
        engine must then prefill them and write their pages."""
        admitted: List[Request] = []
        while self.queue and self.queue[0].arrival_step <= now:
            free_slots = [i for i, s in enumerate(self.slots) if s is None]
            if not free_slots:
                break
            req = self.queue[0]
            if not self._allocate(req):
                break  # head-of-line blocks until pages free up
            self.queue.pop(0)
            req.slot = free_slots[0]
            req.state = RequestState.RUNNING
            req.admitted_step = now
            self.slots[req.slot] = req
            admitted.append(req)
            if self.tracer is not None:
                # queue wait is denominated in engine steps (the scheduler
                # clock), not wall seconds, so it rides as an attr on a
                # zero-duration join marker; the SLO monitor reads it as
                # the join-to-first-token objective's input
                self.tracer.emit_span(
                    "join",
                    dur=0.0,
                    step=now,
                    component="scheduler.join",
                    rid=req.rid,
                    slot=req.slot,
                    wait_steps=now - req.arrival_step,
                    shared_pages=req.n_shared_pages,
                )
        return admitted

    def _allocate(self, req: Request) -> bool:
        """Reserve pages for the request's whole lifetime (prompt + frontend
        + max_new_tokens), reusing shared prefix pages where possible."""
        if self.tracer is not None:
            with self.tracer.span(
                "page_alloc", component="pages.alloc", rid=req.rid
            ) as h:
                ok = self._allocate_inner(req)
                h.set(ok=ok, pages=len(req.page_ids), shared=req.n_shared_pages)
            return ok
        return self._allocate_inner(req)

    def _allocate_inner(self, req: Request) -> bool:
        shared: List[int] = []
        use_prefix = self.prefix is not None and req.frontend_embeds is None
        if use_prefix:
            entry = self.prefix.match_full(req.prompt, self.pool)
            if entry is not None:
                shared = list(entry.page_ids)
                req.prefill_skipped = True
                req.full_entry = entry
            else:
                shared = self.prefix.match(req.prompt, self.pool)
        need = self.pool.pages_for(self.total_tokens(req)) - len(shared)
        if need > self.pool.free_pages and self.prefix is not None:
            self.prefix.release_lru(self.pool, min_free=need)
        if need > self.pool.free_pages:
            if shared:
                self.pool.free(shared)
            req.prefill_skipped = False
            req.full_entry = None
            return False
        req.page_ids = shared + self.pool.alloc(need)
        req.n_shared_pages = len(shared)
        if shared:
            self.prefix.hits += 1
            self.prefix.pages_shared += len(shared)
        if req.prefill_skipped:
            self.prefix.prefills_skipped += 1
        return True

    # ------------------------------------------------------------------
    def finish(self, req: Request, now: int) -> None:
        """Evict-on-finish: release the slot and all page references."""
        req.state = RequestState.FINISHED
        req.finished_step = now
        self.slots[req.slot] = None
        if self.tracer is not None:
            with self.tracer.span(
                "page_evict",
                step=now,
                component="pages.evict",
                rid=req.rid,
                pages=len(req.page_ids),
            ):
                self.pool.free(req.page_ids)
        else:
            self.pool.free(req.page_ids)
        req.page_ids = []
        self.finished.append(req)
