"""Hemingway capacity planning for the serving fleet.

Hemingway picks the algorithm and cluster size m from a fitted system model
f(m) (paper §3.2.1; Ernest, NSDI'16).  Serving is the same shaped problem:
the per-step decode latency is a smooth function of the batching operating
point b, and fleet capacity is a function of the replica count m.  This
module fits two ``ErnestModel`` instances on serve telemetry —

* ``step_model``: decode step seconds vs. active batch b, terms
  ``theta0 + theta1*b + theta2*log b`` (dispatch floor + per-sequence work +
  batching sublinearity), fitted by the same NNLS as training f(m);
* a fleet overhead term ``log m`` models load-balancer fan-out when
  extrapolating one replica's throughput to m replicas —

and answers the serving versions of the paper's two queries:

* ``plan`` (fastest-to-epsilon analogue): minimum replica count m and
  max-batch b that sustain a target QPS within a p50 latency SLO;
* ``best_latency_within_fleet`` (best-within-budget analogue): the lowest
  achievable p50 given a fixed fleet of m replicas.

Decisions are returned as ``repro.core.hemingway.PlanDecision`` records with
``algorithm = "continuous@b<batch>"`` so the serve planner composes with the
training planner's reporting.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.ernest import ErnestModel
from repro.core.hemingway import NoFeasiblePlan, PlanDecision, PlanResult

STEP_TERMS: Tuple[str, ...] = ("const", "m", "log_m")


def decision_batch(decision: PlanDecision) -> int:
    """Recover the batch operating point from a capacity ``PlanDecision``.

    Single point of truth for the ``continuous@b<batch>`` algorithm-label
    format ``plan``/``best_latency_within_fleet`` emit — consumers (the
    fleet simulator above all) must not parse the label themselves."""
    return int(decision.algorithm.rsplit("@b", 1)[1])


@dataclasses.dataclass
class ServeObservation:
    batch: int
    step_s: float


class CapacityPlanner:
    def __init__(self, fleet_overhead_s_per_log_m: float = 0.0):
        self.observations: List[ServeObservation] = []
        self.step_model = ErnestModel(term_names=STEP_TERMS)
        self.fleet_overhead = fleet_overhead_s_per_log_m
        # speculative-decode acceptance: tokens committed per occupied slot
        # per step (1.0 = plain one-token decode).  Measured, not assumed —
        # the engine's verify telemetry carries the committed counts.
        self._committed_tokens = 0.0
        self._slot_steps = 0.0
        # chunked-prefill throughput (tokens/s across chunk calls)
        self._prefill_tokens = 0.0
        self._prefill_s = 0.0
        # per-replica accounting from a routed (multi-engine) deployment:
        # replica index -> accumulators.  Populated by replica-tagged
        # serve_step rows (replica >= 0) and router dispatch events.
        self._replica: Dict[int, Dict[str, float]] = {}
        self._router_dispatches = 0
        self._router_hits = 0
        self._router_routable = 0
        self._router_spills = 0
        # SLO burn-rate alerts from trace.slo.SLOMonitor: an early-warning
        # signal that the live system is missing its objectives *before*
        # the drift detector accumulates enough residuals to fire.
        self._slo_alerts: List = []

    def _replica_acc(self, idx: int) -> Dict[str, float]:
        return self._replica.setdefault(
            idx,
            {
                "decode_tokens": 0.0,
                "busy_s": 0.0,
                "dispatches": 0.0,
                "affinity_hits": 0.0,
                "spills": 0.0,
            },
        )

    # ------------------------------------------------------------------
    def observe(self, batch: int, step_s: float) -> None:
        self.observations.append(ServeObservation(int(batch), float(step_s)))

    def ingest(self, events, *, n_layers: int = 1, overhead_s: float = 0.0) -> int:
        """THE telemetry entrypoint: feed typed bus events, dispatch on kind.

        * ``serve_step`` — decode and draft-verify steps feed the f(b) step
          model plus the measured accepted-tokens-per-slot-step multiplier;
          chunked-prefill steps feed the prefill throughput estimate.
        * ``tune`` — autotuner results for the paged decode kernel seed the
          step model from measured kernel timings: one decode step is
          approximated as ``n_layers * kernel + overhead_s``.
        * ``slo_alert`` — burn-rate alerts from the SLO monitor are kept
          (``slo_alerts`` / ``last_slo_alert_step``) so a planner refit can
          be triggered by budget burn before model drift is detectable.
        * ``router`` — dispatch decisions from a multi-replica router feed
          the affinity-hit rate and per-replica dispatch counts; combined
          with replica-tagged ``serve_step`` rows (``replica >= 0``) the
          planner measures each replica's *effective* throughput — a
          replica that mostly serves cold prompts decodes fewer tokens per
          busy second than an affinity-hot one.

        Other kinds are ignored, so an entire run log can be replayed in.
        Returns the number of events that contributed observations."""
        n = 0
        for ev in events:
            kind = getattr(ev, "kind", None)
            if kind == "serve_step":
                replica = int(getattr(ev, "replica", -1))
                if ev.op == "prefill":
                    self._prefill_tokens += float(ev.prefill_tokens)
                    self._prefill_s += float(ev.step_s)
                    n += 1
                elif ev.batch > 0:
                    self.observe(ev.batch, ev.step_s)
                    self._committed_tokens += float(ev.committed)
                    self._slot_steps += float(ev.batch)
                    if replica >= 0:
                        acc = self._replica_acc(replica)
                        acc["decode_tokens"] += float(ev.committed)
                        acc["busy_s"] += float(ev.step_s)
                    n += 1
            elif kind == "router":
                acc = self._replica_acc(int(ev.replica))
                acc["dispatches"] += 1
                self._router_dispatches += 1
                if ev.prompt_pages > 0:
                    self._router_routable += 1
                if ev.matched_pages > 0:
                    acc["affinity_hits"] += 1
                    self._router_hits += 1
                if ev.reason == "spill":
                    acc["spills"] += 1
                    self._router_spills += 1
                n += 1
            elif kind == "tune":
                if ev.family == "flash_decode_paged" and ev.shape.get("b", 0) > 0:
                    step_s = n_layers * ev.us_per_call * 1e-6 + overhead_s
                    self.observe(int(ev.shape["b"]), step_s)
                    n += 1
            elif kind == "slo_alert":
                self._slo_alerts.append(ev)
                n += 1
        return n

    # ------------------------------------------------------------------
    # SLO burn-rate alerts (trace.slo.SLOMonitor)
    # ------------------------------------------------------------------
    @property
    def slo_alerts(self) -> List:
        """Burn-rate alerts ingested so far, in arrival order."""
        return list(self._slo_alerts)

    @property
    def last_slo_alert_step(self) -> int:
        """Step of the most recent SLO alert (-1 when none ingested)."""
        if not self._slo_alerts:
            return -1
        return max(int(a.step) for a in self._slo_alerts)

    def observe_telemetry(self, telemetry: Sequence[Dict]) -> None:
        """Thin legacy wrapper over :meth:`ingest` for ``ServeEngine``
        row dicts ({batch, step_s, ...}).  Rows from pre-speculation
        engines (no ``kind`` key) are ingested as plain one-token decode
        steps."""
        from repro.telemetry import from_legacy

        self.ingest(from_legacy("serve_step", row) for row in telemetry)

    @property
    def accepted_per_slot_step(self) -> float:
        """Measured tokens committed per occupied slot per step (>= 1 with
        speculation accepting drafts; exactly 1 without)."""
        if not self._slot_steps:
            return 1.0
        return self._committed_tokens / self._slot_steps

    @property
    def prefill_tokens_per_s(self) -> float:
        """Measured chunked-prefill throughput (0.0 when never observed)."""
        if not self._prefill_s:
            return 0.0
        return self._prefill_tokens / self._prefill_s

    # ------------------------------------------------------------------
    # multi-replica (router) accounting
    # ------------------------------------------------------------------
    @property
    def router_dispatches(self) -> int:
        """Router dispatch decisions ingested so far (0 = no router ran)."""
        return self._router_dispatches

    @property
    def affinity_hit_rate(self) -> float:
        """Fraction of *routable* dispatches (>= 1 full prompt page) that
        landed on a replica already holding cached prefix pages."""
        if not self._router_routable:
            return 0.0
        return self._router_hits / self._router_routable

    def replica_stats(self) -> Dict[int, Dict[str, float]]:
        """Per-replica measured accounting: dispatches, affinity hits,
        spills, decode tokens, busy seconds, and tokens/busy-second."""
        out: Dict[int, Dict[str, float]] = {}
        for idx in sorted(self._replica):
            acc = dict(self._replica[idx])
            busy = acc["busy_s"]
            acc["tok_per_s"] = acc["decode_tokens"] / busy if busy else 0.0
            out[idx] = acc
        return out

    def measured_effective_replicas(self) -> float:
        """Effective replica count from measured per-replica throughput:
        each replica contributes its tokens/busy-second relative to the
        fastest one, so a fleet whose replicas all run affinity-hot counts
        ~N while a skewed fleet counts fewer.  The measured analogue of the
        fractional ``m`` accepted by :meth:`tokens_per_s`; 0.0 until
        replica-tagged rows have been ingested."""
        rates = [s["tok_per_s"] for s in self.replica_stats().values()]
        peak = max(rates, default=0.0)
        if peak <= 0.0:
            return 0.0
        return sum(r / peak for r in rates)

    def observe_tuned_kernels(
        self, rows: Sequence[Dict], *, n_layers: int = 1, overhead_s: float = 0.0
    ) -> int:
        """Thin legacy wrapper over :meth:`ingest` for
        ``repro.kernels.tune.decode_step_rows`` dicts ({batch, step_s}):
        each row becomes a ``tune`` event for the paged decode kernel.
        Returns the number of rows ingested."""
        from repro.telemetry import TuneEvent

        return self.ingest(
            (
                TuneEvent(
                    family="flash_decode_paged",
                    shape={"b": int(row["batch"])},
                    dtype="",
                    backend="",
                    config={},
                    us_per_call=float(row["step_s"]) * 1e6,
                )
                for row in rows
                if row["batch"] > 0
            ),
            n_layers=n_layers,
            overhead_s=overhead_s,
        )

    def fit(self) -> "CapacityPlanner":
        if len({o.batch for o in self.observations}) < 2:
            raise ValueError("need observations at >= 2 distinct batch sizes")
        b = np.asarray([o.batch for o in self.observations], np.float64)
        t = np.asarray([o.step_s for o in self.observations], np.float64)
        self.step_model.fit(b, np.ones_like(b), t)
        return self

    # ------------------------------------------------------------------
    def step_time(self, batch: int) -> float:
        return float(self.step_model.predict(float(batch), 1.0))

    def tokens_per_s(self, batch: int, m: float = 1) -> float:
        """Fleet decode throughput at operating point (b, m).  ``m`` may be
        fractional: the fleet simulator models degraded replicas (stragglers,
        cluster slowdowns) as an effective replica count.  The measured
        speculative-acceptance multiplier scales per-step tokens: a step
        commits ``batch * accepted_per_slot_step`` tokens, not ``batch``."""
        t = self.step_time(batch) + self.fleet_overhead * np.log(m + 1.0)
        return m * batch * self.accepted_per_slot_step / t

    def p50_latency_s(self, batch: int, gen_tokens: int, m: float = 1) -> float:
        """Per-request latency to decode ``gen_tokens`` at full batch b
        (``gen_tokens / accepted_per_slot_step`` steps with speculation)."""
        t = self.step_time(batch) + self.fleet_overhead * np.log(m + 1.0)
        return gen_tokens / self.accepted_per_slot_step * t

    # ------------------------------------------------------------------
    def plan(
        self,
        *,
        target_p50_s: float,
        qps: float,
        gen_tokens: int,
        batch_grid: Sequence[int],
        m_grid: Sequence[int],
    ) -> PlanResult:
        """Smallest fleet (m, then b) sustaining ``qps`` requests/s of
        ``gen_tokens``-token responses with p50 <= ``target_p50_s``."""
        table: Dict[Tuple[str, int], float] = {}
        best: Optional[PlanDecision] = None
        for m in sorted(int(x) for x in m_grid):
            for b in sorted(int(x) for x in batch_grid):
                lat = self.p50_latency_s(b, gen_tokens, m)
                cap_qps = self.tokens_per_s(b, m) / gen_tokens
                table[(f"continuous@b{b}", m)] = lat
                feasible = lat <= target_p50_s and cap_qps >= qps
                if feasible and best is None:
                    best = PlanDecision(f"continuous@b{b}", m, predicted_time=lat)
        if best is None:
            return NoFeasiblePlan(
                query="capacity_plan",
                reason=(
                    f"no (m, batch) meets p50<={target_p50_s}s at {qps} qps "
                    f"(m_grid={sorted(int(x) for x in m_grid)}, "
                    f"batch_grid={sorted(int(x) for x in batch_grid)})"
                ),
                table=table,
            )
        best.table = table
        return best

    def best_latency_within_fleet(
        self,
        *,
        m: int,
        qps: float,
        gen_tokens: int,
        batch_grid: Sequence[int],
    ) -> PlanResult:
        """Best-within-budget analogue: lowest p50 a fixed fleet of ``m``
        replicas can offer while still sustaining ``qps``."""
        table: Dict[Tuple[str, int], float] = {}
        best: Optional[PlanDecision] = None
        for b in sorted(int(x) for x in batch_grid):
            lat = self.p50_latency_s(b, gen_tokens, m)
            cap_qps = self.tokens_per_s(b, m) / gen_tokens
            table[(f"continuous@b{b}", m)] = lat
            if cap_qps < qps:
                continue
            if best is None or lat < best.predicted_time:
                best = PlanDecision(f"continuous@b{b}", m, predicted_time=lat)
        if best is None:
            return NoFeasiblePlan(
                query="best_latency_within_fleet",
                reason=(
                    f"fleet of m={m} cannot sustain {qps} qps at any "
                    f"batch in {sorted(int(x) for x in batch_grid)}"
                ),
                table=table,
            )
        best.table = table
        return best
