"""Fixed-size page allocator for the paged KV/state cache.

A *page* is ``page_size`` consecutive sequence positions of every attention
(or MLA latent) layer's cache at once — one physical page id indexes each
layer's page array, so a request carries a single page table shared by all
layers (vLLM-style).  Pages are reference counted: prefix sharing and the
prefix cache hold extra references, and a page returns to the free list only
when its count reaches zero.

Page 0 is reserved as the *scratch* page: idle decode slots point their page
tables at it so the batched decode step always has a legal write target.  It
is never allocated and never counted as in use.
"""

from __future__ import annotations

from collections import deque
from typing import Iterable, List

SCRATCH_PAGE = 0


class OutOfPages(RuntimeError):
    """Raised when an allocation cannot be satisfied from the free list."""


class PagePool:
    """Free-list allocator with reference counting over ``num_pages`` pages."""

    def __init__(self, num_pages: int, page_size: int):
        if num_pages < 2:
            raise ValueError("need at least one page beyond the scratch page")
        self.num_pages = num_pages
        self.page_size = page_size
        self._free = deque(range(1, num_pages))
        self._refcount = [0] * num_pages
        self._refcount[SCRATCH_PAGE] = 1  # pinned forever

    # ------------------------------------------------------------------
    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def pages_in_use(self) -> int:
        """Pages with a live reference, excluding the pinned scratch page."""
        return sum(1 for i, c in enumerate(self._refcount) if c > 0) - 1

    def refcount(self, page: int) -> int:
        return self._refcount[page]

    # ------------------------------------------------------------------
    def alloc(self, n: int) -> List[int]:
        """Allocate ``n`` fresh pages (refcount 1 each)."""
        if n > len(self._free):
            raise OutOfPages(f"need {n} pages, {len(self._free)} free")
        pages = [self._free.popleft() for _ in range(n)]
        for p in pages:
            self._refcount[p] = 1
        return pages

    def share(self, pages: Iterable[int]) -> None:
        """Take an extra reference on already-allocated pages."""
        for p in pages:
            if p == SCRATCH_PAGE:
                raise ValueError("cannot share the scratch page")
            if self._refcount[p] == 0:
                raise ValueError(f"page {p} is not allocated")
            self._refcount[p] += 1

    def free(self, pages: Iterable[int]) -> None:
        """Drop one reference per page; pages hitting zero become reusable."""
        for p in pages:
            if p == SCRATCH_PAGE:
                raise ValueError("cannot free the scratch page")
            if self._refcount[p] <= 0:
                raise ValueError(f"double free of page {p}")
            self._refcount[p] -= 1
            if self._refcount[p] == 0:
                self._free.append(p)

    # ------------------------------------------------------------------
    def pages_for(self, n_tokens: int) -> int:
        """Number of pages covering ``n_tokens`` positions."""
        return -(-n_tokens // self.page_size)
