"""Continuous-batching serve engine over the paged KV/state cache.

One fixed-shape jitted decode step serves every request: each decode slot
contributes one token per step, idle slots point at the scratch page, and
requests join (after a batch-1 prefill writes their pages) or leave between
steps without draining the batch.  Greedy decoding only.

Time is measured in decode steps; a request's ``arrival_step`` gates its
admission, which keeps traces deterministic.  Per-step telemetry
``(active_batch, step_seconds)`` feeds the ``CapacityPlanner``
(``repro.serve.planner``) — the serve-side analogue of the training f(m)
loop.

Determinism notes: with a dense architecture every slot's computation is
independent of the other slots' contents, so a request's token trajectory is
bit-identical whether it runs alone or joins a busy batch of the same shape
(``max_batch`` and page geometry fixed).  MoE architectures couple slots
through expert capacity and do not carry this guarantee.
"""

from __future__ import annotations

import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.models.model import LM
from repro.models.runtime import Runtime
from repro.serve.cache import (
    init_paged_cache,
    max_pages_per_seq,
    restore_state,
    snapshot_state,
    write_prefill,
)
from repro.serve.paging import SCRATCH_PAGE, PagePool
from repro.serve.prefix import PrefixCache
from repro.serve.scheduler import Request, Scheduler


class ServeEngine:
    def __init__(
        self,
        arch: str,
        *,
        smoke: bool = True,
        max_batch: int = 8,
        page_size: int = 16,
        max_seq: int = 256,
        num_pages: Optional[int] = None,
        seed: int = 0,
        prefix_caching: bool = True,
        collect_logits: bool = False,
        rt: Optional[Runtime] = None,
        paged_impl: Optional[str] = None,
    ):
        self.cfg = self.config_for(arch, smoke)
        self.seed = seed
        # block_q = block_k = 16 pins the flash-attention blocking: the
        # kernel clamps blocks to min(block, max(seq, 16)), so 16 is the one
        # setting whose block grid never depends on prompt length.  That
        # makes prefix-position activations — and therefore shared prefix
        # pages — bitwise independent of what follows them, which is what
        # lets prefix reuse skip rewriting shared pages (see write_prefill).
        # paged_impl picks the decode-attention implementation ("stream" =
        # paged-native, "pallas" = TPU kernel, "gather" = legacy oracle);
        # stream/gather are bit-identical, so prefix guarantees hold under
        # any.  When both rt and paged_impl are given, paged_impl wins (an
        # explicitly requested implementation must not be silently ignored).
        self.rt = rt or Runtime(
            remat="none",
            block_q=16,
            block_k=16,
            scan_chunk=32,
            page_size=page_size,
            paged_impl=paged_impl or "stream",
        )
        if paged_impl is not None and self.rt.paged_impl != paged_impl:
            import dataclasses

            self.rt = dataclasses.replace(self.rt, paged_impl=paged_impl)
        if self.rt.page_size != page_size:
            raise ValueError("Runtime.page_size must match engine page_size")
        self.lm = LM(self.cfg, self.rt)
        self.params, _ = self.lm.init(jax.random.PRNGKey(seed))
        self.max_batch = max_batch
        self.page_size = page_size
        self.max_seq = max_seq
        self.pages_per_seq = max_pages_per_seq(max_seq, page_size)
        if num_pages is None:
            num_pages = 1 + max_batch * self.pages_per_seq
        self.pool = PagePool(num_pages, page_size)
        self.prefix = PrefixCache(page_size) if prefix_caching else None
        self.scheduler = Scheduler(
            max_batch,
            self.pool,
            prefix_cache=self.prefix,
            n_frontend_tokens=self.cfg.n_frontend_tokens,
        )
        self.collect_logits = collect_logits
        self.axes = self.lm.cache_axes()
        self.cache = init_paged_cache(
            self.lm,
            num_pages=num_pages,
            page_size=page_size,
            max_batch=max_batch,
        )
        self.page_tables = np.full(
            (max_batch, self.pages_per_seq), SCRATCH_PAGE, np.int32
        )
        # device-resident mirror of page_tables: rows only change on
        # join/evict, so we sync those rows in place instead of re-uploading
        # the whole host array every decode step
        self.page_tables_dev = jnp.asarray(self.page_tables)
        self.lengths = np.zeros(max_batch, np.int32)
        self.next_tokens = np.zeros(max_batch, np.int32)
        self._prefill = jax.jit(self.lm.prefill)
        self._decode = jax.jit(self.lm.decode_step_paged, donate_argnums=(3,))
        self.step_count = 0
        self._rid = 0
        self.telemetry: List[Dict] = []

    @staticmethod
    def config_for(arch: str, smoke: bool):
        return get_smoke_config(arch) if smoke else get_config(arch)

    # ------------------------------------------------------------------
    def submit(
        self,
        prompt: np.ndarray,
        max_new_tokens: int,
        arrival_step: int = 0,
        frontend_embeds: Optional[np.ndarray] = None,
    ) -> Request:
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        n_front = 0 if frontend_embeds is None else self.cfg.n_frontend_tokens
        total = len(prompt) + n_front + max_new_tokens
        if total > self.max_seq:
            raise ValueError(
                f"prompt+generation needs {total} positions > max_seq={self.max_seq}"
            )
        req = Request(
            rid=self._rid,
            prompt=prompt,
            max_new_tokens=max_new_tokens,
            arrival_step=arrival_step,
            frontend_embeds=frontend_embeds,
        )
        if self.collect_logits:
            req.logits_trace = []
        self._rid += 1
        self.scheduler.submit(req)
        return req

    # ------------------------------------------------------------------
    def _admit(self, req: Request) -> None:
        """Prefill (or reuse a stored prefill) and seed the decode slot."""
        slot = req.slot
        n_front = 0 if req.frontend_embeds is None else self.cfg.n_frontend_tokens
        if req.prefill_skipped:
            logits = req.full_entry.last_logits
            self.cache = restore_state(
                self.cache, req.full_entry.state, self.axes, slot
            )
        else:
            fe = (
                None
                if req.frontend_embeds is None
                else jnp.asarray(req.frontend_embeds)[None]
            )
            t0 = time.perf_counter()
            logits_dev, pre_cache = self._prefill(
                self.params, jnp.asarray(req.prompt)[None], fe
            )
            logits_dev.block_until_ready()
            req.prefill_s = time.perf_counter() - t0
            self.cache = write_prefill(
                self.cache,
                pre_cache,
                self.axes,
                slot=slot,
                page_ids=req.page_ids,
                page_size=self.page_size,
                skip_pages=req.n_shared_pages,
            )
            logits = np.asarray(logits_dev[0])
            if self.prefix is not None and req.frontend_embeds is None:
                n_prompt_pages = -(-len(req.prompt) // self.page_size)
                self.prefix.register(
                    req.prompt, req.page_ids[:n_prompt_pages], self.pool
                )
                self.prefix.register_full(
                    req.prompt,
                    req.page_ids[: len(req.prompt) // self.page_size],
                    logits,
                    snapshot_state(self.cache, self.axes, slot),
                    self.pool,
                )
        tok = int(np.argmax(logits))
        req.generated.append(tok)
        if req.logits_trace is not None:
            req.logits_trace.append(np.asarray(logits, np.float32).copy())
        self.lengths[slot] = len(req.prompt) + n_front
        row = np.full(self.pages_per_seq, SCRATCH_PAGE, np.int32)
        row[: len(req.page_ids)] = req.page_ids
        self.page_tables[slot] = row
        self.page_tables_dev = self.page_tables_dev.at[slot].set(jnp.asarray(row))
        self.next_tokens[slot] = tok

    def _release_slot(self, slot: int) -> None:
        self.lengths[slot] = 0
        self.next_tokens[slot] = 0
        self.page_tables[slot] = SCRATCH_PAGE
        self.page_tables_dev = self.page_tables_dev.at[slot].set(SCRATCH_PAGE)

    # ------------------------------------------------------------------
    def step(self) -> int:
        """Admit arrived requests, run one batched decode step, retire
        finished requests.  Returns the number of active requests served."""
        for req in self.scheduler.admit_ready(self.step_count):
            self._admit(req)
            if req.done:  # max_new_tokens == 1: prefill already finished it
                slot = req.slot
                self.scheduler.finish(req, self.step_count)
                self._release_slot(slot)
        active = self.scheduler.active
        if not active:
            self.step_count += 1
            return 0
        t0 = time.perf_counter()
        logits_dev, self.cache = self._decode(
            self.params,
            jnp.asarray(self.next_tokens),
            jnp.asarray(self.lengths),
            self.cache,
            self.page_tables_dev,
        )
        logits_np = np.asarray(logits_dev)
        dt = time.perf_counter() - t0
        self.telemetry.append(
            {"step": self.step_count, "batch": len(active), "step_s": dt}
        )
        for req in active:
            slot = req.slot
            tok = int(np.argmax(logits_np[slot]))
            req.generated.append(tok)
            if req.logits_trace is not None:
                req.logits_trace.append(logits_np[slot].astype(np.float32).copy())
            self.lengths[slot] += 1
            self.next_tokens[slot] = tok
            if req.done:
                slot_to_clear = req.slot
                self.scheduler.finish(req, self.step_count)
                self._release_slot(slot_to_clear)
        self.step_count += 1
        return len(active)

    def run(self, max_steps: int = 100_000) -> Dict:
        """Drive steps until every submitted request has finished."""
        while not self.scheduler.drained:
            if self.step_count >= max_steps:
                raise RuntimeError(f"trace did not drain in {max_steps} steps")
            self.step()
        return self.stats()

    # ------------------------------------------------------------------
    def stats(self) -> Dict:
        steps = [t for t in self.telemetry if t["batch"] > 0]
        tok = sum(t["batch"] for t in steps)
        busy = sum(t["step_s"] for t in steps)
        out: Dict = {
            "requests_finished": len(self.scheduler.finished),
            "decode_steps": len(steps),
            "decode_tokens": tok,
            "decode_tok_per_s": tok / busy if busy else 0.0,
            "mean_batch": tok / len(steps) if steps else 0.0,
            "pages_in_use": self.pool.pages_in_use,
            "free_pages": self.pool.free_pages,
        }
        if self.prefix is not None:
            out["prefix_hits"] = self.prefix.hits
            out["prefix_pages_shared"] = self.prefix.pages_shared
            out["prefills_skipped"] = self.prefix.prefills_skipped
        return out
