"""Continuous-batching serve engine over the paged KV/state cache.

One fixed-shape jitted decode step serves every request: each decode slot
contributes one token per step, idle slots point at the scratch page, and
requests join (after a prefill writes their pages) or leave between steps
without draining the batch.  Greedy decoding only.

Two optional step-loop extensions (attention-only archs; see DESIGN.md §11):

* **Chunked prefill** (``prefill_chunk=C``): prompts stream into their pages
  ``C`` tokens per engine step instead of one monolithic batch-1 prefill, so
  a burst of long prompts no longer stalls the running decode batch and
  join-to-first-token p99 is bounded by ``ceil(P/C)`` steps rather than one
  arbitrarily long prefill.  Each chunk is causally masked with a static
  ``q_offset`` so the final pages and logits are bitwise a monolithic
  prefill's.
* **Speculative multi-token decode** (``speculate=k``): an n-gram /
  prefix-cache proposer (``repro.serve.speculate``) drafts up to ``k``
  tokens per slot, verified by ONE batched target step over the paged pools
  (the decode jit retraced at ``max_batch*(k+1)`` folded rows).  The
  accept-longest-prefix rule commits exactly the tokens greedy one-at-a-time
  decode would emit — drafts change step count, never output bits.

Time is measured in decode steps; a request's ``arrival_step`` gates its
admission, which keeps traces deterministic.  Per-step telemetry
``(active_batch, step_seconds, kind, committed)`` feeds the
``CapacityPlanner`` (``repro.serve.planner``) — the serve-side analogue of
the training f(m) loop.

Determinism notes: with a dense architecture every slot's computation is
independent of the other slots' contents, so a request's token trajectory is
bit-identical whether it runs alone or joins a busy batch of the same shape
(``max_batch`` and page geometry fixed).  MoE eval is dropless (capacity =
tokens, see models/moe.py), so per-token expert outputs are independent of
the dispatch size and the guarantee extends to folded verify batches.
"""

from __future__ import annotations

import time
from contextlib import nullcontext
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.models.model import LM
from repro.models.runtime import Runtime
from repro.serve.cache import (
    init_paged_cache,
    max_pages_per_seq,
    restore_state,
    snapshot_state,
    write_prefill,
)
from repro.serve.paging import SCRATCH_PAGE, PagePool
from repro.serve.prefix import PrefixCache
from repro.serve.scheduler import Request, RequestState, Scheduler
from repro.serve.sharding import ShardingPlan
from repro.serve.speculate import NgramProposer
from repro.telemetry import (
    Event,
    MemorySink,
    ServeStepEvent,
    Tracker,
    warn_deprecated,
)
from repro.telemetry.trace import SpanTracer


class ServeEngine:
    def __init__(
        self,
        arch: str,
        *,
        smoke: bool = True,
        max_batch: int = 8,
        page_size: int = 16,
        max_seq: int = 256,
        num_pages: Optional[int] = None,
        seed: int = 0,
        prefix_caching: bool = True,
        collect_logits: bool = False,
        rt: Optional[Runtime] = None,
        paged_impl: Optional[str] = None,
        prefill_chunk: Optional[int] = None,
        speculate: int = 0,
        draft_ngram: int = 3,
        replica_id: int = -1,
        trace: bool = False,
        trace_clock: Optional[Callable[[], float]] = None,
    ):
        self.cfg = self.config_for(arch, smoke)
        if speculate < 0:
            raise ValueError(f"speculate must be >= 0, got {speculate}")
        if (prefill_chunk is not None or speculate) and any(
            spec.mixer != "attn" for spec in self.cfg.period
        ):
            raise ValueError(
                "chunked prefill / speculative decode require attention-only "
                f"architectures; {self.cfg.name} has recurrent-state layers "
                "whose slot-major cache has no paged/positional form"
            )
        self.seed = seed
        # block_q = block_k = 16 pins the flash-attention blocking: the
        # kernel clamps blocks to min(block, max(seq, 16)), so 16 is the one
        # setting whose block grid never depends on prompt length.  That
        # makes prefix-position activations — and therefore shared prefix
        # pages — bitwise independent of what follows them, which is what
        # lets prefix reuse skip rewriting shared pages (see write_prefill).
        # paged_impl picks the decode-attention implementation ("stream" =
        # paged-native, "pallas" = TPU kernel, "gather" = legacy oracle);
        # stream/gather are bit-identical, so prefix guarantees hold under
        # any.  When both rt and paged_impl are given, paged_impl wins (an
        # explicitly requested implementation must not be silently ignored).
        self.rt = rt or Runtime(
            remat="none",
            block_q=16,
            block_k=16,
            scan_chunk=32,
            page_size=page_size,
            paged_impl=paged_impl or "stream",
        )
        if paged_impl is not None and self.rt.paged_impl != paged_impl:
            import dataclasses

            self.rt = dataclasses.replace(self.rt, paged_impl=paged_impl)
        if self.rt.page_size != page_size:
            raise ValueError("Runtime.page_size must match engine page_size")
        self.lm = LM(self.cfg, self.rt)
        self.params, _ = self.lm.init(jax.random.PRNGKey(seed))
        self.max_batch = max_batch
        self.page_size = page_size
        self.max_seq = max_seq
        self.pages_per_seq = max_pages_per_seq(max_seq, page_size)
        if num_pages is None:
            num_pages = 1 + max_batch * self.pages_per_seq
        self.pool = PagePool(num_pages, page_size)
        self.prefix = PrefixCache(page_size) if prefix_caching else None
        self.prefill_chunk = prefill_chunk
        self.speculate = speculate
        self.proposer = (
            NgramProposer(draft_ngram, prefix_cache=self.prefix)
            if speculate
            else None
        )
        self.scheduler = Scheduler(
            max_batch,
            self.pool,
            prefix_cache=self.prefix,
            n_frontend_tokens=self.cfg.n_frontend_tokens,
            prefill_chunk=prefill_chunk,
        )
        self.collect_logits = collect_logits
        self.axes = self.lm.cache_axes()
        self.cache = init_paged_cache(
            self.lm,
            num_pages=num_pages,
            page_size=page_size,
            max_batch=max_batch,
        )
        self.page_tables = np.full(
            (max_batch, self.pages_per_seq), SCRATCH_PAGE, np.int32
        )
        # device-resident mirror of page_tables: rows only change on
        # join/evict, so we sync those rows in place instead of re-uploading
        # the whole host array every decode step
        self.page_tables_dev = jnp.asarray(self.page_tables)
        self.lengths = np.zeros(max_batch, np.int32)
        self.next_tokens = np.zeros(max_batch, np.int32)
        self._prefill = jax.jit(self.lm.prefill)
        self._decode = jax.jit(self.lm.decode_step_paged, donate_argnums=(3,))
        # chunk width is static (fixed jit shape); s0 is static too because
        # the flash q_offset feeds the compile-time causal mask — the jit
        # cache is keyed per distinct chunk start, a bounded set (multiples
        # of the chunk width offset by page-aligned shared-prefix starts)
        self._chunk = jax.jit(
            self.lm.prefill_chunk, static_argnames=("s0",), donate_argnums=(3,)
        )
        # sharded data plane (DESIGN.md §13): when the Runtime carries a
        # mesh, place params and the paged cache per the serving Rules and
        # replace the decode/chunk jits with explicitly-sharded ones.  The
        # host-side step loop is untouched — tokens/lengths/page tables are
        # replicated, and the eager cache writers (write_prefill,
        # restore_state) hand arrays back to the jit, whose in_shardings
        # re-pin them.
        # every step timing rides the telemetry bus as a ServeStepEvent;
        # the deprecated ``telemetry`` property reconstructs legacy rows
        self.tracker = Tracker([MemorySink()])
        self._t_s = 0.0
        # opt-in hierarchical span tracing (DESIGN.md §14): spans share the
        # engine bus, so events()/to_jsonl carry them alongside serve_step
        # rows.  IDs are deterministic (seed-derived); timestamps come from
        # trace_clock (default wall clock — inject CountingClock for
        # byte-identical trace files across same-seed runs).
        self.spans: Optional[SpanTracer] = (
            SpanTracer(
                self.tracker,
                trace=("serve", self.cfg.name, seed, replica_id),
                replica=replica_id,
                clock=trace_clock,
            )
            if trace
            else None
        )
        self.scheduler.tracer = self.spans
        self.plan = ShardingPlan.for_runtime(self.rt)
        if self.plan is not None:
            self.params = self.plan.shard_params(self.params, self.lm.param_axes())
            self.cache = self.plan.shard_cache(self.cache, self.axes)
            self.page_tables_dev = self.plan.put_replicated(self.page_tables_dev)
            self._decode = self.plan.decode_jit(
                self.lm, self.params, self.cache, tracer=self.spans
            )
            self._chunk = self.plan.prefill_chunk_jit(
                self.lm, self.params, self.cache, tracer=self.spans
            )
        self.step_count = 0
        self._rid = 0
        self.replica_id = replica_id

    @staticmethod
    def config_for(arch: str, smoke: bool):
        return get_smoke_config(arch) if smoke else get_config(arch)

    def _sp(self, name: str, **attrs):
        """Span scope when tracing is on, else a free no-op context."""
        if self.spans is None:
            return nullcontext()
        return self.spans.span(name, step=self.step_count, **attrs)

    # ------------------------------------------------------------------
    def submit(
        self,
        prompt: np.ndarray,
        max_new_tokens: int,
        arrival_step: int = 0,
        frontend_embeds: Optional[np.ndarray] = None,
    ) -> Request:
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        n_front = 0 if frontend_embeds is None else self.cfg.n_frontend_tokens
        total = len(prompt) + n_front + max_new_tokens
        if total > self.max_seq:
            raise ValueError(
                f"prompt+generation needs {total} positions > max_seq={self.max_seq}"
            )
        req = Request(
            rid=self._rid,
            prompt=prompt,
            max_new_tokens=max_new_tokens,
            arrival_step=arrival_step,
            frontend_embeds=frontend_embeds,
        )
        if self.collect_logits:
            req.logits_trace = []
        self._rid += 1
        self.scheduler.submit(req)
        return req

    # ------------------------------------------------------------------
    def _admit(self, req: Request) -> None:
        """Prefill (or reuse a stored prefill) and seed the decode slot."""
        slot = req.slot
        n_front = 0 if req.frontend_embeds is None else self.cfg.n_frontend_tokens
        if req.prefill_skipped:
            with self._sp(
                "prefill",
                component="engine.prefill",
                rid=req.rid,
                tokens=len(req.prompt),
                skipped=True,
            ):
                logits = req.full_entry.last_logits
                self.cache = restore_state(
                    self.cache, req.full_entry.state, self.axes, slot
                )
        else:
            fe = (
                None
                if req.frontend_embeds is None
                else jnp.asarray(req.frontend_embeds)[None]
            )
            t0 = time.perf_counter()
            with self._sp(
                "prefill",
                component="engine.prefill",
                rid=req.rid,
                tokens=len(req.prompt),
            ):
                logits_dev, pre_cache = self._prefill(
                    self.params, jnp.asarray(req.prompt)[None], fe
                )
                logits_dev.block_until_ready()
            req.prefill_s = time.perf_counter() - t0
            self.cache = write_prefill(
                self.cache,
                pre_cache,
                self.axes,
                slot=slot,
                page_ids=req.page_ids,
                page_size=self.page_size,
                skip_pages=req.n_shared_pages,
            )
            logits = np.asarray(logits_dev[0])
            if self.prefix is not None and req.frontend_embeds is None:
                n_prompt_pages = -(-len(req.prompt) // self.page_size)
                self.prefix.register(
                    req.prompt, req.page_ids[:n_prompt_pages], self.pool
                )
                self.prefix.register_full(
                    req.prompt,
                    req.page_ids[: len(req.prompt) // self.page_size],
                    logits,
                    snapshot_state(self.cache, self.axes, slot),
                    self.pool,
                )
        self._activate(req, logits, n_front)

    def _activate(self, req: Request, logits: np.ndarray, n_front: int) -> None:
        """Seed the first token from prefill logits and arm the decode slot."""
        slot = req.slot
        tok = int(np.argmax(logits))
        req.generated.append(tok)
        if req.logits_trace is not None:
            req.logits_trace.append(np.asarray(logits, np.float32).copy())
        req.state = RequestState.RUNNING
        req.first_token_step = self.step_count
        self.lengths[slot] = len(req.prompt) + n_front
        row = np.full(self.pages_per_seq, SCRATCH_PAGE, np.int32)
        row[: len(req.page_ids)] = req.page_ids
        self.page_tables[slot] = row
        self.page_tables_dev = self.page_tables_dev.at[slot].set(jnp.asarray(row))
        self.next_tokens[slot] = tok

    # ------------------------------------------------------------------
    def _use_chunked(self, req: Request) -> bool:
        """Chunked prefill applies when there is new prompt to stream in:
        skipped prefills are free, frontend embeds use the legacy path, and
        an all-shared prompt head falls back to the (cheap) full prefill so
        the last-token logits exist to seed decode."""
        return (
            self.prefill_chunk is not None
            and req.frontend_embeds is None
            and not req.prefill_skipped
            and req.n_shared_pages * self.page_size < len(req.prompt)
        )

    def _prefill_chunk_step(self, req: Request, n_tokens: int) -> None:
        """Run one chunk of ``req``'s prompt through the paged stack.  While
        PREFILLING the slot's host page-table row stays at SCRATCH (the slot
        is invisible to decode/verify); the real row is passed straight to
        the chunk jit.  The final chunk registers prefix pages and activates
        the slot."""
        slot = req.slot
        s0 = req.prefill_pos
        c = self.prefill_chunk
        chunk = np.zeros(c, np.int32)
        chunk[:n_tokens] = req.prompt[s0: s0 + n_tokens]
        row = np.full(self.pages_per_seq, SCRATCH_PAGE, np.int32)
        row[: len(req.page_ids)] = req.page_ids
        t0 = time.perf_counter()
        with self._sp(
            "prefill_chunk",
            component="engine.prefill_chunk",
            rid=req.rid,
            tokens=n_tokens,
            s0=s0,
        ):
            logits_dev, self.cache = self._chunk(
                self.params,
                jnp.asarray(chunk)[None],
                jnp.int32(n_tokens),
                self.cache,
                jnp.asarray(row)[None],
                s0=s0,
            )
            logits_dev.block_until_ready()
        dt = time.perf_counter() - t0
        req.prefill_s += dt
        req.prefill_pos += n_tokens
        self._emit("prefill", batch=0, step_s=dt, prefill_tokens=n_tokens)
        if req.prefill_pos >= len(req.prompt):
            logits = np.asarray(logits_dev[0, n_tokens - 1])
            if self.prefix is not None:
                n_prompt_pages = -(-len(req.prompt) // self.page_size)
                self.prefix.register(
                    req.prompt, req.page_ids[:n_prompt_pages], self.pool
                )
                self.prefix.register_full(
                    req.prompt,
                    req.page_ids[: len(req.prompt) // self.page_size],
                    logits,
                    snapshot_state(self.cache, self.axes, slot),
                    self.pool,
                )
            self._activate(req, logits, 0)

    def _release_slot(self, slot: int) -> None:
        self.lengths[slot] = 0
        self.next_tokens[slot] = 0
        self.page_tables[slot] = SCRATCH_PAGE
        self.page_tables_dev = self.page_tables_dev.at[slot].set(SCRATCH_PAGE)

    # ------------------------------------------------------------------
    def step(self) -> int:
        """One unified engine step: admit arrived requests, advance chunked
        prefill within its token budget, then run one batched decode (or
        draft-verify) step and retire finished requests.  Returns the number
        of requests that contributed decode tokens."""
        with self._sp("step", component="engine.step"):
            return self._step_inner()

    def _step_inner(self) -> int:
        for req in self.scheduler.admit_ready(self.step_count):
            if self._use_chunked(req):
                req.state = RequestState.PREFILLING
                req.prefill_pos = req.n_shared_pages * self.page_size
            else:
                self._admit(req)
                if req.done:  # max_new_tokens == 1: prefill already finished
                    slot = req.slot
                    self.scheduler.finish(req, self.step_count)
                    self._release_slot(slot)
        for req, take in self.scheduler.plan_prefill():
            self._prefill_chunk_step(req, take)
            if req.state is RequestState.RUNNING and req.done:
                slot = req.slot
                self.scheduler.finish(req, self.step_count)
                self._release_slot(slot)
        decoding = self.scheduler.decoding
        if not decoding:
            self.step_count += 1
            return 0
        drafts = self._propose_drafts(decoding) if self.speculate else None
        if drafts is not None:
            n = self._verify_step(decoding, drafts)
            self.step_count += 1
            return n
        t0 = time.perf_counter()
        with self._sp("decode", component="engine.decode", batch=len(decoding)):
            logits_dev, self.cache = self._decode(
                self.params,
                jnp.asarray(self.next_tokens),
                jnp.asarray(self.lengths),
                self.cache,
                self.page_tables_dev,
            )
            logits_np = np.asarray(logits_dev)
        dt = time.perf_counter() - t0
        self._emit(
            "decode", batch=len(decoding), step_s=dt, committed=len(decoding)
        )
        for req in decoding:
            slot = req.slot
            tok = int(np.argmax(logits_np[slot]))
            req.generated.append(tok)
            if req.logits_trace is not None:
                req.logits_trace.append(logits_np[slot].astype(np.float32).copy())
            self.lengths[slot] += 1
            self.next_tokens[slot] = tok
            if req.done:
                slot_to_clear = req.slot
                self.scheduler.finish(req, self.step_count)
                self._release_slot(slot_to_clear)
        self.step_count += 1
        return len(decoding)

    # ------------------------------------------------------------------
    def _propose_drafts(self, decoding) -> Optional[Dict[int, np.ndarray]]:
        """Draft tokens per slot (``None`` means run the plain decode step).
        Draft count is capped at ``remaining - 1`` so no speculative write
        lands past the position the baseline's final decode step would use.

        A verify step runs ``max_batch * (k+1)`` rows where plain decode
        runs ``max_batch`` — roughly a 2x wall premium at serving shapes —
        so sparse drafts lose even when they are right.  The step is only
        worth it when drafting is dense (every slot deep in a predictable
        stretch, e.g. looping or prompt-copying output), so the gate
        requires two full-depth drafts' worth of tokens per active slot
        before paying for verification; anything less decodes normally and
        costs speculation nothing."""
        drafts: Dict[int, np.ndarray] = {}
        total = 0
        for req in decoding:
            remaining = req.max_new_tokens - len(req.generated)
            cap = min(self.speculate, remaining - 1)
            if cap > 0:
                ctx = np.concatenate(
                    [req.prompt, np.asarray(req.generated, np.int32)]
                )
                d = self.proposer.propose(ctx, cap, slot=req.slot)
            else:
                d = np.empty(0, np.int32)
            drafts[req.slot] = d
            total += len(d)
        gate = len(decoding) * min(self.speculate, 2)
        return drafts if total >= max(gate, 1) else None

    def _verify_step(self, decoding, drafts: Dict[int, np.ndarray]) -> int:
        """One batched draft-verify step: fold each slot to ``k+1`` rows of
        the regular paged decode step (row t = pending token if t=0 else
        draft t, at length L+t, sharing the slot's page-table row), then
        commit the longest accepted prefix per slot.  Row t's logits are the
        target model's next-token distribution after consuming the pending
        token and drafts 1..t — bitwise the sequential decode's logits
        whenever those drafts match what it would have committed, which is
        exactly the accept condition (DESIGN.md §11).  Padded rows get
        length 0 and an all-scratch page-table row so they can neither read
        nor clobber live pages."""
        t_rows = self.speculate + 1
        n_rows = self.max_batch * t_rows
        toks = np.zeros(n_rows, np.int32)
        lens = np.zeros(n_rows, np.int32)
        pts = np.full((n_rows, self.pages_per_seq), SCRATCH_PAGE, np.int32)
        for req in decoding:
            s = req.slot
            d = drafts[s]
            base = s * t_rows
            toks[base] = self.next_tokens[s]
            toks[base + 1: base + 1 + len(d)] = d
            lens[base: base + 1 + len(d)] = self.lengths[s] + np.arange(
                len(d) + 1
            )
            pts[base: base + 1 + len(d)] = self.page_tables[s]
        t0 = time.perf_counter()
        with self._sp(
            "verify",
            component="engine.verify",
            batch=len(decoding),
            rows=n_rows,
        ):
            logits_dev, self.cache = self._decode(
                self.params,
                jnp.asarray(toks),
                jnp.asarray(lens),
                self.cache,
                jnp.asarray(pts),
            )
            logits_np = np.asarray(logits_dev)
        dt = time.perf_counter() - t0
        total_committed = 0
        total_drafted = 0
        for req in decoding:
            s = req.slot
            d = drafts[s]
            rows = logits_np[s * t_rows: (s + 1) * t_rows]
            committed = [int(np.argmax(rows[0]))]
            for i in range(len(d)):
                if int(d[i]) != committed[i]:
                    break
                committed.append(int(np.argmax(rows[i + 1])))
            self.proposer.record(len(d), len(committed) - 1)
            for i, tok in enumerate(committed):
                req.generated.append(tok)
                if req.logits_trace is not None:
                    req.logits_trace.append(rows[i].astype(np.float32).copy())
            self.lengths[s] += len(committed)
            self.next_tokens[s] = committed[-1]
            total_committed += len(committed)
            total_drafted += len(d)
            if req.done:
                slot = req.slot
                self.scheduler.finish(req, self.step_count)
                self._release_slot(slot)
        self._emit(
            "verify",
            batch=len(decoding),
            step_s=dt,
            committed=total_committed,
            drafted=total_drafted,
        )
        return len(decoding)

    def run(self, max_steps: int = 100_000) -> Dict:
        """Drive steps until every submitted request has finished."""
        while not self.scheduler.drained:
            if self.step_count >= max_steps:
                raise RuntimeError(f"trace did not drain in {max_steps} steps")
            self.step()
        return self.stats()

    # ------------------------------------------------------------------
    def _emit(
        self,
        op: str,
        *,
        batch: int,
        step_s: float,
        committed: int = 0,
        drafted: int = 0,
        prefill_tokens: int = 0,
    ) -> None:
        self._t_s += step_s
        self.tracker.emit(
            ServeStepEvent(
                step=self.step_count,
                step_s=step_s,
                op=op,
                batch=batch,
                committed=committed,
                drafted=drafted,
                prefill_tokens=prefill_tokens,
                t_s=self._t_s,
                replica=self.replica_id,
            )
        )

    def events(self, kind: Optional[str] = None) -> List[Event]:
        """Typed events on the engine's bus (``serve_step`` rows)."""
        return self.tracker.events(kind)

    def to_jsonl(self, path) -> int:
        """Dump the engine's event stream as JSONL."""
        return self.tracker.to_jsonl(path)

    @property
    def telemetry(self) -> List[Dict]:
        """Deprecated: legacy row dicts; use ``events()`` instead."""
        warn_deprecated("ServeEngine.telemetry", 'ServeEngine.events("serve_step")')
        return [e.to_legacy() for e in self.tracker.events("serve_step")]

    # ------------------------------------------------------------------
    def stats(self) -> Dict:
        evs = self.events("serve_step")
        steps = [e for e in evs if e.batch > 0]
        tok = sum(e.committed for e in steps)
        busy = sum(e.step_s for e in steps)
        batch_tok = sum(e.batch for e in steps)
        out: Dict = {
            "requests_finished": len(self.scheduler.finished),
            "decode_steps": len(steps),
            "decode_tokens": tok,
            "decode_tok_per_s": tok / busy if busy else 0.0,
            "mean_batch": batch_tok / len(steps) if steps else 0.0,
            "pages_in_use": self.pool.pages_in_use,
            "free_pages": self.pool.free_pages,
        }
        if self.prefix is not None:
            out["prefix_hits"] = self.prefix.hits
            out["prefix_pages_shared"] = self.prefix.pages_shared
            out["prefills_skipped"] = self.prefix.prefills_skipped
        if self.prefill_chunk is not None:
            chunks = [e for e in evs if e.op == "prefill"]
            out["prefill_chunks"] = len(chunks)
            out["prefill_chunk_tokens"] = sum(e.prefill_tokens for e in chunks)
        if self.proposer is not None:
            out["draft_proposed"] = self.proposer.proposed_tokens
            out["draft_accepted"] = self.proposer.accepted_tokens
            out["spec_accept_rate"] = self.proposer.accept_rate
        joins = [
            r.first_token_step - r.arrival_step
            for r in self.scheduler.finished
            if r.first_token_step >= 0
        ]
        if joins:
            out["join_to_first_token_p50"] = float(np.percentile(joins, 50))
            out["join_to_first_token_p99"] = float(np.percentile(joins, 99))
        return out
