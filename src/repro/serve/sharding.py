"""Sharded serve data plane: ``Runtime`` + ``Rules`` -> placed tensors + jits.

This is the one place the serve engine meets a device mesh (DESIGN.md §13).
Given a ``Runtime`` carrying a mesh (and optionally explicit ``Rules`` —
``Rules.for_serving`` is the default policy: tensor parallelism over
"model", page pool and decode slots replicated), a :class:`ShardingPlan`

* places parameters with ``Rules.param_pspec`` over their logical axes;
* places the paged cache with ``Rules.act_pspec`` over ``LM.cache_axes()``
  — attention/MLA pools shard along their head/latent feature dims on the
  same mesh axes as the matching parameters, while the physical-page axis
  (``cache_batch``) stays replicated so any slot's page table can reference
  any page;
* compiles the decode / prefill-chunk jits with explicit in/out shardings
  (cache donated), so every step runs partitioned instead of relying on
  sharding propagation from whatever the last host write left behind.

Both paged-attention implementations ("stream" and "gather") run under the
plan — they read the pool with gathers that partition trivially when the
page axis is replicated.  The "pallas" kernel path is host-compiled and is
rejected at world size > 1.

The plan is geometry-only: it never copies weights itself until
``shard_params`` / ``shard_cache`` are called, so a CPU smoke engine on a
1x1 mesh pays one no-op ``device_put`` and is bitwise the unsharded engine.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.dist.partitioning import Rules
from repro.dist.treeutil import map_with_axes


def mesh_world_size(mesh) -> int:
    return int(mesh.devices.size) if mesh is not None else 1


@dataclasses.dataclass(frozen=True)
class ShardingPlan:
    """Placement of one serve engine's state on one mesh."""

    mesh: Any
    rules: Rules

    # ------------------------------------------------------------------
    @classmethod
    def for_runtime(cls, rt) -> Optional["ShardingPlan"]:
        """Plan for ``Runtime`` ``rt``; ``None`` when it carries no mesh."""
        if rt.mesh is None:
            return None
        rules = rt.rules or Rules.for_serving(rt.mesh)
        if rt.paged_impl == "pallas" and mesh_world_size(rt.mesh) > 1:
            raise ValueError(
                "paged_impl='pallas' is host-compiled and cannot run "
                "partitioned; use 'stream' or 'gather' on a multi-device "
                "mesh"
            )
        return cls(mesh=rt.mesh, rules=rules)

    # ------------------------------------------------------------------
    def replicated(self) -> NamedSharding:
        return NamedSharding(self.mesh, P())

    def param_sharding_tree(self, params: Any, param_axes: Any) -> Any:
        return map_with_axes(
            lambda leaf, ax: NamedSharding(
                self.mesh, self.rules.param_pspec(ax, tuple(leaf.shape))
            ),
            params,
            param_axes,
        )

    def cache_sharding_tree(self, cache: Any, cache_axes: Any) -> Any:
        """Shardings for a *paged* cache tree.  ``act_pspec`` resolves
        activation names first and falls back to parameter names (cache
        trees reuse e.g. "mamba_inner"); the shape-aware divisibility
        fallback leaves any non-dividing head/latent dim replicated."""
        return map_with_axes(
            lambda leaf, ax: NamedSharding(
                self.mesh, self.rules.act_pspec(ax, tuple(leaf.shape))
            ),
            cache,
            cache_axes,
        )

    # ------------------------------------------------------------------
    def shard_params(self, params: Any, param_axes: Any) -> Any:
        return jax.device_put(params, self.param_sharding_tree(params, param_axes))

    def shard_cache(self, cache: Any, cache_axes: Any) -> Any:
        return jax.device_put(cache, self.cache_sharding_tree(cache, cache_axes))

    def put_replicated(self, x: Any) -> Any:
        return jax.device_put(x, self.replicated())

    # ------------------------------------------------------------------
    def _dispatch_span(self, tracer, jitted, name: str):
        """Wrap a sharded jit so each dispatch emits a trace span.

        The span covers the partitioned *dispatch* (argument transfer +
        launch), not device completion — jax returns before the collective
        finishes, so the enclosing engine scope (which blocks) carries the
        wall time while this span shows the launch overhead per step."""
        world = mesh_world_size(self.mesh)

        def dispatched(*args, **kwargs):
            with tracer.span(
                name, component="sharding.dispatch", world=world
            ):
                return jitted(*args, **kwargs)

        return dispatched

    def decode_jit(self, lm, params: Any, cache: Any, tracer: Any = None):
        """``LM.decode_step_paged`` jitted with explicit shardings:
        (params, tokens, lengths, cache, page_tables) -> (logits, cache),
        cache donated, logits replicated (the engine argmaxes on host)."""
        param_sh = self.param_sharding_tree(params, lm.param_axes())
        cache_sh = self.cache_sharding_tree(cache, lm.cache_axes())
        rep = self.replicated()
        jitted = jax.jit(
            lm.decode_step_paged,
            in_shardings=(param_sh, rep, rep, cache_sh, rep),
            out_shardings=(rep, cache_sh),
            donate_argnums=(3,),
        )
        if tracer is None:
            return jitted
        return self._dispatch_span(tracer, jitted, "sharded_decode")

    def prefill_chunk_jit(self, lm, params: Any, cache: Any, tracer: Any = None):
        """``LM.prefill_chunk`` jitted with the same cache placement (chunk
        logits replicated; ``s0`` static as in the unsharded jit).  pjit
        rejects kwargs once ``in_shardings`` is given, so ``s0`` becomes a
        static *positional* under a wrapper keeping the engine's
        ``s0=``-kwarg call signature."""
        param_sh = self.param_sharding_tree(params, lm.param_axes())
        cache_sh = self.cache_sharding_tree(cache, lm.cache_axes())
        rep = self.replicated()
        jitted = jax.jit(
            lambda params, tokens, n_tokens, cache, rows, s0: lm.prefill_chunk(
                params, tokens, n_tokens, cache, rows, s0=s0
            ),
            static_argnums=(5,),
            in_shardings=(param_sh, rep, rep, cache_sh, rep),
            out_shardings=(rep, cache_sh),
            donate_argnums=(3,),
        )

        def chunk(params, tokens, n_tokens, cache, rows, *, s0):
            return jitted(params, tokens, n_tokens, cache, rows, s0)

        if tracer is None:
            return chunk
        return self._dispatch_span(tracer, chunk, "sharded_prefill_chunk")
