"""Prefix-affinity router over N serve-engine replicas (DESIGN.md §13).

A fleet of replicas multiplies throughput only if requests land where their
KV pages already live: the prefix cache is per-replica state, so a
round-robin fleet pays a cold prefill for every request whose prompt head a
*different* replica already holds.  The router therefore dispatches each
request to the replica owning the **longest cached prefix** of its prompt
(probed side-effect-free with ``PrefixCache.peek`` — only the chosen replica
perturbs its LRU state), with two corrections:

* **load-aware tiebreak** — among replicas tied at the best affinity (and
  among all replicas when nobody has cached pages), the least-loaded wins,
  measured in ``Scheduler.pending_tokens`` (outstanding prompt + generation
  positions, the unit decode steps are actually spent on); remaining ties
  break to the lowest replica index, keeping dispatch fully deterministic;
* **overflow spill** — an affinity winner whose load exceeds the fleet
  minimum by more than ``spill_slack`` tokens forfeits the request to the
  least-loaded replica: re-prefilling a prefix is cheaper than queueing
  behind a hot spot (the classic consistent-hashing-with-bounded-loads
  escape hatch).

Requests are dispatched at their *arrival step*, not at submit time, so
affinity decisions see the cache state earlier requests actually built.
Every decision is a typed ``RouterEvent`` on the router's telemetry bus;
``CapacityPlanner.ingest`` learns per-replica effective throughput and
affinity-hit rates from the combined router + engine streams.

Determinism and bit-identity: dispatch depends only on (trace, replica
count, spill_slack) — ``peek`` and ``pending_tokens`` are pure functions of
prior dispatches.  And because a dense-arch engine's per-request token
stream is independent of batch composition (see serve/engine.py), routing a
trace across N same-seed replicas yields **bit-identical** per-request
outputs to one engine serving the whole trace — the property
tests/test_router.py and the CI router smoke assert.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro.serve.engine import ServeEngine
from repro.serve.scheduler import Request
from repro.telemetry import Event, MemorySink, RouterEvent, Tracker
from repro.telemetry.trace import SpanTracer


@dataclasses.dataclass
class RoutedRequest:
    """Router-side handle: one submitted request and where it went."""

    rid: int  # router-global id (engine-local rids differ)
    prompt: np.ndarray
    max_new_tokens: int
    arrival_step: int
    frontend_embeds: Optional[np.ndarray] = None
    replica: int = -1  # chosen replica; -1 while still queued
    request: Optional[Request] = None  # engine-side record once dispatched

    @property
    def generated(self) -> List[int]:
        return [] if self.request is None else self.request.generated


class Router:
    """Dispatch a request trace across ``replicas`` lock-stepped engines."""

    def __init__(
        self,
        engines: List[ServeEngine],
        *,
        spill_slack: int = 512,
        trace: bool = False,
        trace_clock=None,
    ):
        if not engines:
            raise ValueError("router needs at least one engine")
        if spill_slack < 0:
            raise ValueError(f"spill_slack must be >= 0, got {spill_slack}")
        page_sizes = {e.page_size for e in engines}
        if len(page_sizes) != 1:
            raise ValueError(
                f"replicas disagree on page_size: {sorted(page_sizes)}; "
                "prefix affinity compares page-granular matches"
            )
        self.engines = engines
        self.page_size = engines[0].page_size
        self.spill_slack = spill_slack
        for i, eng in enumerate(engines):
            eng.replica_id = i
            if eng.spans is not None:
                # re-key each engine's trace identity to its fleet position
                # (the engine was built with replica_id=-1); spans emitted
                # from here on carry the replica tag
                eng.spans.set_trace(
                    "serve", eng.cfg.name, eng.seed, i, replica=i
                )
        self.requests: List[RoutedRequest] = []
        self._queue: List[RoutedRequest] = []
        self.step_count = 0
        self.tracker = Tracker([MemorySink()])
        # router-side dispatch spans ride the router bus, so all_events()
        # interleaves them with replica span trees under distinct trace_ids
        self.spans: Optional[SpanTracer] = (
            SpanTracer(
                self.tracker,
                trace=("router", engines[0].seed, len(engines)),
                clock=trace_clock,
            )
            if trace
            else None
        )

    # ------------------------------------------------------------------
    def submit(
        self,
        prompt: np.ndarray,
        max_new_tokens: int,
        arrival_step: int = 0,
        frontend_embeds: Optional[np.ndarray] = None,
    ) -> RoutedRequest:
        """Queue a request; it is *dispatched* when its arrival step is
        reached, so the affinity probe sees the caches earlier requests
        built rather than the cold state at submit time."""
        rr = RoutedRequest(
            rid=len(self.requests),
            prompt=np.asarray(prompt, np.int32).reshape(-1),
            max_new_tokens=max_new_tokens,
            arrival_step=arrival_step,
            frontend_embeds=frontend_embeds,
        )
        self.requests.append(rr)
        self._queue.append(rr)
        self._queue.sort(key=lambda r: (r.arrival_step, r.rid))
        return rr

    # ------------------------------------------------------------------
    def _dispatch(self, rr: RoutedRequest) -> None:
        if self.spans is None:
            return self._dispatch_inner(rr)
        with self.spans.span(
            "dispatch",
            step=self.step_count,
            component="router.dispatch",
            rid=rr.rid,
        ) as h:
            self._dispatch_inner(rr)
            h.set(replica=rr.replica)
        return None

    def _dispatch_inner(self, rr: RoutedRequest) -> None:
        loads = [eng.scheduler.pending_tokens for eng in self.engines]
        matches = [
            eng.prefix.peek(rr.prompt) if eng.prefix is not None else 0
            for eng in self.engines
        ]
        best = max(matches)
        idxs = range(len(self.engines))
        least_loaded = min(idxs, key=lambda i: (loads[i], i))
        if best > 0:
            winner = min(
                (i for i in idxs if matches[i] == best),
                key=lambda i: (loads[i], i),
            )
            if loads[winner] - loads[least_loaded] > self.spill_slack:
                replica, reason = least_loaded, "spill"
            else:
                replica, reason = winner, "affinity"
        else:
            replica, reason = least_loaded, "load"
        rr.replica = replica
        rr.request = self.engines[replica].submit(
            rr.prompt,
            rr.max_new_tokens,
            arrival_step=rr.arrival_step,
            frontend_embeds=rr.frontend_embeds,
        )
        self.tracker.emit(
            RouterEvent(
                step=self.step_count,
                rid=rr.rid,
                replica=replica,
                matched_pages=matches[replica],
                best_affinity=best,
                reason=reason,
                prompt_pages=len(rr.prompt) // self.page_size,
                loads=loads,
            )
        )

    # ------------------------------------------------------------------
    def step(self) -> int:
        """Dispatch every request whose arrival step has been reached, then
        advance all replicas one engine step in lockstep.  Returns the total
        number of requests that contributed decode tokens this step."""
        while self._queue and self._queue[0].arrival_step <= self.step_count:
            self._dispatch(self._queue.pop(0))
        n = sum(eng.step() for eng in self.engines)
        self.step_count += 1
        return n

    @property
    def drained(self) -> bool:
        return not self._queue and all(e.scheduler.drained for e in self.engines)

    def run(self, max_steps: int = 100_000) -> Dict:
        while not self.drained:
            if self.step_count >= max_steps:
                raise RuntimeError(f"trace did not drain in {max_steps} steps")
            self.step()
        return self.stats()

    # ------------------------------------------------------------------
    def events(self, kind: Optional[str] = "router") -> List[Event]:
        """Typed router events (pass ``kind=None`` for all)."""
        return self.tracker.events(kind)

    def all_events(self) -> List[Event]:
        """Router events plus every replica's serve_step events (replica-
        tagged), the combined stream ``CapacityPlanner.ingest`` consumes."""
        evs: List[Event] = list(self.tracker.events())
        for eng in self.engines:
            evs.extend(eng.events())
        return evs

    def to_jsonl(self, path) -> int:
        """Dump the combined router + replica event stream as JSONL."""
        tr = Tracker([MemorySink()])
        for ev in self.all_events():
            tr.emit(ev)
        return tr.to_jsonl(path)

    # ------------------------------------------------------------------
    def stats(self) -> Dict:
        evs = self.events("router")
        dispatched = len(evs)
        hits = sum(1 for e in evs if e.matched_pages > 0)
        routable = sum(1 for e in evs if e.prompt_pages > 0)
        per_replica = [0] * len(self.engines)
        for e in evs:
            per_replica[e.replica] += 1
        out: Dict = {
            "replicas": len(self.engines),
            "dispatched": dispatched,
            "affinity_hits": hits,
            # hit rate over requests that *could* hit (>= 1 full prompt
            # page); short prompts never have shareable pages
            "affinity_hit_rate": hits / routable if routable else 0.0,
            "spills": sum(1 for e in evs if e.reason == "spill"),
            "dispatch_per_replica": per_replica,
            "requests_finished": sum(
                e.stats()["requests_finished"] for e in self.engines
            ),
            "decode_tokens": sum(
                e.stats()["decode_tokens"] for e in self.engines
            ),
        }
        return out
