"""Continuous-batching serving subsystem (paged KV cache + Hemingway
capacity planning).  See DESIGN.md §7 and §13 (sharded data plane +
prefix-affinity router)."""

from repro.serve.cache import init_paged_cache, write_prefill
from repro.serve.engine import ServeEngine
from repro.serve.migrate import (
    MigrationError,
    migrate_replica,
    restore_engine,
    snapshot_engine,
)
from repro.serve.paging import SCRATCH_PAGE, OutOfPages, PagePool
from repro.serve.planner import CapacityPlanner
from repro.serve.prefix import PrefixCache
from repro.serve.router import RoutedRequest, Router
from repro.serve.scheduler import Request, RequestState, Scheduler
from repro.serve.sharding import ShardingPlan

__all__ = [
    "CapacityPlanner",
    "MigrationError",
    "OutOfPages",
    "PagePool",
    "PrefixCache",
    "Request",
    "RequestState",
    "RoutedRequest",
    "Router",
    "SCRATCH_PAGE",
    "Scheduler",
    "ServeEngine",
    "ShardingPlan",
    "init_paged_cache",
    "migrate_replica",
    "restore_engine",
    "snapshot_engine",
    "write_prefill",
]
