"""Continuous-batching serving subsystem (paged KV cache + Hemingway
capacity planning).  See DESIGN.md §7."""

from repro.serve.cache import init_paged_cache, write_prefill
from repro.serve.engine import ServeEngine
from repro.serve.paging import SCRATCH_PAGE, OutOfPages, PagePool
from repro.serve.planner import CapacityPlanner
from repro.serve.prefix import PrefixCache
from repro.serve.scheduler import Request, RequestState, Scheduler

__all__ = [
    "CapacityPlanner",
    "OutOfPages",
    "PagePool",
    "PrefixCache",
    "Request",
    "RequestState",
    "SCRATCH_PAGE",
    "Scheduler",
    "ServeEngine",
    "init_paged_cache",
    "write_prefill",
]
