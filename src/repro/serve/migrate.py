"""Live serving-state migration: drain-free replica handoff (DESIGN.md §15).

Resizing a serving fleet without this layer means draining: stop routing to
the replica, wait for every in-flight request to finish, then kill it — tail
latency of the longest request, paid on every resize.  Migration instead
moves the replica's *entire* serving state between engine steps:

* the paged KV/latent cache (every layer's page-major pools plus slot-major
  recurrent state), pulled to host in one snapshot;
* the page tables, per-slot lengths and pending tokens — the decode batch's
  exact register state;
* the ``PagePool`` free list **in order** and per-page refcounts, so
  allocation order (and therefore page ids, and therefore everything keyed
  on them) continues bit-identically;
* the ``PrefixCache`` hash chains, full-prompt entries and LRU orders —
  a migrated replica keeps winning the router's affinity probes;
* the scheduler's admission queue, occupied slots and finished list, every
  ``Request`` rebuilt field-for-field on the destination;
* the speculative proposer's counters and per-slot source memory.

Because the engine mutates state only inside ``step()``, a snapshot taken
between steps is consistent by construction — no locks, no quiesce.  The
restored engine's next step is bitwise the step the source engine would
have taken: the engine's slot-independence guarantee (serve/engine.py)
plus an exact state copy leave nothing to diverge.  ``migrate_replica``
swaps the restored engine into a live ``Router`` at a step boundary and
re-points the router's request handles, so from the caller's side the
replica simply kept serving.  The handoff wall time rides the telemetry
bus as a ``ckpt_cost`` event (``op="migrate"``) — the same stream the
fleet scheduler's measured-recovery refit consumes.

What does NOT migrate: model parameters (replicas of a deployment share
weights; the destination engine already initialized them from the same
seed — a mismatch is rejected), and telemetry (each engine keeps its own
event stream; the router's combined view concatenates both lives).
"""

from __future__ import annotations

import time
from collections import OrderedDict, deque
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.serve.engine import ServeEngine
from repro.serve.prefix import FullPromptEntry, _chain_key
from repro.serve.scheduler import Request, RequestState
from repro.telemetry import CkptCostEvent

SNAPSHOT_FORMAT = 1

# every geometry field that shapes the decode computation or the step
# schedule; a mismatch on any of these makes "bit-identical continuation"
# unsatisfiable, so restore refuses rather than silently diverging
_GEOMETRY_FIELDS = (
    "arch",
    "seed",
    "max_batch",
    "page_size",
    "max_seq",
    "num_pages",
    "prefill_chunk",
    "speculate",
    "collect_logits",
)


class MigrationError(RuntimeError):
    """A snapshot cannot be restored onto the given destination engine."""


def _geometry(engine: ServeEngine) -> Dict[str, Any]:
    return {
        "arch": engine.cfg.name,
        "seed": engine.seed,
        "max_batch": engine.max_batch,
        "page_size": engine.page_size,
        "max_seq": engine.max_seq,
        "num_pages": engine.pool.num_pages,
        "prefill_chunk": engine.prefill_chunk,
        "speculate": engine.speculate,
        "collect_logits": engine.collect_logits,
    }


# ---------------------------------------------------------------------------
# request (de)serialization
# ---------------------------------------------------------------------------


def _pack_request(req: Request) -> Dict[str, Any]:
    return {
        "rid": req.rid,
        "prompt": req.prompt.copy(),
        "max_new_tokens": req.max_new_tokens,
        "arrival_step": req.arrival_step,
        "frontend_embeds": (
            None
            if req.frontend_embeds is None
            else np.asarray(req.frontend_embeds).copy()
        ),
        "state": req.state.value,
        "slot": req.slot,
        "page_ids": list(req.page_ids),
        "n_shared_pages": req.n_shared_pages,
        "prefill_skipped": req.prefill_skipped,
        # full_entry is a live reference into the prefix cache; carry its
        # chain key and re-link after the cache itself is restored
        "full_entry_key": (
            _chain_key(req.prompt) if req.full_entry is not None else None
        ),
        "generated": list(req.generated),
        "logits_trace": (
            None
            if req.logits_trace is None
            else [np.asarray(a).copy() for a in req.logits_trace]
        ),
        "admitted_step": req.admitted_step,
        "finished_step": req.finished_step,
        "prefill_s": req.prefill_s,
        "prefill_pos": req.prefill_pos,
        "first_token_step": req.first_token_step,
    }


def _unpack_request(d: Dict[str, Any], full: Dict[str, FullPromptEntry]) -> Request:
    req = Request(
        rid=d["rid"],
        prompt=np.asarray(d["prompt"], np.int32),
        max_new_tokens=d["max_new_tokens"],
        arrival_step=d["arrival_step"],
        frontend_embeds=d["frontend_embeds"],
    )
    req.state = RequestState(d["state"])
    req.slot = d["slot"]
    req.page_ids = list(d["page_ids"])
    req.n_shared_pages = d["n_shared_pages"]
    req.prefill_skipped = d["prefill_skipped"]
    if d["full_entry_key"] is not None:
        req.full_entry = full[d["full_entry_key"]]
    req.generated = list(d["generated"])
    if d["logits_trace"] is not None:
        req.logits_trace = [a.copy() for a in d["logits_trace"]]
    req.admitted_step = d["admitted_step"]
    req.finished_step = d["finished_step"]
    req.prefill_s = d["prefill_s"]
    req.prefill_pos = d["prefill_pos"]
    req.first_token_step = d["first_token_step"]
    return req


# ---------------------------------------------------------------------------
# snapshot
# ---------------------------------------------------------------------------


def snapshot_engine(engine: ServeEngine) -> Dict[str, Any]:
    """Consistent host-side snapshot of one engine's full serving state.

    Must be called between engine steps (the engine mutates state only
    inside ``step()``); the result is plain host data — numpy arrays and
    builtin containers — safe to hold across the source engine's teardown.
    """
    prefix = None
    if engine.prefix is not None:
        p = engine.prefix
        prefix = {
            "pages": [(k, pid) for k, pid in p._pages.items()],
            "parent": dict(p._parent),
            "nchildren": dict(p._nchildren),
            "full": [
                (
                    k,
                    {
                        "page_ids": list(e.page_ids),
                        "last_logits": np.asarray(e.last_logits).copy(),
                        "state": jax.tree_util.tree_map(np.copy, e.state),
                        "tokens": None if e.tokens is None else e.tokens.copy(),
                    },
                )
                for k, e in p._full.items()
            ],
            "hits": p.hits,
            "pages_shared": p.pages_shared,
            "prefills_skipped": p.prefills_skipped,
            "draft_hit": p._draft_hit,
        }
    proposer = None
    if engine.proposer is not None:
        pr = engine.proposer
        proposer = {
            "proposals": pr.proposals,
            "proposed_tokens": pr.proposed_tokens,
            "accepted_tokens": pr.accepted_tokens,
            "last_source": dict(pr._last_source),
        }
    sched = engine.scheduler
    return {
        "format": SNAPSHOT_FORMAT,
        "geometry": _geometry(engine),
        "step_count": engine.step_count,
        "rid": engine._rid,
        "lengths": engine.lengths.copy(),
        "next_tokens": engine.next_tokens.copy(),
        "page_tables": engine.page_tables.copy(),
        "cache": jax.tree_util.tree_map(
            lambda x: np.asarray(jax.device_get(x)), engine.cache
        ),
        "pool": {
            "free": list(engine.pool._free),
            "refcount": list(engine.pool._refcount),
        },
        "prefix": prefix,
        "proposer": proposer,
        "scheduler": {
            "queue": [_pack_request(r) for r in sched.queue],
            "slots": [
                None if r is None else _pack_request(r) for r in sched.slots
            ],
            "finished": [_pack_request(r) for r in sched.finished],
        },
    }


def snapshot_nbytes(snap: Dict[str, Any]) -> int:
    """Serialized payload size: the paged cache dominates, so that is what
    gets reported (request/prefix metadata is noise next to it)."""
    return sum(
        leaf.nbytes for leaf in jax.tree_util.tree_leaves(snap["cache"])
    )


# ---------------------------------------------------------------------------
# restore
# ---------------------------------------------------------------------------


def _check_compatible(engine: ServeEngine, snap: Dict[str, Any]) -> None:
    if snap.get("format") != SNAPSHOT_FORMAT:
        raise MigrationError(
            f"snapshot format {snap.get('format')!r} != {SNAPSHOT_FORMAT}"
        )
    dst = _geometry(engine)
    bad = [
        f"{k}: snapshot={snap['geometry'][k]!r} dest={dst[k]!r}"
        for k in _GEOMETRY_FIELDS
        if snap["geometry"][k] != dst[k]
    ]
    if bad:
        raise MigrationError(
            "destination engine geometry does not match the snapshot "
            "(bit-identical continuation impossible): " + "; ".join(bad)
        )
    if (snap["prefix"] is None) != (engine.prefix is None):
        raise MigrationError(
            "prefix caching mismatch between snapshot and destination"
        )
    if engine.step_count or engine._rid or engine.scheduler.queue or any(
        s is not None for s in engine.scheduler.slots
    ):
        raise MigrationError(
            "destination engine must be fresh (it has served traffic; "
            "restoring over live state would leak pages)"
        )


def restore_engine(
    engine: ServeEngine, snap: Dict[str, Any]
) -> Dict[int, Request]:
    """Install ``snap`` onto a fresh, geometry-identical engine.

    Returns ``{rid: Request}`` over every restored request (queued, active
    and finished) so callers holding handles into the source engine — the
    ``Router`` — can re-point them at the destination's objects.
    """
    _check_compatible(engine, snap)
    engine.cache = jax.tree_util.tree_map(jnp.asarray, snap["cache"])
    if engine.plan is not None:
        engine.cache = engine.plan.shard_cache(engine.cache, engine.axes)
    engine.page_tables = snap["page_tables"].copy()
    engine.page_tables_dev = jnp.asarray(engine.page_tables)
    if engine.plan is not None:
        engine.page_tables_dev = engine.plan.put_replicated(
            engine.page_tables_dev
        )
    engine.lengths = snap["lengths"].copy()
    engine.next_tokens = snap["next_tokens"].copy()
    engine.step_count = snap["step_count"]
    engine._rid = snap["rid"]

    pool = engine.pool
    pool._free = deque(snap["pool"]["free"])
    pool._refcount = list(snap["pool"]["refcount"])

    full: Dict[str, FullPromptEntry] = {}
    if snap["prefix"] is not None:
        p, ps = engine.prefix, snap["prefix"]
        p._pages = OrderedDict(ps["pages"])
        p._parent = dict(ps["parent"])
        p._nchildren = dict(ps["nchildren"])
        p._full = OrderedDict(
            (
                k,
                FullPromptEntry(
                    tuple(e["page_ids"]),
                    e["last_logits"].copy(),
                    jax.tree_util.tree_map(np.copy, e["state"]),
                    None if e["tokens"] is None else e["tokens"].copy(),
                ),
            )
            for k, e in ps["full"]
        )
        p.hits = ps["hits"]
        p.pages_shared = ps["pages_shared"]
        p.prefills_skipped = ps["prefills_skipped"]
        p._draft_hit = ps["draft_hit"]
        full = dict(p._full)

    if snap["proposer"] is not None and engine.proposer is not None:
        pr, prs = engine.proposer, snap["proposer"]
        pr.proposals = prs["proposals"]
        pr.proposed_tokens = prs["proposed_tokens"]
        pr.accepted_tokens = prs["accepted_tokens"]
        pr._last_source = dict(prs["last_source"])

    sched, ss = engine.scheduler, snap["scheduler"]
    rid_map: Dict[int, Request] = {}

    def build(d: Dict[str, Any]) -> Request:
        req = _unpack_request(d, full)
        rid_map[req.rid] = req
        return req

    sched.queue = [build(d) for d in ss["queue"]]
    sched.slots = [None if d is None else build(d) for d in ss["slots"]]
    sched.finished = [build(d) for d in ss["finished"]]
    return rid_map


# ---------------------------------------------------------------------------
# router-level handoff
# ---------------------------------------------------------------------------


def migrate_replica(
    router,
    replica: int,
    make_engine: Callable[[], ServeEngine],
    *,
    assumed_s: Optional[float] = None,
) -> Dict[str, Any]:
    """Hand replica ``replica`` off to a freshly built engine, live.

    Call between router steps.  The source engine is snapshotted, the
    destination (from ``make_engine``; must match the source's geometry)
    restored, swapped into the router, and every ``RoutedRequest`` handle
    pointing at the old engine re-bound — in-flight streams continue on
    the destination bit-identically.  Emits a ``ckpt_cost`` event
    (``op="migrate"``) on the router bus and returns the measured handoff
    stats the launch CLI prints.
    """
    if not 0 <= replica < len(router.engines):
        raise ValueError(
            f"replica {replica} out of range for a "
            f"{len(router.engines)}-replica fleet"
        )
    src = router.engines[replica]
    t0 = time.perf_counter()
    snap = snapshot_engine(src)
    dst = make_engine()
    rid_map = restore_engine(dst, snap)
    dst.replica_id = replica
    if dst.spans is not None:
        dst.spans.set_trace(
            "serve", dst.cfg.name, dst.seed, replica, replica=replica
        )
    router.engines[replica] = dst
    in_flight = 0
    for rr in router.requests:
        if rr.replica == replica and rr.request is not None:
            rr.request = rid_map[rr.request.rid]
            if rr.request.state is not RequestState.FINISHED:
                in_flight += 1
    wall_s = time.perf_counter() - t0
    nbytes = snapshot_nbytes(snap)
    n_shards = len(jax.tree_util.tree_leaves(snap["cache"]))
    router.tracker.emit(
        CkptCostEvent(
            step=router.step_count,
            op="migrate",
            wall_s=wall_s,
            assumed_s=assumed_s,
            workload=dst.cfg.name,
            nbytes=nbytes,
            n_shards=n_shards,
            replica=replica,
        )
    )
    return {
        "replica": replica,
        "wall_s": wall_s,
        "nbytes": nbytes,
        "n_shards": n_shards,
        "requests": len(rid_map),
        "in_flight": in_flight,
        "pages_in_use": dst.pool.pages_in_use,
    }


__all__: List[str] = [
    "MigrationError",
    "migrate_replica",
    "restore_engine",
    "snapshot_engine",
    "snapshot_nbytes",
]
