"""Paged KV/state cache construction and prefill-to-page writes.

The paged cache mirrors ``LM.init_cache``'s pytree exactly, with two leaf
transformations driven by the logical cache axes (``LM.cache_axes``):

* leaves with a ``cache_seq`` axis (attention K/V, MLA latents) become
  *page-major*: the ``cache_batch`` axis is replaced by ``num_pages`` and the
  sequence axis is truncated to ``page_size`` — one row per physical page,
  shared by every request via its page table;
* leaves without a sequence axis (mamba recurrent + conv state) become
  *slot-major*: the batch axis is sized ``max_batch`` and indexed by the
  decode slot directly, so the existing mamba decode path runs unchanged.

All writers here are functional (return new trees); the engine owns the
authoritative tree and threads it through the jitted decode step with
donation.
"""

from __future__ import annotations

from typing import Any, Dict, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist.treeutil import map_with_axes, map_zip_with_axes


def _is_paged(axes: tuple) -> bool:
    return "cache_seq" in axes


def init_paged_cache(lm, *, num_pages: int, page_size: int, max_batch: int):
    """Zero paged-cache pytree for ``lm`` (see module docstring)."""
    template = jax.eval_shape(lambda: lm.init_cache(1, page_size))
    axes = lm.cache_axes()

    def build(leaf, ax):
        ba = ax.index("cache_batch")
        shape = list(leaf.shape)
        shape[ba] = num_pages if _is_paged(ax) else max_batch
        return jnp.zeros(shape, leaf.dtype)

    return map_with_axes(build, template, axes)


def write_prefill(
    paged: Any,
    prefill_cache: Any,
    axes: Any,
    *,
    slot: int,
    page_ids: Sequence[int],
    page_size: int,
    skip_pages: int = 0,
):
    """Write a batch-1 prefill cache into ``page_ids`` (attention leaves) and
    decode slot ``slot`` (state leaves).  The last page may be partial; its
    tail is zero-padded and overwritten by subsequent decode steps.

    ``skip_pages`` leading pages are NOT written: those are prefix-shared,
    immutable, and may back a request that is still decoding — their content
    is already bitwise what this prefill computed for the same positions
    (the engine pins the flash block size so prefix activations are
    independent of total prompt length)."""
    pids = jnp.asarray(np.asarray(page_ids[skip_pages:], np.int32))

    def write(paged_leaf, pre_leaf, ax):
        ba = ax.index("cache_batch")
        pre = jnp.take(pre_leaf, 0, axis=ba)  # drop the size-1 batch axis
        if not _is_paged(ax):
            idx = (slice(None),) * ba + (slot,)
            return paged_leaf.at[idx].set(pre.astype(paged_leaf.dtype))
        if len(pids) == 0:
            return paged_leaf
        sa = ax.index("cache_seq")
        sa2 = sa - 1 if ba < sa else sa
        n_tok = pre.shape[sa2]
        pad = [(0, 0)] * pre.ndim
        pad[sa2] = (0, len(page_ids) * page_size - n_tok)
        pre = jnp.pad(pre, pad)
        pre = pre.reshape(
            pre.shape[:sa2] + (len(page_ids), page_size) + pre.shape[sa2 + 1 :]
        )
        # drop the shared pages' slices, then land each remaining logical
        # page on its physical page (page axis replaces the batch axis)
        pre = jnp.take(pre, np.arange(skip_pages, len(page_ids)), axis=sa2)
        pre = jnp.moveaxis(pre, sa2, ba)
        idx = (slice(None),) * ba + (pids,)
        return paged_leaf.at[idx].set(pre.astype(paged_leaf.dtype))

    return map_zip_with_axes(write, paged, prefill_cache, axes)


def snapshot_state(paged: Any, axes: Any, slot: int) -> Dict:
    """Copy the slot-major (recurrent state) leaves of decode slot ``slot``
    to host; paged leaves are returned as ``None``.  Used by the prefix cache
    to support whole-prompt reuse on architectures with mamba layers."""

    def snap(leaf, ax):
        if _is_paged(ax):
            return None
        ba = ax.index("cache_batch")
        idx = (slice(None),) * ba + (slot,)
        return np.asarray(leaf[idx])

    return map_with_axes(snap, paged, axes)


def restore_state(paged: Any, snapshot: Any, axes: Any, slot: int):
    """Write a ``snapshot_state`` result back into decode slot ``slot``."""

    def rest(leaf, snap, ax):
        if snap is None:
            return leaf
        ba = ax.index("cache_batch")
        idx = (slice(None),) * ba + (slot,)
        return leaf.at[idx].set(jnp.asarray(snap).astype(leaf.dtype))

    return map_zip_with_axes(rest, paged, snapshot, axes)


def max_pages_per_seq(max_seq: int, page_size: int) -> int:
    return -(-max_seq // page_size)
