"""Prefix cache: hash-chained page sharing for common prompt heads.

Each *full* page of a prompt is keyed by the hash of every token up to and
including that page (a hash chain, so a key identifies the entire prefix and
not just the page's own tokens).  Matching walks the chain from page 0 and
shares physical pages for as long as keys hit — requests with a common
prompt head then reference the same pages, because causal attention makes a
position's K/V depend only on the tokens at or before it.

Only full pages are ever shared, and decode writes land at positions at or
past the prompt length, so shared pages are immutable — no copy-on-write is
needed.

Whole-prompt entries additionally store the prefill's last-token logits and
a snapshot of the recurrent (mamba) state, enabling a skip-prefill fast path
when an identical, page-aligned prompt is admitted again.  Reused logits are
bit-identical to a cold prefill by construction: they *are* the stored output
of one.

The cache holds one pool reference per registered page; ``release_lru``
drops the oldest chains when the pool runs dry, and ``clear`` drops
everything (after which a drained pool must report zero pages in use — the
leak invariant ``tests/test_serve.py`` checks).

Eviction-order invariant (DESIGN.md §13): the registered chain keys always
form a *prefix-closed* set — every key's parent (the chain one page shorter)
is registered too.  ``match()`` walks from page 0 and breaks at the first
missing key, so dropping a mid-chain page would make every descendant
unreachable while its entry kept pinning a pool reference (a strand).
``release_lru`` therefore evicts suffix-first: only chain *leaves* (keys with
no registered children) are ever dropped, oldest leaf first, which unwinds
the LRU chain from its tail without ever stranding a descendant.
"""

from __future__ import annotations

import dataclasses
import hashlib
from collections import OrderedDict
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.serve.paging import PagePool


def _chain_key(tokens: np.ndarray) -> str:
    return hashlib.sha1(np.ascontiguousarray(tokens, np.int32).tobytes()).hexdigest()


@dataclasses.dataclass
class FullPromptEntry:
    page_ids: Tuple[int, ...]
    last_logits: np.ndarray
    state: Any  # snapshot_state tree, or None for stateless archs
    tokens: Optional[np.ndarray] = None  # the prompt itself (draft source)


class PrefixCache:
    def __init__(self, page_size: int):
        self.page_size = page_size
        # chain-hash -> physical page id, in LRU order (oldest first)
        self._pages: "OrderedDict[str, int]" = OrderedDict()
        # chain linkage: key -> parent key (None for page-0 keys) and the
        # number of registered children.  Eviction only ever drops keys with
        # zero children (chain leaves), so the key set stays prefix-closed
        # and no registered page can become unreachable via ``match``.
        self._parent: Dict[str, Optional[str]] = {}
        self._nchildren: Dict[str, int] = {}
        self._full: "OrderedDict[str, FullPromptEntry]" = OrderedDict()
        # counters are maintained by the scheduler on *successful* admission
        # only, so a request blocked on pages and retried every step does not
        # inflate them
        self.hits = 0
        self.pages_shared = 0
        self.prefills_skipped = 0
        # key of the entry that served the last speculative draft (MRU
        # fast path for ``draft``)
        self._draft_hit: Optional[str] = None

    # ------------------------------------------------------------------
    def match(self, prompt: np.ndarray, pool: PagePool) -> List[int]:
        """Longest chain of already-cached full pages for ``prompt``.  Takes
        one reference per matched page on behalf of the caller."""
        ps = self.page_size
        matched: List[int] = []
        for j in range(len(prompt) // ps):
            key = _chain_key(prompt[: (j + 1) * ps])
            pid = self._pages.get(key)
            if pid is None:
                break
            self._pages.move_to_end(key)
            matched.append(pid)
        if matched:
            pool.share(matched)
        return matched

    def peek(self, prompt: np.ndarray) -> int:
        """Number of leading full pages of ``prompt`` the cache could share,
        with no side effects: no references taken and no LRU bumps.  Routers
        probe every replica with this — only the replica that actually
        receives the request should perturb its cache state."""
        ps = self.page_size
        n = 0
        for j in range(len(prompt) // ps):
            if _chain_key(prompt[: (j + 1) * ps]) not in self._pages:
                break
            n += 1
        return n

    def register(
        self, prompt: np.ndarray, page_ids: Sequence[int], pool: PagePool
    ) -> None:
        """Publish ``prompt``'s full pages (already written) for future
        sharing.  The cache takes its own reference on each new page."""
        ps = self.page_size
        prev: Optional[str] = None
        for j in range(len(prompt) // ps):
            key = _chain_key(prompt[: (j + 1) * ps])
            if key in self._pages:
                self._pages.move_to_end(key)
            else:
                pool.share([page_ids[j]])
                self._pages[key] = page_ids[j]
                # j > 0 keys always have a registered parent: this loop just
                # inserted (or bumped) the one-page-shorter chain
                self._parent[key] = prev
                self._nchildren[key] = 0
                if prev is not None:
                    self._nchildren[prev] += 1
            prev = key

    # ------------------------------------------------------------------
    def match_full(
        self, prompt: np.ndarray, pool: PagePool
    ) -> Optional[FullPromptEntry]:
        """Skip-prefill fast path: exact whole-prompt entry (page-aligned
        prompts only).  Shares the entry's pages on behalf of the caller."""
        if len(prompt) % self.page_size:
            return None
        entry = self._full.get(_chain_key(prompt))
        if entry is None:
            return None
        self._full.move_to_end(_chain_key(prompt))
        pool.share(entry.page_ids)
        return entry

    def register_full(
        self,
        prompt: np.ndarray,
        page_ids: Sequence[int],
        last_logits: np.ndarray,
        state: Any,
        pool: PagePool,
    ) -> None:
        if len(prompt) % self.page_size:
            return  # only page-aligned prompts are exactly reusable
        key = _chain_key(prompt)
        if key in self._full:
            return
        pool.share(page_ids)
        self._full[key] = FullPromptEntry(
            tuple(page_ids),
            np.asarray(last_logits),
            state,
            np.asarray(prompt, np.int32).copy(),
        )

    # ------------------------------------------------------------------
    def draft(self, ngram: np.ndarray, max_draft: int) -> Optional[np.ndarray]:
        """Cross-request draft source for speculative decode: the tokens
        that followed the last occurrence of ``ngram`` in the most recently
        used stored prompt containing it (see ``repro.serve.speculate``)."""
        from repro.serve.speculate import find_last_ngram

        ngram = np.asarray(ngram, np.int32).reshape(-1)
        if max_draft <= 0 or len(ngram) == 0:
            return None

        def scan(entry: FullPromptEntry) -> Optional[np.ndarray]:
            if entry.tokens is None:
                return None
            j = find_last_ngram(entry.tokens, ngram)
            if j < 0 or j + len(ngram) >= len(entry.tokens):
                return None
            start = j + len(ngram)
            return entry.tokens[start: start + max_draft].copy()

        # a drafting slot streams down one source prompt, re-matching it
        # every step — try the entry that produced the previous draft before
        # scanning the whole registry.  Every served draft MRU-bumps its
        # source entry: an actively-drafting source that sat at the LRU end
        # would otherwise be evicted mid-stream under pool pressure,
        # silently killing the speculative accept rate.
        hit = self._draft_hit
        if hit is not None and hit in self._full:
            d = scan(self._full[hit])
            if d is not None:
                self._full.move_to_end(hit)
                return d
        for key in reversed(list(self._full)):
            if key == hit:
                continue
            d = scan(self._full[key])
            if d is not None:
                self._draft_hit = key
                self._full.move_to_end(key)
                return d
        return None

    # ------------------------------------------------------------------
    def _drop_key(self, key: str, pool: PagePool) -> None:
        pid = self._pages.pop(key)
        parent = self._parent.pop(key, None)
        self._nchildren.pop(key, None)
        if parent is not None and parent in self._nchildren:
            self._nchildren[parent] -= 1
        pool.free([pid])

    def release_lru(self, pool: PagePool, min_free: int) -> int:
        """Drop oldest entries until ``pool.free_pages >= min_free`` (or the
        cache is empty).  Returns the number of references released.

        Chain pages are evicted suffix-first: only *leaves* (keys with no
        registered children) are candidates, oldest leaf first.  Evicting a
        mid-chain page would strand every descendant — ``match`` breaks at
        the first missing key, so stranded entries could never be shared
        again yet would keep pinning pool references (see module docstring).
        """
        released = 0
        while pool.free_pages < min_free and (self._pages or self._full):
            if self._full:
                _, entry = self._full.popitem(last=False)
                pool.free(entry.page_ids)
                released += len(entry.page_ids)
            else:
                key = next(k for k in self._pages if self._nchildren.get(k, 0) == 0)
                self._drop_key(key, pool)
                released += 1
        return released

    def clear(self, pool: PagePool) -> None:
        for pid in self._pages.values():
            pool.free([pid])
        self._pages.clear()
        self._parent.clear()
        self._nchildren.clear()
        for entry in self._full.values():
            pool.free(entry.page_ids)
        self._full.clear()
