"""Draft proposal for speculative multi-token decode (no second model).

Drafts come from *prompt lookup* (n-gram self-continuation): the proposer
searches the request's own prompt + generated tokens for the most recent
earlier occurrence of the current tail n-gram and proposes the tokens that
followed it.  When the request's own context has no match, the hash-chain
prefix cache is consulted the same way across the *other* stored prompts
(cross-request drafting) — common instruction heads make one request's
continuation a good draft for another's.

The proposer never influences the committed tokens, only how many target
steps they cost: every draft is verified by one batched target step over the
paged pools and accepted only as the longest prefix that matches what greedy
decode would have produced anyway (see ServeEngine.step and DESIGN.md §11).
A wrong draft therefore costs compute, never correctness — which is why a
cheap heuristic proposer is enough.
"""

from __future__ import annotations

from typing import Optional

import numpy as np

from repro.serve.prefix import PrefixCache


def find_last_ngram(hay: np.ndarray, needle: np.ndarray) -> int:
    """Index of the last occurrence of ``needle`` in ``hay`` (or -1)."""
    n = len(needle)
    if n == 0 or len(hay) < n:
        return -1
    if n == 1:
        matches = np.nonzero(hay == needle[0])[0]
    else:
        windows = np.lib.stride_tricks.sliding_window_view(hay, n)
        matches = np.nonzero((windows == needle).all(axis=1))[0]
    return int(matches[-1]) if len(matches) else -1


class NgramProposer:
    """Greedy-draft proposer: longest-match n-gram lookup, self then cross."""

    def __init__(self, max_n: int = 3, min_n: int = 2,
                 prefix_cache: Optional[PrefixCache] = None):
        if max_n < 1:
            raise ValueError(f"max_n must be >= 1, got {max_n}")
        # 1-gram self-matches are mostly coincidence on anything but heavily
        # looping text, and every spurious draft turns a cheap decode step
        # into a wide verify step — so the self-lookup stops at min_n unless
        # the caller explicitly opts into 1-gram drafting.
        self.max_n = max_n
        self.min_n = max(1, min(min_n, max_n))
        self.prefix = prefix_cache
        self.proposals = 0
        self.proposed_tokens = 0
        self.accepted_tokens = 0
        # slot -> which source drafted last ("self" | "prefix"): a slot
        # streaming down a cached prompt re-hits the same source every
        # step, so that source is tried first and the other scan skipped
        # on a hit
        self._last_source: dict = {}

    # ------------------------------------------------------------------
    def _propose_self(self, context: np.ndarray,
                      max_draft: int) -> np.ndarray:
        for n in range(min(self.max_n, len(context) - 1),
                       self.min_n - 1, -1):
            tail = context[-n:]
            # search excludes the tail itself so a continuation always exists
            j = find_last_ngram(context[:-1], tail)
            if j >= 0:
                return context[j + n: j + n + max_draft].astype(np.int32)
        return np.empty(0, np.int32)

    def _propose_prefix(self, context: np.ndarray,
                        max_draft: int) -> np.ndarray:
        if self.prefix is not None:
            for n in range(min(self.max_n, len(context)),
                           self.min_n - 1, -1):
                d = self.prefix.draft(context[-n:], max_draft)
                if d is not None and len(d):
                    return d.astype(np.int32)
        return np.empty(0, np.int32)

    def propose(self, context: np.ndarray, max_draft: int,
                slot: Optional[int] = None) -> np.ndarray:
        """Up to ``max_draft`` draft tokens continuing ``context``."""
        context = np.asarray(context, np.int32).reshape(-1)
        if max_draft <= 0 or len(context) < 2:
            return np.empty(0, np.int32)
        sources = [("self", self._propose_self),
                   ("prefix", self._propose_prefix)]
        if slot is not None and self._last_source.get(slot) == "prefix":
            sources.reverse()
        for name, fn in sources:
            d = fn(context, max_draft)
            if len(d):
                if slot is not None:
                    self._last_source[slot] = name
                return d
        return np.empty(0, np.int32)

    # ------------------------------------------------------------------
    def record(self, proposed: int, accepted: int) -> None:
        """Account one verified proposal (engine calls this per slot/step)."""
        if proposed > 0:
            self.proposals += 1
            self.proposed_tokens += int(proposed)
            self.accepted_tokens += int(accepted)

    @property
    def accept_rate(self) -> float:
        if not self.proposed_tokens:
            return 0.0
        return self.accepted_tokens / self.proposed_tokens
