"""Async, sharded, crash-safe checkpointing (see manager.py for the
on-disk format and the commit protocol)."""
from repro.checkpoint.manager import (  # noqa: F401
    CheckpointManager,
    CheckpointWrite,
    CorruptCheckpoint,
    FORMAT_VERSION,
)

__all__ = ["CheckpointManager", "CheckpointWrite", "CorruptCheckpoint",
           "FORMAT_VERSION"]
