"""Sharded, atomic, async checkpointing with keep-k retention.

Layout (format 2):  <dir>/step_<N>/
           shard_0000.npz ...    (balanced key partitions of the flat tree)
           manifest.json         (schema, shard index, metadata — written LAST)
           COMMITTED             (legacy marker, kept for external tooling)

Crash-safety protocol: every file goes through ``telemetry.io`` atomic
write-temp-then-rename, and the manifest is written *after* every shard —
its presence is the commit point.  A crash at any instant leaves either a
previous complete checkpoint or a step directory without a valid manifest,
which ``all_steps``/``restore`` skip.  A ``file_lock`` sidecar serializes
writers across processes (two trainers pointed at one directory cannot
interleave shard writes).

* ``save_async`` snapshots each leaf to host memory at call time (the
  donate-safe copy — training may mutate device buffers immediately after)
  and hands the write to a background thread, returning a
  :class:`CheckpointWrite` handle; ``wait()`` is the barrier.
* ``save`` is the pre-format-2 synchronous-signature shim (warn-once).
* ``restore`` validates the manifest schema and shard set and raises a
  typed :class:`CorruptCheckpoint` on any torn/invalid step — then falls
  back to the previous complete step with a ``RuntimeWarning`` instead of
  dying mid-recovery.
* ``restore_sharded`` re-places host shards onto ANY mesh/sharding — the
  elastic-rescale path (a checkpoint taken on 256 chips restores onto 8).
* Retention: keep the most recent ``keep`` checkpoints; the newest
  *complete* manifest is never deleted.
* Measured costs: every save/restore appends ``{op, step, wall_s, bytes}``
  to ``timings`` — the chaos/fleet loops feed these wall-times back into
  their resize models instead of assuming a constant.
"""
from __future__ import annotations

import io as _io
import json
import shutil
import threading
import time
import warnings
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.telemetry.io import (
    atomic_write_bytes,
    atomic_write_json,
    atomic_write_text,
    file_lock,
)

FORMAT_VERSION = 2

# default shard sizing: one shard per ~64 MiB of leaf bytes, capped
_SHARD_BYTES = 64 << 20
_MAX_SHARDS = 16


class CorruptCheckpoint(RuntimeError):
    """A step directory failed validation: torn or unparseable manifest,
    schema/shard-count mismatch, or an unreadable shard file."""


def _flatten(tree, prefix="") -> Dict[str, Any]:
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}/"))
    elif isinstance(tree, (tuple, list)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}#{i}/"))
    else:
        out[prefix[:-1]] = tree
    return out


def _unflatten(flat: Dict[str, Any]):
    # rebuild nested dict/tuple structure from '/'-joined keys
    root: Dict = {}
    for key, val in flat.items():
        parts = key.split("/")
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = val

    def fix(node):
        if not isinstance(node, dict):
            return node
        if node and all(k.startswith("#") for k in node):
            items = sorted(node.items(), key=lambda kv: int(kv[0][1:]))
            return tuple(fix(v) for _, v in items)
        return {k: fix(v) for k, v in node.items()}

    return fix(root)


class CheckpointWrite:
    """Handle for one in-flight (or finished) checkpoint write."""

    def __init__(self, step: int):
        self.step = int(step)
        self.wall_s: Optional[float] = None   # set when the write commits
        self.nbytes = 0
        self.n_shards = 0
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def wait(self) -> "CheckpointWrite":
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err
        return self

    @property
    def done(self) -> bool:
        return self._thread is None or not self._thread.is_alive()


class CheckpointManager:
    def __init__(self, directory: str | Path, keep: int = 3,
                 async_write: bool = True, shard_bytes: int = _SHARD_BYTES,
                 max_shards: int = _MAX_SHARDS):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.async_write = async_write
        self.shard_bytes = int(shard_bytes)
        self.max_shards = int(max_shards)
        self._pending: Optional[CheckpointWrite] = None
        # measured wall-times, oldest first: {"op", "step", "wall_s", "bytes"}
        self.timings: List[Dict[str, Any]] = []

    # ------------------------------------------------------------------
    # save
    # ------------------------------------------------------------------
    def save_async(self, step: int, tree,
                   metadata: Optional[Dict] = None) -> CheckpointWrite:
        """Snapshot ``tree`` to host memory NOW and flush it to disk on a
        background writer thread.  Returns a handle; ``wait()``/the next
        ``save_async`` is the barrier (one outstanding write at a time)."""
        flat = _flatten(tree)
        host = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}
        meta = dict(metadata or {})
        meta["step"] = int(step)
        self.wait()  # one outstanding async write at a time
        handle = CheckpointWrite(step)
        if self.async_write:
            handle._thread = threading.Thread(
                target=self._write_guarded, args=(step, host, meta, handle),
                daemon=True)
            handle._thread.start()
            self._pending = handle
        else:
            self._write_guarded(step, host, meta, handle)
            handle.wait()
        return handle

    _warned_legacy_save = False

    def save(self, step: int, tree, metadata: Optional[Dict] = None,
             block: bool = False) -> None:
        """Pre-format-2 signature (synchronous when ``block`` or the manager
        was built with ``async_write=False``).  Warn-once shim over
        :meth:`save_async` so old chaos/fleet drivers replay unchanged."""
        if not CheckpointManager._warned_legacy_save:
            CheckpointManager._warned_legacy_save = True
            warnings.warn(
                "CheckpointManager.save(step, tree, block=...) is deprecated; "
                "use save_async(step, tree).wait() for a barrier",
                DeprecationWarning, stacklevel=2)
        handle = self.save_async(step, tree, metadata)
        if block:
            handle.wait()

    def wait(self) -> None:
        """Barrier: block until the in-flight write (if any) has committed."""
        if self._pending is not None:
            pending, self._pending = self._pending, None
            pending.wait()

    # ------------------------------------------------------------------
    @staticmethod
    def _to_savable(v: np.ndarray) -> np.ndarray:
        # numpy's npz can't represent ml_dtypes (bfloat16/fp8); store the raw
        # bits in a same-width integer view, true dtype kept in the manifest
        if v.dtype.kind == "V" or str(v.dtype) in ("bfloat16", "float8_e4m3fn",
                                                   "float8_e5m2"):
            return v.view({1: np.uint8, 2: np.uint16}[v.dtype.itemsize])
        return v

    def _partition(self, host: Dict[str, np.ndarray]) -> List[List[str]]:
        """Deterministic balanced key partition: big leaves first, each onto
        the lightest shard."""
        total = sum(v.nbytes for v in host.values())
        n = max(1, min(self.max_shards, len(host),
                       -(-total // max(self.shard_bytes, 1))))
        loads = [0] * n
        shards: List[List[str]] = [[] for _ in range(n)]
        for key in sorted(host, key=lambda k: (-host[k].nbytes, k)):
            i = min(range(n), key=lambda j: (loads[j], j))
            loads[i] += host[key].nbytes
            shards[i].append(key)
        return [sorted(s) for s in shards if s]

    def _write_guarded(self, step, host, meta, handle: CheckpointWrite):
        try:
            self._write(step, host, meta, handle)
        except BaseException as e:  # surfaced on wait(), not lost in the thread
            handle._error = e

    def _write(self, step: int, host: Dict[str, np.ndarray], meta: Dict,
               handle: CheckpointWrite):
        t0 = time.perf_counter()
        with file_lock(self.dir / ".ckpt.lock"):
            final = self.dir / f"step_{step:08d}"
            if final.exists() and not self._complete(final):
                shutil.rmtree(final)  # torn remains of a crashed writer
            final.mkdir(parents=True, exist_ok=True)
            shard_keys = self._partition(host)
            shard_index = []
            for i, keys in enumerate(shard_keys):
                buf = _io.BytesIO()
                np.savez(buf, **{k: self._to_savable(host[k]) for k in keys})
                atomic_write_bytes(final / f"shard_{i:04d}.npz", buf.getvalue())
                shard_index.append({
                    "file": f"shard_{i:04d}.npz",
                    "arrays": {k: {"shape": list(host[k].shape),
                                   "dtype": str(host[k].dtype)}
                               for k in keys},
                })
            manifest = {
                "format": FORMAT_VERSION,
                "step": int(step),
                "metadata": meta,
                "n_shards": len(shard_index),
                "shards": shard_index,
                "written_at": time.time(),
            }
            # the manifest is the commit point: written last, atomically
            atomic_write_json(final / "manifest.json", manifest)
            atomic_write_text(final / "COMMITTED", "ok")  # legacy marker
            self._gc()
        handle.nbytes = sum(v.nbytes for v in host.values())
        handle.n_shards = len(shard_index)
        handle.wall_s = time.perf_counter() - t0
        self.timings.append({"op": "save", "step": int(step),
                             "wall_s": handle.wall_s,
                             "bytes": handle.nbytes})

    def _gc(self) -> None:
        # never deletes the newest complete manifest: candidates are drawn
        # from the complete set, oldest first, keeping the last ``keep``
        steps = self.all_steps()
        for s in steps[: max(len(steps) - self.keep, 0)]:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    # ------------------------------------------------------------------
    # discovery / validation
    # ------------------------------------------------------------------
    @staticmethod
    def _manifest(path: Path) -> Dict:
        mpath = path / "manifest.json"
        if not mpath.exists():
            raise CorruptCheckpoint(f"{path.name}: no manifest")
        try:
            manifest = json.loads(mpath.read_text())
        except (json.JSONDecodeError, OSError) as e:
            raise CorruptCheckpoint(f"{path.name}: unreadable manifest: {e}")
        if not isinstance(manifest, dict) or "metadata" not in manifest:
            raise CorruptCheckpoint(f"{path.name}: manifest schema invalid")
        fmt = manifest.get("format", 1)
        if fmt > FORMAT_VERSION:
            raise CorruptCheckpoint(
                f"{path.name}: format {fmt} is newer than supported "
                f"({FORMAT_VERSION})")
        if fmt >= 2:
            shards = manifest.get("shards")
            if not isinstance(shards, list) or \
                    manifest.get("n_shards") != len(shards):
                raise CorruptCheckpoint(f"{path.name}: shard count mismatch")
            for entry in shards:
                if not (path / entry["file"]).exists():
                    raise CorruptCheckpoint(
                        f"{path.name}: missing shard {entry['file']}")
        else:  # format-1 layout: single arrays.npz + COMMITTED marker
            if "arrays" not in manifest:
                raise CorruptCheckpoint(f"{path.name}: manifest schema invalid")
            if not (path / "COMMITTED").exists() or \
                    not (path / "arrays.npz").exists():
                raise CorruptCheckpoint(f"{path.name}: uncommitted legacy step")
        return manifest

    def _complete(self, path: Path) -> bool:
        try:
            self._manifest(path)
            return True
        except CorruptCheckpoint:
            return False

    def all_steps(self) -> List[int]:
        steps = []
        for p in self.dir.glob("step_*"):
            if self._complete(p):
                steps.append(int(p.name.split("_")[1]))
        return sorted(steps)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    # ------------------------------------------------------------------
    # restore
    # ------------------------------------------------------------------
    @staticmethod
    def _load_npz(path: Path, dtypes: Dict[str, str]) -> Dict[str, np.ndarray]:
        try:
            with np.load(path) as z:
                flat = {}
                for k in z.files:
                    arr = z[k]
                    want = dtypes.get(k, str(arr.dtype))
                    if want != str(arr.dtype):
                        import ml_dtypes  # noqa: F401 — registers np views
                        arr = arr.view(np.dtype(want))
                    flat[k] = arr
            return flat
        except (OSError, ValueError, KeyError) as e:  # BadZipFile is OSError
            raise CorruptCheckpoint(f"{path.name}: unreadable shard: {e}")

    def _load_step(self, step: int) -> Tuple[Any, Dict]:
        path = self.dir / f"step_{step:08d}"
        if not path.exists():
            raise CorruptCheckpoint(f"step_{step:08d}: no such checkpoint")
        manifest = self._manifest(path)
        flat: Dict[str, np.ndarray] = {}
        if manifest.get("format", 1) >= 2:
            for entry in manifest["shards"]:
                dtypes = {k: v["dtype"] for k, v in entry["arrays"].items()}
                part = self._load_npz(path / entry["file"], dtypes)
                if set(part) != set(entry["arrays"]):
                    raise CorruptCheckpoint(
                        f"{path.name}/{entry['file']}: key set does not "
                        f"match manifest")
                flat.update(part)
        else:
            dtypes = {k: v["dtype"] for k, v in manifest["arrays"].items()}
            flat = self._load_npz(path / "arrays.npz", dtypes)
        return _unflatten(flat), manifest["metadata"]

    def restore(self, step: Optional[int] = None, *,
                fallback: bool = True) -> Tuple[Any, Dict]:
        """Returns (host tree, metadata).  A corrupt step falls back to the
        previous complete one with a ``RuntimeWarning`` (``fallback=False``
        raises the typed :class:`CorruptCheckpoint` instead)."""
        t0 = time.perf_counter()
        complete = self.all_steps()
        if step is None:
            if not complete:
                raise FileNotFoundError(f"no committed checkpoint in {self.dir}")
            candidates = list(reversed(complete))
        else:
            candidates = [step] + [s for s in reversed(complete) if s < step]
        last_err: Optional[CorruptCheckpoint] = None
        for i, s in enumerate(candidates):
            try:
                tree, meta = self._load_step(s)
            except CorruptCheckpoint as e:
                last_err = e
                if not fallback:
                    raise
                continue
            if i > 0:
                warnings.warn(
                    f"checkpoint step {candidates[0]} is corrupt "
                    f"({last_err}); fell back to step {s}", RuntimeWarning,
                    stacklevel=2)
            self.timings.append({"op": "restore", "step": int(s),
                                 "wall_s": time.perf_counter() - t0,
                                 "bytes": sum(np.asarray(v).nbytes for v in
                                              _flatten(tree).values())})
            return tree, meta
        assert last_err is not None
        raise last_err

    def restore_sharded(self, shardings, step: Optional[int] = None
                        ) -> Tuple[Any, Dict]:
        """Restore and place each leaf with the given sharding tree — works
        across DIFFERENT mesh shapes (elastic rescale)."""
        host, meta = self.restore(step)

        def place(x, sh):
            return jax.device_put(x, sh) if sh is not None else jax.device_put(x)

        placed = jax.tree.map(place, host, shardings)
        return placed, meta

    # ------------------------------------------------------------------
    def last_timing(self, op: str) -> Optional[Dict[str, Any]]:
        """Most recent measured wall-time entry for ``op`` ('save'/'restore')."""
        for entry in reversed(self.timings):
            if entry["op"] == op:
                return entry
        return None
