"""Sharded, atomic, async checkpointing with keep-k retention.

Layout:  <dir>/step_<N>/
           manifest.json        (flat key -> shape/dtype, metadata, data state)
           arrays.npz           (flattened '/'-joined key -> host array)
           COMMITTED            (written last -> atomic visibility)

* ``save`` gathers each leaf to host memory (per-shard in a real multi-host
  deployment — here addressable shards are assembled) and hands the write to
  a background thread; training continues (async checkpointing).
* ``restore`` returns host arrays + metadata; ``restore_sharded`` re-places
  them onto ANY mesh/sharding — this is the elastic-rescale path (a
  checkpoint taken on 256 chips restores onto 8, 32, 512, ...).
* Retention: keep the most recent ``keep`` COMMITTED checkpoints.
"""
from __future__ import annotations

import json
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten(tree, prefix="") -> Dict[str, Any]:
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}/"))
    elif isinstance(tree, (tuple, list)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}#{i}/"))
    else:
        out[prefix[:-1]] = tree
    return out


def _unflatten(flat: Dict[str, Any]):
    # rebuild nested dict/tuple structure from '/'-joined keys
    root: Dict = {}
    for key, val in flat.items():
        parts = key.split("/")
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = val

    def fix(node):
        if not isinstance(node, dict):
            return node
        if node and all(k.startswith("#") for k in node):
            items = sorted(node.items(), key=lambda kv: int(kv[0][1:]))
            return tuple(fix(v) for _, v in items)
        return {k: fix(v) for k, v in node.items()}

    return fix(root)


class CheckpointManager:
    def __init__(self, directory: str | Path, keep: int = 3,
                 async_write: bool = True):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.async_write = async_write
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------------
    def save(self, step: int, tree, metadata: Optional[Dict] = None,
             block: bool = False) -> None:
        flat = _flatten(tree)
        host = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}
        meta = dict(metadata or {})
        meta["step"] = int(step)
        self.wait()  # one outstanding async write at a time
        if self.async_write and not block:
            self._thread = threading.Thread(
                target=self._write, args=(step, host, meta), daemon=True)
            self._thread.start()
        else:
            self._write(step, host, meta)

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    @staticmethod
    def _to_savable(v: np.ndarray) -> np.ndarray:
        # numpy's npz can't represent ml_dtypes (bfloat16/fp8); store the raw
        # bits in a same-width integer view, true dtype kept in the manifest
        if v.dtype.kind == "V" or str(v.dtype) in ("bfloat16", "float8_e4m3fn",
                                                   "float8_e5m2"):
            return v.view({1: np.uint8, 2: np.uint16}[v.dtype.itemsize])
        return v

    def _write(self, step: int, host: Dict[str, np.ndarray], meta: Dict):
        final = self.dir / f"step_{step:08d}"
        tmp = self.dir / f".tmp_step_{step:08d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        np.savez(tmp / "arrays.npz",
                 **{k: self._to_savable(v) for k, v in host.items()})
        manifest = {
            "metadata": meta,
            "arrays": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                       for k, v in host.items()},
            "written_at": time.time(),
        }
        (tmp / "manifest.json").write_text(json.dumps(manifest, indent=2))
        (tmp / "COMMITTED").write_text("ok")
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)
        self._gc()

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: max(len(steps) - self.keep, 0)]:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    # ------------------------------------------------------------------
    def all_steps(self) -> List[int]:
        steps = []
        for p in self.dir.glob("step_*"):
            if (p / "COMMITTED").exists():
                steps.append(int(p.name.split("_")[1]))
        return sorted(steps)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: Optional[int] = None) -> Tuple[Any, Dict]:
        """Returns (host tree, metadata)."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {self.dir}")
        path = self.dir / f"step_{step:08d}"
        manifest = json.loads((path / "manifest.json").read_text())
        dtypes = {k: v["dtype"] for k, v in manifest["arrays"].items()}
        with np.load(path / "arrays.npz") as z:
            flat = {}
            for k in z.files:
                arr = z[k]
                want = dtypes.get(k, str(arr.dtype))
                if want != str(arr.dtype):
                    import ml_dtypes  # noqa: F401 — registers np views
                    arr = arr.view(np.dtype(want))
                flat[k] = arr
        return _unflatten(flat), manifest["metadata"]

    def restore_sharded(self, shardings, step: Optional[int] = None
                        ) -> Tuple[Any, Dict]:
        """Restore and place each leaf with the given sharding tree — works
        across DIFFERENT mesh shapes (elastic rescale)."""
        host, meta = self.restore(step)

        def place(x, sh):
            return jax.device_put(x, sh) if sh is not None else jax.device_put(x)

        placed = jax.tree.map(place, host, shardings)
        return placed, meta
