"""Typed, versioned event schema for the telemetry bus.

Every measurement in the repo — kernel tune results, serve engine step
timings, chaos training steps, fleet scheduler ticks, and the streaming
model-refit lifecycle — is one of the frozen dataclasses below.  Each
event carries:

* ``kind``      — registry key, serialized as ``"kind"``;
* ``schema_version`` — serialized as ``"v"``; readers reject rows from a
  *newer* schema than they understand and accept older ones;
* ``step``      — monotonic step / tick index within a run.

``from_legacy(kind, row)`` adapts the four pre-bus ad-hoc row shapes
into events, and ``Event.to_legacy()`` reproduces the original dict
bit-for-bit so golden-trace fixtures replay unchanged.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, ClassVar, Dict, List, Optional, Type

SCHEMA_VERSION = 1


class SchemaError(ValueError):
    """A serialized row does not match the event schema."""


_REGISTRY: Dict[str, Type["Event"]] = {}


def register(cls: Type["Event"]) -> Type["Event"]:
    """Class decorator: register an Event subclass under its ``kind``."""
    if not cls.kind:
        raise ValueError(f"{cls.__name__} must define a non-empty kind")
    if cls.kind in _REGISTRY:
        raise ValueError(f"duplicate event kind {cls.kind!r}")
    _REGISTRY[cls.kind] = cls
    return cls


def registered_kinds() -> List[str]:
    return sorted(_REGISTRY)


@dataclass(frozen=True)
class Event:
    """Base class for all telemetry events."""

    kind: ClassVar[str] = ""
    schema_version: ClassVar[int] = SCHEMA_VERSION

    def to_dict(self) -> dict:
        """Serialize to a JSON-ready dict with ``kind`` and ``v`` header."""
        d = {"kind": self.kind, "v": self.schema_version}
        for f in dataclasses.fields(self):
            d[f.name] = getattr(self, f.name)
        return d

    def to_legacy(self) -> dict:
        """Reproduce the pre-bus row shape.  Default: fields as-is."""
        return {f.name: getattr(self, f.name) for f in dataclasses.fields(self)}


def from_dict(d: dict) -> Event:
    """Deserialize a dict produced by ``Event.to_dict`` (or a JSONL row)."""
    if not isinstance(d, dict) or "kind" not in d:
        raise SchemaError(f"not an event row: {d!r}")
    kind = d["kind"]
    cls = _REGISTRY.get(kind)
    if cls is None:
        raise SchemaError(f"unknown event kind {kind!r}")
    v = d.get("v", 1)
    if v > cls.schema_version:
        raise SchemaError(f"event kind {kind!r} has schema v{v}, reader understands v{cls.schema_version}")
    names = {f.name for f in dataclasses.fields(cls)}
    required = {
        f.name
        for f in dataclasses.fields(cls)
        if f.default is dataclasses.MISSING and f.default_factory is dataclasses.MISSING
    }
    payload = {k: val for k, val in d.items() if k in names}
    missing = required - set(payload)
    if missing:
        raise SchemaError(f"event kind {kind!r} missing fields {sorted(missing)}")
    extra = {k for k in d if k not in names and k not in ("kind", "v")}
    if extra and "extra" in names:
        payload.setdefault("extra", {})
        payload["extra"] = {**{k: d[k] for k in sorted(extra)}, **payload["extra"]}
    return cls(**payload)


def from_legacy(kind: str, row: dict) -> Event:
    """Adapt one of the four legacy row shapes to a typed event."""
    cls = _REGISTRY.get(kind)
    if cls is None:
        raise SchemaError(f"unknown event kind {kind!r}")
    hook = getattr(cls, "from_legacy_row", None)
    if hook is None:
        raise SchemaError(f"event kind {kind!r} has no legacy adapter")
    return hook(row)


# ---------------------------------------------------------------------------
# kernel tune results (legacy: ConfigCache entry dicts)
# ---------------------------------------------------------------------------


@register
@dataclass(frozen=True)
class TuneEvent(Event):
    """One autotuner sweep result: best config + timing for a kernel shape."""

    kind: ClassVar[str] = "tune"

    family: str
    shape: Dict[str, Any]
    dtype: str
    backend: str
    config: Dict[str, Any]
    us_per_call: float
    swept: int = 0
    pruned: int = 0
    step: int = 0

    @classmethod
    def from_legacy_row(cls, row: dict) -> "TuneEvent":
        return cls(
            family=row["family"],
            shape=dict(row["shape"]),
            dtype=row["dtype"],
            backend=row["backend"],
            config=dict(row["config"]),
            us_per_call=row["us_per_call"],
            swept=row.get("candidates_swept", 0),
            pruned=row.get("candidates_pruned", 0),
        )

    def to_legacy(self) -> dict:
        return {
            "family": self.family,
            "shape": dict(self.shape),
            "dtype": self.dtype,
            "backend": self.backend,
            "config": dict(self.config),
            "us_per_call": self.us_per_call,
            "candidates_swept": self.swept,
            "candidates_pruned": self.pruned,
        }


# ---------------------------------------------------------------------------
# serve engine step telemetry (legacy: ServeEngine.telemetry dicts)
# ---------------------------------------------------------------------------


@register
@dataclass(frozen=True)
class ServeStepEvent(Event):
    """One serve-engine step: a prefill chunk, a decode step, or a
    speculative verify step.  ``op`` holds what the legacy rows called
    ``kind`` (that name is taken by the bus header)."""

    kind: ClassVar[str] = "serve_step"

    step: int
    step_s: float
    op: str  # "prefill" | "decode" | "verify"
    batch: int = 0
    committed: int = 0
    drafted: int = 0
    prefill_tokens: int = 0
    t_s: float = 0.0
    # emitting replica in a multi-engine (routed) deployment; -1 for a
    # standalone engine.  Additive field with a default: older rows parse
    # unchanged, and ``to_legacy`` never emits it (the pre-bus row shape
    # predates multi-replica serving).
    replica: int = -1

    @classmethod
    def from_legacy_row(cls, row: dict) -> "ServeStepEvent":
        op = row.get("kind", "decode")
        batch = int(row.get("batch", 0))
        return cls(
            step=int(row.get("step", 0)),
            step_s=float(row["step_s"]),
            op=op,
            batch=batch,
            committed=int(row.get("committed", batch if op != "prefill" else 0)),
            drafted=int(row.get("drafted", 0)),
            prefill_tokens=int(row.get("prefill_tokens", 0)),
            t_s=float(row.get("t_s", 0.0)),
        )

    def to_legacy(self) -> dict:
        if self.op == "prefill":
            return {
                "step": self.step,
                "batch": 0,
                "step_s": self.step_s,
                "kind": "prefill",
                "prefill_tokens": self.prefill_tokens,
            }
        row = {
            "step": self.step,
            "batch": self.batch,
            "step_s": self.step_s,
            "kind": self.op,
            "committed": self.committed,
        }
        if self.op == "verify":
            row["drafted"] = self.drafted
        return row


# ---------------------------------------------------------------------------
# router dispatch decisions (multi-replica serving; no legacy shape)
# ---------------------------------------------------------------------------


@register
@dataclass(frozen=True)
class RouterEvent(Event):
    """One routing decision: which replica got a request and why.

    ``reason`` is the dispatch rule that fired: ``"affinity"`` (longest
    cached-prefix owner won), ``"load"`` (no replica had cached pages;
    least-loaded won), or ``"spill"`` (the affinity winner was overloaded
    and the request overflowed to the least-loaded replica)."""

    kind: ClassVar[str] = "router"

    step: int  # arrival step of the dispatched request
    rid: int  # router-global request id
    replica: int  # chosen replica index
    matched_pages: int  # cached full prefix pages on the chosen replica
    best_affinity: int  # best cached-prefix match across ALL replicas
    reason: str  # "affinity" | "load" | "spill"
    prompt_pages: int = 0  # full pages in the request's prompt
    loads: List[int] = field(default_factory=list)  # pending tokens/replica


# ---------------------------------------------------------------------------
# chaos training steps (legacy: ChaosRunLog rows)
# ---------------------------------------------------------------------------

_CHAOS_OPTIONAL = (
    "objective",
    "restore",
    "step_s",
    "wall_s",
    "mitigation",
    "flag",
    "decision",
)


@register
@dataclass(frozen=True)
class ChaosStepEvent(Event):
    """One chaos-loop training step (or restore pause)."""

    kind: ClassVar[str] = "chaos_step"

    step: int
    m: int
    events: List[str] = field(default_factory=list)
    objective: Optional[float] = None
    restore: Optional[bool] = None
    step_s: Optional[float] = None
    wall_s: Optional[float] = None
    mitigation: Optional[str] = None
    flag: Optional[str] = None
    decision: Optional[str] = None
    extra: Dict[str, Any] = field(default_factory=dict)

    @classmethod
    def from_legacy_row(cls, row: dict) -> "ChaosStepEvent":
        known = {"step", "m", "events", *_CHAOS_OPTIONAL}
        return cls(
            step=row["step"],
            m=row["m"],
            events=list(row.get("events", [])),
            **{k: row[k] for k in _CHAOS_OPTIONAL if k in row},
            extra={k: row[k] for k in row if k not in known},
        )

    def to_legacy(self) -> dict:
        row: Dict[str, Any] = {"step": self.step, "m": self.m, "events": list(self.events)}
        for k in _CHAOS_OPTIONAL:
            v = getattr(self, k)
            if v is not None:
                row[k] = v
        row.update(self.extra)
        return row


# ---------------------------------------------------------------------------
# fleet scheduler ticks (legacy: FleetRunLog rows)
# ---------------------------------------------------------------------------


@register
@dataclass(frozen=True)
class FleetTickEvent(Event):
    """One fleet-scheduler tick: decisions plus per-tenant snapshots."""

    kind: ClassVar[str] = "fleet_tick"

    step: int
    events: List[str] = field(default_factory=list)
    decisions: List[str] = field(default_factory=list)
    serve: Dict[str, Any] = field(default_factory=dict)
    jobs: Dict[str, Any] = field(default_factory=dict)
    free: int = 0
    cost_hh: float = 0.0
    extra: Dict[str, Any] = field(default_factory=dict)

    @classmethod
    def from_legacy_row(cls, row: dict) -> "FleetTickEvent":
        known = {"step", "events", "decisions", "serve", "jobs", "free", "cost_hh"}
        return cls(
            step=row["step"],
            events=list(row.get("events", [])),
            decisions=list(row.get("decisions", [])),
            serve=row.get("serve", {}),
            jobs=row.get("jobs", {}),
            free=row.get("free", 0),
            cost_hh=row.get("cost_hh", 0.0),
            extra={k: row[k] for k in row if k not in known},
        )

    def to_legacy(self) -> dict:
        row: Dict[str, Any] = {
            "step": self.step,
            "events": list(self.events),
            "decisions": list(self.decisions),
            "serve": self.serve,
            "jobs": self.jobs,
            "free": self.free,
            "cost_hh": self.cost_hh,
        }
        row.update(self.extra)
        return row


# ---------------------------------------------------------------------------
# hierarchical trace spans + SLO burn-rate alerts (no legacy shape)
# ---------------------------------------------------------------------------


@register
@dataclass(frozen=True)
class SpanEvent(Event):
    """One timed scope in a hierarchical trace.

    ``trace_id``/``span_id``/``parent_id`` are deterministic hex digests
    derived from the run seed plus a monotonic per-tracer sequence — no
    wall-clock or randomness feeds the IDs, so traces from the same seed
    replay with bit-identical structure.  ``t0``/``dur`` are seconds
    relative to the tracer epoch; with the default wall clock they carry
    measured time, with an injected deterministic clock (modeled fleet
    time, or ``CountingClock`` in tests) the whole span stream — file
    bytes included — is reproducible.  ``predicted_s`` optionally holds
    the model's forecast for the scope (ErnestModel / CapacityPlanner /
    tune-cache kernel cost) so attribution can compare predicted vs
    measured per component."""

    kind: ClassVar[str] = "span"

    trace_id: str
    span_id: str
    name: str
    t0: float
    dur: float
    parent_id: str = ""
    component: str = ""
    step: int = 0
    replica: int = -1
    predicted_s: Optional[float] = None
    attrs: Dict[str, Any] = field(default_factory=dict)


@register
@dataclass(frozen=True)
class SloAlertEvent(Event):
    """A service-level objective is burning error budget too fast.

    Emitted by ``trace.slo.SLOMonitor`` when the bad-event fraction over
    the rolling window exceeds ``burn_threshold`` times the allowed
    budget.  ``burn_rate`` of 1.0 means the budget is being consumed
    exactly at the sustainable rate; 2x+ is the classic fast-burn page."""

    kind: ClassVar[str] = "slo_alert"

    step: int
    slo: str  # monitor name, e.g. "serve_bg" or "per_token"
    objective: str  # "join_to_first_token" | "per_token_latency" | ...
    target: float  # threshold a good observation must stay under
    burn_rate: float  # window bad-fraction / budget
    budget: float  # allowed bad fraction (error budget)
    window_bad: int  # bad observations in the rolling window
    window: int  # rolling window size
    budget_remaining: float = 1.0  # lifetime error budget left (0..1)


# ---------------------------------------------------------------------------
# checkpoint / migration costs (no legacy shape)
# ---------------------------------------------------------------------------


@register
@dataclass(frozen=True)
class CkptCostEvent(Event):
    """One measured checkpoint/restore/re-shard/migration wall-time.

    Emitted wherever the fault-tolerance machinery actually runs — the
    ``CheckpointManager`` writer thread, the chaos loop's restore path,
    ``serve.migrate``'s replica handoff, and the fleet scheduler's
    modeled recoveries — so planners can refit their *assumed* recovery
    constants from *measured* cost (``assumed_s`` records what the
    planner believed at the time, when known)."""

    kind: ClassVar[str] = "ckpt_cost"

    step: int
    op: str  # "save" | "restore" | "reshard" | "migrate"
    wall_s: float
    assumed_s: Optional[float] = None
    workload: str = ""  # job/deployment name, or "" for a standalone run
    nbytes: int = 0
    n_shards: int = 0
    replica: int = -1


# ---------------------------------------------------------------------------
# streaming-refit lifecycle
# ---------------------------------------------------------------------------


@register
@dataclass(frozen=True)
class DriftDetected(Event):
    """Normalized prediction error of a model exceeded its threshold."""

    kind: ClassVar[str] = "drift"

    step: int
    model: str
    residual: float
    threshold: float
    window: int


@register
@dataclass(frozen=True)
class RefitEvent(Event):
    """A streaming model was re-fit from a trailing observation window."""

    kind: ClassVar[str] = "refit"

    step: int
    model: str
    n_obs: int
    residual_before: float
    residual_after: float


@register
@dataclass(frozen=True)
class RunMeta(Event):
    """JSONL header event making an event log self-contained for replay."""

    kind: ClassVar[str] = "run_meta"

    log_type: str
    trace: Optional[Dict[str, Any]] = None
    meta: Dict[str, Any] = field(default_factory=dict)
    step: int = -1
