"""repro.telemetry — the typed tracker bus + streaming model refits.

One emit/sink API for every measurement the repo produces (DESIGN.md
§12).  The four pre-bus log formats — kernel tune cache rows, serve
engine step telemetry, chaos run logs, fleet tick logs — are now views
over a single typed event stream:

    from repro.telemetry import Tracker, JSONLSink, MemorySink

    tracker = Tracker([MemorySink(), JSONLSink("run.jsonl")])
    tracker.emit(ChaosStepEvent(step=0, m=2, objective=1.5))
    tracker.flush()

Inspect a log from the shell::

    python -m repro.telemetry summarize run.jsonl
    python -m repro.telemetry trace run.jsonl

Hierarchical span tracing, attribution, and SLO burn-rate monitoring
live in :mod:`repro.telemetry.trace` (DESIGN.md §14); the span/alert
event kinds are part of the core schema so any log replays.
"""

from .events import (
    SCHEMA_VERSION,
    ChaosStepEvent,
    CkptCostEvent,
    DriftDetected,
    Event,
    FleetTickEvent,
    RefitEvent,
    RouterEvent,
    RunMeta,
    SchemaError,
    ServeStepEvent,
    SloAlertEvent,
    SpanEvent,
    TuneEvent,
    from_dict,
    from_legacy,
    registered_kinds,
)
from .io import (
    append_jsonl,
    atomic_write_bytes,
    atomic_write_json,
    atomic_write_text,
    file_lock,
    read_jsonl,
)
from .refit import (
    DriftConfig,
    DriftDetector,
    StreamingCapacity,
    StreamingConvergence,
    StreamingCost,
    StreamingErnest,
)
from .tracker import (
    JSONLSink,
    MemorySink,
    P2Quantile,
    Sink,
    StatsSink,
    Tracker,
    default_tracker,
    log_from_device,
    read_events,
    reset_deprecation_warnings,
    set_default_tracker,
    warn_deprecated,
)

__all__ = [
    "SCHEMA_VERSION",
    "ChaosStepEvent",
    "CkptCostEvent",
    "DriftConfig",
    "DriftDetected",
    "DriftDetector",
    "Event",
    "FleetTickEvent",
    "JSONLSink",
    "MemorySink",
    "P2Quantile",
    "RefitEvent",
    "RouterEvent",
    "RunMeta",
    "SchemaError",
    "ServeStepEvent",
    "Sink",
    "SloAlertEvent",
    "SpanEvent",
    "StatsSink",
    "StreamingCapacity",
    "StreamingConvergence",
    "StreamingCost",
    "StreamingErnest",
    "Tracker",
    "TuneEvent",
    "append_jsonl",
    "atomic_write_bytes",
    "atomic_write_json",
    "atomic_write_text",
    "default_tracker",
    "file_lock",
    "from_dict",
    "from_legacy",
    "log_from_device",
    "read_events",
    "read_jsonl",
    "registered_kinds",
    "reset_deprecation_warnings",
    "set_default_tracker",
    "warn_deprecated",
]
