"""Streaming model refits + residual-based drift detection.

Hemingway's models (Ernest ``f(m)``, the convergence model ``g(i, m)``,
the serve ``CapacityPlanner``) are fit once from an offline profiling
pass.  This module makes them *streaming*: each wrapper keeps a sliding
window of live observations from the telemetry bus, watches the model's
normalized prediction error

    r_t = |actual_t - predicted_t| / max(|predicted_t|, eps)

averaged over the last ``window`` points, and when the mean residual
crosses ``threshold`` it raises a typed ``DriftDetected`` event and
re-fits the model from the trailing window — emitting a ``RefitEvent``
that records the residual before and after the refit, so callers can
assert the refit actually helped.

Nothing here imports serve/fleet modules at import time; the wrappers
are handed their model objects, which keeps the bus dependency-free and
cycle-free.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from dataclasses import dataclass
from typing import Deque, List, Optional, Tuple

import numpy as np

from .events import CkptCostEvent, DriftDetected, RefitEvent


@dataclass(frozen=True)
class DriftConfig:
    """Knobs for the residual-based drift detector."""

    window: int = 16  # sliding window of normalized residuals
    threshold: float = 0.3  # mean |err|/|pred| that counts as drift
    min_points: int = 6  # don't judge before this many observations
    cooldown: int = 24  # steps to stay quiet after firing
    eps: float = 1e-9  # normalization floor


class DriftDetector:
    """Sliding-window normalized prediction error vs a threshold."""

    def __init__(self, model_name: str, cfg: Optional[DriftConfig] = None):
        self.model_name = model_name
        self.cfg = cfg or DriftConfig()
        self._errs: Deque[float] = deque(maxlen=self.cfg.window)
        self._quiet_until = -1

    def residual(self) -> float:
        if not self._errs:
            return 0.0
        return float(np.mean(self._errs))

    def observe(self, step: int, predicted: float, actual: float) -> Optional[DriftDetected]:
        err = abs(actual - predicted) / max(abs(predicted), self.cfg.eps)
        self._errs.append(err)
        if len(self._errs) < self.cfg.min_points or step < self._quiet_until:
            return None
        resid = self.residual()
        if resid <= self.cfg.threshold:
            return None
        self._quiet_until = step + self.cfg.cooldown
        return DriftDetected(
            step=step,
            model=self.model_name,
            residual=resid,
            threshold=self.cfg.threshold,
            window=self.cfg.window,
        )

    def reset(self) -> None:
        self._errs.clear()


class StreamingErnest:
    """Windowed re-fit of an ErnestModel from live (m, size, time) points.

    The wrapped model is re-fit *in place* (``ErnestModel.fit`` mutates
    ``theta`` and returns ``self``), so handing this the controller's own
    model instance propagates refits to every consumer automatically.
    """

    def __init__(
        self,
        model,
        cfg: Optional[DriftConfig] = None,
        *,
        window: int = 64,
        refit_every: int = 0,
        name: str = "ernest",
    ):
        self.model = model
        self.name = name
        self.detector = DriftDetector(name, cfg)
        self._obs: Deque[Tuple[int, float, float]] = deque(maxlen=window)
        self.refit_every = refit_every
        self._since_fit = 0

    def _refit(self, step: int) -> Optional[RefitEvent]:
        if len(self._obs) < 2:
            return None
        m = np.array([o[0] for o in self._obs], dtype=float)
        size = np.array([o[1] for o in self._obs], dtype=float)
        t = np.array([o[2] for o in self._obs], dtype=float)
        if len(set(m.tolist())) < 2:
            return None  # NNLS needs variation in m to identify terms
        before = self.detector.residual()
        self.model.fit(m, size, t)
        pred = np.asarray(self.model.predict(m, size), dtype=float)
        after = float(np.mean(np.abs(t - pred) / np.maximum(np.abs(pred), self.detector.cfg.eps)))
        self._since_fit = 0
        return RefitEvent(
            step=step,
            model=self.name,
            n_obs=len(self._obs),
            residual_before=before,
            residual_after=after,
        )

    def observe(self, step: int, m: int, size: float, actual_s: float) -> List:
        """Feed one live measurement; returns drift/refit events raised."""
        pred = float(np.asarray(self.model.predict(np.array([m]), np.array([size])))[0])
        self._obs.append((m, size, actual_s))
        self._since_fit += 1
        out: List = []
        drift = self.detector.observe(step, pred, actual_s)
        if drift is not None:
            out.append(drift)
            refit = self._refit(step)
            if refit is not None:
                out.append(refit)
                self.detector.reset()
        elif self.refit_every and self._since_fit >= self.refit_every:
            refit = self._refit(step)
            if refit is not None:
                out.append(refit)
        return out


class StreamingCost:
    """Windowed estimate of an operation's measured wall-time vs an
    assumed planning constant.

    Planners (the fleet scheduler, ``AdaptiveController``) price every
    restore/re-shard with a fixed assumed constant.  This wrapper ingests
    the *measured* wall-times the fault-tolerance machinery actually
    reports (``ckpt_cost`` events), and when the drift detector sees the
    assumption is persistently wrong it re-fits the estimate to the
    trailing-window mean — ``estimate_s`` then answers with the learned
    cost instead of the assumption, and the refit event records how far
    off the assumption was.
    """

    def __init__(
        self,
        name: str,
        assumed_s: float,
        cfg: Optional[DriftConfig] = None,
        *,
        window: int = 32,
    ):
        self.name = name
        self.assumed_s = float(assumed_s)
        self.detector = DriftDetector(name, cfg)
        self._obs: Deque[float] = deque(maxlen=window)
        self.learned: Optional[float] = None

    @property
    def estimate_s(self) -> float:
        """The learned cost once refit; the assumed constant until then."""
        return self.learned if self.learned is not None else self.assumed_s

    def observe(self, step: int, measured_s: float, *, op: str = "restore", workload: str = "") -> List:
        """Feed one measured wall-time; returns [CkptCostEvent, drift?, refit?]."""
        self._obs.append(float(measured_s))
        out: List = [
            CkptCostEvent(
                step=step,
                op=op,
                wall_s=float(measured_s),
                assumed_s=self.estimate_s,
                workload=workload,
            )
        ]
        drift = self.detector.observe(step, self.estimate_s, measured_s)
        if drift is not None:
            out.append(drift)
            before = drift.residual
            self.learned = float(np.mean(self._obs))
            after = float(
                np.mean([abs(o - self.learned) / max(abs(self.learned), self.detector.cfg.eps) for o in self._obs])
            )
            out.append(
                RefitEvent(
                    step=step,
                    model=self.name,
                    n_obs=len(self._obs),
                    residual_before=before,
                    residual_after=after,
                )
            )
            self.detector.reset()
        return out


class StreamingCapacity:
    """Windowed re-fit of a CapacityPlanner's f(batch) step model."""

    def __init__(
        self,
        planner,
        cfg: Optional[DriftConfig] = None,
        *,
        window: int = 128,
        name: str = "capacity",
    ):
        self.planner = planner
        self.name = name
        self.detector = DriftDetector(name, cfg)
        self._obs: Deque[Tuple[int, float]] = deque(maxlen=window)

    def _refit(self, step: int) -> Optional[RefitEvent]:
        from repro.serve.planner import ServeObservation  # lazy: avoids an import cycle

        batches = {b for b, _ in self._obs}
        if len(batches) < 2:
            return None
        before = self.detector.residual()
        self.planner.observations = [ServeObservation(int(b), float(s)) for b, s in self._obs]
        self.planner.fit()
        errs = [
            abs(s - self.planner.step_time(b)) / max(abs(self.planner.step_time(b)), 1e-9)
            for b, s in self._obs
        ]
        after = float(np.mean(errs))
        return RefitEvent(
            step=step,
            model=self.name,
            n_obs=len(self._obs),
            residual_before=before,
            residual_after=after,
        )

    def observe(self, step: int, batch: int, step_s: float) -> List:
        self._obs.append((batch, step_s))
        if self.planner.step_model.theta is None:
            return []  # planner not fit yet — accumulate only
        pred = float(self.planner.step_time(batch))
        out: List = []
        drift = self.detector.observe(step, pred, step_s)
        if drift is not None:
            out.append(drift)
            refit = self._refit(step)
            if refit is not None:
                out.append(refit)
                self.detector.reset()
        return out


class StreamingConvergence:
    """Windowed re-fit of an AnalyticConvergence-style gap model.

    The analytic model is ``gap(i, m) = gap0 * exp(-rate * i / m**alpha)``
    (plateau ``p_star`` added back on top).  With ``alpha`` and ``p_star``
    held fixed, ``log gap = log gap0 - rate * (i / m**alpha)`` is linear
    in ``(1, i/m**alpha)`` — a two-parameter least-squares refit from the
    trailing window of (iteration, m, objective) points.
    """

    def __init__(
        self,
        model,
        cfg: Optional[DriftConfig] = None,
        *,
        window: int = 64,
        name: str = "convergence",
    ):
        self.model = model  # duck-typed: .p_star, .gap0, .rate, .alpha, .predict
        self.name = name
        self.detector = DriftDetector(name, cfg)
        self._obs: Deque[Tuple[float, int, float]] = deque(maxlen=window)

    def _refit(self, step: int) -> Optional[RefitEvent]:
        pts = [(i, m, v) for i, m, v in self._obs if v - self.model.p_star > 1e-12]
        if len(pts) < 4:
            return None
        before = self.detector.residual()
        x = np.array([i / (m**self.model.alpha) for i, m, _ in pts])
        y = np.log([v - self.model.p_star for _, _, v in pts])
        A = np.stack([np.ones_like(x), -x], axis=1)
        coef, *_ = np.linalg.lstsq(A, y, rcond=None)
        gap0 = float(np.exp(coef[0]))
        rate = max(float(coef[1]), 1e-9)
        self.model = dataclasses.replace(self.model, gap0=gap0, rate=rate)
        errs = []
        for i, m, v in pts:
            p = float(np.asarray(self.model.predict(i, m))[0])
            errs.append(abs(v - p) / max(abs(p), 1e-9))
        after = float(np.mean(errs))
        return RefitEvent(
            step=step,
            model=self.name,
            n_obs=len(pts),
            residual_before=before,
            residual_after=after,
        )

    def observe(self, step: int, iteration: float, m: int, objective: float) -> List:
        self._obs.append((iteration, m, objective))
        pred = float(np.asarray(self.model.predict(iteration, m))[0])
        out: List = []
        drift = self.detector.observe(step, pred, objective)
        if drift is not None:
            out.append(drift)
            refit = self._refit(step)
            if refit is not None:
                out.append(refit)
                self.detector.reset()
        return out
