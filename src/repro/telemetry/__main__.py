"""CLI: inspect a telemetry JSONL event log.

    python -m repro.telemetry summarize run.jsonl [--strict]
    python -m repro.telemetry trace run.jsonl [--perfetto out.json]

``summarize`` prints per-kind counts plus min/mean/max and streaming
p50/p95/p99 of every numeric field, and — when the log came from a routed
deployment — a per-replica breakdown (decode tok/s, dispatch share,
affinity hit rate).  With ``--strict``, any schema-invalid row fails the
command (exit 1) — the CI telemetry smoke step uses this to assert a
fresh run log is well-formed.

``trace`` renders the hierarchical span tree a ``--trace`` serve run (or
a ``--spans`` fleet run) logged, with per-component predicted-vs-measured
attribution; ``--perfetto`` re-exports the spans as a Chrome/Perfetto
trace, ``--flame`` adds the self-time flame summary, and ``--tune-cache``
joins kernel-tuner entries in as per-kernel attribution rows.
"""

from __future__ import annotations

import argparse
import json
import sys
from typing import List

from .events import Event, SchemaError, from_dict
from .tracker import StatsSink


def _replica_breakdown(events: List[Event]) -> None:
    """Per-replica serving summary from replica-tagged serve_step rows
    plus router dispatch decisions; silent for single-engine logs."""
    steps = [e for e in events if e.kind == "serve_step" and e.replica >= 0]
    routes = [e for e in events if e.kind == "router"]
    if not steps and not routes:
        return
    replicas = sorted(
        {e.replica for e in steps} | {e.replica for e in routes}
    )
    print("per-replica:")
    for r in replicas:
        mine = [e for e in steps if e.replica == r]
        decode = [e for e in mine if e.op in ("decode", "verify")]
        busy = sum(e.step_s for e in decode)
        toks = sum(e.committed for e in decode)
        tok_s = toks / busy if busy > 0 else 0.0
        disp = [e for e in routes if e.replica == r]
        routable = [e for e in disp if e.prompt_pages > 0]
        hits = sum(1 for e in routable if e.matched_pages > 0)
        rate = hits / len(routable) if routable else 0.0
        print(
            f"  replica {r}: {toks} tokens in {busy:.3f}s "
            f"({tok_s:.1f} tok/s), dispatches={len(disp)}, "
            f"affinity_hit_rate={rate:.2f}"
        )
    spills = sum(1 for e in routes if e.reason == "spill")
    if routes:
        print(f"  router: {len(routes)} dispatches, {spills} spills")


def summarize(path: str, strict: bool = False) -> int:
    stats = StatsSink()
    events: List[Event] = []
    bad = 0
    with open(path) as f:
        for lineno, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            try:
                ev = from_dict(json.loads(line))
            except (SchemaError, json.JSONDecodeError) as e:
                bad += 1
                print(f"{path}:{lineno}: invalid row: {e}", file=sys.stderr)
                continue
            stats.write(ev)
            events.append(ev)
    for kind, info in stats.summary().items():
        print(f"{kind:<12} n={info['count']}")
        for name, agg in info["fields"].items():
            line = (
                f"  {name:<16} mean={agg['mean']:.6g} "
                f"min={agg['min']:.6g} max={agg['max']:.6g}"
            )
            if "p50" in agg:
                line += (
                    f" p50={agg['p50']:.6g} p95={agg['p95']:.6g} "
                    f"p99={agg['p99']:.6g}"
                )
            print(line)
    _replica_breakdown(events)
    total = sum(stats.counts.values())
    print(f"total        {total} events, {bad} invalid rows")
    return 1 if (strict and bad) else 0


def trace(
    path: str,
    perfetto: str = "",
    flame: bool = False,
    tune_cache: str = "",
    n_layers: int = 1,
) -> int:
    from .tracker import read_events
    from .trace import (
        attribute,
        flame_summary,
        format_attribution,
        format_tree,
        write_perfetto,
    )

    events: List[Event] = list(read_events(path))
    if tune_cache:
        from repro.kernels.tune.cache import ConfigCache
        from repro.kernels.tune.telemetry import tune_events

        events.extend(tune_events(ConfigCache(tune_cache)))
    spans = [e for e in events if e.kind == "span"]
    if not spans:
        print(f"{path}: no span events (run with --trace / --spans)",
              file=sys.stderr)
        return 1
    print(format_tree(events))
    # a planner refit from the log's own serve_step rows prices decode /
    # verify spans that did not carry predicted_s at emit time
    planner = None
    try:
        from repro.serve.planner import CapacityPlanner

        p = CapacityPlanner()
        p.ingest(events)
        p.fit()
        p.step_time(1)
        planner = p
    except Exception:
        planner = None
    attr = attribute(events, planner=planner, n_layers=n_layers)
    print(format_attribution(attr))
    if flame:
        print(flame_summary(events))
    alerts = [e for e in events if e.kind == "slo_alert"]
    for a in alerts:
        print(
            f"slo_alert step {a.step} {a.slo}/{a.objective}: "
            f"burn={a.burn_rate:.2f}x budget "
            f"(remaining {a.budget_remaining:.0%})"
        )
    if perfetto:
        n = write_perfetto(perfetto, events)
        print(f"perfetto: {n} spans -> {perfetto}")
    return 0


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(prog="python -m repro.telemetry")
    sub = parser.add_subparsers(dest="cmd", required=True)
    p_sum = sub.add_parser("summarize", help="per-kind stats for a JSONL event log")
    p_sum.add_argument("path")
    p_sum.add_argument("--strict", action="store_true", help="exit 1 on schema-invalid rows")
    p_tr = sub.add_parser("trace", help="span tree + cost attribution for a JSONL event log")
    p_tr.add_argument("path")
    p_tr.add_argument("--perfetto", default="", metavar="OUT_JSON",
                      help="also export the spans as a Perfetto/Chrome trace")
    p_tr.add_argument("--flame", action="store_true",
                      help="print the per-component self-time flame summary")
    p_tr.add_argument("--tune-cache", default="", metavar="CACHE_JSON",
                      help="join kernel-tuner cache entries as attribution rows")
    p_tr.add_argument("--n-layers", type=int, default=1,
                      help="model depth for per-kernel predicted cost rows")
    args = parser.parse_args(argv)
    if args.cmd == "summarize":
        return summarize(args.path, strict=args.strict)
    if args.cmd == "trace":
        return trace(
            args.path,
            perfetto=args.perfetto,
            flame=args.flame,
            tune_cache=args.tune_cache,
            n_layers=args.n_layers,
        )
    return 2


if __name__ == "__main__":
    raise SystemExit(main())
