"""CLI: inspect a telemetry JSONL event log.

    python -m repro.telemetry summarize run.jsonl [--strict]

Prints per-kind counts plus min/mean/max of every numeric field.  With
``--strict``, any schema-invalid row fails the command (exit 1) — the CI
telemetry smoke step uses this to assert a fresh run log is well-formed.
"""

from __future__ import annotations

import argparse
import json
import sys

from .events import SchemaError, from_dict
from .tracker import StatsSink


def summarize(path: str, strict: bool = False) -> int:
    stats = StatsSink()
    bad = 0
    with open(path) as f:
        for lineno, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            try:
                stats.write(from_dict(json.loads(line)))
            except (SchemaError, json.JSONDecodeError) as e:
                bad += 1
                print(f"{path}:{lineno}: invalid row: {e}", file=sys.stderr)
    for kind, info in stats.summary().items():
        print(f"{kind:<12} n={info['count']}")
        for name, agg in info["fields"].items():
            print(
                f"  {name:<16} mean={agg['mean']:.6g} "
                f"min={agg['min']:.6g} max={agg['max']:.6g}"
            )
    total = sum(stats.counts.values())
    print(f"total        {total} events, {bad} invalid rows")
    return 1 if (strict and bad) else 0


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(prog="python -m repro.telemetry")
    sub = parser.add_subparsers(dest="cmd", required=True)
    p_sum = sub.add_parser("summarize", help="per-kind stats for a JSONL event log")
    p_sum.add_argument("path")
    p_sum.add_argument("--strict", action="store_true", help="exit 1 on schema-invalid rows")
    args = parser.parse_args(argv)
    if args.cmd == "summarize":
        return summarize(args.path, strict=args.strict)
    return 2


if __name__ == "__main__":
    raise SystemExit(main())
