"""Tracker facade + composable sinks.

A ``Tracker`` is the single write API for telemetry: every subsystem
calls ``tracker.emit(event)`` and the attached sinks decide what happens
— keep it in memory (``MemorySink``), append it to a JSONL file with an
atomic write (``JSONLSink``), or fold it into running aggregates
(``StatsSink``).  Sinks are tiny and composable; a tracker with a
memory sink is the in-process default so existing run logs keep their
``rows``-style readers as thin views over the event stream.

``log_from_device`` bridges jit-compiled code to the bus via
``jax.debug.callback`` — host-side emission that stays off the hot path
(the callback fires asynchronously and carries only small scalars).
"""

from __future__ import annotations

import json
import warnings
from collections import deque
from typing import Any, Callable, Dict, Iterable, List, Optional, Sequence

from . import io as tio
from .events import Event, from_dict


class Sink:
    """Interface for event consumers attached to a Tracker."""

    def write(self, event: Event) -> None:  # pragma: no cover - interface
        raise NotImplementedError

    def flush(self) -> None:
        pass

    def close(self) -> None:
        self.flush()

    def __enter__(self) -> "Sink":
        return self

    def __exit__(self, *exc: Any) -> None:
        self.close()


class MemorySink(Sink):
    """Keep events in memory (optionally a bounded ring)."""

    def __init__(self, maxlen: Optional[int] = None):
        self._events: deque = deque(maxlen=maxlen)

    def write(self, event: Event) -> None:
        self._events.append(event)

    def events(self, kind: Optional[str] = None) -> List[Event]:
        if kind is None:
            return list(self._events)
        return [e for e in self._events if e.kind == kind]

    def __len__(self) -> int:
        return len(self._events)


class JSONLSink(Sink):
    """Buffer events and flush them to a JSONL file via atomic append."""

    def __init__(self, path, flush_every: int = 64):
        self.path = path
        self.flush_every = max(1, int(flush_every))
        self._buf: List[str] = []
        self.written = 0

    def write(self, event: Event) -> None:
        self._buf.append(json.dumps(event.to_dict(), sort_keys=True))
        if len(self._buf) >= self.flush_every:
            self.flush()

    def flush(self) -> None:
        if self._buf:
            self.written += tio.append_jsonl(self.path, self._buf)
            self._buf = []


class P2Quantile:
    """Streaming quantile estimate via the P² algorithm (Jain & Chlamtac).

    Five markers track the target quantile without buffering the stream;
    below five observations the estimate is exact (sorted lookup).  Each
    ``observe`` is O(1), so a sink can afford one estimator per numeric
    field per kind."""

    def __init__(self, p: float):
        if not 0.0 < p < 1.0:
            raise ValueError(f"quantile must be in (0, 1), got {p}")
        self.p = p
        self._n = 0
        self._q: List[float] = []  # marker heights
        self._pos: List[float] = []  # marker positions (1-based)

    def observe(self, x: float) -> None:
        x = float(x)
        self._n += 1
        if self._n <= 5:
            self._q.append(x)
            self._q.sort()
            if self._n == 5:
                self._pos = [1.0, 2.0, 3.0, 4.0, 5.0]
            return
        q, pos, p = self._q, self._pos, self.p
        if x < q[0]:
            q[0] = x
            k = 0
        elif x >= q[4]:
            q[4] = x
            k = 3
        else:
            k = 0
            while k < 3 and x >= q[k + 1]:
                k += 1
        for i in range(k + 1, 5):
            pos[i] += 1.0
        n = pos[4]
        # desired positions for the five markers at stream length n
        desired = [
            1.0,
            1.0 + (n - 1) * p / 2.0,
            1.0 + (n - 1) * p,
            1.0 + (n - 1) * (1.0 + p) / 2.0,
            n,
        ]
        for i in (1, 2, 3):
            d = desired[i] - pos[i]
            if (d >= 1.0 and pos[i + 1] - pos[i] > 1.0) or (d <= -1.0 and pos[i - 1] - pos[i] < -1.0):
                d = 1.0 if d >= 0 else -1.0
                # parabolic (piecewise-quadratic) prediction of the new height
                qi = q[i] + d / (pos[i + 1] - pos[i - 1]) * (
                    (pos[i] - pos[i - 1] + d) * (q[i + 1] - q[i]) / (pos[i + 1] - pos[i])
                    + (pos[i + 1] - pos[i] - d) * (q[i] - q[i - 1]) / (pos[i] - pos[i - 1])
                )
                if not q[i - 1] < qi < q[i + 1]:
                    # parabola escaped the bracket: fall back to linear
                    j = i + (1 if d > 0 else -1)
                    qi = q[i] + d * (q[j] - q[i]) / (pos[j] - pos[i])
                q[i] = qi
                pos[i] += d

    def value(self) -> float:
        if self._n == 0:
            return float("nan")
        if self._n <= 5:
            # exact while the sample fits in the marker buffer
            s = sorted(self._q)
            idx = self.p * (len(s) - 1)
            lo = int(idx)
            hi = min(lo + 1, len(s) - 1)
            return s[lo] + (idx - lo) * (s[hi] - s[lo])
        return self._q[2]

    @property
    def n(self) -> int:
        return self._n


#: percentiles every StatsSink tracks per numeric field
STATS_PERCENTILES = (0.5, 0.95, 0.99)


class StatsSink(Sink):
    """Fold events into per-kind counts and numeric-field aggregates.

    Besides min/mean/max, each numeric field carries streaming
    p50/p95/p99 estimates (P² — constant memory, no buffering), so
    ``summarize`` and SLO reports see real latency percentiles."""

    def __init__(self):
        self.counts: Dict[str, int] = {}
        self._sums: Dict[str, Dict[str, float]] = {}
        self._mins: Dict[str, Dict[str, float]] = {}
        self._maxs: Dict[str, Dict[str, float]] = {}
        self._quant: Dict[str, Dict[str, Dict[float, P2Quantile]]] = {}

    def write(self, event: Event) -> None:
        k = event.kind
        self.counts[k] = self.counts.get(k, 0) + 1
        sums = self._sums.setdefault(k, {})
        mins = self._mins.setdefault(k, {})
        maxs = self._maxs.setdefault(k, {})
        quant = self._quant.setdefault(k, {})
        for name, v in event.to_dict().items():
            if name in ("kind", "v") or isinstance(v, bool) or not isinstance(v, (int, float)):
                continue
            sums[name] = sums.get(name, 0.0) + v
            mins[name] = min(mins.get(name, v), v)
            maxs[name] = max(maxs.get(name, v), v)
            est = quant.setdefault(name, {p: P2Quantile(p) for p in STATS_PERCENTILES})
            for q in est.values():
                q.observe(v)

    def summary(self) -> Dict[str, Dict[str, Any]]:
        out: Dict[str, Dict[str, Any]] = {}
        for k, n in sorted(self.counts.items()):
            fields = {}
            for name, s in sorted(self._sums[k].items()):
                fields[name] = {
                    "mean": s / n,
                    "min": self._mins[k][name],
                    "max": self._maxs[k][name],
                }
                for p, est in self._quant[k][name].items():
                    fields[name][f"p{int(p * 100)}"] = est.value()
            out[k] = {"count": n, "fields": fields}
        return out


class Tracker:
    """The one emit API.  Fans each event out to every attached sink."""

    def __init__(self, sinks: Optional[Sequence[Sink]] = None):
        if sinks is None:
            sinks = [MemorySink()]
        self.sinks: List[Sink] = list(sinks)

    # -- write side ---------------------------------------------------------

    def emit(self, event: Event) -> Event:
        for s in self.sinks:
            s.write(event)
        return event

    def emit_many(self, events: Iterable[Event]) -> int:
        n = 0
        for e in events:
            self.emit(e)
            n += 1
        return n

    def flush(self) -> None:
        for s in self.sinks:
            s.flush()

    def close(self) -> None:
        for s in self.sinks:
            s.close()

    def __enter__(self) -> "Tracker":
        return self

    def __exit__(self, *exc: Any) -> None:
        self.close()

    # -- read side (delegates to the first capable sink) --------------------

    def _memory(self) -> Optional[MemorySink]:
        for s in self.sinks:
            if isinstance(s, MemorySink):
                return s
        return None

    def events(self, kind: Optional[str] = None) -> List[Event]:
        mem = self._memory()
        if mem is None:
            return []
        return mem.events(kind)

    def summary(self) -> Dict[str, Dict[str, Any]]:
        for s in self.sinks:
            if isinstance(s, StatsSink):
                return s.summary()
        stats = StatsSink()
        for e in self.events():
            stats.write(e)
        return stats.summary()

    def to_jsonl(self, path, header: Optional[Event] = None) -> int:
        """Dump buffered events (plus optional header) to a JSONL file."""
        events: List[Event] = list(self.events())
        if header is not None:
            events = [header] + events
        return tio.append_jsonl(path, [json.dumps(e.to_dict(), sort_keys=True) for e in events])


def read_events(path) -> List[Event]:
    """Parse a JSONL event log back into typed events.

    A torn *trailing* line (a writer died mid-append between flush
    boundaries) is skipped with a warning instead of raising — every
    complete row before it is still returned.  Malformed JSON anywhere
    else in the file is still an error: that is corruption, not a torn
    tail."""
    with open(path, "r", encoding="utf-8") as f:
        lines = f.read().splitlines()
    out: List[Event] = []
    last = len(lines) - 1
    for i, line in enumerate(lines):
        s = line.strip()
        if not s:
            continue
        try:
            d = json.loads(s)
        except json.JSONDecodeError:
            if i == last:
                warnings.warn(
                    f"{path}: skipping torn trailing line ({len(s)} bytes)",
                    RuntimeWarning,
                    stacklevel=2,
                )
                continue
            raise
        out.append(from_dict(d))
    return out


def log_from_device(
    tracker: Tracker,
    make_event: Callable[..., Event],
    *args: Any,
    ordered: bool = False,
) -> None:
    """Emit an event from inside jit-compiled code.

    ``make_event`` runs host-side under ``jax.debug.callback`` with the
    traced ``args`` materialized as concrete arrays; it must build the
    Event (converting scalars with ``int``/``float``).  Keep this off
    per-step hot paths — it is for sparse diagnostics, not inner loops.

    With ``ordered=True`` the callback is sequenced with every other
    ordered callback in the computation, so multiple emissions inside
    one jitted step land on the bus in program order — required when the
    events form a span hierarchy or any reader assumes emit order.
    """
    import jax  # local import: the bus itself has no jax dependency

    def _cb(*vals):
        tracker.emit(make_event(*vals))

    jax.debug.callback(_cb, *args, ordered=ordered)


_DEFAULT: Optional[Tracker] = None


def default_tracker() -> Tracker:
    """Process-wide tracker for emitters with no explicit bus wired in."""
    global _DEFAULT
    if _DEFAULT is None:
        _DEFAULT = Tracker([MemorySink(maxlen=4096)])
    return _DEFAULT


def set_default_tracker(tracker: Optional[Tracker]) -> Optional[Tracker]:
    global _DEFAULT
    prev = _DEFAULT
    _DEFAULT = tracker
    return prev


# ---------------------------------------------------------------------------
# one-release deprecation shim helper
# ---------------------------------------------------------------------------

_WARNED: set = set()


def warn_deprecated(old: str, new: str) -> None:
    """Warn once per process that ``old`` is deprecated in favor of ``new``."""
    if old in _WARNED:
        return
    _WARNED.add(old)
    warnings.warn(
        f"{old} is deprecated and will be removed next release; use {new} instead",
        DeprecationWarning,
        stacklevel=3,
    )


def reset_deprecation_warnings() -> None:
    """Test hook: make every deprecation warn again."""
    _WARNED.clear()
