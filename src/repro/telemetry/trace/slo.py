"""SLO error-budget + burn-rate monitoring over latency objectives.

An ``SLOMonitor`` watches one objective — join-to-first-token steps,
per-token decode latency, fleet tick p95 — as a stream of observations.
Each observation is *good* (under ``target``) or *bad*; the allowed bad
fraction is the error budget.  When the bad fraction over the rolling
window exceeds ``burn_threshold`` times the budget, the monitor emits a
typed ``SloAlertEvent``: the classic SRE fast-burn page.

Why this beats the drift detector to the punch: the PR-7
``DriftDetector`` needs a *window mean* of normalized residuals to cross
its threshold (``min_points`` sustained observations), while a burn-rate
monitor fires as soon as a couple of bad points land in a short window.
On the golden 2x-slowdown scenario the SLO alert lands several steps
before drift — early warning the ``CapacityPlanner`` and the fleet
autoscaler consume (extra headroom) while the refit loop catches up.
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass
from typing import Deque, Iterable, List, Optional

from ..events import Event, SloAlertEvent


@dataclass(frozen=True)
class SloConfig:
    """Tunables for one SLO objective.

    ``budget`` is the allowed bad fraction (0.05 = 95% of observations
    must meet ``target``); ``burn_threshold`` is how many times the
    sustainable burn rate triggers an alert (2x = classic fast burn)."""

    target: float
    budget: float = 0.05
    window: int = 16
    burn_threshold: float = 2.0
    min_points: int = 4
    cooldown: int = 16

    def __post_init__(self):
        if self.target <= 0.0:
            raise ValueError(f"target must be positive, got {self.target}")
        if not 0.0 < self.budget < 1.0:
            raise ValueError(f"budget must be in (0, 1), got {self.budget}")


class SLOMonitor:
    """Rolling error-budget accountant for one latency objective."""

    def __init__(self, cfg: SloConfig, *, name: str = "slo", objective: str = "latency"):
        self.cfg = cfg
        self.name = name
        self.objective = objective
        self._window: Deque[bool] = deque(maxlen=cfg.window)
        self._seen = 0
        self._bad = 0
        self._last_alert_step: Optional[int] = None
        self.alerts: List[SloAlertEvent] = []

    # -- accounting ----------------------------------------------------------

    @property
    def burn_rate(self) -> float:
        """Window bad-fraction divided by the budget (1.0 = sustainable)."""
        if not self._window:
            return 0.0
        bad = sum(self._window)
        return (bad / len(self._window)) / self.cfg.budget

    def budget_remaining(self) -> float:
        """Lifetime error budget left, 1.0 (untouched) down to 0.0 (spent)."""
        if not self._seen:
            return 1.0
        consumed = (self._bad / self._seen) / self.cfg.budget
        return max(0.0, 1.0 - consumed)

    # -- observation ---------------------------------------------------------

    def observe(self, step: int, value: float) -> Optional[SloAlertEvent]:
        """Feed one measurement; returns an alert iff one fires this step."""
        bad = float(value) > self.cfg.target
        self._window.append(bad)
        self._seen += 1
        self._bad += int(bad)
        if len(self._window) < self.cfg.min_points:
            return None
        if self.burn_rate < self.cfg.burn_threshold:
            return None
        if self._last_alert_step is not None and step - self._last_alert_step < self.cfg.cooldown:
            return None
        self._last_alert_step = step
        alert = SloAlertEvent(
            step=int(step),
            slo=self.name,
            objective=self.objective,
            target=self.cfg.target,
            burn_rate=self.burn_rate,
            budget=self.cfg.budget,
            window_bad=int(sum(self._window)),
            window=len(self._window),
            budget_remaining=self.budget_remaining(),
        )
        self.alerts.append(alert)
        return alert


def monitor_serve_events(
    events: Iterable[Event],
    *,
    per_token: Optional[SloConfig] = None,
    join_first_token: Optional[SloConfig] = None,
    name: str = "serve",
) -> List[SloAlertEvent]:
    """Replay a serve event stream through SLO monitors; return alerts.

    * ``per_token`` watches ``serve_step`` decode/verify latency per
      committed token (seconds);
    * ``join_first_token`` watches request join-to-first-token in steps,
      read from ``span`` events the scheduler emits at admission
      (``scheduler.join`` spans carry ``wait_steps``).
    """
    alerts: List[SloAlertEvent] = []
    tok = SLOMonitor(per_token, name=name, objective="per_token_latency") if per_token else None
    join = (
        SLOMonitor(join_first_token, name=name, objective="join_to_first_token")
        if join_first_token
        else None
    )
    for ev in events:
        kind = getattr(ev, "kind", None)
        if tok is not None and kind == "serve_step" and ev.op in ("decode", "verify"):
            committed = max(int(ev.committed), 1)
            a = tok.observe(int(ev.step), float(ev.step_s) / committed)
            if a is not None:
                alerts.append(a)
        elif join is not None and kind == "span" and ev.component == "scheduler.join":
            wait = ev.attrs.get("wait_steps")
            if wait is not None:
                a = join.observe(int(ev.step), float(wait))
                if a is not None:
                    alerts.append(a)
    return alerts
