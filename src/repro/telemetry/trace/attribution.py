"""Attribution: roll a span trace up into predicted-vs-measured rows.

Hemingway's models forecast *aggregate* pace; when the forecast misses,
this module says **where**.  Each instrumented component becomes one row
comparing the model's prediction against the measured span time:

* spans that carry ``predicted_s`` (decode/verify steps priced by the
  fitted ``CapacityPlanner``, fleet jobs priced by the pace model)
  contribute directly;
* kernel rows come from the autotuner cache: a ``tune`` event for the
  paged decode kernel predicts a decode step as
  ``n_layers * us_per_call * 1e-6``, compared against the measured
  decode spans at the same batch.

``ratio = measured / predicted`` localizes drift — a healthy component
sits near 1.0, the component hosting a 2x slowdown sits near 2.0 while
everything else stays flat.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

from ..events import Event, SpanEvent, TuneEvent
from .export import span_roots


@dataclass
class ComponentRow:
    """One attribution line: a component's measured vs predicted time."""

    component: str
    n: int
    measured_s: float
    predicted_s: Optional[float] = None  # None: no model priced this scope
    share: float = 0.0  # fraction of total measured span time

    @property
    def ratio(self) -> Optional[float]:
        if self.predicted_s is None or self.predicted_s <= 0.0:
            return None
        return self.measured_s / self.predicted_s


@dataclass
class Attribution:
    """The rolled-up report plus reconciliation against engine wall time."""

    rows: List[ComponentRow] = field(default_factory=list)
    total_measured_s: float = 0.0  # sum over root spans
    n_spans: int = 0

    def row(self, component: str) -> Optional[ComponentRow]:
        for r in self.rows:
            if r.component == component:
                return r
        return None

    def reconcile(self, engine_busy_s: float, *, tol: float = 0.05) -> bool:
        """Do root span durations agree with measured engine wall time?

        The engine instruments the same scopes its ``serve_step`` events
        time, so the two totals must match within ``tol`` (default the
        acceptance bound, 5%)."""
        if engine_busy_s <= 0.0:
            return self.total_measured_s == 0.0
        return abs(self.total_measured_s - engine_busy_s) / engine_busy_s <= tol

    def worst_ratio(self) -> Optional[ComponentRow]:
        """The component whose measured/predicted ratio diverges most
        from 1.0 — where the drift lives."""
        priced = [r for r in self.rows if r.ratio is not None]
        if not priced:
            return None
        return max(priced, key=lambda r: abs(math.log(max(r.ratio, 1e-12))))


def attribute(
    events: Sequence[Event],
    *,
    planner=None,
    n_layers: int = 1,
    kernel_family: str = "flash_decode_paged",
) -> Attribution:
    """Roll spans (and tune-cache kernel rows) into an Attribution.

    ``planner`` (a fitted ``CapacityPlanner``) prices decode/verify spans
    that carry a ``batch`` attr but no inline ``predicted_s``.  ``tune``
    events present in the stream produce ``kernel/`` rows comparing the
    autotuned kernel cost (scaled by ``n_layers``) against measured
    decode spans at the same batch."""
    spans = [e for e in events if isinstance(e, SpanEvent)]
    tunes = [e for e in events if isinstance(e, TuneEvent)]

    def _predict(s: SpanEvent) -> Optional[float]:
        if s.predicted_s is not None:
            return s.predicted_s
        if planner is not None and s.component in ("engine.decode", "engine.verify"):
            batch = s.attrs.get("batch")
            if batch:
                try:
                    return float(planner.step_time(int(batch)))
                except Exception:
                    return None
        return None

    meas: Dict[str, float] = {}
    pred: Dict[str, float] = {}
    pred_n: Dict[str, int] = {}
    counts: Dict[str, int] = {}
    for s in spans:
        meas[s.component] = meas.get(s.component, 0.0) + s.dur
        counts[s.component] = counts.get(s.component, 0) + 1
        p = _predict(s)
        if p is not None:
            pred[s.component] = pred.get(s.component, 0.0) + p
            pred_n[s.component] = pred_n.get(s.component, 0) + 1

    total = sum(r.dur for r in span_roots(spans))
    rows: List[ComponentRow] = []
    for comp in sorted(meas, key=lambda c: -meas[c]):
        predicted: Optional[float] = None
        if comp in pred:
            # scale the priced subtotal up to the full span count so a
            # partially-priced component still compares like-for-like
            predicted = pred[comp] * counts[comp] / pred_n[comp]
        rows.append(
            ComponentRow(
                component=comp,
                n=counts[comp],
                measured_s=meas[comp],
                predicted_s=predicted,
                share=meas[comp] / total if total > 0 else 0.0,
            )
        )

    # kernel rows from the tune cache: predicted decode step at batch b
    # vs the measured mean decode span at that batch
    by_batch: Dict[int, List[float]] = {}
    for s in spans:
        if s.component == "engine.decode" and s.attrs.get("batch"):
            by_batch.setdefault(int(s.attrs["batch"]), []).append(s.dur)
    seen_kernel: Dict[int, TuneEvent] = {}
    for t in tunes:
        b = int(t.shape.get("b", t.shape.get("batch", 0)) or 0)
        if t.family == kernel_family and b > 0:
            seen_kernel[b] = t  # last tune wins, matches cache semantics
    for b in sorted(seen_kernel):
        durs = by_batch.get(b)
        if not durs:
            continue
        t = seen_kernel[b]
        rows.append(
            ComponentRow(
                component=f"kernel/{kernel_family}@b{b}",
                n=len(durs),
                measured_s=sum(durs) / len(durs),
                predicted_s=n_layers * t.us_per_call * 1e-6,
                share=0.0,  # informational row: not part of the span total
            )
        )

    return Attribution(rows=rows, total_measured_s=total, n_spans=len(spans))


def format_attribution(attr: Attribution) -> str:
    """Render the attribution report as an aligned text table."""
    header = (
        f"{'component':<32} {'n':>6} {'measured_s':>11} {'predicted_s':>12} "
        f"{'ratio':>6} {'share':>7}"
    )
    lines = [header, "-" * len(header)]
    for r in attr.rows:
        pred = f"{r.predicted_s:>12.4f}" if r.predicted_s is not None else f"{'-':>12}"
        ratio = f"{r.ratio:>6.2f}" if r.ratio is not None else f"{'-':>6}"
        lines.append(
            f"{r.component:<32} {r.n:>6} {r.measured_s:>11.4f} {pred} {ratio} {r.share:>6.1%}"
        )
    lines.append(f"total (root spans): {attr.total_measured_s:.4f}s over {attr.n_spans} spans")
    # drift is the *slow* direction only: a component comfortably under its
    # predicted budget (e.g. serve latency below its SLO target) is healthy
    slow = [r for r in attr.rows if r.ratio is not None and r.ratio > 1.5]
    if slow:
        worst = max(slow, key=lambda r: r.ratio)
        lines.append(
            f"drift suspect: {worst.component} measured/predicted = {worst.ratio:.2f}x"
        )
    return "\n".join(lines)
