"""repro.telemetry.trace — hierarchical spans, attribution, SLO burn rate.

Layered on the PR-7 event bus: ``SpanTracer`` emits deterministic-ID
``SpanEvent``s from instrumented scopes across the serve and fleet
stacks; ``attribution`` rolls a trace into per-component
predicted-vs-measured rows; ``export`` renders Perfetto JSON and text
trees; ``slo`` turns latency streams into error-budget burn alerts.
"""

from .attribution import Attribution, ComponentRow, attribute, format_attribution
from .export import (
    flame_summary,
    format_tree,
    load_perfetto,
    span_roots,
    to_perfetto,
    total_span_time,
    validate_perfetto,
    write_perfetto,
)
from .slo import SloConfig, SLOMonitor, monitor_serve_events
from .spans import CountingClock, SpanHandle, SpanTracer, det_id

__all__ = [
    "Attribution",
    "ComponentRow",
    "attribute",
    "format_attribution",
    "flame_summary",
    "format_tree",
    "load_perfetto",
    "span_roots",
    "to_perfetto",
    "total_span_time",
    "validate_perfetto",
    "write_perfetto",
    "SloConfig",
    "SLOMonitor",
    "monitor_serve_events",
    "CountingClock",
    "SpanHandle",
    "SpanTracer",
    "det_id",
]
