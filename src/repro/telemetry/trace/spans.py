"""Hierarchical span tracing on top of the telemetry bus.

A ``SpanTracer`` opens nested timed scopes and emits one ``SpanEvent``
per scope onto a ``Tracker`` when the scope closes.  Two properties make
traces replayable:

* **Deterministic identity** — ``trace_id`` and every ``span_id`` are
  blake2b digests of the run seed plus a monotonic per-tracer sequence
  number.  No wall-clock, PID, or randomness feeds the IDs, so two runs
  from the same seed produce the same span tree, span for span.
* **Injectable clock** — timestamps come from ``clock()`` (default
  ``time.perf_counter``).  Inject a ``CountingClock`` (or a modeled
  virtual clock, as the fleet simulator does) and the *values* are
  deterministic too, making whole trace files byte-identical across
  replays.

Spans nest via an explicit stack: the innermost open span is the parent
of the next one opened.  Events are emitted in close order (children
before parents), which every reader here handles.
"""

from __future__ import annotations

import hashlib
import time
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Iterator, List, Optional, Tuple

from ..events import SpanEvent
from ..tracker import MemorySink, Tracker


def det_id(*parts: Any) -> str:
    """16-hex-char blake2b digest of the given parts — a deterministic ID."""
    h = hashlib.blake2b("/".join(str(p) for p in parts).encode(), digest_size=8)
    return h.hexdigest()


class CountingClock:
    """Deterministic fake clock: advances a fixed tick per reading.

    Used by tests (and ``--trace-clock steps``) to make span *values*
    reproducible, turning byte-identical trace files into a testable
    invariant instead of a best-effort claim."""

    def __init__(self, tick: float = 1e-3, t: float = 0.0):
        self.tick = float(tick)
        self.t = float(t)

    def __call__(self) -> float:
        self.t += self.tick
        return self.t


@dataclass
class _Frame:
    """One open span on the tracer stack."""

    span_id: str
    parent_id: str
    name: str
    component: str
    step: int
    t0: float
    predicted_s: Optional[float] = None
    attrs: Dict[str, Any] = field(default_factory=dict)


class SpanHandle:
    """Yielded by ``SpanTracer.span`` so the body can annotate the span."""

    def __init__(self, frame: _Frame):
        self._frame = frame

    @property
    def span_id(self) -> str:
        return self._frame.span_id

    def set(self, **attrs: Any) -> "SpanHandle":
        self._frame.attrs.update(attrs)
        return self

    def predict(self, predicted_s: Optional[float]) -> "SpanHandle":
        self._frame.predicted_s = predicted_s
        return self


class SpanTracer:
    """Emit nested ``SpanEvent``s with deterministic identity.

    One tracer corresponds to one trace (one engine run, one router, one
    fleet sim).  ``replica`` tags every span it emits; a router assigns
    it after construction via ``set_trace``."""

    def __init__(
        self,
        tracker: Optional[Tracker] = None,
        *,
        trace: Tuple[Any, ...] = ("run",),
        replica: int = -1,
        clock: Optional[Callable[[], float]] = None,
    ):
        self.tracker = tracker if tracker is not None else Tracker([MemorySink()])
        self.clock: Callable[[], float] = clock if clock is not None else time.perf_counter
        self.replica = replica
        self.trace_id = det_id("trace", *trace)
        self._seq = 0
        self._stack: List[_Frame] = []
        self._epoch: Optional[float] = None

    def set_trace(self, *trace: Any, replica: Optional[int] = None) -> None:
        """Re-key the trace identity (e.g. once a router assigns a replica).

        Only legal before the first span is opened — re-keying mid-trace
        would orphan already-emitted spans."""
        if self._seq or self._stack:
            raise RuntimeError("cannot re-key a trace after spans were emitted")
        self.trace_id = det_id("trace", *trace)
        if replica is not None:
            self.replica = replica

    # -- time ----------------------------------------------------------------

    def now(self) -> float:
        """Seconds since the tracer epoch (first clock reading = 0)."""
        t = float(self.clock())
        if self._epoch is None:
            self._epoch = t
        return t - self._epoch

    # -- span API ------------------------------------------------------------

    @property
    def depth(self) -> int:
        return len(self._stack)

    def _next_id(self) -> str:
        sid = det_id(self.trace_id, self._seq)
        self._seq += 1
        return sid

    @contextmanager
    def span(
        self,
        name: str,
        *,
        step: int = 0,
        component: str = "",
        predicted_s: Optional[float] = None,
        **attrs: Any,
    ) -> Iterator[SpanHandle]:
        """Open a timed scope; the ``SpanEvent`` is emitted on exit."""
        parent = self._stack[-1].span_id if self._stack else ""
        frame = _Frame(
            span_id=self._next_id(),
            parent_id=parent,
            name=name,
            component=component or name,
            step=step,
            t0=self.now(),
            predicted_s=predicted_s,
            attrs=dict(attrs),
        )
        self._stack.append(frame)
        try:
            yield SpanHandle(frame)
        finally:
            self._stack.pop()
            self._emit(frame, self.now() - frame.t0)

    def emit_span(
        self,
        name: str,
        *,
        dur: float,
        t0: Optional[float] = None,
        step: int = 0,
        component: str = "",
        predicted_s: Optional[float] = None,
        **attrs: Any,
    ) -> SpanEvent:
        """Emit a span with explicit timing (no scope entered).

        For pre-measured or modeled durations — a queue wait that spans
        earlier steps, a fleet tick on the virtual clock.  Parents to the
        innermost open span, like ``span``."""
        frame = _Frame(
            span_id=self._next_id(),
            parent_id=self._stack[-1].span_id if self._stack else "",
            name=name,
            component=component or name,
            step=step,
            t0=self.now() - dur if t0 is None else t0,
            predicted_s=predicted_s,
            attrs=dict(attrs),
        )
        return self._emit(frame, dur)

    def _emit(self, frame: _Frame, dur: float) -> SpanEvent:
        ev = SpanEvent(
            trace_id=self.trace_id,
            span_id=frame.span_id,
            parent_id=frame.parent_id,
            name=frame.name,
            component=frame.component,
            step=frame.step,
            replica=self.replica,
            t0=frame.t0,
            dur=dur,
            predicted_s=frame.predicted_s,
            attrs=frame.attrs,
        )
        self.tracker.emit(ev)
        return ev
