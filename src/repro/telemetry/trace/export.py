"""Trace export: Perfetto/Chrome ``trace_event`` JSON + text renderings.

``to_perfetto`` maps ``SpanEvent``s onto complete (``"ph": "X"``) trace
events — the JSON object format both ``chrome://tracing`` and the
Perfetto UI load directly.  Replicas map to Chrome "threads" so a routed
deployment renders as one lane per replica.  Serialization is fully
deterministic (stable sort, sorted keys), so byte-identical span streams
produce byte-identical files.

``format_tree`` and ``flame_summary`` are the terminal-friendly views
used by ``python -m repro.telemetry trace``.
"""

from __future__ import annotations

import json
from typing import Any, Dict, List, Optional, Sequence

from .. import io as tio
from ..events import Event, SpanEvent

_US = 1e6  # trace_event timestamps are microseconds


def _spans(events: Sequence[Event]) -> List[SpanEvent]:
    return [e for e in events if isinstance(e, SpanEvent)]


def _sort_key(s: SpanEvent):
    # stable, content-only ordering: start time, longest-first (parents
    # before their children at the same t0), then ID as the tiebreak
    return (s.replica, s.t0, -s.dur, s.span_id)


def to_perfetto(events: Sequence[Event], *, process_name: str = "repro.serve") -> Dict[str, Any]:
    """Render spans as a Chrome/Perfetto ``trace_event`` JSON object."""
    spans = sorted(_spans(events), key=_sort_key)
    rows: List[Dict[str, Any]] = []
    tids = sorted({max(s.replica, 0) for s in spans}) or [0]
    rows.append(
        {
            "ph": "M",
            "name": "process_name",
            "pid": 0,
            "tid": 0,
            "args": {"name": process_name},
        }
    )
    for tid in tids:
        rows.append(
            {
                "ph": "M",
                "name": "thread_name",
                "pid": 0,
                "tid": tid,
                "args": {"name": f"replica{tid}"},
            }
        )
    for s in spans:
        args: Dict[str, Any] = {
            "span_id": s.span_id,
            "parent_id": s.parent_id,
            "trace_id": s.trace_id,
            "step": s.step,
        }
        if s.predicted_s is not None:
            args["predicted_s"] = s.predicted_s
        for k in sorted(s.attrs):
            args[k] = s.attrs[k]
        rows.append(
            {
                "ph": "X",
                "name": s.name,
                "cat": s.component,
                "ts": round(s.t0 * _US, 3),
                "dur": round(s.dur * _US, 3),
                "pid": 0,
                "tid": max(s.replica, 0),
                "args": args,
            }
        )
    return {"traceEvents": rows, "displayTimeUnit": "ms"}


def write_perfetto(path, events: Sequence[Event], *, process_name: str = "repro.serve") -> int:
    """Atomically write the Perfetto JSON; returns the span count."""
    payload = to_perfetto(events, process_name=process_name)
    tio.atomic_write_text(path, json.dumps(payload, sort_keys=True, indent=1) + "\n")
    return sum(1 for r in payload["traceEvents"] if r["ph"] == "X")


def validate_perfetto(payload: Any) -> List[str]:
    """Schema-check a trace_event payload; returns a list of problems."""
    errs: List[str] = []
    if not isinstance(payload, dict) or "traceEvents" not in payload:
        return ["payload is not a dict with a traceEvents list"]
    rows = payload["traceEvents"]
    if not isinstance(rows, list):
        return ["traceEvents is not a list"]
    seen_ids = set()
    n_spans = 0
    for i, r in enumerate(rows):
        if not isinstance(r, dict):
            errs.append(f"row {i}: not an object")
            continue
        ph = r.get("ph")
        if ph not in ("X", "M"):
            errs.append(f"row {i}: unsupported ph {ph!r}")
            continue
        for key in ("name", "pid", "tid"):
            if key not in r:
                errs.append(f"row {i}: missing {key!r}")
        if ph != "X":
            continue
        n_spans += 1
        for key in ("ts", "dur"):
            v = r.get(key)
            if not isinstance(v, (int, float)) or isinstance(v, bool):
                errs.append(f"row {i}: {key} not numeric")
            elif v < 0:
                errs.append(f"row {i}: {key} negative ({v})")
        args = r.get("args", {})
        sid = args.get("span_id")
        if not sid:
            errs.append(f"row {i}: args.span_id missing")
        elif sid in seen_ids:
            errs.append(f"row {i}: duplicate span_id {sid}")
        else:
            seen_ids.add(sid)
    if n_spans == 0:
        errs.append("no complete (ph=X) span rows")
    # parent links must resolve within the file
    for i, r in enumerate(rows):
        if isinstance(r, dict) and r.get("ph") == "X":
            pid = r.get("args", {}).get("parent_id", "")
            if pid and pid not in seen_ids:
                errs.append(f"row {i}: parent_id {pid} not in file")
    return errs


def format_tree(
    events: Sequence[Event],
    *,
    max_roots: int = 20,
    max_children: int = 12,
) -> str:
    """Indented span tree: one block per root span, children nested."""
    spans = sorted(_spans(events), key=_sort_key)
    if not spans:
        return "(no spans)"
    by_id = {s.span_id: s for s in spans}
    children: Dict[str, List[SpanEvent]] = {}
    roots: List[SpanEvent] = []
    for s in spans:
        if s.parent_id and s.parent_id in by_id:
            children.setdefault(s.parent_id, []).append(s)
        else:
            roots.append(s)
    lines: List[str] = []

    def _fmt(s: SpanEvent, depth: int) -> None:
        pred = f"  pred={s.predicted_s * 1e3:.3f}ms" if s.predicted_s is not None else ""
        rep = f" r{s.replica}" if s.replica >= 0 else ""
        lines.append(
            f"{'  ' * depth}{s.name:<{max(24 - 2 * depth, 8)}}"
            f" {s.dur * 1e3:9.3f}ms{pred}  [{s.component}{rep} step={s.step}]"
        )
        kids = children.get(s.span_id, [])
        for c in kids[:max_children]:
            _fmt(c, depth + 1)
        if len(kids) > max_children:
            lines.append(f"{'  ' * (depth + 1)}... {len(kids) - max_children} more children")

    shown = roots[:max_roots]
    for r in shown:
        _fmt(r, 0)
    if len(roots) > max_roots:
        lines.append(f"... {len(roots) - max_roots} more root spans")
    lines.append(f"{len(spans)} spans, {len(roots)} roots")
    return "\n".join(lines)


def flame_summary(events: Sequence[Event], *, width: int = 40) -> str:
    """Per-component aggregate bars — a flat 'flame' view of where time went.

    Only root-relative *self* time would need the full tree; for the
    flat summary each component's total span time is enough because the
    instrumented scopes per component do not nest within themselves."""
    spans = _spans(events)
    if not spans:
        return "(no spans)"
    totals: Dict[str, float] = {}
    counts: Dict[str, int] = {}
    child_total: Dict[str, float] = {}
    by_id = {s.span_id: s for s in spans}
    for s in spans:
        totals[s.component] = totals.get(s.component, 0.0) + s.dur
        counts[s.component] = counts.get(s.component, 0) + 1
        if s.parent_id and s.parent_id in by_id:
            p = by_id[s.parent_id]
            child_total[p.span_id] = child_total.get(p.span_id, 0.0) + s.dur
    # self time per component = own dur minus time covered by children
    self_totals: Dict[str, float] = {}
    for s in spans:
        self_totals[s.component] = self_totals.get(s.component, 0.0) + max(
            0.0, s.dur - child_total.get(s.span_id, 0.0)
        )
    total_self = sum(self_totals.values()) or 1.0
    lines = [f"{'component':<24} {'n':>6} {'self_s':>10} {'share':>7}"]
    for comp in sorted(self_totals, key=lambda c: -self_totals[c]):
        share = self_totals[comp] / total_self
        bar = "#" * max(1, int(round(share * width))) if self_totals[comp] > 0 else ""
        lines.append(
            f"{comp:<24} {counts[comp]:>6} {self_totals[comp]:>10.4f} {share:>6.1%} {bar}"
        )
    return "\n".join(lines)


def load_perfetto(path) -> Dict[str, Any]:
    """Read a Perfetto JSON file back (for validation round trips)."""
    with open(path, "r", encoding="utf-8") as f:
        return json.load(f)


def span_roots(events: Sequence[Event]) -> List[SpanEvent]:
    """Spans with no in-stream parent (the top-level scopes)."""
    spans = _spans(events)
    ids = {s.span_id for s in spans}
    return [s for s in spans if not s.parent_id or s.parent_id not in ids]


def total_span_time(events: Sequence[Event], component: Optional[str] = None) -> float:
    """Sum of root span durations (or all spans of one component)."""
    if component is not None:
        return sum(s.dur for s in _spans(events) if s.component == component)
    return sum(s.dur for s in span_roots(events))
