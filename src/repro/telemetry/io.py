"""Atomic filesystem primitives every telemetry writer goes through.

Two write patterns cover every sink and cache in the repo:

* **whole-file JSON** (``atomic_write_json``): write-temp-then-rename in
  the destination directory, so a concurrent reader sees either the old
  file or the new one, never a torn write.  The kernel-tune config cache
  and the run-log ``save()`` paths both route here — two processes
  sweeping the same key (CI slow job + tier-1 overlap) can no longer
  corrupt ``tune_cache.json``.
* **append-only JSONL** (``append_jsonl``): one ``os.write`` on an
  ``O_APPEND`` descriptor per flush.  POSIX appends of a single write
  are atomic with respect to other appenders, so concurrent writers
  interleave whole lines, never partial ones.
"""

from __future__ import annotations

import contextlib
import fcntl
import json
import os
import tempfile
from pathlib import Path
from typing import Any, Iterable, List


def atomic_write_text(path, text: str) -> None:
    """Write ``text`` to ``path`` via temp-file + rename (same directory,
    so the rename never crosses a filesystem boundary)."""
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=path.parent, suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as f:
            f.write(text)
        os.replace(tmp, path)
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise


def atomic_write_json(path, payload: Any, *, indent: int = 2, sort_keys: bool = True) -> None:
    """Atomically serialize ``payload`` as JSON to ``path``."""
    atomic_write_text(path, json.dumps(payload, indent=indent, sort_keys=sort_keys))


def atomic_write_bytes(path, data: bytes) -> None:
    """Binary twin of :func:`atomic_write_text` — checkpoint shards route
    here so a crash mid-save can never leave a torn ``.npz``."""
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=path.parent, suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            f.write(data)
        os.replace(tmp, path)
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise


@contextlib.contextmanager
def file_lock(path):
    """Exclusive advisory lock on a sidecar file, serializing
    read-merge-write cycles across processes (the atomic rename alone
    keeps files untorn but lets two concurrent merges drop entries)."""
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    fd = os.open(path, os.O_WRONLY | os.O_CREAT, 0o644)
    try:
        fcntl.flock(fd, fcntl.LOCK_EX)
        yield
    finally:
        fcntl.flock(fd, fcntl.LOCK_UN)
        os.close(fd)


def append_jsonl(path, lines: Iterable[str]) -> int:
    """Append ``lines`` (no trailing newlines) to ``path`` as one atomic
    ``os.write``.  Returns the number of lines appended."""
    lines = list(lines)
    if not lines:
        return 0
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    data = ("\n".join(lines) + "\n").encode("utf-8")
    fd = os.open(path, os.O_WRONLY | os.O_CREAT | os.O_APPEND, 0o644)
    try:
        os.write(fd, data)
    finally:
        os.close(fd)
    return len(lines)


def read_jsonl(path) -> List[dict]:
    """Parse every non-empty line of a JSONL file."""
    out: List[dict] = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out
