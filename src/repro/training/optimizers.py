"""Optimizers (optax-free): AdamW and Adafactor.

Each optimizer also maps the params' *logical axes* tree onto its state tree
(``init_axes``) so the dry-run can construct shardings for the optimizer
state (ZeRO-style: state is sharded exactly like its parameter).

Adafactor (factored second moments, no first moment) is the default for
>=70B-parameter archs: Adam's fp32 (m, v) alone would not fit 16 GB/chip for
jamba-398B on a 256-chip pod.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]                 # params -> state
    update: Callable[[Any, Any, Any, jnp.ndarray], Tuple[Any, Any]]
    # (grads, state, params, lr) -> (new_params, new_state)
    init_axes: Callable[[Any], Any]            # param axes tree -> state axes tree
    name: str = "opt"


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale, tree), norm


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------
def adamw(b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
        return {"mu": jax.tree.map(zeros, params),
                "nu": jax.tree.map(zeros, params),
                "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, lr):
        count = state["count"] + 1
        cf = count.astype(jnp.float32)
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                          state["mu"], grads)
        nu = jax.tree.map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state["nu"], grads)
        mu_hat_scale = 1.0 / (1 - b1 ** cf)
        nu_hat_scale = 1.0 / (1 - b2 ** cf)

        def step(p, m, v):
            upd = (m * mu_hat_scale) / (jnp.sqrt(v * nu_hat_scale) + eps)
            upd = upd + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * upd).astype(p.dtype)

        new_params = jax.tree.map(step, params, mu, nu)
        return new_params, {"mu": mu, "nu": nu, "count": count}

    def init_axes(axes):
        return {"mu": axes, "nu": axes, "count": ()}

    return Optimizer(init, update, init_axes, name="adamw")


# ---------------------------------------------------------------------------
# Adafactor (Shazeer & Stern 2018; factored v, no momentum)
# ---------------------------------------------------------------------------
def adafactor(eps: float = 1e-30, clip_threshold: float = 1.0,
              decay: float = 0.8, weight_decay: float = 0.0) -> Optimizer:
    def _factored(p) -> bool:
        return p.ndim >= 2

    def init(params):
        def leaf(p):
            if _factored(p):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
            return {"v": jnp.zeros_like(p, dtype=jnp.float32)}

        return {"v": jax.tree.map(leaf, params),
                "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, lr):
        count = state["count"] + 1
        cf = count.astype(jnp.float32)
        beta2 = 1.0 - cf ** (-decay)

        def leaf(p, g, s):
            gf = g.astype(jnp.float32)
            g2 = jnp.square(gf) + eps
            if _factored(p):
                vr = beta2 * s["vr"] + (1 - beta2) * g2.mean(-1)
                vc = beta2 * s["vc"] + (1 - beta2) * g2.mean(-2)
                denom = jnp.maximum(vr.mean(-1, keepdims=True), eps)
                rhat = (vr / denom)[..., None]
                upd = gf * jax.lax.rsqrt(rhat * vc[..., None, :] + eps)
                new_s = {"vr": vr, "vc": vc}
            else:
                v = beta2 * s["v"] + (1 - beta2) * g2
                upd = gf * jax.lax.rsqrt(v + eps)
                new_s = {"v": v}
            # update clipping by RMS
            rms = jnp.sqrt(jnp.mean(jnp.square(upd)) + 1e-30)
            upd = upd / jnp.maximum(1.0, rms / clip_threshold)
            if weight_decay:
                upd = upd + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * upd).astype(p.dtype), new_s

        flat_p, treedef = jax.tree.flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_s = treedef.flatten_up_to(state["v"])
        new_p, new_s = zip(*[leaf(p, g, s) for p, g, s
                             in zip(flat_p, flat_g, flat_s)])
        return (jax.tree.unflatten(treedef, new_p),
                {"v": jax.tree.unflatten(treedef, new_s), "count": count})

    def init_axes(axes):
        from repro.dist.treeutil import map_axes

        def leaf(ax):
            ax = tuple(ax)
            if len(ax) >= 2:
                return {"vr": ax[:-1], "vc": ax[:-2] + ax[-1:]}
            return {"v": ax}

        return {"v": map_axes(leaf, axes), "count": ()}

    return Optimizer(init, update, init_axes, name="adafactor")


def get_optimizer(name: str, **kw) -> Optimizer:
    if name == "adamw":
        return adamw(**kw)
    if name == "adafactor":
        return adafactor(**kw)
    raise ValueError(f"unknown optimizer {name!r}")


def default_optimizer_for(n_params: int) -> str:
    """Adafactor for huge models (fp32 Adam state would not fit per chip)."""
    return "adafactor" if n_params > 40e9 else "adamw"
